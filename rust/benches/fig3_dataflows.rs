//! Bench: regenerate the paper's Fig. 3 (runtime breakdown + HBM BW
//! utilization for FA-2/FA-3/Flat/FlatColl/FlatAsyn over six MHA layers)
//! and time the simulation itself.
//!
//!     cargo bench --bench fig3_dataflows

#[path = "harness.rs"]
mod harness;

use flatattention::report::{fig3, ReportOpts};
use flatattention::util::pool;

fn main() {
    let opts = ReportOpts { quick: false, threads: pool::default_threads() };

    harness::section("Fig. 3 regeneration (paper output)");
    let text = fig3::render(&opts, None);
    println!("{text}");

    harness::section("simulation cost");
    harness::bench("fig3 full grid (30 simulations)", 3, || fig3::run(&opts));
    let quick = ReportOpts { quick: true, ..opts };
    harness::bench("fig3 quick grid (5 simulations)", 5, || fig3::run(&quick));
}
