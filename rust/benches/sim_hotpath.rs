//! Bench: the simulator's own hot path (program build + DES execution) —
//! the §Perf optimization target. Measures the optimized path (template
//! stamping + symmetry folding + arena + sealed CSR + indexed event
//! queue) against the retained seed baseline (naive per-block emission +
//! `BinaryHeap` reference executor, which re-derives the CSR per run),
//! reports events/second at several scales, measures the symmetry-folding
//! speedup on the Flash 32×32 grid sweep, measures the sharded parallel
//! executor plus the end-to-end parallel sweep path (`sim_parallel`
//! section: `parallel_e2e_speedup`, target ≥ 2x at 8 threads), and writes
//! machine-readable results to `BENCH_sim_hotpath.json` at the repo root.
//!
//!     cargo bench --bench sim_hotpath
//!
//! `BENCH_SMOKE=1` shrinks grids and iteration counts for CI (the
//! `rust-bench` job), keeping every recorded metric measured for real.

#[path = "harness.rs"]
mod harness;

use flatattention::analysis::Roofline;
use flatattention::arch::presets;
use flatattention::coordinator::{run_all_uncached, ExperimentSpec};
use flatattention::dataflow::{
    build_program, build_program_in, run, set_symmetry_folding, set_template_stamping,
    tracked_tile, Dataflow, Workload,
};
use flatattention::sim::{execute, execute_parallel, execute_reference, ProgramArena};

const OUT_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim_hotpath.json");

fn main() {
    let smoke = harness::smoke();
    let iters = if smoke { 2 } else { 5 };
    let arch = presets::table1();
    let mut rec = harness::Recorder::new();
    let all_cases = [
        ("flat  S4096 D128 H32 B2 G32", Workload::new(4096, 128, 32, 2), Dataflow::FlatAsyn, 32),
        ("flat  S2048 D128 H32 B4 G8 ", Workload::new(2048, 128, 32, 4), Dataflow::FlatAsyn, 8),
        ("flash S4096 D128 H32 B2    ", Workload::new(4096, 128, 32, 2), Dataflow::Flash3, 1),
    ];
    let cases = if smoke { &all_cases[..1] } else { &all_cases[..] };

    harness::section("program construction (template-stamped + arena vs naive)");
    let mut arena = ProgramArena::new();
    for (label, wl, df, g) in cases {
        let p = build_program(&arch, wl, *df, *g);
        println!("  {label}: {} ops, {} resources", p.num_ops(), p.num_resources());
        rec.metric(&format!("num_ops {label}"), p.num_ops() as f64);
        set_template_stamping(false);
        rec.bench(&format!("build/naive   {label}"), iters, || build_program(&arch, wl, *df, *g));
        set_template_stamping(true);
        rec.bench(&format!("build/stamped {label}"), iters, || build_program(&arch, wl, *df, *g));
        rec.bench(&format!("build/arena   {label}"), iters, || {
            let p = build_program_in(&mut arena, &arch, wl, *df, *g);
            let n = p.num_ops();
            arena.recycle(p);
            n
        });
    }

    harness::section("DES execution (indexed queue + sealed CSR vs seed heap engine)");
    for (label, wl, df, g) in cases {
        let p = build_program(&arch, wl, *df, *g);
        let n = p.num_ops();
        let tracked = tracked_tile(&arch, *df, *g);
        rec.bench(&format!("execute/reference {label}"), iters, || execute_reference(&p, tracked));
        let mean = rec.bench(&format!("execute/indexed   {label}"), iters, || execute(&p, tracked));
        println!("    -> {:.2} M ops/s (indexed)", n as f64 / mean / 1e6);
        rec.metric(&format!("mops_per_s {label}"), n as f64 / mean / 1e6);
    }

    harness::section("end-to-end (build + execute, FlatAsyn S4096 D128)");
    let (label, wl, df, g) = &cases[0];
    let tracked = tracked_tile(&arch, *df, *g);
    // Seed-equivalent baseline: naive builder + heap engine, unfolded.
    // The builder now always seals, which the seed never paid (the heap
    // engine derives its own CSR), so the raw baseline over-counts by
    // exactly one CSR pass — measure that pass and subtract it for the
    // corrected number. (Residual bias runs the other way: the "naive"
    // builder still shares the hoisted-cost/dep-buffer micro-optimizations
    // the seed lacked, so the corrected speedup is a conservative lower
    // bound vs the seed.)
    set_template_stamping(false);
    set_symmetry_folding(false);
    let base_raw = rec.bench("e2e/baseline full run flatasyn S4096 D128", iters, || {
        let p = build_program(&arch, wl, *df, *g);
        execute_reference(&p, tracked)
    });
    set_template_stamping(true);
    set_symmetry_folding(true);
    let mut p_seal = build_program(&arch, wl, *df, *g);
    let seal_cost = rec.bench("csr/seal (baseline correction)", iters, || {
        p_seal.unseal();
        p_seal.seal();
    });
    let base = (base_raw - seal_cost).max(0.0);
    // Optimized path as `dataflow::run` executes it (arena-recycled).
    let opt = rec.bench("e2e/optimized full run flatasyn S4096 D128", iters, || {
        let p = build_program_in(&mut arena, &arch, wl, *df, *g);
        let stats = execute(&p, tracked);
        arena.recycle(p);
        stats
    });
    let speedup = base / opt;
    println!("\n  end-to-end speedup ({label}): {speedup:.2}x seal-corrected (target >= 2x)");
    rec.metric("e2e_baseline_raw_s", base_raw);
    rec.metric("e2e_baseline_seal_correction_s", seal_cost);
    rec.metric("e2e_baseline_s", base);
    rec.metric("e2e_optimized_s", opt);
    rec.metric("e2e_speedup", speedup);

    harness::section("symmetry folding (folded vs unfolded, Flash 32x32 grid sweep)");
    // The ROADMAP symmetry-folding target: the Flash dataflow on the
    // Table-I 32×32 mesh simulates ~1024 congruent tile streams; folding
    // keeps the 1/32-per-channel contention exact while collapsing 1023
    // streams' private compute. Sweep a few layer shapes end to end
    // (build + execute through `dataflow::run`'s arena path).
    let all_fold_sweep = [
        Workload::new(4096, 128, 64, 2),
        Workload::new(4096, 128, 32, 2),
        Workload::new(2048, 128, 64, 1),
        Workload::new(2048, 64, 32, 2),
    ];
    let fold_sweep = if smoke { &all_fold_sweep[2..] } else { &all_fold_sweep[..] };
    let fold_iters = if smoke { 2 } else { 3 };
    {
        let p_folded = build_program(&arch, &fold_sweep[0], Dataflow::Flash2, 1);
        set_symmetry_folding(false);
        let p_unfolded = build_program(&arch, &fold_sweep[0], Dataflow::Flash2, 1);
        set_symmetry_folding(true);
        println!(
            "  flash2 {}: {} ops folded ({} streams) vs {} unfolded",
            fold_sweep[0].label(),
            p_folded.num_ops(),
            p_folded.fold.streams,
            p_unfolded.num_ops()
        );
        rec.metric("fold_num_ops_folded", p_folded.num_ops() as f64);
        rec.metric("fold_num_ops_unfolded", p_unfolded.num_ops() as f64);
        rec.metric("fold_streams", p_folded.fold.streams as f64);
    }
    set_symmetry_folding(false);
    let unfolded_t = rec.bench("fold/e2e unfolded flash2 32x32 sweep", fold_iters, || {
        fold_sweep
            .iter()
            .map(|wl| run(&arch, wl, Dataflow::Flash2, 1).makespan)
            .sum::<u64>()
    });
    set_symmetry_folding(true);
    let folded_t = rec.bench("fold/e2e folded   flash2 32x32 sweep", fold_iters, || {
        fold_sweep
            .iter()
            .map(|wl| run(&arch, wl, Dataflow::Flash2, 1).makespan)
            .sum::<u64>()
    });
    let fold_speedup = unfolded_t / folded_t;
    println!("\n  folding e2e speedup (flash2 32x32 sweep): {fold_speedup:.2}x (target >= 3x)");
    rec.metric("fold_e2e_unfolded_s", unfolded_t);
    rec.metric("fold_e2e_folded_s", folded_t);
    rec.metric("fold_e2e_speedup", fold_speedup);

    harness::section("sharded parallel DES + parallel sweep (sim_parallel)");
    // Within one program: the sharded executor on an unfolded Flash2 grid
    // (per-tile stream shards arbitrating through the shared HBM shard —
    // the full-fidelity mode where folding is off by definition, e.g.
    // `flatattention trace`). Informational metric: the epoch fences
    // bound this win by how many shards carry events per timestamp.
    let par_wl =
        if smoke { Workload::new(1024, 128, 32, 1) } else { Workload::new(2048, 128, 32, 2) };
    set_symmetry_folding(false);
    let p = build_program(&arch, &par_wl, Dataflow::Flash2, 1);
    set_symmetry_folding(true);
    println!("  flash2 {}: {} shards, {} ops", par_wl.label(), p.num_shards(), p.num_ops());
    rec.metric("parallel_num_shards", p.num_shards() as f64);
    let one_serial =
        rec.bench("parallel/1prog serial    flash2 32x32", fold_iters, || execute(&p, 0));
    let one_par = rec.bench("parallel/1prog 8 workers flash2 32x32", fold_iters, || {
        execute_parallel(&p, 0, 8)
    });
    rec.metric("parallel_1prog_speedup", one_serial / one_par);

    // The e2e target: the Flash2 32×32 sweep through the production sweep
    // path (`coordinator::run_all_uncached` = build + DES per point over
    // the worker pool), 1 thread vs 8. Point-level fan-out composes with
    // the sharded executor (`coordinator::set_engine_threads`); the
    // in-bench target is >= 2x at 8 threads, checked by
    // scripts/check_bench_targets.py (which skips the gate on starved
    // < 3-core runners where 2x is arithmetically out of reach).
    let seqs: &[u64] = if smoke { &[512, 1024] } else { &[1024, 2048, 4096] };
    let mut sweep: Vec<ExperimentSpec> = Vec::new();
    for &s in seqs {
        for &d in &[64u64, 128] {
            for &h in &[16u64, 32] {
                sweep.push(ExperimentSpec {
                    arch: arch.clone(),
                    workload: Workload::new(s, d, h, 1),
                    dataflow: Dataflow::Flash2,
                    group: 1,
                });
            }
        }
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("  sweep: {} Flash2 32x32 points, {} cores available", sweep.len(), cores);
    // Three iterations even in smoke mode: the gated ratio below takes
    // the best of N, and N=3 gives the minimum something to work with.
    let sweep_iters = 3;
    let serial_name = format!("parallel/e2e sweep {} pts, 1 thread ", sweep.len());
    let par_name = format!("parallel/e2e sweep {} pts, 8 threads", sweep.len());
    let sweep_serial = rec.bench(&serial_name, sweep_iters, || run_all_uncached(&sweep, 1));
    let sweep_par = rec.bench(&par_name, sweep_iters, || run_all_uncached(&sweep, 8));
    // The gated ratio uses best-of-N: on shared CI runners a single
    // noisy-neighbor interval skews a mean, not a minimum.
    let parallel_speedup = rec.min_of(&serial_name).unwrap_or(sweep_serial)
        / rec.min_of(&par_name).unwrap_or(sweep_par);
    println!(
        "\n  parallel e2e speedup (flash2 32x32 sweep @ 8 threads): {parallel_speedup:.2}x \
         (target >= 2x)"
    );
    rec.metric("parallel_threads", 8.0);
    rec.metric("parallel_cores_available", cores as f64);
    rec.metric("parallel_e2e_serial_s", sweep_serial);
    rec.metric("parallel_e2e_parallel_s", sweep_par);
    rec.metric("parallel_e2e_speedup", parallel_speedup);

    harness::section("roofline cross-check (analysis::Roofline, makespan >= bound)");
    // Every benched schedule must respect the analytical lower bounds —
    // a "speedup" that finishes faster than the hardware could move the
    // bytes or do the flops is a simulator bug, not a win. Checked on the
    // headline case; utilization against the binding bound is tracked in
    // the report JSON (gated <= 1.0 by scripts/check_bench_targets.py).
    let (rl_label, rl_wl, rl_df, rl_g) = &cases[0];
    let rl_p = build_program(&arch, rl_wl, *rl_df, *rl_g);
    let rl_stats = execute(&rl_p, tracked_tile(&arch, *rl_df, *rl_g));
    let rep = Roofline::of(&arch, rl_wl, &rl_p)
        .check(rl_stats.makespan)
        .unwrap_or_else(|d| panic!("{rl_label}: {d}"));
    println!(
        "  {rl_label}: {} bound {} cycles, utilization {:.1}%",
        rep.binding,
        rep.bound,
        rep.utilization * 100.0
    );
    rec.metric("roofline_utilization", rep.utilization);

    rec.write_json(OUT_PATH, "sim_hotpath");
    if speedup < 2.0 {
        println!("WARNING: end-to-end speedup {speedup:.2}x below the 2x acceptance target");
    }
    if fold_speedup < 3.0 {
        println!("WARNING: folding speedup {fold_speedup:.2}x below the 3x acceptance target");
    }
    if parallel_speedup < 2.0 {
        println!(
            "WARNING: parallel e2e speedup {parallel_speedup:.2}x below the 2x acceptance target \
             ({cores} cores available)"
        );
    }
}
