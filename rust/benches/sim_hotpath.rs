//! Bench: the simulator's own hot path (program build + DES execution) —
//! the §Perf optimization target. Reports events/second at several scales.
//!
//!     cargo bench --bench sim_hotpath

#[path = "harness.rs"]
mod harness;

use flatattention::arch::presets;
use flatattention::dataflow::{build_program, Dataflow, Workload};
use flatattention::sim::execute;

fn main() {
    let arch = presets::table1();

    harness::section("program construction");
    for (label, wl, df, g) in [
        ("flat  S4096 D128 H32 B2 G32", Workload::new(4096, 128, 32, 2), Dataflow::FlatAsyn, 32),
        ("flat  S2048 D128 H32 B4 G8 ", Workload::new(2048, 128, 32, 4), Dataflow::FlatAsyn, 8),
        ("flash S4096 D128 H32 B2    ", Workload::new(4096, 128, 32, 2), Dataflow::Flash3, 1),
    ] {
        let p = build_program(&arch, &wl, df, g);
        println!("  {label}: {} ops, {} resources", p.num_ops(), p.num_resources());
        harness::bench(&format!("build   {label}"), 5, || build_program(&arch, &wl, df, g));
    }

    harness::section("DES execution");
    for (label, wl, df, g) in [
        ("flat  S4096 D128 H32 B2 G32", Workload::new(4096, 128, 32, 2), Dataflow::FlatAsyn, 32),
        ("flat  S2048 D128 H32 B4 G8 ", Workload::new(2048, 128, 32, 4), Dataflow::FlatAsyn, 8),
        ("flash S4096 D128 H32 B2    ", Workload::new(4096, 128, 32, 2), Dataflow::Flash3, 1),
    ] {
        let p = build_program(&arch, &wl, df, g);
        let n = p.num_ops();
        let mean = harness::bench(&format!("execute {label}"), 5, || execute(&p, 0));
        println!("    -> {:.2} M ops/s", n as f64 / mean / 1e6);
    }

    harness::section("end-to-end (build + execute)");
    let wl = Workload::new(4096, 128, 32, 2);
    harness::bench("full run flatasyn S4096 D128", 5, || {
        let p = build_program(&arch, &wl, Dataflow::FlatAsyn, 32);
        execute(&p, 0)
    });
}
