//! Bench: the simulator's own hot path (program build + DES execution) —
//! the §Perf optimization target. Measures the optimized path (template
//! stamping + symmetry folding + arena + sealed CSR + indexed event
//! queue) against the retained seed baseline (naive per-block emission +
//! `BinaryHeap` reference executor, which re-derives the CSR per run),
//! reports events/second at several scales, measures the symmetry-folding
//! speedup on the Flash 32×32 grid sweep, and writes machine-readable
//! results to `BENCH_sim_hotpath.json` at the repo root.
//!
//!     cargo bench --bench sim_hotpath

#[path = "harness.rs"]
mod harness;

use flatattention::arch::presets;
use flatattention::dataflow::{
    build_program, build_program_in, run, set_symmetry_folding, set_template_stamping,
    tracked_tile, Dataflow, Workload,
};
use flatattention::sim::{execute, execute_reference, ProgramArena};

const OUT_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim_hotpath.json");

fn main() {
    let arch = presets::table1();
    let mut rec = harness::Recorder::new();
    let cases = [
        ("flat  S4096 D128 H32 B2 G32", Workload::new(4096, 128, 32, 2), Dataflow::FlatAsyn, 32),
        ("flat  S2048 D128 H32 B4 G8 ", Workload::new(2048, 128, 32, 4), Dataflow::FlatAsyn, 8),
        ("flash S4096 D128 H32 B2    ", Workload::new(4096, 128, 32, 2), Dataflow::Flash3, 1),
    ];

    harness::section("program construction (template-stamped + arena vs naive)");
    let mut arena = ProgramArena::new();
    for (label, wl, df, g) in cases {
        let p = build_program(&arch, &wl, df, g);
        println!("  {label}: {} ops, {} resources", p.num_ops(), p.num_resources());
        rec.metric(&format!("num_ops {label}"), p.num_ops() as f64);
        set_template_stamping(false);
        rec.bench(&format!("build/naive   {label}"), 5, || build_program(&arch, &wl, df, g));
        set_template_stamping(true);
        rec.bench(&format!("build/stamped {label}"), 5, || build_program(&arch, &wl, df, g));
        rec.bench(&format!("build/arena   {label}"), 5, || {
            let p = build_program_in(&mut arena, &arch, &wl, df, g);
            let n = p.num_ops();
            arena.recycle(p);
            n
        });
    }

    harness::section("DES execution (indexed queue + sealed CSR vs seed heap engine)");
    for (label, wl, df, g) in cases {
        let p = build_program(&arch, &wl, df, g);
        let n = p.num_ops();
        let tracked = tracked_tile(&arch, df, g);
        rec.bench(&format!("execute/reference {label}"), 5, || execute_reference(&p, tracked));
        let mean = rec.bench(&format!("execute/indexed   {label}"), 5, || execute(&p, tracked));
        println!("    -> {:.2} M ops/s (indexed)", n as f64 / mean / 1e6);
        rec.metric(&format!("mops_per_s {label}"), n as f64 / mean / 1e6);
    }

    harness::section("end-to-end (build + execute, FlatAsyn S4096 D128)");
    let (label, wl, df, g) = cases[0];
    let tracked = tracked_tile(&arch, df, g);
    // Seed-equivalent baseline: naive builder + heap engine, unfolded.
    // The builder now always seals, which the seed never paid (the heap
    // engine derives its own CSR), so the raw baseline over-counts by
    // exactly one CSR pass — measure that pass and subtract it for the
    // corrected number. (Residual bias runs the other way: the "naive"
    // builder still shares the hoisted-cost/dep-buffer micro-optimizations
    // the seed lacked, so the corrected speedup is a conservative lower
    // bound vs the seed.)
    set_template_stamping(false);
    set_symmetry_folding(false);
    let base_raw = rec.bench("e2e/baseline full run flatasyn S4096 D128", 5, || {
        let p = build_program(&arch, &wl, df, g);
        execute_reference(&p, tracked)
    });
    set_template_stamping(true);
    set_symmetry_folding(true);
    let mut p_seal = build_program(&arch, &wl, df, g);
    let seal_cost = rec.bench("csr/seal (baseline correction)", 5, || {
        p_seal.unseal();
        p_seal.seal();
    });
    let base = (base_raw - seal_cost).max(0.0);
    // Optimized path as `dataflow::run` executes it (arena-recycled).
    let opt = rec.bench("e2e/optimized full run flatasyn S4096 D128", 5, || {
        let p = build_program_in(&mut arena, &arch, &wl, df, g);
        let stats = execute(&p, tracked);
        arena.recycle(p);
        stats
    });
    let speedup = base / opt;
    println!("\n  end-to-end speedup ({label}): {speedup:.2}x seal-corrected (target >= 2x)");
    rec.metric("e2e_baseline_raw_s", base_raw);
    rec.metric("e2e_baseline_seal_correction_s", seal_cost);
    rec.metric("e2e_baseline_s", base);
    rec.metric("e2e_optimized_s", opt);
    rec.metric("e2e_speedup", speedup);

    harness::section("symmetry folding (folded vs unfolded, Flash 32x32 grid sweep)");
    // The ROADMAP symmetry-folding target: the Flash dataflow on the
    // Table-I 32×32 mesh simulates ~1024 congruent tile streams; folding
    // keeps the 1/32-per-channel contention exact while collapsing 1023
    // streams' private compute. Sweep a few layer shapes end to end
    // (build + execute through `dataflow::run`'s arena path).
    let fold_sweep = [
        Workload::new(4096, 128, 64, 2),
        Workload::new(4096, 128, 32, 2),
        Workload::new(2048, 128, 64, 1),
        Workload::new(2048, 64, 32, 2),
    ];
    {
        let p_folded = build_program(&arch, &fold_sweep[0], Dataflow::Flash2, 1);
        set_symmetry_folding(false);
        let p_unfolded = build_program(&arch, &fold_sweep[0], Dataflow::Flash2, 1);
        set_symmetry_folding(true);
        println!(
            "  flash2 S4096 D128 H64 B2: {} ops folded ({} streams) vs {} unfolded",
            p_folded.num_ops(),
            p_folded.fold.streams,
            p_unfolded.num_ops()
        );
        rec.metric("fold_num_ops_folded", p_folded.num_ops() as f64);
        rec.metric("fold_num_ops_unfolded", p_unfolded.num_ops() as f64);
        rec.metric("fold_streams", p_folded.fold.streams as f64);
    }
    set_symmetry_folding(false);
    let unfolded_t = rec.bench("fold/e2e unfolded flash2 32x32 sweep", 3, || {
        fold_sweep
            .iter()
            .map(|wl| run(&arch, wl, Dataflow::Flash2, 1).makespan)
            .sum::<u64>()
    });
    set_symmetry_folding(true);
    let folded_t = rec.bench("fold/e2e folded   flash2 32x32 sweep", 3, || {
        fold_sweep
            .iter()
            .map(|wl| run(&arch, wl, Dataflow::Flash2, 1).makespan)
            .sum::<u64>()
    });
    let fold_speedup = unfolded_t / folded_t;
    println!("\n  folding e2e speedup (flash2 32x32 sweep): {fold_speedup:.2}x (target >= 3x)");
    rec.metric("fold_e2e_unfolded_s", unfolded_t);
    rec.metric("fold_e2e_folded_s", folded_t);
    rec.metric("fold_e2e_speedup", fold_speedup);

    rec.write_json(OUT_PATH, "sim_hotpath");
    if speedup < 2.0 {
        println!("WARNING: end-to-end speedup {speedup:.2}x below the 2x acceptance target");
    }
    if fold_speedup < 3.0 {
        println!("WARNING: folding speedup {fold_speedup:.2}x below the 3x acceptance target");
    }
}
