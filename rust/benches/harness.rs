//! Shared micro-benchmark harness (criterion is unavailable offline).
//!
//! Each bench target is `harness = false` with its own `main`; this module
//! provides wall-clock measurement with warmup, min/mean/max reporting,
//! a simple table printer compatible with `cargo bench` output, and a
//! [`Recorder`] that additionally captures every measurement for
//! machine-readable JSON export (`BENCH_sim_hotpath.json` at the repo
//! root records the perf trajectory across PRs).

use std::time::Instant;

/// True when `BENCH_SMOKE` is set (and not `0`): CI smoke mode. Each
/// harness shrinks its sweep grids / iteration counts so the whole bench
/// suite finishes in minutes while still measuring every recorded metric
/// for real — the `rust-bench` CI job runs with this knob and checks the
/// in-bench targets on the produced `BENCH_*.json`.
#[allow(dead_code)]
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Measure `f` for `iters` iterations after one warmup; prints a
/// `test ... bench:` style line and returns the mean seconds per iter.
#[allow(dead_code)]
pub fn bench<R>(name: &str, iters: usize, f: impl FnMut() -> R) -> f64 {
    measure(name, iters, f).mean_s
}

/// One recorded measurement.
#[allow(dead_code)]
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub iters: usize,
}

fn measure<R>(name: &str, iters: usize, mut f: impl FnMut() -> R) -> Measurement {
    std::hint::black_box(f()); // warmup
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let min_s = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max_s = times.iter().cloned().fold(0.0, f64::max);
    let mean_s = times.iter().sum::<f64>() / times.len() as f64;
    println!(
        "bench {name:<52} {:>10.3} ms/iter (min {:.3}, max {:.3}, n={iters})",
        mean_s * 1e3,
        min_s * 1e3,
        max_s * 1e3
    );
    Measurement { name: name.to_string(), mean_s, min_s, max_s, iters }
}

/// Pretty section header.
#[allow(dead_code)]
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Collects measurements plus free-form scalar metrics and writes them as
/// a JSON report.
#[allow(dead_code)]
#[derive(Debug, Default)]
pub struct Recorder {
    pub measurements: Vec<Measurement>,
    pub metrics: Vec<(String, f64)>,
}

#[allow(dead_code)]
impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Like [`bench`], but records the measurement.
    pub fn bench<R>(&mut self, name: &str, iters: usize, f: impl FnMut() -> R) -> f64 {
        let m = measure(name, iters, f);
        let mean = m.mean_s;
        self.measurements.push(m);
        mean
    }

    /// Record a derived scalar (speedups, op counts, events/s, ...).
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    /// Mean seconds of a recorded measurement by name.
    pub fn mean_of(&self, name: &str) -> Option<f64> {
        self.measurements.iter().find(|m| m.name == name).map(|m| m.mean_s)
    }

    /// Minimum (best-of-N) seconds of a recorded measurement by name —
    /// the noise-robust basis for speedup ratios that gate CI (a single
    /// noisy-neighbor interval on a shared runner skews a mean, not a
    /// minimum).
    pub fn min_of(&self, name: &str) -> Option<f64> {
        self.measurements.iter().find(|m| m.name == name).map(|m| m.min_s)
    }

    /// Serialize to a JSON string (no external deps; flat schema).
    pub fn to_json(&self, bench_name: &str) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{bench_name}\",\n"));
        out.push_str("  \"unit\": \"seconds_per_iter\",\n");
        out.push_str("  \"measurements\": [\n");
        for (i, m) in self.measurements.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"mean_s\": {:.6e}, \"min_s\": {:.6e}, \"max_s\": {:.6e}, \"iters\": {}}}{}\n",
                m.name.replace('"', "'"),
                m.mean_s,
                m.min_s,
                m.max_s,
                m.iters,
                if i + 1 < self.measurements.len() { "," } else { "" }
            ));
        }
        out.push_str("  ],\n");
        out.push_str("  \"metrics\": {\n");
        for (i, (k, v)) in self.metrics.iter().enumerate() {
            out.push_str(&format!(
                "    \"{}\": {:.6}{}\n",
                k.replace('"', "'"),
                v,
                if i + 1 < self.metrics.len() { "," } else { "" }
            ));
        }
        out.push_str("  }\n}\n");
        out
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &str, bench_name: &str) {
        match std::fs::write(path, self.to_json(bench_name)) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("\nerror writing {path}: {e}"),
        }
    }
}
