//! Shared micro-benchmark harness (criterion is unavailable offline).
//!
//! Each bench target is `harness = false` with its own `main`; this module
//! provides wall-clock measurement with warmup, min/mean/max reporting,
//! and a simple table printer compatible with `cargo bench` output.

use std::time::Instant;

/// Measure `f` for `iters` iterations after one warmup; prints a
/// `test ... bench:` style line and returns the mean seconds per iter.
pub fn bench<R>(name: &str, iters: usize, mut f: impl FnMut() -> R) -> f64 {
    std::hint::black_box(f()); // warmup
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    println!(
        "bench {name:<52} {:>10.3} ms/iter (min {:.3}, max {:.3}, n={iters})",
        mean * 1e3,
        min * 1e3,
        max * 1e3
    );
    mean
}

/// Pretty section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
