//! Bench: serving-scheduler throughput — continuous batching of a mixed
//! prefill+decode request stream on the Table-I 32×32 mesh, Flash2 vs the
//! FlatAttention family, plus the continuous-vs-static batching headline
//! on the skewed-output burst trace (short requests free their slot while
//! long ones keep decoding — the effect continuous batching exists for).
//! Writes `BENCH_schedule_sweep.json` at the repo root.
//!
//!     cargo bench --bench schedule_sweep

#[path = "harness.rs"]
mod harness;

use flatattention::arch::presets;
use flatattention::dataflow::{set_template_stamping, Dataflow};
use flatattention::scheduler::{
    route, simulate, try_simulate_with, BatchPolicy, RequestTrace, RouterConfig, SchedulerConfig,
};
use flatattention::sim::FaultPlan;
use flatattention::telemetry::RunTelemetry;

const OUT_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_schedule_sweep.json");

fn main() {
    let smoke = harness::smoke();
    let iters = if smoke { 1 } else { 2 };
    let arch = presets::table1();
    let mut rec = harness::Recorder::new();
    let kv_heads = 8; // GQA 32/8, the serving default

    // Mixed staggered trace: scheduler wall-clock throughput per dataflow.
    // `BENCH_SMOKE` drops the FlatAsyn replay (async schedules never fold,
    // so it dominates wall clock) — the asserted continuous-vs-static
    // targets below only involve Flash2/FlatColl and run either way.
    let trace = RequestTrace::builtin("mixed", kv_heads).expect("builtin trace");
    harness::section("schedule sweep (Table I arch, slots=4, chunk=512)");
    let replay_dfs: &[Dataflow] = if smoke {
        &[Dataflow::Flash2, Dataflow::FlatColl]
    } else {
        &[Dataflow::Flash2, Dataflow::FlatColl, Dataflow::FlatAsyn]
    };
    let mut tps = Vec::new();
    for &df in replay_dfs {
        let cfg = SchedulerConfig::new(df);
        let mut last = None;
        rec.bench(&format!("replay/{}", df.label()), iters, || {
            let r = simulate(&arch, &trace, &cfg);
            let t = r.tokens_per_s;
            last = Some(r);
            t
        });
        let r = last.expect("ran");
        println!(
            "  {}: {:.0} tokens/s, TTFT {:.3} ms, TPOT {:.4} ms, occupancy {:.1}%",
            df.label(),
            r.tokens_per_s,
            r.ttft_mean_ms,
            r.tpot_mean_ms,
            r.occupancy * 100.0
        );
        rec.metric(&format!("tokens_per_s_{}", df.label()), r.tokens_per_s);
        tps.push((df, r.tokens_per_s));
    }
    let fa2 = tps[0].1;
    let flat = tps[1].1;
    rec.metric("flat_over_fa2_tokens_per_s", flat / fa2.max(1e-9));

    // Continuous vs static batching on the burst trace.
    harness::section("continuous vs static batching (burst trace, skewed outputs)");
    let burst = RequestTrace::builtin("burst", kv_heads).expect("burst trace");
    let mut speedups = Vec::new();
    for df in [Dataflow::Flash2, Dataflow::FlatColl] {
        let cont = simulate(
            &arch,
            &burst,
            &SchedulerConfig { policy: BatchPolicy::Continuous, ..SchedulerConfig::new(df) },
        );
        let stat = simulate(
            &arch,
            &burst,
            &SchedulerConfig { policy: BatchPolicy::Static, ..SchedulerConfig::new(df) },
        );
        let speedup = cont.tokens_per_s / stat.tokens_per_s.max(1e-9);
        println!(
            "  {}: continuous {:.0} vs static {:.0} tokens/s -> {speedup:.2}x",
            df.label(),
            cont.tokens_per_s,
            stat.tokens_per_s
        );
        rec.metric(&format!("continuous_over_static_{}", df.label()), speedup);
        speedups.push(speedup);
    }

    // Target: continuous batching must beat static batching by >= 1.5x on
    // the skewed burst (the slot-starvation shape it was designed for).
    assert!(
        speedups.iter().all(|&s| s >= 1.5),
        "continuous/static speedups {speedups:?} below the 1.5x target"
    );

    // Degradation under faults: replay the mixed trace through the
    // graceful-degradation router fault-free, then with the last 1/8 of
    // the HBM channels (one serving slot's channel-affine KV partition)
    // derated to half bandwidth for the whole run. Prefill steps are
    // compute-bound and decode steps are short (serving_sweep pins
    // decode_over_prefill_makespan <= 0.1), so a healthy stack keeps most
    // of its throughput — the in-bench target gates exactly that.
    harness::section("degradation under faults (derated KV channels, router)");
    let cfg = SchedulerConfig::new(Dataflow::FlatColl);
    let free = route(&arch, &trace, &cfg, &RouterConfig::default());
    let total = arch.hbm.total_channels() as u32;
    let k = (total / 8).max(1);
    let faults = (total - k..total)
        .fold(FaultPlan::none(), |p, c| p.with_derate(c, 0, u64::MAX / 2, 2, 1));
    let rc = RouterConfig { faults, ..RouterConfig::default() };
    let degraded = route(&arch, &trace, &cfg, &rc);
    assert_eq!(degraded.expired, 0, "derated channels must degrade, not drop, requests");
    assert_eq!(degraded.serving.tokens, free.serving.tokens, "token accounting is fault-invariant");
    let ratio = degraded.serving.tokens_per_s / free.serving.tokens_per_s.max(1e-9);
    println!(
        "  flatcoll: fault-free {:.0} vs derated {:.0} tokens/s -> {ratio:.2}x retained",
        free.serving.tokens_per_s,
        degraded.serving.tokens_per_s
    );
    rec.metric("degraded_over_faultfree_tokens_per_s", ratio);

    // Target: with 1/8 of the channels at half bandwidth the router must
    // retain >= 0.6 of fault-free serving throughput.
    assert!(
        ratio >= 0.6,
        "degraded/fault-free throughput {ratio:.3} below the 0.6 target"
    );

    // §Incremental composition: replay a recurring-shape synthetic stream
    // in the default composer mode (template stamping + in-place cost
    // patching + solo-run memoization) and in the full-rebuild mode every
    // step used to pay (stamping off, every step re-emitted, re-sealed
    // and re-run through the DES). tests/incremental_differential.rs pins
    // the two bit-identical, so the ratio is pure composition cost.
    harness::section("incremental step composition (recurring-shape stream)");
    let n = if smoke { 192 } else { 384 };
    let stream = RequestTrace::synthetic(n, 1_000);
    let inc_cfg = SchedulerConfig::new(Dataflow::Flash2);
    let mut full_cfg = inc_cfg.clone();
    full_cfg.incremental = false;
    full_cfg.memoize = false;
    rec.bench("incremental/replay", iters, || simulate(&arch, &stream, &inc_cfg).tokens);
    set_template_stamping(false);
    rec.bench("incremental/full_rebuild", iters, || simulate(&arch, &stream, &full_cfg).tokens);
    set_template_stamping(true);
    let fast = rec.min_of("incremental/replay").expect("recorded");
    let slow = rec.min_of("incremental/full_rebuild").expect("recorded");
    let speedup = slow / fast.max(1e-12);
    println!(
        "  {n}-request stream: full rebuild {:.0} ms vs incremental {:.0} ms -> {speedup:.1}x",
        slow * 1e3,
        fast * 1e3
    );
    rec.metric("step_compose_speedup", speedup);

    // Target: the incremental composer must beat a per-step full rebuild
    // by >= 5x on the recurring-shape stream (ISSUE 8 acceptance; the
    // ROADMAP "Million-request scale" item rides on this ratio).
    assert!(
        speedup >= 5.0,
        "incremental-over-rebuild speedup {speedup:.2} below the 5x target"
    );

    // Million-request scale: at steady state the recurring shapes turn
    // nearly every step into a memo merge, so the replay cost is bounded
    // by the scheduler loop rather than the DES. Smoke mode scales the
    // stream down but records the actual request count, so the JSON
    // never overstates what ran; `schedule --trace synthetic:1000000`
    // replays the full-size stream from the CLI.
    harness::section("million-request synthetic stream");
    let m = if smoke { 50_000 } else { 1_000_000 };
    let mstream = RequestTrace::synthetic(m, 500);
    let mut mlast = None;
    let wall = rec.bench("incremental/synthetic_stream", 1, || {
        let r = simulate(&arch, &mstream, &inc_cfg);
        let done = r.requests.len();
        mlast = Some(r);
        done
    });
    let mrep = mlast.expect("ran");
    assert_eq!(mrep.requests.len(), m, "every synthetic request must complete");
    let rps = m as f64 / wall.max(1e-12);
    println!(
        "  {m} requests replayed in {wall:.2} s wall ({rps:.0} requests/s, {} steps)",
        mrep.steps
    );
    rec.metric("synthetic_stream_requests", m as f64);
    rec.metric("synthetic_stream_requests_per_s", rps);

    // Target: the stream must complete and replay at a rate only the
    // incremental path can reach (a full rebuild per step is orders of
    // magnitude below this floor at scale).
    assert!(
        rps >= 1_000.0,
        "synthetic stream replayed at {rps:.0} requests/s, below the 1000/s floor"
    );

    // §Telemetry: replay the mixed trace with no sink (the default path —
    // the scheduler entry points take Option<&mut RunTelemetry> and None
    // must stay free) and with the full sink attached (windowed metrics +
    // lifecycle trace). The off/on wall-clock ratio is recorded and gated
    // >= 0.95 by scripts/check_bench_targets.py: instrumentation may cost
    // at most ~5%. The sink's engine_ counters also expose the composer's
    // patch/memo effectiveness as hit-rate metrics.
    harness::section("telemetry overhead (mixed trace, flash2)");
    rec.bench("telemetry/off", iters, || simulate(&arch, &trace, &inc_cfg).tokens);
    let mut tel_last = None;
    rec.bench("telemetry/on", iters, || {
        let mut tel = RunTelemetry::new().with_trace();
        let r =
            try_simulate_with(&arch, &trace, &inc_cfg, Some(&mut tel)).expect("valid config");
        tel_last = Some(tel);
        r.tokens
    });
    let t_off = rec.min_of("telemetry/off").expect("recorded");
    let t_on = rec.min_of("telemetry/on").expect("recorded");
    let retained = t_off / t_on.max(1e-12);
    println!(
        "  off {:.1} ms vs on {:.1} ms -> off/on {retained:.3} (target >= 0.95)",
        t_off * 1e3,
        t_on * 1e3
    );
    rec.metric("telemetry_overhead", retained);
    let tel = tel_last.expect("ran");
    let hits = tel.metrics.counter("engine_solo_memo_hits") as f64;
    let misses = tel.metrics.counter("engine_solo_memo_misses") as f64;
    let patched = tel.metrics.counter("engine_steps_patched") as f64;
    let resealed = tel.metrics.counter("engine_steps_resealed") as f64;
    let memo_hit_rate = hits / (hits + misses).max(1.0);
    let patch_hit_rate = patched / (patched + resealed).max(1.0);
    println!("  memo hit rate {memo_hit_rate:.3}, patch hit rate {patch_hit_rate:.3}");
    rec.metric("memo_hit_rate", memo_hit_rate);
    rec.metric("patch_hit_rate", patch_hit_rate);

    // Roofline cross-check on the fault-free serving replay: the bytes it
    // moved over the aggregate HBM bandwidth bound any schedule's run
    // time from below (each step's makespan >= its bytes / peak BW, and
    // steps are sequential). Utilization against that bound is tracked
    // across PRs and gated <= 1.0 by scripts/check_bench_targets.py.
    let hbm_bound = free.serving.hbm_bytes.div_ceil(arch.hbm.peak_bytes_per_cycle());
    assert!(
        free.serving.total_cycles >= hbm_bound,
        "serving replay finished in {} cycles, below the HBM roofline bound {} — \
         the scheduler moved bytes faster than the hardware could",
        free.serving.total_cycles,
        hbm_bound
    );
    let rl_util = hbm_bound as f64 / free.serving.total_cycles.max(1) as f64;
    println!("  roofline (fault-free replay): HBM bound {hbm_bound} cycles, utilization {:.1}%", rl_util * 100.0);
    rec.metric("roofline_utilization", rl_util);

    rec.write_json(OUT_PATH, "schedule_sweep");
}
