//! Bench: serving-scheduler throughput — continuous batching of a mixed
//! prefill+decode request stream on the Table-I 32×32 mesh, Flash2 vs the
//! FlatAttention family, plus the continuous-vs-static batching headline
//! on the skewed-output burst trace (short requests free their slot while
//! long ones keep decoding — the effect continuous batching exists for).
//! Writes `BENCH_schedule_sweep.json` at the repo root.
//!
//!     cargo bench --bench schedule_sweep

#[path = "harness.rs"]
mod harness;

use flatattention::arch::presets;
use flatattention::dataflow::Dataflow;
use flatattention::scheduler::{simulate, BatchPolicy, RequestTrace, SchedulerConfig};

const OUT_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_schedule_sweep.json");

fn main() {
    let smoke = harness::smoke();
    let iters = if smoke { 1 } else { 2 };
    let arch = presets::table1();
    let mut rec = harness::Recorder::new();
    let kv_heads = 8; // GQA 32/8, the serving default

    // Mixed staggered trace: scheduler wall-clock throughput per dataflow.
    // `BENCH_SMOKE` drops the FlatAsyn replay (async schedules never fold,
    // so it dominates wall clock) — the asserted continuous-vs-static
    // targets below only involve Flash2/FlatColl and run either way.
    let trace = RequestTrace::builtin("mixed", kv_heads).expect("builtin trace");
    harness::section("schedule sweep (Table I arch, slots=4, chunk=512)");
    let replay_dfs: &[Dataflow] = if smoke {
        &[Dataflow::Flash2, Dataflow::FlatColl]
    } else {
        &[Dataflow::Flash2, Dataflow::FlatColl, Dataflow::FlatAsyn]
    };
    let mut tps = Vec::new();
    for &df in replay_dfs {
        let cfg = SchedulerConfig::new(df);
        let mut last = None;
        rec.bench(&format!("replay/{}", df.label()), iters, || {
            let r = simulate(&arch, &trace, &cfg);
            let t = r.tokens_per_s;
            last = Some(r);
            t
        });
        let r = last.expect("ran");
        println!(
            "  {}: {:.0} tokens/s, TTFT {:.3} ms, TPOT {:.4} ms, occupancy {:.1}%",
            df.label(),
            r.tokens_per_s,
            r.ttft_mean_ms,
            r.tpot_mean_ms,
            r.occupancy * 100.0
        );
        rec.metric(&format!("tokens_per_s_{}", df.label()), r.tokens_per_s);
        tps.push((df, r.tokens_per_s));
    }
    let fa2 = tps[0].1;
    let flat = tps[1].1;
    rec.metric("flat_over_fa2_tokens_per_s", flat / fa2.max(1e-9));

    // Continuous vs static batching on the burst trace.
    harness::section("continuous vs static batching (burst trace, skewed outputs)");
    let burst = RequestTrace::builtin("burst", kv_heads).expect("burst trace");
    let mut speedups = Vec::new();
    for df in [Dataflow::Flash2, Dataflow::FlatColl] {
        let cont = simulate(
            &arch,
            &burst,
            &SchedulerConfig { policy: BatchPolicy::Continuous, ..SchedulerConfig::new(df) },
        );
        let stat = simulate(
            &arch,
            &burst,
            &SchedulerConfig { policy: BatchPolicy::Static, ..SchedulerConfig::new(df) },
        );
        let speedup = cont.tokens_per_s / stat.tokens_per_s.max(1e-9);
        println!(
            "  {}: continuous {:.0} vs static {:.0} tokens/s -> {speedup:.2}x",
            df.label(),
            cont.tokens_per_s,
            stat.tokens_per_s
        );
        rec.metric(&format!("continuous_over_static_{}", df.label()), speedup);
        speedups.push(speedup);
    }

    // Target: continuous batching must beat static batching by >= 1.5x on
    // the skewed burst (the slot-starvation shape it was designed for).
    assert!(
        speedups.iter().all(|&s| s >= 1.5),
        "continuous/static speedups {speedups:?} below the 1.5x target"
    );

    rec.write_json(OUT_PATH, "schedule_sweep");
}
