//! Bench: the §II collective-latency model — regenerates the paper's
//! hardware-vs-software multicast comparison (6.1× at N=7) across message
//! sizes and chain lengths, plus an ablation over link widths.
//!
//!     cargo bench --bench noc_collectives

#[path = "harness.rs"]
mod harness;

use flatattention::arch::NocConfig;
use flatattention::noc::{collective_time, CollectiveKind};
use flatattention::report::section2;

fn noc(hw: bool, link: u64) -> NocConfig {
    NocConfig {
        link_bytes_per_cycle: link,
        router_latency: 4,
        inject_latency: 10,
        hw_collectives: hw,
    }
}

fn main() {
    harness::section("§II worked example (paper output)");
    println!("{}", section2::render_section2());

    harness::section("hw/sw reduction across message sizes (N=31, 1024-bit links)");
    println!("  {:>10}  {:>12}  {:>12}  {:>9}", "bytes", "sw (cyc)", "hw (cyc)", "reduction");
    for kib in [1u64, 4, 16, 64] {
        let bytes = kib * 1024;
        let sw = collective_time(&noc(false, 128), bytes, 31, CollectiveKind::Multicast).total();
        let hw = collective_time(&noc(true, 128), bytes, 31, CollectiveKind::Multicast).total();
        println!("  {:>8}KB  {:>12}  {:>12}  {:>8.1}x", kib, sw, hw, sw as f64 / hw as f64);
    }

    harness::section("link-width ablation (16 KB multicast, N=31)");
    for link in [32u64, 64, 128, 256] {
        let hw = collective_time(&noc(true, link), 16 * 1024, 31, CollectiveKind::Multicast).total();
        println!("  {:>4}-bit link: {hw} cycles", link * 8);
    }

    harness::section("model evaluation cost");
    harness::bench("collective_time x 1M evals", 5, || {
        let c = noc(true, 128);
        let mut acc = 0u64;
        for i in 0..1_000_000u64 {
            acc = acc.wrapping_add(
                collective_time(&c, 1 + (i % 65536), 1 + (i % 31), CollectiveKind::Multicast)
                    .total(),
            );
        }
        acc
    });
}
