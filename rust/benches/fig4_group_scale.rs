//! Bench: regenerate the paper's Fig. 4 (group-scale trade-off /
//! over-flattening) and time the sweep.
//!
//!     cargo bench --bench fig4_group_scale

#[path = "harness.rs"]
mod harness;

use flatattention::report::{fig4, ReportOpts};
use flatattention::util::pool;

fn main() {
    let opts = ReportOpts { quick: false, threads: pool::default_threads() };

    harness::section("Fig. 4 regeneration (paper output)");
    println!("{}", fig4::render(&opts, None));

    harness::section("simulation cost");
    harness::bench("fig4 full sweep (16 simulations)", 3, || fig4::run(&opts));
}
