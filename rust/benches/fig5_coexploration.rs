//! Bench: regenerate the paper's Fig. 5 — (a) architecture co-exploration
//! heatmap, (b) BestArch vs FA-3-on-H100, (c) SUMMA GEMM vs H100 — and the
//! §V-C die-area estimate.
//!
//!     cargo bench --bench fig5_coexploration

#[path = "harness.rs"]
mod harness;

use flatattention::report::{fig5a, fig5b, fig5c, section2, ReportOpts};
use flatattention::util::pool;

fn main() {
    let opts = ReportOpts { quick: false, threads: pool::default_threads() };

    harness::section("Fig. 5a regeneration");
    println!("{}", fig5a::render(&opts, None));

    harness::section("Fig. 5b regeneration");
    println!("{}", fig5b::render(&opts, None));

    harness::section("Fig. 5c regeneration");
    println!("{}", fig5c::render(&opts, None));

    harness::section("§V-C die area");
    println!("{}", section2::render_area());

    harness::section("simulation cost");
    let quick = ReportOpts { quick: true, ..opts };
    harness::bench("fig5a heatmap (quick, 9 cells)", 2, || fig5a::run(&quick));
    harness::bench("fig5b comparison (quick)", 3, || fig5b::run(&quick));
    harness::bench("fig5c GEMMs (quick)", 3, || fig5c::run(&quick));
}
