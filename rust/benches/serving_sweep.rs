//! Bench: serving-shape sweep throughput — GQA/MQA head-sharing, decode
//! (S=1 query against a KV cache) and batched small-S prefill across all
//! dataflows on the Table-I mesh. Measures end-to-end sweep latency
//! (build + execute per point, through the same `dataflow::run` path the
//! coordinator uses), per-phase point rates, and records the modeled
//! serving headlines (decode MQA K/V-traffic reduction, decode vs prefill
//! makespan ratio) so the perf trajectory of the serving path is tracked
//! across PRs in `BENCH_serving_sweep.json` at the repo root.
//!
//!     cargo bench --bench serving_sweep

#[path = "harness.rs"]
mod harness;

use flatattention::analysis::Roofline;
use flatattention::arch::presets;
use flatattention::dataflow::{
    layer_program, run, Dataflow, LayerWorkload, WeightResidency, Workload, ALL_DATAFLOWS,
};
use flatattention::scheduler::{simulate, RequestTrace, SchedulerConfig};
use flatattention::sim::execute;

const OUT_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving_sweep.json");

/// FlatAttention group edge for the serving points (see report::serving).
const GROUP: usize = 8;

fn main() {
    let smoke = harness::smoke();
    let iters = if smoke { 1 } else { 3 };
    let arch = presets::table1();
    let mut rec = harness::Recorder::new();

    // The report::serving grid, bench-sized: one batch per phase so a
    // full iteration stays in seconds. `BENCH_SMOKE` keeps one sequence
    // length per phase (the modeled-headline section below stays at
    // S=4096 either way — its targets are scale-dependent).
    let kv_grid: &[u64] = if smoke { &[32, 1] } else { &[32, 8, 1] };
    let seq_grid: &[u64] = if smoke { &[512] } else { &[512, 4096] };
    let prefill: Vec<Workload> = kv_grid
        .iter()
        .flat_map(|&kv| {
            seq_grid.iter().map(move |&s| Workload::new(s, 128, 32, 4).with_kv_heads(kv))
        })
        .collect();
    let decode: Vec<Workload> = prefill.iter().map(|wl| wl.decode()).collect();

    harness::section("serving sweep (all dataflows, Table I arch, G=8x8)");
    for (phase, wls) in [("prefill", &prefill), ("decode", &decode)] {
        let points = wls.len() * ALL_DATAFLOWS.len();
        let mean = rec.bench(&format!("sweep/{phase} ({points} points)"), iters, || {
            let mut acc = 0u64;
            for wl in wls {
                for df in ALL_DATAFLOWS {
                    let g = if df.is_flat() { GROUP } else { 1 };
                    acc ^= run(&arch, wl, df, g).makespan;
                }
            }
            acc
        });
        rec.metric(&format!("{phase}_points_per_s"), points as f64 / mean);
    }

    harness::section("serving headlines (modeled)");
    let s = 4096u64;
    let dec_mha = run(&arch, &Workload::new(s, 128, 32, 4).decode(), Dataflow::Flash2, 1);
    let dec_mqa = run(
        &arch,
        &Workload::new(s, 128, 32, 4).with_kv_heads(1).decode(),
        Dataflow::Flash2,
        1,
    );
    let kv_reduction = dec_mha.hbm_bytes as f64 / dec_mqa.hbm_bytes as f64;
    println!("  decode S={s} FA-2: MQA traffic reduction {kv_reduction:.2}x (32 KV heads -> 1)");
    rec.metric("decode_mqa_traffic_reduction", kv_reduction);
    // Decode is bandwidth-bound: a single token should cost a tiny
    // fraction of the full-prefill makespan.
    let pre_mha = run(&arch, &Workload::new(s, 128, 32, 4), Dataflow::Flash2, 1);
    let ratio = dec_mha.makespan as f64 / pre_mha.makespan as f64;
    println!("  decode/prefill makespan ratio at S={s}: {ratio:.4}");
    rec.metric("decode_over_prefill_makespan", ratio);

    // Targets: MQA must cut decode traffic by an order of magnitude (the
    // exact model value is ~32x less a small Q/O constant), and a decode
    // step must be far cheaper than a prefill.
    assert!(
        kv_reduction > 10.0,
        "decode MQA traffic reduction {kv_reduction:.2}x below the 10x target"
    );
    assert!(ratio < 0.1, "decode/prefill makespan ratio {ratio:.3} above the 0.1 target");

    // Roofline cross-check: the prefill headline must respect the
    // workload-level analytical lower bounds (flops over peak compute,
    // compulsory bytes over aggregate HBM bandwidth). Utilization against
    // the binding bound is tracked across PRs and gated <= 1.0 by
    // scripts/check_bench_targets.py.
    let rep = Roofline::from_workload(&arch, &Workload::new(s, 128, 32, 4))
        .check(pre_mha.makespan)
        .unwrap_or_else(|d| panic!("prefill S={s} FA-2: {d}"));
    println!(
        "  roofline (prefill S={s} FA-2): {} bound {} cycles, utilization {:.1}%",
        rep.binding,
        rep.bound,
        rep.utilization * 100.0
    );
    rec.metric("roofline_utilization", rep.utilization);

    // Layer serving: full transformer layers per step (attention + the
    // four projection/FFN GEMM tails per request band), two layers deep so
    // requests pipeline across bands at different layer depths. Gated
    // metrics: the layered run's mesh occupancy (pipeline utilization, in
    // (0, 1]) and the roofline utilization of a GEMM-bearing composed
    // layer program — both must stay physical (<= 1.0).
    harness::section("layer serving (2 layers/token, FFN x2, table2-8x8)");
    let sarch = presets::table2(8);
    let mut cfg = SchedulerConfig::new(Dataflow::FlatColl);
    cfg.group = 2;
    cfg.slots = 4;
    cfg.chunk = 128;
    cfg.page_tokens = 32;
    cfg.heads = 8;
    cfg.head_dim = 64;
    cfg.layers = 2;
    cfg.ffn_mult = 2;
    cfg.weights = WeightResidency::HbmStream;
    let trace = RequestTrace::from_rows(
        &[(0, 160, 4), (0, 96, 6), (5_000, 200, 3), (20_000, 128, 5)],
        2,
    );
    let mut occupancy = 0.0f64;
    rec.bench("layered serving replay (4 requests)", iters, || {
        let r = simulate(&sarch, &trace, &cfg);
        occupancy = r.occupancy;
        r.steps as u64
    });
    println!("  layered replay occupancy {:.1}%", occupancy * 100.0);
    rec.metric("layer_pipeline_utilization", occupancy);

    let lw = LayerWorkload::new(
        Workload::new(512, 64, 8, 1).with_kv_heads(2).with_causal(true),
        2,
        WeightResidency::HbmStream,
    );
    let lp = layer_program(&sarch, &lw, Dataflow::FlatColl, 2);
    let layer_stats = execute(&lp.program, 0);
    let layer_rep = Roofline::from_program(&sarch, &lp.program)
        .check(layer_stats.makespan)
        .unwrap_or_else(|d| panic!("composed layer: {d}"));
    println!(
        "  roofline (composed layer, FlatColl g2): {} bound {} cycles, utilization {:.1}%",
        layer_rep.binding,
        layer_rep.bound,
        layer_rep.utilization * 100.0
    );
    rec.metric("layer_roofline_utilization", layer_rep.utilization);

    rec.write_json(OUT_PATH, "serving_sweep");
}
