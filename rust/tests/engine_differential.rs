//! Differential test: the indexed-bucket-queue executor vs the seed
//! `BinaryHeap` reference engine.
//!
//! The event-queue swap (and the move of the dependents CSR into the
//! sealed `Program`) must be *schedule-preserving*: on any DAG, both
//! engines must produce identical `RunStats` (makespan, breakdown,
//! hbm_bytes, busy totals) and identical per-op traces. Randomized DAGs
//! exercise resource contention, zero-duration barriers, pipeline
//! latencies, duplicate deps, wide fan-in/fan-out and equal-time event
//! storms — the cases where tie-breaking differences would surface.

use flatattention::sim::{
    execute, execute_reference, execute_reference_traced, execute_traced, Component, OpId, Program,
};
use flatattention::util::quickcheck::{check, forall_cases};
use flatattention::util::Rng;

const COMPONENTS: [Component; 7] = [
    Component::RedMule,
    Component::Spatz,
    Component::SumReduce,
    Component::MaxReduce,
    Component::Multicast,
    Component::HbmAccess,
    Component::Other,
];

/// Build a random DAG: arbitrary fan-in (with duplicates), mixed
/// occupancy/latency, several resources and tiles, occasional barriers.
fn random_program(rng: &mut Rng) -> Program {
    let mut p = Program::new();
    let n_res = 1 + rng.gen_range(8) as usize;
    let res = p.resources(n_res);
    let n_ops = 5 + rng.gen_range(150) as usize;
    let mut ids: Vec<OpId> = Vec::with_capacity(n_ops);
    for i in 0..n_ops {
        let mut deps: Vec<OpId> = Vec::new();
        if i > 0 {
            for _ in 0..rng.gen_range(4) {
                // Duplicate deps are allowed and must be handled alike.
                deps.push(ids[rng.gen_range(i as u64) as usize]);
            }
        }
        let barrier = rng.gen_range(8) == 0;
        let occupancy = if barrier { 0 } else { rng.gen_range(60) };
        let latency = if rng.gen_range(3) == 0 { rng.gen_range(250) } else { 0 };
        let component = COMPONENTS[rng.gen_range(COMPONENTS.len() as u64) as usize];
        let tile = rng.gen_range(4) as u32;
        let hbm_bytes = if component == Component::HbmAccess {
            1 + rng.gen_range(4096)
        } else {
            0
        };
        let r = res[rng.gen_range(n_res as u64) as usize];
        ids.push(p.op(r, occupancy, latency, component, tile, hbm_bytes, &deps));
    }
    p.flops = rng.gen_range(1 << 30);
    p
}

#[test]
fn indexed_queue_engine_matches_reference_on_random_dags() {
    forall_cases(250, 0xD1FF, |rng| {
        let mut p = random_program(rng);
        let tracked = rng.gen_range(4) as u32;
        let trace_limit = Some(1 + rng.gen_range(4) as u32);

        let (ref_stats, ref_trace) = execute_reference_traced(&p, tracked, trace_limit);

        // Unsealed path (locally-derived CSR)...
        let (new_stats, new_trace) = execute_traced(&p, tracked, trace_limit);
        check(
            ref_stats == new_stats && ref_trace == new_trace,
            format!("unsealed mismatch: ref {ref_stats:?} vs new {new_stats:?}"),
        )?;

        // ...and the sealed path (prebuilt CSR) must agree too.
        p.seal();
        let (sealed_stats, sealed_trace) = execute_traced(&p, tracked, trace_limit);
        check(
            ref_stats == sealed_stats && ref_trace == sealed_trace,
            format!("sealed mismatch: ref {ref_stats:?} vs sealed {sealed_stats:?}"),
        )
    });
}

#[test]
fn engines_agree_on_builder_programs() {
    // Beyond synthetic DAGs: the real dataflow programs (every variant)
    // must execute identically under both engines.
    use flatattention::arch::presets;
    use flatattention::dataflow::{build_program, tracked_tile, Workload, ALL_DATAFLOWS};

    let arch = presets::table2(8);
    let wl = Workload::new(1024, 64, 6, 1);
    for df in ALL_DATAFLOWS {
        let p = build_program(&arch, &wl, df, 4);
        let tracked = tracked_tile(&arch, df, 4);
        let reference = execute_reference(&p, tracked);
        let engine = execute(&p, tracked);
        assert_eq!(reference, engine, "{df:?}");
    }
}

#[test]
fn equal_time_event_storm_is_deterministic() {
    // Many zero-duration ops completing at the same cycle on shared
    // resources: the worst case for tie-breaking. Both engines must agree
    // and repeated runs must be stable.
    let mut p = Program::new();
    let gate_res = p.resource();
    let shared = p.resource();
    let gate = p.op(gate_res, 5, 0, Component::Other, 0, 0, &[]);
    let mut prev: Vec<OpId> = Vec::new();
    for k in 0..200u64 {
        let id = p.op(shared, k % 2, 0, Component::RedMule, (k % 3) as u32, 0, &[gate]);
        prev.push(id);
    }
    let _join = p.op(gate_res, 0, 0, Component::Other, 1, 0, &prev);
    let a = execute(&p, 0);
    let b = execute_reference(&p, 0);
    assert_eq!(a, b);
    let c = execute(&p, 0);
    assert_eq!(a, c);
}
