//! End-to-end reproduction of every quantitative claim in the paper's
//! abstract and evaluation (the tolerances document how close the model
//! lands; EXPERIMENTS.md records the measured values).

use flatattention::analytics::h100::{H100_HBM_GBPS, H100_PEAK_TFLOPS};
use flatattention::arch::area::{AreaModel, H100_DIE_MM2};
use flatattention::arch::presets;
use flatattention::dataflow::{run, Dataflow, Workload};
use flatattention::report::{fig4, fig5b, fig5c, ReportOpts};

fn d128_s4096() -> Workload {
    Workload::new(4096, 128, 32, 2)
}

#[test]
fn claim_utilization_89_3() {
    // "FlatAttention achieves up to 89.3% utilization"
    let arch = presets::table1();
    let stats = run(&arch, &d128_s4096(), Dataflow::FlatAsyn, 32);
    let u = stats.compute_utilization(arch.peak_flops_per_cycle());
    assert!((0.84..0.95).contains(&u), "utilization {u:.3} (paper 0.893)");
}

#[test]
fn claim_speedup_4_1x_over_fa3() {
    // "4.1× performance speedup over FlashAttention-3 dataflow"
    let arch = presets::table1();
    let wl = d128_s4096();
    let fa3 = run(&arch, &wl, Dataflow::Flash3, 32);
    let flat = run(&arch, &wl, Dataflow::FlatAsyn, 32);
    let speedup = fa3.makespan as f64 / flat.makespan as f64;
    assert!((3.0..5.2).contains(&speedup), "speedup {speedup:.2} (paper 4.1)");
}

#[test]
fn claim_hbm_traffic_16x() {
    // "...whilst reducing HBM traffic by 16x"
    let arch = presets::table1();
    let wl = d128_s4096();
    let fa3 = run(&arch, &wl, Dataflow::Flash3, 32);
    let flat = run(&arch, &wl, Dataflow::FlatAsyn, 32);
    let r = fa3.hbm_bytes as f64 / flat.hbm_bytes as f64;
    assert!((14.0..18.0).contains(&r), "traffic reduction {r:.1} (paper 16)");
}

#[test]
fn claim_1_3x_utilization_over_h100() {
    // "up to 1.3× higher utilization over FlashAttention-3 on H100"
    let opts = ReportOpts::default();
    let rows = fig5b::run(&opts);
    let max_ratio = rows.iter().map(|c| c.util_ratio).fold(0.0, f64::max);
    assert!((1.15..1.55).contains(&max_ratio), "max util ratio {max_ratio:.2} (paper 1.3)");
    // And at the headline layer it must exceed H100.
    let d128 = rows
        .iter()
        .find(|c| c.workload.head_dim == 128 && c.workload.seq == 4096)
        .unwrap();
    assert!(d128.util_ratio > 1.0);
}

#[test]
fn claim_40pct_less_hbm_bandwidth() {
    let arch = presets::best_arch();
    let reduction = 1.0 - arch.hbm.peak_gbps(arch.freq_ghz) / H100_HBM_GBPS;
    assert!((0.35..0.45).contains(&reduction), "BW reduction {reduction:.2} (paper 0.40)");
}

#[test]
fn claim_peak_performance_comparable_to_h100() {
    let arch = presets::best_arch();
    let ratio = arch.peak_tflops() / H100_PEAK_TFLOPS;
    assert!((0.95..1.15).contains(&ratio), "peak ratio {ratio:.2}");
}

#[test]
fn claim_die_size_457mm2_1_8x() {
    let area = AreaModel::default().estimate(&presets::best_arch());
    assert!((440.0..475.0).contains(&area.total_mm2), "die {:.0} mm²", area.total_mm2);
    let r = H100_DIE_MM2 / area.total_mm2;
    assert!((1.7..1.9).contains(&r), "reduction {r:.2} (paper 1.8)");
}

#[test]
fn claim_fig4_group_optimum_shifts_with_seq() {
    // §V-B: "For every sequence length, there exists an optimal group
    // scale balancing the two effects."
    let opts = ReportOpts { quick: false, ..Default::default() };
    let results = fig4::run(&opts);
    let best = |seq: u64| {
        results
            .iter()
            .filter(|(_, r)| r.workload.seq == seq)
            .min_by_key(|(_, r)| r.makespan)
            .map(|(g, _)| *g)
            .unwrap()
    };
    let bests: Vec<usize> = [512u64, 1024, 2048, 4096].iter().map(|&s| best(s)).collect();
    // Non-decreasing optimum with sequence length, small at 512, max at 4096.
    assert!(bests.windows(2).all(|w| w[0] <= w[1]), "optima {bests:?} not monotone");
    assert!(bests[0] <= 8, "S=512 optimum {}", bests[0]);
    assert!(bests[3] >= 16, "S=4096 optimum {}", bests[3]);
}

#[test]
fn claim_fig4_16x16_32x32_high_util_at_4096() {
    // "The 16×16 and 32×32 group scales achieve 88% and 87% utilization
    // ... for a sequence length of 4096" (B=4 workload).
    let arch = presets::table1();
    let wl = Workload::new(4096, 128, 32, 4);
    for g in [16usize, 32] {
        let stats = run(&arch, &wl, Dataflow::FlatAsyn, g);
        let u = stats.compute_utilization(arch.peak_flops_per_cycle());
        assert!(u > 0.70, "G={g}: utilization {u:.3} (paper ~0.87-0.88)");
    }
}

#[test]
fn claim_gemm_1_2x_over_h100() {
    let opts = ReportOpts::default();
    let rows = fig5c::run(&opts);
    let max_ratio = rows.iter().map(|c| c.util_ratio).fold(0.0, f64::max);
    assert!((1.05..1.35).contains(&max_ratio), "GEMM ratio {max_ratio:.2} (paper 1.2)");
}

#[test]
fn claim_fa_hbm_bound_80pct() {
    // §V-A: FlashAttention reaches up to ~80% average HBM BW utilization
    // (saturation given request granularity) and stays compute-poor.
    let arch = presets::table1();
    let wl = d128_s4096();
    for df in [Dataflow::Flash2, Dataflow::Flash3] {
        let stats = run(&arch, &wl, df, 1);
        let bw = stats.hbm_bw_utilization(arch.hbm.peak_bytes_per_cycle());
        let cu = stats.compute_utilization(arch.peak_flops_per_cycle());
        assert!(bw > 0.7, "{df:?}: HBM BW {bw:.2}");
        assert!(cu < 0.45, "{df:?}: compute util {cu:.2} should be memory-bound");
    }
}
