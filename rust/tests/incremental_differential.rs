//! §Incremental replay differential wall.
//!
//! The step composer's two levers — in-place cost patching of the sealed
//! step program and memoized solo-run merging — are pure optimizations:
//! every mode must reproduce the full-rebuild scheduler **bit for bit**,
//! reports compared field by field (`ServingReport`/`RouterReport`
//! derive `PartialEq`, so `f64` metrics must match exactly, not within a
//! tolerance). The axes here follow the serving feature matrix: page
//! placements × dataflows × preemption on/off × fault plans, including
//! the band-death requeue and deadline-retry lifecycle paths.

use flatattention::arch::presets;
use flatattention::dataflow::{Dataflow, ALL_DATAFLOWS};
use flatattention::scheduler::{
    route, simulate, RequestTrace, RouterConfig, SchedulerConfig, VictimPolicy, ALL_PLACEMENTS,
};
use flatattention::sim::FaultPlan;

/// (incremental, memoize) — every lever combination beyond the baseline.
const MODES: [(bool, bool); 3] = [(true, false), (false, true), (true, true)];

fn tiny_cfg(df: Dataflow) -> SchedulerConfig {
    let mut cfg = SchedulerConfig::new(df);
    cfg.slots = 4;
    cfg.group = 2;
    cfg.chunk = 96;
    cfg.page_tokens = 32;
    cfg.heads = 4;
    cfg.head_dim = 64;
    cfg
}

/// The reference mode: full rebuild + full DES every step.
fn full_rebuild(cfg: &SchedulerConfig) -> SchedulerConfig {
    let mut c = cfg.clone();
    c.incremental = false;
    c.memoize = false;
    c
}

fn mixed_trace() -> RequestTrace {
    RequestTrace::from_rows(
        &[(0, 160, 4), (0, 96, 8), (5_000, 200, 3), (20_000, 64, 6), (40_000, 128, 5)],
        2,
    )
}

#[test]
fn simulate_modes_match_across_placements_and_dataflows() {
    let arch = presets::table2(8);
    let trace = mixed_trace();
    for df in ALL_DATAFLOWS {
        for placement in ALL_PLACEMENTS {
            let mut cfg = tiny_cfg(df);
            cfg.placement = placement;
            let want = simulate(&arch, &trace, &full_rebuild(&cfg));
            for (inc, memo) in MODES {
                let mut c = cfg.clone();
                c.incremental = inc;
                c.memoize = memo;
                let got = simulate(&arch, &trace, &c);
                assert_eq!(got, want, "{df:?}/{placement:?} inc={inc} memo={memo}");
            }
        }
    }
}

/// Faulted steps compose incrementally but never memoize; a mid-step
/// band death re-queues its request (the §Router band-eviction path) and
/// page pressure evicts or gates admission depending on `preemption`.
/// All of it must replay identically in every composer mode.
#[test]
fn router_modes_match_under_faults_preemption_and_band_death() {
    let arch = presets::table2(8);
    let trace = RequestTrace::from_rows(
        &[(0, 160, 4), (0, 96, 8), (0, 200, 3), (0, 64, 6), (40_000, 128, 5)],
        2,
    );
    // Band 3 (first tile 48) dies almost immediately; every channel runs
    // at half bandwidth for the whole trace.
    let mut death = FaultPlan::none().with_tile_death(48, 1);
    for c in 0..arch.hbm.total_channels() as u32 {
        death = death.with_derate(c, 0, u64::MAX / 2, 2, 1);
    }
    for df in [Dataflow::Flash2, Dataflow::FlatColl] {
        let cfg = tiny_cfg(df);
        for preemption in [true, false] {
            for plan in [FaultPlan::none(), death.clone()] {
                let faulted = !plan.is_none();
                let rc = RouterConfig {
                    faults: plan,
                    max_total_pages: 12,
                    victim: VictimPolicy::Newest,
                    preemption,
                    ..RouterConfig::default()
                };
                let want = route(&arch, &trace, &full_rebuild(&cfg), &rc);
                if faulted {
                    assert!(want.band_evictions >= 1, "the dying band must requeue its request");
                }
                for (inc, memo) in MODES {
                    let mut c = cfg.clone();
                    c.incremental = inc;
                    c.memoize = memo;
                    let got = route(&arch, &trace, &c, &rc);
                    assert_eq!(
                        got, want,
                        "{df:?} preemption={preemption} faulted={faulted} inc={inc} memo={memo}"
                    );
                }
            }
        }
    }
}

#[test]
fn router_modes_match_under_deadline_retries() {
    let arch = presets::table2(8);
    let trace = mixed_trace();
    let cfg = tiny_cfg(Dataflow::Flash2);
    let rc = RouterConfig { deadline: 1, max_retries: 1, ..RouterConfig::default() };
    let want = route(&arch, &trace, &full_rebuild(&cfg), &rc);
    assert!(want.retries >= 1, "the 1-cycle deadline must trigger retries");
    for (inc, memo) in MODES {
        let mut c = cfg.clone();
        c.incremental = inc;
        c.memoize = memo;
        assert_eq!(route(&arch, &trace, &c, &rc), want, "inc={inc} memo={memo}");
    }
}

/// The recurrent synthetic stream is the memo's best case (a bounded
/// shape palette at steady state) — and exactly where a subtly wrong
/// merge rule would silently skew the serving metrics.
#[test]
fn synthetic_stream_replays_identically_in_every_mode() {
    let arch = presets::table2(8);
    let trace = RequestTrace::synthetic(48, 2_000);
    let cfg = tiny_cfg(Dataflow::Flash2);
    let want = simulate(&arch, &trace, &full_rebuild(&cfg));
    assert_eq!(want.requests.len(), 48, "everyone completes");
    for (inc, memo) in MODES {
        let mut c = cfg.clone();
        c.incremental = inc;
        c.memoize = memo;
        assert_eq!(simulate(&arch, &trace, &c), want, "inc={inc} memo={memo}");
    }
}
