//! Coordinator-level integration: parallel runs, sweeps, persistence, and
//! report renderers end to end (quick workloads).

use flatattention::arch::presets;
use flatattention::coordinator::{
    best_group, run_all, run_all_uncached, run_one, valid_groups, ExperimentSpec, ResultStore,
};
use flatattention::dataflow::{Dataflow, Workload, ALL_DATAFLOWS};
use flatattention::report::{fig3, fig4, fig5a, headline, section2, tables, ReportOpts};

fn quick_opts() -> ReportOpts {
    ReportOpts { quick: true, ..Default::default() }
}

#[test]
fn parallel_and_serial_runs_agree() {
    // Thread count must not change results (simulations are independent
    // and deterministic).
    let arch = presets::table1();
    let wl = Workload::new(1024, 128, 8, 1);
    let specs: Vec<ExperimentSpec> = ALL_DATAFLOWS
        .into_iter()
        .map(|df| ExperimentSpec { arch: arch.clone(), workload: wl, dataflow: df, group: 16 })
        .collect();
    let serial = run_all(&specs, 1);
    let parallel = run_all(&specs, 8);
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.makespan, b.makespan, "{}", a.id);
        assert_eq!(a.hbm_bytes, b.hbm_bytes);
    }
}

#[test]
fn best_group_is_actually_best() {
    let arch = presets::table1();
    let wl = Workload::new(2048, 128, 16, 2);
    let best = best_group(&arch, &wl, Dataflow::FlatAsyn, 4);
    for g in valid_groups(&arch) {
        let r = run_one(&ExperimentSpec {
            arch: arch.clone(),
            workload: wl,
            dataflow: Dataflow::FlatAsyn,
            group: g,
        });
        assert!(best.makespan <= r.makespan, "group {g} beats 'best' {}", best.group);
    }
}

#[test]
fn full_report_pipeline_with_store() {
    let mut store = ResultStore::new();
    let opts = quick_opts();
    let t3 = fig3::render(&opts, Some(&mut store));
    assert!(t3.contains("FlatAsyn"));
    let t4 = fig4::render(&opts, Some(&mut store));
    assert!(t4.contains("optimal group"));
    assert!(store.section("fig3").is_some());
    assert!(store.section("fig4").is_some());

    let path = std::env::temp_dir().join(format!("fa-report-{}.json", std::process::id()));
    store.save(&path).unwrap();
    let loaded = ResultStore::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(
        loaded.section("fig3").unwrap().len(),
        store.section("fig3").unwrap().len()
    );
}

#[test]
fn memoized_reports_are_bit_identical() {
    // The memoized coordinator must produce byte-identical report tables:
    // render twice (second pass is served almost entirely from the cache)
    // and cross-check the underlying result rows against an uncached run.
    let opts = quick_opts();
    let first = fig3::render(&opts, None);
    let second = fig3::render(&opts, None);
    assert_eq!(first, second, "fig3 render must not depend on cache state");

    let t4a = fig4::render(&opts, None);
    let t4b = fig4::render(&opts, None);
    assert_eq!(t4a, t4b);

    let arch = presets::table1();
    let wl = Workload::new(1024, 128, 8, 1);
    let specs: Vec<ExperimentSpec> = ALL_DATAFLOWS
        .into_iter()
        .map(|df| ExperimentSpec { arch: arch.clone(), workload: wl, dataflow: df, group: 16 })
        .collect();
    assert_eq!(run_all(&specs, 4), run_all_uncached(&specs, 4));
}

#[test]
fn memoized_serving_results_are_bit_identical() {
    // Acceptance: memoized ≡ uncached bit-identity holds for the serving
    // shapes too — GQA, MQA and decode points across every dataflow (the
    // SpecKey must fingerprint kv_heads and phase or a cached MHA result
    // would be served for a GQA spec).
    let arch = presets::table2(8);
    let workloads = [
        Workload::new(640, 64, 8, 1).with_kv_heads(2),
        Workload::new(640, 64, 8, 1).with_kv_heads(1),
        Workload::new(1280, 64, 8, 1).decode(),
        Workload::new(1280, 64, 8, 1).with_kv_heads(2).decode(),
    ];
    let specs: Vec<ExperimentSpec> = workloads
        .into_iter()
        .flat_map(|wl| ALL_DATAFLOWS.into_iter().map(move |df| (wl, df)))
        .map(|(workload, dataflow)| ExperimentSpec {
            arch: arch.clone(),
            workload,
            dataflow,
            group: 4,
        })
        .collect();
    let uncached = run_all_uncached(&specs, 4);
    let memoized = run_all(&specs, 4);
    assert_eq!(uncached, memoized);
    // A second pass is served from the cache and stays identical.
    assert_eq!(run_all(&specs, 4), memoized);
    // Distinct serving points must not alias: every id is unique.
    let mut ids: Vec<&str> = memoized.iter().map(|r| r.id.as_str()).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), specs.len(), "serving spec ids must be distinct");
}

#[test]
fn serving_report_renders_with_store() {
    use flatattention::report::serving;
    let arch = presets::table2(8);
    let wls = serving::workloads_for(4, &[128], &[1], true);
    let opts = quick_opts();
    let results = serving::run_on(&arch, 4, &wls, &opts);
    let mut store = ResultStore::new();
    let text = serving::render_results("tiny", &results, Some(&mut store));
    assert!(text.contains("decode") && text.contains("HBMvsMHA"));
    let rows = store.section("serving").unwrap();
    assert_eq!(rows.len(), results.len());
    assert!(rows[0].get("kv_heads").is_some());
    assert!(rows[0].get("phase").is_some());
}

#[test]
fn fig5a_heatmap_renders() {
    let s = fig5a::render(&quick_opts(), None);
    assert!(s.contains("BestArch"));
    assert!(s.contains("32x32"));
    assert!(s.contains("8x8"));
}

#[test]
fn static_reports_render() {
    assert!(tables::render_table1().contains("RedMulE"));
    assert!(tables::render_table2().contains("16x16"));
    assert!(section2::render_section2().contains("hardware"));
    assert!(section2::render_area().contains("BestArch"));
}

#[test]
fn headline_report_with_store() {
    let mut store = ResultStore::new();
    let s = headline::render(&ReportOpts::default(), Some(&mut store));
    assert!(s.contains("measured"));
    let rows = store.section("headline").unwrap();
    assert_eq!(rows.len(), 1);
    let util = rows[0].get("utilization").unwrap().as_f64().unwrap();
    assert!(util > 0.8, "headline utilization {util}");
}
