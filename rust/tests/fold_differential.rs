//! Differential test: symmetry-folded vs unfolded program execution.
//!
//! Folding (see `dataflow::set_symmetry_folding`) is a pure mechanical
//! optimization, like template stamping: a folded build keeps every
//! shared-resource op verbatim and collapses only private compute chains,
//! so executing it must reproduce the unfolded build's `RunStats` —
//! makespan, Fig. 3/4 breakdown, HBM traffic, busy totals and executed-op
//! count — *bit for bit*, and the representative stream's trace records
//! as well. The randomized sweep covers every dataflow, causal and
//! non-causal workloads, partial trailing blocks, GQA/MQA head-sharing
//! (`kv_heads < heads` — K/V loads shared across stacked query-head
//! streams), the decode phase (S=1 query against a KV cache), and a
//! degenerate single-edge HBM configuration.
//!
//! Tests here toggle the process-global folding/stamping switches, so
//! they serialize on a local lock (each integration-test binary is its
//! own process; the lib unit tests have their own lock for the same
//! purpose).

use std::sync::Mutex;

use flatattention::arch::{presets, ArchConfig};
use flatattention::dataflow::{
    build_program, set_symmetry_folding, set_template_stamping, tracked_tile, Dataflow, Phase,
    Workload, ALL_DATAFLOWS,
};
use flatattention::hbm::PageMap;
use flatattention::scheduler::batch::{compose, BatchEntry};
use flatattention::sim::{execute, execute_traced, RunStats};
use flatattention::util::quickcheck::{check, forall_cases};

static FOLD_LOCK: Mutex<()> = Mutex::new(());

/// Build and execute the same spec folded and unfolded.
fn run_both(arch: &ArchConfig, wl: &Workload, df: Dataflow, group: usize) -> (RunStats, RunStats) {
    let tracked = tracked_tile(arch, df, group);
    set_symmetry_folding(true);
    let folded_prog = build_program(arch, wl, df, group);
    set_symmetry_folding(false);
    let unfolded_prog = build_program(arch, wl, df, group);
    set_symmetry_folding(true);
    (execute(&folded_prog, tracked), execute(&unfolded_prog, tracked))
}

/// West-edge-only HBM: `col_channel` falls back to the row channels — the
/// degenerate-channel configuration of the zero-channel bugfix family.
fn degenerate_channel_arch() -> ArchConfig {
    let mut a = presets::table2(8);
    a.name = "table2-8x8-westonly".into();
    a.hbm.channels_west = 4;
    a.hbm.channels_south = 0;
    a
}

#[test]
fn folded_matches_unfolded_randomized_sweep() {
    let _guard = FOLD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arches = [
        presets::table2(8),
        presets::with_hbm_channels(presets::table2(8), 2),
        degenerate_channel_arch(),
    ];
    forall_cases(48, 0xF01D, |rng| {
        let arch = &arches[rng.gen_range(arches.len() as u64) as usize];
        let df = *rng.choose(&ALL_DATAFLOWS);
        let group = *rng.choose(&[2usize, 4, 8]);
        // 256..=896 in 128 steps: deliberately not block-aligned, so the
        // trailing partial row block (heterogeneous chain costs) is part
        // of the sweep.
        let seq = 256 + 128 * rng.gen_range(6);
        let d = *rng.choose(&[64u64, 128]);
        // Serving axes: GQA head groups (kv_heads ∈ {heads, heads/4 via
        // q_per_kv=4, 1 via MQA-style kv_heads=1}) and the decode phase.
        let kv_heads = 1 + rng.gen_range(4);
        let q_per_kv = *rng.choose(&[1u64, 2, 4]);
        let heads = kv_heads * q_per_kv;
        let batch = 1 + rng.gen_range(2);
        let causal = rng.gen_range(2) == 0;
        let phase = if rng.gen_range(3) == 0 { Phase::Decode } else { Phase::Prefill };
        let wl = Workload::new(seq, d, heads, batch)
            .with_causal(causal)
            .with_kv_heads(kv_heads)
            .with_phase(phase);
        let (folded, unfolded) = run_both(arch, &wl, df, group);
        check(
            folded == unfolded,
            format!(
                "{} {df:?} g{group} S{seq} D{d} H{heads} kv{kv_heads} B{batch} \
                 causal={causal} {phase:?}:\n\
                 folded   {folded:?}\nunfolded {unfolded:?}",
                arch.name
            ),
        )
    });
}

#[test]
fn folded_matches_unfolded_on_table1_preset() {
    // Spot-check the paper's Table-I mesh itself (1024 tiles, 16×2 HBM
    // channels) — the configuration the fold speedup claim is about.
    let _guard = FOLD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table1();
    for (df, group, wl) in [
        (Dataflow::Flash2, 1, Workload::new(1024, 128, 8, 1)),
        (Dataflow::FlatColl, 8, Workload::new(1024, 128, 32, 1)),
        (Dataflow::Flat, 16, Workload::new(512, 64, 8, 1).with_causal(true)),
        (Dataflow::Flash2, 1, Workload::new(2048, 128, 32, 1).with_kv_heads(8).decode()),
        (Dataflow::FlatColl, 8, Workload::new(1024, 128, 32, 1).with_kv_heads(1)),
        (Dataflow::Flat, 8, Workload::new(4096, 64, 16, 1).with_kv_heads(4).decode()),
    ] {
        let (folded, unfolded) = run_both(&arch, &wl, df, group);
        assert_eq!(folded, unfolded, "{df:?} g{group}");
    }
}

#[test]
fn fold_class_count_and_op_conservation_on_table1() {
    // Fold coverage on the Table-I preset: with every tile (resp. group)
    // stream busy, all streams but the representative fold, and the
    // elided-op accounting exactly conserves the executed-op count.
    let _guard = FOLD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table1();

    // 2·96·⌈4096/192⌉ = 4224 blocks over 1024 tiles: every stream busy.
    let wl = Workload::new(4096, 128, 96, 2);
    set_symmetry_folding(true);
    let folded = build_program(&arch, &wl, Dataflow::Flash2, 1);
    set_symmetry_folding(false);
    let unfolded = build_program(&arch, &wl, Dataflow::Flash2, 1);
    set_symmetry_folding(true);
    assert_eq!(folded.fold.streams, 1023, "all tile streams but tile 0 fold");
    assert_eq!(unfolded.fold.streams, 0);
    assert_eq!(
        folded.num_ops() as u64 + folded.fold.ops,
        unfolded.num_ops() as u64,
        "elided-op accounting must conserve the total op count"
    );
    assert!(
        folded.num_ops() * 2 < unfolded.num_ops(),
        "folding should at least halve the executed DES ops ({} vs {})",
        folded.num_ops(),
        unfolded.num_ops()
    );

    // FlatColl at G=8: 16 groups, 32 blocks — every group busy.
    let wl8 = Workload::new(1024, 128, 32, 1);
    set_symmetry_folding(true);
    let folded8 = build_program(&arch, &wl8, Dataflow::FlatColl, 8);
    set_symmetry_folding(false);
    let unfolded8 = build_program(&arch, &wl8, Dataflow::FlatColl, 8);
    set_symmetry_folding(true);
    assert_eq!(folded8.fold.streams, 15, "all groups but group 0 fold");
    assert_eq!(
        folded8.num_ops() as u64 + folded8.fold.ops,
        unfolded8.num_ops() as u64
    );
    assert!(folded8.num_ops() * 2 < unfolded8.num_ops());
}

#[test]
fn mixed_batch_composition_folds_exactly() {
    // The scheduler's composed mixed prefill+decode programs must
    // preserve fold exactness *per request*: every entry's band folds
    // around its own representative stream, and the folded batch executes
    // bit-identically to the unfolded one. (Stamping is bypassed in paged
    // batch programs, so the folding switch is the only mode axis.)
    let _guard = FOLD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8);
    let mut pages: Vec<PageMap> = Vec::new();
    let wls = [
        Workload::new(128, 64, 4, 1).with_kv_heads(2).with_causal(true),
        Workload::new(96, 64, 4, 1).with_causal(true).with_kv_prefix(160),
        Workload::new(300, 64, 4, 1).with_kv_heads(1).decode(),
    ];
    for (k, wl) in wls.iter().enumerate() {
        let mut pm = PageMap::new(32);
        // Stripe pages over all 16 channels, offset per request, so the
        // folded/unfolded comparison also covers cross-entry contention.
        pm.grow_to(wl.kv_len(), |p| ((p + 5 * k as u64) % 16) as u32);
        pages.push(pm);
    }
    for df in [Dataflow::Flash2, Dataflow::Flat, Dataflow::FlatColl, Dataflow::Flash3] {
        let entries: Vec<BatchEntry<'_>> = wls
            .iter()
            .enumerate()
            .map(|(k, wl)| BatchEntry { request: k, slot: k, workload: *wl, pages: &pages[k] })
            .collect();
        set_symmetry_folding(true);
        let folded = compose(&arch, df, 2, 4, &entries);
        set_symmetry_folding(false);
        let unfolded = compose(&arch, df, 2, 4, &entries);
        set_symmetry_folding(true);
        let asynchronous = matches!(df, Dataflow::Flash3 | Dataflow::FlatAsyn);
        if asynchronous {
            assert_eq!(folded.program.fold.streams, 0, "{df:?} must not fold");
        } else {
            assert!(folded.program.fold.streams > 0, "{df:?} should fold per band");
            assert_eq!(
                folded.program.num_ops() as u64 + folded.program.fold.ops,
                unfolded.program.num_ops() as u64,
                "{df:?} op conservation"
            );
        }
        assert_eq!(folded.spans.len(), unfolded.spans.len());
        assert_eq!(execute(&folded.program, 0), execute(&unfolded.program, 0), "{df:?}");
    }
}

#[test]
fn async_dataflows_fall_back_to_unfolded() {
    // FA-3 / FlatAsyn interleave two streams per engine (real
    // arbitration), so the builders must not fold them even when the
    // switch is on.
    let _guard = FOLD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8);
    let wl = Workload::new(512, 64, 8, 1);
    set_symmetry_folding(true);
    for (df, group) in [(Dataflow::Flash3, 1), (Dataflow::FlatAsyn, 4)] {
        let p = build_program(&arch, &wl, df, group);
        assert_eq!(p.fold.streams, 0, "{df:?} must not fold");
        assert_eq!(p.fold.ops, 0);
    }
}

#[test]
fn folded_traces_match_for_representative_tiles() {
    // The representative stream is built unfolded and first, so its op
    // indices, start and completion times — hence its trace records —
    // are identical between the folded and unfolded programs.
    let _guard = FOLD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8);
    let wl = Workload::new(512, 64, 6, 1);
    for (df, group, limit) in [(Dataflow::Flash2, 1usize, 1u32), (Dataflow::FlatColl, 4, 4)] {
        let tracked = tracked_tile(&arch, df, group);
        set_symmetry_folding(true);
        let fp = build_program(&arch, &wl, df, group);
        let (fs, ft) = execute_traced(&fp, tracked, Some(limit));
        set_symmetry_folding(false);
        let up = build_program(&arch, &wl, df, group);
        set_symmetry_folding(true);
        let (us, ut) = execute_traced(&up, tracked, Some(limit));
        assert_eq!(fs, us, "{df:?} stats");
        assert_eq!(ft, ut, "{df:?} trace records");
    }
}

#[test]
fn folding_and_stamping_compose_exactly() {
    // All four (stamping × folding) builder modes must execute to the
    // same RunStats — for prefill MHA, causal GQA, and GQA decode alike.
    let _guard = FOLD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8);
    for (wl, df, group) in [
        (Workload::new(768, 64, 5, 1).with_causal(true), Dataflow::FlatColl, 4usize),
        (
            Workload::new(768, 64, 12, 1).with_kv_heads(3).with_causal(true),
            Dataflow::FlatColl,
            4,
        ),
        (Workload::new(896, 128, 8, 2).with_kv_heads(2).decode(), Dataflow::Flash2, 1),
        (Workload::new(640, 64, 16, 1).with_kv_heads(1).decode(), Dataflow::Flat, 2),
    ] {
        let tracked = tracked_tile(&arch, df, group);
        let mut results: Vec<RunStats> = Vec::new();
        for (stamp, fold) in [(true, true), (true, false), (false, true), (false, false)] {
            set_template_stamping(stamp);
            set_symmetry_folding(fold);
            let p = build_program(&arch, &wl, df, group);
            results.push(execute(&p, tracked));
        }
        set_template_stamping(true);
        set_symmetry_folding(true);
        assert!(
            results.windows(2).all(|w| w[0] == w[1]),
            "modes diverge for {wl:?} {df:?}: {results:#?}"
        );
    }
}
