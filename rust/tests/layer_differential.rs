//! §Layer composition differential wall.
//!
//! A composed transformer layer (`dataflow::layer_program`) chains the
//! attention kernel and the four projection/FFN GEMMs behind strict
//! cross-kernel barriers. Strictness is the whole correctness story, so
//! it is pinned from four directions:
//!
//! 1. **Additivity** — the composed makespan equals the solo attention
//!    makespan plus the solo GEMM makespans, *exactly*, for every
//!    dataflow × weight residency. The entry barrier completes at the
//!    previous kernel's last sink completion and all shared resources
//!    (HBM channels) have drained by then, so each kernel's sub-DAG
//!    replays its solo schedule shifted by the running total.
//! 2. **Trace shift** — per-op start/completion cycles of each composed
//!    GEMM kernel are the solo program's records shifted by that running
//!    total, op for op; the attention span's records match the solo
//!    attention build verbatim.
//! 3. **Fold exactness** — folding elides only attention-private compute
//!    chains and GEMM kernels never fold, so folded and unfolded layer
//!    builds execute to bit-identical `RunStats`.
//! 4. **Batch conservation** — `compose_layered` on channel-disjoint
//!    entries reproduces each entry's solo layered timeline bit for bit
//!    (the attention-only conservation wall extended to GEMM tails).
//!
//! Tests toggling the process-global folding switch serialize on a local
//! lock (each integration-test binary is its own process).

use std::sync::Mutex;

use flatattention::arch::presets;
use flatattention::dataflow::{
    build_program, gemm_band_program, layer_program, set_symmetry_folding, tracked_tile, Dataflow,
    LayerWorkload, Workload, ALL_DATAFLOWS, ALL_RESIDENCIES,
};
use flatattention::hbm::PageMap;
use flatattention::scheduler::batch::{compose_layered, BatchEntry, LayerParams};
use flatattention::sim::{execute, execute_traced};

static FOLD_LOCK: Mutex<()> = Mutex::new(());

fn layer_wl(weights: flatattention::dataflow::WeightResidency) -> LayerWorkload {
    LayerWorkload::new(
        Workload::new(256, 64, 4, 1).with_kv_heads(2).with_causal(true),
        2,
        weights,
    )
}

#[test]
fn composed_layer_makespan_is_strictly_additive() {
    // ISSUE acceptance: the layer-composed program reproduces the solo
    // kernel timelines under strict barriers — makespan, HBM traffic and
    // FLOPs all partition exactly, for every dataflow × residency.
    let _guard = FOLD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8);
    for df in ALL_DATAFLOWS {
        for res in ALL_RESIDENCIES {
            let lw = layer_wl(res);
            let lp = layer_program(&arch, &lw, df, 2);
            let tracked = tracked_tile(&arch, df, 2);
            let composed = execute(&lp.program, tracked);

            let attn = execute(&build_program(&arch, &lw.attn, df, 2), tracked);
            let mut makespan = attn.makespan;
            let mut hbm_bytes = attn.hbm_bytes;
            for g in lw.gemms() {
                let solo = execute(&gemm_band_program(&arch, &g, 0, arch.mesh_y, res), 0);
                makespan += solo.makespan;
                hbm_bytes += solo.hbm_bytes;
            }
            assert_eq!(
                composed.makespan, makespan,
                "{df:?}/{}: composed layer must equal the sum of solo kernel makespans",
                res.label()
            );
            assert_eq!(composed.hbm_bytes, hbm_bytes, "{df:?}/{}", res.label());
            assert_eq!(lp.program.flops, lw.flops(), "{df:?}/{}", res.label());
        }
    }
}

#[test]
fn composed_kernel_traces_are_solo_traces_shifted() {
    // Stronger than additivity: every tile-owned op of composed kernel i
    // starts and completes at its solo cycle plus the running total of
    // the preceding kernels' makespans. Barriers are `NO_TILE`, so they
    // never appear in either trace and op indices line up span-relative.
    let _guard = FOLD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8);
    for (df, group) in [(Dataflow::Flash2, 1usize), (Dataflow::FlatColl, 2)] {
        for res in ALL_RESIDENCIES {
            let lw = layer_wl(res);
            let lp = layer_program(&arch, &lw, df, group);
            let tracked = tracked_tile(&arch, df, group);
            let (_, composed) = execute_traced(&lp.program, tracked, Some(u32::MAX));

            // Attention span: composed records restricted to spans[0]
            // must equal the solo attention build's records verbatim
            // (same op ids, zero shift).
            let attn_prog = build_program(&arch, &lw.attn, df, group);
            let (attn_stats, attn_trace) = execute_traced(&attn_prog, tracked, Some(u32::MAX));
            let (s0, e0) = lp.spans[0];
            let mut in_span: Vec<_> = composed
                .iter()
                .filter(|r| (r.0 as usize) >= s0 && (r.0 as usize) < e0)
                .copied()
                .collect();
            in_span.sort_unstable();
            let mut want = attn_trace.clone();
            want.sort_unstable();
            assert_eq!(in_span, want, "{df:?}/{}: attention span trace", res.label());

            // GEMM spans: solo records shifted by the running total.
            let mut shift = attn_stats.makespan;
            for (i, g) in lw.gemms().iter().enumerate() {
                let solo_prog = gemm_band_program(&arch, g, 0, arch.mesh_y, res);
                let (solo_stats, solo_trace) = execute_traced(&solo_prog, 0, Some(u32::MAX));
                let (s, e) = lp.spans[i + 1];
                let mut got: Vec<_> = composed
                    .iter()
                    .filter(|r| (r.0 as usize) >= s && (r.0 as usize) < e)
                    .map(|&(op, st, en)| (op - s as u32, st, en))
                    .collect();
                got.sort_unstable();
                let mut want: Vec<_> =
                    solo_trace.iter().map(|&(op, st, en)| (op, st + shift, en + shift)).collect();
                want.sort_unstable();
                assert_eq!(
                    got,
                    want,
                    "{df:?}/{}: kernel {} ({}) trace must be the solo trace shifted by {shift}",
                    res.label(),
                    i + 1,
                    g.label
                );
                shift += solo_stats.makespan;
            }
        }
    }
}

#[test]
fn folded_layer_matches_unfolded_layer() {
    // Fold exactness survives cross-kernel composition: folding elides
    // only attention-private compute chains, the per-stream attention
    // sinks (where the first GEMM's entry barrier attaches) are emitted
    // verbatim in both modes, and GEMM kernels never fold.
    let _guard = FOLD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8);
    for (df, group) in [(Dataflow::Flash2, 1usize), (Dataflow::Flat, 2), (Dataflow::FlatColl, 4)] {
        for res in ALL_RESIDENCIES {
            let lw = layer_wl(res);
            let tracked = tracked_tile(&arch, df, group);
            set_symmetry_folding(true);
            let folded = layer_program(&arch, &lw, df, group);
            set_symmetry_folding(false);
            let unfolded = layer_program(&arch, &lw, df, group);
            set_symmetry_folding(true);
            assert!(
                folded.program.num_ops() <= unfolded.program.num_ops(),
                "{df:?}/{}",
                res.label()
            );
            assert_eq!(
                execute(&folded.program, tracked),
                execute(&unfolded.program, tracked),
                "{df:?}/{}: folded layer diverges from unfolded",
                res.label()
            );
        }
    }
}

/// A page map on the given slot's affine south-channel partition of the
/// table2-8x8 arch (8 west + 8 south channels, 4 slots ⇒ 2 south
/// channels per slot): entry K/V channels are pairwise disjoint, and the
/// GEMM tails ride each band's own west row channels — no resource is
/// shared between entries.
fn affine_pages(slot: usize, tokens: u64) -> PageMap {
    let mut pm = PageMap::new(32);
    pm.grow_to(tokens, |p| (8 + slot as u32 * 2) + (p % 2) as u32);
    pm
}

#[test]
fn layered_batch_per_request_stats_match_solo_runs() {
    // The attention-only conservation wall extended to GEMM tails: under
    // channel-disjoint placement, each entry's composed attention+tail
    // trace (span-relative ids, absolute cycles) is bit-identical to
    // composing that entry alone on the same slot.
    let _guard = FOLD_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8);
    let wls = [
        Workload::new(128, 64, 4, 1).with_kv_heads(2).with_causal(true),
        Workload::new(300, 64, 4, 1).with_kv_heads(1).decode(),
    ];
    let slots = [0usize, 2];
    let pages: Vec<PageMap> =
        slots.iter().zip(&wls).map(|(&s, wl)| affine_pages(s, wl.kv_len())).collect();
    let lp = LayerParams {
        ffn_mult: 2,
        weights: flatattention::dataflow::WeightResidency::HbmStream,
    };
    for df in ALL_DATAFLOWS {
        let entries: Vec<BatchEntry<'_>> = (0..2)
            .map(|k| BatchEntry { request: k, slot: slots[k], workload: wls[k], pages: &pages[k] })
            .collect();
        let mixed = compose_layered(&arch, df, 2, 4, &entries, lp);
        let (_, mixed_stats) = mixed.entry_stats();
        for k in 0..2 {
            let solo_entry = vec![BatchEntry {
                request: k,
                slot: slots[k],
                workload: wls[k],
                pages: &pages[k],
            }];
            let solo = compose_layered(&arch, df, 2, 4, &solo_entry, lp);
            let (_, solo_stats) = solo.entry_stats();
            assert_eq!(
                mixed_stats[k], solo_stats[0],
                "{df:?} entry {k}: layered mixed-batch stats diverge from the solo compose"
            );
        }
    }
}
