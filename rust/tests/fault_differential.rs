//! Differential test: fault injection vs the fault-free engines.
//!
//! `sim::execute_faulted` threads a resolved `FaultPlan` through the same
//! scheduling step the fault-free engines use (see `sim`'s §Fault essay).
//! Two exactness properties fall out and are pinned here:
//!
//! 1. **`FaultPlan::none()` is the identity** — the faulted path with an
//!    empty plan takes the identical arithmetic with empty window tables,
//!    so it must reproduce the fault-free `RunStats` *and* per-op trace
//!    records bit for bit, across every dataflow × folding × thread count.
//! 2. **Faulted runs are deterministic and thread-count-invariant** —
//!    fault decisions are pure functions of (op fields, shard-local FIFO
//!    cursor, epoch timestamp, immutable plan), so the parallel engine
//!    reproduces the serial faulted schedule exactly, `FaultReport`
//!    included.
//!
//! Plus the monotonicity sanity wall: derating every HBM channel must
//! strictly lengthen a memory-bound schedule, and a tile death mid-run
//! degrades gracefully (killed + stalled + completed conserves the op
//! count; no panic, no deadlock).
//!
//! Tests here toggle the process-global folding switch, so they
//! serialize on a local lock (each integration-test binary is its own
//! process).

use std::sync::Mutex;

use flatattention::arch::presets;
use flatattention::dataflow::{
    build_program, set_symmetry_folding, tracked_tile, Dataflow, Workload, ALL_DATAFLOWS,
};
use flatattention::sim::{execute_faulted, execute_faulted_traced, execute_traced, FaultPlan};

static SWITCH_LOCK: Mutex<()> = Mutex::new(());

/// Thread counts under test (same env override contract as
/// `parallel_differential.rs`): serial + even + oversubscribed.
fn thread_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("FLATATTN_PAR_THREADS") {
        let parsed: Vec<usize> =
            v.split(',').filter_map(|s| s.trim().parse().ok()).filter(|&n| n >= 1).collect();
        if !parsed.is_empty() {
            return parsed;
        }
    }
    vec![1, 2, 8]
}

#[test]
fn none_plan_is_bit_identical_to_baseline() {
    let _guard = SWITCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8);
    let wl = Workload::new(320, 64, 4, 1).with_causal(true).with_kv_heads(2);
    let counts = thread_counts();
    let none = FaultPlan::none();
    for folding in [true, false] {
        for df in ALL_DATAFLOWS {
            set_symmetry_folding(folding);
            let p = build_program(&arch, &wl, df, 4);
            set_symmetry_folding(true);
            let tracked = tracked_tile(&arch, df, 4);
            let (want, want_trace) = execute_traced(&p, tracked, Some(u32::MAX));
            for &t in &counts {
                let (got, got_trace, fr) =
                    execute_faulted_traced(&p, tracked, Some(u32::MAX), &none, t);
                assert!(fr.is_clean(), "{df:?} folding={folding} t{t}: clean run reports faults");
                assert_eq!(want, got, "{df:?} folding={folding} t{t}: RunStats diverge");
                assert_eq!(want_trace, got_trace, "{df:?} folding={folding} t{t}: trace diverges");
            }
        }
    }
}

#[test]
fn faulted_runs_are_thread_count_invariant() {
    let _guard = SWITCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8);
    let wl = Workload::new(384, 64, 4, 1).with_kv_heads(2);
    let counts = thread_counts();
    for folding in [true, false] {
        for df in ALL_DATAFLOWS {
            set_symmetry_folding(folding);
            let p = build_program(&arch, &wl, df, 4);
            set_symmetry_folding(true);
            let tracked = tracked_tile(&arch, df, 4);
            // Anchor the fault windows to this program's own timescale so
            // every kind of fault actually lands mid-run.
            let (free, _) = execute_traced(&p, tracked, Some(u32::MAX));
            let mid = (free.makespan / 2).max(1);
            let plan = FaultPlan::none()
                .with_outage(0, 0, mid)
                .with_derate(1, 0, free.makespan.max(2), 3, 1)
                .with_noc_slowdown(0, free.makespan.max(2), 2, 1)
                .with_tile_death(tracked, mid);
            let (want, want_trace, want_fr) =
                execute_faulted_traced(&p, tracked, Some(u32::MAX), &plan, 1);
            for &t in &counts {
                let (got, got_trace, got_fr) =
                    execute_faulted_traced(&p, tracked, Some(u32::MAX), &plan, t);
                assert_eq!(want, got, "{df:?} folding={folding} t{t}: faulted stats diverge");
                assert_eq!(
                    want_trace, got_trace,
                    "{df:?} folding={folding} t{t}: faulted trace diverges"
                );
                assert_eq!(
                    want_fr, got_fr,
                    "{df:?} folding={folding} t{t}: FaultReport diverges"
                );
            }
        }
    }
}

#[test]
fn derated_channels_strictly_dominate_fault_free_twin() {
    let _guard = SWITCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_symmetry_folding(true);
    let arch = presets::table2(8);
    // Memory-bound shape: decode against a long KV cache keeps the HBM
    // channels on the critical path for every dataflow under test.
    let wl = Workload::new(2048, 128, 8, 1).with_kv_heads(2).decode();
    let mut plan = FaultPlan::none();
    for c in 0..arch.hbm.total_channels() as u32 {
        plan = plan.with_derate(c, 0, u64::MAX / 2, 8, 1);
    }
    for df in [Dataflow::Flash2, Dataflow::FlatColl] {
        let p = build_program(&arch, &wl, df, 4);
        let tracked = tracked_tile(&arch, df, 4);
        let (free, _) = execute_traced(&p, tracked, Some(u32::MAX));
        let (slow, fr) = execute_faulted(&p, tracked, &plan, 1);
        assert!(fr.is_clean(), "{df:?}: derating kills nothing");
        assert!(
            slow.makespan > free.makespan,
            "{df:?}: 8x-derated channels must strictly lengthen the run \
             ({} vs {})",
            slow.makespan,
            free.makespan
        );
    }
}

#[test]
fn tile_death_mid_run_degrades_gracefully() {
    let _guard = SWITCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // Unfolded build: `ops_executed` counts real scheduled ops only, so
    // the conservation identity is exact without fold re-accounting.
    set_symmetry_folding(false);
    let arch = presets::table2(8);
    let wl = Workload::new(256, 64, 4, 1);
    let df = Dataflow::Flash2;
    let p = build_program(&arch, &wl, df, 1);
    set_symmetry_folding(true);
    let tracked = tracked_tile(&arch, df, 1);
    let plan = FaultPlan::none().with_tile_death(tracked, 0);
    for t in [1usize, 4] {
        let (stats, fr) = execute_faulted(&p, tracked, &plan, t);
        assert!(!fr.killed.is_empty(), "t{t}: the dead tile's ops are killed");
        assert_eq!(
            stats.ops_executed + fr.killed.len() + fr.stalled.len(),
            p.num_ops(),
            "t{t}: completed + killed + stalled conserves the op count"
        );
        assert!(fr.killed.windows(2).all(|w| w[0] < w[1]), "t{t}: killed ids sorted");
        assert!(fr.stalled.windows(2).all(|w| w[0] < w[1]), "t{t}: stalled ids sorted");
    }
}
