//! Differential test: the sharded multi-worker executor vs the serial
//! engine (and the seed reference engine).
//!
//! `sim::execute_parallel` partitions each sealed program into private
//! shards plus one shared shard and advances workers in global-timestamp
//! epochs (see `sim`'s sharding essay). The whole point of the design is
//! that the parallel schedule is **bit-identical** to the serial one —
//! same `RunStats` (makespan, Fig. 3/4 breakdown, HBM traffic, busy
//! totals, op counts) and the same per-op trace records in the same
//! order, at every thread count. This file pins that across all five
//! dataflows × folding on/off × paged batch programs × randomized DAGs,
//! and walls off the shard partition invariants the exactness proof
//! rests on.
//!
//! Thread counts default to `[1, 2, 8]`; the CI determinism matrix
//! overrides them per leg via `FLATATTN_PAR_THREADS` (comma-separated),
//! and the release-mode leg rides the `cargo test --release` job.
//!
//! Tests here toggle the process-global folding switch, so they
//! serialize on a local lock (each integration-test binary is its own
//! process).

use std::sync::Mutex;

use flatattention::arch::presets;
use flatattention::dataflow::{
    build_program, set_symmetry_folding, tracked_tile, Dataflow, Workload, ALL_DATAFLOWS,
};
use flatattention::hbm::PageMap;
use flatattention::scheduler::batch::{compose, BatchEntry};
use flatattention::scheduler::{simulate, RequestTrace, SchedulerConfig};
use flatattention::sim::{
    execute_parallel_traced, execute_reference_traced, execute_traced, Component, OpId, Program,
    SHARED_SHARD,
};
use flatattention::util::quickcheck::{check, forall_cases};
use flatattention::util::Rng;

static SWITCH_LOCK: Mutex<()> = Mutex::new(());

/// Thread counts under test: `FLATATTN_PAR_THREADS="1,2,8"`-style env
/// override (the CI determinism matrix passes one count per leg), else
/// serial + even + oversubscribed.
fn thread_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("FLATATTN_PAR_THREADS") {
        let parsed: Vec<usize> =
            v.split(',').filter_map(|s| s.trim().parse().ok()).filter(|&n| n >= 1).collect();
        if !parsed.is_empty() {
            return parsed;
        }
    }
    vec![1, 2, 8]
}

/// The shard-partition wall: every op in exactly one shard, every
/// resource used by exactly one shard, contended resources (ops from ≥ 2
/// distinct tiles) all in the shared shard, and no private-to-private
/// dependency edge crossing shards — the invariants `execute_parallel`'s
/// exactness argument rests on. The wall itself now lives in product
/// code (`analysis::verify_program`, run at every seal in debug builds);
/// this wrapper pins that the checker stays wired up and clean on every
/// program shape this suite builds.
fn assert_shard_wall(p: &Program, label: &str) {
    assert!(p.is_sealed(), "{label}: wall needs a sealed program");
    let diags = flatattention::analysis::verify_program(p);
    assert!(
        diags.is_empty(),
        "{label}: verifier reported {} diagnostic(s):\n  {}",
        diags.len(),
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n  ")
    );
}

/// Assert parallel == serial (stats + full trace) at every thread count.
fn assert_parallel_matches(p: &Program, tracked: u32, counts: &[usize], label: &str) {
    let (want, want_trace) = execute_traced(p, tracked, Some(u32::MAX));
    for &t in counts {
        let (got, got_trace) = execute_parallel_traced(p, tracked, Some(u32::MAX), t);
        assert_eq!(want, got, "{label}: RunStats diverge at {t} threads");
        assert_eq!(want_trace, got_trace, "{label}: traces diverge at {t} threads");
    }
}

#[test]
fn shard_partition_wall_on_builder_programs() {
    let _guard = SWITCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8);
    let wl = Workload::new(768, 64, 6, 1).with_causal(true);
    for folding in [true, false] {
        set_symmetry_folding(folding);
        for df in ALL_DATAFLOWS {
            let p = build_program(&arch, &wl, df, 4);
            assert_shard_wall(&p, &format!("{df:?} folding={folding}"));
        }
    }
    set_symmetry_folding(true);

    // An unfolded Flash grid exposes roughly per-tile parallelism: with
    // enough heads every one of the 64 tiles owns a private shard.
    set_symmetry_folding(false);
    let p = build_program(&arch, &Workload::new(1024, 64, 96, 1), Dataflow::Flash2, 1);
    set_symmetry_folding(true);
    assert!(
        p.num_shards() > 32,
        "unfolded 8x8 Flash2 should shard per tile, got {}",
        p.num_shards()
    );
    // And its shared shard holds every HBM-channel op (channels are the
    // first `total_channels` resources in the flash builders).
    let n_chan = arch.hbm.total_channels();
    for (i, op) in p.ops().iter().enumerate() {
        let on_channel = (op.resource.0 as usize) < n_chan;
        assert_eq!(
            p.op_shards()[i] == SHARED_SHARD,
            on_channel,
            "op {i}: channel ops and only channel ops arbitrate in the shared shard"
        );
    }
}

#[test]
fn parallel_matches_serial_randomized_dataflow_sweep() {
    let _guard = SWITCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arches = [presets::table2(8), presets::with_hbm_channels(presets::table2(8), 2)];
    let counts = thread_counts();
    forall_cases(12, 0x5AAD, |rng| {
        let arch = &arches[rng.gen_range(arches.len() as u64) as usize];
        let df = *rng.choose(&ALL_DATAFLOWS);
        let group = *rng.choose(&[2usize, 4]);
        // Deliberately not block-aligned: partial trailing blocks included.
        let seq = 192 + 64 * rng.gen_range(4);
        let kv_heads = 1 + rng.gen_range(2);
        let q_per_kv = *rng.choose(&[1u64, 2]);
        let causal = rng.gen_range(2) == 0;
        let folding = rng.gen_range(2) == 0;
        let mut wl = Workload::new(seq, 64, kv_heads * q_per_kv, 1)
            .with_causal(causal)
            .with_kv_heads(kv_heads);
        if rng.gen_range(4) == 0 {
            wl = wl.decode();
        }
        set_symmetry_folding(folding);
        let p = build_program(arch, &wl, df, group);
        set_symmetry_folding(true);
        let tracked = tracked_tile(arch, df, group);
        let (want, want_trace) = execute_traced(&p, tracked, Some(u32::MAX));
        for &t in &counts {
            let (got, got_trace) = execute_parallel_traced(&p, tracked, Some(u32::MAX), t);
            check(
                want == got,
                format!(
                    "{} {df:?} g{group} {} folding={folding} threads={t}:\n\
                     serial   {want:?}\nparallel {got:?}",
                    arch.name,
                    wl.label()
                ),
            )?;
            check(
                want_trace == got_trace,
                format!(
                    "{} {df:?} g{group} {} folding={folding} threads={t}: trace diverges \
                     ({} vs {} records)",
                    arch.name,
                    wl.label(),
                    want_trace.len(),
                    got_trace.len()
                ),
            )?;
        }
        Ok(())
    });
}

/// Random DAGs with a private/shared resource mix: resources `0..4` are
/// per-tile (ops on resource `r` carry tile `r` — private), the rest draw
/// random tiles (contended). Exercises duplicate deps, zero-duration
/// barriers, latency pipelining and equal-time storms across the shard
/// boundary.
fn random_sharded_program(rng: &mut Rng) -> Program {
    let mut p = Program::new();
    let n_private = 4usize;
    let n_res = n_private + 1 + rng.gen_range(4) as usize;
    let res = p.resources(n_res);
    let n_ops = 10 + rng.gen_range(120) as usize;
    let mut ids: Vec<OpId> = Vec::with_capacity(n_ops);
    const COMPONENTS: [Component; 7] = [
        Component::RedMule,
        Component::Spatz,
        Component::SumReduce,
        Component::MaxReduce,
        Component::Multicast,
        Component::HbmAccess,
        Component::Other,
    ];
    for i in 0..n_ops {
        let mut deps: Vec<OpId> = Vec::new();
        if i > 0 {
            for _ in 0..rng.gen_range(4) {
                deps.push(ids[rng.gen_range(i as u64) as usize]);
            }
        }
        let ri = rng.gen_range(n_res as u64) as usize;
        let tile = if ri < n_private { ri as u32 } else { rng.gen_range(4) as u32 };
        let barrier = rng.gen_range(8) == 0;
        let occupancy = if barrier { 0 } else { rng.gen_range(60) };
        let latency = if rng.gen_range(3) == 0 { rng.gen_range(250) } else { 0 };
        let component = COMPONENTS[rng.gen_range(COMPONENTS.len() as u64) as usize];
        let hbm_bytes = if component == Component::HbmAccess { 1 + rng.gen_range(4096) } else { 0 };
        ids.push(p.op(res[ri], occupancy, latency, component, tile, hbm_bytes, &deps));
    }
    p.flops = rng.gen_range(1 << 30);
    p
}

#[test]
fn parallel_matches_both_engines_on_random_dags() {
    forall_cases(60, 0xBADD, |rng| {
        let mut p = random_sharded_program(rng);
        p.seal();
        assert_shard_wall(&p, "random DAG");
        let tracked = rng.gen_range(4) as u32;
        let limit = Some(1 + rng.gen_range(4) as u32);
        let (want, want_trace) = execute_traced(&p, tracked, limit);
        let (ref_stats, ref_trace) = execute_reference_traced(&p, tracked, limit);
        check(
            want == ref_stats && want_trace == ref_trace,
            format!("serial vs reference diverge: {want:?} vs {ref_stats:?}"),
        )?;
        for t in [2usize, 5] {
            let (got, got_trace) = execute_parallel_traced(&p, tracked, limit, t);
            check(
                want == got,
                format!("parallel({t}) stats diverge:\nserial   {want:?}\nparallel {got:?}"),
            )?;
            check(
                want_trace == got_trace,
                format!(
                    "parallel({t}) trace diverges ({} vs {} records)",
                    want_trace.len(),
                    got_trace.len()
                ),
            )?;
        }
        Ok(())
    });
}

#[test]
fn parallel_matches_serial_on_paged_batch_programs() {
    let _guard = SWITCH_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let arch = presets::table2(8); // 8 west + 2 south channels
    let counts = thread_counts();
    // Mixed placements: striped, single-channel affine, two-channel.
    let mut pm0 = PageMap::new(32);
    pm0.grow_to(256, |pg| (pg % 4) as u32);
    let mut pm1 = PageMap::new(32);
    pm1.grow_to(300, |_| 9);
    let mut pm2 = PageMap::new(32);
    pm2.grow_to(192, |pg| 8 + (pg % 2) as u32);
    for folding in [true, false] {
        set_symmetry_folding(folding);
        for df in ALL_DATAFLOWS {
            let entries = vec![
                BatchEntry {
                    request: 0,
                    slot: 0,
                    workload: Workload::new(128, 64, 4, 1).with_causal(true).with_kv_prefix(128),
                    pages: &pm0,
                },
                BatchEntry {
                    request: 1,
                    slot: 1,
                    workload: Workload::new(300, 64, 4, 1).with_kv_heads(2).decode(),
                    pages: &pm1,
                },
                BatchEntry {
                    request: 2,
                    slot: 3,
                    workload: Workload::new(192, 64, 2, 1).with_causal(true),
                    pages: &pm2,
                },
            ];
            let bp = compose(&arch, df, 2, 4, &entries);
            let label = format!("batch {df:?} folding={folding}");
            assert_shard_wall(&bp.program, &label);
            assert_parallel_matches(&bp.program, 0, &counts, &label);
        }
    }
    set_symmetry_folding(true);
}

#[test]
fn scheduler_replay_is_thread_count_invariant() {
    // End to end through the serving scheduler: the virtual clock, token
    // throughput and traffic of a whole trace replay must not move with
    // the DES worker count.
    let arch = presets::table2(8);
    let trace = RequestTrace::builtin("builtin", 2).expect("builtin trace");
    for df in [Dataflow::Flash2, Dataflow::FlatColl] {
        let mut cfg = SchedulerConfig::new(df);
        cfg.slots = 4;
        cfg.group = 2;
        cfg.chunk = 128;
        cfg.page_tokens = 32;
        cfg.heads = 4;
        cfg.head_dim = 64;
        cfg.threads = 1;
        let serial = simulate(&arch, &trace, &cfg);
        cfg.threads = 4;
        let parallel = simulate(&arch, &trace, &cfg);
        assert_eq!(serial.total_cycles, parallel.total_cycles, "{df:?}");
        assert_eq!(serial.steps, parallel.steps, "{df:?}");
        assert_eq!(serial.tokens, parallel.tokens, "{df:?}");
        assert_eq!(serial.hbm_bytes, parallel.hbm_bytes, "{df:?}");
        assert_eq!(serial.tokens_per_s, parallel.tokens_per_s, "{df:?}");
    }
}
