//! Scheduler subsystem integration tests: batch-program conservation,
//! paged-placement contention, and end-to-end trace replays.

use flatattention::arch::presets;
use flatattention::dataflow::{Dataflow, Workload, ALL_DATAFLOWS};
use flatattention::hbm::PageMap;
use flatattention::scheduler::batch::{compose, BatchEntry};
use flatattention::scheduler::{
    simulate, BatchPolicy, PagePlacement, RequestTrace, SchedulerConfig,
};

/// A page map whose pages stay on the given slot's affine south-channel
/// partition of the wide table2-8x8 arch (8 west + 8 south channels,
/// 4 slots ⇒ 2 south channels per slot) — the placement under which
/// entries' channels are pairwise disjoint.
fn affine_pages(slot: usize, tokens: u64) -> PageMap {
    let mut pm = PageMap::new(32);
    pm.grow_to(tokens, |p| (8 + slot as u32 * 2) + (p % 2) as u32);
    pm
}

fn mixed_workloads() -> [Workload; 3] {
    [
        // Fresh prefill chunk.
        Workload::new(128, 64, 4, 1).with_kv_heads(2).with_causal(true),
        // Mid-stream chunk behind a 128-token prefix.
        Workload::new(128, 64, 4, 1).with_causal(true).with_kv_prefix(128),
        // In-flight decode over a 300-token cache (MQA).
        Workload::new(300, 64, 4, 1).with_kv_heads(1).decode(),
    ]
}

/// The conservation property the composition is designed around: on an
/// uncontended (wide-HBM, channel-affine) architecture, each request's
/// per-op timeline and traffic in a mixed prefill+decode batch are
/// bit-identical to composing that request alone on the same slot —
/// mixing requests into one program perturbs nothing but genuinely shared
/// channels.
#[test]
fn mixed_batch_per_request_stats_match_solo_runs() {
    let arch = presets::table2(8); // 8 west + 8 south channels: wide
    let wls = mixed_workloads();
    let slots = [0usize, 1, 2];
    let pages: Vec<PageMap> = slots
        .iter()
        .zip(&wls)
        .map(|(&s, wl)| affine_pages(s, wl.kv_len()))
        .collect();
    for df in ALL_DATAFLOWS {
        let entries: Vec<BatchEntry<'_>> = (0..3)
            .map(|k| BatchEntry {
                request: k,
                slot: slots[k],
                workload: wls[k],
                pages: &pages[k],
            })
            .collect();
        let mixed = compose(&arch, df, 2, 4, &entries);
        let (_, mixed_stats) = mixed.entry_stats();
        for k in 0..3 {
            let solo_entry = vec![BatchEntry {
                request: k,
                slot: slots[k],
                workload: wls[k],
                pages: &pages[k],
            }];
            let solo = compose(&arch, df, 2, 4, &solo_entry);
            let (_, solo_stats) = solo.entry_stats();
            assert_eq!(
                mixed_stats[k], solo_stats[0],
                "{df:?} entry {k}: mixed-batch per-request stats diverge from the solo compose"
            );
        }
    }
}

/// Paged placement is a real performance lever: on a narrow-HBM arch the
/// policies concentrate vs spread channel load and the makespans differ —
/// channel-affine serializes one request's whole cache on its single
/// partition channel, round-robin stripes it across all four.
#[test]
fn paged_placement_policies_change_contention_makespan() {
    let arch = presets::with_hbm_channels(presets::table2(8), 2); // 2+2 channels
    let wl = Workload::new(2048, 64, 4, 1).with_kv_heads(1).decode();
    let mk = |alloc: &mut dyn FnMut(u64) -> u32| {
        let mut pm = PageMap::new(64);
        pm.grow_to(wl.kv_len(), alloc);
        pm
    };
    let rr = mk(&mut |p| (p % 4) as u32);
    let affine = mk(&mut |_| 0u32);
    let mut rng = flatattention::util::Rng::new(0xBADC0DE);
    let random = mk(&mut |_| rng.gen_range(4) as u32);

    let run = |pages: &PageMap| {
        let entries = vec![BatchEntry { request: 0, slot: 0, workload: wl, pages }];
        compose(&arch, Dataflow::Flash2, 2, 4, &entries).run()
    };
    let (st_rr, st_aff, st_rand) = (run(&rr), run(&affine), run(&random));
    // Identical traffic, different placement...
    assert_eq!(st_rr.hbm_bytes, st_aff.hbm_bytes);
    assert_eq!(st_rr.hbm_bytes, st_rand.hbm_bytes);
    // ...but measurably different contention: the single-channel affine
    // placement serializes every K/V page behind the request's own Q/O
    // channel, while round-robin draws all four channels.
    assert!(
        st_aff.makespan > st_rr.makespan,
        "affine-on-one-channel {} should exceed round-robin {}",
        st_aff.makespan,
        st_rr.makespan
    );
    assert!(st_rand.makespan > 0 && st_rr.makespan > 0);
}

/// End-to-end: the builtin mixed trace replays on every dataflow, every
/// request finishes, and token accounting is exact.
#[test]
fn scheduler_replays_builtin_trace_on_all_dataflows() {
    let arch = presets::table2(8);
    let mut trace = RequestTrace::builtin("mixed", 2).expect("builtin");
    trace.requests.truncate(6);
    for r in &mut trace.requests {
        r.prompt = r.prompt.min(192);
        r.output = r.output.min(10);
    }
    let total: u64 = trace.requests.iter().map(|r| r.output).sum();
    for df in ALL_DATAFLOWS {
        let mut cfg = SchedulerConfig::new(df);
        cfg.group = 2;
        cfg.slots = 4;
        cfg.chunk = 96;
        cfg.page_tokens = 32;
        cfg.heads = 4;
        cfg.head_dim = 64;
        let r = simulate(&arch, &trace, &cfg);
        assert_eq!(r.tokens, total, "{df:?}");
        assert_eq!(r.requests.len(), trace.requests.len());
        assert!(r.tokens_per_s > 0.0 && r.total_cycles > 0, "{df:?}");
        assert!(r.occupancy > 0.0 && r.occupancy <= 1.0, "{df:?}");
        assert!(
            r.requests.iter().all(|m| m.first_token >= m.arrival && m.finish >= m.first_token),
            "{df:?}"
        );
        // Static batching completes the same token count.
        cfg.policy = BatchPolicy::Static;
        let s = simulate(&arch, &trace, &cfg);
        assert_eq!(s.tokens, total, "{df:?} static");
    }
}

/// Sliding windows thread through the scheduler: a windowed replay moves
/// strictly less HBM traffic than the dense one (decode steps read only
/// the cache suffix). Table-I tiles keep K/V blocks (160 tokens at D=64)
/// smaller than the caches, so the window actually skips blocks — the
/// huge-L1 table2-8 tile would hold the whole cache in one block.
#[test]
fn scheduler_window_cuts_traffic() {
    let arch = presets::table1();
    let trace = RequestTrace::from_rows(&[(0, 192, 12), (0, 256, 12)], 2);
    let mut cfg = SchedulerConfig::new(Dataflow::Flash2);
    cfg.group = 8;
    cfg.slots = 4;
    cfg.chunk = 96;
    cfg.page_tokens = 32;
    cfg.heads = 4;
    cfg.head_dim = 64;
    let dense = simulate(&arch, &trace, &cfg);
    cfg.window = 64;
    let windowed = simulate(&arch, &trace, &cfg);
    assert_eq!(dense.tokens, windowed.tokens);
    assert!(
        windowed.hbm_bytes < dense.hbm_bytes,
        "windowed {} vs dense {}",
        windowed.hbm_bytes,
        dense.hbm_bytes
    );
}

/// Different placement policies yield different serving makespans end to
/// end on a narrow-HBM machine (the contention is not a micro-artifact).
#[test]
fn scheduler_placement_policies_differ_end_to_end() {
    let arch = presets::with_hbm_channels(presets::table2(8), 2);
    let trace = RequestTrace::from_rows(&[(0, 128, 16), (0, 192, 16), (0, 96, 16)], 2);
    let mut cfg = SchedulerConfig::new(Dataflow::Flash2);
    cfg.group = 2;
    cfg.slots = 4;
    cfg.chunk = 128;
    cfg.page_tokens = 32;
    cfg.heads = 4;
    cfg.head_dim = 64;
    let mut cycles = Vec::new();
    for placement in [PagePlacement::RoundRobin, PagePlacement::ChannelAffine, PagePlacement::Random]
    {
        cfg.placement = placement;
        let r = simulate(&arch, &trace, &cfg);
        assert_eq!(r.tokens, 48);
        cycles.push(r.total_cycles);
    }
    assert!(
        cycles.iter().any(|&c| c != cycles[0]),
        "placement policies all produced identical serving makespans: {cycles:?}"
    );
}
