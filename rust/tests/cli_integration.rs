//! CLI-level pins for the `schedule` subcommand's structured rejection
//! paths: an impossible configuration must produce one clean
//! `error: ...` diagnostic on stderr and exit code 1 — never a panic
//! backtrace. The library-level rejection paths themselves are pinned in
//! `scheduler::tests`; these tests cover the surfacing.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_flatattention"))
}

fn write_trace(name: &str, body: &str) -> PathBuf {
    let path = std::env::temp_dir().join(name);
    std::fs::write(&path, body).expect("write trace file");
    path
}

/// The per-request `kv_heads` CSV column can violate the model config
/// even when the CLI's own `--kv-heads` pre-check passes — this is the
/// rejection that must flow out of `try_simulate` as a clean exit 1.
#[test]
fn schedule_rejects_non_dividing_trace_kv_heads_cleanly() {
    let path = write_trace("flatattn_cli_bad_kv.csv", "0,64,2,3\n");
    let out = bin()
        .args(["schedule", "--trace"])
        .arg(&path)
        .args(["--heads", "4", "--d", "64", "--dataflow", "flash2"])
        .output()
        .expect("run schedule");
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(
        err.contains("error: request 0: kv_heads 3 must divide the model's 4 query heads"),
        "stderr: {err}"
    );
    assert!(!err.contains("panicked"), "no backtrace wanted: {err}");
}

/// Router options route through `try_route`, which shares the same
/// validation — and the same clean surfacing.
#[test]
fn schedule_router_path_rejects_the_same_way() {
    let path = write_trace("flatattn_cli_bad_kv_router.csv", "0,64,2,3\n");
    let out = bin()
        .args(["schedule", "--trace"])
        .arg(&path)
        .args(["--heads", "4", "--d", "64", "--dataflow", "flash2", "--deadline", "1000000"])
        .output()
        .expect("run schedule");
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("kv_heads 3 must divide"), "stderr: {err}");
    assert!(!err.contains("panicked"), "no backtrace wanted: {err}");
}

/// `--trace synthetic:N[:GAP]` streams the deterministic recurring-shape
/// trace (the bench's million-request path) straight from the CLI; a
/// malformed spec gets the same clean exit-1 surfacing as a bad config.
#[test]
fn schedule_replays_a_synthetic_stream_and_rejects_bad_specs() {
    let out = bin()
        .args(["schedule", "--trace", "synthetic:12", "--arch", "table2-8", "--slots", "4"])
        .args(["--group", "2", "--chunk", "128", "--page-tokens", "32", "--heads", "4"])
        .args(["--d", "64", "--dataflow", "flash2"])
        .output()
        .expect("run schedule");
    let err = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {err}");
    assert!(String::from_utf8_lossy(&out.stdout).contains("FA-2"));

    let out = bin()
        .args(["schedule", "--trace", "synthetic:zero", "--heads", "4", "--d", "64"])
        .output()
        .expect("run schedule");
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("expected synthetic:N[:GAP]"), "stderr: {err}");
    assert!(!err.contains("panicked"), "no backtrace wanted: {err}");
}

#[test]
fn schedule_runs_a_tiny_trace_end_to_end() {
    let path = write_trace("flatattn_cli_ok.csv", "0,64,2\n");
    let out = bin()
        .args(["schedule", "--trace"])
        .arg(&path)
        .args(["--heads", "4", "--kv-heads", "2", "--d", "64", "--chunk", "64"])
        .args(["--dataflow", "flash2"])
        .output()
        .expect("run schedule");
    let err = String::from_utf8_lossy(&out.stderr);
    assert_eq!(out.status.code(), Some(0), "stderr: {err}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("FA-2"), "{stdout}");
}
