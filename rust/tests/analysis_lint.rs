//! Integration: the `analysis` structural verifier and roofline
//! cross-checker over real builder output, composed batch programs and
//! public-API fault plans — the same surface `flatattention lint`
//! sweeps in CI's rust-analysis job. The corrupted-program defect
//! classes (cycle, shard leak, cross-shard edge, ...) are pinned by the
//! in-crate unit tests in `src/analysis/verify.rs`, which can tamper
//! with sealed internals; this file pins the public-API side: clean
//! production programs verify clean, their makespans respect the
//! analytical lower bounds, and batch/fault-plan misuse reachable
//! through public fields is named.

use flatattention::analysis::{verify_batch, verify_fault_plan, verify_program, Roofline};
use flatattention::arch::presets;
use flatattention::dataflow::{build_program, tracked_tile, Workload, ALL_DATAFLOWS};
use flatattention::hbm::PageMap;
use flatattention::scheduler::batch::{compose, BatchEntry};
use flatattention::sim::fault::{ChannelOutage, TileDeath};
use flatattention::sim::{execute, FaultPlan};

#[test]
fn builder_programs_verify_clean_and_respect_the_roofline() {
    let arch = presets::table2(8);
    let wl = Workload::new(512, 64, 8, 1).with_causal(true);
    for df in ALL_DATAFLOWS {
        let p = build_program(&arch, &wl, df, arch.mesh_x);
        let diags = verify_program(&p);
        assert!(diags.is_empty(), "{df:?}: {diags:?}");
        let stats = execute(&p, tracked_tile(&arch, df, arch.mesh_x));
        let rep = Roofline::of(&arch, &wl, &p)
            .check(stats.makespan)
            .unwrap_or_else(|d| panic!("{df:?}: {d}"));
        assert!(rep.bound > 0, "{df:?}: degenerate bound");
        assert!(rep.utilization > 0.0 && rep.utilization <= 1.0, "{df:?}: {rep:?}");
    }
}

#[test]
fn decode_and_gqa_programs_verify_clean() {
    // The serving-shaped workloads exercise different builder paths
    // (single-row decode, shared K/V heads) — the verifier must accept
    // them all.
    let arch = presets::table2(8);
    for wl in [
        Workload::new(256, 64, 8, 1).with_kv_heads(2).decode(),
        Workload::new(128, 64, 8, 2).with_kv_heads(1),
        Workload::new(256, 64, 4, 1).with_causal(true).with_window(64),
    ] {
        for df in ALL_DATAFLOWS {
            let p = build_program(&arch, &wl, df, arch.mesh_x);
            let diags = verify_program(&p);
            assert!(diags.is_empty(), "{df:?} {}: {diags:?}", wl.label());
        }
    }
}

#[test]
fn composed_batches_verify_clean_and_tampered_spans_are_named() {
    let arch = presets::table2(8);
    let nch = arch.hbm.total_channels() as u64;
    let mut p0 = PageMap::new(32);
    p0.grow_to(256, |i| (i % nch) as u32);
    let mut p1 = PageMap::new(32);
    p1.grow_to(300, |i| ((i + 1) % nch) as u32);
    let entries = vec![
        BatchEntry {
            request: 0,
            slot: 0,
            workload: Workload::new(128, 64, 4, 1).with_causal(true).with_kv_prefix(128),
            pages: &p0,
        },
        BatchEntry {
            request: 1,
            slot: 2,
            workload: Workload::new(300, 64, 4, 1).with_kv_heads(2).decode(),
            pages: &p1,
        },
    ];
    for df in ALL_DATAFLOWS {
        let mut bp = compose(&arch, df, 2, 4, &entries);
        let diags = verify_batch(&bp);
        assert!(diags.is_empty(), "{df:?}: {diags:?}");
        let (stats, _) = bp.entry_stats();
        let rep = Roofline::from_program(&arch, &bp.program)
            .check(stats.makespan)
            .unwrap_or_else(|d| panic!("{df:?}: {d}"));
        assert!(rep.utilization <= 1.0, "{df:?}: {rep:?}");

        // Corrupt the span table so entry 1 claims entry 0's ops: both
        // the span overlap and the resulting tile-band sharing are named.
        bp.spans[1] = bp.spans[0];
        let diags = verify_batch(&bp);
        let checks: Vec<_> = diags.iter().map(|d| d.check).collect();
        assert!(checks.contains(&"batch-span"), "{df:?}: {diags:?}");
        assert!(checks.contains(&"batch-band-overlap"), "{df:?}: {diags:?}");
    }
}

#[test]
fn fault_plans_are_vetted_against_the_machine_shape() {
    let arch = presets::table2(8);
    let channels = arch.hbm.total_channels();
    let tiles = arch.num_tiles();
    let good =
        FaultPlan::parse("slow:3@0-1000x2;off:1@10-20;noc@0-100x3/2;die:5@100").expect("valid");
    assert!(verify_fault_plan(&good, channels, tiles).is_empty());

    // Defects reachable through the public fields (the parser rejects
    // most of these up front; the verifier guards plans built in code).
    let mut bad = FaultPlan::none();
    bad.outages.push(ChannelOutage { channel: channels as u32 + 5, from: 10, until: 5 });
    bad.deaths.push(TileDeath { tile: tiles as u32, at: 0 });
    bad.deaths.push(TileDeath { tile: 3, at: 1 });
    bad.deaths.push(TileDeath { tile: 3, at: 2 });
    let diags = verify_fault_plan(&bad, channels, tiles);
    let checks: Vec<_> = diags.iter().map(|d| d.check).collect();
    for want in ["fault-window", "fault-channel", "fault-tile", "fault-duplicate-death"] {
        assert!(checks.contains(&want), "missing {want} in {diags:?}");
    }
}
