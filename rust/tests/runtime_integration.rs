//! Integration: AOT artifacts → PJRT → functional dataflow → golden.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! works on a fresh checkout; the Makefile `test` target always builds
//! artifacts first) and a build with the `pjrt` feature enabled (default
//! builds are simulation-only — see Cargo.toml).
#![cfg(feature = "pjrt")]

use flatattention::functional::{
    attention_golden, run_flat_group_functional, NativeCompute, RuntimeCompute,
};
use flatattention::runtime::{default_artifact_dir, Runtime};
use flatattention::util::{Rng, Tensor};

fn runtime_or_skip() -> Option<Runtime> {
    let dir = default_artifact_dir();
    if !Runtime::available(&dir) {
        eprintln!("skipping: no artifacts in {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Runtime::new(dir).expect("runtime starts"))
}

#[test]
fn pjrt_block_step_matches_native() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(0xB10C);
    for &(br, bc, d) in &[(16usize, 16usize, 128usize), (64, 64, 64), (128, 128, 128)] {
        let q = Tensor::randn(br, d, &mut rng);
        let k = Tensor::randn(bc, d, &mut rng);
        let v = Tensor::randn(bc, d, &mut rng);
        let kt = k.transpose();
        let m: Vec<f32> = (0..br).map(|_| rng.normal_f32() * 0.5).collect();
        let l: Vec<f32> = (0..br).map(|_| rng.f32() + 0.5).collect();
        let o = Tensor::randn(br, d, &mut rng);

        let (m2, l2, o2) = rt.block_step(&q, &kt, &v, &m, &l, &o).expect("pjrt exec");

        // Native reference.
        let st = flatattention::functional::golden::SoftmaxState { m, l, o };
        let want = flatattention::functional::block_step_native(&q, &kt, &v, &st);
        let m_diff = m2
            .iter()
            .zip(&want.m)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let l_diff = l2
            .iter()
            .zip(&want.l)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        let o_diff = o2.max_abs_diff(&want.o);
        assert!(m_diff < 1e-4, "r{br} c{bc} d{d}: m diff {m_diff}");
        assert!(l_diff < 1e-3, "r{br} c{bc} d{d}: l diff {l_diff}");
        assert!(o_diff < 1e-3, "r{br} c{bc} d{d}: o diff {o_diff}");
    }
}

#[test]
fn pjrt_functional_group_matches_golden() {
    // The full three-layer composition: Rust group dataflow + PJRT-compiled
    // Pallas block step reproduces plain attention.
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(0x600D);
    // g=2 over S=256 → 128-slices; g=4 → 64-slices (both have artifacts
    // at D=64 via (128,128,64)/(64,64,64)).
    for &(s, d, g) in &[(256usize, 64usize, 2usize), (256, 64, 4)] {
        let q = Tensor::randn(s, d, &mut rng);
        let k = Tensor::randn(s, d, &mut rng);
        let v = Tensor::randn(s, d, &mut rng);
        let compute = RuntimeCompute { runtime: &rt };
        let res = run_flat_group_functional(&q, &k, &v, g, &compute).expect("group run");
        let golden = attention_golden(&q, &k, &v);
        let diff = res.output.max_abs_diff(&golden);
        assert!(diff < 2e-3, "s={s} d={d} g={g}: diff {diff}");
        assert_eq!(res.block_steps, g * g);

        // And agrees with the native backend bit-for-bit-ish.
        let native = run_flat_group_functional(&q, &k, &v, g, &NativeCompute).unwrap();
        assert!(res.output.max_abs_diff(&native.output) < 2e-3);
    }
}

#[test]
fn pjrt_mha_artifact_matches_golden_per_head() {
    let Some(rt) = runtime_or_skip() else { return };
    let (b, h, s, d) = (1u64, 4u64, 256u64, 64u64);
    let n = (b * h * s * d) as usize;
    let mut rng = Rng::new(0xAB);
    let q: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let k: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    let out = rt.mha(b, h, s, d, &q, &k, &v).expect("mha exec");
    assert_eq!(out.len(), n);

    // Check one head against the golden reference.
    let head = 2usize;
    let stride = (s * d) as usize;
    let off = head * stride;
    let qh = Tensor::from_vec(s as usize, d as usize, q[off..off + stride].to_vec());
    let kh = Tensor::from_vec(s as usize, d as usize, k[off..off + stride].to_vec());
    let vh = Tensor::from_vec(s as usize, d as usize, v[off..off + stride].to_vec());
    let golden = attention_golden(&qh, &kh, &vh);
    let oh = Tensor::from_vec(s as usize, d as usize, out[off..off + stride].to_vec());
    let diff = oh.max_abs_diff(&golden);
    assert!(diff < 2e-3, "mha head diff {diff}");
}

#[test]
fn executable_cache_compiles_once() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(1);
    let q = Tensor::randn(16, 128, &mut rng);
    let kt = Tensor::randn(128, 16, &mut rng);
    let v = Tensor::randn(16, 128, &mut rng);
    let m = vec![0.0f32; 16];
    let l = vec![1.0f32; 16];
    let o = Tensor::zeros(16, 128);
    for _ in 0..3 {
        rt.block_step(&q, &kt, &v, &m, &l, &o).unwrap();
    }
    assert_eq!(rt.compiled_count(), 1);
}

#[test]
fn missing_shape_errors_cleanly() {
    let Some(rt) = runtime_or_skip() else { return };
    let q = Tensor::zeros(17, 128); // no artifact for br=17
    let kt = Tensor::zeros(128, 17);
    let v = Tensor::zeros(17, 128);
    let err = rt
        .block_step(&q, &kt, &v, &vec![0.0; 17], &vec![0.0; 17], &Tensor::zeros(17, 128))
        .unwrap_err();
    assert!(err.to_string().contains("no block_step artifact"), "{err}");
}
