//! Cross-module integration: dataflow programs vs analytical models,
//! property-based invariants over the workload/architecture space.

use flatattention::analytics::{flash_io_bytes, flat_io_bytes};
use flatattention::arch::presets;
use flatattention::dataflow::{
    build_program, flash_block_size, run, Dataflow, FlatTiling, Workload, ALL_DATAFLOWS,
};
use flatattention::sim::execute;
use flatattention::util::quickcheck::{check, forall_cases, pow2_in};

#[test]
fn every_dataflow_executes_every_small_layer() {
    let arch = presets::table1();
    for df in ALL_DATAFLOWS {
        for &(s, d) in &[(512u64, 64u64), (1024, 128)] {
            let wl = Workload::new(s, d, 4, 1);
            let stats = run(&arch, &wl, df, 8);
            assert!(stats.makespan > 0, "{df:?} {s} {d}");
            assert!(
                stats.hbm_bytes >= wl.compulsory_bytes(),
                "{df:?}: traffic below compulsory"
            );
            assert_eq!(stats.flops, wl.matmul_flops());
            assert_eq!(stats.breakdown.total(), stats.makespan);
        }
    }
}

#[test]
fn flash_traffic_matches_io_formula() {
    let arch = presets::table1();
    forall_cases(12, 0x10F, |rng| {
        let s = pow2_in(rng, 512, 4096);
        let d = *rng.choose(&[64u64, 128]);
        let h = 1 + rng.gen_range(8);
        let wl = Workload::new(s, d, h, 1);
        let m = flash_block_size(&arch.tile, d, false);
        let stats = run(&arch, &wl, Dataflow::Flash2, 1);
        let model = flash_io_bytes(&wl, m) as f64;
        let ratio = stats.hbm_bytes as f64 / model;
        check(
            (0.8..1.2).contains(&ratio),
            format!("S{s} D{d} H{h}: sim {} vs model {model} ({ratio:.3})", stats.hbm_bytes),
        )
    });
}

#[test]
fn flat_traffic_matches_io_formula() {
    let arch = presets::table1();
    forall_cases(12, 0xF1A, |rng| {
        let s = pow2_in(rng, 1024, 4096);
        let d = *rng.choose(&[64u64, 128]);
        let g = *rng.choose(&[8usize, 16, 32]);
        let wl = Workload::new(s, d, 8, 1);
        let tiling = FlatTiling::resolve(&arch, &wl, g, false);
        let stats = run(&arch, &wl, Dataflow::FlatColl, g);
        let model = flat_io_bytes(&wl, tiling.block) as f64;
        let ratio = stats.hbm_bytes as f64 / model;
        check(
            (0.9..1.1).contains(&ratio),
            format!("S{s} D{d} G{g}: sim {} vs model {model} ({ratio:.3})", stats.hbm_bytes),
        )
    });
}

#[test]
fn makespan_monotone_in_workload() {
    // More heads ⇒ more work ⇒ no shorter runtime, for every dataflow.
    let arch = presets::table1();
    for df in ALL_DATAFLOWS {
        let small = run(&arch, &Workload::new(1024, 128, 4, 1), df, 16);
        let large = run(&arch, &Workload::new(1024, 128, 16, 1), df, 16);
        assert!(
            large.makespan >= small.makespan,
            "{df:?}: 16 heads ({}) faster than 4 heads ({})",
            large.makespan,
            small.makespan
        );
    }
}

#[test]
fn hw_collectives_never_slower() {
    let arch = presets::table1();
    forall_cases(8, 0xC011, |rng| {
        let s = pow2_in(rng, 512, 2048);
        let g = *rng.choose(&[8usize, 16]);
        let wl = Workload::new(s, 128, 4, 1);
        let sw = run(&arch, &wl, Dataflow::Flat, g);
        let hw = run(&arch, &wl, Dataflow::FlatColl, g);
        check(
            hw.makespan <= sw.makespan,
            format!("S{s} G{g}: hw {} > sw {}", hw.makespan, sw.makespan),
        )
    });
}

#[test]
fn async_overlap_helps_at_long_sequence() {
    let arch = presets::table1();
    let wl = Workload::new(4096, 128, 32, 2);
    let sync = run(&arch, &wl, Dataflow::FlatColl, 32);
    let asyn = run(&arch, &wl, Dataflow::FlatAsyn, 32);
    assert!(
        asyn.makespan < sync.makespan,
        "async {} should beat sync {}",
        asyn.makespan,
        sync.makespan
    );
}

#[test]
fn programs_are_valid_dags() {
    let arch = presets::table1();
    forall_cases(10, 0xDA6, |rng| {
        let s = pow2_in(rng, 512, 2048);
        let d = *rng.choose(&[64u64, 128]);
        let g = *rng.choose(&[4usize, 8, 16, 32]);
        let df = *rng.choose(&ALL_DATAFLOWS);
        let wl = Workload::new(s, d, 2, 1);
        let p = build_program(&arch, &wl, df, g);
        check(p.validate().is_ok(), format!("{df:?} S{s} D{d} G{g}: invalid DAG"))
    });
}

#[test]
fn determinism_same_spec_same_result() {
    let arch = presets::table1();
    let wl = Workload::new(1024, 128, 8, 1);
    for df in ALL_DATAFLOWS {
        let a = run(&arch, &wl, df, 16);
        let b = run(&arch, &wl, df, 16);
        assert_eq!(a.makespan, b.makespan, "{df:?} nondeterministic");
        assert_eq!(a.hbm_bytes, b.hbm_bytes);
        assert_eq!(a.breakdown, b.breakdown);
    }
}

#[test]
fn smaller_mesh_archs_work() {
    // Table II granularities execute all dataflows.
    for g in [16usize, 8] {
        let arch = presets::table2(g);
        let wl = Workload::new(1024, 128, 4, 1);
        for df in ALL_DATAFLOWS {
            let group = if df.is_flat() { g.min(8) } else { 1 };
            let stats = run(&arch, &wl, df, group);
            assert!(stats.makespan > 0, "{df:?} on table2-{g}");
        }
    }
}

#[test]
fn utilization_bounded_by_one() {
    let arch = presets::table1();
    forall_cases(10, 0x0B0E, |rng| {
        let s = pow2_in(rng, 512, 4096);
        let df = *rng.choose(&ALL_DATAFLOWS);
        let wl = Workload::new(s, 128, 4, 2);
        let stats = run(&arch, &wl, df, 16);
        let u = stats.compute_utilization(arch.peak_flops_per_cycle());
        let bw = stats.hbm_bw_utilization(arch.hbm.peak_bytes_per_cycle());
        check(
            (0.0..=1.0).contains(&u) && (0.0..=1.0).contains(&bw),
            format!("{df:?} S{s}: util {u} bw {bw}"),
        )
    });
}

#[test]
fn summa_executes_and_validates() {
    use flatattention::dataflow::summa::{summa_program, GemmWorkload};
    let arch = presets::table1();
    let g = GemmWorkload::new(2048, 4096, 2048, "it");
    let p = summa_program(&arch, &g);
    assert!(p.validate().is_ok());
    let stats = execute(&p, 0);
    assert!(stats.makespan > 0);
    assert!(stats.compute_utilization(arch.peak_flops_per_cycle()) > 0.3);
}

#[test]
fn every_dataflow_runs_gqa_mqa_and_decode() {
    // Acceptance: GQA (kv_heads < heads), MQA (kv_heads == 1) and decode
    // (single query row) run end-to-end on every dataflow, with coherent
    // accounting (traffic ≥ compulsory, useful-FLOP bookkeeping, full
    // breakdown partition).
    let arch = presets::table1();
    let serving = [
        Workload::new(1024, 128, 8, 1).with_kv_heads(2), // GQA prefill
        Workload::new(1024, 64, 8, 1).with_kv_heads(1),  // MQA prefill
        Workload::new(2048, 128, 8, 1).decode(),         // MHA decode
        Workload::new(2048, 64, 8, 2).with_kv_heads(2).decode(), // GQA decode
        Workload::new(512, 64, 8, 1).with_kv_heads(1).decode(), // MQA decode
        Workload::new(1024, 64, 8, 1).with_kv_heads(4).with_causal(true), // causal GQA
    ];
    for df in ALL_DATAFLOWS {
        for wl in serving {
            let stats = run(&arch, &wl, df, 8);
            assert!(stats.makespan > 0, "{df:?} {wl:?}");
            assert!(
                stats.hbm_bytes >= wl.compulsory_bytes(),
                "{df:?} {wl:?}: traffic {} below compulsory {}",
                stats.hbm_bytes,
                wl.compulsory_bytes()
            );
            assert_eq!(stats.flops, wl.matmul_flops(), "{df:?} {wl:?}");
            assert_eq!(stats.breakdown.total(), stats.makespan, "{df:?} {wl:?}");
        }
    }
}

#[test]
fn gqa_never_moves_more_bytes_than_mha() {
    // Sharing K/V across a head group can only reduce HBM traffic, on
    // every dataflow and in both phases.
    let arch = presets::table1();
    for df in ALL_DATAFLOWS {
        for base in [
            Workload::new(1024, 128, 8, 1),
            Workload::new(1024, 128, 8, 1).decode(),
        ] {
            let mha = run(&arch, &base, df, 8);
            for kv in [4u64, 2, 1] {
                let gqa = run(&arch, &base.with_kv_heads(kv), df, 8);
                assert!(
                    gqa.hbm_bytes <= mha.hbm_bytes,
                    "{df:?} kv{kv} {:?}: {} > {}",
                    base.phase,
                    gqa.hbm_bytes,
                    mha.hbm_bytes
                );
            }
        }
    }
}

#[test]
fn decode_kv_traffic_scales_by_kv_over_heads() {
    // Acceptance: modeled K/V HBM traffic scales by kv_heads/heads vs MHA
    // on the same shape. Decode makes this exact for FlashAttention (the
    // single row block reads the cache exactly once per KV head): total
    // traffic equals compulsory, so the K/V share is analytic.
    let arch = presets::table1();
    let base = Workload::new(4096, 128, 16, 2).decode();
    let qo = 2 * base.batch * base.heads * base.head_dim * Workload::BYTES_PER_ELEM;
    let mha = run(&arch, &base, Dataflow::Flash2, 1);
    assert_eq!(mha.hbm_bytes, base.compulsory_bytes());
    for kv in [4u64, 1] {
        let wl = base.with_kv_heads(kv);
        let st = run(&arch, &wl, Dataflow::Flash2, 1);
        assert_eq!(st.hbm_bytes, wl.compulsory_bytes(), "kv{kv}");
        // (traffic - Q/O) scales exactly by kv/heads.
        assert_eq!(
            (mha.hbm_bytes - qo) * kv,
            (st.hbm_bytes - qo) * base.heads,
            "kv{kv}"
        );
    }
}

#[test]
fn degenerate_serving_shapes_execute_on_every_dataflow() {
    // S=1, S < group, d > S, MQA, decode, causal — the crash-prone corner
    // of the serving space must build valid DAGs and execute (tiny mesh so
    // the grid stays cheap).
    let arch = presets::table2(8);
    for df in ALL_DATAFLOWS {
        for s in [1u64, 3, 7, 16] {
            for decode in [false, true] {
                for kv_heads in [4u64, 1] {
                    let mut wl = Workload::new(s, 64, 4, 1)
                        .with_kv_heads(kv_heads)
                        .with_causal(s % 2 == 1);
                    if decode {
                        wl = wl.decode();
                    }
                    let p = build_program(&arch, &wl, df, 4);
                    assert!(p.validate().is_ok(), "{df:?} {wl:?}: invalid DAG");
                    let stats = run(&arch, &wl, df, 4);
                    assert!(stats.makespan > 0, "{df:?} {wl:?}");
                    assert_eq!(stats.breakdown.total(), stats.makespan, "{df:?} {wl:?}");
                }
            }
        }
    }
}

#[test]
fn causal_halves_runtime_and_traffic() {
    // Causal prefill skips ~half the K/V blocks: runtime and HBM traffic
    // drop substantially for every dataflow at long sequence length.
    let arch = presets::table1();
    let wl = Workload::new(4096, 128, 32, 2);
    let wlc = wl.with_causal(true);
    // Group 8 so T_c > 1 (with the full-mesh group the single block IS the
    // diagonal — nothing to skip, only the mask cost remains).
    for (df, g) in [(Dataflow::Flash2, 1), (Dataflow::FlatAsyn, 8)] {
        let full = run(&arch, &wl, df, g);
        let causal = run(&arch, &wlc, df, g);
        let rt = causal.makespan as f64 / full.makespan as f64;
        assert!(
            (0.35..0.85).contains(&rt),
            "{df:?}: causal/full runtime {rt:.2}"
        );
        assert!(causal.hbm_bytes < full.hbm_bytes, "{df:?}: traffic must drop");
    }
}

#[test]
fn causal_flops_accounting() {
    let wl = Workload::new(4096, 128, 32, 2);
    let wlc = wl.with_causal(true);
    // Useful causal flops ≈ half of full.
    let ratio = wlc.matmul_flops() as f64 / wl.matmul_flops() as f64;
    assert!((ratio - 0.5).abs() < 0.01, "{ratio}");
}

#[test]
fn causal_utilization_reasonable() {
    // Diagonal-block waste means causal utilization (useful flops) is a
    // bit below non-causal but still high at S=4096 on FlatAsyn.
    let arch = presets::table1();
    let wlc = Workload::new(4096, 128, 32, 2).with_causal(true);
    let stats = run(&arch, &wlc, Dataflow::FlatAsyn, 8);
    let u = stats.compute_utilization(arch.peak_flops_per_cycle());
    assert!(u > 0.35, "causal FlatAsyn utilization {u:.3}");
}
