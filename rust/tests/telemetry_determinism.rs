//! §Telemetry determinism wall.
//!
//! The deterministic telemetry snapshot (Prometheus text with `engine_*`
//! hidden) and the exported chrome-trace document must be **byte-identical**
//! across DES thread counts and composer modes — telemetry is a pure
//! function of the serving schedule, which the PR-7/8 differential walls
//! already pin. On top of that: the registry must stay O(windows + buckets)
//! regardless of request count, and the exported trace must reconcile
//! exactly with the per-request TTFT/TPOT numbers in the `ServingReport`.

use flatattention::arch::presets;
use flatattention::dataflow::Dataflow;
use flatattention::scheduler::{
    try_route_with, try_simulate_with, RequestTrace, RouterConfig, SchedulerConfig,
};
use flatattention::sim::FaultPlan;
use flatattention::telemetry::RunTelemetry;
use flatattention::util::json::Json;

/// (incremental, memoize) — the baseline plus every lever combination.
const MODES: [(bool, bool); 4] = [(false, false), (true, false), (false, true), (true, true)];
const THREADS: [usize; 3] = [1, 2, 8];

fn tiny_cfg(df: Dataflow) -> SchedulerConfig {
    let mut cfg = SchedulerConfig::new(df);
    cfg.slots = 4;
    cfg.group = 2;
    cfg.chunk = 96;
    cfg.page_tokens = 32;
    cfg.heads = 4;
    cfg.head_dim = 64;
    cfg
}

fn mixed_trace() -> RequestTrace {
    RequestTrace::from_rows(
        &[(0, 160, 4), (0, 96, 8), (5_000, 200, 3), (20_000, 64, 6), (40_000, 128, 5)],
        2,
    )
}

/// One instrumented scheduler run → (deterministic metrics text, trace doc).
fn snap_simulate(threads: usize, inc: bool, memo: bool) -> (String, String) {
    let arch = presets::table2(8);
    let trace = mixed_trace();
    let mut cfg = tiny_cfg(Dataflow::Flash2);
    cfg.threads = threads;
    cfg.incremental = inc;
    cfg.memoize = memo;
    let mut tel = RunTelemetry::new().with_trace();
    let rep = try_simulate_with(&arch, &trace, &cfg, Some(&mut tel)).expect("valid config");
    assert!(rep.telemetry.is_some(), "instrumented run embeds the snapshot");
    (tel.metrics.to_prometheus(false), tel.trace_json().unwrap().to_string())
}

/// One instrumented router run with a mid-run band death → same pair.
fn snap_route(threads: usize, inc: bool, memo: bool) -> (String, String) {
    let arch = presets::table2(8);
    let trace = RequestTrace::from_rows(
        &[(0, 160, 4), (0, 96, 8), (0, 200, 3), (0, 64, 6), (40_000, 128, 5)],
        2,
    );
    let mut cfg = tiny_cfg(Dataflow::Flash2);
    cfg.threads = threads;
    cfg.incremental = inc;
    cfg.memoize = memo;
    // Band 3 (first tile 48) dies almost immediately — the lifecycle
    // stream must carry the band death and the resulting requeue.
    let rc = RouterConfig {
        faults: FaultPlan::none().with_tile_death(48, 1),
        ..RouterConfig::default()
    };
    let mut tel = RunTelemetry::new().with_trace();
    let rep = try_route_with(&arch, &trace, &cfg, &rc, Some(&mut tel)).expect("valid config");
    assert!(rep.serving.telemetry.is_some());
    assert!(rep.band_evictions >= 1, "the dying band must requeue its request");
    (tel.metrics.to_prometheus(false), tel.trace_json().unwrap().to_string())
}

#[test]
fn scheduler_snapshots_bit_identical_across_threads_and_modes() {
    let (want_m, want_t) = snap_simulate(1, false, false);
    assert!(want_m.contains("flatattn_requests_completed"));
    assert!(want_t.contains("prefill"));
    for threads in THREADS {
        for (inc, memo) in MODES {
            let (m, t) = snap_simulate(threads, inc, memo);
            assert_eq!(m, want_m, "metrics diverged: threads={threads} inc={inc} memo={memo}");
            assert_eq!(t, want_t, "trace diverged: threads={threads} inc={inc} memo={memo}");
        }
    }
}

#[test]
fn router_snapshots_bit_identical_across_threads_and_modes_under_faults() {
    let (want_m, want_t) = snap_route(1, false, false);
    assert!(want_m.contains("flatattn_bands_died"));
    assert!(want_t.contains("band-dead"));
    for threads in THREADS {
        for (inc, memo) in MODES {
            let (m, t) = snap_route(threads, inc, memo);
            assert_eq!(m, want_m, "metrics diverged: threads={threads} inc={inc} memo={memo}");
            assert_eq!(t, want_t, "trace diverged: threads={threads} inc={inc} memo={memo}");
        }
    }
}

/// The registry is windowed + log-bucketed: a 20x bigger request stream
/// must not grow it remotely proportionally, and its absolute size stays
/// within the O(windows + buckets + names) budget.
#[test]
fn registry_memory_bounded_by_windows_not_requests() {
    let arch = presets::table2(8);
    let mut cfg = tiny_cfg(Dataflow::Flash2);
    cfg.incremental = true;
    cfg.memoize = true;
    let footprint = |n: usize| {
        let trace = RequestTrace::synthetic(n, 500);
        let mut tel = RunTelemetry::new();
        try_simulate_with(&arch, &trace, &cfg, Some(&mut tel)).expect("valid config");
        assert_eq!(tel.metrics.counter("requests_completed"), n as u64);
        tel.metrics.footprint()
    };
    let small = footprint(24);
    let big = footprint(480);
    assert!(big <= small * 8, "footprint scaled with requests: {small} -> {big} for 20x load");
    assert!(big < 16_384, "footprint exceeds the windows+buckets budget: {big}");
}

fn fnum(e: &Json, key: &str) -> f64 {
    e.get(key).and_then(Json::as_f64).unwrap_or(f64::NAN)
}

fn is_named(e: &Json, name: &str) -> bool {
    e.get("name").and_then(Json::as_str) == Some(name)
}

/// The exported chrome trace must agree with the report's per-request
/// metrics: the queued span starts at arrival, the first-token instant is
/// the TTFT anchor, the completed instant is the finish clock, and the
/// prefill/decode slices tile [admitted, finish] with no gaps.
#[test]
fn exported_trace_reconciles_with_ttft_and_tpot() {
    let arch = presets::table2(8);
    let trace = mixed_trace();
    let cfg = tiny_cfg(Dataflow::Flash2);
    let mut tel = RunTelemetry::new().with_trace();
    let rep = try_simulate_with(&arch, &trace, &cfg, Some(&mut tel)).expect("valid config");
    // Round-trip through text: this is exactly what `--trace-out` writes.
    let doc = Json::parse(&tel.trace_json().unwrap().to_string()).expect("well-formed JSON");
    assert_eq!(doc.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(rep.requests.len(), trace.requests.len(), "everyone completes fault-free");
    for r in &rep.requests {
        let pid = (r.id + 1) as f64;
        let mine: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("pid").and_then(Json::as_f64) == Some(pid))
            .filter(|e| e.get("ph").and_then(Json::as_str) != Some("M"))
            .collect();
        let queued: Vec<&&Json> = mine.iter().filter(|e| is_named(e, "queued")).collect();
        assert_eq!(queued.len(), 1, "request {} re-queued in a fault-free run", r.id);
        assert_eq!(fnum(queued[0], "ts"), r.arrival as f64, "request {} arrival", r.id);
        let first: Vec<&&Json> = mine.iter().filter(|e| is_named(e, "first-token")).collect();
        assert_eq!(first.len(), 1);
        assert_eq!(fnum(first[0], "ts"), r.first_token as f64, "request {} TTFT", r.id);
        let done: Vec<&&Json> = mine.iter().filter(|e| is_named(e, "completed")).collect();
        assert_eq!(done.len(), 1);
        assert_eq!(fnum(done[0], "ts"), r.finish as f64, "request {} finish", r.id);
        // Slices tile the admitted..finish interval (TPOT is finish minus
        // first-token over output-1 tokens, so gap-free slices pin it too).
        let mut slices: Vec<(f64, f64)> = mine
            .iter()
            .filter(|e| is_named(e, "prefill") || is_named(e, "decode"))
            .map(|e| (fnum(e, "ts"), fnum(e, "dur")))
            .collect();
        slices.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(!slices.is_empty());
        let mut cursor = fnum(queued[0], "ts") + fnum(queued[0], "dur");
        for (ts, dur) in &slices {
            assert_eq!(*ts, cursor, "gap in request {} timeline at {ts}", r.id);
            cursor = ts + dur;
        }
        assert_eq!(cursor, r.finish as f64, "request {} last slice != finish", r.id);
    }
}
