//! Minimal offline shim of the `anyhow` error-handling API.
//!
//! The build environment has no crates.io access, so this path dependency
//! provides the subset of `anyhow` the crate uses: [`Error`], the
//! defaulted [`Result`] alias, the [`Context`] extension trait and the
//! [`anyhow!`] / [`bail!`] macros. Errors are flattened to strings —
//! sufficient for a CLI/simulation stack where errors are reported, not
//! matched on.

use std::fmt;

/// A string-backed error value. Like `anyhow::Error`, it deliberately does
/// not implement `std::error::Error`, which permits the blanket
/// `From<E: std::error::Error>` conversion used by `?`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message (used by [`anyhow!`]).
    pub fn new(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string() }
    }

    /// Alias of [`Error::new`] matching `anyhow::Error::msg`.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self::new(msg)
    }

    /// Prepend a context layer, rendered as `context: cause`.
    pub fn context(self, context: impl fmt::Display) -> Self {
        Self { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`, mirroring `anyhow::Context`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::new(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::new(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::new(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent-anyhow-shim-test")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_layers_render_outermost_first() {
        let e: Result<()> = Err(Error::new("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert!(e.to_string().contains("missing 7"));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 3);
        assert_eq!(e.to_string(), "bad value 3");
        fn f() -> Result<()> {
            bail!("stop {}", "now")
        }
        assert_eq!(f().unwrap_err().to_string(), "stop now");
    }

    #[test]
    fn result_alias_allows_custom_error() {
        let r: Result<u32, String> = Err("plain".into());
        assert!(r.context("ctx").is_err());
    }
}
