//! Table I (system configuration) and Table II (fabric granularity vs
//! tile specifications) renderers.

use crate::arch::presets;
use crate::report::Table;

/// Render Table I (the 32×32 mesh instance).
pub fn render_table1() -> String {
    let a = presets::table1();
    let mut out = String::new();
    out.push_str("Table I — Architecture configuration of the tile-based many-PE accelerator\n\n");
    let mut t = Table::new(&["component", "specification"]);
    t.row(vec![
        "System".into(),
        format!("{}x{} tiles, {}-bit NoC link width", a.mesh_x, a.mesh_y, a.noc.link_bytes_per_cycle * 8),
    ]);
    t.row(vec![
        "HBM".into(),
        format!(
            "{}x2 channels ({} GB/s each), west + south edges",
            a.hbm.channels_west,
            a.hbm.channel_bytes_per_cycle
        ),
    ]);
    t.row(vec![
        "Matrix engine".into(),
        format!(
            "RedMulE {}x{} CE array, {:.0} GFLOPS @ FP16",
            a.tile.redmule_rows,
            a.tile.redmule_cols,
            a.tile.redmule_flops_per_cycle() as f64 * a.freq_ghz
        ),
    ]);
    t.row(vec![
        "Vector engine".into(),
        format!(
            "Spatz {} FPU, {:.0} GFLOPS @ FP16",
            a.tile.spatz_fpus,
            a.tile.spatz_flops_per_cycle() as f64 * a.freq_ghz
        ),
    ]);
    t.row(vec![
        "Local memory".into(),
        format!("{} KB, {} GB/s", a.tile.l1_kib, a.tile.l1_bytes_per_cycle),
    ]);
    t.row(vec![
        "Summary".into(),
        format!(
            "{:.0} TFLOPS peak, {:.0} TB/s peak HBM bandwidth",
            a.peak_tflops(),
            a.hbm.peak_gbps(a.freq_ghz) / 1000.0
        ),
    ]);
    out.push_str(&t.render());
    out
}

/// Render Table II (tile-granularity instances).
pub fn render_table2() -> String {
    let mut out = String::new();
    out.push_str("Table II — Fabric granularity and tile specifications (iso 1024 TFLOPS, iso on-chip memory)\n\n");
    let mut t = Table::new(&[
        "fabric granularity", "RedMulE CE", "Spatz FU", "L1 (KiB)", "L1 BW (GB/s)", "peak TFLOPS",
    ]);
    for g in [32usize, 16, 8] {
        let a = presets::table2(g);
        t.row(vec![
            format!("{g}x{g}"),
            format!("{}x{}", a.tile.redmule_rows, a.tile.redmule_cols),
            a.tile.spatz_fpus.to_string(),
            a.tile.l1_kib.to_string(),
            a.tile.l1_bytes_per_cycle.to_string(),
            format!("{:.0}", a.peak_tflops()),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_mentions_paper_numbers() {
        let s = render_table1();
        assert!(s.contains("32x32 tiles"));
        assert!(s.contains("1024-bit"));
        assert!(s.contains("16x2 channels"));
        assert!(s.contains("1049 TFLOPS") || s.contains("1048 TFLOPS"));
    }

    #[test]
    fn table2_rows_match_presets() {
        let s = render_table2();
        assert!(s.contains("128x64"));
        assert!(s.contains("6144"));
        assert!(s.contains("8192"));
    }
}
