//! Serving-schedule figure (our extension): Flash vs Flat families under
//! continuous-batching load.
//!
//! Replays the built-in mixed request trace through `crate::scheduler`
//! for every dataflow × page-placement policy and reports tokens/s, mean
//! TTFT, mean TPOT and batch occupancy, plus the continuous-vs-static
//! batching speedup on the burst trace — the serving headline the kernel
//! figures can't show.

use crate::arch::presets;
use crate::arch::ArchConfig;
use crate::coordinator::ResultStore;
use crate::dataflow::{Dataflow, ALL_DATAFLOWS};
use crate::report::{pct, ReportOpts, Table};
use crate::scheduler::{
    simulate, BatchPolicy, PagePlacement, RequestTrace, SchedulerConfig, ServingReport,
    ALL_PLACEMENTS,
};
use crate::util::json::Json;

/// Default GQA K/V heads of the serving model (32 query heads / 8).
pub const KV_HEADS: u64 = 8;

/// One rendered grid point.
pub struct ScheduleRow {
    /// Dataflow under test.
    pub dataflow: Dataflow,
    /// KV page placement policy.
    pub placement: PagePlacement,
    /// Serving outcome at this point.
    pub report: ServingReport,
}

/// Run the dataflow × placement grid on one architecture.
pub fn run_grid(arch: &ArchConfig, trace: &RequestTrace, base: &SchedulerConfig) -> Vec<ScheduleRow> {
    let mut rows = Vec::new();
    for df in ALL_DATAFLOWS {
        for placement in ALL_PLACEMENTS {
            let cfg = SchedulerConfig { dataflow: df, placement, ..base.clone() };
            rows.push(ScheduleRow { dataflow: df, placement, report: simulate(arch, trace, &cfg) });
        }
    }
    rows
}

fn row_json(r: &ScheduleRow, mode: &str) -> Json {
    Json::obj([
        ("dataflow", Json::str(r.dataflow.label())),
        ("placement", Json::str(r.placement.label())),
        ("mode", Json::str(mode.to_string())),
        ("tokens_per_s", Json::num(r.report.tokens_per_s)),
        ("ttft_ms", Json::num(r.report.ttft_mean_ms)),
        ("tpot_ms", Json::num(r.report.tpot_mean_ms)),
        ("ttft_p50_ms", Json::num(r.report.ttft_p50_ms)),
        ("ttft_p95_ms", Json::num(r.report.ttft_p95_ms)),
        ("ttft_p99_ms", Json::num(r.report.ttft_p99_ms)),
        ("tpot_p50_ms", Json::num(r.report.tpot_p50_ms)),
        ("tpot_p95_ms", Json::num(r.report.tpot_p95_ms)),
        ("tpot_p99_ms", Json::num(r.report.tpot_p99_ms)),
        ("goodput_tokens_per_s", Json::num(r.report.goodput_tokens_per_s)),
        ("occupancy", Json::num(r.report.occupancy)),
        ("hbm_gb", Json::num(r.report.hbm_bytes as f64 / 1e9)),
        ("steps", Json::num(r.report.steps as f64)),
        ("total_cycles", Json::num(r.report.total_cycles as f64)),
    ])
}

/// Render the schedule figure; optionally record rows in `store`.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let (arch, base, setup) = if opts.quick {
        let mut b = SchedulerConfig::new(Dataflow::Flash2);
        b.group = 2;
        b.chunk = 128;
        b.page_tokens = 32;
        (presets::table2(8), b, "table2-8x8, slots=4, chunk=128")
    } else {
        let b = SchedulerConfig::new(Dataflow::Flash2);
        (presets::table1(), b, "Table I arch, slots=4, chunk=512")
    };
    let mut trace = RequestTrace::builtin("mixed", KV_HEADS).expect("builtin trace");
    if opts.quick {
        trace.requests.truncate(6);
        for r in &mut trace.requests {
            r.prompt = r.prompt.min(256);
            r.output = r.output.min(12);
        }
    }
    render_on(&arch, &trace, &base, setup, opts, store)
}

/// Render a schedule grid (shared by the CLI figure and the tiny-mesh
/// smoke tests).
pub fn render_on(
    arch: &ArchConfig,
    trace: &RequestTrace,
    base: &SchedulerConfig,
    setup: &str,
    opts: &ReportOpts,
    store: Option<&mut ResultStore>,
) -> String {
    let rows = run_grid(arch, trace, base);

    // Continuous vs static batching on the burst trace (skewed output
    // lengths), for one representative of each family. The burst requests
    // reuse the grid trace's kv_heads so they stay compatible with the
    // caller's model config (the grid already validated it).
    let burst_kv = trace.requests.first().map(|r| r.kv_heads).unwrap_or(base.heads);
    let mut burst = RequestTrace::builtin("burst", burst_kv).expect("burst trace");
    if opts.quick {
        for r in &mut burst.requests {
            r.prompt = r.prompt.min(256);
            r.output = r.output.min(16);
        }
    }
    let mut speedups: Vec<(Dataflow, f64, f64)> = Vec::new();
    for df in [Dataflow::Flash2, Dataflow::FlatColl] {
        let cont = simulate(
            arch,
            &burst,
            &SchedulerConfig { dataflow: df, policy: BatchPolicy::Continuous, ..base.clone() },
        );
        let stat = simulate(
            arch,
            &burst,
            &SchedulerConfig { dataflow: df, policy: BatchPolicy::Static, ..base.clone() },
        );
        speedups.push((df, cont.tokens_per_s, cont.tokens_per_s / stat.tokens_per_s.max(1e-9)));
    }

    if let Some(store) = store {
        let mut json: Vec<Json> = rows.iter().map(|r| row_json(r, "continuous")).collect();
        for &(df, tps, speedup) in &speedups {
            json.push(Json::obj([
                ("dataflow", Json::str(df.label())),
                ("mode", Json::str("burst-continuous-vs-static")),
                ("tokens_per_s", Json::num(tps)),
                ("continuous_over_static", Json::num(speedup)),
            ]));
        }
        store.add_json("schedule", json);
    }

    let mut out = String::new();
    out.push_str(&format!(
        "Serving schedule — continuous batching, mixed prefill+decode trace ({} requests, {setup})\n\n",
        trace.requests.len()
    ));
    let mut t = Table::new(&[
        "dataflow",
        "placement",
        "tokens/s",
        "goodput/s",
        "TTFT_ms",
        "TTFT_p95",
        "TPOT_ms",
        "TPOT_p95",
        "occupancy",
        "HBM_GB",
        "steps",
    ]);
    for r in &rows {
        t.row(vec![
            r.dataflow.label().to_string(),
            r.placement.label().to_string(),
            format!("{:.0}", r.report.tokens_per_s),
            format!("{:.0}", r.report.goodput_tokens_per_s),
            format!("{:.3}", r.report.ttft_mean_ms),
            format!("{:.3}", r.report.ttft_p95_ms),
            format!("{:.4}", r.report.tpot_mean_ms),
            format!("{:.4}", r.report.tpot_p95_ms),
            pct(r.report.occupancy),
            format!("{:.3}", r.report.hbm_bytes as f64 / 1e9),
            r.report.steps.to_string(),
        ]);
    }
    out.push_str(&t.render());
    out.push('\n');
    for (df, tps, speedup) in &speedups {
        out.push_str(&format!(
            "burst trace, {}: continuous batching {:.0} tokens/s, {:.2}x over static batching\n",
            df.label(),
            tps,
            speedup
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::RequestTrace;

    fn smoke_setup() -> (ArchConfig, RequestTrace, SchedulerConfig) {
        let arch = presets::table2(8);
        let trace = RequestTrace::from_rows(
            &[(0, 160, 4), (0, 96, 8), (5_000, 200, 3), (20_000, 64, 6)],
            2,
        );
        let mut cfg = SchedulerConfig::new(Dataflow::Flash2);
        cfg.slots = 4;
        cfg.group = 2;
        cfg.chunk = 96;
        cfg.page_tokens = 32;
        cfg.heads = 4;
        cfg.head_dim = 64;
        (arch, trace, cfg)
    }

    /// CI smoke: the full schedule figure path (all dataflows × placements
    /// through the scheduler and renderer) on a tiny mesh.
    #[test]
    fn schedule_grid_smoke_tiny_mesh() {
        let (arch, trace, cfg) = smoke_setup();
        let rows = run_grid(&arch, &trace, &cfg);
        assert_eq!(rows.len(), ALL_DATAFLOWS.len() * ALL_PLACEMENTS.len());
        let total: u64 = trace.requests.iter().map(|r| r.output).sum();
        for r in &rows {
            assert_eq!(r.report.tokens, total, "{:?}/{:?}", r.dataflow, r.placement);
            assert!(r.report.tokens_per_s > 0.0);
            assert!(r.report.ttft_mean_ms >= 0.0 && r.report.tpot_mean_ms >= 0.0);
            assert!(r.report.occupancy > 0.0 && r.report.occupancy <= 1.0);
            // Tail percentiles are ordered and goodput never exceeds
            // throughput.
            assert!(r.report.ttft_p50_ms <= r.report.ttft_p95_ms);
            assert!(r.report.ttft_p95_ms <= r.report.ttft_p99_ms);
            assert!(r.report.tpot_p50_ms <= r.report.tpot_p95_ms);
            assert!(r.report.tpot_p95_ms <= r.report.tpot_p99_ms);
            assert!(r.report.goodput_tokens_per_s <= r.report.tokens_per_s + 1e-9);
        }
        // Placement changes timing, never token accounting.
        let opts = ReportOpts { quick: true, ..Default::default() };
        let text = render_on(&arch, &trace, &cfg, "smoke", &opts, None);
        for df in ALL_DATAFLOWS {
            assert!(text.contains(df.label()), "missing {}", df.label());
        }
        assert!(text.contains("continuous batching"));
    }
}
