//! Fig. 5c: GEMM comparison — SUMMA with fabric collectives on BestArch
//! vs H100 GEMM utilization on LLaMA-70B-style layer shapes.

use crate::analytics::h100::h100_gemm_utilization;
use crate::arch::presets;
use crate::coordinator::ResultStore;
use crate::dataflow::summa::{summa_program, GemmWorkload};
use crate::report::{pct, ratio, ReportOpts, Table};
use crate::sim::execute;
use crate::util::json::Json;
use crate::util::pool;

/// The Fig. 5c GEMM set: LLaMA-70B FFN + projection shapes [26].
pub fn gemms(quick: bool) -> Vec<GemmWorkload> {
    let mut v = vec![GemmWorkload::new(4096, 8192, 28672, "ffn-up/gate")];
    if !quick {
        v.push(GemmWorkload::new(4096, 28672, 8192, "ffn-down"));
        v.push(GemmWorkload::new(4096, 8192, 8192, "o-proj"));
        v.push(GemmWorkload::new(8192, 8192, 8192, "square-8k"));
    }
    v
}

/// One BestArch-vs-H100 GEMM comparison row.
pub struct GemmComparison {
    /// The compared GEMM shape.
    pub gemm: GemmWorkload,
    /// BestArch SUMMA utilization.
    pub ours_util: f64,
    /// H100 cuBLAS utilization against its peak.
    pub h100_util: f64,
    /// `ours_util / h100_util`.
    pub util_ratio: f64,
}

/// Build every GEMM comparison row.
pub fn run(opts: &ReportOpts) -> Vec<GemmComparison> {
    let arch = presets::best_arch();
    let list = gemms(opts.quick);
    pool::par_map(&list, opts.threads, |g| {
        let stats = execute(&summa_program(&arch, g), 0);
        let ours_util = stats.compute_utilization(arch.peak_flops_per_cycle());
        let h100_util = h100_gemm_utilization(g.m, g.k, g.n);
        GemmComparison {
            gemm: g.clone(),
            ours_util,
            h100_util,
            util_ratio: ours_util / h100_util,
        }
    })
}

/// Render the Fig. 5c table, optionally persisting rows.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let rows = run(opts);
    if let Some(store) = store {
        store.add_json(
            "fig5c",
            rows.iter()
                .map(|c| {
                    Json::obj([
                        ("gemm", Json::str(c.gemm.label.clone())),
                        ("m", Json::num(c.gemm.m as f64)),
                        ("k", Json::num(c.gemm.k as f64)),
                        ("n", Json::num(c.gemm.n as f64)),
                        ("ours_util", Json::num(c.ours_util)),
                        ("h100_util", Json::num(c.h100_util)),
                        ("util_ratio", Json::num(c.util_ratio)),
                    ])
                })
                .collect(),
        );
    }

    let mut out = String::new();
    out.push_str("Fig. 5c — SUMMA GEMM on BestArch vs H100 GEMM (LLaMA-70B layer shapes)\n\n");
    let mut t = Table::new(&["gemm", "M", "K", "N", "ours util", "H100 util", "ratio"]);
    for c in &rows {
        t.row(vec![
            c.gemm.label.clone(),
            c.gemm.m.to_string(),
            c.gemm.k.to_string(),
            c.gemm.n.to_string(),
            pct(c.ours_util),
            pct(c.h100_util),
            ratio(c.util_ratio),
        ]);
    }
    out.push_str(&t.render());
    let max_ratio = rows.iter().map(|c| c.util_ratio).fold(0.0, f64::max);
    out.push_str(&format!(
        "\nMax GEMM utilization ratio {max_ratio:.2}x (paper: up to 1.2x)\n"
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summa_beats_h100_on_ffn() {
        let opts = ReportOpts { quick: true, ..Default::default() };
        let rows = run(&opts);
        assert_eq!(rows.len(), 1);
        let c = &rows[0];
        assert!(
            c.util_ratio > 1.0 && c.util_ratio < 1.4,
            "ffn util ratio {:.2} (paper: up to 1.2)",
            c.util_ratio
        );
    }
}
