//! Fig. 5a: architecture co-exploration heatmap — utilization (with the
//! best dataflow/group per cell) across fabric granularity × HBM channel
//! connectivity, at iso-peak performance (Table II).

use crate::arch::{presets, ArchConfig};
use crate::coordinator::{best_group, run_one, ExperimentSpec, ResultStore};
use crate::dataflow::{Dataflow, Workload};
use crate::report::{pct, ReportOpts, Table};
use crate::util::json::Json;

/// Tile granularities (mesh edge) swept on one heatmap axis.
pub const GRANULARITIES: [usize; 3] = [32, 16, 8];
/// HBM channels per die edge swept on the other heatmap axis.
pub const CHANNELS_PER_EDGE: [usize; 3] = [4, 8, 16];

/// Evaluation workloads for the heatmap (paper: "multiple MHA layers").
pub fn workloads(quick: bool) -> Vec<Workload> {
    if quick {
        vec![Workload::new(4096, 128, 32, 2)]
    } else {
        vec![
            Workload::new(1024, 128, 32, 2),
            Workload::new(4096, 128, 32, 2),
            Workload::new(4096, 64, 32, 2),
        ]
    }
}

/// One heatmap cell: the best achievable utilization over dataflows
/// (FA-3 and FlatAsyn with group search), averaged over the workloads.
pub struct Cell {
    /// The cell's architecture instance.
    pub arch: ArchConfig,
    /// Best utilization achieved over dataflows and groups.
    pub utilization: f64,
    /// Label of the winning dataflow.
    pub best_dataflow: String,
    /// Winning FlatAttention group edge (1 for FlashAttention).
    pub best_group: usize,
}

/// Evaluate one heatmap cell over the workload set.
pub fn evaluate_cell(arch: &ArchConfig, wls: &[Workload], threads: usize) -> Cell {
    let mut util_sum = 0.0;
    let mut best_label = String::new();
    let mut best_grp = 0usize;
    for wl in wls {
        let flat = best_group(arch, wl, Dataflow::FlatAsyn, threads);
        let fa3 = run_one(&ExperimentSpec {
            arch: arch.clone(),
            workload: *wl,
            dataflow: Dataflow::Flash3,
            group: 1,
        });
        if flat.makespan <= fa3.makespan {
            util_sum += flat.utilization;
            best_label = "FlatAsyn".into();
            best_grp = flat.group;
        } else {
            util_sum += fa3.utilization;
            best_label = "FA-3".into();
        }
    }
    Cell {
        arch: arch.clone(),
        utilization: util_sum / wls.len() as f64,
        best_dataflow: best_label,
        best_group: best_grp,
    }
}

/// Run the full granularity × channels grid.
pub fn run(opts: &ReportOpts) -> Vec<Cell> {
    let wls = workloads(opts.quick);
    let cells: Vec<ArchConfig> = GRANULARITIES
        .iter()
        .flat_map(|&g| {
            CHANNELS_PER_EDGE
                .iter()
                .map(move |&c| presets::with_hbm_channels(presets::table2(g), c))
        })
        .collect();
    // Parallelism lives inside best_group; evaluate cells sequentially to
    // bound peak memory (each cell runs up to ~10 simulations).
    cells
        .iter()
        .map(|a| evaluate_cell(a, &wls, opts.threads))
        .collect()
}

/// Render the Fig. 5a heatmap, optionally persisting rows.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let cells = run(opts);
    if let Some(store) = store {
        let rows = cells
            .iter()
            .map(|c| {
                Json::obj([
                    ("arch", Json::str(c.arch.name.clone())),
                    ("mesh", Json::num(c.arch.mesh_x as f64)),
                    ("hbm_channels", Json::num(c.arch.hbm.total_channels() as f64)),
                    ("utilization", Json::num(c.utilization)),
                    ("best_dataflow", Json::str(c.best_dataflow.clone())),
                    ("best_group", Json::num(c.best_group as f64)),
                ])
            })
            .collect();
        store.add_json("fig5a", rows);
    }

    let mut out = String::new();
    out.push_str("Fig. 5a — Co-exploration heatmap: avg utilization with best dataflow/group\n");
    out.push_str("(iso 1024-TFLOPS Table II tiles; HBM channels per edge x2 edges)\n\n");
    let mut t = Table::new(&["fabric \\ HBM", "4x2 ch", "8x2 ch", "16x2 ch"]);
    for &g in &GRANULARITIES {
        let mut row = vec![format!("{g}x{g}")];
        for &c in &CHANNELS_PER_EDGE {
            let cell = cells
                .iter()
                .find(|cell| cell.arch.mesh_x == g && cell.arch.hbm.channels_west == c.min(g))
                .unwrap();
            row.push(format!(
                "{} ({} g{})",
                pct(cell.utilization),
                cell.best_dataflow,
                cell.best_group
            ));
        }
        t.row(row);
    }
    out.push_str(&t.render());

    if let Some(best) = cells.iter().max_by(|a, b| {
        a.utilization
            .partial_cmp(&b.utilization)
            .unwrap()
    }) {
        out.push_str(&format!(
            "\nBestArch: {} — avg utilization {}, peak {} TFLOPS, HBM {} GB/s\n",
            best.arch.name,
            pct(best.utilization),
            best.arch.peak_tflops().round(),
            best.arch.hbm.peak_gbps(best.arch.freq_ghz).round(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool;

    #[test]
    fn heatmap_has_nine_cells() {
        let opts = ReportOpts { quick: true, threads: pool::default_threads() };
        let cells = run(&opts);
        assert_eq!(cells.len(), 9);
        for c in &cells {
            assert!(c.utilization > 0.0 && c.utilization <= 1.0);
        }
    }

    #[test]
    fn more_channels_never_hurt_utilization_much() {
        // Adding HBM channels at fixed granularity should not reduce
        // performance (FIFO channels only get less contended).
        let opts = ReportOpts { quick: true, threads: pool::default_threads() };
        let cells = run(&opts);
        for &g in &GRANULARITIES {
            let u: Vec<f64> = CHANNELS_PER_EDGE
                .iter()
                .map(|&c| {
                    cells
                        .iter()
                        .find(|cell| cell.arch.mesh_x == g && cell.arch.hbm.channels_west == c.min(g))
                        .unwrap()
                        .utilization
                })
                .collect();
            assert!(u[2] + 0.02 >= u[0], "granularity {g}: {u:?}");
        }
    }
}
