//! Fig. 5b: BestArch + FlatAttention vs FlashAttention-3 on the H100,
//! accounting for the K pre-transposition time (§III footnote 2).
//!
//! The H100 side uses the published FA-3 numbers (`analytics::h100`); the
//! BestArch side runs the simulator with the best group per layer and adds
//! the pre-transposition traffic (read + write K once) at HBM bandwidth.

use crate::analytics::h100::{h100_fa3_tflops, H100_HBM_GBPS, H100_PEAK_TFLOPS};
use crate::arch::presets;
use crate::coordinator::{best_group, ResultStore};
use crate::dataflow::{Dataflow, Workload};
use crate::report::{pct, ratio, ReportOpts, Table};
use crate::util::json::Json;

/// Fig. 5b MHA shape set (`quick` = CI-sized).
pub fn workloads(quick: bool) -> Vec<Workload> {
    let mut v = vec![Workload::new(4096, 128, 32, 2)];
    if !quick {
        v = vec![
            Workload::new(1024, 64, 32, 2),
            Workload::new(2048, 64, 32, 2),
            Workload::new(4096, 64, 32, 2),
            Workload::new(1024, 128, 32, 2),
            Workload::new(2048, 128, 32, 2),
            Workload::new(4096, 128, 32, 2),
        ];
    }
    v
}

/// One BestArch-vs-H100 MHA comparison row.
pub struct Comparison {
    /// The compared workload.
    pub workload: Workload,
    /// Winning FlatAttention group edge.
    pub best_group: usize,
    /// BestArch TFLOPS including the K pre-transposition time.
    pub ours_tflops: f64,
    /// BestArch utilization (including pre-transposition time).
    pub ours_util: f64,
    /// Published H100 FlashAttention-3 TFLOPS.
    pub h100_tflops: f64,
    /// H100 utilization against its peak.
    pub h100_util: f64,
    /// `ours_util / h100_util`.
    pub util_ratio: f64,
}

/// Extra cycles to pre-transpose K in HBM: read + write K once at peak
/// aggregate bandwidth.
fn pretranspose_cycles(wl: &Workload, hbm_bytes_per_cycle: u64) -> u64 {
    let k_bytes = wl.batch * wl.heads * wl.seq * wl.head_dim * Workload::BYTES_PER_ELEM;
    (2 * k_bytes).div_ceil(hbm_bytes_per_cycle)
}

/// Build every comparison row.
pub fn run(opts: &ReportOpts) -> Vec<Comparison> {
    let arch = presets::best_arch();
    workloads(opts.quick)
        .into_iter()
        .filter_map(|wl| {
            let h100_tflops = h100_fa3_tflops(wl.head_dim, wl.seq)?;
            let r = best_group(&arch, &wl, Dataflow::FlatAsyn, opts.threads);
            let pre = pretranspose_cycles(&wl, arch.hbm.peak_bytes_per_cycle());
            let cycles = r.makespan + pre;
            let ours_tflops =
                wl.matmul_flops() as f64 / (cycles as f64 / (arch.freq_ghz * 1e9)) / 1e12;
            let ours_util = ours_tflops / arch.peak_tflops();
            let h100_util = h100_tflops / H100_PEAK_TFLOPS;
            Some(Comparison {
                workload: wl,
                best_group: r.group,
                ours_tflops,
                ours_util,
                h100_tflops,
                h100_util,
                util_ratio: ours_util / h100_util,
            })
        })
        .collect()
}

/// Render the Fig. 5b table, optionally persisting rows.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let arch = presets::best_arch();
    let rows = run(opts);
    if let Some(store) = store {
        store.add_json(
            "fig5b",
            rows.iter()
                .map(|c| {
                    Json::obj([
                        ("layer", Json::str(c.workload.label())),
                        ("best_group", Json::num(c.best_group as f64)),
                        ("ours_tflops", Json::num(c.ours_tflops)),
                        ("ours_util", Json::num(c.ours_util)),
                        ("h100_tflops", Json::num(c.h100_tflops)),
                        ("h100_util", Json::num(c.h100_util)),
                        ("util_ratio", Json::num(c.util_ratio)),
                    ])
                })
                .collect(),
        );
    }

    let mut out = String::new();
    out.push_str(&format!(
        "Fig. 5b — BestArch ({:.0} TFLOPS, {:.0} GB/s HBM) + FlatAttention vs FA-3 on H100 ({:.0} TFLOPS, {:.0} GB/s HBM)\n",
        arch.peak_tflops(),
        arch.hbm.peak_gbps(arch.freq_ghz),
        H100_PEAK_TFLOPS,
        H100_HBM_GBPS,
    ));
    out.push_str("(BestArch runtime includes K pre-transposition; H100 numbers from Shah et al. [6], arXiv v1)\n\n");

    let mut t = Table::new(&[
        "layer", "group", "ours TFLOPS", "ours util", "H100 TFLOPS", "H100 util", "util ratio",
    ]);
    for c in &rows {
        t.row(vec![
            c.workload.label(),
            format!("{0}x{0}", c.best_group),
            format!("{:.0}", c.ours_tflops),
            pct(c.ours_util),
            format!("{:.0}", c.h100_tflops),
            pct(c.h100_util),
            ratio(c.util_ratio),
        ]);
    }
    out.push_str(&t.render());

    let max_ratio = rows.iter().map(|c| c.util_ratio).fold(0.0, f64::max);
    let bw_reduction = 1.0 - arch.hbm.peak_gbps(arch.freq_ghz) / H100_HBM_GBPS;
    out.push_str(&format!(
        "\nMax utilization ratio {:.2}x (paper: up to 1.3x); HBM bandwidth requirement {:.0}% lower than H100 (paper: 40%)\n",
        max_ratio,
        bw_reduction * 100.0,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pretranspose_charged() {
        let wl = Workload::new(4096, 128, 32, 2);
        let cycles = pretranspose_cycles(&wl, 2048);
        // 2 × (2·32·4096·128·2 B) / 2048 B/cyc.
        assert_eq!(cycles, (2 * 2 * 32 * 4096 * 128 * 2u64).div_ceil(2048));
    }

    #[test]
    fn quick_comparison_beats_h100_utilization() {
        let opts = ReportOpts { quick: true, ..Default::default() };
        let rows = run(&opts);
        assert_eq!(rows.len(), 1);
        let c = &rows[0];
        assert!(
            c.util_ratio > 1.0 && c.util_ratio < 1.6,
            "D128-S4096 util ratio {:.2} (paper: ~1.3)",
            c.util_ratio
        );
    }

    #[test]
    fn bandwidth_claim_40pct() {
        let arch = presets::best_arch();
        let red = 1.0 - arch.hbm.peak_gbps(arch.freq_ghz) / H100_HBM_GBPS;
        assert!((red - 0.40).abs() < 0.03, "bandwidth reduction {red:.2}");
    }
}
