//! Telemetry figure (our extension): utilization-over-time and request
//! lifecycle waterfall for one serving run.
//!
//! Replays the built-in mixed trace through the graceful-degradation
//! router with a telemetry sink attached ([`crate::telemetry`]) and a
//! mid-run tile death (so the lifecycle stream shows a band death and the
//! resulting requeue), then renders what the raw `ServingReport` cannot
//! show: how occupancy, HBM traffic and NoC-collective traffic evolve
//! over virtual time, and where each request spent its life
//! (queued → admitted → first token → completed, with requeue counts).

use crate::arch::{presets, ArchConfig};
use crate::coordinator::ResultStore;
use crate::dataflow::Dataflow;
use crate::report::{pct, ReportOpts, Table};
use crate::scheduler::{simulate, try_route_with, RequestTrace, RouterConfig, SchedulerConfig};
use crate::sim::{Cycle, FaultPlan};
use crate::telemetry::{LifeEvent, RunTelemetry};
use crate::util::json::Json;

/// Display cap on utilization rows: windows are grouped so the table never
/// exceeds this many rows regardless of run length.
const MAX_UTIL_ROWS: usize = 12;

/// Per-request waterfall record assembled from the lifecycle stream.
#[derive(Default, Clone)]
struct Waterfall {
    arrival: Option<Cycle>,
    admitted: Option<Cycle>,
    first_token: Option<Cycle>,
    end: Option<Cycle>,
    outcome: &'static str,
    requeues: u32,
}

fn waterfalls(events: &[LifeEvent]) -> Vec<(u32, Waterfall)> {
    let mut map: std::collections::BTreeMap<u32, Waterfall> = Default::default();
    for ev in events {
        match *ev {
            LifeEvent::Queued { req, t } => {
                let w = map.entry(req).or_default();
                if w.arrival.is_none() {
                    w.arrival = Some(t);
                }
            }
            LifeEvent::Admitted { req, t, .. } => {
                let w = map.entry(req).or_default();
                if w.admitted.is_none() {
                    w.admitted = Some(t);
                }
            }
            LifeEvent::FirstToken { req, t } => {
                map.entry(req).or_default().first_token = Some(t);
            }
            LifeEvent::Completed { req, t } => {
                let w = map.entry(req).or_default();
                w.end = Some(t);
                w.outcome = "completed";
            }
            LifeEvent::Dropped { req, t, cause } => {
                let w = map.entry(req).or_default();
                w.end = Some(t);
                w.outcome = cause.label();
            }
            LifeEvent::Requeued { req, .. } => {
                map.entry(req).or_default().requeues += 1;
            }
            _ => {}
        }
    }
    map.into_iter().collect()
}

fn fmt_opt(c: Option<Cycle>) -> String {
    c.map(|v| v.to_string()).unwrap_or_else(|| "-".to_string())
}

/// Render the telemetry figure; optionally record rows in `store`.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let (arch, mut cfg, setup) = if opts.quick {
        let mut c = SchedulerConfig::new(Dataflow::Flash2);
        c.group = 2;
        c.chunk = 128;
        c.page_tokens = 32;
        (presets::table2(8), c, "table2-8x8, slots=4, chunk=128")
    } else {
        (presets::table1(), SchedulerConfig::new(Dataflow::Flash2), "Table I arch, slots=4")
    };
    cfg.threads = opts.threads;
    let mut trace =
        RequestTrace::builtin("mixed", super::schedule::KV_HEADS).expect("builtin trace");
    if opts.quick {
        trace.requests.truncate(6);
        for r in &mut trace.requests {
            r.prompt = r.prompt.min(256);
            r.output = r.output.min(12);
        }
    }
    render_on(&arch, &trace, &cfg, setup, store)
}

/// Render the telemetry figure for one `(arch, trace, cfg)` (shared by the
/// CLI figure and the tiny-mesh smoke test).
pub fn render_on(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
    setup: &str,
    store: Option<&mut ResultStore>,
) -> String {
    // Place a single tile death at a third of the fault-free makespan so
    // the lifecycle stream exercises the degradation events.
    let free = simulate(arch, trace, cfg);
    let death_at = (free.total_cycles / 3).max(1);
    let rows_per = arch.mesh_y / cfg.slots;
    let dying_tile = ((cfg.slots - 1) * rows_per * arch.mesh_x) as u32;
    let rc = RouterConfig {
        faults: FaultPlan::none().with_tile_death(dying_tile, death_at),
        ..RouterConfig::default()
    };
    let mut tel = RunTelemetry::new().with_trace();
    let rep = try_route_with(arch, trace, cfg, &rc, Some(&mut tel)).expect("validated config");
    let m = &tel.metrics;

    let mut out = String::new();
    out.push_str(&format!(
        "Telemetry — router run, mixed trace ({} requests, {setup}), tile {dying_tile} dies at \
         cycle {death_at}\n\n",
        trace.requests.len()
    ));

    // Lifecycle counters.
    let mut t = Table::new(&["metric", "value"]);
    for name in [
        "requests_queued",
        "requests_admitted",
        "requests_completed",
        "requests_expired",
        "requeue_band_death",
        "requeue_deadline_retry",
        "requeue_preemption",
        "bands_died",
        "steps_total",
        "tokens_generated",
    ] {
        t.row(vec![name.to_string(), m.counter(name).to_string()]);
    }
    t.row(vec!["peak_queue_depth".to_string(), m.gauge("peak_queue_depth").to_string()]);
    t.row(vec!["peak_pages_in_use".to_string(), m.gauge("peak_pages_in_use").to_string()]);
    if let Some(h) = m.hist("ttft_cycles") {
        t.row(vec!["ttft_p50_cycles<=".to_string(), h.quantile_upper(500).to_string()]);
    }
    if let Some(h) = m.hist("tpot_cycles") {
        t.row(vec!["tpot_p50_cycles<=".to_string(), h.quantile_upper(500).to_string()]);
    }
    out.push_str(&t.render());
    out.push('\n');

    // Utilization over virtual time: slot occupancy plus HBM / NoC busy
    // cycles (scheduled demand), grouped so the table stays bounded.
    let busy = m.series("busy_slot_cycles");
    let cap = m.series("slot_cycles");
    if let (Some(busy), Some(cap)) = (busy, cap) {
        let window = cap.window();
        let n = cap.values().len();
        let group = n.div_ceil(MAX_UTIL_ROWS).max(1);
        let sum_lanes = |lanes: &[crate::telemetry::WindowSeries], lo: usize, hi: usize| -> u64 {
            let mut acc = 0u64;
            for w in lanes {
                let v = w.values();
                acc += v[lo.min(v.len())..hi.min(v.len())].iter().sum::<u64>();
            }
            acc
        };
        let mut t = Table::new(&["cycles", "occupancy", "hbm_busy_cyc", "noc_busy_cyc"]);
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + group).min(n);
            let bv = busy.values();
            let b: u64 = bv[lo.min(bv.len())..hi.min(bv.len())].iter().sum();
            let c: u64 = cap.values()[lo..hi].iter().sum();
            let occ = if c > 0 { b as f64 / c as f64 } else { 0.0 };
            let hbm = sum_lanes(m.hbm_chan_busy.windows(), lo, hi);
            let noc = sum_lanes(m.noc_slot_busy.windows(), lo, hi);
            t.row(vec![
                format!("{}..{}", lo as u64 * window, hi as u64 * window),
                pct(occ),
                hbm.to_string(),
                noc.to_string(),
            ]);
            lo = hi;
        }
        out.push_str(&t.render());
        out.push('\n');
    }

    // Lifecycle waterfall.
    let wf = tel.trace.as_ref().map(|tc| waterfalls(tc.events())).unwrap_or_default();
    let mut t = Table::new(&[
        "req",
        "arrival",
        "admitted",
        "queue_wait",
        "first_token",
        "end",
        "outcome",
        "requeues",
    ]);
    for (req, w) in &wf {
        let wait = match (w.arrival, w.admitted) {
            (Some(a), Some(b)) => (b.saturating_sub(a)).to_string(),
            _ => "-".to_string(),
        };
        t.row(vec![
            req.to_string(),
            fmt_opt(w.arrival),
            fmt_opt(w.admitted),
            wait,
            fmt_opt(w.first_token),
            fmt_opt(w.end),
            w.outcome.to_string(),
            w.requeues.to_string(),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "\nrouter: {} completed, {} expired, {} band evictions, {} dead bands at end\n",
        rep.completed, rep.expired, rep.band_evictions, rep.dead_bands
    ));

    if let Some(store) = store {
        let mut json: Vec<Json> = Vec::new();
        for (req, w) in &wf {
            json.push(Json::obj([
                ("request", Json::num(*req as f64)),
                ("arrival", Json::num(w.arrival.unwrap_or(0) as f64)),
                ("admitted", Json::num(w.admitted.map(|v| v as f64).unwrap_or(-1.0))),
                ("first_token", Json::num(w.first_token.map(|v| v as f64).unwrap_or(-1.0))),
                ("end", Json::num(w.end.map(|v| v as f64).unwrap_or(-1.0))),
                ("outcome", Json::str(w.outcome.to_string())),
                ("requeues", Json::num(w.requeues as f64)),
            ]));
        }
        json.push(Json::obj([
            ("mode", Json::str("counters")),
            ("requests_completed", Json::num(m.counter("requests_completed") as f64)),
            ("requeue_band_death", Json::num(m.counter("requeue_band_death") as f64)),
            ("bands_died", Json::num(m.counter("bands_died") as f64)),
            ("steps_total", Json::num(m.counter("steps_total") as f64)),
        ]));
        store.add_json("telemetry", json);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// CI smoke: the full telemetry figure path on a tiny mesh — counters,
    /// utilization windows and a waterfall row per request.
    #[test]
    fn telemetry_figure_smoke_tiny_mesh() {
        let arch = presets::table2(8);
        let trace = RequestTrace::from_rows(
            &[(0, 160, 4), (0, 96, 8), (5_000, 200, 3), (20_000, 64, 6)],
            2,
        );
        let mut cfg = SchedulerConfig::new(Dataflow::Flash2);
        cfg.slots = 4;
        cfg.group = 2;
        cfg.chunk = 96;
        cfg.page_tokens = 32;
        cfg.heads = 4;
        cfg.head_dim = 64;
        let text = render_on(&arch, &trace, &cfg, "smoke", None);
        assert!(text.contains("requests_completed"));
        assert!(text.contains("occupancy"));
        assert!(text.contains("first_token"));
        // Every request appears in the waterfall (first column is the
        // request id, left-aligned).
        for req in 0..trace.requests.len() {
            let marker = format!("{req} ");
            assert!(
                text.lines().any(|l| l.starts_with(&marker)),
                "request {req} missing from waterfall:\n{text}"
            );
        }
    }
}
