//! Headline-claims summary: every number from the abstract, measured.

use crate::arch::area::{AreaModel, H100_DIE_MM2};
use crate::arch::presets;
use crate::analytics::h100::H100_HBM_GBPS;
use crate::coordinator::{run_all, ExperimentSpec, ResultStore};
use crate::dataflow::{Dataflow, Workload};
use crate::report::{pct, ReportOpts, Table};
use crate::util::json::Json;

/// Render the headline utilization/runtime table, optionally persisting rows.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let arch = presets::table1();
    // The abstract's strongest point: D=128, S=4096.
    let wl = Workload::new(4096, 128, 32, 2);
    let specs: Vec<ExperimentSpec> = [Dataflow::Flash3, Dataflow::FlatAsyn]
        .into_iter()
        .map(|df| ExperimentSpec { arch: arch.clone(), workload: wl, dataflow: df, group: 32 })
        .collect();
    let results = run_all(&specs, opts.threads);
    let (fa3, flat) = (&results[0], &results[1]);

    let speedup = fa3.makespan as f64 / flat.makespan as f64;
    let traffic = fa3.hbm_bytes as f64 / flat.hbm_bytes as f64;
    let area = AreaModel::default().estimate(&arch);
    let bw_red = 1.0 - arch.hbm.peak_gbps(arch.freq_ghz) / H100_HBM_GBPS;

    if let Some(store) = store {
        store.add_json(
            "headline",
            vec![Json::obj([
                ("utilization", Json::num(flat.utilization)),
                ("speedup_vs_fa3", Json::num(speedup)),
                ("hbm_traffic_reduction", Json::num(traffic)),
                ("die_mm2", Json::num(area.total_mm2)),
                ("die_reduction_vs_h100", Json::num(H100_DIE_MM2 / area.total_mm2)),
                ("hbm_bw_reduction_vs_h100", Json::num(bw_red)),
            ])],
        );
    }

    let mut out = String::new();
    out.push_str("Headline claims (abstract) vs measured — D=128, S=4096, H=32, B=2, Table I arch\n\n");
    let mut t = Table::new(&["claim", "paper", "measured"]);
    t.row(vec![
        "FlatAttention utilization (up to)".into(),
        "89.3%".into(),
        pct(flat.utilization),
    ]);
    t.row(vec![
        "Speedup over FA-3 dataflow (up to)".into(),
        "4.1x".into(),
        format!("{speedup:.1}x"),
    ]);
    t.row(vec![
        "HBM traffic reduction (up to)".into(),
        "16x".into(),
        format!("{traffic:.1}x"),
    ]);
    t.row(vec![
        "HBM BW requirement vs H100".into(),
        "-40%".into(),
        format!("{:.0}%", -bw_red * 100.0),
    ]);
    t.row(vec![
        "Die size (TSMC 5nm)".into(),
        "457 mm2 (1.8x < H100)".into(),
        format!("{:.0} mm2 ({:.1}x)", area.total_mm2, H100_DIE_MM2 / area.total_mm2),
    ]);
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_report_renders() {
        let opts = ReportOpts::default();
        let s = render(&opts, None);
        assert!(s.contains("89.3%"));
        assert!(s.contains("16x"));
    }
}
