//! Fig. 4: runtime breakdown vs (square) group scale, with per-tile slice
//! sizes and RedMulE active-utilization labels.
//!
//! Paper setup: Table I architecture, FlatAsyn dataflow,
//! G ∈ {4, 8, 16, 32}², S ∈ {512, 1024, 2048, 4096}, D = 128, H = 32, B = 4.

use crate::arch::presets;
use crate::coordinator::{run_all, ExperimentResult, ExperimentSpec, ResultStore};
use crate::dataflow::{Dataflow, FlatTiling, Workload};
use crate::report::{pct, ReportOpts, Table};
use crate::util::json::Json;

/// Group edges swept in Fig. 4.
pub const GROUPS: [usize; 4] = [4, 8, 16, 32];

/// Fig. 4 workload grid (sequence-length sweep; `quick` = CI-sized).
pub fn workloads(quick: bool) -> Vec<Workload> {
    let seqs: &[u64] = if quick { &[512, 4096] } else { &[512, 1024, 2048, 4096] };
    seqs.iter().map(|&s| Workload::new(s, 128, 32, 4)).collect()
}

/// Run the Fig. 4 grid.
pub fn run(opts: &ReportOpts) -> Vec<(usize, ExperimentResult)> {
    let arch = presets::table1();
    let specs: Vec<ExperimentSpec> = workloads(opts.quick)
        .into_iter()
        .flat_map(|wl| GROUPS.into_iter().map(move |g| (wl, g)))
        .map(|(workload, group)| ExperimentSpec {
            arch: arch.clone(),
            workload,
            dataflow: Dataflow::FlatAsyn,
            group,
        })
        .collect();
    specs
        .iter()
        .map(|s| s.group)
        .zip(run_all(&specs, opts.threads))
        .collect()
}

/// Render the Fig. 4 table, optionally persisting rows.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let arch = presets::table1();
    let results = run(opts);
    if let Some(store) = store {
        let rows = results
            .iter()
            .map(|(g, r)| {
                let mut j = r.to_json();
                if let Json::Obj(map) = &mut j {
                    map.insert("group".into(), Json::num(*g as f64));
                }
                j
            })
            .collect();
        store.add_json("fig4", rows);
    }

    let mut out = String::new();
    out.push_str(
        "Fig. 4 — FlatAsyn runtime breakdown vs group scale (Table I arch, D=128, H=32, B=4)\n\n",
    );
    let mut t = Table::new(&[
        "S", "group", "slice/tile", "runtime_ms", "RedMulE%", "Spatz%", "Coll%", "HBM%", "Other%",
        "util", "RedMulE_active",
    ]);
    for (g, r) in &results {
        let tiling = FlatTiling::resolve(&arch, &r.workload, *g, true);
        let total = r.makespan.max(1) as f64;
        let coll = (r.breakdown.multicast + r.breakdown.max_reduce + r.breakdown.sum_reduce) as f64;
        t.row(vec![
            r.workload.seq.to_string(),
            format!("{g}x{g}"),
            tiling.slice.to_string(),
            format!("{:.3}", r.runtime_ms),
            format!("{:.1}", r.breakdown.redmule as f64 / total * 100.0),
            format!("{:.1}", r.breakdown.spatz as f64 / total * 100.0),
            format!("{:.1}", coll / total * 100.0),
            format!("{:.1}", r.breakdown.hbm as f64 / total * 100.0),
            format!("{:.1}", r.breakdown.other as f64 / total * 100.0),
            pct(r.utilization),
            pct(r.redmule_active_util),
        ]);
    }
    out.push_str(&t.render());

    // Per-S optimum (the §V-B trade-off).
    out.push('\n');
    for wl in workloads(opts.quick) {
        if let Some((g, r)) = results
            .iter()
            .filter(|(_, r)| r.workload.seq == wl.seq)
            .min_by_key(|(_, r)| r.makespan)
        {
            out.push_str(&format!(
                "S={}: optimal group {g}x{g} (util {}, runtime {:.3} ms)\n",
                wl.seq,
                pct(r.utilization),
                r.runtime_ms
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn over_flattening_trend() {
        // At S=512 the optimum group is small; at S=4096 it is large.
        let opts = ReportOpts { quick: true, ..Default::default() };
        let results = run(&opts);
        let best = |seq: u64| {
            results
                .iter()
                .filter(|(_, r)| r.workload.seq == seq)
                .min_by_key(|(_, r)| r.makespan)
                .map(|(g, _)| *g)
                .unwrap()
        };
        assert!(best(512) <= 8, "S=512 best group {}", best(512));
        assert!(best(4096) >= 16, "S=4096 best group {}", best(4096));
    }

    #[test]
    fn active_util_drops_with_over_flattening() {
        // Paper: 32×32 at S=512 → ~23% active RedMulE utilization.
        let opts = ReportOpts { quick: true, ..Default::default() };
        let results = run(&opts);
        let r512_g32 = results
            .iter()
            .find(|(g, r)| *g == 32 && r.workload.seq == 512)
            .map(|(_, r)| r)
            .unwrap();
        assert!(
            (0.15..0.35).contains(&r512_g32.redmule_active_util),
            "active util {} (paper ~0.23)",
            r512_g32.redmule_active_util
        );
        let r4096_g32 = results
            .iter()
            .find(|(g, r)| *g == 32 && r.workload.seq == 4096)
            .map(|(_, r)| r)
            .unwrap();
        assert!(r4096_g32.redmule_active_util > 0.8);
    }
}
