//! Serving sweep (our extension, beyond the paper's prefill-MHA grid):
//! utilization and HBM traffic across batch × sequence × kv_heads for
//! every dataflow, in both phases.
//!
//! * **Prefill rows** are serving-chunk prefills (small-to-long S).
//! * **Decode rows** are single-token generation against an S-long cache.
//! * `kv_heads` sweeps MHA (32) → GQA (8) → MQA (1) at 32 query heads;
//!   the `HBMvsMHA` column shows each point's traffic relative to the
//!   dense-MHA point of the same (dataflow, phase, B, S) — the K/V share
//!   scales by `kv_heads/heads` (exactly, in the decode rows).
//!
//! The FlatAttention variants run at a fixed 8×8 group: serving traffic
//! is dominated by small effective row counts, where the full-mesh group
//! of the prefill headline over-flattens (§V-B applied to decode).

use crate::arch::presets;
use crate::arch::ArchConfig;
use crate::coordinator::{run_all, ExperimentResult, ExperimentSpec, ResultStore};
use crate::dataflow::{Dataflow, Phase, Workload, ALL_DATAFLOWS};
use crate::report::{pct, ReportOpts, Table};

/// FlatAttention group edge used by the serving sweep.
pub const GROUP: usize = 8;

/// The serving workload grid at `heads` query heads. The kv_heads axis is
/// MHA → GQA (heads/4) → MQA, keeping only values that divide `heads`
/// (GQA groups must be uniform) and dropping duplicates, so any head
/// count yields a valid, duplicate-free grid.
pub fn workloads_for(heads: u64, seqs: &[u64], batches: &[u64], quick: bool) -> Vec<Workload> {
    let mut kv_grid: Vec<u64> = if quick { vec![heads, 1] } else { vec![heads, heads / 4, 1] };
    kv_grid.retain(|&kv| kv >= 1 && heads % kv == 0);
    kv_grid.dedup();
    let mut out = Vec::new();
    for &phase in &[Phase::Prefill, Phase::Decode] {
        for &b in batches {
            for &s in seqs {
                for &kv in &kv_grid {
                    out.push(Workload::new(s, 128, heads, b).with_kv_heads(kv).with_phase(phase));
                }
            }
        }
    }
    out
}

/// The Table-I serving grid.
pub fn workloads(quick: bool) -> Vec<Workload> {
    if quick {
        workloads_for(32, &[512, 4096], &[4], true)
    } else {
        workloads_for(32, &[512, 2048, 4096], &[1, 8], false)
    }
}

/// Run a serving grid on an architecture (every dataflow per workload;
/// `group` applies to the FlatAttention variants).
pub fn run_on(
    arch: &ArchConfig,
    group: usize,
    wls: &[Workload],
    opts: &ReportOpts,
) -> Vec<ExperimentResult> {
    let specs: Vec<ExperimentSpec> = wls
        .iter()
        .flat_map(|wl| ALL_DATAFLOWS.into_iter().map(move |df| (*wl, df)))
        .map(|(workload, dataflow)| ExperimentSpec {
            arch: arch.clone(),
            workload,
            dataflow,
            group,
        })
        .collect();
    run_all(&specs, opts.threads)
}

/// Run the Table-I serving sweep.
pub fn run(opts: &ReportOpts) -> Vec<ExperimentResult> {
    run_on(&presets::table1(), GROUP, &workloads(opts.quick), opts)
}

/// Traffic of each point relative to the dense-MHA point with the same
/// (dataflow, phase, batch, seq); 1.0 where no MHA partner exists.
fn mha_relative_traffic(results: &[ExperimentResult]) -> Vec<f64> {
    results
        .iter()
        .map(|r| {
            let mha = results.iter().find(|m| {
                m.dataflow == r.dataflow
                    && m.workload.phase == r.workload.phase
                    && m.workload.batch == r.workload.batch
                    && m.workload.seq == r.workload.seq
                    && m.workload.head_dim == r.workload.head_dim
                    && m.workload.kv_heads == m.workload.heads
            });
            match mha {
                Some(m) if m.hbm_bytes > 0 => r.hbm_bytes as f64 / m.hbm_bytes as f64,
                _ => 1.0,
            }
        })
        .collect()
}

/// Render the serving sweep; optionally record rows in `store`.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let results = run(opts);
    render_results("Table I arch, G=8x8, H=32, D=128", &results, store)
}

/// Render a serving grid's results (shared by the CLI figure and the
/// tiny-mesh smoke path).
pub fn render_results(
    setup: &str,
    results: &[ExperimentResult],
    store: Option<&mut ResultStore>,
) -> String {
    if let Some(store) = store {
        store.add_results("serving", results);
    }
    if results.is_empty() {
        return String::from("Serving sweep — no results\n");
    }
    let rel = mha_relative_traffic(results);

    let mut out = String::new();
    out.push_str(&format!(
        "Serving sweep — GQA/MQA and decode across batch x S x kv_heads ({setup})\n\n"
    ));
    let mut t = Table::new(&[
        "phase", "B", "S", "kv", "dataflow", "runtime_ms", "util", "HBM_BW", "HBM_GB", "HBMvsMHA",
    ]);
    for (r, rel) in results.iter().zip(&rel) {
        t.row(vec![
            r.workload.phase.label().to_string(),
            r.workload.batch.to_string(),
            r.workload.seq.to_string(),
            r.workload.kv_heads.to_string(),
            r.dataflow.label().to_string(),
            format!("{:.4}", r.runtime_ms),
            pct(r.utilization),
            pct(r.hbm_bw_util),
            format!("{:.3}", r.hbm_bytes as f64 / 1e9),
            format!("{:.2}", rel),
        ]);
    }
    out.push_str(&t.render());

    // Headline derived from the sweep: decode MQA traffic saving.
    let decode_pair = |kv: u64| {
        results.iter().zip(&rel).find(|(r, _)| {
            r.workload.is_decode() && r.workload.kv_heads == kv && r.dataflow == Dataflow::Flash2
        })
    };
    if let (Some((mha, _)), Some((mqa, mqa_rel))) =
        (decode_pair(results[0].workload.heads), decode_pair(1))
    {
        out.push_str(&format!(
            "\nDecode S={} (FA-2): MQA moves {:.0}% of MHA traffic ({:.3} vs {:.3} GB)\n",
            mha.workload.seq,
            mqa_rel * 100.0,
            mqa.hbm_bytes as f64 / 1e9,
            mha.hbm_bytes as f64 / 1e9,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The CI smoke grid: tiny mesh, tiny shapes — exercises the full
    /// serving sweep path (all dataflows × phases × kv_heads through the
    /// coordinator and renderer) in well under a second.
    fn smoke_results() -> (Vec<ExperimentResult>, Vec<f64>) {
        let arch = presets::table2(8);
        let wls = workloads_for(4, &[128, 256], &[1], true);
        let opts = ReportOpts { quick: true, ..Default::default() };
        let results = run_on(&arch, 4, &wls, &opts);
        let rel = mha_relative_traffic(&results);
        (results, rel)
    }

    #[test]
    fn serving_sweep_smoke_tiny_mesh() {
        let (results, _) = smoke_results();
        // phases(2) × B(1) × S(2) × kv{4,1}(2) × dataflows(5)
        assert_eq!(results.len(), 40);
        assert!(results.iter().all(|r| r.makespan > 0));
        let text = render_results("smoke", &results, None);
        for df in ALL_DATAFLOWS {
            assert!(text.contains(df.label()), "missing {}", df.label());
        }
        assert!(text.contains("decode"));
        assert!(text.contains("prefill"));
    }

    #[test]
    fn decode_mqa_cuts_traffic_on_every_dataflow() {
        let (results, rel) = smoke_results();
        for df in ALL_DATAFLOWS {
            let (_, r) = results
                .iter()
                .zip(&rel)
                .find(|(r, _)| {
                    r.dataflow == df
                        && r.workload.is_decode()
                        && r.workload.kv_heads == 1
                        && r.workload.seq == 256
                })
                .expect("mqa decode point");
            // MQA shares one K/V head across 4 query heads: the K/V-
            // dominated decode traffic lands near 1/4 of MHA.
            assert!(
                (0.2..0.7).contains(r),
                "{df:?}: MQA/MHA decode traffic ratio {r:.3}"
            );
        }
    }
}
