//! §II worked example (hardware vs software multicast) and the §V-C die
//! area estimate.

use crate::arch::area::{AreaModel, H100_DIE_MM2};
use crate::arch::{presets, NocConfig};
use crate::noc::{collective_time, CollectiveKind};
use crate::report::Table;

/// The §II multicast example: α = 16 KB, β = 128 B/cycle, Ld = 10, Lr = 4,
/// N = 7 — hardware collectives reduce latency ~6×.
pub fn render_section2() -> String {
    let mk = |hw: bool| NocConfig {
        link_bytes_per_cycle: 128,
        router_latency: 4,
        inject_latency: 10,
        hw_collectives: hw,
    };
    let bytes = 16 * 1024;
    let mut out = String::new();
    out.push_str("§II — Multicast latency: software chain vs hardware path-based forwarding\n");
    out.push_str("(alpha=16 KB, beta=128 B/cycle, Ld=10, Lr=4)\n\n");
    let mut t = Table::new(&["N (destinations)", "software (cyc)", "hardware (cyc)", "reduction"]);
    for n in [1u64, 3, 7, 15, 31] {
        let sw = collective_time(&mk(false), bytes, n, CollectiveKind::Multicast).total();
        let hw = collective_time(&mk(true), bytes, n, CollectiveKind::Multicast).total();
        t.row(vec![
            n.to_string(),
            sw.to_string(),
            hw.to_string(),
            format!("{:.1}x", sw as f64 / hw as f64),
        ]);
    }
    out.push_str(&t.render());
    out.push_str("\nPaper reports 6.1x at N=7.\n");
    out
}

/// §V-C die-area estimate for BestArch vs the H100.
pub fn render_area() -> String {
    let model = AreaModel::default();
    let mut out = String::new();
    out.push_str("§V-C — Die area estimate (TSMC 5nm: 4 Tr/GE, 138.2 MTr/mm2, 0.021 um2/bit SRAM, 66% utilization)\n\n");
    let mut t = Table::new(&["arch", "logic mm2", "SRAM mm2", "total mm2", "vs H100 (814 mm2)"]);
    for g in [32usize, 16, 8] {
        let arch = presets::table2(g);
        let a = model.estimate(&arch);
        t.row(vec![
            arch.name.clone(),
            format!("{:.1}", a.logic_mm2),
            format!("{:.1}", a.sram_mm2),
            format!("{:.1}", a.total_mm2),
            format!("{:.2}x smaller", H100_DIE_MM2 / a.total_mm2),
        ]);
    }
    out.push_str(&t.render());
    let best = model.estimate(&presets::best_arch());
    out.push_str(&format!(
        "\nBestArch: {:.0} mm2 (paper: 457 mm2), {:.1}x reduction vs H100 (paper: 1.8x)\n",
        best.total_mm2,
        H100_DIE_MM2 / best.total_mm2
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn section2_contains_n7_row() {
        let s = render_section2();
        assert!(s.contains("6.") || s.contains("7."), "{s}");
        assert!(s.lines().count() > 8);
    }

    #[test]
    fn area_report_matches_paper() {
        let s = render_area();
        assert!(s.contains("1.8x") || s.contains("1.7x") || s.contains("1.9x"));
    }
}
