//! Layer composition sweep: full transformer layers (attention + the
//! four projection/FFN GEMMs, `dataflow::layer_program`) across
//! dataflows × weight residency, with the per-kernel share of the layer
//! critical path.
//!
//! Strict cross-kernel barriers make the shares exact: each kernel's
//! solo makespan is its contribution to the composed layer (additivity,
//! pinned by `tests/layer_differential.rs`), so the "share" columns are
//! a true breakdown, not an attribution heuristic.

use crate::arch::presets;
use crate::coordinator::{run_layer, ResultStore};
use crate::dataflow::{Dataflow, LayerWorkload, WeightResidency, Workload, ALL_RESIDENCIES};
use crate::report::{pct, ReportOpts, Table};
use crate::util::json::Json;
use crate::util::pool;

/// One swept layer point.
pub struct LayerRow {
    /// Attention dataflow of the composed layer.
    pub dataflow: Dataflow,
    /// Projection/FFN weight residency.
    pub weights: WeightResidency,
    /// Composed layer makespan (cycles).
    pub makespan: u64,
    /// Compute utilization of the whole layer (useful FLOPs over peak).
    pub utilization: f64,
    /// `(kernel label, share of the layer makespan)`.
    pub shares: Vec<(String, f64)>,
}

/// The swept attention shape: a GQA causal prefill layer with a 4×
/// FFN (quick mode shrinks the sequence).
pub fn layer_workload(quick: bool, weights: WeightResidency) -> LayerWorkload {
    let seq = if quick { 512 } else { 2048 };
    LayerWorkload::new(
        Workload::new(seq, 128, 16, 1).with_kv_heads(4).with_causal(true),
        4,
        weights,
    )
}

/// Sweep dataflows × weight residencies over the composed layer.
pub fn run(opts: &ReportOpts) -> Vec<LayerRow> {
    let arch = presets::table2(8);
    let dataflows = if opts.quick {
        vec![Dataflow::Flash2, Dataflow::FlatColl]
    } else {
        vec![
            Dataflow::Flash2,
            Dataflow::Flash3,
            Dataflow::Flat,
            Dataflow::FlatColl,
            Dataflow::FlatAsyn,
        ]
    };
    let points: Vec<(Dataflow, WeightResidency)> = dataflows
        .iter()
        .flat_map(|&df| ALL_RESIDENCIES.map(|r| (df, r)))
        .collect();
    pool::par_map(&points, opts.threads, |&(df, weights)| {
        let lw = layer_workload(opts.quick, weights);
        let r = run_layer(&arch, &lw, df, 2);
        let shares = r
            .kernels
            .iter()
            .map(|(label, ms)| (label.clone(), *ms as f64 / r.makespan as f64))
            .collect();
        LayerRow {
            dataflow: df,
            weights,
            makespan: r.makespan,
            utilization: r.flops as f64 / (r.makespan as f64 * arch.peak_flops_per_cycle()),
            shares,
        }
    })
}

/// Render the layer table, optionally persisting rows.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let rows = run(opts);
    if let Some(store) = store {
        store.add_json(
            "layers",
            rows.iter()
                .map(|r| {
                    Json::obj([
                        ("dataflow", Json::str(r.dataflow.label())),
                        ("weights", Json::str(r.weights.label())),
                        ("makespan", Json::num(r.makespan as f64)),
                        ("utilization", Json::num(r.utilization)),
                        (
                            "shares",
                            Json::Obj(
                                r.shares.iter().map(|(l, s)| (l.clone(), Json::num(*s))).collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
    }

    let lw = layer_workload(opts.quick, WeightResidency::HbmStream);
    let mut out = String::new();
    out.push_str(&format!(
        "Layer sweep — {} + 4 GEMMs (d_model {}, FFN x{}) on table2-8x8\n\n",
        lw.attn.label(),
        lw.d_model(),
        lw.ffn_mult
    ));
    let mut t = Table::new(&[
        "dataflow", "weights", "makespan", "util", "attn", "out-proj", "ffn-up", "ffn-down",
        "qkv-proj",
    ]);
    for r in &rows {
        let mut cells = vec![
            r.dataflow.label().to_string(),
            r.weights.label().to_string(),
            r.makespan.to_string(),
            pct(r.utilization),
        ];
        cells.extend(r.shares.iter().map(|(_, s)| pct(*s)));
        t.row(cells);
    }
    out.push_str(&t.render());
    out.push_str(
        "\nShares are exact: strict cross-kernel barriers make the composed layer\n\
         the sum of its solo kernels (tests/layer_differential.rs).\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_shares_sum_to_one() {
        let opts = ReportOpts { quick: true, ..Default::default() };
        let rows = run(&opts);
        assert_eq!(rows.len(), 4); // 2 dataflows × 2 residencies
        for r in &rows {
            assert!(r.makespan > 0);
            assert!(r.utilization > 0.0 && r.utilization <= 1.0, "{:?}", r.dataflow);
            assert_eq!(r.shares.len(), 5);
            assert_eq!(r.shares[0].0, "attention");
            let total: f64 = r.shares.iter().map(|(_, s)| s).sum();
            // Additivity: shares partition the makespan exactly (integer
            // division noise only).
            assert!((total - 1.0).abs() < 1e-9, "{:?} shares sum {total}", r.dataflow);
        }
    }

    #[test]
    fn resident_weights_never_slower() {
        let opts = ReportOpts { quick: true, ..Default::default() };
        let rows = run(&opts);
        for pair in rows.chunks(2) {
            let (hbm, res) = (&pair[0], &pair[1]);
            assert_eq!(hbm.dataflow, res.dataflow);
            assert!(res.makespan <= hbm.makespan, "{:?}", hbm.dataflow);
        }
    }
}
