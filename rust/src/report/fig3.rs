//! Fig. 3: runtime breakdown + average HBM BW utilization for the five
//! MHA dataflow implementations across layer sizes.
//!
//! Paper setup: Table I architecture, G = 32×32 for the Flat variants,
//! S ∈ {1024, 2048, 4096}, D ∈ {64, 128}, B = 2, H = 32.

use crate::arch::presets;
use crate::coordinator::{run_all, ExperimentResult, ExperimentSpec, ResultStore};
use crate::dataflow::{Dataflow, Workload, ALL_DATAFLOWS};
use crate::report::{pct, ReportOpts, Table};
use crate::sim::breakdown::ALL_COMPONENTS;

/// The paper's Fig. 3 workloads.
pub fn workloads(quick: bool) -> Vec<Workload> {
    let seqs: &[u64] = if quick { &[1024] } else { &[1024, 2048, 4096] };
    let dims: &[u64] = if quick { &[128] } else { &[64, 128] };
    let mut out = Vec::new();
    for &d in dims {
        for &s in seqs {
            out.push(Workload::new(s, d, 32, 2));
        }
    }
    out
}

/// Run the full Fig. 3 grid.
pub fn run(opts: &ReportOpts) -> Vec<ExperimentResult> {
    let arch = presets::table1();
    let group = arch.mesh_x; // G = 32×32: all tiles in one group
    let specs: Vec<ExperimentSpec> = workloads(opts.quick)
        .into_iter()
        .flat_map(|wl| {
            ALL_DATAFLOWS.into_iter().map(move |df| (wl, df))
        })
        .map(|(workload, dataflow)| ExperimentSpec {
            arch: arch.clone(),
            workload,
            dataflow,
            group,
        })
        .collect();
    run_all(&specs, opts.threads)
}

/// Render the figure as text; optionally record rows in `store`.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let results = run(opts);
    if let Some(store) = store {
        store.add_results("fig3", &results);
    }

    let mut out = String::new();
    out.push_str("Fig. 3 — Runtime breakdown and avg HBM BW utilization (Table I arch, G=32x32, B=2, H=32)\n\n");

    let mut t = Table::new(&[
        "layer", "dataflow", "runtime_ms", "RedMulE%", "Spatz%", "SumRed%", "MaxRed%", "Mcast%",
        "HBM%", "Other%", "util", "HBM_BW", "HBM_GB",
    ]);
    for r in &results {
        let total = r.makespan.max(1) as f64;
        let mut cells = vec![
            r.workload.label(),
            r.dataflow.label().to_string(),
            format!("{:.3}", r.runtime_ms),
        ];
        for c in ALL_COMPONENTS {
            cells.push(format!("{:.1}", r.breakdown.get(c) as f64 / total * 100.0));
        }
        cells.push(pct(r.utilization));
        cells.push(pct(r.hbm_bw_util));
        cells.push(format!("{:.2}", r.hbm_bytes as f64 / 1e9));
        t.row(cells);
    }
    out.push_str(&t.render());

    // The paper's headline derived from this figure.
    if let (Some(fa3), Some(flat)) = (
        results
            .iter()
            .filter(|r| r.dataflow == Dataflow::Flash3)
            .max_by(|a, b| a.workload.seq.cmp(&b.workload.seq).then(a.workload.head_dim.cmp(&b.workload.head_dim))),
        results
            .iter()
            .filter(|r| r.dataflow == Dataflow::FlatAsyn)
            .max_by(|a, b| a.workload.seq.cmp(&b.workload.seq).then(a.workload.head_dim.cmp(&b.workload.head_dim))),
    ) {
        out.push_str(&format!(
            "\nLargest layer ({}): FlatAsyn vs FA-3 speedup {:.1}x, HBM traffic reduction {:.1}x, FlatAsyn utilization {}\n",
            flat.workload.label(),
            fa3.makespan as f64 / flat.makespan as f64,
            fa3.hbm_bytes as f64 / flat.hbm_bytes as f64,
            pct(flat.utilization),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_grid_runs() {
        let opts = ReportOpts { quick: true, ..Default::default() };
        let results = run(&opts);
        assert_eq!(results.len(), 5); // 1 layer × 5 dataflows
        // FlashAttention variants are memory-bound; Flat* reduce traffic.
        let fa2 = results.iter().find(|r| r.dataflow == Dataflow::Flash2).unwrap();
        let coll = results.iter().find(|r| r.dataflow == Dataflow::FlatColl).unwrap();
        assert!(coll.hbm_bytes < fa2.hbm_bytes);
    }

    #[test]
    fn render_produces_all_rows() {
        let opts = ReportOpts { quick: true, ..Default::default() };
        let text = render(&opts, None);
        for df in ALL_DATAFLOWS {
            assert!(text.contains(df.label()), "missing {}", df.label());
        }
        assert!(text.contains("speedup"));
    }
}
