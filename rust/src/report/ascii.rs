//! Minimal ASCII table renderer for report output.

/// A column-aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// True when no rows were added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with a header rule and column alignment (first column left,
    /// the rest right — the usual numeric-table convention).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let c = &cells[i];
                if i == 0 {
                    line.push_str(&format!("{c:<w$}", w = widths[i]));
                } else {
                    line.push_str(&format!("{c:>w$}", w = widths[i]));
                }
            }
            line
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["short".into(), "1".into()]);
        t.row(vec!["a-much-longer-name".into(), "123456".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines equal width.
        assert_eq!(lines[0].len(), lines[3].len());
        assert!(lines[3].ends_with("123456"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
