//! Ablation study over the design choices DESIGN.md calls out:
//!
//! * hardware fabric collectives (the paper's co-design thesis, §II/Fig. 3)
//! * asynchronous two-head scheduling (§III-C)
//! * K/V double buffering (Fig. 3's "*without double buffering" footnote)
//! * the custom Spatz exponential unit (§IV)
//! * HBM access latency (the over-flattening driver, §V-B)
//! * NoC link width (Table I's 1024-bit choice)

use crate::arch::presets;
use crate::coordinator::ResultStore;
use crate::dataflow::{double_buffer_programs, run, Dataflow, Workload};
use crate::report::{pct, ReportOpts, Table};
use crate::sim::execute;
use crate::util::json::Json;

/// One ablation grid point.
pub struct AblationRow {
    /// Ablation label.
    pub name: String,
    /// Modeled runtime in milliseconds.
    pub runtime_ms: f64,
    /// System compute utilization.
    pub utilization: f64,
    /// Runtime ratio vs the un-ablated base.
    pub slowdown_vs_base: f64,
}

/// Run the ablation grid (see the module docs).
pub fn run_ablations(opts: &ReportOpts) -> Vec<AblationRow> {
    let arch = presets::table1();
    let wl = if opts.quick {
        Workload::new(2048, 128, 32, 2)
    } else {
        Workload::new(4096, 128, 32, 2)
    };
    let group = 32;
    let tracked = crate::dataflow::tracked_tile(&arch, Dataflow::FlatAsyn, group);

    let mut rows: Vec<AblationRow> = Vec::new();
    let base = run(&arch, &wl, Dataflow::FlatAsyn, group);
    let base_ms = base.runtime_ms(arch.freq_ghz);
    let mut push = |name: &str, makespan: u64, flops: u64, baseline_ms: f64| {
        let ms = makespan as f64 / (arch.freq_ghz * 1e9) * 1e3;
        rows.push(AblationRow {
            name: name.to_string(),
            runtime_ms: ms,
            utilization: flops as f64
                / (makespan as f64 * arch.peak_flops_per_cycle() as f64),
            slowdown_vs_base: ms / baseline_ms,
        });
    };
    push(
        "baseline (FlatAsyn g32, hw coll, db, exp unit)",
        base.makespan,
        base.flops,
        base_ms,
    );

    // − asynchronous scheduling (vs the g32 baseline).
    let sync = run(&arch, &wl, Dataflow::FlatColl, group);
    let sync_ms = sync.runtime_ms(arch.freq_ghz);
    push("- async two-head schedule", sync.makespan, sync.flops, base_ms);

    // − hardware collectives (vs the g32 baseline).
    let sw = run(&arch, &wl, Dataflow::Flat, group);
    push("- hw collectives (sw unicast chains)", sw.makespan, sw.flops, base_ms);

    // − custom exp unit, on the synchronous schedule where the vector path
    //   is exposed (the async schedule fully hides it — itself a finding).
    let mut noexp = arch.clone();
    noexp.tile.spatz_exp_per_fpu = 0;
    let r = run(&noexp, &wl, Dataflow::FlatColl, group);
    push("- Spatz exp unit (sync; sw exp 16 FLOPs/elem)", r.makespan, r.flops, sync_ms);
    let r = run(&noexp, &wl, Dataflow::FlatAsyn, group);
    push("- Spatz exp unit (async: hidden by overlap)", r.makespan, r.flops, base_ms);

    // − double buffering, at group 8 where T_c > 1 so prefetch matters
    //   (at g32/S4096 a single K/V block spans the head — nothing to
    //   prefetch, also a finding). Both variants come from ONE builder
    //   pass (`double_buffer_programs`): only the K/V prefetch deps
    //   differ, so the second variant is derived, not re-emitted.
    let g8 = 8.min(arch.mesh_x);
    let tracked8 = crate::dataflow::tracked_tile(&arch, Dataflow::FlatColl, g8);
    let (db_prog, nodb_prog) = double_buffer_programs(&arch, &wl, Dataflow::FlatColl, g8);
    let db8 = execute(&db_prog, tracked8);
    let db8_ms = db8.runtime_ms(arch.freq_ghz);
    push("  (sync g8 with db, for reference)", db8.makespan, db8.flops, db8_ms);
    let nodb = execute(&nodb_prog, tracked8);
    push("- K/V double buffering (sync g8)", nodb.makespan, nodb.flops, db8_ms);

    // HBM access latency sensitivity (vs the g32 baseline).
    for lat in [100u64, 400, 800] {
        let mut a = arch.clone();
        a.hbm.access_latency = lat;
        let r = run(&a, &wl, Dataflow::FlatAsyn, group);
        push(&format!("HBM access latency {lat} cyc (base 200)"), r.makespan, r.flops, base_ms);
    }

    // NoC link width sensitivity (vs the g32 baseline).
    for link in [64u64, 256] {
        let mut a = arch.clone();
        a.noc.link_bytes_per_cycle = link;
        let r = run(&a, &wl, Dataflow::FlatAsyn, group);
        push(&format!("NoC link {} bit (base 1024)", link * 8), r.makespan, r.flops, base_ms);
    }

    let _ = tracked;
    rows
}

/// Render the ablation table, optionally persisting rows.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let rows = run_ablations(opts);
    if let Some(store) = store {
        store.add_json(
            "ablations",
            rows.iter()
                .map(|r| {
                    Json::obj([
                        ("name", Json::str(r.name.clone())),
                        ("runtime_ms", Json::num(r.runtime_ms)),
                        ("utilization", Json::num(r.utilization)),
                        ("slowdown", Json::num(r.slowdown_vs_base)),
                    ])
                })
                .collect(),
        );
    }
    let mut out = String::new();
    out.push_str("Ablations — FlatAttention design choices (Table I arch, G=32x32, D=128)\n\n");
    let mut t = Table::new(&["configuration", "runtime_ms", "util", "vs baseline"]);
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            format!("{:.3}", r.runtime_ms),
            pct(r.utilization),
            format!("{:.2}x", r.slowdown_vs_base),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_ordered_sensibly() {
        let opts = ReportOpts { quick: true, ..Default::default() };
        let rows = run_ablations(&opts);
        assert!(rows.len() >= 9);
        let base = &rows[0];
        assert!((base.slowdown_vs_base - 1.0).abs() < 1e-9);
        // Removing any co-designed feature must not speed things up.
        for r in &rows[1..5] {
            assert!(
                r.slowdown_vs_base >= 0.99,
                "{}: {:.2}x should be >= 1x",
                r.name,
                r.slowdown_vs_base
            );
        }
        // Software collectives are the worst ablation (the paper's thesis).
        let sw = rows.iter().find(|r| r.name.contains("hw collectives")).unwrap();
        let others: f64 = rows[1..]
            .iter()
            .filter(|r| !r.name.contains("hw collectives"))
            .map(|r| r.slowdown_vs_base)
            .fold(0.0, f64::max);
        assert!(sw.slowdown_vs_base >= others, "sw collectives should dominate ablation cost");
    }
}
