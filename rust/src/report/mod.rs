//! Report renderers — one per paper table/figure (DESIGN.md §5).
//!
//! Every renderer re-runs the relevant simulations (or evaluates the
//! relevant model) and prints the same rows/series the paper reports, as
//! ASCII tables, optionally persisting machine-readable rows into a
//! [`crate::coordinator::ResultStore`].

pub mod ablations;
pub mod ascii;
pub mod fig3;
pub mod fig4;
pub mod fig5a;
pub mod fig5b;
pub mod fig5c;
pub mod headline;
pub mod layers;
pub mod robustness;
pub mod schedule;
pub mod section2;
pub mod serving;
pub mod tables;
pub mod telemetry;

pub use ascii::Table;

/// Common options for report generation.
#[derive(Debug, Clone)]
pub struct ReportOpts {
    /// Worker threads for the simulation fan-out.
    pub threads: usize,
    /// Reduced workload set (CI-sized).
    pub quick: bool,
}

impl Default for ReportOpts {
    fn default() -> Self {
        Self {
            threads: crate::util::pool::default_threads(),
            quick: false,
        }
    }
}

/// Format a fraction as a percentage with one decimal.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Format a ratio like `4.1x`.
pub fn ratio(x: f64) -> String {
    format!("{x:.1}x")
}
