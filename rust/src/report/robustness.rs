//! Graceful-degradation figure (our extension): serving throughput and
//! goodput vs fault severity.
//!
//! Replays the mixed serving trace through the request router
//! ([`crate::scheduler::route`]) under an escalating fault ladder — clean,
//! mildly derated HBM, heavily derated HBM, and derated HBM plus a
//! mid-trace tile death — for a representative of each dataflow family,
//! with the page pool under pressure both ways the router supports:
//! preemption (optimistic admission, evict on pressure) and
//! admission-only (reservation admission, never evict). The figure the
//! kernel papers can't show: how much serving throughput survives a
//! degraded fabric, and what the preemption machinery buys.

use crate::arch::presets;
use crate::arch::ArchConfig;
use crate::coordinator::ResultStore;
use crate::dataflow::Dataflow;
use crate::report::{ReportOpts, Table};
use crate::scheduler::{
    route, RequestTrace, RouterConfig, RouterReport, SchedulerConfig, VictimPolicy,
};
use crate::sim::{Cycle, FaultPlan};
use crate::util::json::Json;

/// Fault-severity ladder size (levels 0..4).
pub const LEVELS: usize = 4;

/// One grid point: dataflow × admission mode × severity level.
pub struct RobustnessRow {
    /// Dataflow under test.
    pub dataflow: Dataflow,
    /// True when the router may preempt to relieve page pressure.
    pub preemption: bool,
    /// Severity level index (0 = fault-free).
    pub level: usize,
    /// Human label of the level.
    pub severity: &'static str,
    /// Router outcome at this point.
    pub report: RouterReport,
}

/// The fault plan of severity `level`: derates hit the *last* channels —
/// the south edge where channel-affine KV pages live — so the ladder
/// degrades the serving-critical resource, not a bystander.
fn severity_plan(
    level: usize,
    arch: &ArchConfig,
    slots: usize,
    death_at: Cycle,
) -> (FaultPlan, &'static str) {
    let total = arch.hbm.total_channels() as u32;
    let derate_last = |plan: FaultPlan, frac: u32, num: u64| {
        let k = (total / frac).max(1);
        (total - k..total).fold(plan, |p, c| p.with_derate(c, 0, u64::MAX / 2, num, 1))
    };
    match level {
        0 => (FaultPlan::none(), "clean"),
        1 => (derate_last(FaultPlan::none(), 8, 2), "derate 1/8 ch x2"),
        2 => (derate_last(FaultPlan::none(), 4, 4), "derate 1/4 ch x4"),
        _ => {
            // Severity 3: the heavy derate plus the last band's
            // representative tile dying a third of the way into the trace.
            let rows_per = arch.mesh_y / slots;
            let tile = ((slots - 1) * rows_per * arch.mesh_x) as u32;
            let plan = derate_last(FaultPlan::none(), 4, 4).with_tile_death(tile, death_at);
            (plan, "derate + tile death")
        }
    }
}

/// A page budget that pressures but never starves: 3/4 of the maximal
/// footprint of the `slots` largest requests, floored at the single
/// largest request so no request is infeasible on an idle machine.
fn page_budget(trace: &RequestTrace, cfg: &SchedulerConfig) -> u64 {
    let mut per: Vec<u64> =
        trace.requests.iter().map(|r| (r.prompt + r.output).div_ceil(cfg.page_tokens)).collect();
    per.sort_unstable_by(|a, b| b.cmp(a));
    let top: u64 = per.iter().take(cfg.slots).sum();
    (top * 3 / 4).max(per.first().copied().unwrap_or(1))
}

/// Run the dataflow × admission-mode × severity ladder.
pub fn run_ladder(
    arch: &ArchConfig,
    trace: &RequestTrace,
    base: &SchedulerConfig,
) -> Vec<RobustnessRow> {
    let mut rows = Vec::new();
    for df in [Dataflow::Flash2, Dataflow::FlatColl] {
        let cfg = SchedulerConfig { dataflow: df, ..base.clone() };
        // Size the death time off the clean run so it lands mid-trace.
        let clean = route(arch, trace, &cfg, &RouterConfig::default());
        let death_at = (clean.serving.total_cycles / 3).max(1);
        let budget = page_budget(trace, &cfg);
        for preemption in [true, false] {
            for level in 0..LEVELS {
                let (faults, severity) = severity_plan(level, arch, cfg.slots, death_at);
                let rc = RouterConfig {
                    faults,
                    max_total_pages: budget,
                    victim: VictimPolicy::FewestPages,
                    preemption,
                    ..RouterConfig::default()
                };
                let report = route(arch, trace, &cfg, &rc);
                rows.push(RobustnessRow { dataflow: df, preemption, level, severity, report });
            }
        }
    }
    rows
}

/// Throughput of this row's clean (level-0) twin, for the vs-clean ratio.
fn clean_tps(rows: &[RobustnessRow], r: &RobustnessRow) -> f64 {
    rows.iter()
        .find(|c| c.dataflow == r.dataflow && c.preemption == r.preemption && c.level == 0)
        .map(|c| c.report.serving.tokens_per_s)
        .unwrap_or(0.0)
}

fn row_json(r: &RobustnessRow, vs_clean: f64) -> Json {
    Json::obj([
        ("dataflow", Json::str(r.dataflow.label())),
        ("mode", Json::str(if r.preemption { "preemption" } else { "admission-only" })),
        ("severity", Json::str(r.severity)),
        ("level", Json::num(r.level as f64)),
        ("tokens_per_s", Json::num(r.report.serving.tokens_per_s)),
        ("goodput_tokens_per_s", Json::num(r.report.serving.goodput_tokens_per_s)),
        ("tokens_per_s_vs_clean", Json::num(vs_clean)),
        ("completed", Json::num(r.report.completed as f64)),
        ("expired", Json::num(r.report.expired as f64)),
        ("preemptions", Json::num(r.report.preemptions as f64)),
        ("band_evictions", Json::num(r.report.band_evictions as f64)),
        ("dead_bands", Json::num(r.report.dead_bands as f64)),
    ])
}

/// Render the robustness figure; optionally record rows in `store`.
pub fn render(opts: &ReportOpts, store: Option<&mut ResultStore>) -> String {
    let (arch, base, setup) = if opts.quick {
        let mut b = SchedulerConfig::new(Dataflow::Flash2);
        b.group = 2;
        b.chunk = 128;
        b.page_tokens = 32;
        (presets::table2(8), b, "table2-8x8, slots=4, chunk=128")
    } else {
        let b = SchedulerConfig::new(Dataflow::Flash2);
        (presets::table1(), b, "Table I arch, slots=4, chunk=512")
    };
    let mut trace =
        RequestTrace::builtin("mixed", crate::report::schedule::KV_HEADS).expect("builtin trace");
    if opts.quick {
        trace.requests.truncate(6);
        for r in &mut trace.requests {
            r.prompt = r.prompt.min(256);
            r.output = r.output.min(12);
        }
    }
    render_on(&arch, &trace, &base, setup, store)
}

/// Render a robustness ladder (shared by the CLI figure and the
/// tiny-mesh smoke tests).
pub fn render_on(
    arch: &ArchConfig,
    trace: &RequestTrace,
    base: &SchedulerConfig,
    setup: &str,
    store: Option<&mut ResultStore>,
) -> String {
    let rows = run_ladder(arch, trace, base);

    if let Some(store) = store {
        let json: Vec<Json> = rows
            .iter()
            .map(|r| {
                let clean = clean_tps(&rows, r).max(1e-9);
                row_json(r, r.report.serving.tokens_per_s / clean)
            })
            .collect();
        store.add_json("robustness", json);
    }

    let mut out = String::new();
    out.push_str(&format!(
        "Serving robustness — degradation under faults ({} requests, {setup})\n\n",
        trace.requests.len()
    ));
    let mut t = Table::new(&[
        "dataflow",
        "mode",
        "severity",
        "tokens/s",
        "goodput/s",
        "vs_clean",
        "done",
        "expired",
        "preempt",
        "band_evict",
        "dead",
    ]);
    for r in &rows {
        let clean = clean_tps(&rows, r).max(1e-9);
        t.row(vec![
            r.dataflow.label().to_string(),
            if r.preemption { "preemption" } else { "admission-only" }.to_string(),
            r.severity.to_string(),
            format!("{:.0}", r.report.serving.tokens_per_s),
            format!("{:.0}", r.report.serving.goodput_tokens_per_s),
            format!("{:.2}", r.report.serving.tokens_per_s / clean),
            r.report.completed.to_string(),
            r.report.expired.to_string(),
            r.report.preemptions.to_string(),
            r.report.band_evictions.to_string(),
            r.report.dead_bands.to_string(),
        ]);
    }
    out.push_str(&t.render());
    out.push('\n');
    out.push_str(
        "severity ladder: clean | mild HBM derate | heavy HBM derate | heavy derate + tile death\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_setup() -> (ArchConfig, RequestTrace, SchedulerConfig) {
        let arch = presets::table2(8);
        // All-zero arrivals: admission then depends on step events only,
        // never on the (severity-dependent) clock, so every severity
        // level replays the same composition sequence and the monotone
        // degradation assertion below is exact.
        let trace = RequestTrace::from_rows(
            &[(0, 160, 4), (0, 96, 8), (0, 200, 3), (0, 64, 6), (0, 128, 5)],
            2,
        );
        let mut cfg = SchedulerConfig::new(Dataflow::Flash2);
        cfg.slots = 4;
        cfg.group = 2;
        cfg.chunk = 96;
        cfg.page_tokens = 32;
        cfg.heads = 4;
        cfg.head_dim = 64;
        (arch, trace, cfg)
    }

    /// CI smoke: the full degradation ladder on a tiny mesh — every row
    /// completes its requests, the dead band registers, and degraded
    /// throughput never exceeds the clean twin.
    #[test]
    fn robustness_ladder_smoke_tiny_mesh() {
        let (arch, trace, cfg) = smoke_setup();
        let rows = run_ladder(&arch, &trace, &cfg);
        assert_eq!(rows.len(), 2 * 2 * LEVELS);
        for r in &rows {
            assert_eq!(r.report.expired, 0, "{:?} L{}: nothing dropped", r.dataflow, r.level);
            assert_eq!(r.report.completed, trace.requests.len(), "{:?} L{}", r.dataflow, r.level);
            let clean = clean_tps(&rows, r);
            assert!(clean > 0.0);
            assert!(
                r.report.serving.tokens_per_s <= clean + 1e-9,
                "{:?} L{} ({}): faults must not speed the run up",
                r.dataflow,
                r.level,
                r.severity
            );
            if r.level == 3 {
                assert_eq!(r.report.dead_bands, 1, "{:?}: L3 tile death visible", r.dataflow);
            } else {
                assert_eq!(r.report.dead_bands, 0);
            }
        }
        let text = render_on(&arch, &trace, &cfg, "smoke", None);
        assert!(text.contains("tile death"));
        assert!(text.contains("preemption") && text.contains("admission-only"));
    }
}
