//! Tile-compute backends for the functional simulator.
//!
//! [`NativeCompute`] runs the online-softmax block step in pure Rust;
//! [`RuntimeCompute`] runs the AOT-compiled Pallas kernel through PJRT —
//! the production path proving all three layers compose.

use anyhow::Result;

#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::util::Tensor;

use super::golden::{block_step_native, SoftmaxState};

/// A backend able to execute one per-tile block update.
pub trait TileCompute {
    /// Apply one online-softmax block step:
    /// (q [Br,D], kt [D,Bc], v [Bc,D], state) → state'.
    fn block_step(&self, q: &Tensor, kt: &Tensor, v: &Tensor, st: &SoftmaxState)
        -> Result<SoftmaxState>;

    /// Backend name for logs.
    fn name(&self) -> &'static str;
}

/// Pure-Rust backend (always available; used as cross-check).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeCompute;

impl TileCompute for NativeCompute {
    fn block_step(
        &self,
        q: &Tensor,
        kt: &Tensor,
        v: &Tensor,
        st: &SoftmaxState,
    ) -> Result<SoftmaxState> {
        Ok(block_step_native(q, kt, v, st))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// PJRT backend executing the AOT Pallas `block_step` artifact.
///
/// The HLO kernel takes finite m/l (the compiled `exp(m - m')` produces
/// NaN from `-inf - -inf`), so the first step from the ±inf init state is
/// seeded with a large-negative sentinel max, which is mathematically
/// equivalent for any finite scores.
#[cfg(feature = "pjrt")]
pub struct RuntimeCompute<'rt> {
    /// The loaded PJRT runtime the kernels execute on.
    pub runtime: &'rt Runtime,
}

/// Finite stand-in for -inf in compiled kernels.
#[cfg(feature = "pjrt")]
const NEG_LARGE: f32 = -1.0e30;

#[cfg(feature = "pjrt")]
impl<'rt> TileCompute for RuntimeCompute<'rt> {
    fn block_step(
        &self,
        q: &Tensor,
        kt: &Tensor,
        v: &Tensor,
        st: &SoftmaxState,
    ) -> Result<SoftmaxState> {
        let m_in: Vec<f32> = st
            .m
            .iter()
            .map(|&m| if m == f32::NEG_INFINITY { NEG_LARGE } else { m })
            .collect();
        let (m, l, o) = self.runtime.block_step(q, kt, v, &m_in, &st.l, &st.o)?;
        Ok(SoftmaxState { m, l, o })
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn native_matches_direct_call() {
        let mut rng = Rng::new(4);
        let q = Tensor::randn(8, 16, &mut rng);
        let k = Tensor::randn(8, 16, &mut rng);
        let v = Tensor::randn(8, 16, &mut rng);
        let st = SoftmaxState::init(8, 16);
        let a = NativeCompute.block_step(&q, &k.transpose(), &v, &st).unwrap();
        let b = block_step_native(&q, &k.transpose(), &v, &st);
        assert_eq!(a.m, b.m);
        assert!(a.o.max_abs_diff(&b.o) == 0.0);
    }
}
