//! Band-partitioned GEMM functional model and the full-layer golden
//! oracle.
//!
//! `dataflow::gemm` models the *time* of the projection/FFN GEMMs; this
//! module models their *values*: [`gemm_band_functional`] evaluates
//! `C = A·B` with exactly the partition the band dataflow uses — M split
//! across band rows, N across mesh columns, K accumulated in panel order
//! — and must agree with the flat reference matmul. On top of it,
//! [`qkv_split`] unpacks the GQA-narrowed QKV projection
//! (`[dm, dm + 2·kv_dim]`) into per-head tensors, so the tests can chain
//! QKV-proj → attention → out-proj → FFN through the band-partitioned
//! evaluation and compare the whole layer against the golden composition
//! of flat matmuls and [`super::golden::attention_gqa_golden`].

use crate::util::Tensor;

/// Evaluate `C[M×N] = A[M×K] · B[K×N]` exactly as the band GEMM dataflow
/// partitions it: `rows` band rows each own `ceil(M/rows)` output rows,
/// `cols` mesh columns each own `ceil(N/cols)` output columns, and every
/// tile accumulates its C tile over `kb`-sized K panels in panel order.
/// Per-element this performs the same multiply-adds as `A·B` grouped into
/// panel partial sums; f32 addition is associative enough at test sizes
/// that results match the flat reference to tight tolerance.
pub fn gemm_band_functional(a: &Tensor, b: &Tensor, rows: usize, cols: usize, kb: usize) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(b.rows(), k, "inner dimensions must agree");
    assert!(rows > 0 && cols > 0 && kb > 0);
    let mb = m.div_ceil(rows);
    let nt = n.div_ceil(cols);
    let mut c = Tensor::zeros(m, n);
    for ly in 0..rows {
        let (r0, r1) = ((ly * mb).min(m), ((ly + 1) * mb).min(m));
        for x in 0..cols {
            let (c0, c1) = ((x * nt).min(n), ((x + 1) * nt).min(n));
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + kb).min(k);
                for r in r0..r1 {
                    for cc in c0..c1 {
                        let mut acc = 0.0f32;
                        for kk in k0..k1 {
                            acc += a.at(r, kk) * b.at(kk, cc);
                        }
                        c.set(r, cc, c.at(r, cc) + acc);
                    }
                }
                k0 = k1;
            }
        }
    }
    c
}

/// Split a packed QKV projection output `[S, dm + 2·kv_dim]`
/// (`dm = heads·head_dim`, `kv_dim = kv_heads·head_dim` — the
/// GQA-narrowed layout `dataflow::layer::LayerWorkload::gemms` sizes the
/// `qkv-proj` GEMM for) into per-query-head Q tensors and per-KV-head
/// K/V tensors, each `[S, head_dim]`.
pub fn qkv_split(
    xw: &Tensor,
    heads: usize,
    kv_heads: usize,
    head_dim: usize,
) -> (Vec<Tensor>, Vec<Tensor>, Vec<Tensor>) {
    let s = xw.rows();
    let dm = heads * head_dim;
    let kv_dim = kv_heads * head_dim;
    assert_eq!(xw.cols(), dm + 2 * kv_dim, "packed QKV width mismatch");
    let slice = |base: usize, h: usize| {
        let mut t = Tensor::zeros(s, head_dim);
        for r in 0..s {
            for c in 0..head_dim {
                t.set(r, c, xw.at(r, base + h * head_dim + c));
            }
        }
        t
    };
    let q = (0..heads).map(|h| slice(0, h)).collect();
    let k = (0..kv_heads).map(|h| slice(dm, h)).collect();
    let v = (0..kv_heads).map(|h| slice(dm + kv_dim, h)).collect();
    (q, k, v)
}

/// Concatenate per-head `[S, head_dim]` outputs back into `[S, dm]`.
pub fn concat_heads(heads: &[Tensor]) -> Tensor {
    assert!(!heads.is_empty());
    let s = heads[0].rows();
    let d = heads[0].cols();
    let mut out = Tensor::zeros(s, heads.len() * d);
    for (h, t) in heads.iter().enumerate() {
        assert_eq!((t.rows(), t.cols()), (s, d));
        for r in 0..s {
            for c in 0..d {
                out.set(r, h * d + c, t.at(r, c));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::golden::attention_gqa_golden;
    use crate::util::Rng;

    #[test]
    fn band_partitioned_gemm_matches_flat_matmul() {
        let mut rng = Rng::new(0x6E00);
        let a = Tensor::randn(37, 24, &mut rng); // ragged M: last band short
        let b = Tensor::randn(24, 19, &mut rng); // ragged N and K panels
        let flat = a.matmul(&b);
        for (rows, cols, kb) in [(1, 1, 24), (4, 4, 16), (8, 3, 7), (5, 19, 5)] {
            let banded = gemm_band_functional(&a, &b, rows, cols, kb);
            let diff = banded.max_abs_diff(&flat);
            assert!(diff < 1e-4, "rows={rows} cols={cols} kb={kb}: diff {diff}");
        }
    }

    #[test]
    fn full_layer_through_band_gemms_matches_golden_composition() {
        // The satellite oracle: QKV-proj (GQA-narrowed) → attention →
        // out-proj → FFN-up → FFN-down, every GEMM evaluated through the
        // band partition, must reproduce the same chain built from flat
        // matmuls and the golden GQA attention.
        let mut rng = Rng::new(0x1A7E);
        let (s, heads, kv_heads, head_dim, mult) = (24usize, 4usize, 2usize, 8usize, 2usize);
        let dm = heads * head_dim;
        let kv_dim = kv_heads * head_dim;
        let x = Tensor::randn(s, dm, &mut rng);
        let w_qkv = Tensor::randn(dm, dm + 2 * kv_dim, &mut rng);
        let w_out = Tensor::randn(dm, dm, &mut rng);
        let w_up = Tensor::randn(dm, mult * dm, &mut rng);
        let w_down = Tensor::randn(mult * dm, dm, &mut rng);

        let layer = |mm: &dyn Fn(&Tensor, &Tensor) -> Tensor| {
            let (q, k, v) = qkv_split(&mm(&x, &w_qkv), heads, kv_heads, head_dim);
            let attn = concat_heads(&attention_gqa_golden(&q, &k, &v));
            mm(&mm(&mm(&attn, &w_out), &w_up), &w_down)
        };
        let golden = layer(&|a, b| a.matmul(b));
        let banded = layer(&|a, b| gemm_band_functional(a, b, 4, 4, 16));
        let diff = banded.max_abs_diff(&golden);
        assert!(banded.all_finite() && diff < 1e-2, "layer diff {diff}");
    }

    #[test]
    fn qkv_split_roundtrips_concat() {
        let mut rng = Rng::new(0x0F17);
        let (s, heads, kv_heads, head_dim) = (10usize, 4usize, 4usize, 8usize);
        // With kv_heads == heads the packed layout is three dm-wide
        // blocks; splitting then concatenating each must reproduce them.
        let xw = Tensor::randn(s, 3 * heads * head_dim, &mut rng);
        let (q, k, v) = qkv_split(&xw, heads, kv_heads, head_dim);
        let (qc, kc, vc) = (concat_heads(&q), concat_heads(&k), concat_heads(&v));
        let dm = heads * head_dim;
        for r in 0..s {
            for c in 0..dm {
                assert_eq!(qc.at(r, c), xw.at(r, c));
                assert_eq!(kc.at(r, c), xw.at(r, dm + c));
                assert_eq!(vc.at(r, c), xw.at(r, 2 * dm + c));
            }
        }
    }
}
