//! Group-level functional execution of FlatAttention (Algorithm 2).
//!
//! Two executions are provided:
//!
//! * [`run_flat_group_literal`] follows Algorithm 2 *line by line*: every
//!   inner iteration performs the row-wise max reduction + multicast, the
//!   exp with the *global* row maxima, the row-wise sum reduction +
//!   multicast, and the O rescale — real data moving the way the NoC
//!   collectives move it. Pure native math (the per-step granularity does
//!   not match the fused block-step artifact).
//! * [`run_flat_group_functional`] exploits the associativity of online
//!   softmax (validated in `golden::tests::merge_property_random_splits`):
//!   each tile independently folds its K/V slices with the (native or
//!   PJRT-compiled) `block_step` kernel, and the row-wise reduction merges
//!   the per-tile partial states — the same result through the artifact
//!   path the production system uses.
//!
//! Both must agree with `attention_golden` to float tolerance; the
//! integration tests assert all three paths coincide.

use anyhow::Result;

use crate::util::Tensor;

use super::compute::TileCompute;
use super::golden::{softmax_merge, SoftmaxState};

/// Output of a functional group run.
pub struct FlatGroupResult {
    /// Assembled attention output [S, D].
    pub output: Tensor,
    /// Number of block-step invocations (for artifact-use accounting).
    pub block_steps: usize,
}

/// Partition `seq` into `g` contiguous slices (last may be ragged).
fn slice_bounds(seq: usize, g: usize) -> Vec<(usize, usize)> {
    let t = seq.div_ceil(g);
    (0..g)
        .map(|i| {
            let lo = (i * t).min(seq);
            let hi = ((i + 1) * t).min(seq);
            (lo, hi - lo)
        })
        .filter(|&(_, n)| n > 0)
        .collect()
}

/// Merge-at-end execution over a `g × g` group using a [`TileCompute`]
/// backend. q/k/v: [S, D] single head.
pub fn run_flat_group_functional(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    g: usize,
    compute: &dyn TileCompute,
) -> Result<FlatGroupResult> {
    let (s, d) = (q.rows(), q.cols());
    assert_eq!(k.rows(), s);
    assert_eq!(v.rows(), s);
    let rows = slice_bounds(s, g);
    let cols = slice_bounds(s, g);
    let mut output = Tensor::zeros(s, d);
    let mut steps = 0usize;

    // Row y of the group holds Q slice y (row-multicast along the row);
    // column x holds Kᵀ/V slice x (column-multicast down the column).
    for &(q0, qn) in &rows {
        let q_slice = q.row_block(q0, qn);
        // Each tile (x, y) folds its K/V slice into a local state...
        let mut partials: Vec<SoftmaxState> = Vec::with_capacity(cols.len());
        for &(k0, kn) in &cols {
            let kt = k.row_block(k0, kn).transpose();
            let vj = v.row_block(k0, kn);
            let st = compute.block_step(&q_slice, &kt, &vj, &SoftmaxState::init(qn, d))?;
            steps += 1;
            partials.push(st);
        }
        // ...and the row-wise reduction merges the partials to the west
        // edge (this is what the NoC sum/max reduction computes).
        let merged = partials
            .into_iter()
            .reduce(|a, b| softmax_merge(&a, &b))
            .expect("at least one column");
        output.write_block(q0, 0, &merged.normalize());
    }
    Ok(FlatGroupResult { output, block_steps: steps })
}

/// Literal Algorithm-2 execution: per-iteration global row statistics via
/// max/sum reductions and multicasts, native math.
pub fn run_flat_group_literal(q: &Tensor, k: &Tensor, v: &Tensor, g: usize) -> FlatGroupResult {
    let (s, d) = (q.rows(), q.cols());
    let rows = slice_bounds(s, g);
    let cols = slice_bounds(s, g);
    let scale = 1.0 / (d as f32).sqrt();
    let mut output = Tensor::zeros(s, d);
    let mut steps = 0usize;

    for &(q0, qn) in &rows {
        let q_slice = q.row_block(q0, qn);
        // Per-tile O accumulators along this group row, plus shared stats.
        let mut o_parts: Vec<Tensor> = vec![Tensor::zeros(qn, d); cols.len()];
        let mut m_run = vec![f32::NEG_INFINITY; qn];
        let mut l_run = vec![0.0f32; qn];

        for (j, &(k0, kn)) in cols.iter().enumerate() {
            // ⑤ every tile computes its S slice (same data in a real group;
            // here we iterate the x dimension).
            let kt = k.row_block(k0, kn).transpose();
            let vj = v.row_block(k0, kn);
            let mut s_blk = q_slice.matmul(&kt);
            for val in s_blk.data_mut() {
                *val *= scale;
            }
            steps += 1;
            // ⑥–⑨ local maxima then row-wise max REDUCTION + multicast:
            let mut m_new = m_run.clone();
            for r in 0..qn {
                for c in 0..kn {
                    m_new[r] = m_new[r].max(s_blk.at(r, c));
                }
            }
            // ⑩–⑬ exp with *global* maxima, local sums, sum reduction:
            let mut p = Tensor::zeros(qn, kn);
            for r in 0..qn {
                for c in 0..kn {
                    p.set(r, c, (s_blk.at(r, c) - m_new[r]).exp());
                }
            }
            let alpha: Vec<f32> = m_run
                .iter()
                .zip(&m_new)
                .map(|(&mo, &mn)| if mo == f32::NEG_INFINITY { 0.0 } else { (mo - mn).exp() })
                .collect();
            let psum = p.row_sum();
            for r in 0..qn {
                l_run[r] = alpha[r] * l_run[r] + psum[r];
            }
            // ⑭–⑰ every tile rescales its O partial and accumulates P̃·V.
            // (In hardware tile x holds o_parts[x]; the rescale factor is
            // multicast with the stats.)
            for o_part in o_parts.iter_mut() {
                o_part.scale_rows(&alpha);
            }
            o_parts[j] = o_parts[j].add(&p.matmul(&vj));
            m_run = m_new;
        }

        // ⑱–⑳ normalize and row-reduce the O partials to the west edge.
        let mut o_total = Tensor::zeros(qn, d);
        for o_part in &o_parts {
            o_total = o_total.add(o_part);
        }
        let inv: Vec<f32> = l_run.iter().map(|&x| 1.0 / x).collect();
        o_total.scale_rows(&inv);
        output.write_block(q0, 0, &o_total);
    }
    FlatGroupResult { output, block_steps: steps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::golden::attention_golden;
    use crate::functional::NativeCompute;
    use crate::util::Rng;

    fn inputs(s: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        (
            Tensor::randn(s, d, &mut rng),
            Tensor::randn(s, d, &mut rng),
            Tensor::randn(s, d, &mut rng),
        )
    }

    #[test]
    fn functional_matches_golden_various_groups() {
        for &(s, d, g) in &[(64usize, 16usize, 2usize), (128, 32, 4), (128, 16, 8), (96, 8, 3)] {
            let (q, k, v) = inputs(s, d, (s + d + g) as u64);
            let res = run_flat_group_functional(&q, &k, &v, g, &NativeCompute).unwrap();
            let golden = attention_golden(&q, &k, &v);
            let diff = res.output.max_abs_diff(&golden);
            assert!(diff < 2e-4, "s={s} d={d} g={g}: diff {diff}");
            assert_eq!(res.block_steps, g.min(s) * g.min(s));
        }
    }

    #[test]
    fn literal_algorithm2_matches_golden() {
        for &(s, d, g) in &[(64usize, 16usize, 4usize), (128, 32, 8)] {
            let (q, k, v) = inputs(s, d, 99 + g as u64);
            let res = run_flat_group_literal(&q, &k, &v, g);
            let golden = attention_golden(&q, &k, &v);
            let diff = res.output.max_abs_diff(&golden);
            assert!(diff < 2e-4, "s={s} d={d} g={g}: diff {diff}");
        }
    }

    #[test]
    fn literal_and_functional_agree() {
        let (q, k, v) = inputs(128, 16, 7);
        let a = run_flat_group_functional(&q, &k, &v, 4, &NativeCompute).unwrap();
        let b = run_flat_group_literal(&q, &k, &v, 4);
        assert!(a.output.max_abs_diff(&b.output) < 2e-4);
    }

    #[test]
    fn group_of_one_is_flash() {
        // g=1 degenerates to single-tile FlashAttention.
        let (q, k, v) = inputs(64, 8, 11);
        let res = run_flat_group_functional(&q, &k, &v, 1, &NativeCompute).unwrap();
        let golden = attention_golden(&q, &k, &v);
        assert!(res.output.max_abs_diff(&golden) < 1e-4);
        assert_eq!(res.block_steps, 1);
    }

    #[test]
    fn ragged_sequence_slices() {
        // S not divisible by G exercises the ragged last slice.
        let (q, k, v) = inputs(100, 16, 13);
        let res = run_flat_group_functional(&q, &k, &v, 3, &NativeCompute).unwrap();
        let golden = attention_golden(&q, &k, &v);
        assert!(res.output.max_abs_diff(&golden) < 2e-4);
    }
}
