//! Functional (data-carrying) simulation of the FlatAttention dataflow.
//!
//! The DES (`crate::sim`) models *time*; this module models *values*: it
//! executes Algorithm 2's data movement on real f32 buffers — per-tile Q/K/V
//! slices, row/column multicasts, row-wise max/sum reductions, the O-slice
//! reduction — and checks the assembled output against the golden attention
//! reference. The per-tile compute runs either natively
//! ([`compute::NativeCompute`]) or through the AOT-compiled Pallas
//! `block_step` artifact via PJRT ([`compute::RuntimeCompute`]), which is
//! the three-layer composition proof: Rust coordination + simulated fabric
//! + compiled JAX/Pallas math.

pub mod compute;
pub mod gemm;
pub mod golden;
pub mod group;

pub use compute::{NativeCompute, TileCompute};
pub use gemm::{concat_heads, gemm_band_functional, qkv_split};
#[cfg(feature = "pjrt")]
pub use compute::RuntimeCompute;
pub use golden::{
    attention_decode_golden, attention_golden, attention_gqa_golden, block_step_native,
    softmax_merge,
};
pub use group::{run_flat_group_functional, run_flat_group_literal, FlatGroupResult};
