//! Native golden references: plain attention, the online-softmax block
//! step, and the softmax-merge combine used by the group reductions.

use crate::util::Tensor;

/// Plain softmax(Q Kᵀ / √D) V for a single head. q: [S,D], k/v: [S,D].
pub fn attention_golden(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let d = q.cols();
    let scale = 1.0 / (d as f32).sqrt();
    let mut s = q.matmul(&k.transpose());
    for val in s.data_mut() {
        *val *= scale;
    }
    let m = s.row_max();
    let rows = s.rows();
    let cols = s.cols();
    let mut p = Tensor::zeros(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            p.set(r, c, (s.at(r, c) - m[r]).exp());
        }
    }
    let l = p.row_sum();
    let mut out = p.matmul(v);
    let inv: Vec<f32> = l.iter().map(|&x| 1.0 / x).collect();
    out.scale_rows(&inv);
    out
}

/// Running online-softmax state for a row block.
#[derive(Debug, Clone)]
pub struct SoftmaxState {
    /// Row maxima (length Br).
    pub m: Vec<f32>,
    /// Row denominators (length Br).
    pub l: Vec<f32>,
    /// Unnormalized output accumulator [Br, D].
    pub o: Tensor,
}

impl SoftmaxState {
    /// Fresh accumulator state for a `[br, d]` output block.
    pub fn init(br: usize, d: usize) -> Self {
        Self {
            m: vec![f32::NEG_INFINITY; br],
            l: vec![0.0; br],
            o: Tensor::zeros(br, d),
        }
    }

    /// Finalize: O · diag(l)⁻¹.
    pub fn normalize(mut self) -> Tensor {
        let inv: Vec<f32> = self.l.iter().map(|&x| 1.0 / x).collect();
        self.o.scale_rows(&inv);
        self.o
    }
}

/// One online-softmax block update in native Rust — the same math as the
/// Pallas `block_step` kernel (ref.py `block_step_ref`).
/// q: [Br,D], kt: [D,Bc], v: [Bc,D].
pub fn block_step_native(q: &Tensor, kt: &Tensor, v: &Tensor, st: &SoftmaxState) -> SoftmaxState {
    let d = q.cols();
    let scale = 1.0 / (d as f32).sqrt();
    let mut s = q.matmul(kt);
    for val in s.data_mut() {
        *val *= scale;
    }
    let br = q.rows();
    let bc = v.rows();
    let mut m_new = st.m.clone();
    for r in 0..br {
        for c in 0..bc {
            m_new[r] = m_new[r].max(s.at(r, c));
        }
    }
    let mut p = Tensor::zeros(br, bc);
    for r in 0..br {
        for c in 0..bc {
            p.set(r, c, (s.at(r, c) - m_new[r]).exp());
        }
    }
    let alpha: Vec<f32> = st
        .m
        .iter()
        .zip(&m_new)
        .map(|(&mo, &mn)| if mo == f32::NEG_INFINITY { 0.0 } else { (mo - mn).exp() })
        .collect();
    let psum = p.row_sum();
    let l_new: Vec<f32> = st
        .l
        .iter()
        .zip(&alpha)
        .zip(&psum)
        .map(|((&l, &a), &ps)| a * l + ps)
        .collect();
    let mut o_new = st.o.clone();
    o_new.scale_rows(&alpha);
    let o_new = o_new.add(&p.matmul(v));
    SoftmaxState { m: m_new, l: l_new, o: o_new }
}

/// Grouped-query attention golden: `q` holds one `[S, D]` tensor per
/// *query* head, `k`/`v` one `[S, D]` tensor per *KV* head
/// (`q.len() % k.len() == 0`); query head `h` attends K/V head
/// `h / (H / H_kv)`. Returns one output per query head. With
/// `k.len() == q.len()` this is plain per-head MHA.
pub fn attention_gqa_golden(q: &[Tensor], k: &[Tensor], v: &[Tensor]) -> Vec<Tensor> {
    assert!(!q.is_empty() && !k.is_empty(), "at least one head required");
    assert_eq!(k.len(), v.len(), "K and V head counts must match");
    assert!(
        q.len() % k.len() == 0,
        "query heads ({}) must be a multiple of KV heads ({})",
        q.len(),
        k.len()
    );
    let q_per_kv = q.len() / k.len();
    q.iter()
        .enumerate()
        .map(|(h, qh)| attention_golden(qh, &k[h / q_per_kv], &v[h / q_per_kv]))
        .collect()
}

/// Decode golden: `q` is the `[rows, D]` block of *new* query rows (rows
/// is 1 for plain decode, or a stacked GQA group), attending over the
/// full `[S, D]` cache, streamed through the online-softmax block step in
/// `block`-sized chunks — the decode dataflow's compute schedule. Equals
/// the corresponding trailing rows of prefill attention.
pub fn attention_decode_golden(q: &Tensor, k: &Tensor, v: &Tensor, block: usize) -> Tensor {
    assert!(block > 0, "block must be non-zero");
    let mut st = SoftmaxState::init(q.rows(), q.cols());
    let s = k.rows();
    let mut j = 0;
    while j < s {
        let bc = block.min(s - j);
        st = block_step_native(q, &k.row_block(j, bc).transpose(), &v.row_block(j, bc), &st);
        j += bc;
    }
    st.normalize()
}

/// Merge two online-softmax states covering disjoint K/V ranges of the same
/// row block — exactly what FlatAttention's row-wise reductions compute
/// when combining per-tile partials.
pub fn softmax_merge(a: &SoftmaxState, b: &SoftmaxState) -> SoftmaxState {
    let br = a.m.len();
    assert_eq!(br, b.m.len());
    let mut m = vec![0.0f32; br];
    let mut wa = vec![0.0f32; br];
    let mut wb = vec![0.0f32; br];
    for r in 0..br {
        m[r] = a.m[r].max(b.m[r]);
        wa[r] = if a.m[r] == f32::NEG_INFINITY { 0.0 } else { (a.m[r] - m[r]).exp() };
        wb[r] = if b.m[r] == f32::NEG_INFINITY { 0.0 } else { (b.m[r] - m[r]).exp() };
    }
    let l: Vec<f32> = (0..br).map(|r| wa[r] * a.l[r] + wb[r] * b.l[r]).collect();
    let mut oa = a.o.clone();
    oa.scale_rows(&wa);
    let mut ob = b.o.clone();
    ob.scale_rows(&wb);
    SoftmaxState { m, l, o: oa.add(&ob) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, forall_cases};
    use crate::util::Rng;

    fn randn(rng: &mut Rng, r: usize, c: usize) -> Tensor {
        Tensor::randn(r, c, rng)
    }

    #[test]
    fn golden_rows_sum_to_convex_combination() {
        let mut rng = Rng::new(1);
        let (q, k, v) = (randn(&mut rng, 16, 8), randn(&mut rng, 32, 8), randn(&mut rng, 32, 8));
        let out = attention_golden(&q, &k, &v);
        assert!(out.all_finite());
        // Each output row within the V column envelope.
        for c in 0..8 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for r in 0..32 {
                lo = lo.min(v.at(r, c));
                hi = hi.max(v.at(r, c));
            }
            for r in 0..16 {
                assert!(out.at(r, c) >= lo - 1e-4 && out.at(r, c) <= hi + 1e-4);
            }
        }
    }

    #[test]
    fn block_steps_compose_to_attention() {
        let mut rng = Rng::new(2);
        let (s, d, bc) = (64, 16, 16);
        let (q, k, v) = (randn(&mut rng, 32, d), randn(&mut rng, s, d), randn(&mut rng, s, d));
        let mut st = SoftmaxState::init(32, d);
        for j in (0..s).step_by(bc) {
            let kt = k.row_block(j, bc).transpose();
            let vj = v.row_block(j, bc);
            st = block_step_native(&q, &kt, &vj, &st);
        }
        let out = st.normalize();
        let golden = attention_golden(&q, &k, &v);
        assert!(out.max_abs_diff(&golden) < 1e-4, "diff {}", out.max_abs_diff(&golden));
    }

    #[test]
    fn merge_equals_sequential() {
        // Splitting the K/V range in two and merging == processing all
        // blocks sequentially (associativity of online softmax).
        let mut rng = Rng::new(3);
        let (d, bc) = (8, 16);
        let q = randn(&mut rng, 16, d);
        let (k1, v1) = (randn(&mut rng, bc, d), randn(&mut rng, bc, d));
        let (k2, v2) = (randn(&mut rng, bc, d), randn(&mut rng, bc, d));
        let init = SoftmaxState::init(16, d);
        let seq = block_step_native(&q, &k2.transpose(), &v2,
            &block_step_native(&q, &k1.transpose(), &v1, &init));
        let p1 = block_step_native(&q, &k1.transpose(), &v1, &init);
        let p2 = block_step_native(&q, &k2.transpose(), &v2, &init);
        let merged = softmax_merge(&p1, &p2);
        let a = seq.normalize();
        let b = merged.normalize();
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    fn gqa_equals_per_head_with_repeated_kv() {
        // Grouped K/V must equal dense attention with each KV head
        // repeated heads/kv_heads times — the GQA oracle the dataflow
        // builders' sharing argument rests on.
        let mut rng = Rng::new(0x60A);
        let (s, d, heads, kv_heads) = (32usize, 8usize, 8usize, 2usize);
        let q: Vec<Tensor> = (0..heads).map(|_| Tensor::randn(s, d, &mut rng)).collect();
        let k: Vec<Tensor> = (0..kv_heads).map(|_| Tensor::randn(s, d, &mut rng)).collect();
        let v: Vec<Tensor> = (0..kv_heads).map(|_| Tensor::randn(s, d, &mut rng)).collect();
        let grouped = attention_gqa_golden(&q, &k, &v);
        // Independently repeat K/V to dense MHA and compare per head.
        let q_per_kv = heads / kv_heads;
        let k_rep: Vec<Tensor> = (0..heads).map(|h| k[h / q_per_kv].clone()).collect();
        let v_rep: Vec<Tensor> = (0..heads).map(|h| v[h / q_per_kv].clone()).collect();
        let dense = attention_gqa_golden(&q, &k_rep, &v_rep);
        assert_eq!(grouped.len(), heads);
        for (h, (g, m)) in grouped.iter().zip(&dense).enumerate() {
            assert!(g.max_abs_diff(m) < 1e-6, "head {h}: diff {}", g.max_abs_diff(m));
        }
    }

    #[test]
    fn decode_equals_last_prefill_row() {
        // A single decode row against the full cache must reproduce the
        // last row of prefill attention (streamed through the online
        // block step, including a partial trailing K/V chunk).
        let mut rng = Rng::new(0xDEC0);
        let (s, d) = (56usize, 16usize); // 56 % 16 != 0: partial last block
        let q = Tensor::randn(s, d, &mut rng);
        let k = Tensor::randn(s, d, &mut rng);
        let v = Tensor::randn(s, d, &mut rng);
        let prefill = attention_golden(&q, &k, &v);
        let decode = attention_decode_golden(&q.row_block(s - 1, 1), &k, &v, 16);
        assert_eq!(decode.rows(), 1);
        for c in 0..d {
            let diff = (decode.at(0, c) - prefill.at(s - 1, c)).abs();
            assert!(diff < 1e-4, "col {c}: diff {diff}");
        }
    }

    #[test]
    fn stacked_gqa_decode_rows_are_independent() {
        // Stacking a KV group's decode rows into one block (the builders'
        // GQA trick) must not couple them: each stacked row equals its own
        // single-row decode.
        let mut rng = Rng::new(0x57AC);
        let (s, d, rows) = (48usize, 8usize, 4usize);
        let q = Tensor::randn(rows, d, &mut rng);
        let k = Tensor::randn(s, d, &mut rng);
        let v = Tensor::randn(s, d, &mut rng);
        let stacked = attention_decode_golden(&q, &k, &v, 16);
        for r in 0..rows {
            let solo = attention_decode_golden(&q.row_block(r, 1), &k, &v, 16);
            for c in 0..d {
                let diff = (stacked.at(r, c) - solo.at(0, c)).abs();
                assert!(diff < 1e-5, "row {r} col {c}: diff {diff}");
            }
        }
    }

    #[test]
    fn merge_property_random_splits() {
        forall_cases(30, 0xFA7, |rng| {
            let d = 8;
            let br = 8;
            let n_blocks = 2 + rng.gen_range(3) as usize;
            let q = Tensor::randn(br, d, rng);
            let blocks: Vec<(Tensor, Tensor)> = (0..n_blocks)
                .map(|_| (Tensor::randn(16, d, rng), Tensor::randn(16, d, rng)))
                .collect();
            // Sequential over all blocks.
            let mut st = SoftmaxState::init(br, d);
            for (k, v) in &blocks {
                st = block_step_native(&q, &k.transpose(), v, &st);
            }
            let seq = st.normalize();
            // Tree merge of per-block partials.
            let partials: Vec<SoftmaxState> = blocks
                .iter()
                .map(|(k, v)| block_step_native(&q, &k.transpose(), v, &SoftmaxState::init(br, d)))
                .collect();
            let merged = partials
                .into_iter()
                .reduce(|a, b| softmax_merge(&a, &b))
                .unwrap()
                .normalize();
            check(
                seq.max_abs_diff(&merged) < 1e-4,
                format!("diff {} with {n_blocks} blocks", seq.max_abs_diff(&merged)),
            )
        });
    }
}
