//! Native golden references: plain attention, the online-softmax block
//! step, and the softmax-merge combine used by the group reductions.

use crate::util::Tensor;

/// Plain softmax(Q Kᵀ / √D) V for a single head. q: [S,D], k/v: [S,D].
pub fn attention_golden(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
    let d = q.cols();
    let scale = 1.0 / (d as f32).sqrt();
    let mut s = q.matmul(&k.transpose());
    for val in s.data_mut() {
        *val *= scale;
    }
    let m = s.row_max();
    let rows = s.rows();
    let cols = s.cols();
    let mut p = Tensor::zeros(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            p.set(r, c, (s.at(r, c) - m[r]).exp());
        }
    }
    let l = p.row_sum();
    let mut out = p.matmul(v);
    let inv: Vec<f32> = l.iter().map(|&x| 1.0 / x).collect();
    out.scale_rows(&inv);
    out
}

/// Running online-softmax state for a row block.
#[derive(Debug, Clone)]
pub struct SoftmaxState {
    /// Row maxima (length Br).
    pub m: Vec<f32>,
    /// Row denominators (length Br).
    pub l: Vec<f32>,
    /// Unnormalized output accumulator [Br, D].
    pub o: Tensor,
}

impl SoftmaxState {
    pub fn init(br: usize, d: usize) -> Self {
        Self {
            m: vec![f32::NEG_INFINITY; br],
            l: vec![0.0; br],
            o: Tensor::zeros(br, d),
        }
    }

    /// Finalize: O · diag(l)⁻¹.
    pub fn normalize(mut self) -> Tensor {
        let inv: Vec<f32> = self.l.iter().map(|&x| 1.0 / x).collect();
        self.o.scale_rows(&inv);
        self.o
    }
}

/// One online-softmax block update in native Rust — the same math as the
/// Pallas `block_step` kernel (ref.py `block_step_ref`).
/// q: [Br,D], kt: [D,Bc], v: [Bc,D].
pub fn block_step_native(q: &Tensor, kt: &Tensor, v: &Tensor, st: &SoftmaxState) -> SoftmaxState {
    let d = q.cols();
    let scale = 1.0 / (d as f32).sqrt();
    let mut s = q.matmul(kt);
    for val in s.data_mut() {
        *val *= scale;
    }
    let br = q.rows();
    let bc = v.rows();
    let mut m_new = st.m.clone();
    for r in 0..br {
        for c in 0..bc {
            m_new[r] = m_new[r].max(s.at(r, c));
        }
    }
    let mut p = Tensor::zeros(br, bc);
    for r in 0..br {
        for c in 0..bc {
            p.set(r, c, (s.at(r, c) - m_new[r]).exp());
        }
    }
    let alpha: Vec<f32> = st
        .m
        .iter()
        .zip(&m_new)
        .map(|(&mo, &mn)| if mo == f32::NEG_INFINITY { 0.0 } else { (mo - mn).exp() })
        .collect();
    let psum = p.row_sum();
    let l_new: Vec<f32> = st
        .l
        .iter()
        .zip(&alpha)
        .zip(&psum)
        .map(|((&l, &a), &ps)| a * l + ps)
        .collect();
    let mut o_new = st.o.clone();
    o_new.scale_rows(&alpha);
    let o_new = o_new.add(&p.matmul(v));
    SoftmaxState { m: m_new, l: l_new, o: o_new }
}

/// Merge two online-softmax states covering disjoint K/V ranges of the same
/// row block — exactly what FlatAttention's row-wise reductions compute
/// when combining per-tile partials.
pub fn softmax_merge(a: &SoftmaxState, b: &SoftmaxState) -> SoftmaxState {
    let br = a.m.len();
    assert_eq!(br, b.m.len());
    let mut m = vec![0.0f32; br];
    let mut wa = vec![0.0f32; br];
    let mut wb = vec![0.0f32; br];
    for r in 0..br {
        m[r] = a.m[r].max(b.m[r]);
        wa[r] = if a.m[r] == f32::NEG_INFINITY { 0.0 } else { (a.m[r] - m[r]).exp() };
        wb[r] = if b.m[r] == f32::NEG_INFINITY { 0.0 } else { (b.m[r] - m[r]).exp() };
    }
    let l: Vec<f32> = (0..br).map(|r| wa[r] * a.l[r] + wb[r] * b.l[r]).collect();
    let mut oa = a.o.clone();
    oa.scale_rows(&wa);
    let mut ob = b.o.clone();
    ob.scale_rows(&wb);
    SoftmaxState { m, l, o: oa.add(&ob) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, forall_cases};
    use crate::util::Rng;

    fn randn(rng: &mut Rng, r: usize, c: usize) -> Tensor {
        Tensor::randn(r, c, rng)
    }

    #[test]
    fn golden_rows_sum_to_convex_combination() {
        let mut rng = Rng::new(1);
        let (q, k, v) = (randn(&mut rng, 16, 8), randn(&mut rng, 32, 8), randn(&mut rng, 32, 8));
        let out = attention_golden(&q, &k, &v);
        assert!(out.all_finite());
        // Each output row within the V column envelope.
        for c in 0..8 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for r in 0..32 {
                lo = lo.min(v.at(r, c));
                hi = hi.max(v.at(r, c));
            }
            for r in 0..16 {
                assert!(out.at(r, c) >= lo - 1e-4 && out.at(r, c) <= hi + 1e-4);
            }
        }
    }

    #[test]
    fn block_steps_compose_to_attention() {
        let mut rng = Rng::new(2);
        let (s, d, bc) = (64, 16, 16);
        let (q, k, v) = (randn(&mut rng, 32, d), randn(&mut rng, s, d), randn(&mut rng, s, d));
        let mut st = SoftmaxState::init(32, d);
        for j in (0..s).step_by(bc) {
            let kt = k.row_block(j, bc).transpose();
            let vj = v.row_block(j, bc);
            st = block_step_native(&q, &kt, &vj, &st);
        }
        let out = st.normalize();
        let golden = attention_golden(&q, &k, &v);
        assert!(out.max_abs_diff(&golden) < 1e-4, "diff {}", out.max_abs_diff(&golden));
    }

    #[test]
    fn merge_equals_sequential() {
        // Splitting the K/V range in two and merging == processing all
        // blocks sequentially (associativity of online softmax).
        let mut rng = Rng::new(3);
        let (d, bc) = (8, 16);
        let q = randn(&mut rng, 16, d);
        let (k1, v1) = (randn(&mut rng, bc, d), randn(&mut rng, bc, d));
        let (k2, v2) = (randn(&mut rng, bc, d), randn(&mut rng, bc, d));
        let init = SoftmaxState::init(16, d);
        let seq = block_step_native(&q, &k2.transpose(), &v2,
            &block_step_native(&q, &k1.transpose(), &v1, &init));
        let p1 = block_step_native(&q, &k1.transpose(), &v1, &init);
        let p2 = block_step_native(&q, &k2.transpose(), &v2, &init);
        let merged = softmax_merge(&p1, &p2);
        let a = seq.normalize();
        let b = merged.normalize();
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    fn merge_property_random_splits() {
        forall_cases(30, 0xFA7, |rng| {
            let d = 8;
            let br = 8;
            let n_blocks = 2 + rng.gen_range(3) as usize;
            let q = Tensor::randn(br, d, rng);
            let blocks: Vec<(Tensor, Tensor)> = (0..n_blocks)
                .map(|_| (Tensor::randn(16, d, rng), Tensor::randn(16, d, rng)))
                .collect();
            // Sequential over all blocks.
            let mut st = SoftmaxState::init(br, d);
            for (k, v) in &blocks {
                st = block_step_native(&q, &k.transpose(), v, &st);
            }
            let seq = st.normalize();
            // Tree merge of per-block partials.
            let partials: Vec<SoftmaxState> = blocks
                .iter()
                .map(|(k, v)| block_step_native(&q, &k.transpose(), v, &SoftmaxState::init(br, d)))
                .collect();
            let merged = partials
                .into_iter()
                .reduce(|a, b| softmax_merge(&a, &b))
                .unwrap()
                .normalize();
            check(
                seq.max_abs_diff(&merged) < 1e-4,
                format!("diff {} with {n_blocks} blocks", seq.max_abs_diff(&merged)),
            )
        });
    }
}
