//! FlatAttention: Dataflow and Fabric Collectives Co-Optimization for
//! Efficient Multi-Head Attention on Tile-Based Many-PE Accelerators.
//!
//! Reproduction of Zhang et al., CS.AR 2025.
//!
//! This crate implements the full SoftHier-style modeling and simulation
//! stack for tile-based many-PE accelerators, the FlatAttention /
//! FlashAttention dataflow family, the NoC fabric collective primitives
//! co-design, and the paper's complete evaluation harness.
//!
//! A guided tour of the module graph lives in `docs/ARCHITECTURE.md`; the
//! CLI surface (the `flatattention` binary) is documented in `docs/CLI.md`.

#![warn(missing_docs)]

pub mod analysis;
pub mod arch;
pub mod sim;
pub mod noc;
pub mod engines;
pub mod hbm;
pub mod dataflow;
pub mod functional;
pub mod runtime;
pub mod scheduler;
pub mod telemetry;
pub mod coordinator;
pub mod analytics;
pub mod report;
pub mod util;

pub use arch::ArchConfig;
