//! Minimal TOML-subset parser for architecture configuration files.
//!
//! Supports what `configs/*.toml` need: `[table]` headers (one level of
//! nesting via dotted names is not required), `key = value` pairs with
//! string / integer / float / boolean values, `#` comments, and blank
//! lines. Unknown syntax is a hard error with a line number — configs are
//! hand-written and should fail loudly.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// A quoted string.
    Str(String),
    /// An integer.
    Int(i64),
    /// A float.
    Float(f64),
    /// A boolean.
    Bool(bool),
}

impl TomlValue {
    /// String value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Non-negative integer value, if this is an `Int >= 0`.
    pub fn as_u64(&self) -> Option<u64> {
        match *self {
            TomlValue::Int(i) if i >= 0 => Some(i as u64),
            _ => None,
        }
    }

    /// [`Self::as_u64`] narrowed to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// Float value (integers widen), if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match *self {
            TomlValue::Float(f) => Some(f),
            TomlValue::Int(i) => Some(i as f64),
            _ => None,
        }
    }

    /// Boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match *self {
            TomlValue::Bool(b) => Some(b),
            _ => None,
        }
    }
}

/// A parsed document: `tables["tile"]["l1_kib"]` etc. Top-level keys live
/// in the `""` table.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    /// `table name -> key -> value`; top-level keys live under `""`.
    pub tables: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Look up `table.key`.
    pub fn get(&self, table: &str, key: &str) -> Option<&TomlValue> {
        self.tables.get(table).and_then(|t| t.get(key))
    }

    /// Typed getters with defaults.
    pub fn usize_or(&self, table: &str, key: &str, default: usize) -> usize {
        self.get(table, key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    /// `u64` at `table.key`, or `default`.
    pub fn u64_or(&self, table: &str, key: &str, default: u64) -> u64 {
        self.get(table, key).and_then(|v| v.as_u64()).unwrap_or(default)
    }

    /// `f64` at `table.key`, or `default`.
    pub fn f64_or(&self, table: &str, key: &str, default: f64) -> f64 {
        self.get(table, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// `bool` at `table.key`, or `default`.
    pub fn bool_or(&self, table: &str, key: &str, default: bool) -> bool {
        self.get(table, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

/// Parse a TOML-subset document.
pub fn parse_toml(input: &str) -> Result<TomlDoc, String> {
    let mut doc = TomlDoc::default();
    let mut current = String::new();
    doc.tables.entry(current.clone()).or_default();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated table header", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty table name", lineno + 1));
            }
            current = name.to_string();
            doc.tables.entry(current.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected 'key = value'", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(value.trim())
            .ok_or_else(|| format!("line {}: cannot parse value '{}'", lineno + 1, value.trim()))?;
        doc.tables.get_mut(&current).unwrap().insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<TomlValue> {
    if let Some(stripped) = s.strip_prefix('"') {
        return stripped.strip_suffix('"').map(|v| TomlValue::Str(v.to_string()));
    }
    match s {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Some(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Some(TomlValue::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
name = "custom"     # inline comment
freq_ghz = 1.5

[mesh]
x = 16
y = 16

[tile]
l1_kib = 1_536
hw = true
"#;

    #[test]
    fn parses_sample() {
        let doc = parse_toml(SAMPLE).unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("custom"));
        assert_eq!(doc.f64_or("", "freq_ghz", 0.0), 1.5);
        assert_eq!(doc.usize_or("mesh", "x", 0), 16);
        assert_eq!(doc.u64_or("tile", "l1_kib", 0), 1536);
        assert!(doc.bool_or("tile", "hw", false));
    }

    #[test]
    fn defaults_for_missing() {
        let doc = parse_toml("").unwrap();
        assert_eq!(doc.usize_or("mesh", "x", 42), 42);
        assert!(!doc.bool_or("noc", "hw", false));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse_toml(r##"label = "a#b""##).unwrap();
        assert_eq!(doc.get("", "label").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_have_line_numbers() {
        assert!(parse_toml("[unclosed").unwrap_err().contains("line 1"));
        assert!(parse_toml("\njust a line").unwrap_err().contains("line 2"));
        assert!(parse_toml("k = @bad").unwrap_err().contains("line 1"));
    }

    #[test]
    fn negative_and_float_values() {
        let doc = parse_toml("a = -3\nb = 2.75").unwrap();
        assert_eq!(doc.get("", "a"), Some(&TomlValue::Int(-3)));
        assert_eq!(doc.f64_or("", "b", 0.0), 2.75);
        // Negative ints are not u64.
        assert_eq!(doc.get("", "a").unwrap().as_u64(), None);
    }
}
