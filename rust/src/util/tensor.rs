//! Minimal dense 2-D f32 tensor used by the functional simulator and the
//! golden attention reference.
//!
//! The simulator's *timing* path never touches this type; it only appears on
//! the functional-validation path (where numbers must be exact) and in
//! tests. Row-major, no strides, no views — slicing copies, which keeps the
//! data-movement semantics of the dataflow explicit (a DMA'd slice really is
//! a separate buffer, as in the tile L1s).

use std::fmt;

/// Dense row-major matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor[{}x{}]", self.rows, self.cols)
    }
}

impl Tensor {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Self { rows, cols, data: vec![v; rows * cols] }
    }

    /// Build from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Self { rows, cols, data }
    }

    /// Random-normal matrix (for synthesizing Q/K/V inputs).
    pub fn randn(rows: usize, cols: usize, rng: &mut super::Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal_f32()).collect();
        Self { rows, cols, data }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row-major backing slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major backing slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    /// Element at `(r, c)`.
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    /// Set the element at `(r, c)`.
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// `self @ other` — naive triple loop with k-inner accumulation in f32.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Tensor::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                let brow = &other.data[p * n..(p + 1) * n];
                for (o, b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Tensor {
        let mut out = Tensor::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Copy of the row block `[r0, r0+nr)`.
    pub fn row_block(&self, r0: usize, nr: usize) -> Tensor {
        assert!(r0 + nr <= self.rows, "row_block out of range");
        let data = self.data[r0 * self.cols..(r0 + nr) * self.cols].to_vec();
        Tensor::from_vec(nr, self.cols, data)
    }

    /// Copy of the column block `[c0, c0+nc)`.
    pub fn col_block(&self, c0: usize, nc: usize) -> Tensor {
        assert!(c0 + nc <= self.cols, "col_block out of range");
        let mut out = Tensor::zeros(self.rows, nc);
        for r in 0..self.rows {
            out.data[r * nc..(r + 1) * nc]
                .copy_from_slice(&self.data[r * self.cols + c0..r * self.cols + c0 + nc]);
        }
        out
    }

    /// Write `block` into `self` at `(r0, c0)`.
    pub fn write_block(&mut self, r0: usize, c0: usize, block: &Tensor) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for r in 0..block.rows {
            let dst = (r0 + r) * self.cols + c0;
            self.data[dst..dst + block.cols]
                .copy_from_slice(&block.data[r * block.cols..(r + 1) * block.cols]);
        }
    }

    /// Per-row maximum.
    pub fn row_max(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| {
                self.data[r * self.cols..(r + 1) * self.cols]
                    .iter()
                    .copied()
                    .fold(f32::NEG_INFINITY, f32::max)
            })
            .collect()
    }

    /// Per-row sum.
    pub fn row_sum(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.data[r * self.cols..(r + 1) * self.cols].iter().sum())
            .collect()
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Multiply every element of row `r` by `s[r]`.
    pub fn scale_rows(&mut self, s: &[f32]) {
        assert_eq!(s.len(), self.rows);
        for r in 0..self.rows {
            for v in &mut self.data[r * self.cols..(r + 1) * self.cols] {
                *v *= s[r];
            }
        }
    }

    /// Elementwise addition.
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Tensor {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    /// Max absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// True if all elements are finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_identity() {
        let mut eye = Tensor::zeros(3, 3);
        for i in 0..3 {
            eye.set(i, i, 1.0);
        }
        let mut rng = Rng::new(1);
        let a = Tensor::randn(3, 3, &mut rng);
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(4, 7, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn blocks_round_trip() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(8, 6, &mut rng);
        let blk = a.row_block(2, 4);
        let mut b = Tensor::zeros(8, 6);
        b.write_block(2, 0, &blk);
        for r in 2..6 {
            for c in 0..6 {
                assert_eq!(b.at(r, c), a.at(r, c));
            }
        }
        let cb = a.col_block(1, 3);
        assert_eq!(cb.rows(), 8);
        assert_eq!(cb.cols(), 3);
        assert_eq!(cb.at(5, 0), a.at(5, 1));
    }

    #[test]
    fn row_stats() {
        let a = Tensor::from_vec(2, 3, vec![1.0, 5.0, 2.0, -1.0, -5.0, -2.0]);
        assert_eq!(a.row_max(), vec![5.0, -1.0]);
        assert_eq!(a.row_sum(), vec![8.0, -8.0]);
    }

    #[test]
    fn scale_rows_applies_per_row() {
        let mut a = Tensor::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        a.scale_rows(&[2.0, 0.5]);
        assert_eq!(a.data(), &[2.0, 4.0, 1.5, 2.0]);
    }

    #[test]
    fn max_abs_diff_zero_for_self() {
        let mut rng = Rng::new(4);
        let a = Tensor::randn(5, 5, &mut rng);
        assert_eq!(a.max_abs_diff(&a), 0.0);
    }
}
