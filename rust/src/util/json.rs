//! Minimal JSON value model with an emitter and a recursive-descent parser.
//!
//! Used for experiment result persistence (`coordinator::store`) and for
//! machine-readable report output. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII payloads).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are stored as f64 (integers round-trip exactly up
/// to 2^53, far beyond any cycle count we serialize at report granularity).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; `BTreeMap` keys give deterministic serialization.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from static-key pairs.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a number.
    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    /// Build a string.
    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    /// Numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// String slice, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Element slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Member lookup, if this is an `Obj`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_indented(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_indented(&self, out: &mut String, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            for _ in 0..d {
                out.push_str("  ");
            }
        };
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    pad(out, depth + 1);
                    x.write_indented(out, depth + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    pad(out, depth + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_indented(out, depth + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Parse a JSON document. Returns an error message with byte offset on
    /// malformed input.
    pub fn parse(input: &str) -> Result<Json, String> {
        let mut p = Parser { b: input.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_num(out: &mut String, v: f64) {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_basic() {
        let j = Json::obj([
            ("name", Json::str("fig3")),
            ("cycles", Json::num(1234567_u32)),
            ("ok", Json::Bool(true)),
            ("list", Json::Arr(vec![Json::num(1), Json::num(2.5), Json::Null])),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parses_pretty_output() {
        let j = Json::obj([("a", Json::Arr(vec![Json::str("x\ny"), Json::num(-3)]))]);
        let back = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parses_nested() {
        let s = r#"{"a": {"b": [1, 2, {"c": null}]}, "d": "e\"f"}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.get("d").unwrap().as_str().unwrap(), "e\"f");
        let arr = v.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn integers_exact() {
        let j = Json::num(9_007_199_254_740_991_u64 as f64);
        assert_eq!(j.to_string(), "9007199254740991");
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }
}
