//! Micro property-based testing harness (proptest is unavailable offline).
//!
//! Runs a property over many PRNG-generated cases; on failure it performs a
//! simple halving shrink over the integer parameters and reports the
//! minimal failing case with its seed so the failure reproduces exactly.
//!
//! ```ignore
//! forall_cases(200, 0xC0FFEE, |rng| {
//!     let s = pow2_in(rng, 64, 1024);
//!     check(reassemble(split(s)) == s, format!("s={s}"))
//! });
//! ```

use super::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Convenience: turn a boolean + message into a [`CaseResult`].
pub fn check(ok: bool, msg: impl Into<String>) -> CaseResult {
    if ok {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `prop` for `cases` generated cases. Panics (test failure) with the
/// case index, seed and message on the first failing case.
pub fn forall_cases(cases: u32, seed: u64, prop: impl Fn(&mut Rng) -> CaseResult) {
    for case in 0..cases {
        let case_seed = seed ^ (0x9E3779B97F4A7C15u64.wrapping_mul(case as u64 + 1));
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case}/{cases} (seed {case_seed:#x}): {msg}\n\
                 reproduce with Rng::new({case_seed:#x})"
            );
        }
    }
}

/// Sample a power of two in `[lo, hi]` (both must be powers of two).
pub fn pow2_in(rng: &mut Rng, lo: u64, hi: u64) -> u64 {
    assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
    let lo_exp = lo.trailing_zeros();
    let hi_exp = hi.trailing_zeros();
    1u64 << (lo_exp + rng.gen_range((hi_exp - lo_exp + 1) as u64) as u32)
}

/// Sample a multiple of `step` in `[lo, hi]`.
pub fn multiple_in(rng: &mut Rng, step: u64, lo: u64, hi: u64) -> u64 {
    assert!(step > 0 && lo <= hi && lo % step == 0);
    let n = (hi - lo) / step + 1;
    lo + rng.gen_range(n) * step
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall_cases(100, 1, |rng| {
            let x = rng.gen_range(1000);
            check(x < 1000, format!("x={x}"))
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall_cases(100, 2, |rng| {
            let x = rng.gen_range(10);
            check(x != 3, format!("x={x}"))
        });
    }

    #[test]
    fn pow2_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let v = pow2_in(&mut rng, 64, 1024);
            assert!(v.is_power_of_two() && (64..=1024).contains(&v));
        }
    }

    #[test]
    fn multiple_in_range() {
        let mut rng = Rng::new(4);
        for _ in 0..1000 {
            let v = multiple_in(&mut rng, 32, 32, 512);
            assert!(v % 32 == 0 && (32..=512).contains(&v));
        }
    }
}
