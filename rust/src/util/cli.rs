//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed getters and a generated usage string. The `flatattention`
//! binary builds its subcommand dispatch on top of this.

use std::collections::BTreeMap;

/// Parsed arguments: positionals in order, plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches, in order of appearance.
    pub flags: Vec<String>,
}

/// Parse a raw argument list. `spec_flags` lists option names that take NO
/// value (bare flags); everything else starting with `--` consumes the next
/// token (or the `=`-suffix) as its value.
pub fn parse(raw: &[String], spec_flags: &[&str]) -> Result<Args, String> {
    let mut args = Args::default();
    let mut i = 0;
    while i < raw.len() {
        let tok = &raw[i];
        if let Some(stripped) = tok.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                args.options.insert(k.to_string(), v.to_string());
            } else if spec_flags.contains(&stripped) {
                args.flags.push(stripped.to_string());
            } else {
                i += 1;
                let v = raw
                    .get(i)
                    .ok_or_else(|| format!("option --{stripped} expects a value"))?;
                args.options.insert(stripped.to_string(), v.clone());
            }
        } else {
            args.positional.push(tok.clone());
        }
        i += 1;
    }
    Ok(args)
}

impl Args {
    /// True if `--name` was passed as a bare switch.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Value of `--name`, if the option was passed.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Value of `--name`, or `default` when absent.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse `--name` as `usize`, defaulting when absent; the error names the flag.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Parse `--name` as `u64`, defaulting when absent; the error names the flag.
    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Parse a comma-separated list of integers, e.g. `--seq 1024,2048`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| format!("--{name}: bad integer '{p}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&v(&["run", "--seq", "4096", "--d=128", "--verbose"]), &["verbose"]).unwrap();
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("seq"), Some("4096"));
        assert_eq!(a.get("d"), Some("128"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&v(&["--seq"]), &[]).is_err());
    }

    #[test]
    fn typed_getters() {
        let a = parse(&v(&["--n", "42", "--list", "1,2,3"]), &[]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.get_usize_list("list", &[]).unwrap(), vec![1, 2, 3]);
        assert!(a.get_usize_list("list", &[]).is_ok());
        let bad = parse(&v(&["--n", "xyz"]), &[]).unwrap();
        assert!(bad.get_usize("n", 0).is_err());
    }
}
