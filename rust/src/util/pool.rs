//! Parallel map over std threads (rayon is unavailable offline).
//!
//! The coordinator fans experiment runs out across cores with
//! [`par_map`]; work is distributed via an atomic index so uneven run
//! times (e.g. 8×8-group sims vs 32×32) self-balance.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: `FLATATTN_THREADS` env override, else
/// available parallelism, else 4.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("FLATATTN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every item of `items` in parallel, preserving order of
/// results. `f` must be `Sync` (called from many threads) and the items are
/// taken by reference.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().map(|it| f(it)).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker completed every claimed item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&items, 8, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let items = vec![1, 2, 3];
        assert_eq!(par_map(&items, 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        assert!(par_map(&items, 4, |x| *x).is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        // Items with wildly different costs still all complete.
        let items: Vec<u64> = (0..64).collect();
        let out = par_map(&items, 8, |x| {
            let mut acc = 0u64;
            for i in 0..(x % 7) * 10_000 {
                acc = acc.wrapping_add(i);
            }
            acc.wrapping_add(*x)
        });
        assert_eq!(out.len(), 64);
    }
}
