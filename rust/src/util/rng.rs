//! Deterministic xoshiro256** PRNG.
//!
//! Used by the property-testing helpers, workload generators and the
//! functional simulator's input synthesis. Seeded explicitly everywhere so
//! every test and experiment is reproducible.

/// xoshiro256** generator (Blackman & Vigna). Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        // Guard against the all-zero state (splitmix cannot emit 4 zeros for
        // any seed, but be defensive).
        let s = if s == [0, 0, 0, 0] { [1, 2, 3, 4] } else { s };
        Self { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Lemire-style rejection-free-enough reduction; bias is negligible
        // for the n values used here (all << 2^32).
        ((self.next_u64() >> 32).wrapping_mul(n)) >> 32
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard-normal-ish f32 via sum of uniforms (Irwin–Hall, k=12).
    /// Adequate for synthesizing attention inputs.
    pub fn normal_f32(&mut self) -> f32 {
        let mut acc = 0.0f32;
        for _ in 0..12 {
            acc += self.f32();
        }
        acc - 6.0
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_roughly_centered() {
        let mut r = Rng::new(9);
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        let n = 20_000;
        for _ in 0..n {
            let v = r.normal_f32() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
