//! Self-contained utility substrate.
//!
//! The build environment vendors only the `xla` crate closure, so the
//! general-purpose infrastructure a project of this size normally pulls from
//! crates.io (CLI parsing, JSON emission, a thread pool, property-based
//! testing helpers, a PRNG) is implemented here.

pub mod cli;
pub mod json;
pub mod pool;
pub mod quickcheck;
pub mod rng;
pub mod tensor;
pub mod toml;

pub use rng::Rng;
pub use tensor::Tensor;

/// Ceiling division for unsigned integers.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: u64, b: u64) -> u64 {
    ceil_div(a, b) * b
}

/// Format a cycle count with thousands separators for reports.
pub fn fmt_cycles(c: u64) -> String {
    let s = c.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    out
}

/// Format a byte count using binary prefixes (KiB/MiB/GiB).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(0, 3), 0);
    }

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(10, 8), 16);
        assert_eq!(round_up(16, 8), 16);
        assert_eq!(round_up(0, 8), 0);
    }

    #[test]
    fn fmt_cycles_groups() {
        assert_eq!(fmt_cycles(0), "0");
        assert_eq!(fmt_cycles(999), "999");
        assert_eq!(fmt_cycles(1000), "1,000");
        assert_eq!(fmt_cycles(1234567), "1,234,567");
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert!(fmt_bytes(3 * 1024 * 1024).starts_with("3.00 MiB"));
    }
}
