//! Band-scoped tiled GEMM kernels for the transformer projection / FFN
//! workloads that surround attention in a full layer (see
//! `crate::dataflow::layer` for the composition).
//!
//! Unlike [`crate::dataflow::summa`], which owns the full mesh, these
//! kernels are emitted onto a horizontal *band* of tile rows — the same
//! band a scheduler slot owns for attention — so a composed serving step
//! can run request A's projections while request B's attention occupies a
//! different band. The mapping is output-stationary and band-local:
//!
//! - **M** (activation rows) partitions across the band's tile rows;
//! - **N** (output columns) partitions across the mesh columns, so each
//!   tile owns an `mb × nt` block of C;
//! - **K** streams in panels sized by [`gemm_panel_kb`] to fit L1 with
//!   double buffering.
//!
//! Per K panel, each band row loads its `A` panel once through the row's
//! west HBM channel and row-multicasts it to the row's tiles (the fabric
//! collective); `B` weight panels stream per tile through the same row
//! channel when [`WeightResidency::HbmStream`], and are elided entirely
//! under [`WeightResidency::Resident`] (weights pinned on-tile — the
//! sweepable axis). `C` stores leave through the row channel. Restricting
//! *all* traffic to the band's own west row channels keeps a batch
//! entry's channel footprint band-local, which is what the conservative-
//! composition / disjoint-channel differential story (and the scheduler's
//! channel masks) rely on. The cost of that choice is honest: `B` panels
//! are re-streamed once per band row instead of column-multicast across
//! bands — cross-band collectives would contend on physical column buses
//! shared with other entries' bands.
//!
//! GEMM ops never fold or stamp: symmetry folding is an attention-stream
//! concept (see `crate::dataflow` §fold); every GEMM op is emitted
//! verbatim, so cross-kernel dependency edges always attach to real ops.

use crate::arch::ArchConfig;
use crate::engines::{dma_hbm_time, matmul_cycles, SpatzOp};
use crate::hbm::HbmMap;
use crate::noc::{collective_time, CollectiveKind};
use crate::sim::{Component, OpId, Program, ResourceId, NO_TILE};

use super::summa::GemmWorkload;

/// FP16 element size (matches `Workload::BYTES_PER_ELEM`).
const EB: u64 = 2;

/// Where a GEMM kernel's `B` (weight) operand lives — the sweepable
/// weights axis of the layer workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WeightResidency {
    /// Weights stream from HBM through the band's row channels each time
    /// the kernel runs (the honest serving default: layer weights do not
    /// fit in SRAM).
    HbmStream,
    /// Weights are pinned in on-tile memory; the kernel moves only
    /// activations. An idealized upper bound — the other end of the
    /// sweep.
    Resident,
}

/// The residency values a sweep iterates over.
pub const ALL_RESIDENCIES: [WeightResidency; 2] =
    [WeightResidency::HbmStream, WeightResidency::Resident];

impl WeightResidency {
    /// Stable CLI / report label.
    pub fn label(self) -> &'static str {
        match self {
            WeightResidency::HbmStream => "hbm",
            WeightResidency::Resident => "resident",
        }
    }

    /// Parse a [`WeightResidency::label`] (the `--weights` grammar).
    pub fn from_label(s: &str) -> Option<Self> {
        match s {
            "hbm" => Some(WeightResidency::HbmStream),
            "resident" => Some(WeightResidency::Resident),
            _ => None,
        }
    }
}

/// K-panel depth for a band GEMM tile: the largest multiple of 16 whose
/// double-buffered footprint fits L1 (at least 16 even when nothing
/// fits — degenerate tiles still make progress).
///
/// The footprint formula is the L1 tiling contract shared with the SUMMA
/// builder: `A` and `B` panels are double-buffered, the `C` block is
/// resident once:
///
/// ```
/// use flatattention::dataflow::gemm_panel_kb;
///
/// let (l1, mb, nt) = (512 * 1024, 128, 448);
/// let kb = gemm_panel_kb(l1, mb, nt);
/// // 2 bytes/elem · (2·A + 2·B + C) must fit in L1:
/// assert!(2 * (2 * mb * kb + 2 * kb * nt + mb * nt) <= l1);
/// assert!(kb >= 16 && kb % 16 == 0);
/// ```
pub fn gemm_panel_kb(l1_bytes: u64, mb: u64, nt: u64) -> u64 {
    let mut kb = 16u64;
    while kb < 1024 {
        let next = kb + 16;
        if EB * (2 * mb * next + 2 * next * nt + mb * nt) > l1_bytes {
            break;
        }
        kb = next;
    }
    kb
}

/// Append one band-scoped GEMM kernel to `prog` and return the id of its
/// zero-cost *sink barrier* — the single op every later kernel hangs its
/// cross-kernel dependency on.
///
/// `prog` must already own the architecture's HBM channel resources at
/// indices `0..n_chan` (the attention builders' channel-first invariant);
/// engine and bus resources are allocated fresh per call, which is exact
/// because the entry barrier serializes this kernel behind `deps` anyway
/// — by the time any GEMM op can issue, the previous kernel's engines
/// are drained.
///
/// `deps` are the cross-kernel edges (the previous kernel's sinks, or
/// empty for a solo kernel). They are joined by a zero-cost *entry
/// barrier* which every root op of this kernel depends on, so the whole
/// kernel issues no earlier than `max(completion of deps)` — the fact
/// the layer-additivity differential test pins.
pub(crate) fn append_gemm_band(
    prog: &mut Program,
    arch: &ArchConfig,
    gemm: &GemmWorkload,
    y0: usize,
    y1: usize,
    residency: WeightResidency,
    deps: &[OpId],
) -> OpId {
    let hbm_map = HbmMap::new(arch);
    let n_chan = hbm_map.total_channels();
    debug_assert!(
        prog.num_resources() >= n_chan,
        "append_gemm_band: program must own the channel resources first"
    );
    debug_assert!(y0 < y1 && y1 <= arch.mesh_y, "append_gemm_band: bad band {y0}..{y1}");

    let rows = y1 - y0;
    let cols = arch.mesh_x;

    // Fresh private resources for this kernel instance.
    let barrier_res = prog.resource();
    let redmule = prog.resources(rows * cols);
    let spatz = prog.resources(rows * cols);
    let row_bus = prog.resources(rows);

    let entry = prog.op(barrier_res, 0, 0, Component::Other, NO_TILE, 0, deps);

    let mb = gemm.m.div_ceil(rows as u64);
    let nt = gemm.n.div_ceil(cols as u64);
    let kb = gemm_panel_kb(arch.tile.l1_bytes(), mb.max(1), nt.max(1));
    let k_steps = gemm.k.div_ceil(kb);
    let local = |lx: usize, ly: usize| ly * cols + lx;

    // Double-buffer chain per tile (same discipline as SUMMA).
    let mut gemm_prev: Vec<Option<OpId>> = vec![None; rows * cols];
    let mut gemm_prev2: Vec<Option<OpId>> = vec![None; rows * cols];
    let mut stores: Vec<OpId> = Vec::with_capacity(rows * cols);
    let mut deps_buf: Vec<OpId> = Vec::with_capacity(4);

    for ly in 0..rows {
        let y = y0 + ly;
        let mb_cur = (gemm.m - (mb * ly as u64).min(gemm.m)).min(mb);
        if mb_cur == 0 {
            continue; // short M: band rows past the activation rows idle
        }
        let ch = hbm_map.row_channel(0, y);
        for step in 0..k_steps {
            let kb_cur = (gemm.k - step * kb).min(kb);

            // A(row, k) panel: load at the row head, row-multicast.
            let a_bytes = mb_cur * kb_cur * EB;
            let ta = dma_hbm_time(&arch.hbm, &arch.noc, a_bytes, ch.hops);
            deps_buf.clear();
            deps_buf.push(entry);
            deps_buf.extend(gemm_prev2[local(0, ly)]);
            let a_load = prog.op(
                ResourceId(ch.index as u32),
                ta.occupancy,
                ta.latency,
                Component::HbmAccess,
                arch.tile_id(0, y),
                a_bytes,
                &deps_buf,
            );
            let mt = collective_time(
                &arch.noc,
                a_bytes,
                (cols - 1).max(1) as u64,
                CollectiveKind::Multicast,
            );
            let a_mc = prog.op(
                row_bus[ly],
                mt.occupancy,
                mt.latency,
                Component::Multicast,
                arch.tile_id(0, y),
                0,
                &[a_load],
            );

            for lx in 0..cols {
                let nt_cur = (gemm.n - (nt * lx as u64).min(gemm.n)).min(nt);
                if nt_cur == 0 {
                    continue;
                }
                let tl = local(lx, ly);
                deps_buf.clear();
                deps_buf.push(a_mc);
                if residency == WeightResidency::HbmStream {
                    // B(k, col) weight panel through the band row channel.
                    let b_bytes = kb_cur * nt_cur * EB;
                    let bch = hbm_map.row_channel(lx, y);
                    let tb = dma_hbm_time(&arch.hbm, &arch.noc, b_bytes, bch.hops);
                    let mut bdeps = vec![entry];
                    bdeps.extend(gemm_prev2[tl]);
                    let b_load = prog.op(
                        ResourceId(bch.index as u32),
                        tb.occupancy,
                        tb.latency,
                        Component::HbmAccess,
                        arch.tile_id(lx, y),
                        b_bytes,
                        &bdeps,
                    );
                    deps_buf.push(b_load);
                }
                deps_buf.extend(gemm_prev[tl]);
                let op = prog.op(
                    redmule[tl],
                    matmul_cycles(&arch.tile, mb_cur, kb_cur, nt_cur),
                    0,
                    Component::RedMule,
                    arch.tile_id(lx, y),
                    0,
                    &deps_buf,
                );
                gemm_prev2[tl] = gemm_prev[tl];
                gemm_prev[tl] = Some(op);
            }
        }

        // Epilogue + C store per tile of the row.
        for lx in 0..cols {
            let nt_cur = (gemm.n - (nt * lx as u64).min(gemm.n)).min(nt);
            if nt_cur == 0 {
                continue;
            }
            let tl = local(lx, ly);
            let last = gemm_prev[tl].expect("k loop emitted at least one matmul");
            let ep = prog.op(
                spatz[tl],
                SpatzOp::Scale { elems: mb_cur * nt_cur }.cycles(&arch.tile),
                0,
                Component::Spatz,
                arch.tile_id(lx, y),
                0,
                &[last],
            );
            let c_bytes = mb_cur * nt_cur * EB;
            let sch = hbm_map.row_channel(lx, y);
            let tc = dma_hbm_time(&arch.hbm, &arch.noc, c_bytes, sch.hops);
            stores.push(prog.op(
                ResourceId(sch.index as u32),
                tc.occupancy,
                tc.latency,
                Component::HbmAccess,
                arch.tile_id(lx, y),
                c_bytes,
                &[ep],
            ));
        }
    }

    // Sink barrier: the kernel's single downstream handle. A GEMM over an
    // empty band (m == 0) still yields a well-formed chain through the
    // entry barrier.
    if stores.is_empty() {
        return prog.op(barrier_res, 0, 0, Component::Other, NO_TILE, 0, &[entry]);
    }
    prog.op(barrier_res, 0, 0, Component::Other, NO_TILE, 0, &stores)
}

/// Build a solo band GEMM program (channel resources first, one kernel,
/// sealed) — the differential-test and roofline harness entry point.
pub fn gemm_band_program(
    arch: &ArchConfig,
    gemm: &GemmWorkload,
    y0: usize,
    y1: usize,
    residency: WeightResidency,
) -> Program {
    let mut prog = Program::new();
    let hbm_map = HbmMap::new(arch);
    prog.resources(hbm_map.total_channels());
    append_gemm_band(&mut prog, arch, gemm, y0, y1, residency, &[]);
    prog.flops = gemm.flops();
    prog.seal();
    prog
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::sim::execute;

    #[test]
    fn residency_labels_round_trip() {
        for r in ALL_RESIDENCIES {
            assert_eq!(WeightResidency::from_label(r.label()), Some(r));
        }
        assert_eq!(WeightResidency::from_label("l2"), None);
    }

    #[test]
    fn band_gemm_builds_and_runs() {
        let arch = presets::table2(8);
        let g = GemmWorkload::new(512, 4096, 4096, "out-proj");
        for res in ALL_RESIDENCIES {
            let p = gemm_band_program(&arch, &g, 0, 2, res);
            assert!(p.validate().is_ok(), "{res:?}");
            let st = execute(&p, 0);
            assert!(st.makespan > 0, "{res:?}");
        }
    }

    #[test]
    fn resident_weights_move_fewer_bytes() {
        let arch = presets::table2(8);
        let g = GemmWorkload::new(512, 4096, 4096, "ffn-up");
        let stream = execute(&gemm_band_program(&arch, &g, 0, 4, WeightResidency::HbmStream), 0);
        let resident = execute(&gemm_band_program(&arch, &g, 0, 4, WeightResidency::Resident), 0);
        // Streaming moves at least the weight matrix on top of activations.
        assert!(stream.hbm_bytes >= resident.hbm_bytes + EB * g.k * g.n);
        assert!(resident.makespan <= stream.makespan);
    }

    #[test]
    fn short_m_decode_gemm_still_works() {
        // Decode projections have m == 1: only band row 0 computes.
        let arch = presets::table2(8);
        let g = GemmWorkload::new(1, 4096, 4096, "decode-proj");
        let p = gemm_band_program(&arch, &g, 4, 8, WeightResidency::HbmStream);
        assert!(p.validate().is_ok());
        let st = execute(&p, 0);
        assert!(st.makespan > 0);
        // All tile-owned ops sit inside the band.
        for op in p.ops() {
            if op.tile != crate::sim::NO_TILE {
                let y = op.tile as usize / arch.mesh_x;
                assert!((4..8).contains(&y), "tile row {y} outside band");
            }
        }
    }
}
