//! SUMMA GEMM dataflow with NoC collectives (Fig. 5c).
//!
//! Beyond MHA, the paper shows that common GEMM kernels using the
//! collective-based SUMMA dataflow [25] also profit from the fabric
//! collectives. We implement classical SUMMA on the full `P × P` mesh:
//! the `C(i,j)` block lives on tile `(j, i)`; at panel step `k`, the
//! owning column's tiles row-multicast their `A(i,k)` panels and the
//! owning row's tiles column-multicast their `B(k,j)` panels, then every
//! tile runs a local GEMM accumulation. Panels are double-buffered so
//! loads and multicasts overlap the matrix engine.
//!
//! Large `N` is processed in column passes (`nc` columns per tile per
//! pass) chosen so A/B panels plus the C chunk fit in L1; A is re-streamed
//! once per pass, B and C move exactly once — mirroring how the paper's
//! I/O accounting works for GEMM.

use crate::arch::ArchConfig;
use crate::engines::{dma_hbm_time, matmul_cycles, SpatzOp};
use crate::hbm::HbmMap;
use crate::noc::{collective_time, CollectiveKind};
use crate::sim::{Component, OpId, Program};

/// A GEMM workload `C[M×N] = A[M×K] · B[K×N]` (FP16).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GemmWorkload {
    /// Output rows (M).
    pub m: u64,
    /// Inner/reduction dimension (K).
    pub k: u64,
    /// Output columns (N).
    pub n: u64,
    /// Display name used in reports and benches.
    pub label: String,
}

impl GemmWorkload {
    /// A GEMM of shape `M x K x N`.
    pub fn new(m: u64, k: u64, n: u64, label: impl Into<String>) -> Self {
        Self { m, k, n, label: label.into() }
    }

    /// `2 * M * K * N` multiply-accumulate FLOPs.
    pub fn flops(&self) -> u64 {
        2 * self.m * self.k * self.n
    }
}

const EB: u64 = 2; // FP16

/// Panel sizing: pick `kb` and `nc` (multiples of 16) maximizing the local
/// GEMM size under the L1 budget:
/// `2·(A: mb·kb·2(db) + B: kb·nc·2(db) + C: mb·nc)` bytes.
fn panel_sizes(l1_bytes: u64, mb: u64, nb: u64) -> (u64, u64) {
    let mut best = (16, 16);
    let mut best_vol = 0u64;
    let mut nc = 16;
    while nc <= nb.max(16) {
        let mut kb = 16;
        while kb <= 1024 {
            let bytes = EB * (2 * mb * kb + 2 * kb * nc + mb * nc);
            if bytes <= l1_bytes {
                let vol = mb * kb * nc;
                if vol > best_vol {
                    best_vol = vol;
                    best = (kb, nc);
                }
            }
            kb += 16;
        }
        nc += 16;
    }
    best
}

/// Build the SUMMA program on the full mesh.
pub fn summa_program(arch: &ArchConfig, gemm: &GemmWorkload) -> Program {
    let p = arch.mesh_x.min(arch.mesh_y) as u64;
    let mut prog = Program::new();
    let hbm_map = HbmMap::new(arch);
    let chan_res = prog.resources(hbm_map.total_channels());
    let g = p as usize;
    let redmule = prog.resources(g * g);
    let spatz = prog.resources(g * g);
    let row_bus = prog.resources(g);
    let col_bus = prog.resources(g);

    let mb = gemm.m.div_ceil(p);
    let nb = gemm.n.div_ceil(p);
    let (kb, nc) = panel_sizes(arch.tile.l1_bytes(), mb, nb);
    let n_passes = nb.div_ceil(nc);
    let k_steps = gemm.k.div_ceil(kb);
    let n_dest = p - 1;
    let local = |lx: usize, ly: usize| ly * g + lx;

    // Per-tile previous-gemm ids for double-buffer deps.
    let mut gemm_prev: Vec<Option<OpId>> = vec![None; g * g];
    let mut gemm_prev2: Vec<Option<OpId>> = vec![None; g * g];

    for pass in 0..n_passes {
        let nc_cur = (nb - pass * nc).min(nc);
        for step in 0..k_steps {
            let kb_cur = (gemm.k - step * kb).min(kb);
            let owner = (step % p) as usize;

            // A(i, k) panels: owner-column tiles load + row-multicast.
            let mut a_mc: Vec<OpId> = Vec::with_capacity(g);
            let a_bytes = mb * kb_cur * EB;
            for ly in 0..g {
                let ch = hbm_map.row_channel(owner, ly);
                let ta = dma_hbm_time(&arch.hbm, &arch.noc, a_bytes, ch.hops);
                let tl = local(owner, ly);
                let mut deps: Vec<OpId> = Vec::new();
                deps.extend(gemm_prev2[tl]);
                let load = prog.op(
                    chan_res[ch.index],
                    ta.occupancy,
                    ta.latency,
                    Component::HbmAccess,
                    arch.tile_id(owner, ly),
                    a_bytes,
                    &deps,
                );
                let mt = collective_time(&arch.noc, a_bytes, n_dest, CollectiveKind::Multicast);
                a_mc.push(prog.op(
                    row_bus[ly],
                    mt.occupancy,
                    mt.latency,
                    Component::Multicast,
                    arch.tile_id(owner, ly),
                    0,
                    &[load],
                ));
            }

            // B(k, j) panels: owner-row tiles load + column-multicast.
            let mut b_mc: Vec<OpId> = Vec::with_capacity(g);
            let b_bytes = kb_cur * nc_cur * EB;
            for lx in 0..g {
                let ch = hbm_map.col_channel(lx, owner);
                let tb = dma_hbm_time(&arch.hbm, &arch.noc, b_bytes, ch.hops);
                let tl = local(lx, owner);
                let mut deps: Vec<OpId> = Vec::new();
                deps.extend(gemm_prev2[tl]);
                let load = prog.op(
                    chan_res[ch.index],
                    tb.occupancy,
                    tb.latency,
                    Component::HbmAccess,
                    arch.tile_id(lx, owner),
                    b_bytes,
                    &deps,
                );
                let mt = collective_time(&arch.noc, b_bytes, n_dest, CollectiveKind::Multicast);
                b_mc.push(prog.op(
                    col_bus[lx],
                    mt.occupancy,
                    mt.latency,
                    Component::Multicast,
                    arch.tile_id(lx, owner),
                    0,
                    &[load],
                ));
            }

            // Local GEMM accumulation on every tile.
            for ly in 0..g {
                for lx in 0..g {
                    let tl = local(lx, ly);
                    let mut deps = vec![a_mc[ly], b_mc[lx]];
                    deps.extend(gemm_prev[tl]);
                    let op = prog.op(
                        redmule[tl],
                        matmul_cycles(&arch.tile, mb, kb_cur, nc_cur),
                        0,
                        Component::RedMule,
                        arch.tile_id(lx, ly),
                        0,
                        &deps,
                    );
                    gemm_prev2[tl] = gemm_prev[tl];
                    gemm_prev[tl] = Some(op);
                }
            }
        }

        // Store the pass's C chunk from every tile (address-interleaved).
        let c_bytes = mb * nc_cur * EB;
        let n_chan = hbm_map.total_channels();
        for ly in 0..g {
            for lx in 0..g {
                let tl = local(lx, ly);
                // Small epilogue on the vector engine (cast/accumulate).
                let ep = prog.op(
                    spatz[tl],
                    SpatzOp::Scale { elems: mb * nc_cur }.cycles(&arch.tile),
                    0,
                    Component::Spatz,
                    arch.tile_id(lx, ly),
                    0,
                    &[gemm_prev[tl].expect("k loop ran")],
                );
                let chan = (tl + pass as usize) % n_chan;
                let tc = dma_hbm_time(&arch.hbm, &arch.noc, c_bytes, (lx + ly) as u64 / 2 + 1);
                let st = prog.op(
                    chan_res[chan],
                    tc.occupancy,
                    tc.latency,
                    Component::HbmAccess,
                    arch.tile_id(lx, ly),
                    c_bytes,
                    &[ep],
                );
                // C-buffer reuse across passes: next pass's first gemm on
                // this tile must wait for the store.
                gemm_prev[tl] = Some(st);
                gemm_prev2[tl] = Some(st);
            }
        }
    }

    prog.flops = gemm.flops();
    prog
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::table1;
    use crate::sim::execute;

    #[test]
    fn builds_and_validates() {
        let arch = table1();
        let g = GemmWorkload::new(4096, 1024, 4096, "test");
        let p = summa_program(&arch, &g);
        assert!(p.validate().is_ok());
        assert_eq!(p.flops, g.flops());
    }

    #[test]
    fn panel_sizes_fit_l1() {
        let arch = table1();
        let (kb, nc) = panel_sizes(arch.tile.l1_bytes(), 128, 896);
        assert!(kb >= 16 && nc >= 16);
        assert!(EB * (2 * 128 * kb + 2 * kb * nc + 128 * nc) <= arch.tile.l1_bytes());
    }

    #[test]
    fn large_gemm_high_utilization() {
        // Fig. 5c: SUMMA on BestArch reaches >80% utilization on the
        // LLaMA-70B FFN GEMMs.
        let arch = table1();
        let g = GemmWorkload::new(4096, 8192, 28672, "ffn-up");
        let st = execute(&summa_program(&arch, &g), 0);
        let u = st.compute_utilization(arch.peak_flops_per_cycle());
        assert!(u > 0.7, "SUMMA utilization {u:.3}");
    }

    #[test]
    fn traffic_accounting_reasonable() {
        let arch = table1();
        let g = GemmWorkload::new(4096, 8192, 8192, "proj");
        let st = execute(&summa_program(&arch, &g), 0);
        // Lower bound: A + B + C moved at least once.
        let compulsory = EB * (g.m * g.k + g.k * g.n + g.m * g.n);
        assert!(st.hbm_bytes >= compulsory);
        // Upper bound: A re-streamed once per pass, small factor.
        assert!(st.hbm_bytes < 8 * compulsory, "{} vs {}", st.hbm_bytes, compulsory);
    }
}
