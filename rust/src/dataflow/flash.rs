//! FlashAttention dataflows on the tile-based architecture (Algorithm 1).
//!
//! The MHA workload is partitioned over batch × KV-heads × share-chunks ×
//! output-sequence blocks; blocks are distributed round-robin over tiles,
//! which process them independently (no inter-tile communication, no
//! cross-tile reuse — the defining property the paper contrasts
//! FlatAttention against). For GQA/MQA (`kv_heads < heads`) a block stacks
//! the query rows of a whole KV group (`share` heads), so each K/V block
//! is loaded from HBM once per group instead of once per query head;
//! decode blocks hold a single query row against the full cache (see
//! `crate::dataflow` § Workload model). Chunked prefill (`kv_prefix`)
//! rides the same rectangular geometry — the chunk's rows simply sit at
//! the end of a longer cache — and sliding windows skip the K/V blocks
//! below every row's window start and prefix-mask the straddling block
//! (the mirror of the causal suffix rule; `window >= kv_len` reproduces
//! dense causal emission op for op). For composed serving batches
//! ([`flash_batch_program_in`]) K/V loads are placed page by page through
//! a [`PageMap`] instead of the address-interleaved rotation.
//!
//! * **FA-2** (synchronous): one block in flight per tile, Kᵀ/V
//!   double-buffered so the next load overlaps the current compute.
//! * **FA-3** (asynchronous): two blocks (different heads) in flight per
//!   tile; while the matrix engine works on one head, the DMA and vector
//!   engine process the other (§III-C). Each stream's K/V is
//!   single-buffered — the second stream provides the overlap. FA-3 pays a
//!   per-iteration scheduling overhead on the scalar core (§V-A: "FA-3
//!   introduces an overhead for more complex scheduling").
//!
//! §Perf: within a tile stream, every block of the same shape `(m_r,
//! t_c_eff)` — i.e. every full-height block of a head — emits an identical
//! subgraph up to (a) the previous block's completion dependency and
//! (b) the K/V channel rotation `(tid + blk_no + j) mod n_chan`. The first
//! instance is built normally and registered as a template; repetitions
//! are stamped with [`Program::stamp_range`] and the K/V loads' channel
//! resource + NoC latency patched for the rotation (DMA occupancy depends
//! only on the byte count, so it copies verbatim). Stamped and naive
//! builds are op-for-op identical
//! (`tests::stamped_build_is_identical_to_naive_build`).
//!
//! §Fold: with symmetry folding enabled (synchronous schedule only), every
//! tile stream except the representative (tile 0, the breakdown tile)
//! keeps its HBM-channel ops verbatim but collapses each inner iteration's
//! private chain `QKᵀ → softmax₁ → softmax₂ → rescale → P·V` (plus the
//! final normalize) into one delay op on the tile's matrix engine. The
//! chain runs on engines private to the tile and is never
//! resource-blocked, so its completion is exactly `max(deps) + Σ
//! occupancy` — the delay op reproduces every kept op's issue time, hence
//! channel contention, makespan and `RunStats`, bit for bit (see
//! `crate::dataflow` docs and `tests/fold_differential.rs`).
//!
//! §Shard: under the event-loop partition `Program::seal` derives (see
//! `crate::sim`'s sharding essay), each tile's stream — engines private,
//! both async streams included — becomes one private shard, and every
//! HBM-channel op lands in the shared shard, so an unfolded grid exposes
//! ~`mesh_x × mesh_y`-way parallelism to `sim::execute_parallel`; in
//! composed serving batches each band tile shards the same way per
//! request.

use crate::arch::ArchConfig;
use crate::engines::{dma_hbm_time, matmul_cycles, SpatzOp};
use crate::hbm::{HbmMap, PageMap};
use crate::noc::Topology;
use crate::sim::program::NO_TILE;
use crate::sim::{Component, FoldStats, OpId, Program, ResourceId};

use super::opt_deps;
use super::tiling::{causal_mask_from, window_block_range, FlashTiling};
use super::{DbEdit, Workload};

/// Scalar-core scheduling overhead per inner iteration for the
/// asynchronous schedule (cycles).
pub const FA3_SCHED_OVERHEAD: u64 = 60;

struct TileCtx {
    redmule: ResourceId,
    spatz: ResourceId,
    scalar: ResourceId,
}

/// Per-shape engine costs, memoized per `(m_r, m_c)` (§Perf: the seed
/// recomputed these for every inner iteration of every block of every
/// tile; they only depend on the block shape).
#[derive(Clone, Copy)]
struct ShapeCosts {
    qk: u64,
    scale: u64,
    sm1_base: u64,
    sm2: u64,
    pv: u64,
}

fn shape_costs(arch: &ArchConfig, m_r: u64, m_c: u64, d: u64) -> ShapeCosts {
    let t = &arch.tile;
    let scale = SpatzOp::Scale { elems: m_r * m_c }.cycles(t);
    ShapeCosts {
        qk: matmul_cycles(t, m_r, d, m_c),
        scale,
        sm1_base: scale
            + SpatzOp::RowMax { rows: m_r, cols: m_c }.cycles(t)
            + SpatzOp::StatsUpdate { rows: m_r }.cycles(t),
        sm2: SpatzOp::Exp { elems: m_r * m_c }.cycles(t)
            + SpatzOp::RowSum { rows: m_r, cols: m_c }.cycles(t)
            + SpatzOp::StatsUpdate { rows: m_r }.cycles(t),
        pv: matmul_cycles(t, m_r, m_c, d),
    }
}

/// A registered block template within one tile stream. Two blocks emit
/// identical subgraphs iff their stacked row count, effective K/V block
/// range and causal/window mask positions agree — with square MHA blocks
/// `mask_from == t_c_eff - 1` and `(j_lo, win_until) == (0, 0)` always,
/// so the key space matches the historical `(m_r, t_c_eff)` one; the
/// extra fields only split classes for the rectangular serving and
/// sliding-window geometries where they must.
struct BlockTemplate {
    m_r: u64,
    t_c_eff: u64,
    mask_from: u64,
    j_lo: u64,
    win_until: u64,
    base: u32,
    len: u32,
    /// Offsets (relative to `base`) of the K/V load ops, whose channel
    /// resource rotates with the block number.
    kv_ops: Vec<u32>,
    blk_no: usize,
    /// Fold accounting of the block (zero when built unfolded); re-applied
    /// once per stamped instance.
    fold_delta: FoldStats,
}

/// Build the FlashAttention program (`asynchronous` = FA-3 schedule).
pub fn flash_program(arch: &ArchConfig, wl: &Workload, asynchronous: bool) -> Program {
    flash_program_ext(arch, wl, asynchronous, true)
}

/// Extended builder: `double_buffer = false` disables K/V prefetching (the
/// Fig. 3 "*implementations without double buffering" ablation).
pub fn flash_program_ext(
    arch: &ArchConfig,
    wl: &Workload,
    asynchronous: bool,
    double_buffer: bool,
) -> Program {
    flash_program_ext_in(Program::new(), arch, wl, asynchronous, double_buffer)
}

/// Arena-aware builder: constructs into `prog` (typically taken from a
/// [`crate::sim::ProgramArena`]) and seals the result.
pub(crate) fn flash_program_ext_in(
    prog: Program,
    arch: &ArchConfig,
    wl: &Workload,
    asynchronous: bool,
    double_buffer: bool,
) -> Program {
    flash_build(prog, arch, wl, asynchronous, double_buffer, None)
}

/// Build the K/V double-buffering ablation pair `(with_db, without_db)`
/// in one builder pass (see [`super::double_buffer_programs`]): the
/// db=true program is emitted naively (stamping off — the variant
/// derivation journals every K/V load) while recording each load's
/// prefetch-dependency choice; the db=false variant is derived by
/// retargeting exactly those dependencies.
pub(crate) fn flash_program_db_pair(arch: &ArchConfig, wl: &Workload) -> (Program, Program) {
    let mut edits: Vec<DbEdit> = Vec::new();
    let db = flash_build(Program::new(), arch, wl, false, true, Some(&mut edits));
    let nodb = super::derive_double_buffer_variant(&db, &edits, false);
    (db, nodb)
}

fn flash_build(
    mut prog: Program,
    arch: &ArchConfig,
    wl: &Workload,
    asynchronous: bool,
    double_buffer: bool,
    mut edits: Option<&mut Vec<DbEdit>>,
) -> Program {
    let topo = Topology::new(arch.mesh_x, arch.mesh_y);
    let hbm_map = HbmMap::new(arch);
    let n_tiles = topo.num_tiles();
    let n_chan = hbm_map.total_channels();

    // HBM channels are allocated first so `ResourceId(c)` == channel `c`
    // inside `build_stream` (asserted here).
    let chan_res = prog.resources(n_chan);
    debug_assert!(chan_res.first().is_none_or(|r| r.0 == 0));
    let _ = chan_res;
    let tiles: Vec<TileCtx> = (0..n_tiles)
        .map(|_| TileCtx {
            redmule: prog.resource(),
            spatz: prog.resource(),
            scalar: prog.resource(),
        })
        .collect();

    let tiling = FlashTiling::resolve(&arch.tile, wl, asynchronous);
    let eb = Workload::BYTES_PER_ELEM;

    // Deal blocks round-robin over tiles. Each block stacks `share_c`
    // query heads' rows against one K/V residency; dense MHA degenerates
    // to the historical (b, h, i) enumeration (share_c == 1, one chunk
    // per head).
    let tile_blocks = super::deal_blocks(wl, tiling.share, tiling.chunks, tiling.t_r, n_tiles);

    // §Fold: tile 0 is the representative (breakdown) stream and always
    // builds unfolded; the asynchronous schedule interleaves two streams
    // per engine (real arbitration) and never folds.
    let folding = super::symmetry_folding() && !asynchronous;
    // Edit-journaling builds emit naively: the journal must hold every
    // K/V load, and stamped-vs-naive equivalence makes the derived
    // variants identical to stamped fresh builds anyway.
    let stamping = super::template_stamping() && edits.is_none();

    let mut hops_by_chan: Vec<u64> = vec![0; n_chan];
    for tid in 0..n_tiles {
        let (x, y) = topo.coords(tid as u32);
        let blocks = &tile_blocks[tid];
        if blocks.is_empty() {
            continue;
        }
        for (c, h) in hops_by_chan.iter_mut().enumerate() {
            *h = hbm_map.channel_hops(x, y, c).max(1);
        }
        let row_ch = hbm_map.row_channel(x, y);
        if asynchronous {
            // Two interleaved streams sharing the tile's engines.
            let (even, odd): (Vec<_>, Vec<_>) =
                blocks.iter().enumerate().partition(|(i, _)| i % 2 == 0);
            for stream in [even, odd] {
                let list: Vec<_> = stream.into_iter().map(|(_, b)| *b).collect();
                build_stream(
                    &mut prog, arch, wl, row_ch, &hops_by_chan, &tiles[tid], tid as u32, &list,
                    &tiling, eb, true, double_buffer, false, stamping, None,
                    edits.as_deref_mut(),
                );
            }
        } else {
            build_stream(
                &mut prog, arch, wl, row_ch, &hops_by_chan, &tiles[tid], tid as u32, blocks,
                &tiling, eb, false, double_buffer, folding && tid != 0, stamping, None,
                edits.as_deref_mut(),
            );
        }
    }

    prog.flops = wl.matmul_flops();
    prog.seal();
    prog
}

/// One request's share of a composed mixed batch (see `crate::scheduler`):
/// a serving workload emitted onto a horizontal band of tile rows, with
/// its KV cache channel-placed page by page.
pub(crate) struct FlashBatchEntry<'a> {
    /// This request's serving workload slice.
    pub wl: Workload,
    /// KV-cache page table (page -> HBM channel).
    pub pages: &'a PageMap,
    /// Tile-row band `[y0, y1)` this entry's blocks are dealt over.
    pub y0: usize,
    /// Exclusive band end (see `y0`).
    pub y1: usize,
}

/// Compose one FlashAttention program holding every entry's op stream:
/// HBM channels and all tile engines are allocated once (shared — channel
/// contention across requests is real), each entry's blocks are dealt
/// round-robin over its own tile band only, and K/V loads are split into
/// per-page-segment channel transactions through the entry's [`PageMap`].
/// Per entry, the band's first tile is the fold representative, so the
/// fold/stamp exactness argument applies per request. Template stamping
/// applies to paged streams too: a block's page segments depend only on
/// its K/V token range, which the template key pins, so stamped paged
/// instances are verbatim copies (no channel patch needed). Returns the
/// *unsealed* program plus each entry's contiguous op span — the caller
/// (`scheduler::batch`) seals, or cost-patches a previously sealed step
/// program instead (§Incremental in `scheduler`).
pub(crate) fn flash_batch_program_in(
    mut prog: Program,
    arch: &ArchConfig,
    entries: &[FlashBatchEntry<'_>],
    asynchronous: bool,
) -> (Program, Vec<(usize, usize)>) {
    let topo = Topology::new(arch.mesh_x, arch.mesh_y);
    let hbm_map = HbmMap::new(arch);
    let n_tiles = topo.num_tiles();
    let n_chan = hbm_map.total_channels();
    let chan_res = prog.resources(n_chan);
    debug_assert!(chan_res.first().is_none_or(|r| r.0 == 0));
    let _ = chan_res;
    let tiles: Vec<TileCtx> = (0..n_tiles)
        .map(|_| TileCtx {
            redmule: prog.resource(),
            spatz: prog.resource(),
            scalar: prog.resource(),
        })
        .collect();
    let eb = Workload::BYTES_PER_ELEM;
    let folding = super::symmetry_folding() && !asynchronous;
    let stamping = super::template_stamping();

    let mut hops_by_chan: Vec<u64> = vec![0; n_chan];
    let mut spans: Vec<(usize, usize)> = Vec::with_capacity(entries.len());
    let mut flops = 0u64;
    for e in entries {
        let begin = prog.num_ops();
        let wl = &e.wl;
        debug_assert!(
            e.pages.tokens_capacity() >= wl.kv_len(),
            "page map must cover the KV cache"
        );
        let tiling = FlashTiling::resolve(&arch.tile, wl, asynchronous);
        let band: Vec<usize> = (e.y0..e.y1)
            .flat_map(|y| (0..arch.mesh_x).map(move |x| y * arch.mesh_x + x))
            .collect();
        let rep = band[0] as u32;
        let tile_blocks =
            super::deal_blocks(wl, tiling.share, tiling.chunks, tiling.t_r, band.len());
        for (bi, &tid) in band.iter().enumerate() {
            let blocks = &tile_blocks[bi];
            if blocks.is_empty() {
                continue;
            }
            let (x, y) = topo.coords(tid as u32);
            for (c, h) in hops_by_chan.iter_mut().enumerate() {
                *h = hbm_map.channel_hops(x, y, c).max(1);
            }
            let row_ch = hbm_map.row_channel(x, y);
            if asynchronous {
                let (even, odd): (Vec<_>, Vec<_>) =
                    blocks.iter().enumerate().partition(|(i, _)| i % 2 == 0);
                for stream in [even, odd] {
                    let list: Vec<_> = stream.into_iter().map(|(_, b)| *b).collect();
                    build_stream(
                        &mut prog, arch, wl, row_ch, &hops_by_chan, &tiles[tid], tid as u32,
                        &list, &tiling, eb, true, true, false, stamping, Some(e.pages), None,
                    );
                }
            } else {
                build_stream(
                    &mut prog, arch, wl, row_ch, &hops_by_chan, &tiles[tid], tid as u32, blocks,
                    &tiling, eb, false, true, folding && tid as u32 != rep, stamping,
                    Some(e.pages), None,
                );
            }
        }
        flops += wl.matmul_flops();
        spans.push((begin, prog.num_ops()));
    }

    prog.flops = flops;
    (prog, spans)
}

/// Emit one serial stream of blocks for a tile. Deps keep the stream
/// internally ordered while engines arbitrate across streams. With `fold`
/// set, private compute chains collapse into delay ops (§Fold) while the
/// channel op stream stays verbatim. With `pages` set, K/V loads split
/// into per-page-segment transactions on the page table's channels; the
/// segments depend only on the block's token range, which the template
/// key determines, so stamped paged instances copy verbatim and the
/// rotation patch never fires (`kv_ops` stays empty). `edits` journals
/// every K/V load's prefetch dependency for the double-buffer variant
/// derivation.
#[allow(clippy::too_many_arguments)]
fn build_stream(
    prog: &mut Program,
    arch: &ArchConfig,
    wl: &Workload,
    row_ch: crate::hbm::ChannelRef,
    hops_by_chan: &[u64],
    ctx: &TileCtx,
    tid: u32,
    blocks: &[(u64, u64)],
    tiling: &FlashTiling,
    eb: u64,
    asynchronous: bool,
    double_buffer: bool,
    fold: bool,
    stamping: bool,
    pages: Option<&PageMap>,
    mut edits: Option<&mut Vec<DbEdit>>,
) {
    debug_assert!(!(fold && asynchronous), "async streams never fold");
    let chan_base = |c: usize| ResourceId(c as u32);
    let n_chan = hops_by_chan.len();
    let stamping = stamping && edits.is_none();
    let d = wl.head_dim;
    let (q_len, kv_len) = (wl.q_len(), wl.kv_len());
    let (b_r, b_c, t_c) = (tiling.b_r, tiling.b_c, tiling.t_c);
    // Decode rows (and chunked-prefill queries) sit at the *end* of the
    // KV cache (single-shot prefill: offset 0).
    let kv_off = kv_len - q_len;
    // DMA latency decomposition (mirrors `dma_hbm_time`): occupancy is a
    // function of bytes alone, latency adds per-hop routing.
    let kv_lat_base = arch.hbm.access_latency + 2 * arch.noc.inject_latency;
    let router = arch.noc.router_latency;

    if fold {
        prog.fold.streams += 1;
    }
    let mut prev_block_end: Option<OpId> = None;
    let mut templates: Vec<BlockTemplate> = Vec::new();
    // Scratch reused across iterations: paged K/V fans one block's load
    // into per-page segments, so dependency lists are no longer
    // statically bounded.
    let mut dep_buf: Vec<OpId> = Vec::new();
    let mut seg_buf: Vec<(u32, u64)> = Vec::new();
    let mut kv_loads: Vec<OpId> = Vec::new();

    for (blk_no, &(share_c, i)) in blocks.iter().enumerate() {
        // Per-head row-block height (last block may be partial); the
        // block's working rows stack `share_c` query heads of a KV group.
        let qr_i = (q_len - i * b_r).min(b_r);
        let m_r = share_c * qr_i;
        // Causal: K/V blocks strictly above the row range are skipped,
        // blocks straddling the diagonal are masked (decode rows see the
        // whole cache: `t_c_eff == t_c`, no mask).
        let row_start = kv_off + i * b_r;
        let t_c_eff = if wl.causal { (row_start + qr_i).div_ceil(b_c) } else { t_c };
        let mask_from = if wl.causal {
            causal_mask_from(row_start, b_c, kv_len, t_c_eff)
        } else {
            t_c_eff
        };
        // Sliding window: blocks wholly below every row's window start are
        // skipped, blocks straddling a window start pay the prefix mask.
        // `(0, 0)` without a window — dense emission is untouched.
        let (j_lo, win_until) =
            window_block_range(row_start, row_start + qr_i, wl.window, b_c, t_c_eff);

        if stamping {
            if let (Some(prev), Some(t)) = (
                prev_block_end,
                templates.iter().find(|t| {
                    t.m_r == m_r
                        && t.t_c_eff == t_c_eff
                        && t.mask_from == mask_from
                        && t.j_lo == j_lo
                        && t.win_until == win_until
                }),
            ) {
                let new_base = prog.stamp_range(t.base, t.len, prev);
                // Rotate the stamped K/V loads to this block's channels
                // and re-derive their hop-dependent latency.
                let rot = blk_no - t.blk_no;
                for &off in &t.kv_ops {
                    let op = &mut prog.ops[(new_base + off) as usize];
                    let chan = (op.resource.0 as usize + rot) % n_chan;
                    op.resource = chan_base(chan);
                    op.latency = kv_lat_base + hops_by_chan[chan] * router;
                }
                let fold_delta = t.fold_delta;
                prog.fold.accumulate(&fold_delta);
                prev_block_end = Some(OpId(new_base + t.len - 1));
                continue;
            }
        }

        let block_base = prog.num_ops() as u32;
        let fold_before = prog.fold;
        let gated = prev_block_end.is_some();
        let start_dep = prev_block_end;
        let mut kv_ops: Vec<u32> = Vec::with_capacity(t_c_eff as usize);

        // Load Q_i through the tile's row channel (west edge).
        let q_bytes = m_r * d * eb; // stacked rows: one load per head chunk
        let tq = dma_hbm_time(&arch.hbm, &arch.noc, q_bytes, row_ch.hops);
        let mut dbuf = [OpId(0); 2];
        let nd = opt_deps(&mut dbuf, start_dep, None);
        let load_q = prog.op(
            chan_base(row_ch.index),
            tq.occupancy,
            tq.latency,
            Component::HbmAccess,
            tid,
            q_bytes,
            &dbuf[..nd],
        );

        let rs_cycles = SpatzOp::Rescale { rows: m_r, elems: m_r * d }.cycles(&arch.tile);
        let norm_cycles = SpatzOp::Normalize { rows: m_r, elems: m_r * d }.cycles(&arch.tile);
        let mut pv: Vec<OpId> = Vec::with_capacity((t_c_eff - j_lo) as usize);
        let mut last_stage: Option<OpId> = None;
        let mut costs_memo: Option<(u64, ShapeCosts)> = None;

        for j in j_lo..t_c_eff {
            let m_c = (kv_len - j * b_c).min(b_c);
            let costs = match costs_memo {
                Some((key, c)) if key == m_c => c,
                _ => {
                    let c = shape_costs(arch, m_r, m_c, d);
                    costs_memo = Some((m_c, c));
                    c
                }
            };
            // Buffering: double-buffered (dep on pv[j-2]) for the sync
            // schedule, single-buffered (dep on pv[j-1]) for async streams.
            let jr = j - j_lo;
            let db_dep = jr.checked_sub(2).map(|k| pv[k as usize]);
            let nodb_dep = jr.checked_sub(1).map(|k| pv[k as usize]);
            let buf_dep = if asynchronous || !double_buffer { nodb_dep } else { db_dep };

            kv_loads.clear();
            match pages {
                None => {
                    // K/V blocks are address-interleaved across channels
                    // (no spatial affinity for per-tile independent
                    // blocks).
                    let kv_chan = (tid as usize + blk_no + j as usize) % n_chan;
                    let kv_hops = hops_by_chan[kv_chan];
                    let kv_bytes = 2 * m_c * d * eb;
                    let tkv = dma_hbm_time(&arch.hbm, &arch.noc, kv_bytes, kv_hops);
                    let mut dbuf = [OpId(0); 2];
                    let nd = opt_deps(&mut dbuf, start_dep, buf_dep);
                    let lkv = prog.op(
                        chan_base(kv_chan),
                        tkv.occupancy,
                        tkv.latency,
                        Component::HbmAccess,
                        tid,
                        kv_bytes,
                        &dbuf[..nd],
                    );
                    kv_ops.push(lkv.0 - block_base);
                    kv_loads.push(lkv);
                    if let Some(ed) = edits.as_deref_mut() {
                        ed.push(DbEdit {
                            op: lkv.0,
                            base: start_dep.map(|o| o.0),
                            db: db_dep.map(|o| o.0),
                            nodb: nodb_dep.map(|o| o.0),
                        });
                    }
                }
                Some(pm) => {
                    // Paged KV cache: one channel transaction per page
                    // segment of the block's token range [j·b_c, +m_c).
                    pm.segments(j * b_c, m_c, 2 * d * eb, &mut seg_buf);
                    for &(chan, bytes) in &seg_buf {
                        let tkv =
                            dma_hbm_time(&arch.hbm, &arch.noc, bytes, hops_by_chan[chan as usize]);
                        let mut dbuf = [OpId(0); 2];
                        let nd = opt_deps(&mut dbuf, start_dep, buf_dep);
                        let lkv = prog.op(
                            chan_base(chan as usize),
                            tkv.occupancy,
                            tkv.latency,
                            Component::HbmAccess,
                            tid,
                            bytes,
                            &dbuf[..nd],
                        );
                        kv_loads.push(lkv);
                        if let Some(ed) = edits.as_deref_mut() {
                            ed.push(DbEdit {
                                op: lkv.0,
                                base: start_dep.map(|o| o.0),
                                db: db_dep.map(|o| o.0),
                                nodb: nodb_dep.map(|o| o.0),
                            });
                        }
                    }
                }
            }

            // Diagonal-straddling blocks of causal workloads and window-
            // straddling blocks pay the mask on the vector engine.
            let masked = j >= mask_from || j < win_until;

            if fold {
                // §Fold: the private chain qk → sm1 → sm2 → rs → pv
                // (+ final normalize) never blocks on the tile's engines,
                // so one delay op of the summed occupancy completes at
                // exactly the chain's completion time.
                let mask_cycles = if masked { costs.scale } else { 0 };
                let spatz_occ = mask_cycles + costs.sm1_base + costs.sm2 + rs_cycles;
                let last = j + 1 == t_c_eff;
                let spatz_occ = spatz_occ + if last { norm_cycles } else { 0 };
                dep_buf.clear();
                dep_buf.push(load_q);
                dep_buf.extend_from_slice(&kv_loads);
                if let Some(prev) = last_stage {
                    dep_buf.push(prev);
                }
                let delay = prog.op(
                    ctx.redmule,
                    costs.qk + costs.pv + spatz_occ,
                    0,
                    Component::Other,
                    NO_TILE,
                    0,
                    &dep_buf,
                );
                prog.fold.ops += if last { 5 } else { 4 };
                prog.fold.redmule_busy += costs.qk + costs.pv;
                prog.fold.spatz_busy += spatz_occ;
                pv.push(delay);
                last_stage = Some(delay);
                continue;
            }

            // Scalar-core scheduling overhead (FA-3 only).
            let sched = if asynchronous {
                Some(prog.op(
                    ctx.scalar,
                    FA3_SCHED_OVERHEAD,
                    0,
                    Component::Other,
                    tid,
                    0,
                    last_stage.as_slice(),
                ))
            } else {
                None
            };

            // S = Q_i · K_jᵀ on the matrix engine.
            dep_buf.clear();
            dep_buf.push(load_q);
            dep_buf.extend_from_slice(&kv_loads);
            if let Some(ls) = last_stage {
                dep_buf.push(ls);
            }
            if let Some(s) = sched {
                dep_buf.push(s);
            }
            let qk = prog.op(
                ctx.redmule,
                costs.qk,
                0,
                Component::RedMule,
                tid,
                0,
                &dep_buf,
            );

            // Softmax phase 1: scale by 1/√D, row maxima, running max
            // (+ the triangular/window mask where the block straddles).
            let mask_cycles = if masked { costs.scale } else { 0 };
            let sm1 = prog.op(
                ctx.spatz,
                mask_cycles + costs.sm1_base,
                0,
                Component::Spatz,
                tid,
                0,
                &[qk],
            );

            // Softmax phase 2: exp, row sums, running denominator.
            let sm2 = prog.op(ctx.spatz, costs.sm2, 0, Component::Spatz, tid, 0, &[sm1]);

            // Rescale the O accumulator by e^{m_old - m_new}.
            let rs = prog.op(ctx.spatz, rs_cycles, 0, Component::Spatz, tid, 0, &[sm2]);

            // O += P̃ · V_j.
            let pvop = prog.op(ctx.redmule, costs.pv, 0, Component::RedMule, tid, 0, &[rs]);
            pv.push(pvop);
            last_stage = Some(pvop);
        }

        // Final normalization by diag(l)^{-1} and store of O_i. Folded
        // streams absorbed the normalize into the last delay op.
        let last_stage_op = *pv.last().expect("at least one inner iteration");
        let pre_store = if fold {
            last_stage_op
        } else {
            prog.op(ctx.spatz, norm_cycles, 0, Component::Spatz, tid, 0, &[last_stage_op])
        };
        let o_bytes = m_r * d * eb;
        let to = dma_hbm_time(&arch.hbm, &arch.noc, o_bytes, row_ch.hops);
        let store = prog.op(
            chan_base(row_ch.index),
            to.occupancy,
            to.latency,
            Component::HbmAccess,
            tid,
            o_bytes,
            &[pre_store],
        );
        if stamping && gated {
            templates.push(BlockTemplate {
                m_r,
                t_c_eff,
                mask_from,
                j_lo,
                win_until,
                base: block_base,
                len: prog.num_ops() as u32 - block_base,
                kv_ops,
                blk_no,
                fold_delta: prog.fold.delta_since(&fold_before),
            });
        }
        prev_block_end = Some(store);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::table1;
    use crate::dataflow::{
        assert_programs_equal, flash_block_size, set_symmetry_folding, set_template_stamping,
    };
    use crate::sim::execute;

    fn small_wl() -> Workload {
        Workload::new(1024, 128, 4, 1)
    }

    #[test]
    fn program_builds_and_validates() {
        let arch = table1();
        let p = flash_program(&arch, &small_wl(), false);
        assert!(p.validate().is_ok());
        assert!(p.num_ops() > 0);
        assert_eq!(p.flops, small_wl().matmul_flops());
        assert!(p.is_sealed());
    }

    #[test]
    fn stamped_build_is_identical_to_naive_build() {
        // Stamped repetitions must reproduce the naive emission exactly,
        // including the per-block K/V channel rotation. The 8×8 mesh with
        // many heads gives every tile stream several same-shape blocks
        // (≥3, so the template registered at the second block is stamped).
        // Runs under both folding modes: stamping must reproduce the
        // collapsed emission (incl. the fold accounting) just as exactly.
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let arch = crate::arch::presets::table2(8);
        for folding in [true, false] {
            set_symmetry_folding(folding);
            for (wl, asyn) in [
                (Workload::new(1024, 128, 192, 2), false),
                (Workload::new(1024, 128, 192, 2), true),
                (Workload::new(2048, 64, 96, 1).with_causal(true), false),
                (Workload::new(1024, 128, 192, 2).with_kv_heads(48), false),
                (Workload::new(1024, 64, 96, 1).with_kv_heads(24).with_causal(true), false),
                (Workload::new(2048, 128, 192, 2).with_kv_heads(48).decode(), true),
            ] {
                let stamped = flash_program(&arch, &wl, asyn);
                set_template_stamping(false);
                let naive = flash_program(&arch, &wl, asyn);
                set_template_stamping(true);
                assert_programs_equal(&stamped, &naive);
            }
        }
        set_symmetry_folding(true);
    }

    #[test]
    fn folded_build_executes_bit_identically() {
        // §Fold exactness on the synchronous schedule: identical RunStats
        // (makespan, breakdown, traffic, busy totals, op counts) from the
        // folded and unfolded builds.
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let arch = crate::arch::presets::table2(8);
        for wl in [
            Workload::new(1024, 128, 96, 1),
            Workload::new(1536, 64, 48, 1).with_causal(true),
            Workload::new(1024, 128, 96, 1).with_kv_heads(24),
            Workload::new(2048, 64, 96, 1).with_kv_heads(12).decode(),
        ] {
            set_symmetry_folding(true);
            let folded = flash_program(&arch, &wl, false);
            set_symmetry_folding(false);
            let unfolded = flash_program(&arch, &wl, false);
            set_symmetry_folding(true);
            assert!(folded.fold.streams > 0, "folding should engage");
            assert_eq!(unfolded.fold.streams, 0);
            assert_eq!(
                folded.num_ops() as u64 + folded.fold.ops,
                unfolded.num_ops() as u64,
                "op conservation"
            );
            assert_eq!(execute(&folded, 0), execute(&unfolded, 0), "{wl:?}");
        }
    }

    #[test]
    fn executes_and_accounts_traffic() {
        let arch = table1();
        let wl = small_wl();
        let p = flash_program(&arch, &wl, false);
        let st = execute(&p, 0);
        assert!(st.makespan > 0);
        // Traffic = Q + O once, K/V once per row block:
        // (2 + 2·T_r·(T_c terms…)) — at least compulsory, at most
        // compulsory × (1 + T_c).
        assert!(st.hbm_bytes >= wl.compulsory_bytes());
        let m = flash_block_size(&arch.tile, wl.head_dim, false) as f64;
        let expected = wl.compulsory_bytes() as f64 / 2.0 * (1.0 + wl.seq as f64 / m);
        let ratio = st.hbm_bytes as f64 / expected;
        assert!((0.8..1.2).contains(&ratio), "traffic ratio {ratio}");
    }

    #[test]
    fn fa2_is_memory_bound_on_table1() {
        // §V-A: FlashAttention saturates HBM bandwidth (up to ~80% avg)
        // and compute utilization stays low.
        let arch = table1();
        let wl = Workload::new(4096, 128, 32, 2);
        let st = execute(&flash_program(&arch, &wl, false), 0);
        let bw = st.hbm_bw_utilization(arch.hbm.peak_bytes_per_cycle());
        let cu = st.compute_utilization(arch.peak_flops_per_cycle());
        assert!(bw > 0.6, "HBM BW utilization {bw:.2} should approach saturation");
        assert!(cu < 0.4, "compute utilization {cu:.2} should be memory-bound");
    }

    #[test]
    fn decode_traffic_is_compulsory_and_kv_scales_with_kv_heads() {
        // Decode has a single row block (T_r = 1), so every K/V byte is
        // read exactly once per KV head per share-chunk: with the whole
        // group stacked (chunks == 1) the modeled traffic is *exactly*
        // compulsory, and the K/V share scales by kv_heads/heads vs MHA.
        let arch = table1();
        let base = Workload::new(4096, 128, 32, 2).decode();
        let qo = 2 * 2 * 32 * 128 * Workload::BYTES_PER_ELEM; // B·H·D reads + writes
        let mut kv_bytes = Vec::new();
        for kv_heads in [32u64, 8, 1] {
            let wl = base.with_kv_heads(kv_heads);
            let st = execute(&flash_program(&arch, &wl, false), 0);
            assert_eq!(st.hbm_bytes, wl.compulsory_bytes(), "kv{kv_heads}");
            kv_bytes.push(st.hbm_bytes - qo);
        }
        assert_eq!(kv_bytes[0] / kv_bytes[1], 4); // 32 → 8 KV heads
        assert_eq!(kv_bytes[0] / kv_bytes[2], 32); // 32 → 1 (MQA)
        assert_eq!(kv_bytes[0] % kv_bytes[2], 0);
    }

    #[test]
    fn gqa_reduces_small_s_prefill_traffic() {
        // Serving-chunk prefill (S within one row block): K/V is loaded
        // once per KV group instead of once per head, so traffic drops.
        let arch = table1();
        let mha = Workload::new(128, 128, 32, 2);
        let gqa = mha.with_kv_heads(4);
        let st_mha = execute(&flash_program(&arch, &mha, false), 0);
        let st_gqa = execute(&flash_program(&arch, &gqa, false), 0);
        assert!(
            st_gqa.hbm_bytes < st_mha.hbm_bytes,
            "gqa {} vs mha {}",
            st_gqa.hbm_bytes,
            st_mha.hbm_bytes
        );
        assert!(st_gqa.hbm_bytes >= gqa.compulsory_bytes());
    }

    #[test]
    fn fa3_moves_more_bytes_than_fa2() {
        // FA-3's smaller block (M=128 vs 192 at D=128) raises I/O.
        let arch = table1();
        let wl = small_wl();
        let st2 = execute(&flash_program(&arch, &wl, false), 0);
        let st3 = execute(&flash_program(&arch, &wl, true), 0);
        assert!(st3.hbm_bytes > st2.hbm_bytes);
    }

    #[test]
    fn async_streams_overlap_compute() {
        // On a memory-rich config (few heads => little HBM pressure),
        // FA-3 should not be slower than twice-serialized FA-2 compute.
        let arch = table1();
        let wl = Workload::new(2048, 128, 2, 1);
        let st2 = execute(&flash_program(&arch, &wl, false), 0);
        let st3 = execute(&flash_program(&arch, &wl, true), 0);
        // Loose sanity bound: async within 2× of sync either way.
        let r = st3.makespan as f64 / st2.makespan as f64;
        assert!((0.3..2.0).contains(&r), "async/sync ratio {r}");
    }

    #[test]
    fn breakdown_partitions_makespan() {
        let arch = table1();
        let p = flash_program(&arch, &small_wl(), false);
        let st = execute(&p, 0);
        assert_eq!(st.breakdown.total(), st.makespan);
    }

    #[test]
    fn window_equal_to_seq_reproduces_dense_causal_emission() {
        // The acceptance pin for sliding windows: W == S must emit the
        // dense-causal program op for op (same ops, deps, fold accounting),
        // under both schedules.
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let arch = crate::arch::presets::table2(8);
        for (wl, asyn) in [
            (Workload::new(1024, 128, 8, 1).with_causal(true), false),
            (Workload::new(768, 64, 12, 1).with_kv_heads(3).with_causal(true), false),
            (Workload::new(1024, 128, 8, 1).with_causal(true), true),
        ] {
            let dense = flash_program(&arch, &wl, asyn);
            let windowed = flash_program(&arch, &wl.with_window(wl.seq), asyn);
            assert_programs_equal(&dense, &windowed);
        }
    }

    #[test]
    fn sliding_window_cuts_traffic_and_work() {
        // A small window skips most K/V blocks: traffic and makespan drop
        // versus dense causal, and traffic still covers the compulsory
        // windowed bytes.
        let arch = table1();
        let dense = Workload::new(4096, 128, 8, 1).with_causal(true);
        let wind = dense.with_window(256);
        let st_dense = execute(&flash_program(&arch, &dense, false), 0);
        let st_wind = execute(&flash_program(&arch, &wind, false), 0);
        assert!(
            st_wind.hbm_bytes < st_dense.hbm_bytes / 2,
            "windowed {} vs dense {}",
            st_wind.hbm_bytes,
            st_dense.hbm_bytes
        );
        assert!(st_wind.hbm_bytes >= wind.compulsory_bytes());
        assert!(st_wind.makespan < st_dense.makespan);
        // Windowed decode reads only the cache suffix.
        let dec = Workload::new(4096, 128, 8, 1).decode().with_window(512);
        let st_dec = execute(&flash_program(&arch, &dec, false), 0);
        let dec_dense = Workload::new(4096, 128, 8, 1).decode().with_causal(true);
        let st_dec_dense = execute(&flash_program(&arch, &dec_dense, false), 0);
        assert!(st_dec.hbm_bytes < st_dec_dense.hbm_bytes / 4);
    }

    #[test]
    fn chunked_prefill_builds_and_covers_whole_cache() {
        // A prefill chunk behind a cache prefix streams the *whole* cache
        // through K/V (every chunk row attends over the prefix), while Q/O
        // traffic covers only the chunk rows.
        let arch = table1();
        let chunk = Workload::new(512, 128, 8, 1).with_causal(true).with_kv_prefix(1536);
        let p = flash_program(&arch, &chunk, false);
        assert!(p.validate().is_ok());
        let st = execute(&p, 0);
        assert!(st.hbm_bytes >= chunk.compulsory_bytes());
        // The same rows without the prefix move strictly less K/V.
        let head = Workload::new(512, 128, 8, 1).with_causal(true);
        let st_head = execute(&flash_program(&arch, &head, false), 0);
        assert!(st.hbm_bytes > st_head.hbm_bytes);
    }

    #[test]
    fn double_buffer_pair_matches_fresh_builds() {
        // The derived variant must be bit-identical to a fresh build of
        // each mode — ops, deps, fold accounting and execution.
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let arch = crate::arch::presets::table2(8);
        for wl in [
            Workload::new(1024, 128, 24, 1),
            Workload::new(768, 64, 12, 1).with_kv_heads(3).with_causal(true),
            Workload::new(2048, 64, 16, 1).with_kv_heads(4).decode(),
        ] {
            let (db, nodb) = flash_program_db_pair(&arch, &wl);
            let fresh_db = flash_program_ext(&arch, &wl, false, true);
            let fresh_nodb = flash_program_ext(&arch, &wl, false, false);
            assert_programs_equal(&db, &fresh_db);
            assert_programs_equal(&nodb, &fresh_nodb);
            assert_eq!(execute(&db, 0), execute(&fresh_db, 0), "{wl:?} db");
            assert_eq!(execute(&nodb, 0), execute(&fresh_nodb, 0), "{wl:?} nodb");
        }
    }

    #[test]
    fn causal_corner_single_row_last_block_stays_unmasked() {
        // Pin for PR 3's only intentional emission divergence: at
        // `seq % b_c == 1` the final causal row block is a single row with
        // nothing above it in its diagonal K/V block — it sees every real
        // column, so it must NOT pay the triangular mask (the pre-PR-3
        // code masked it). If a tiling edit moves this corner again, the
        // final block's emission will stop matching its non-causal twin.
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let arch = table1();
        let wl = Workload::new(193, 128, 1, 1); // b_c = 192 ⇒ S % b_c == 1
        let t = FlashTiling::resolve(&arch.tile, &wl, false);
        assert_eq!((t.b_c, t.t_r), (192, 2), "corner geometry moved: {t:?}");
        // The mask rule itself: the 1-row block at row 192 of a 193-long
        // cache is fully visible.
        assert_eq!(causal_mask_from(192, 192, 193, 2), 2);
        crate::dataflow::set_symmetry_folding(false);
        let dense = flash_program(&arch, &wl, false);
        let causal = flash_program(&arch, &wl.with_causal(true), false);
        crate::dataflow::set_symmetry_folding(true);
        // Tile 1 holds exactly the corner block (two row blocks dealt
        // round-robin); its stream must be identical with and without
        // causal masking — i.e. the corner is unmasked.
        let pick = |p: &Program| {
            p.ops()
                .iter()
                .filter(|o| o.tile == 1)
                .map(|o| (o.resource, o.occupancy, o.latency, o.component))
                .collect::<Vec<_>>()
        };
        let c = pick(&causal);
        assert!(!c.is_empty(), "tile 1 should own the corner block");
        assert_eq!(c, pick(&dense));
    }
}
