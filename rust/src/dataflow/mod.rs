//! MHA and GEMM dataflow implementations.
//!
//! Each dataflow compiles `(ArchConfig, Workload)` into a [`Program`]
//! (an op DAG over engines, HBM channels and NoC buses) which the
//! DES engine executes. Implemented dataflows, matching the paper's Fig. 3
//! legend:
//!
//! * [`Dataflow::Flash2`] — FlashAttention-2 mapped per-tile (Algorithm 1).
//! * [`Dataflow::Flash3`] — FA-2 plus FlashAttention-3-style asynchronous
//!   two-block overlap (§III-C notes FA-3 uses the same technique).
//! * [`Dataflow::Flat`] — FlatAttention with *software* collectives.
//! * [`Dataflow::FlatColl`] — FlatAttention with *hardware* NoC collectives.
//! * [`Dataflow::FlatAsyn`] — FlatColl plus asynchronous two-head overlap
//!   (Algorithm 2 + §III-C).
//!
//! plus [`summa`] for the Fig. 5c GEMM comparison.

pub mod flash;
pub mod flat;
pub mod summa;
pub mod tiling;

use crate::arch::ArchConfig;
use crate::sim::{execute, Program, RunStats};

pub use summa::{summa_program, GemmWorkload};
pub use tiling::{flash_block_size, flat_slice_size, FlatTiling};

/// An MHA prefill workload (one attention layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Workload {
    /// Sequence length S.
    pub seq: u64,
    /// Head dimension D.
    pub head_dim: u64,
    /// Number of heads H.
    pub heads: u64,
    /// Batch size B.
    pub batch: u64,
    /// Causal (autoregressive) masking. The paper evaluates the
    /// non-causal prefill kernel (matching FlashAttention's benchmarks);
    /// causal support is our extension: dataflows skip fully-masked K/V
    /// blocks and mask the diagonal blocks on the vector engine.
    pub causal: bool,
}

impl Workload {
    pub fn new(seq: u64, head_dim: u64, heads: u64, batch: u64) -> Self {
        Self { seq, head_dim, heads, batch, causal: false }
    }

    /// Builder-style causal toggle.
    pub fn with_causal(mut self, causal: bool) -> Self {
        self.causal = causal;
        self
    }

    /// FP16 element size used throughout the paper.
    pub const BYTES_PER_ELEM: u64 = 2;

    /// Matrix-engine FLOPs of the layer: QKᵀ and P·V, 2·S²·D each per
    /// head (multiply-accumulate = 2 FLOPs). For causal workloads this is
    /// the *useful* count (≈ half); dataflow builders report the FLOPs
    /// actually executed (diagonal blocks compute fully and mask).
    pub fn matmul_flops(&self) -> u64 {
        if self.causal {
            // Σ_i 2·(i+1)·D over rows, ×2 matmuls: 2·S·(S+1)·D per head.
            2 * self.batch * self.heads * self.seq * (self.seq + 1) * self.head_dim
        } else {
            4 * self.batch * self.heads * self.seq * self.seq * self.head_dim
        }
    }

    /// Minimal (compulsory) HBM traffic in bytes: read Q, K, V and write O
    /// exactly once.
    pub fn compulsory_bytes(&self) -> u64 {
        4 * self.batch * self.heads * self.seq * self.head_dim * Self::BYTES_PER_ELEM
    }

    /// Short label like `D128-S4096`.
    pub fn label(&self) -> String {
        format!("D{}-S{}", self.head_dim, self.seq)
    }
}

/// The evaluated MHA dataflow variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    Flash2,
    Flash3,
    Flat,
    FlatColl,
    FlatAsyn,
}

pub const ALL_DATAFLOWS: [Dataflow; 5] = [
    Dataflow::Flash2,
    Dataflow::Flash3,
    Dataflow::Flat,
    Dataflow::FlatColl,
    Dataflow::FlatAsyn,
];

impl Dataflow {
    pub fn label(self) -> &'static str {
        match self {
            Dataflow::Flash2 => "FA-2",
            Dataflow::Flash3 => "FA-3",
            Dataflow::Flat => "Flat",
            Dataflow::FlatColl => "FlatColl",
            Dataflow::FlatAsyn => "FlatAsyn",
        }
    }

    pub fn from_label(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fa-2" | "fa2" | "flash2" => Some(Dataflow::Flash2),
            "fa-3" | "fa3" | "flash3" => Some(Dataflow::Flash3),
            "flat" => Some(Dataflow::Flat),
            "flatcoll" | "flat-coll" => Some(Dataflow::FlatColl),
            "flatasyn" | "flat-asyn" | "flatasync" => Some(Dataflow::FlatAsyn),
            _ => None,
        }
    }

    /// Does this dataflow group tiles (FlatAttention family)?
    pub fn is_flat(self) -> bool {
        matches!(self, Dataflow::Flat | Dataflow::FlatColl | Dataflow::FlatAsyn)
    }
}

/// Build the op-graph program for a dataflow.
///
/// `group` is the (square `Gx = Gy`) FlatAttention group edge; ignored by
/// the FlashAttention variants. Collective hardware support follows the
/// dataflow (`Flat` forces software collectives, `FlatColl`/`FlatAsyn`
/// force hardware collectives) so a single `ArchConfig` can be used for
/// every bar of Fig. 3.
pub fn build_program(arch: &ArchConfig, wl: &Workload, df: Dataflow, group: usize) -> Program {
    match df {
        Dataflow::Flash2 => flash::flash_program(arch, wl, false),
        Dataflow::Flash3 => flash::flash_program(arch, wl, true),
        Dataflow::Flat => {
            let mut a = arch.clone();
            a.noc.hw_collectives = false;
            flat::flat_program(&a, wl, group, false)
        }
        Dataflow::FlatColl => {
            let mut a = arch.clone();
            a.noc.hw_collectives = true;
            flat::flat_program(&a, wl, group, false)
        }
        Dataflow::FlatAsyn => {
            let mut a = arch.clone();
            a.noc.hw_collectives = true;
            flat::flat_program(&a, wl, group, true)
        }
    }
}

/// Build and execute in one step, tracking the canonical critical tile.
pub fn run(arch: &ArchConfig, wl: &Workload, df: Dataflow, group: usize) -> RunStats {
    let program = build_program(arch, wl, df, group);
    let tracked = tracked_tile(arch, df, group);
    execute(&program, tracked)
}

/// The representative tile whose timeline feeds the runtime breakdown:
/// for FlatAttention, the south-west corner tile of group 0 (it loads Q
/// *and* K/V and owns its row/column collectives); for FlashAttention,
/// tile 0 (all tiles behave identically).
pub fn tracked_tile(arch: &ArchConfig, df: Dataflow, group: usize) -> u32 {
    if df.is_flat() {
        let gy = group.min(arch.mesh_y);
        arch.tile_id(0, gy - 1)
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_flops() {
        let wl = Workload::new(4096, 128, 32, 2);
        // 4·B·H·S²·D = 4·2·32·4096²·128
        assert_eq!(wl.matmul_flops(), 549_755_813_888 * 1_000 / 1_000);
        assert_eq!(wl.matmul_flops(), 4 * 2 * 32 * 4096 * 4096 * 128);
    }

    #[test]
    fn dataflow_labels_round_trip() {
        for df in ALL_DATAFLOWS {
            assert_eq!(Dataflow::from_label(df.label()), Some(df));
        }
        assert_eq!(Dataflow::from_label("nope"), None);
    }

    #[test]
    fn compulsory_traffic() {
        let wl = Workload::new(1024, 64, 8, 1);
        assert_eq!(wl.compulsory_bytes(), 4 * 8 * 1024 * 64 * 2);
    }
}
