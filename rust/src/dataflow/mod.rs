//! MHA and GEMM dataflow implementations.
//!
//! Each dataflow compiles `(ArchConfig, Workload)` into a [`Program`]
//! (an op DAG over engines, HBM channels and NoC buses) which the
//! DES engine executes. Implemented dataflows, matching the paper's Fig. 3
//! legend:
//!
//! * [`Dataflow::Flash2`] — FlashAttention-2 mapped per-tile (Algorithm 1).
//! * [`Dataflow::Flash3`] — FA-2 plus FlashAttention-3-style asynchronous
//!   two-block overlap (§III-C notes FA-3 uses the same technique).
//! * [`Dataflow::Flat`] — FlatAttention with *software* collectives.
//! * [`Dataflow::FlatColl`] — FlatAttention with *hardware* NoC collectives.
//! * [`Dataflow::FlatAsyn`] — FlatColl plus asynchronous two-head overlap
//!   (Algorithm 2 + §III-C).
//!
//! plus [`summa`] for the Fig. 5c GEMM comparison.
//!
//! # Workload model
//!
//! A [`Workload`] describes one attention layer in serving terms:
//! `(S, D, H, H_kv, B, causal, phase)`. Prefill MHA (`H_kv == H`,
//! [`Phase::Prefill`]) is the paper's evaluated configuration; the serving
//! extensions compose with every dataflow:
//!
//! * **GQA/MQA** (`kv_heads < heads`): the `H / H_kv` query heads of a KV
//!   group are *stacked* into one row block, so the K/V block is loaded
//!   from HBM once per group and amortized across the group's query rows —
//!   on the FlatAttention family the existing column multicast then
//!   broadcasts that single load through the group, on FlashAttention the
//!   stacked block reuses it from L1. K/V channel traffic therefore
//!   scales by `kv_heads / heads` (exactly, whenever the stacked rows
//!   still fit L1 — see `tiling::FlashTiling` for the `share` fallback).
//!   Stacking grows the Q/O/score footprint, so block/slice sizes shrink
//!   accordingly; with `share == 1` the sizing reduces bit-for-bit to the
//!   dense-MHA formulas.
//! * **Decode** ([`Phase::Decode`]): one query row per (batch, head)
//!   against a KV cache of length `S`. Builders degenerate to a single
//!   row block (`T_r == 1`); the row sits at the end of the cache, so
//!   causal masking is a no-op. FlatAttention pads the single row across
//!   the group's `G` row slices (the honest over-flattening cost of
//!   running a decode token on a big group).
//! * **Chunked prefill** (`kv_prefix > 0`): the `seq` query rows sit at
//!   positions `kv_prefix..kv_prefix + seq` of a `kv_prefix + seq`-long
//!   cache — the unit of work of the continuous-batching scheduler
//!   (`crate::scheduler`). The decode geometry generalized: builders
//!   place the rows via the same end-of-cache offset.
//! * **Sliding window** ([`Workload::with_window`], implies causal):
//!   K/V blocks wholly below every row's window start are skipped and
//!   blocks straddling a window start pay a prefix mask — the mirror of
//!   the causal suffix rule (`tiling::window_block_range`). `window >=
//!   kv_len` reproduces dense causal emission op for op.
//!
//! Both extensions preserve the fold/stamp machinery: shared-resource ops
//! stay verbatim, templates key on the (stacked-rows, block-geometry,
//! mask-position) triple, and folded ≡ unfolded / stamped ≡ naive remain
//! bit-exact (`tests/fold_differential.rs` sweeps `kv_heads` and `phase`).

pub mod flash;
pub mod flat;
pub mod gemm;
pub mod layer;
pub mod summa;
pub mod tiling;

use std::sync::atomic::{AtomicBool, Ordering};

use crate::arch::ArchConfig;
use crate::sim::{
    execute, execute_faulted, execute_parallel, FaultPlan, FaultReport, OpId, Program,
    ProgramArena, RunStats,
};

pub use gemm::{gemm_band_program, gemm_panel_kb, WeightResidency, ALL_RESIDENCIES};
pub use layer::{layer_program, LayerProgram, LayerWorkload};
pub use summa::{summa_program, GemmWorkload};
pub use tiling::{flash_block_size, flat_slice_size, FlashTiling, FlatTiling};

/// Global switch for builder template stamping (§Perf). Stamped and naive
/// builds emit op-for-op identical programs (asserted by the
/// `stamped_build_is_identical_to_naive_build` tests); the switch exists so
/// benches can measure the naive baseline and tests can compare both paths.
static TEMPLATE_STAMPING: AtomicBool = AtomicBool::new(true);

/// Enable/disable template stamping in the dataflow builders.
pub fn set_template_stamping(enabled: bool) {
    TEMPLATE_STAMPING.store(enabled, Ordering::Relaxed);
}

/// Current template-stamping setting.
pub fn template_stamping() -> bool {
    TEMPLATE_STAMPING.load(Ordering::Relaxed)
}

/// Global switch for symmetry folding (§Perf).
///
/// A Flash grid on the Table-I mesh simulates ~1024 tile streams whose
/// op subgraphs are congruent; likewise every FlatAttention group beyond
/// the first repeats the same per-block collective schedule. With folding
/// enabled, the builders emit every *shared-resource* op (HBM channel
/// loads/stores, NoC bus collectives) verbatim — so cross-stream
/// contention is simulated exactly — but collapse each non-representative
/// stream's private compute chain (RedMulE/Spatz ops between
/// shared-resource ops) into single delay ops of the same total duration.
/// The collapse is exact, not approximate:
///
/// * In the synchronous schedules each private engine serves one serial
///   chain, so an op there is never blocked on its resource (its
///   dependencies always complete at or after the previous release) and a
///   chain segment's completion is `ready + Σ occupancy` — which is
///   precisely the delay op. Asynchronous variants (FA-3 / FlatAsyn)
///   genuinely arbitrate two streams per engine, so they never fold.
/// * Kept ops preserve their relative emission order, and the executors
///   schedule same-cycle-ready ops in op-id order (see `sim::engine`), so
///   FIFO tie-breaking on shared channels is identical in both builds.
/// * The elided ops' linear accounting (op count, busy cycles) is carried
///   in [`Program::fold`] and re-added by the executors; the breakdown
///   tile (`tracked_tile`) lives in the representative stream, which is
///   always built unfolded.
///
/// Folded and unfolded builds therefore produce bit-identical `RunStats`
/// — pinned by `tests/fold_differential.rs`. Per-op traces cover the
/// representative stream only; `flatattention trace` disables folding for
/// full-fidelity timelines.
static SYMMETRY_FOLDING: AtomicBool = AtomicBool::new(true);

/// Enable/disable symmetry folding in the dataflow builders.
pub fn set_symmetry_folding(enabled: bool) {
    SYMMETRY_FOLDING.store(enabled, Ordering::Relaxed);
}

/// Current symmetry-folding setting.
pub fn symmetry_folding() -> bool {
    SYMMETRY_FOLDING.load(Ordering::Relaxed)
}

/// Pack up to two optional deps into `buf`, returning the count — the
/// builders' allocation-free dep-list helper (§Perf: the seed cloned a
/// `Vec` per emitted op for these).
#[inline]
pub(crate) fn opt_deps(buf: &mut [OpId; 2], a: Option<OpId>, b: Option<OpId>) -> usize {
    let mut n = 0;
    if let Some(x) = a {
        buf[n] = x;
        n += 1;
    }
    if let Some(x) = b {
        buf[n] = x;
        n += 1;
    }
    n
}

/// Attention execution phase (serving workloads).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Prefill: every query position attends (query length == `seq`).
    Prefill,
    /// Decode: a single new query row per (batch, head) attends over a KV
    /// cache of length `seq`. The query sits at the *end* of the cache, so
    /// it sees every position — causal masking is a no-op in this phase.
    Decode,
}

impl Phase {
    /// Stable lowercase name (`"prefill"` / `"decode"`).
    pub fn label(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }
}

/// An MHA/GQA attention workload (one attention layer).
///
/// Serving shapes are first-class: `kv_heads < heads` models grouped-query
/// attention (`kv_heads == 1` is MQA) — every group of `heads / kv_heads`
/// query heads shares one K/V head, and the dataflow builders emit the
/// shared K/V loads once per group (stacking the group's query rows into
/// one block) so modeled K/V HBM traffic scales by `kv_heads / heads`.
/// `Phase::Decode` models single-token generation: one query row against a
/// KV cache of length `seq`. `kv_prefix` places the `seq` query positions
/// *behind* an existing cache prefix (chunked prefill, the unit of work of
/// the continuous-batching scheduler in `crate::scheduler`), and `window`
/// limits attention to the last W positions (sliding-window/local masks).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Workload {
    /// Sequence length S: the query *and* key/value length for prefill,
    /// the KV-cache length for decode.
    pub seq: u64,
    /// Head dimension D.
    pub head_dim: u64,
    /// Number of query heads H.
    pub heads: u64,
    /// Number of K/V heads (`1 ≤ kv_heads ≤ heads`, `heads % kv_heads ==
    /// 0`). `kv_heads == heads` is dense MHA, `1` is MQA.
    pub kv_heads: u64,
    /// Batch size B.
    pub batch: u64,
    /// Causal (autoregressive) masking. The paper evaluates the
    /// non-causal prefill kernel (matching FlashAttention's benchmarks);
    /// causal support is our extension: dataflows skip fully-masked K/V
    /// blocks and mask the diagonal blocks on the vector engine.
    pub causal: bool,
    /// Prefill vs decode (see [`Phase`]).
    pub phase: Phase,
    /// KV-cache tokens already resident *ahead* of this workload's `seq`
    /// span: the queries sit at global positions `kv_prefix .. kv_prefix +
    /// q_len` of a `kv_prefix + seq`-long cache. 0 is the classic
    /// single-shot shape; chunked prefill sets it to the tokens already
    /// prefilled, so causal masking and K/V traffic see the whole prefix.
    pub kv_prefix: u64,
    /// Sliding-window extent W in tokens (0 = unlimited): each query
    /// attends to the last W positions up to and including itself.
    /// A non-zero window implies causal masking ([`Workload::with_window`]
    /// sets it); `window >= kv_len` reproduces dense causal attention
    /// op for op (asserted by builder tests).
    pub window: u64,
}

impl Workload {
    /// Dense MHA prefill constructor; layer on [`Workload::with_kv_heads`]
    /// / [`Workload::with_phase`] for serving shapes.
    ///
    /// Panics on zero-valued dimensions: these used to slip through and
    /// explode deep inside the builders (division by zero in the tiling,
    /// empty-program executes) instead of failing with a usable message.
    pub fn new(seq: u64, head_dim: u64, heads: u64, batch: u64) -> Self {
        assert!(
            seq > 0 && head_dim > 0 && heads > 0 && batch > 0,
            "workload dimensions must be non-zero (got S={seq} D={head_dim} H={heads} B={batch})"
        );
        Self {
            seq,
            head_dim,
            heads,
            kv_heads: heads,
            batch,
            causal: false,
            phase: Phase::Prefill,
            kv_prefix: 0,
            window: 0,
        }
    }

    /// Builder-style causal toggle.
    pub fn with_causal(mut self, causal: bool) -> Self {
        self.causal = causal;
        self
    }

    /// Builder-style K/V head count (GQA/MQA).
    pub fn with_kv_heads(mut self, kv_heads: u64) -> Self {
        assert!(
            kv_heads >= 1 && kv_heads <= self.heads && self.heads % kv_heads == 0,
            "kv_heads must satisfy 1 <= kv_heads <= heads and heads % kv_heads == 0 \
             (got kv_heads={kv_heads}, heads={})",
            self.heads
        );
        self.kv_heads = kv_heads;
        self
    }

    /// Builder-style phase selector.
    pub fn with_phase(mut self, phase: Phase) -> Self {
        self.phase = phase;
        self
    }

    /// Convenience: switch to [`Phase::Decode`].
    pub fn decode(self) -> Self {
        self.with_phase(Phase::Decode)
    }

    /// Builder-style chunked-prefill cache prefix: the `seq` query
    /// positions sit behind `kv_prefix` already-resident cache tokens.
    pub fn with_kv_prefix(mut self, kv_prefix: u64) -> Self {
        self.kv_prefix = kv_prefix;
        self
    }

    /// Builder-style sliding-window mask: each query attends to the last
    /// `window` positions (including itself). Implies causal masking.
    /// Panics on `window == 0` — zero means "unlimited", so omit the call.
    pub fn with_window(mut self, window: u64) -> Self {
        assert!(
            window > 0,
            "sliding window must be >= 1 token (window == 0 means unlimited — omit the call)"
        );
        self.window = window;
        self.causal = true;
        self
    }

    /// FP16 element size used throughout the paper.
    pub const BYTES_PER_ELEM: u64 = 2;

    /// Query rows per (batch, head): S for prefill, 1 for decode.
    pub fn q_len(&self) -> u64 {
        match self.phase {
            Phase::Prefill => self.seq,
            Phase::Decode => 1,
        }
    }

    /// Key/value positions per (batch, KV head): the `kv_prefix` cache
    /// prefix plus the `seq` span (prefill processes the full cache;
    /// decode attends over the full cache).
    pub fn kv_len(&self) -> u64 {
        self.kv_prefix + self.seq
    }

    /// Effective window for arithmetic: `u64::MAX` when unlimited.
    fn eff_window(&self) -> u64 {
        if self.window == 0 {
            u64::MAX
        } else {
            self.window
        }
    }

    /// Key/value positions each query row attends to, summed over the
    /// `q_len` rows of one (batch, head) — the useful score count behind
    /// [`Workload::matmul_flops`]. Accounts for causal masking, the
    /// chunked-prefill `kv_prefix` offset and the sliding window.
    fn visible_per_head(&self) -> u64 {
        let w = self.eff_window();
        match self.phase {
            Phase::Decode => self.kv_len().min(w),
            Phase::Prefill => {
                if !self.causal {
                    return self.seq * self.kv_len();
                }
                // The row at global position p sees min(p + 1, W) keys;
                // rows p0..p0+seq split into a ramp (p + 1 <= W) and a
                // flat tail of width W.
                let p0 = self.kv_prefix;
                let ramp_end = w.min(p0 + self.seq).max(p0); // exclusive
                let ramp_n = ramp_end - p0;
                let ramp_sum = (ramp_end * (ramp_end + 1) - p0 * (p0 + 1)) / 2;
                ramp_sum + (self.seq - ramp_n) * w
            }
        }
    }

    /// KV positions read at least once per KV head: the sliding window
    /// skips the cache prefix no query row can see.
    pub fn kv_touched(&self) -> u64 {
        let w = self.eff_window();
        match self.phase {
            Phase::Decode => self.kv_len().min(w),
            Phase::Prefill => {
                if !self.causal {
                    return self.kv_len();
                }
                // The first query row (global pos kv_prefix) reaches back
                // to kv_prefix + 1 - W; the union over rows extends to the
                // cache end.
                self.kv_len() - (self.kv_prefix + 1).saturating_sub(w).min(self.kv_len())
            }
        }
    }

    /// Query heads sharing each K/V head (`heads / kv_heads`; 1 for MHA).
    pub fn q_per_kv(&self) -> u64 {
        self.heads / self.kv_heads
    }

    /// True when the workload is a decode step.
    pub fn is_decode(&self) -> bool {
        self.phase == Phase::Decode
    }

    /// Matrix-engine FLOPs of the layer: QKᵀ and P·V, 2·visible·D each per
    /// query row per head (multiply-accumulate = 2 FLOPs). For causal /
    /// windowed prefill this is the *useful* count; dataflow builders
    /// report the FLOPs actually executed (diagonal blocks compute fully
    /// and mask). The decode row sees the whole cache (up to the window),
    /// so causal decode has no masked work.
    pub fn matmul_flops(&self) -> u64 {
        4 * self.batch * self.heads * self.head_dim * self.visible_per_head()
    }

    /// Minimal (compulsory) HBM traffic in bytes: read Q and write O once
    /// per query head, read K and V once per *KV* head — the K/V share
    /// shrinks by `kv_heads / heads` under GQA/MQA and covers only the
    /// window-visible cache suffix under sliding-window masks.
    pub fn compulsory_bytes(&self) -> u64 {
        let qo = 2 * self.batch * self.heads * self.q_len() * self.head_dim;
        let kv = 2 * self.batch * self.kv_heads * self.kv_touched() * self.head_dim;
        (qo + kv) * Self::BYTES_PER_ELEM
    }

    /// Short label like `D128-S4096`, suffixed `-kvK` for GQA/MQA,
    /// `-dec` for decode, `-pP` for a chunked-prefill cache prefix and
    /// `-wW` for sliding windows (dense MHA prefill keeps the historical
    /// form).
    pub fn label(&self) -> String {
        let mut s = format!("D{}-S{}", self.head_dim, self.seq);
        if self.kv_heads != self.heads {
            s.push_str(&format!("-kv{}", self.kv_heads));
        }
        if self.is_decode() {
            s.push_str("-dec");
        }
        if self.kv_prefix > 0 {
            s.push_str(&format!("-p{}", self.kv_prefix));
        }
        if self.window > 0 {
            s.push_str(&format!("-w{}", self.window));
        }
        s
    }
}

/// The evaluated MHA dataflow variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// FlashAttention-2: tile-local Q blocks, synchronous K/V streaming.
    Flash2,
    /// FlashAttention-2 dataflow with asynchronous (double-buffered) streaming.
    Flash3,
    /// FlatAttention group dataflow without fabric collectives.
    Flat,
    /// FlatAttention with single-cycle-per-hop fabric collectives.
    FlatColl,
    /// FlatAttention with collectives and asynchronous streaming.
    FlatAsyn,
}

/// Every dataflow, in the order reports print them.
pub const ALL_DATAFLOWS: [Dataflow; 5] = [
    Dataflow::Flash2,
    Dataflow::Flash3,
    Dataflow::Flat,
    Dataflow::FlatColl,
    Dataflow::FlatAsyn,
];

impl Dataflow {
    /// Stable display/CLI name.
    pub fn label(self) -> &'static str {
        match self {
            Dataflow::Flash2 => "FA-2",
            Dataflow::Flash3 => "FA-3",
            Dataflow::Flat => "Flat",
            Dataflow::FlatColl => "FlatColl",
            Dataflow::FlatAsyn => "FlatAsyn",
        }
    }

    /// Parse a (case-insensitive) label, e.g. from the CLI.
    pub fn from_label(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fa-2" | "fa2" | "flash2" => Some(Dataflow::Flash2),
            "fa-3" | "fa3" | "flash3" => Some(Dataflow::Flash3),
            "flat" => Some(Dataflow::Flat),
            "flatcoll" | "flat-coll" => Some(Dataflow::FlatColl),
            "flatasyn" | "flat-asyn" | "flatasync" => Some(Dataflow::FlatAsyn),
            _ => None,
        }
    }

    /// Does this dataflow group tiles (FlatAttention family)?
    pub fn is_flat(self) -> bool {
        matches!(self, Dataflow::Flat | Dataflow::FlatColl | Dataflow::FlatAsyn)
    }
}

/// Build the op-graph program for a dataflow.
///
/// `group` is the (square `Gx = Gy`) FlatAttention group edge; ignored by
/// the FlashAttention variants. Collective hardware support follows the
/// dataflow (`Flat` forces software collectives, `FlatColl`/`FlatAsyn`
/// force hardware collectives) so a single `ArchConfig` can be used for
/// every bar of Fig. 3.
pub fn build_program(arch: &ArchConfig, wl: &Workload, df: Dataflow, group: usize) -> Program {
    build_program_into(Program::new(), arch, wl, df, group)
}

/// Like [`build_program`], but constructing into buffers recycled by a
/// [`ProgramArena`] — the sweep-scale entry point used by [`run`].
pub fn build_program_in(
    arena: &mut ProgramArena,
    arch: &ArchConfig,
    wl: &Workload,
    df: Dataflow,
    group: usize,
) -> Program {
    build_program_into(arena.fresh(), arch, wl, df, group)
}

fn build_program_into(
    prog: Program,
    arch: &ArchConfig,
    wl: &Workload,
    df: Dataflow,
    group: usize,
) -> Program {
    // Reject degenerate groups up front with a diagnosable error: a zero
    // group used to reach `FlatTiling::resolve` (division by zero) and
    // `tracked_tile` (integer underflow) instead.
    assert!(
        !df.is_flat() || group > 0,
        "{df:?} requires a FlatAttention group edge >= 1 (got 0); pick a group that divides \
         the {}x{} mesh",
        arch.mesh_x,
        arch.mesh_y
    );
    let prog = match df {
        Dataflow::Flash2 => flash::flash_program_ext_in(prog, arch, wl, false, true),
        Dataflow::Flash3 => flash::flash_program_ext_in(prog, arch, wl, true, true),
        Dataflow::Flat => {
            let mut a = arch.clone();
            a.noc.hw_collectives = false;
            flat::flat_program_ext_in(prog, &a, wl, group, false, true)
        }
        Dataflow::FlatColl => {
            let mut a = arch.clone();
            a.noc.hw_collectives = true;
            flat::flat_program_ext_in(prog, &a, wl, group, false, true)
        }
        Dataflow::FlatAsyn => {
            let mut a = arch.clone();
            a.noc.hw_collectives = true;
            flat::flat_program_ext_in(prog, &a, wl, group, true, true)
        }
    };
    // §Analysis: the full structural verifier (well-formedness,
    // acyclicity with a cycle witness — strictly stronger than the old
    // `Program::validate` check) runs here on every debug build; sealing
    // re-runs it with the shard-wall and fold-chain passes added.
    #[cfg(debug_assertions)]
    crate::analysis::assert_verified(&prog);
    prog
}

/// Deal a workload's blocks `(batch, kv_head, share-chunk, row-block)`
/// round-robin over `n_streams` tile/group streams — the canonical
/// enumeration every builder driver shares (solo and batch, Flash and
/// Flat families). Each entry is `(share_c, i)`: the stacked query-head
/// count of the chunk (the last chunk of a KV group may be partial) and
/// the row-block index. The scheduler's conservation property depends on
/// every driver dealing identically, so this exists exactly once.
pub(crate) fn deal_blocks(
    wl: &Workload,
    share: u64,
    chunks: u64,
    t_r: u64,
    n_streams: usize,
) -> Vec<Vec<(u64, u64)>> {
    let q_per_kv = wl.q_per_kv();
    let mut out: Vec<Vec<(u64, u64)>> = vec![Vec::new(); n_streams];
    let mut idx = 0usize;
    for _b in 0..wl.batch {
        for _kvh in 0..wl.kv_heads {
            for c in 0..chunks {
                let share_c = share.min(q_per_kv - c * share);
                for i in 0..t_r {
                    out[idx % n_streams].push((share_c, i));
                    idx += 1;
                }
            }
        }
    }
    out
}

/// A journaled K/V-prefetch dependency choice (§Perf, the ROADMAP
/// "reuse the sealed CSR across `double_buffer` ablation variants"
/// lever): for every K/V load they emit, the builders can record the
/// load's non-buffer base dependency plus the buffer dependency under
/// *each* `double_buffer` mode. The two ablation variants differ in
/// nothing else — same ops, same resources, same timings — so the other
/// variant can be derived from one build by retargeting exactly these
/// dependencies instead of re-running the whole builder (tiling, cost
/// model, op emission).
#[derive(Debug, Clone, Copy)]
pub(crate) struct DbEdit {
    /// The K/V load op.
    pub op: u32,
    /// Its non-buffer dependency (the previous block's end), if gated.
    pub base: Option<u32>,
    /// Buffer dependency with double buffering on (`pv[j-2]`).
    pub db: Option<u32>,
    /// Buffer dependency with double buffering off (`pv[j-1]`).
    pub nodb: Option<u32>,
}

/// Derive one `double_buffer` ablation variant from the other: clone the
/// op topology (every op, resource, timing and accounting field is shared
/// verbatim), retarget the journaled K/V prefetch dependencies, and
/// reseal. Bit-identical to a fresh build of the variant — asserted by
/// the per-builder `double_buffer_pair_matches_fresh_builds` tests.
pub(crate) fn derive_double_buffer_variant(
    src: &Program,
    edits: &[DbEdit],
    double_buffer: bool,
) -> Program {
    let mut p = Program::new();
    p.ops = src.ops.clone();
    p.deps_pool = src.deps_pool.clone();
    p.n_resources = src.n_resources;
    p.flops = src.flops;
    p.fold = src.fold;
    for e in edits {
        let deps_start = p.deps_pool.len() as u32;
        let mut deps_len = 0u32;
        if let Some(b) = e.base {
            p.deps_pool.push(b);
            deps_len += 1;
        }
        let buf = if double_buffer { e.db } else { e.nodb };
        if let Some(b) = buf {
            p.deps_pool.push(b);
            deps_len += 1;
        }
        let op = &mut p.ops[e.op as usize];
        op.deps_start = deps_start;
        op.deps_len = deps_len;
    }
    p.seal();
    p
}

/// Build both K/V `double_buffer` ablation variants (Fig. 3's "*without
/// double buffering" footnote) in ONE builder pass: the `double_buffer =
/// true` program is emitted while journaling every K/V load's prefetch
/// dependency, and the `double_buffer = false` variant is derived by
/// retargeting exactly those dependencies on the cloned op topology and
/// resealing — the builder's tiling/cost-model/emission work runs once
/// instead of twice. Returns `(with_db, without_db)`; both are op-for-op
/// identical to fresh single-variant builds (asserted by tests).
///
/// Only defined for the synchronous dataflows: the asynchronous schedules
/// single-buffer each stream regardless, so their pair is trivial.
pub fn double_buffer_programs(
    arch: &ArchConfig,
    wl: &Workload,
    df: Dataflow,
    group: usize,
) -> (Program, Program) {
    match df {
        Dataflow::Flash2 => flash::flash_program_db_pair(arch, wl),
        Dataflow::Flat => {
            let mut a = arch.clone();
            a.noc.hw_collectives = false;
            flat::flat_program_db_pair(&a, wl, group)
        }
        Dataflow::FlatColl => {
            let mut a = arch.clone();
            a.noc.hw_collectives = true;
            flat::flat_program_db_pair(&a, wl, group)
        }
        Dataflow::Flash3 | Dataflow::FlatAsyn => panic!(
            "double_buffer_programs: {df:?} is asynchronous (streams single-buffer regardless); \
             the ablation pair is only defined for Flash2/Flat/FlatColl"
        ),
    }
}

thread_local! {
    /// Per-worker-thread arena: `run` recycles program buffers across the
    /// experiments a coordinator worker executes (§Perf).
    static RUN_ARENA: std::cell::RefCell<ProgramArena> =
        std::cell::RefCell::new(ProgramArena::new());
}

/// Build and execute in one step, tracking the canonical critical tile.
/// Program buffers are recycled through a thread-local [`ProgramArena`].
pub fn run(arch: &ArchConfig, wl: &Workload, df: Dataflow, group: usize) -> RunStats {
    run_threads(arch, wl, df, group, 1)
}

/// Like [`run`], executing the DES with `threads` workers over the
/// program's §Shard partition ([`crate::sim::execute_parallel`]);
/// `threads <= 1` is exactly [`run`]. Results are bit-identical at every
/// thread count — the sharded executor reproduces the serial schedule
/// (`tests/parallel_differential.rs`) — so callers pick the count freely
/// without perturbing any downstream consumer (including the
/// coordinator's memo keys; see `coordinator::set_engine_threads`).
pub fn run_threads(
    arch: &ArchConfig,
    wl: &Workload,
    df: Dataflow,
    group: usize,
    threads: usize,
) -> RunStats {
    let tracked = tracked_tile(arch, df, group);
    RUN_ARENA.with(|cell| {
        let mut arena = cell.borrow_mut();
        let program = build_program_in(&mut arena, arch, wl, df, group);
        let stats = if threads > 1 {
            execute_parallel(&program, tracked, threads)
        } else {
            execute(&program, tracked)
        };
        arena.recycle(program);
        stats
    })
}

/// Like [`run_threads`], executing under a fault plan
/// (`sim::execute_faulted`, §Fault): returns the surviving schedule's
/// stats plus the killed/stalled op report. `FaultPlan::none()` matches
/// [`run_threads`] bit for bit at every thread count
/// (`tests/fault_differential.rs`).
pub fn run_faulted(
    arch: &ArchConfig,
    wl: &Workload,
    df: Dataflow,
    group: usize,
    threads: usize,
    plan: &FaultPlan,
) -> (RunStats, FaultReport) {
    let tracked = tracked_tile(arch, df, group);
    RUN_ARENA.with(|cell| {
        let mut arena = cell.borrow_mut();
        let program = build_program_in(&mut arena, arch, wl, df, group);
        let out = execute_faulted(&program, tracked, plan, threads);
        arena.recycle(program);
        out
    })
}

/// The representative tile whose timeline feeds the runtime breakdown:
/// for FlatAttention, the south-west corner tile of group 0 (it loads Q
/// *and* K/V and owns its row/column collectives); for FlashAttention,
/// tile 0 (all tiles behave identically). The stream containing this tile
/// is always built unfolded (see [`set_symmetry_folding`]).
///
/// Degenerate `group` values clamp to a valid group edge: `group == 0`
/// used to underflow `gy - 1` (a panic in debug builds, a garbage tile id
/// in release builds).
pub fn tracked_tile(arch: &ArchConfig, df: Dataflow, group: usize) -> u32 {
    if df.is_flat() {
        let gy = group.clamp(1, arch.mesh_y);
        arch.tile_id(0, gy - 1)
    } else {
        0
    }
}

/// Serializes tests that toggle the builder globals
/// ([`set_template_stamping`], [`set_symmetry_folding`]) or that build
/// pairs of programs expected to be structurally identical: without this,
/// a concurrent test could flip a global mid-"naive" build, making the
/// stamped-vs-naive (or folded-vs-unfolded) oracle compare two builds of
/// the same mode (trivially green) or of accidentally different modes
/// (spuriously red). Lock around the whole toggle+build+restore sequence;
/// recover from poisoning so one failed test doesn't cascade.
#[cfg(test)]
pub(crate) static GLOBAL_SWITCH_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Assert two programs are identical op for op, dep for dep — the
/// correctness oracle for template stamping (a stamped build must be
/// indistinguishable from the naive emission).
#[cfg(test)]
pub(crate) fn assert_programs_equal(a: &Program, b: &Program) {
    assert_eq!(a.num_ops(), b.num_ops(), "op count");
    assert_eq!(a.num_resources(), b.num_resources(), "resource count");
    assert_eq!(a.flops, b.flops, "flops");
    assert_eq!(a.fold, b.fold, "fold accounting");
    for (i, (x, y)) in a.ops().iter().zip(b.ops().iter()).enumerate() {
        assert_eq!(x.resource, y.resource, "op {i}: resource");
        assert_eq!(x.occupancy, y.occupancy, "op {i}: occupancy");
        assert_eq!(x.latency, y.latency, "op {i}: latency");
        assert_eq!(x.component, y.component, "op {i}: component");
        assert_eq!(x.tile, y.tile, "op {i}: tile");
        assert_eq!(x.hbm_bytes, y.hbm_bytes, "op {i}: hbm_bytes");
        assert_eq!(a.deps_of(x), b.deps_of(y), "op {i}: deps");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_flops() {
        let wl = Workload::new(4096, 128, 32, 2);
        // 4·B·H·S²·D = 4·2·32·4096²·128
        assert_eq!(wl.matmul_flops(), 549_755_813_888 * 1_000 / 1_000);
        assert_eq!(wl.matmul_flops(), 4 * 2 * 32 * 4096 * 4096 * 128);
    }

    #[test]
    fn dataflow_labels_round_trip() {
        for df in ALL_DATAFLOWS {
            assert_eq!(Dataflow::from_label(df.label()), Some(df));
        }
        assert_eq!(Dataflow::from_label("nope"), None);
    }

    #[test]
    fn compulsory_traffic() {
        let wl = Workload::new(1024, 64, 8, 1);
        assert_eq!(wl.compulsory_bytes(), 4 * 8 * 1024 * 64 * 2);
    }

    #[test]
    fn gqa_compulsory_kv_share_scales() {
        // K/V compulsory bytes shrink by heads/kv_heads; Q/O stay put.
        let mha = Workload::new(1024, 64, 8, 1);
        let gqa = mha.with_kv_heads(2);
        let qo = 2 * 8 * 1024 * 64 * 2u64;
        assert_eq!(mha.compulsory_bytes(), qo + qo);
        assert_eq!(gqa.compulsory_bytes(), qo + qo / 4);
        let mqa = mha.with_kv_heads(1);
        assert_eq!(mqa.compulsory_bytes(), qo + qo / 8);
    }

    #[test]
    fn decode_shapes_and_flops() {
        let wl = Workload::new(2048, 128, 8, 2).decode();
        assert_eq!(wl.q_len(), 1);
        assert_eq!(wl.kv_len(), 2048);
        assert_eq!(wl.matmul_flops(), 4 * 2 * 8 * 2048 * 128);
        // Causal decode: the single row sees the whole cache — same count.
        assert_eq!(wl.with_causal(true).matmul_flops(), wl.matmul_flops());
        // Compulsory: Q/O are one row per head, K/V the full cache.
        let qo = 2 * 2 * 8 * 128 * 2u64;
        let kv = 2 * 2 * 8 * 2048 * 128 * 2u64;
        assert_eq!(wl.compulsory_bytes(), qo + kv);
    }

    #[test]
    fn serving_labels() {
        assert_eq!(Workload::new(4096, 128, 32, 2).label(), "D128-S4096");
        assert_eq!(
            Workload::new(4096, 128, 32, 2).with_kv_heads(8).label(),
            "D128-S4096-kv8"
        );
        assert_eq!(
            Workload::new(4096, 128, 32, 2).with_kv_heads(1).decode().label(),
            "D128-S4096-kv1-dec"
        );
        assert_eq!(Phase::Decode.label(), "decode");
    }

    #[test]
    fn chunked_prefill_prefix_shifts_flops_and_cache() {
        // A 128-query chunk behind a 256-token prefix: every chunk row
        // sees the whole prefix plus its causal span.
        let wl = Workload::new(128, 64, 8, 1).with_causal(true).with_kv_prefix(256);
        assert_eq!(wl.q_len(), 128);
        assert_eq!(wl.kv_len(), 384);
        // Σ_{p=256}^{383} (p + 1) = (384·385 − 256·257) / 2 = 41024.
        assert_eq!(wl.matmul_flops(), 4 * 8 * 64 * 41024);
        assert_eq!(wl.kv_touched(), 384);
        // Chunks tile the full prefill exactly: flops of the whole causal
        // layer equal the sum over its chunks.
        let full = Workload::new(384, 64, 8, 1).with_causal(true);
        let head = Workload::new(256, 64, 8, 1).with_causal(true);
        assert_eq!(full.matmul_flops(), head.matmul_flops() + wl.matmul_flops());
    }

    #[test]
    fn sliding_window_flops_and_touched_kv() {
        // S=64, W=16: rows 0..16 ramp (Σ = 136), rows 16..64 see W each.
        let wl = Workload::new(64, 32, 2, 1).with_window(16);
        assert!(wl.causal, "with_window implies causal");
        assert_eq!(wl.matmul_flops(), 4 * 2 * 32 * (136 + 48 * 16));
        assert_eq!(wl.kv_touched(), 64); // union still reaches position 0
        // Decode with a window touches only the last W cache tokens.
        let dec = Workload::new(4096, 128, 8, 1).decode().with_window(1024);
        assert_eq!(dec.kv_touched(), 1024);
        assert_eq!(dec.matmul_flops(), 4 * 8 * 128 * 1024);
        // Window >= S degenerates to dense causal.
        let dense = Workload::new(512, 64, 4, 1).with_causal(true);
        assert_eq!(dense.with_window(512).matmul_flops(), dense.matmul_flops());
        assert_eq!(dense.with_window(512).kv_touched(), dense.kv_touched());
        // A chunk whose window ends inside the prefix skips the head of
        // the cache.
        let chunk = Workload::new(64, 32, 2, 1).with_kv_prefix(192).with_window(128);
        assert_eq!(chunk.kv_touched(), 256 - (192 + 1 - 128));
    }

    #[test]
    #[should_panic(expected = "window must be >= 1")]
    fn with_window_rejects_zero() {
        let _ = Workload::new(64, 32, 2, 1).with_window(0);
    }

    #[test]
    fn serving_labels_extended_shapes() {
        assert_eq!(
            Workload::new(512, 128, 32, 1).with_kv_prefix(1024).label(),
            "D128-S512-p1024"
        );
        assert_eq!(Workload::new(4096, 128, 32, 1).with_window(512).label(), "D128-S4096-w512");
    }

    #[test]
    #[should_panic(expected = "must be non-zero")]
    fn workload_rejects_zero_seq() {
        // Regression: a zero dimension used to survive construction and
        // only explode deep inside the builders.
        let _ = Workload::new(0, 128, 8, 1);
    }

    #[test]
    #[should_panic(expected = "must be non-zero")]
    fn workload_rejects_zero_heads() {
        let _ = Workload::new(1024, 128, 0, 1);
    }

    #[test]
    #[should_panic(expected = "heads % kv_heads == 0")]
    fn workload_rejects_non_dividing_kv_heads() {
        let _ = Workload::new(1024, 128, 6, 1).with_kv_heads(4);
    }

    #[test]
    #[should_panic(expected = "kv_heads must satisfy")]
    fn workload_rejects_zero_kv_heads() {
        let _ = Workload::new(1024, 128, 8, 1).with_kv_heads(0);
    }

    #[test]
    fn arena_build_matches_fresh_build() {
        // Recycled buffers must not leak state between experiments: an
        // arena-backed build equals a fresh build, for every dataflow in
        // sequence through the same arena. Holds the switch lock so a
        // concurrent toggle cannot make the pair structurally different.
        let _guard = GLOBAL_SWITCH_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let arch = crate::arch::presets::table2(8);
        let wl = Workload::new(512, 64, 4, 1);
        let mut arena = ProgramArena::new();
        for df in ALL_DATAFLOWS {
            let fresh = build_program(&arch, &wl, df, 8);
            let pooled = build_program_in(&mut arena, &arch, &wl, df, 8);
            assert_programs_equal(&fresh, &pooled);
            let tracked = tracked_tile(&arch, df, 8);
            assert_eq!(execute(&fresh, tracked), execute(&pooled, tracked));
            arena.recycle(pooled);
        }
    }

    #[test]
    fn double_buffer_pair_dispatch_covers_sync_dataflows() {
        // The pair API must produce executable programs for every
        // synchronous dataflow (the per-builder tests pin bit-identity).
        let _guard = GLOBAL_SWITCH_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let arch = crate::arch::presets::table2(8);
        let wl = Workload::new(512, 64, 4, 1);
        for df in [Dataflow::Flash2, Dataflow::Flat, Dataflow::FlatColl] {
            let (db, nodb) = double_buffer_programs(&arch, &wl, df, 4);
            assert!(db.is_sealed() && nodb.is_sealed(), "{df:?}");
            assert_eq!(db.num_ops(), nodb.num_ops(), "{df:?}: same topology");
            let tracked = tracked_tile(&arch, df, 4);
            let s_db = execute(&db, tracked);
            let s_nodb = execute(&nodb, tracked);
            // Removing the prefetch serializes more (tiny FIFO-reordering
            // slack allowed, as in the ablation report's threshold).
            assert!(s_nodb.makespan * 100 >= s_db.makespan * 99, "{df:?}");
            assert_eq!(s_db.hbm_bytes, s_nodb.hbm_bytes, "{df:?}");
        }
    }

    #[test]
    #[should_panic(expected = "asynchronous")]
    fn double_buffer_pair_rejects_async_dataflows() {
        let arch = crate::arch::presets::table2(8);
        let wl = Workload::new(256, 64, 2, 1);
        let _ = double_buffer_programs(&arch, &wl, Dataflow::Flash3, 1);
    }

    #[test]
    fn tracked_tile_clamps_degenerate_groups() {
        let arch = crate::arch::presets::table2(8);
        // Regression: `group == 0` used to compute `0 - 1` on the group
        // edge (debug panic / release garbage tile id). Now clamps.
        assert_eq!(tracked_tile(&arch, Dataflow::FlatColl, 0), 0);
        // Oversized groups clamp to the mesh edge.
        assert_eq!(tracked_tile(&arch, Dataflow::FlatColl, 64), arch.tile_id(0, 7));
        // FlashAttention ignores the group entirely.
        assert_eq!(tracked_tile(&arch, Dataflow::Flash2, 0), 0);
    }

    #[test]
    #[should_panic(expected = "group edge >= 1")]
    fn build_program_rejects_group_zero_for_flat() {
        // Regression: this used to die deep inside `FlatTiling::resolve`
        // with a bare division-by-zero panic.
        let arch = crate::arch::presets::table2(8);
        let wl = Workload::new(256, 64, 1, 1);
        let _ = build_program(&arch, &wl, Dataflow::FlatColl, 0);
    }

    #[test]
    fn flash_tolerates_group_zero() {
        // The group parameter is documented as ignored for FlashAttention;
        // a zero group must not panic anywhere on that path.
        let arch = crate::arch::presets::table2(8);
        let wl = Workload::new(256, 64, 2, 1);
        let stats = run(&arch, &wl, Dataflow::Flash2, 0);
        assert!(stats.makespan > 0);
    }
}
