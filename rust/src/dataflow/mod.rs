//! MHA and GEMM dataflow implementations.
//!
//! Each dataflow compiles `(ArchConfig, Workload)` into a [`Program`]
//! (an op DAG over engines, HBM channels and NoC buses) which the
//! DES engine executes. Implemented dataflows, matching the paper's Fig. 3
//! legend:
//!
//! * [`Dataflow::Flash2`] — FlashAttention-2 mapped per-tile (Algorithm 1).
//! * [`Dataflow::Flash3`] — FA-2 plus FlashAttention-3-style asynchronous
//!   two-block overlap (§III-C notes FA-3 uses the same technique).
//! * [`Dataflow::Flat`] — FlatAttention with *software* collectives.
//! * [`Dataflow::FlatColl`] — FlatAttention with *hardware* NoC collectives.
//! * [`Dataflow::FlatAsyn`] — FlatColl plus asynchronous two-head overlap
//!   (Algorithm 2 + §III-C).
//!
//! plus [`summa`] for the Fig. 5c GEMM comparison.

pub mod flash;
pub mod flat;
pub mod summa;
pub mod tiling;

use std::sync::atomic::{AtomicBool, Ordering};

use crate::arch::ArchConfig;
use crate::sim::{execute, OpId, Program, ProgramArena, RunStats};

pub use summa::{summa_program, GemmWorkload};
pub use tiling::{flash_block_size, flat_slice_size, FlatTiling};

/// Global switch for builder template stamping (§Perf). Stamped and naive
/// builds emit op-for-op identical programs (asserted by the
/// `stamped_build_is_identical_to_naive_build` tests); the switch exists so
/// benches can measure the naive baseline and tests can compare both paths.
static TEMPLATE_STAMPING: AtomicBool = AtomicBool::new(true);

/// Enable/disable template stamping in the dataflow builders.
pub fn set_template_stamping(enabled: bool) {
    TEMPLATE_STAMPING.store(enabled, Ordering::Relaxed);
}

/// Current template-stamping setting.
pub fn template_stamping() -> bool {
    TEMPLATE_STAMPING.load(Ordering::Relaxed)
}

/// Pack up to two optional deps into `buf`, returning the count — the
/// builders' allocation-free dep-list helper (§Perf: the seed cloned a
/// `Vec` per emitted op for these).
#[inline]
pub(crate) fn opt_deps(buf: &mut [OpId; 2], a: Option<OpId>, b: Option<OpId>) -> usize {
    let mut n = 0;
    if let Some(x) = a {
        buf[n] = x;
        n += 1;
    }
    if let Some(x) = b {
        buf[n] = x;
        n += 1;
    }
    n
}

/// An MHA prefill workload (one attention layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Workload {
    /// Sequence length S.
    pub seq: u64,
    /// Head dimension D.
    pub head_dim: u64,
    /// Number of heads H.
    pub heads: u64,
    /// Batch size B.
    pub batch: u64,
    /// Causal (autoregressive) masking. The paper evaluates the
    /// non-causal prefill kernel (matching FlashAttention's benchmarks);
    /// causal support is our extension: dataflows skip fully-masked K/V
    /// blocks and mask the diagonal blocks on the vector engine.
    pub causal: bool,
}

impl Workload {
    pub fn new(seq: u64, head_dim: u64, heads: u64, batch: u64) -> Self {
        Self { seq, head_dim, heads, batch, causal: false }
    }

    /// Builder-style causal toggle.
    pub fn with_causal(mut self, causal: bool) -> Self {
        self.causal = causal;
        self
    }

    /// FP16 element size used throughout the paper.
    pub const BYTES_PER_ELEM: u64 = 2;

    /// Matrix-engine FLOPs of the layer: QKᵀ and P·V, 2·S²·D each per
    /// head (multiply-accumulate = 2 FLOPs). For causal workloads this is
    /// the *useful* count (≈ half); dataflow builders report the FLOPs
    /// actually executed (diagonal blocks compute fully and mask).
    pub fn matmul_flops(&self) -> u64 {
        if self.causal {
            // Σ_i 2·(i+1)·D over rows, ×2 matmuls: 2·S·(S+1)·D per head.
            2 * self.batch * self.heads * self.seq * (self.seq + 1) * self.head_dim
        } else {
            4 * self.batch * self.heads * self.seq * self.seq * self.head_dim
        }
    }

    /// Minimal (compulsory) HBM traffic in bytes: read Q, K, V and write O
    /// exactly once.
    pub fn compulsory_bytes(&self) -> u64 {
        4 * self.batch * self.heads * self.seq * self.head_dim * Self::BYTES_PER_ELEM
    }

    /// Short label like `D128-S4096`.
    pub fn label(&self) -> String {
        format!("D{}-S{}", self.head_dim, self.seq)
    }
}

/// The evaluated MHA dataflow variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    Flash2,
    Flash3,
    Flat,
    FlatColl,
    FlatAsyn,
}

pub const ALL_DATAFLOWS: [Dataflow; 5] = [
    Dataflow::Flash2,
    Dataflow::Flash3,
    Dataflow::Flat,
    Dataflow::FlatColl,
    Dataflow::FlatAsyn,
];

impl Dataflow {
    pub fn label(self) -> &'static str {
        match self {
            Dataflow::Flash2 => "FA-2",
            Dataflow::Flash3 => "FA-3",
            Dataflow::Flat => "Flat",
            Dataflow::FlatColl => "FlatColl",
            Dataflow::FlatAsyn => "FlatAsyn",
        }
    }

    pub fn from_label(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fa-2" | "fa2" | "flash2" => Some(Dataflow::Flash2),
            "fa-3" | "fa3" | "flash3" => Some(Dataflow::Flash3),
            "flat" => Some(Dataflow::Flat),
            "flatcoll" | "flat-coll" => Some(Dataflow::FlatColl),
            "flatasyn" | "flat-asyn" | "flatasync" => Some(Dataflow::FlatAsyn),
            _ => None,
        }
    }

    /// Does this dataflow group tiles (FlatAttention family)?
    pub fn is_flat(self) -> bool {
        matches!(self, Dataflow::Flat | Dataflow::FlatColl | Dataflow::FlatAsyn)
    }
}

/// Build the op-graph program for a dataflow.
///
/// `group` is the (square `Gx = Gy`) FlatAttention group edge; ignored by
/// the FlashAttention variants. Collective hardware support follows the
/// dataflow (`Flat` forces software collectives, `FlatColl`/`FlatAsyn`
/// force hardware collectives) so a single `ArchConfig` can be used for
/// every bar of Fig. 3.
pub fn build_program(arch: &ArchConfig, wl: &Workload, df: Dataflow, group: usize) -> Program {
    build_program_into(Program::new(), arch, wl, df, group)
}

/// Like [`build_program`], but constructing into buffers recycled by a
/// [`ProgramArena`] — the sweep-scale entry point used by [`run`].
pub fn build_program_in(
    arena: &mut ProgramArena,
    arch: &ArchConfig,
    wl: &Workload,
    df: Dataflow,
    group: usize,
) -> Program {
    build_program_into(arena.fresh(), arch, wl, df, group)
}

fn build_program_into(
    prog: Program,
    arch: &ArchConfig,
    wl: &Workload,
    df: Dataflow,
    group: usize,
) -> Program {
    let prog = match df {
        Dataflow::Flash2 => flash::flash_program_ext_in(prog, arch, wl, false, true),
        Dataflow::Flash3 => flash::flash_program_ext_in(prog, arch, wl, true, true),
        Dataflow::Flat => {
            let mut a = arch.clone();
            a.noc.hw_collectives = false;
            flat::flat_program_ext_in(prog, &a, wl, group, false, true)
        }
        Dataflow::FlatColl => {
            let mut a = arch.clone();
            a.noc.hw_collectives = true;
            flat::flat_program_ext_in(prog, &a, wl, group, false, true)
        }
        Dataflow::FlatAsyn => {
            let mut a = arch.clone();
            a.noc.hw_collectives = true;
            flat::flat_program_ext_in(prog, &a, wl, group, true, true)
        }
    };
    #[cfg(debug_assertions)]
    if let Err(e) = prog.validate() {
        panic!("build_program produced an invalid DAG for {df:?}: {e}");
    }
    prog
}

thread_local! {
    /// Per-worker-thread arena: `run` recycles program buffers across the
    /// experiments a coordinator worker executes (§Perf).
    static RUN_ARENA: std::cell::RefCell<ProgramArena> =
        std::cell::RefCell::new(ProgramArena::new());
}

/// Build and execute in one step, tracking the canonical critical tile.
/// Program buffers are recycled through a thread-local [`ProgramArena`].
pub fn run(arch: &ArchConfig, wl: &Workload, df: Dataflow, group: usize) -> RunStats {
    let tracked = tracked_tile(arch, df, group);
    RUN_ARENA.with(|cell| {
        let mut arena = cell.borrow_mut();
        let program = build_program_in(&mut arena, arch, wl, df, group);
        let stats = execute(&program, tracked);
        arena.recycle(program);
        stats
    })
}

/// The representative tile whose timeline feeds the runtime breakdown:
/// for FlatAttention, the south-west corner tile of group 0 (it loads Q
/// *and* K/V and owns its row/column collectives); for FlashAttention,
/// tile 0 (all tiles behave identically).
pub fn tracked_tile(arch: &ArchConfig, df: Dataflow, group: usize) -> u32 {
    if df.is_flat() {
        let gy = group.min(arch.mesh_y);
        arch.tile_id(0, gy - 1)
    } else {
        0
    }
}

/// Serializes tests that toggle [`set_template_stamping`]: without this,
/// a concurrent test could flip the global back to `true` mid-"naive"
/// build, making the stamped-vs-naive identity oracle compare stamped vs
/// stamped (trivially green). Lock around the whole toggle+build+restore
/// sequence; recover from poisoning so one failed test doesn't cascade.
#[cfg(test)]
pub(crate) static STAMPING_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Assert two programs are identical op for op, dep for dep — the
/// correctness oracle for template stamping (a stamped build must be
/// indistinguishable from the naive emission).
#[cfg(test)]
pub(crate) fn assert_programs_equal(a: &Program, b: &Program) {
    assert_eq!(a.num_ops(), b.num_ops(), "op count");
    assert_eq!(a.num_resources(), b.num_resources(), "resource count");
    assert_eq!(a.flops, b.flops, "flops");
    for (i, (x, y)) in a.ops().iter().zip(b.ops().iter()).enumerate() {
        assert_eq!(x.resource, y.resource, "op {i}: resource");
        assert_eq!(x.occupancy, y.occupancy, "op {i}: occupancy");
        assert_eq!(x.latency, y.latency, "op {i}: latency");
        assert_eq!(x.component, y.component, "op {i}: component");
        assert_eq!(x.tile, y.tile, "op {i}: tile");
        assert_eq!(x.hbm_bytes, y.hbm_bytes, "op {i}: hbm_bytes");
        assert_eq!(a.deps_of(x), b.deps_of(y), "op {i}: deps");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_flops() {
        let wl = Workload::new(4096, 128, 32, 2);
        // 4·B·H·S²·D = 4·2·32·4096²·128
        assert_eq!(wl.matmul_flops(), 549_755_813_888 * 1_000 / 1_000);
        assert_eq!(wl.matmul_flops(), 4 * 2 * 32 * 4096 * 4096 * 128);
    }

    #[test]
    fn dataflow_labels_round_trip() {
        for df in ALL_DATAFLOWS {
            assert_eq!(Dataflow::from_label(df.label()), Some(df));
        }
        assert_eq!(Dataflow::from_label("nope"), None);
    }

    #[test]
    fn compulsory_traffic() {
        let wl = Workload::new(1024, 64, 8, 1);
        assert_eq!(wl.compulsory_bytes(), 4 * 8 * 1024 * 64 * 2);
    }

    #[test]
    fn arena_build_matches_fresh_build() {
        // Recycled buffers must not leak state between experiments: an
        // arena-backed build equals a fresh build, for every dataflow in
        // sequence through the same arena.
        let arch = crate::arch::presets::table2(8);
        let wl = Workload::new(512, 64, 4, 1);
        let mut arena = ProgramArena::new();
        for df in ALL_DATAFLOWS {
            let fresh = build_program(&arch, &wl, df, 8);
            let pooled = build_program_in(&mut arena, &arch, &wl, df, 8);
            assert_programs_equal(&fresh, &pooled);
            let tracked = tracked_tile(&arch, df, 8);
            assert_eq!(execute(&fresh, tracked), execute(&pooled, tracked));
            arena.recycle(pooled);
        }
    }
}
