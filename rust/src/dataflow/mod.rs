//! MHA and GEMM dataflow implementations.
//!
//! Each dataflow compiles `(ArchConfig, Workload)` into a [`Program`]
//! (an op DAG over engines, HBM channels and NoC buses) which the
//! DES engine executes. Implemented dataflows, matching the paper's Fig. 3
//! legend:
//!
//! * [`Dataflow::Flash2`] — FlashAttention-2 mapped per-tile (Algorithm 1).
//! * [`Dataflow::Flash3`] — FA-2 plus FlashAttention-3-style asynchronous
//!   two-block overlap (§III-C notes FA-3 uses the same technique).
//! * [`Dataflow::Flat`] — FlatAttention with *software* collectives.
//! * [`Dataflow::FlatColl`] — FlatAttention with *hardware* NoC collectives.
//! * [`Dataflow::FlatAsyn`] — FlatColl plus asynchronous two-head overlap
//!   (Algorithm 2 + §III-C).
//!
//! plus [`summa`] for the Fig. 5c GEMM comparison.

pub mod flash;
pub mod flat;
pub mod summa;
pub mod tiling;

use std::sync::atomic::{AtomicBool, Ordering};

use crate::arch::ArchConfig;
use crate::sim::{execute, OpId, Program, ProgramArena, RunStats};

pub use summa::{summa_program, GemmWorkload};
pub use tiling::{flash_block_size, flat_slice_size, FlatTiling};

/// Global switch for builder template stamping (§Perf). Stamped and naive
/// builds emit op-for-op identical programs (asserted by the
/// `stamped_build_is_identical_to_naive_build` tests); the switch exists so
/// benches can measure the naive baseline and tests can compare both paths.
static TEMPLATE_STAMPING: AtomicBool = AtomicBool::new(true);

/// Enable/disable template stamping in the dataflow builders.
pub fn set_template_stamping(enabled: bool) {
    TEMPLATE_STAMPING.store(enabled, Ordering::Relaxed);
}

/// Current template-stamping setting.
pub fn template_stamping() -> bool {
    TEMPLATE_STAMPING.load(Ordering::Relaxed)
}

/// Global switch for symmetry folding (§Perf).
///
/// A Flash grid on the Table-I mesh simulates ~1024 tile streams whose
/// op subgraphs are congruent; likewise every FlatAttention group beyond
/// the first repeats the same per-block collective schedule. With folding
/// enabled, the builders emit every *shared-resource* op (HBM channel
/// loads/stores, NoC bus collectives) verbatim — so cross-stream
/// contention is simulated exactly — but collapse each non-representative
/// stream's private compute chain (RedMulE/Spatz ops between
/// shared-resource ops) into single delay ops of the same total duration.
/// The collapse is exact, not approximate:
///
/// * In the synchronous schedules each private engine serves one serial
///   chain, so an op there is never blocked on its resource (its
///   dependencies always complete at or after the previous release) and a
///   chain segment's completion is `ready + Σ occupancy` — which is
///   precisely the delay op. Asynchronous variants (FA-3 / FlatAsyn)
///   genuinely arbitrate two streams per engine, so they never fold.
/// * Kept ops preserve their relative emission order, and the executors
///   schedule same-cycle-ready ops in op-id order (see `sim::engine`), so
///   FIFO tie-breaking on shared channels is identical in both builds.
/// * The elided ops' linear accounting (op count, busy cycles) is carried
///   in [`Program::fold`] and re-added by the executors; the breakdown
///   tile (`tracked_tile`) lives in the representative stream, which is
///   always built unfolded.
///
/// Folded and unfolded builds therefore produce bit-identical `RunStats`
/// — pinned by `tests/fold_differential.rs`. Per-op traces cover the
/// representative stream only; `flatattention trace` disables folding for
/// full-fidelity timelines.
static SYMMETRY_FOLDING: AtomicBool = AtomicBool::new(true);

/// Enable/disable symmetry folding in the dataflow builders.
pub fn set_symmetry_folding(enabled: bool) {
    SYMMETRY_FOLDING.store(enabled, Ordering::Relaxed);
}

/// Current symmetry-folding setting.
pub fn symmetry_folding() -> bool {
    SYMMETRY_FOLDING.load(Ordering::Relaxed)
}

/// Pack up to two optional deps into `buf`, returning the count — the
/// builders' allocation-free dep-list helper (§Perf: the seed cloned a
/// `Vec` per emitted op for these).
#[inline]
pub(crate) fn opt_deps(buf: &mut [OpId; 2], a: Option<OpId>, b: Option<OpId>) -> usize {
    let mut n = 0;
    if let Some(x) = a {
        buf[n] = x;
        n += 1;
    }
    if let Some(x) = b {
        buf[n] = x;
        n += 1;
    }
    n
}

/// An MHA prefill workload (one attention layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Workload {
    /// Sequence length S.
    pub seq: u64,
    /// Head dimension D.
    pub head_dim: u64,
    /// Number of heads H.
    pub heads: u64,
    /// Batch size B.
    pub batch: u64,
    /// Causal (autoregressive) masking. The paper evaluates the
    /// non-causal prefill kernel (matching FlashAttention's benchmarks);
    /// causal support is our extension: dataflows skip fully-masked K/V
    /// blocks and mask the diagonal blocks on the vector engine.
    pub causal: bool,
}

impl Workload {
    pub fn new(seq: u64, head_dim: u64, heads: u64, batch: u64) -> Self {
        Self { seq, head_dim, heads, batch, causal: false }
    }

    /// Builder-style causal toggle.
    pub fn with_causal(mut self, causal: bool) -> Self {
        self.causal = causal;
        self
    }

    /// FP16 element size used throughout the paper.
    pub const BYTES_PER_ELEM: u64 = 2;

    /// Matrix-engine FLOPs of the layer: QKᵀ and P·V, 2·S²·D each per
    /// head (multiply-accumulate = 2 FLOPs). For causal workloads this is
    /// the *useful* count (≈ half); dataflow builders report the FLOPs
    /// actually executed (diagonal blocks compute fully and mask).
    pub fn matmul_flops(&self) -> u64 {
        if self.causal {
            // Σ_i 2·(i+1)·D over rows, ×2 matmuls: 2·S·(S+1)·D per head.
            2 * self.batch * self.heads * self.seq * (self.seq + 1) * self.head_dim
        } else {
            4 * self.batch * self.heads * self.seq * self.seq * self.head_dim
        }
    }

    /// Minimal (compulsory) HBM traffic in bytes: read Q, K, V and write O
    /// exactly once.
    pub fn compulsory_bytes(&self) -> u64 {
        4 * self.batch * self.heads * self.seq * self.head_dim * Self::BYTES_PER_ELEM
    }

    /// Short label like `D128-S4096`.
    pub fn label(&self) -> String {
        format!("D{}-S{}", self.head_dim, self.seq)
    }
}

/// The evaluated MHA dataflow variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    Flash2,
    Flash3,
    Flat,
    FlatColl,
    FlatAsyn,
}

pub const ALL_DATAFLOWS: [Dataflow; 5] = [
    Dataflow::Flash2,
    Dataflow::Flash3,
    Dataflow::Flat,
    Dataflow::FlatColl,
    Dataflow::FlatAsyn,
];

impl Dataflow {
    pub fn label(self) -> &'static str {
        match self {
            Dataflow::Flash2 => "FA-2",
            Dataflow::Flash3 => "FA-3",
            Dataflow::Flat => "Flat",
            Dataflow::FlatColl => "FlatColl",
            Dataflow::FlatAsyn => "FlatAsyn",
        }
    }

    pub fn from_label(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fa-2" | "fa2" | "flash2" => Some(Dataflow::Flash2),
            "fa-3" | "fa3" | "flash3" => Some(Dataflow::Flash3),
            "flat" => Some(Dataflow::Flat),
            "flatcoll" | "flat-coll" => Some(Dataflow::FlatColl),
            "flatasyn" | "flat-asyn" | "flatasync" => Some(Dataflow::FlatAsyn),
            _ => None,
        }
    }

    /// Does this dataflow group tiles (FlatAttention family)?
    pub fn is_flat(self) -> bool {
        matches!(self, Dataflow::Flat | Dataflow::FlatColl | Dataflow::FlatAsyn)
    }
}

/// Build the op-graph program for a dataflow.
///
/// `group` is the (square `Gx = Gy`) FlatAttention group edge; ignored by
/// the FlashAttention variants. Collective hardware support follows the
/// dataflow (`Flat` forces software collectives, `FlatColl`/`FlatAsyn`
/// force hardware collectives) so a single `ArchConfig` can be used for
/// every bar of Fig. 3.
pub fn build_program(arch: &ArchConfig, wl: &Workload, df: Dataflow, group: usize) -> Program {
    build_program_into(Program::new(), arch, wl, df, group)
}

/// Like [`build_program`], but constructing into buffers recycled by a
/// [`ProgramArena`] — the sweep-scale entry point used by [`run`].
pub fn build_program_in(
    arena: &mut ProgramArena,
    arch: &ArchConfig,
    wl: &Workload,
    df: Dataflow,
    group: usize,
) -> Program {
    build_program_into(arena.fresh(), arch, wl, df, group)
}

fn build_program_into(
    prog: Program,
    arch: &ArchConfig,
    wl: &Workload,
    df: Dataflow,
    group: usize,
) -> Program {
    // Reject degenerate groups up front with a diagnosable error: a zero
    // group used to reach `FlatTiling::resolve` (division by zero) and
    // `tracked_tile` (integer underflow) instead.
    assert!(
        !df.is_flat() || group > 0,
        "{df:?} requires a FlatAttention group edge >= 1 (got 0); pick a group that divides \
         the {}x{} mesh",
        arch.mesh_x,
        arch.mesh_y
    );
    let prog = match df {
        Dataflow::Flash2 => flash::flash_program_ext_in(prog, arch, wl, false, true),
        Dataflow::Flash3 => flash::flash_program_ext_in(prog, arch, wl, true, true),
        Dataflow::Flat => {
            let mut a = arch.clone();
            a.noc.hw_collectives = false;
            flat::flat_program_ext_in(prog, &a, wl, group, false, true)
        }
        Dataflow::FlatColl => {
            let mut a = arch.clone();
            a.noc.hw_collectives = true;
            flat::flat_program_ext_in(prog, &a, wl, group, false, true)
        }
        Dataflow::FlatAsyn => {
            let mut a = arch.clone();
            a.noc.hw_collectives = true;
            flat::flat_program_ext_in(prog, &a, wl, group, true, true)
        }
    };
    #[cfg(debug_assertions)]
    if let Err(e) = prog.validate() {
        panic!("build_program produced an invalid DAG for {df:?}: {e}");
    }
    prog
}

thread_local! {
    /// Per-worker-thread arena: `run` recycles program buffers across the
    /// experiments a coordinator worker executes (§Perf).
    static RUN_ARENA: std::cell::RefCell<ProgramArena> =
        std::cell::RefCell::new(ProgramArena::new());
}

/// Build and execute in one step, tracking the canonical critical tile.
/// Program buffers are recycled through a thread-local [`ProgramArena`].
pub fn run(arch: &ArchConfig, wl: &Workload, df: Dataflow, group: usize) -> RunStats {
    let tracked = tracked_tile(arch, df, group);
    RUN_ARENA.with(|cell| {
        let mut arena = cell.borrow_mut();
        let program = build_program_in(&mut arena, arch, wl, df, group);
        let stats = execute(&program, tracked);
        arena.recycle(program);
        stats
    })
}

/// The representative tile whose timeline feeds the runtime breakdown:
/// for FlatAttention, the south-west corner tile of group 0 (it loads Q
/// *and* K/V and owns its row/column collectives); for FlashAttention,
/// tile 0 (all tiles behave identically). The stream containing this tile
/// is always built unfolded (see [`set_symmetry_folding`]).
///
/// Degenerate `group` values clamp to a valid group edge: `group == 0`
/// used to underflow `gy - 1` (a panic in debug builds, a garbage tile id
/// in release builds).
pub fn tracked_tile(arch: &ArchConfig, df: Dataflow, group: usize) -> u32 {
    if df.is_flat() {
        let gy = group.clamp(1, arch.mesh_y);
        arch.tile_id(0, gy - 1)
    } else {
        0
    }
}

/// Serializes tests that toggle the builder globals
/// ([`set_template_stamping`], [`set_symmetry_folding`]) or that build
/// pairs of programs expected to be structurally identical: without this,
/// a concurrent test could flip a global mid-"naive" build, making the
/// stamped-vs-naive (or folded-vs-unfolded) oracle compare two builds of
/// the same mode (trivially green) or of accidentally different modes
/// (spuriously red). Lock around the whole toggle+build+restore sequence;
/// recover from poisoning so one failed test doesn't cascade.
#[cfg(test)]
pub(crate) static GLOBAL_SWITCH_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Assert two programs are identical op for op, dep for dep — the
/// correctness oracle for template stamping (a stamped build must be
/// indistinguishable from the naive emission).
#[cfg(test)]
pub(crate) fn assert_programs_equal(a: &Program, b: &Program) {
    assert_eq!(a.num_ops(), b.num_ops(), "op count");
    assert_eq!(a.num_resources(), b.num_resources(), "resource count");
    assert_eq!(a.flops, b.flops, "flops");
    assert_eq!(a.fold, b.fold, "fold accounting");
    for (i, (x, y)) in a.ops().iter().zip(b.ops().iter()).enumerate() {
        assert_eq!(x.resource, y.resource, "op {i}: resource");
        assert_eq!(x.occupancy, y.occupancy, "op {i}: occupancy");
        assert_eq!(x.latency, y.latency, "op {i}: latency");
        assert_eq!(x.component, y.component, "op {i}: component");
        assert_eq!(x.tile, y.tile, "op {i}: tile");
        assert_eq!(x.hbm_bytes, y.hbm_bytes, "op {i}: hbm_bytes");
        assert_eq!(a.deps_of(x), b.deps_of(y), "op {i}: deps");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_flops() {
        let wl = Workload::new(4096, 128, 32, 2);
        // 4·B·H·S²·D = 4·2·32·4096²·128
        assert_eq!(wl.matmul_flops(), 549_755_813_888 * 1_000 / 1_000);
        assert_eq!(wl.matmul_flops(), 4 * 2 * 32 * 4096 * 4096 * 128);
    }

    #[test]
    fn dataflow_labels_round_trip() {
        for df in ALL_DATAFLOWS {
            assert_eq!(Dataflow::from_label(df.label()), Some(df));
        }
        assert_eq!(Dataflow::from_label("nope"), None);
    }

    #[test]
    fn compulsory_traffic() {
        let wl = Workload::new(1024, 64, 8, 1);
        assert_eq!(wl.compulsory_bytes(), 4 * 8 * 1024 * 64 * 2);
    }

    #[test]
    fn arena_build_matches_fresh_build() {
        // Recycled buffers must not leak state between experiments: an
        // arena-backed build equals a fresh build, for every dataflow in
        // sequence through the same arena. Holds the switch lock so a
        // concurrent toggle cannot make the pair structurally different.
        let _guard = GLOBAL_SWITCH_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let arch = crate::arch::presets::table2(8);
        let wl = Workload::new(512, 64, 4, 1);
        let mut arena = ProgramArena::new();
        for df in ALL_DATAFLOWS {
            let fresh = build_program(&arch, &wl, df, 8);
            let pooled = build_program_in(&mut arena, &arch, &wl, df, 8);
            assert_programs_equal(&fresh, &pooled);
            let tracked = tracked_tile(&arch, df, 8);
            assert_eq!(execute(&fresh, tracked), execute(&pooled, tracked));
            arena.recycle(pooled);
        }
    }

    #[test]
    fn tracked_tile_clamps_degenerate_groups() {
        let arch = crate::arch::presets::table2(8);
        // Regression: `group == 0` used to compute `0 - 1` on the group
        // edge (debug panic / release garbage tile id). Now clamps.
        assert_eq!(tracked_tile(&arch, Dataflow::FlatColl, 0), 0);
        // Oversized groups clamp to the mesh edge.
        assert_eq!(tracked_tile(&arch, Dataflow::FlatColl, 64), arch.tile_id(0, 7));
        // FlashAttention ignores the group entirely.
        assert_eq!(tracked_tile(&arch, Dataflow::Flash2, 0), 0);
    }

    #[test]
    #[should_panic(expected = "group edge >= 1")]
    fn build_program_rejects_group_zero_for_flat() {
        // Regression: this used to die deep inside `FlatTiling::resolve`
        // with a bare division-by-zero panic.
        let arch = crate::arch::presets::table2(8);
        let wl = Workload::new(256, 64, 1, 1);
        let _ = build_program(&arch, &wl, Dataflow::FlatColl, 0);
    }

    #[test]
    fn flash_tolerates_group_zero() {
        // The group parameter is documented as ignored for FlashAttention;
        // a zero group must not panic anywhere on that path.
        let arch = crate::arch::presets::table2(8);
        let wl = Workload::new(256, 64, 2, 1);
        let stats = run(&arch, &wl, Dataflow::Flash2, 0);
        assert!(stats.makespan > 0);
    }
}
