//! Block/slice sizing under the L1 capacity constraint.
//!
//! FlashAttention on a tile must simultaneously host Qᵢ, Kⱼᵀ, Vⱼ, Oᵢ plus
//! the score block Sᵢ in L1, with Kᵀ/V double-buffered for load/compute
//! overlap. With square blocks `M := B_r = B_c` in FP16 the footprint is
//!
//! ```text
//! sync:  bytes(M) = 2 · (Q + O + K + V + dbK + dbV : 6·M·D  +  S: M²)
//! async: bytes(M) = 2 · (8·M·D + 2·M²)
//! ```
//!
//! where the asynchronous schedule (FA-3 / FlatAsyn, §III-C) keeps *two*
//! in-flight row blocks that share the Kᵀ/V stream (the papers' footnote 3
//! variant — two Q/O/S working sets, one double-buffered K/V pair).
//!
//! FlatAttention applies the same budget to the per-tile *slice* `t`
//! (= B_r/G_y = B_c/G_x, kept square per §IV), so the group-level block is
//! `M = t·G` — the aggregate-L1 effect that shrinks HBM I/O by √N. Shorter
//! sequences cap the slice at `S/G` (the over-flattening regime of §V-B).

use crate::arch::{ArchConfig, TileConfig};

/// FP16 bytes of the synchronous working set at block/slice size `m`.
pub fn working_set_bytes(m: u64, d: u64) -> u64 {
    2 * (6 * m * d + m * m)
}

/// FP16 bytes of the asynchronous (two row-block, shared-K/V) working set.
pub fn working_set_async_bytes(m: u64, d: u64) -> u64 {
    2 * (8 * m * d + 2 * m * m)
}

/// Largest size (multiple of `quantum`) whose working set fits.
fn max_fitting(budget: u64, d: u64, quantum: u64, footprint: fn(u64, u64) -> u64) -> u64 {
    let mut m = quantum;
    while footprint(m + quantum, d) <= budget {
        m += quantum;
    }
    m
}

/// FlashAttention block size `M` for one tile (Algorithm 1), maximizing L1
/// occupancy; `asynchronous` selects the FA-3 two-row-block footprint.
pub fn flash_block_size(tile: &TileConfig, d: u64, asynchronous: bool) -> u64 {
    let fp = if asynchronous { working_set_async_bytes } else { working_set_bytes };
    max_fitting(tile.l1_bytes(), d, 32, fp)
}

/// FlatAttention per-tile slice size `t` (Algorithm 2).
pub fn flat_slice_size(tile: &TileConfig, d: u64, seq: u64, group: u64, asynchronous: bool) -> u64 {
    let fp = if asynchronous { working_set_async_bytes } else { working_set_bytes };
    let cap = max_fitting(tile.l1_bytes(), d, 16, fp);
    let seq_cap = (seq / group).max(1);
    cap.min(seq_cap)
}

/// Resolved FlatAttention tiling for a workload on an architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlatTiling {
    /// Group edge (square groups: Gx = Gy = group).
    pub group: u64,
    /// Per-tile slice edge `t`.
    pub slice: u64,
    /// Group-level block size `B_r = B_c = t · group`.
    pub block: u64,
    /// Row blocks per head: `T_r = ⌈S / B_r⌉`.
    pub t_r: u64,
    /// Column blocks per head: `T_c = ⌈S / B_c⌉`.
    pub t_c: u64,
    /// Number of groups on the mesh.
    pub num_groups: u64,
}

impl FlatTiling {
    pub fn resolve(arch: &ArchConfig, d: u64, seq: u64, group: usize, asynchronous: bool) -> Self {
        assert!(
            group > 0 && arch.mesh_x % group == 0 && arch.mesh_y % group == 0,
            "group {group} must divide the {}x{} mesh",
            arch.mesh_x,
            arch.mesh_y
        );
        let g = group as u64;
        let slice = flat_slice_size(&arch.tile, d, seq, g, asynchronous);
        let block = slice * g;
        Self {
            group: g,
            slice,
            block,
            t_r: seq.div_ceil(block),
            t_c: seq.div_ceil(block),
            num_groups: ((arch.mesh_x / group) * (arch.mesh_y / group)) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{table1, table1_tile};

    #[test]
    fn flash_sync_block_maximal() {
        let t = table1_tile();
        let m = flash_block_size(&t, 128, false);
        assert_eq!(m, 192);
        assert!(working_set_bytes(m, 128) <= t.l1_bytes());
        assert!(working_set_bytes(m + 32, 128) > t.l1_bytes());
    }

    #[test]
    fn flash_async_block_is_paper_m128() {
        // FA-3's two-row-block schedule lands on the paper's canonical
        // M = 128 at D = 128 (16.5× I/O ratio vs the full-chip Flat group).
        let t = table1_tile();
        assert_eq!(flash_block_size(&t, 128, true), 128);
    }

    #[test]
    fn flash_block_d64_larger() {
        let t = table1_tile();
        assert!(flash_block_size(&t, 64, false) > flash_block_size(&t, 128, false));
    }

    #[test]
    fn flat_slice_caps_by_sequence() {
        let t = table1_tile();
        // S=512 on a 32-wide group: slice = 512/32 = 16 (paper Fig. 4).
        assert_eq!(flat_slice_size(&t, 128, 512, 32, false), 16);
        assert_eq!(flat_slice_size(&t, 128, 512, 32, true), 16);
        // S=4096, G=32: slice 128 for both schedules (Fig. 4 labels).
        assert_eq!(flat_slice_size(&t, 128, 4096, 32, false), 128);
        assert_eq!(flat_slice_size(&t, 128, 4096, 32, true), 128);
        // Long sequence, small group: pure capacity cap.
        let cap = flat_slice_size(&t, 128, 65536, 4, false);
        assert!(working_set_bytes(cap, 128) <= t.l1_bytes());
        assert!(working_set_bytes(cap + 16, 128) > t.l1_bytes());
    }

    #[test]
    fn tiling_resolve_table1() {
        let a = table1();
        let t = FlatTiling::resolve(&a, 128, 4096, 32, false);
        assert_eq!(t.slice, 128);
        assert_eq!(t.block, 4096);
        assert_eq!(t.t_r, 1);
        assert_eq!(t.t_c, 1);
        assert_eq!(t.num_groups, 1);

        let t8 = FlatTiling::resolve(&a, 128, 4096, 8, false);
        assert_eq!(t8.num_groups, 16);
        assert_eq!(t8.block, t8.slice * 8);
        assert!(t8.t_r >= 1);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn group_must_divide_mesh() {
        let a = table1();
        FlatTiling::resolve(&a, 128, 4096, 12, false);
    }

    #[test]
    fn io_reduction_formula_example() {
        // §III-A: S=4096, M=128, N=64 ⇒ 6.6× reduction.
        let (s, m, n) = (4096.0_f64, 128.0_f64, 64.0_f64);
        let ratio = (1.0 + s / m) / (1.0 + s / (n.sqrt() * m));
        assert!((ratio - 6.6).abs() < 0.1, "ratio {ratio:.2}");
    }

    #[test]
    fn paper_headline_io_ratio_16x() {
        // FA-3 (M=128) vs FlatAttention on the full 32×32 mesh at S=4096:
        // (1 + 4096/128) / (1 + 4096/4096) = 16.5×.
        let t = table1_tile();
        let m_fa3 = flash_block_size(&t, 128, true) as f64;
        let a = table1();
        let flat = FlatTiling::resolve(&a, 128, 4096, 32, true);
        let ratio = (1.0 + 4096.0 / m_fa3) / (1.0 + 4096.0 / flat.block as f64);
        assert!((ratio - 16.5).abs() < 0.6, "ratio {ratio:.2}");
    }
}
