//! Block/slice sizing under the L1 capacity constraint.
//!
//! FlashAttention on a tile must simultaneously host Qᵢ, Kⱼᵀ, Vⱼ, Oᵢ plus
//! the score block Sᵢ in L1, with Kᵀ/V double-buffered for load/compute
//! overlap. With square blocks `M := B_r = B_c` in FP16 the footprint is
//!
//! ```text
//! sync:  bytes(M) = 2 · (Q + O + K + V + dbK + dbV : 6·M·D  +  S: M²)
//! async: bytes(M) = 2 · (8·M·D + 2·M²)
//! ```
//!
//! where the asynchronous schedule (FA-3 / FlatAsyn, §III-C) keeps *two*
//! in-flight row blocks that share the Kᵀ/V stream (the papers' footnote 3
//! variant — two Q/O/S working sets, one double-buffered K/V pair).
//!
//! FlatAttention applies the same budget to the per-tile *slice* `t`
//! (= B_r/G_y = B_c/G_x, kept square per §IV), so the group-level block is
//! `M = t·G` — the aggregate-L1 effect that shrinks HBM I/O by √N. Shorter
//! sequences cap the slice at `S/G` (the over-flattening regime of §V-B).
//!
//! # Serving shapes (GQA / decode)
//!
//! The serving generalization decouples the *query-row* extent from the
//! *K/V-column* extent. A row block holds `rows = share · B_r` stacked
//! query rows — `share` query heads of one KV group processed jointly
//! against a single resident K/V block (the GQA sharing that cuts K/V
//! traffic by `kv_heads/heads`), each contributing `B_r ≤ q_len` rows.
//! The generalized footprint is
//!
//! ```text
//! sync:  bytes(rows, B_c) = 2 · (2·rows·D + 4·B_c·D + rows·B_c)
//! async: bytes(rows, B_c) = 2 · (2·(2·rows·D + rows·B_c) + 4·B_c·D)
//! ```
//!
//! which reduces *exactly* to the square formulas at `rows == B_c` (dense
//! MHA prefill keeps its historical block sizes bit-for-bit). When even
//! the minimal block overflows L1 (extreme MQA share × head_dim), `share`
//! falls back by halving — K/V is then re-read once per share-chunk, the
//! honest capacity cost. Decode (`q_len == 1`) clamps `B_r = 1` and lets
//! `B_c` grow into the freed budget, streaming the cache in fat chunks.

use crate::arch::{ArchConfig, TileConfig};
use crate::dataflow::Workload;

/// FP16 bytes of the synchronous working set at square block/slice size
/// `m` (dense-MHA reference shape; see [`working_set_rows_bytes`]).
pub fn working_set_bytes(m: u64, d: u64) -> u64 {
    2 * (6 * m * d + m * m)
}

/// FP16 bytes of the asynchronous (two row-block, shared-K/V) working set.
pub fn working_set_async_bytes(m: u64, d: u64) -> u64 {
    2 * (8 * m * d + 2 * m * m)
}

/// FP16 bytes of the synchronous serving working set: `rows` stacked query
/// rows (Q + O + score) against a `b_c`-column double-buffered K/V pair.
/// `working_set_rows_bytes(m, m, d) == working_set_bytes(m, d)`.
pub fn working_set_rows_bytes(rows: u64, b_c: u64, d: u64) -> u64 {
    2 * (2 * rows * d + 4 * b_c * d + rows * b_c)
}

/// Asynchronous serving working set: two in-flight row blocks (Q/O/score
/// each) sharing one double-buffered K/V pair.
/// `working_set_rows_async_bytes(m, m, d) == working_set_async_bytes(m, d)`.
pub fn working_set_rows_async_bytes(rows: u64, b_c: u64, d: u64) -> u64 {
    2 * (2 * (2 * rows * d + rows * b_c) + 4 * b_c * d)
}

/// Largest size (multiple of `quantum`) whose working set fits.
fn max_fitting(budget: u64, d: u64, quantum: u64, footprint: fn(u64, u64) -> u64) -> u64 {
    let mut m = quantum;
    while footprint(m + quantum, d) <= budget {
        m += quantum;
    }
    m
}

/// Largest share of jointly-processed query heads (halving descent from
/// `q_per_kv`) whose *minimal* block still fits the budget. `rows_min` is
/// the per-head row extent at the minimal block.
fn max_share(
    budget: u64,
    d: u64,
    q_per_kv: u64,
    rows_min: u64,
    quantum: u64,
    fp: fn(u64, u64, u64) -> u64,
) -> u64 {
    let mut share = q_per_kv.max(1);
    while share > 1 && fp(share * rows_min, quantum, d) > budget {
        share = share.div_ceil(2);
    }
    share
}

/// FlashAttention block size `M` for one tile (Algorithm 1), maximizing L1
/// occupancy; `asynchronous` selects the FA-3 two-row-block footprint.
/// This is the dense-MHA square sizing — serving shapes resolve through
/// [`FlashTiling`], which reduces to this when `share == 1` and
/// `q_len >= M`.
pub fn flash_block_size(tile: &TileConfig, d: u64, asynchronous: bool) -> u64 {
    let fp = if asynchronous { working_set_async_bytes } else { working_set_bytes };
    max_fitting(tile.l1_bytes(), d, 32, fp)
}

/// FlatAttention per-tile slice size `t` (Algorithm 2), dense-MHA square
/// sizing (see [`FlatTiling`] for serving shapes).
pub fn flat_slice_size(tile: &TileConfig, d: u64, seq: u64, group: u64, asynchronous: bool) -> u64 {
    let fp = if asynchronous { working_set_async_bytes } else { working_set_bytes };
    let cap = max_fitting(tile.l1_bytes(), d, 16, fp);
    let seq_cap = (seq / group).max(1);
    cap.min(seq_cap)
}

/// Resolved FlashAttention tiling for a (possibly serving-shaped)
/// workload: per-head query-row blocks of `b_r`, K/V column blocks of
/// `b_c`, with `share` query heads of each KV group stacked per block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlashTiling {
    /// Query rows per row block, per head (`min(b_c, q_len)` unless the
    /// stacked footprint forced it smaller; 1 for decode).
    pub b_r: u64,
    /// K/V columns per block (multiple of 32; the historical `M` for
    /// dense MHA prefill).
    pub b_c: u64,
    /// Query heads of one KV group stacked per block (each K/V block is
    /// loaded once and shared across `share` heads' rows).
    pub share: u64,
    /// Share-chunks per KV group: `ceil(q_per_kv / share)`. K/V is
    /// re-read once per chunk — 1 whenever the full group fits.
    pub chunks: u64,
    /// Row blocks per head: `ceil(q_len / b_r)`.
    pub t_r: u64,
    /// K/V column blocks: `ceil(kv_len / b_c)`.
    pub t_c: u64,
}

impl FlashTiling {
    /// Pick block sizes for `wl` that fit the tile's L1 budget.
    pub fn resolve(tile: &TileConfig, wl: &Workload, asynchronous: bool) -> Self {
        let budget = tile.l1_bytes();
        let d = wl.head_dim;
        let q_len = wl.q_len();
        let fp = if asynchronous { working_set_rows_async_bytes } else { working_set_rows_bytes };
        const Q: u64 = 32;

        let share = max_share(budget, d, wl.q_per_kv(), q_len.min(Q), Q, fp);
        // Grow the K/V block while the stacked footprint fits — identical
        // to `flash_block_size` when share == 1 and q_len >= the result.
        let rows_at = |m: u64| share * m.min(q_len);
        let mut b_c = Q;
        while fp(rows_at(b_c + Q), b_c + Q, d) <= budget {
            b_c += Q;
        }
        // Query-row edge; shrinks below b_c only when even the minimal
        // block overflows (tiny L1 / extreme shapes — the documented
        // clamp is then b_r == 1, b_c == 32).
        let mut b_r = b_c.min(q_len);
        while b_r > 1 && fp(share * b_r, b_c, d) > budget {
            b_r = (b_r / 2).max(1);
        }
        let q_per_kv = wl.q_per_kv();
        Self {
            b_r,
            b_c,
            share,
            chunks: q_per_kv.div_ceil(share),
            t_r: q_len.div_ceil(b_r),
            t_c: wl.kv_len().div_ceil(b_c),
        }
    }
}

/// Resolved FlatAttention tiling for a workload on an architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlatTiling {
    /// Group edge (square groups: Gx = Gy = group).
    pub group: u64,
    /// Per-tile K/V slice edge `t`.
    pub slice: u64,
    /// Group-level K/V block size `B_c = t · group` (also the query-row
    /// block extent for prefill; decode rows clamp to `q_len`).
    pub block: u64,
    /// Row blocks per head: `T_r = ⌈q_len / B_r⌉` (1 for decode).
    pub t_r: u64,
    /// Column blocks per head: `T_c = ⌈kv_len / B_c⌉`.
    pub t_c: u64,
    /// Number of groups on the mesh.
    pub num_groups: u64,
    /// Query heads of one KV group stacked per block (K/V loaded and
    /// column-multicast once per stack).
    pub share: u64,
    /// Share-chunks per KV group: `ceil(q_per_kv / share)`.
    pub chunks: u64,
}

impl FlatTiling {
    /// Pick group-level block/chunk sizes for `wl` on `arch`.
    pub fn resolve(arch: &ArchConfig, wl: &Workload, group: usize, asynchronous: bool) -> Self {
        assert!(
            group > 0 && arch.mesh_x % group == 0 && arch.mesh_y % group == 0,
            "group {group} must divide the {}x{} mesh",
            arch.mesh_x,
            arch.mesh_y
        );
        let g = group as u64;
        let d = wl.head_dim;
        let budget = arch.tile.l1_bytes();
        let fp = if asynchronous { working_set_rows_async_bytes } else { working_set_rows_bytes };
        const Q: u64 = 16;

        // Per-tile row extent at the minimal slice: decode blocks put a
        // single (padded) row on each tile regardless of the slice, so
        // the share descent must not price them at a full 16-row slice —
        // that would halve `share` (hence multiply the K/V re-read
        // chunks) far below what L1 actually holds.
        let rows_min = wl.q_len().div_ceil(g).clamp(1, Q);
        let share = max_share(budget, d, wl.q_per_kv(), rows_min, Q, fp);
        // Square search with `share` stacked row slices per tile — at
        // share == 1 this is exactly `flat_slice_size`. The builder's
        // actual per-tile rows are `share · min(slice, ceil(q_len/g))`
        // ≤ max(share · slice, share · rows_min), both of which fit.
        let mut cap = Q;
        while fp(share * (cap + Q), cap + Q, d) <= budget {
            cap += Q;
        }
        let seq_cap = (wl.kv_len() / g).max(1);
        let slice = cap.min(seq_cap);
        let block = slice * g;
        let q_per_kv = wl.q_per_kv();
        Self {
            group: g,
            slice,
            block,
            t_r: wl.q_len().div_ceil(block),
            t_c: wl.kv_len().div_ceil(block),
            num_groups: ((arch.mesh_x / group) * (arch.mesh_y / group)) as u64,
            share,
            chunks: q_per_kv.div_ceil(share),
        }
    }
}

/// Sliding-window block bounds for a row block spanning global query
/// positions `[row_start, row_end)` over `b_c`-wide K/V blocks. Returns
/// `(j_lo, win_until)`: blocks below `j_lo` hold only tokens below every
/// row's window start (`pos - W + 1`) and are skipped entirely; blocks in
/// `[j_lo, win_until)` straddle some row's window start and pay the prefix
/// mask on the vector engine (the mirror of [`causal_mask_from`]'s suffix
/// rule). `(0, 0)` when `window == 0` (unlimited) — dense emission is
/// untouched — and likewise when `window >= row_end`, so a window covering
/// the whole prefix reproduces dense causal attention op for op.
pub(crate) fn window_block_range(
    row_start: u64,
    row_end: u64,
    window: u64,
    b_c: u64,
    t_c_eff: u64,
) -> (u64, u64) {
    if window == 0 {
        return (0, 0);
    }
    // First token visible to ANY row: the first row's window start.
    let j_lo = ((row_start + 1).saturating_sub(window) / b_c).min(t_c_eff);
    // Blocks starting below the LAST row's window start contain some
    // (row, token) pair the window masks.
    let win_until = row_end.saturating_sub(window).div_ceil(b_c).min(t_c_eff);
    (j_lo, win_until)
}

/// First K/V block index whose *real* columns extend past `row_start`
/// (the global position of a row block's first query row): blocks at or
/// after it straddle the causal diagonal and pay the triangular mask on
/// the vector engine; blocks before it are fully visible. Returns
/// `t_c_eff` when no block needs masking. With square blocks this is the
/// diagonal block index `i` — the historical `j == i` mask rule — and it
/// generalizes to the rectangular (decode / stacked-GQA) geometries.
pub(crate) fn causal_mask_from(row_start: u64, b_c: u64, kv_len: u64, t_c_eff: u64) -> u64 {
    // Block j's last real column is min((j+1)·b_c, kv_len) - 1; it needs
    // masking iff that column exceeds row_start.
    if kv_len < row_start + 2 {
        return t_c_eff; // the row sits at the end of the range: all visible
    }
    ((row_start + 2).div_ceil(b_c) - 1).min(t_c_eff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{table1, table1_tile, table2};
    use crate::dataflow::Phase;
    use crate::util::quickcheck::{check, forall_cases};

    #[test]
    fn flash_sync_block_maximal() {
        let t = table1_tile();
        let m = flash_block_size(&t, 128, false);
        assert_eq!(m, 192);
        assert!(working_set_bytes(m, 128) <= t.l1_bytes());
        assert!(working_set_bytes(m + 32, 128) > t.l1_bytes());
    }

    #[test]
    fn flash_async_block_is_paper_m128() {
        // FA-3's two-row-block schedule lands on the paper's canonical
        // M = 128 at D = 128 (16.5× I/O ratio vs the full-chip Flat group).
        let t = table1_tile();
        assert_eq!(flash_block_size(&t, 128, true), 128);
    }

    #[test]
    fn flash_block_d64_larger() {
        let t = table1_tile();
        assert!(flash_block_size(&t, 64, false) > flash_block_size(&t, 128, false));
    }

    #[test]
    fn serving_footprints_reduce_to_square() {
        for d in [64u64, 128] {
            for m in [32u64, 128, 192] {
                assert_eq!(working_set_rows_bytes(m, m, d), working_set_bytes(m, d));
                assert_eq!(
                    working_set_rows_async_bytes(m, m, d),
                    working_set_async_bytes(m, d)
                );
            }
        }
    }

    #[test]
    fn flash_tiling_mha_prefill_matches_square_sizing() {
        // Dense MHA prefill must reproduce the historical block sizes
        // bit-for-bit (the whole paper-claims test wall depends on it).
        let t = table1_tile();
        for (d, s) in [(128u64, 4096u64), (64, 1024), (128, 512)] {
            for asyn in [false, true] {
                let wl = Workload::new(s, d, 32, 2);
                let ft = FlashTiling::resolve(&t, &wl, asyn);
                let m = flash_block_size(&t, d, asyn);
                assert_eq!((ft.b_r, ft.b_c, ft.share, ft.chunks), (m, m, 1, 1), "D{d} S{s}");
                assert_eq!(ft.t_r, s.div_ceil(m));
                assert_eq!(ft.t_c, s.div_ceil(m));
            }
        }
    }

    #[test]
    fn flash_tiling_gqa_stacks_and_shrinks() {
        // GQA stacks the KV group's rows: the stacked footprint must fit,
        // and the whole group shares one K/V residency when it does.
        let t = table1_tile();
        let wl = Workload::new(4096, 128, 32, 1).with_kv_heads(8); // q_per_kv = 4
        let ft = FlashTiling::resolve(&t, &wl, false);
        assert_eq!(ft.share, 4);
        assert_eq!(ft.chunks, 1);
        assert!(working_set_rows_bytes(ft.share * ft.b_r, ft.b_c, 128) <= t.l1_bytes());
        // Stacking 4 heads costs block size vs MHA.
        assert!(ft.b_c <= flash_block_size(&t, 128, false));
    }

    #[test]
    fn flash_tiling_decode_clamps_rows_and_fattens_kv() {
        let t = table1_tile();
        let wl = Workload::new(4096, 128, 32, 1).decode();
        let ft = FlashTiling::resolve(&t, &wl, false);
        assert_eq!(ft.b_r, 1);
        assert_eq!(ft.t_r, 1);
        // With one resident query row the K/V block outgrows the square
        // prefill block — decode streams the cache in fat chunks.
        assert!(ft.b_c > flash_block_size(&t, 128, false));
        assert!(working_set_rows_bytes(ft.share, ft.b_c, 128) <= t.l1_bytes());
    }

    #[test]
    fn flat_slice_caps_by_sequence() {
        let t = table1_tile();
        // S=512 on a 32-wide group: slice = 512/32 = 16 (paper Fig. 4).
        assert_eq!(flat_slice_size(&t, 128, 512, 32, false), 16);
        assert_eq!(flat_slice_size(&t, 128, 512, 32, true), 16);
        // S=4096, G=32: slice 128 for both schedules (Fig. 4 labels).
        assert_eq!(flat_slice_size(&t, 128, 4096, 32, false), 128);
        assert_eq!(flat_slice_size(&t, 128, 4096, 32, true), 128);
        // Long sequence, small group: pure capacity cap.
        let cap = flat_slice_size(&t, 128, 65536, 4, false);
        assert!(working_set_bytes(cap, 128) <= t.l1_bytes());
        assert!(working_set_bytes(cap + 16, 128) > t.l1_bytes());
    }

    #[test]
    fn tiling_resolve_table1() {
        let a = table1();
        let t = FlatTiling::resolve(&a, &Workload::new(4096, 128, 32, 2), 32, false);
        assert_eq!(t.slice, 128);
        assert_eq!(t.block, 4096);
        assert_eq!(t.t_r, 1);
        assert_eq!(t.t_c, 1);
        assert_eq!(t.num_groups, 1);
        assert_eq!((t.share, t.chunks), (1, 1));

        let t8 = FlatTiling::resolve(&a, &Workload::new(4096, 128, 32, 2), 8, false);
        assert_eq!(t8.num_groups, 16);
        assert_eq!(t8.block, t8.slice * 8);
        assert!(t8.t_r >= 1);
    }

    #[test]
    fn flat_tiling_mha_matches_slice_fn() {
        let a = table1();
        for (d, s, g, asyn) in [(128u64, 4096u64, 8usize, false), (64, 1024, 16, true)] {
            let wl = Workload::new(s, d, 32, 1);
            let t = FlatTiling::resolve(&a, &wl, g, asyn);
            assert_eq!(t.slice, flat_slice_size(&a.tile, d, s, g as u64, asyn));
        }
    }

    #[test]
    fn flat_tiling_decode_single_row_block() {
        let a = table1();
        let wl = Workload::new(4096, 128, 32, 1).with_kv_heads(8).decode();
        let t = FlatTiling::resolve(&a, &wl, 8, false);
        assert_eq!(t.t_r, 1, "decode has exactly one row block");
        assert!(t.t_c >= 1);
        assert_eq!(t.share, 4);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn group_must_divide_mesh() {
        let a = table1();
        FlatTiling::resolve(&a, &Workload::new(4096, 128, 32, 2), 12, false);
    }

    #[test]
    fn degenerate_serving_shapes_resolve_safely() {
        // PR-2 crash-class lesson applied proactively: S=1, S < group,
        // d > S, extreme MQA shares — sizing must not panic, results must
        // respect the invariants, and whenever a minimal block fits at
        // all the resolved block must fit the tile scratchpad.
        let arches = [table1(), table2(8)];
        forall_cases(60, 0x5E41, |rng| {
            let arch = &arches[rng.gen_range(arches.len() as u64) as usize];
            let tile = &arch.tile;
            let budget = tile.l1_bytes();
            let seq = *rng.choose(&[1u64, 2, 3, 5, 7, 16, 31, 63, 100]);
            let d = *rng.choose(&[1u64, 8, 64, 128, 256, 512]);
            let kv_heads = 1 + rng.gen_range(3);
            let q_per_kv = *rng.choose(&[1u64, 2, 4, 32, 128]);
            let heads = kv_heads * q_per_kv;
            let phase = if rng.gen_range(2) == 0 { Phase::Prefill } else { Phase::Decode };
            let asyn = rng.gen_range(2) == 0;
            let wl = Workload::new(seq, d, heads, 1).with_kv_heads(kv_heads).with_phase(phase);
            let fp = if asyn { working_set_rows_async_bytes } else { working_set_rows_bytes };

            let ft = FlashTiling::resolve(tile, &wl, asyn);
            check(
                ft.b_r >= 1
                    && ft.b_r <= wl.q_len().max(1)
                    && ft.b_c >= 32
                    && ft.share >= 1
                    && ft.share <= q_per_kv
                    && ft.chunks == q_per_kv.div_ceil(ft.share)
                    && ft.t_r == wl.q_len().div_ceil(ft.b_r)
                    && ft.t_c == wl.kv_len().div_ceil(ft.b_c),
                format!("flash invariants: {ft:?} for {wl:?}"),
            )?;
            if fp(1, 32, d) <= budget {
                check(
                    fp(ft.share * ft.b_r, ft.b_c, d) <= budget,
                    format!(
                        "flash block overflows L1: {ft:?} for {wl:?} ({} > {budget})",
                        fp(ft.share * ft.b_r, ft.b_c, d)
                    ),
                )?;
            }

            let group = *rng.choose(&[2usize, 4, 8]);
            let t = FlatTiling::resolve(arch, &wl, group, asyn);
            check(
                t.slice >= 1
                    && t.block == t.slice * t.group
                    && t.t_r >= 1
                    && t.t_c >= 1
                    && t.share >= 1
                    && t.share <= q_per_kv
                    && t.t_r == wl.q_len().div_ceil(t.block)
                    && t.t_c == wl.kv_len().div_ceil(t.block),
                format!("flat invariants: {t:?} for {wl:?} g{group}"),
            )?;
            // The builder's per-tile rows are share·min(slice, ⌈q_len/g⌉);
            // the share descent (at the minimal-slice row extent) plus the
            // square cap search guarantee that fits whenever anything does.
            let rows_min = wl.q_len().div_ceil(t.group).clamp(1, 16);
            let rows_actual = t.share * wl.q_len().div_ceil(t.group).min(t.slice).max(1);
            if fp(t.share * rows_min, 16, d) <= budget {
                check(
                    fp(rows_actual, t.slice, d) <= budget,
                    format!("flat slice overflows L1: {t:?} for {wl:?}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn causal_mask_from_matches_square_diagonal() {
        // Square blocks: the first masked block is the diagonal block i.
        for (m, s) in [(192u64, 1024u64), (128, 4096), (64, 512)] {
            let t_c = s.div_ceil(m);
            for i in 0..s.div_ceil(m) {
                let m_r = (s - i * m).min(m);
                let t_c_eff = (i * m + m_r).div_ceil(m);
                assert_eq!(t_c_eff, (i + 1).min(t_c));
                if m_r >= 2 {
                    assert_eq!(causal_mask_from(i * m, m, s, t_c_eff), i, "m{m} s{s} i{i}");
                }
            }
        }
        // Decode: the row is the cache's last position — nothing to mask.
        assert_eq!(causal_mask_from(4095, 256, 4096, 16), 16);
        // Rectangular: rows [0, 64) vs 16-wide K/V blocks — blocks 0..4
        // all straddle the diagonal.
        assert_eq!(causal_mask_from(0, 16, 4096, 4), 0);
    }

    #[test]
    fn window_block_range_bounds() {
        // No window / window covering the whole prefix: dense emission.
        assert_eq!(window_block_range(192, 256, 0, 64, 4), (0, 0));
        assert_eq!(window_block_range(192, 256, 256, 64, 4), (0, 0));
        assert_eq!(window_block_range(192, 256, 4096, 64, 4), (0, 0));
        // W=64 over rows [192, 256): first row sees from 129, last row
        // sees from 192 — block 2 partially visible, blocks 0..2 skipped.
        assert_eq!(window_block_range(192, 256, 64, 64, 4), (2, 3));
        // Exactly block-aligned window start needs no prefix mask.
        let (j_lo, until) = window_block_range(4095, 4096, 1024, 256, 16);
        assert_eq!((j_lo, until), (12, 12));
        // Misaligned decode window: the straddling block pays the mask.
        let (j_lo, until) = window_block_range(4095, 4096, 1000, 256, 16);
        assert_eq!((j_lo, until), (12, 13));
        // j_lo never exceeds win_until, and both clamp to t_c_eff.
        for (rs, re, w, bc, tce) in
            [(0u64, 1u64, 1u64, 32u64, 1u64), (1000, 1064, 3, 32, 34), (7, 8, 8, 32, 1)]
        {
            let (lo, until) = window_block_range(rs, re, w, bc, tce);
            assert!(lo <= until && until <= tce, "({rs},{re},{w},{bc},{tce}) -> ({lo},{until})");
        }
    }

    #[test]
    fn io_reduction_formula_example() {
        // §III-A: S=4096, M=128, N=64 ⇒ 6.6× reduction.
        let (s, m, n) = (4096.0_f64, 128.0_f64, 64.0_f64);
        let ratio = (1.0 + s / m) / (1.0 + s / (n.sqrt() * m));
        assert!((ratio - 6.6).abs() < 0.1, "ratio {ratio:.2}");
    }

    #[test]
    fn paper_headline_io_ratio_16x() {
        // FA-3 (M=128) vs FlatAttention on the full 32×32 mesh at S=4096:
        // (1 + 4096/128) / (1 + 4096/4096) = 16.5×.
        let t = table1_tile();
        let m_fa3 = flash_block_size(&t, 128, true) as f64;
        let a = table1();
        let flat = FlatTiling::resolve(&a, &Workload::new(4096, 128, 32, 2), 32, true);
        let ratio = (1.0 + 4096.0 / m_fa3) / (1.0 + 4096.0 / flat.block as f64);
        assert!((ratio - 16.5).abs() < 0.6, "ratio {ratio:.2}");
    }
}
