//! FlatAttention dataflow (Algorithm 2 + §III-C).
//!
//! A *group* of `G × G` tiles collectively processes one attention block of
//! size `B_r = B_c = t·G` (slice `t` per tile), using the aggregate group
//! L1. Within a group:
//!
//! * west-edge tiles load Q slices from HBM and **row-multicast** them;
//! * south-edge tiles load Kᵀ/V slices and **column-multicast** them;
//! * every tile computes its `t × t` attention-score segment;
//! * softmax row statistics are combined with **row-wise max/sum
//!   reductions** and re-multicast;
//! * O partials are **row-reduced** to the west edge and stored.
//!
//! Distinct groups process distinct blocks — no inter-group communication,
//! exactly like FlashAttention across tiles, but with `√N`-fold lower HBM
//! I/O. The collective primitives run on per-group-row/-column bus
//! resources whose cost follows §II (hardware path-based forwarding or
//! software unicast chains, per `arch.noc.hw_collectives`).
//!
//! Serving shapes compose naturally with the group structure: for GQA/MQA
//! a block stacks the query rows of a whole KV group (`share` heads), so
//! the south-edge K/V loads and their column multicasts happen once per
//! group instead of once per query head — the existing collective is the
//! broadcast that amortizes the shared K/V. Decode blocks hold a single
//! query row, padded across the group's `G` row slices (see
//! `crate::dataflow` § Workload model). Chunked prefill (`kv_prefix`) and
//! sliding windows ride the same block geometry: windowed streams skip
//! the group-level K/V blocks below every row's window start and
//! prefix-mask the straddling block. Composed serving batches
//! ([`flat_batch_program_in`]) place each K/V slice on the channel
//! holding its cache page instead of the fixed column band.
//!
//! The asynchronous variant (`FlatAsyn`) schedules two heads per group as
//! two independent op streams sharing the group's engines and buses
//! (§III-C): matrix multiplications of one head overlap data movement and
//! softmax of the other.
//!
//! §Perf: within a stream, every block with the same row-block index `i`
//! emits an identical op subgraph whose only external dependency is the
//! previous block's barrier. The first such block is built normally and
//! registered as a *template*; all repetitions are instantiated with
//! [`Program::stamp_range`], skipping the cost-model and op-emission work
//! entirely. Stamped and naive builds are op-for-op identical
//! (`tests::stamped_build_is_identical_to_naive_build`).
//!
//! §Shard: under the event-loop partition `Program::seal` derives (see
//! `crate::sim`'s sharding essay), each group's per-tile engine chains,
//! row/column collectives that stay single-owner, and the group's block
//! barrier union into one private shard per group, while HBM-channel ops
//! (and any bus whose ops span tiles) arbitrate in the shared shard — so
//! a multi-group mesh exposes per-group parallelism to
//! `sim::execute_parallel`, exactly the "independent between fabric
//! collectives" structure the paper exploits on the accelerator itself.
//!
//! §Fold: with symmetry folding enabled (synchronous schedules only),
//! every group except group 0 (which holds the breakdown tile) keeps its
//! HBM-channel and bus-collective ops verbatim but collapses the `g²`
//! per-tile compute chains of each inner iteration into *per-row* delay
//! ops. Within a row all `g` chains have uniform timing — their per-stage
//! dependencies (`sum_mc[ly]`, `max_mc[ly]`) are row-wide — and the only
//! cross-column join (`QKᵀ` waiting on all column multicasts) is expressed
//! as the delay op's dependency list, so each collapsed op completes at
//! exactly the time the slowest original chain op would. Group engines in
//! the synchronous schedule serve one serial chain per tile and are never
//! resource-blocked, making the collapse exact (see `crate::dataflow`
//! docs and `tests/fold_differential.rs`).

use crate::arch::ArchConfig;
use crate::engines::{dma_hbm_time, matmul_cycles, SpatzOp};
use crate::hbm::{HbmMap, PageMap};
use crate::noc::{collective_time, CollectiveKind, XferTime};
use crate::sim::program::NO_TILE;
use crate::sim::{Component, FoldStats, OpId, Program, ResourceId};

use super::opt_deps;
use super::tiling::{window_block_range, FlatTiling};
use super::{DbEdit, Workload};

/// Per-(block, inner-iteration) costs, shared by the unfolded and folded
/// emission paths (§Perf: computed once per iteration, not per tile; the
/// values depend only on the slice shapes, never on the group position).
struct IterCosts {
    kv_bytes: u64,
    /// K/V tokens per south-edge slice this iteration (kv_bytes / 2·D·eb).
    t_c_slice: u64,
    mt_kv: XferTime,
    qk_cycles: u64,
    /// Includes the causal mask when the K/V block straddles the diagonal.
    sm1_cycles: u64,
    sm2_cycles: u64,
    sm3_cycles: u64,
    pv_cycles: u64,
    rt_max: XferTime,
    rt_sum: XferTime,
    mt_stat: XferTime,
}

#[allow(clippy::too_many_arguments)]
fn iter_costs(
    arch: &ArchConfig,
    wl: &Workload,
    tiling: &FlatTiling,
    rows: u64,
    masked: bool,
    j: u64,
    n_dest: u64,
) -> IterCosts {
    let d = wl.head_dim;
    let m_c_block = (wl.kv_len() - j * tiling.block).min(tiling.block);
    let t_c_slice = m_c_block.div_ceil(tiling.group).max(1);
    let kv_bytes = 2 * t_c_slice * d * Workload::BYTES_PER_ELEM;
    let mask_cycles = if masked {
        SpatzOp::Scale { elems: rows * t_c_slice }.cycles(&arch.tile)
    } else {
        0
    };
    let stat_bytes = rows * Workload::BYTES_PER_ELEM;
    IterCosts {
        kv_bytes,
        t_c_slice,
        mt_kv: collective_time(&arch.noc, kv_bytes, n_dest, CollectiveKind::Multicast),
        qk_cycles: matmul_cycles(&arch.tile, rows, d, t_c_slice),
        sm1_cycles: mask_cycles
            + SpatzOp::Scale { elems: rows * t_c_slice }.cycles(&arch.tile)
            + SpatzOp::RowMax { rows, cols: t_c_slice }.cycles(&arch.tile)
            + SpatzOp::StatsUpdate { rows }.cycles(&arch.tile),
        sm2_cycles: SpatzOp::Exp { elems: rows * t_c_slice }.cycles(&arch.tile)
            + SpatzOp::RowSum { rows, cols: t_c_slice }.cycles(&arch.tile),
        sm3_cycles: SpatzOp::StatsUpdate { rows }.cycles(&arch.tile)
            + SpatzOp::Rescale { rows, elems: rows * d }.cycles(&arch.tile),
        pv_cycles: matmul_cycles(&arch.tile, rows, t_c_slice, d),
        rt_max: collective_time(&arch.noc, stat_bytes, n_dest, CollectiveKind::MaxReduce),
        rt_sum: collective_time(&arch.noc, stat_bytes, n_dest, CollectiveKind::SumReduce),
        mt_stat: collective_time(&arch.noc, stat_bytes, n_dest, CollectiveKind::Multicast),
    }
}

/// Per-group resource handles.
struct GroupCtx {
    /// Mesh origin of the group (west/north corner).
    origin: (usize, usize),
    /// Per-tile engines, indexed `[local_y * g + local_x]`.
    redmule: Vec<ResourceId>,
    spatz: Vec<ResourceId>,
    /// Row buses (one per group row) carrying row collectives.
    row_bus: Vec<ResourceId>,
    /// Column buses (one per group column).
    col_bus: Vec<ResourceId>,
    /// Sync resource for block barriers.
    sync: ResourceId,
}

/// Build the FlatAttention program. `group` is the square group edge;
/// `asynchronous` enables the two-head §III-C schedule. Collective
/// hardware support is taken from `arch.noc.hw_collectives`.
pub fn flat_program(arch: &ArchConfig, wl: &Workload, group: usize, asynchronous: bool) -> Program {
    flat_program_ext(arch, wl, group, asynchronous, true)
}

/// Extended builder: `double_buffer = false` disables K/V prefetching (the
/// Fig. 3 "*implementations without double buffering" ablation).
pub fn flat_program_ext(
    arch: &ArchConfig,
    wl: &Workload,
    group: usize,
    asynchronous: bool,
    double_buffer: bool,
) -> Program {
    flat_program_ext_in(Program::new(), arch, wl, group, asynchronous, double_buffer)
}

/// Arena-aware builder: constructs into `prog` (typically taken from a
/// [`crate::sim::ProgramArena`]) and seals the result.
pub(crate) fn flat_program_ext_in(
    prog: Program,
    arch: &ArchConfig,
    wl: &Workload,
    group: usize,
    asynchronous: bool,
    double_buffer: bool,
) -> Program {
    flat_build(prog, arch, wl, group, asynchronous, double_buffer, None)
}

/// Build the K/V double-buffering ablation pair `(with_db, without_db)`
/// in one builder pass (see [`super::double_buffer_programs`]).
pub(crate) fn flat_program_db_pair(
    arch: &ArchConfig,
    wl: &Workload,
    group: usize,
) -> (Program, Program) {
    let mut edits: Vec<DbEdit> = Vec::new();
    let db = flat_build(Program::new(), arch, wl, group, false, true, Some(&mut edits));
    let nodb = super::derive_double_buffer_variant(&db, &edits, false);
    (db, nodb)
}

fn flat_build(
    mut prog: Program,
    arch: &ArchConfig,
    wl: &Workload,
    group: usize,
    asynchronous: bool,
    double_buffer: bool,
    mut edits: Option<&mut Vec<DbEdit>>,
) -> Program {
    let tiling = FlatTiling::resolve(arch, wl, group, asynchronous);
    let hbm_map = HbmMap::new(arch);
    let chan_res = prog.resources(hbm_map.total_channels());

    let g = group;
    let g_cols = arch.mesh_x / g;
    let g_rows = arch.mesh_y / g;
    let groups: Vec<GroupCtx> = (0..g_rows * g_cols)
        .map(|gi| {
            let origin = ((gi % g_cols) * g, (gi / g_cols) * g);
            GroupCtx {
                origin,
                redmule: prog.resources(g * g),
                spatz: prog.resources(g * g),
                row_bus: prog.resources(g),
                col_bus: prog.resources(g),
                sync: prog.resource(),
            }
        })
        .collect();

    // Deal blocks round-robin over groups; a block stacks `share_c` query
    // heads of one KV group (dense MHA degenerates to the historical
    // (b, h, i) enumeration).
    let group_blocks =
        super::deal_blocks(wl, tiling.share, tiling.chunks, tiling.t_r, groups.len());

    // §Fold: group 0 is the representative (breakdown) stream and always
    // builds unfolded; the asynchronous schedule arbitrates two streams
    // per engine and never folds.
    let folding = super::symmetry_folding() && !asynchronous;
    // Edit-journaling builds emit naively (see `flash_build`).
    let stamping = super::template_stamping() && edits.is_none();

    for (gi, (gc, blocks)) in groups.iter().zip(&group_blocks).enumerate() {
        if blocks.is_empty() {
            continue;
        }
        if asynchronous {
            let (even, odd): (Vec<_>, Vec<_>) =
                blocks.iter().enumerate().partition(|(i, _)| i % 2 == 0);
            for stream in [even, odd] {
                let list: Vec<(u64, u64)> = stream.into_iter().map(|(_, b)| *b).collect();
                build_group_stream(
                    &mut prog, arch, wl, &hbm_map, &chan_res, gc, &tiling, &list, true,
                    double_buffer, false, stamping, None, edits.as_deref_mut(),
                );
            }
        } else {
            build_group_stream(
                &mut prog, arch, wl, &hbm_map, &chan_res, gc, &tiling, blocks, false,
                double_buffer, folding && gi != 0, stamping, None, edits.as_deref_mut(),
            );
        }
    }

    prog.flops = wl.matmul_flops();
    prog.seal();
    prog
}

/// One request's share of a composed mixed batch (see `crate::scheduler`):
/// a serving workload emitted onto the FlatAttention groups whose origin
/// rows fall inside the entry's tile-row band, with its KV cache
/// channel-placed by a page table.
pub(crate) struct FlatBatchEntry<'a> {
    /// This request's serving workload slice.
    pub wl: Workload,
    /// KV-cache page table (page -> HBM channel).
    pub pages: &'a PageMap,
    /// Tile-row band `[y0, y1)`; must be aligned to the group edge.
    pub y0: usize,
    /// Exclusive band end (see `y0`).
    pub y1: usize,
}

/// Compose one FlatAttention program holding every entry's op stream.
/// Group resources are allocated for the whole mesh (in the classic
/// order, so a solo compose is resource-identical to a mixed one); each
/// entry's blocks are dealt round-robin over its band's groups only, with
/// the band's first group as the fold representative. K/V slices load
/// from the channel holding their page (slice granularity — group slices
/// are small relative to a page). Returns the *unsealed* program plus
/// each entry's contiguous op span — the caller (`scheduler::batch`)
/// seals, or cost-patches a previously sealed step program instead
/// (§Incremental in `scheduler`).
pub(crate) fn flat_batch_program_in(
    mut prog: Program,
    arch: &ArchConfig,
    entries: &[FlatBatchEntry<'_>],
    group: usize,
    asynchronous: bool,
) -> (Program, Vec<(usize, usize)>) {
    let hbm_map = HbmMap::new(arch);
    let chan_res = prog.resources(hbm_map.total_channels());
    let g = group;
    let g_cols = arch.mesh_x / g;
    let g_rows = arch.mesh_y / g;
    let groups: Vec<GroupCtx> = (0..g_rows * g_cols)
        .map(|gi| {
            let origin = ((gi % g_cols) * g, (gi / g_cols) * g);
            GroupCtx {
                origin,
                redmule: prog.resources(g * g),
                spatz: prog.resources(g * g),
                row_bus: prog.resources(g),
                col_bus: prog.resources(g),
                sync: prog.resource(),
            }
        })
        .collect();
    let folding = super::symmetry_folding() && !asynchronous;
    let stamping = super::template_stamping();

    let mut spans: Vec<(usize, usize)> = Vec::with_capacity(entries.len());
    let mut flops = 0u64;
    for e in entries {
        let begin = prog.num_ops();
        let wl = &e.wl;
        debug_assert!(
            e.pages.tokens_capacity() >= wl.kv_len(),
            "page map must cover the KV cache"
        );
        assert!(
            e.y0 % g == 0 && e.y1 % g == 0 && e.y1 > e.y0,
            "entry band [{}, {}) must align to the group edge {g}",
            e.y0,
            e.y1
        );
        let tiling = FlatTiling::resolve(arch, wl, group, asynchronous);
        let band_groups: Vec<usize> = (0..groups.len())
            .filter(|&gi| {
                let oy = groups[gi].origin.1;
                oy >= e.y0 && oy < e.y1
            })
            .collect();
        let group_blocks =
            super::deal_blocks(wl, tiling.share, tiling.chunks, tiling.t_r, band_groups.len());
        for (bi, &gi) in band_groups.iter().enumerate() {
            let blocks = &group_blocks[bi];
            if blocks.is_empty() {
                continue;
            }
            let gc = &groups[gi];
            if asynchronous {
                let (even, odd): (Vec<_>, Vec<_>) =
                    blocks.iter().enumerate().partition(|(i, _)| i % 2 == 0);
                for stream in [even, odd] {
                    let list: Vec<(u64, u64)> = stream.into_iter().map(|(_, b)| *b).collect();
                    build_group_stream(
                        &mut prog, arch, wl, &hbm_map, &chan_res, gc, &tiling, &list, true, true,
                        false, stamping, Some(e.pages), None,
                    );
                }
            } else {
                build_group_stream(
                    &mut prog, arch, wl, &hbm_map, &chan_res, gc, &tiling, blocks, false, true,
                    folding && bi != 0, stamping, Some(e.pages), None,
                );
            }
        }
        flops += wl.matmul_flops();
        spans.push((begin, prog.num_ops()));
    }

    prog.flops = flops;
    (prog, spans)
}

/// Emit one serial stream of blocks for a group. With `fold` set, the
/// `g²` per-tile compute chains collapse into per-row delay ops (§Fold)
/// while the channel and bus op streams stay verbatim. With `pages` set,
/// each south-edge K/V slice loads from the channel holding its page;
/// the slice's token offset depends only on the block's `(i, share_c)`
/// template key (via `j`, `lx`), so stamped paged instances are verbatim
/// copies. `edits` journals every K/V load's prefetch dependency for the
/// double-buffer variant derivation.
#[allow(clippy::too_many_arguments)]
fn build_group_stream(
    prog: &mut Program,
    arch: &ArchConfig,
    wl: &Workload,
    hbm_map: &HbmMap,
    chan_res: &[ResourceId],
    gc: &GroupCtx,
    tiling: &FlatTiling,
    blocks: &[(u64, u64)],
    asynchronous: bool,
    double_buffer: bool,
    fold: bool,
    stamping: bool,
    pages: Option<&PageMap>,
    mut edits: Option<&mut Vec<DbEdit>>,
) {
    debug_assert!(!(fold && asynchronous), "async streams never fold");
    let g = tiling.group as usize;
    let d = wl.head_dim;
    let eb = Workload::BYTES_PER_ELEM;
    let (q_len, kv_len) = (wl.q_len(), wl.kv_len());
    // Decode rows sit at the *end* of the KV cache (prefill: offset 0).
    let kv_off = kv_len - q_len;
    let (ox, oy) = gc.origin;
    let tid = |lx: usize, ly: usize| arch.tile_id(ox + lx, oy + ly);
    let local = |lx: usize, ly: usize| ly * g + lx;
    let n_dest = (g - 1) as u64;
    let stamping = stamping && edits.is_none();
    // Channel + hop distance of the (j, lx) K/V slice load issued by the
    // south-edge tile at (gx, gy): the fixed column band normally, or the
    // page holding the slice's first token when the cache is paged.
    let kv_channel = |pm: Option<&PageMap>, j: u64, lx: usize, t_c_slice: u64| {
        let (gx, gy) = (ox + lx, oy + g - 1);
        match pm {
            Some(pm) => {
                let tok0 = (j * tiling.block + lx as u64 * t_c_slice).min(kv_len - 1);
                let chan = pm.channel_of_token(tok0) as usize;
                (chan, hbm_map.channel_hops(gx, gy, chan))
            }
            None => {
                let ch = hbm_map.col_channel(gx, gy);
                (ch.index, ch.hops)
            }
        }
    };

    if fold {
        prog.fold.streams += 1;
    }
    let mut prev_barrier: Option<OpId> = None;
    // Block templates, keyed by (row-block index `i`, stacked-head count
    // `share_c`) — together they determine the whole block geometry:
    // `(i, share_c, first op, op count, fold delta)`. Only blocks gated on
    // a previous barrier are registered, so every stamped instance has
    // exactly one external dependency to rewrite.
    let mut templates: Vec<(u64, u64, u32, u32, FoldStats)> = Vec::new();

    for &(share_c, i) in blocks {
        if stamping {
            if let (Some(prev), Some((_, _, base, len, fold_delta))) = (
                prev_barrier,
                templates.iter().find(|t| t.0 == i && t.1 == share_c).copied(),
            ) {
                let new_base = prog.stamp_range(base, len, prev);
                prog.fold.accumulate(&fold_delta);
                prev_barrier = Some(OpId(new_base + len - 1));
                continue;
            }
        }

        let block_base = prog.num_ops() as u32;
        let fold_before = prog.fold;
        let m_r_block = (q_len - i * tiling.block).min(tiling.block);
        // Per-tile slice rows for this block (partial last block — and
        // the decode single row — shrinks every row's slice; `max(1)`
        // pads rows shorter than the group edge across all G row slices),
        // stacked over the block's `share_c` query heads.
        let t_r_slice = share_c * m_r_block.div_ceil(tiling.group).max(1);
        let start_dep = prev_barrier;

        // ① West-edge tiles load Q slices; ② row-wise multicast.
        let q_bytes = t_r_slice * d * eb;
        let mt_q = collective_time(&arch.noc, q_bytes, n_dest, CollectiveKind::Multicast);
        let mut q_mcast: Vec<OpId> = Vec::with_capacity(g);
        for ly in 0..g {
            let (gx, gy) = (ox, oy + ly);
            let ch = hbm_map.row_channel(gx, gy);
            let tq = dma_hbm_time(&arch.hbm, &arch.noc, q_bytes, ch.hops);
            let mut dbuf = [OpId(0); 2];
            let nd = opt_deps(&mut dbuf, start_dep, None);
            let load = prog.op(
                chan_res[ch.index],
                tq.occupancy,
                tq.latency,
                Component::HbmAccess,
                tid(0, ly),
                q_bytes,
                &dbuf[..nd],
            );
            let mc = prog.op(
                gc.row_bus[ly],
                mt_q.occupancy,
                mt_q.latency,
                Component::Multicast,
                tid(0, ly),
                0,
                &[load],
            );
            q_mcast.push(mc);
        }

        // Causal: group-level K/V blocks above the row range are skipped;
        // diagonal-straddling blocks are masked on the vector engine
        // (decode rows see the whole cache: full t_c, no mask).
        let row_start = kv_off + i * tiling.block;
        let t_c_eff = if wl.causal {
            (row_start + m_r_block).div_ceil(tiling.block)
        } else {
            tiling.t_c
        };
        let mask_from = if wl.causal {
            crate::dataflow::tiling::causal_mask_from(row_start, tiling.block, kv_len, t_c_eff)
        } else {
            t_c_eff
        };
        // Sliding window: group-level K/V blocks below every row's window
        // start are skipped, straddling blocks pay the prefix mask
        // (`(0, 0)` without a window — dense emission is untouched).
        let (j_lo, win_until) = window_block_range(
            row_start,
            row_start + m_r_block,
            wl.window,
            tiling.block,
            t_c_eff,
        );
        let norm_cycles =
            SpatzOp::Normalize { rows: t_r_slice, elems: t_r_slice * d }.cycles(&arch.tile);
        let o_bytes = t_r_slice * d * eb;
        let rt_o = collective_time(&arch.noc, o_bytes, n_dest, CollectiveKind::SumReduce);
        let mut stores: Vec<OpId> = Vec::with_capacity(g);

        if fold {
            // §Fold: collapsed inner loop — identical channel (loads,
            // stores) and bus (multicasts, reductions) op stream, with the
            // g² per-tile chains of each stage replaced by one delay op
            // per row. Within a row the original chains complete in
            // lockstep (their stage deps are row-wide), so the delay op's
            // completion equals every original chain op's completion.
            let g64 = g as u64;
            let gg = g64 * g64;
            let mut pv_row: Vec<Option<OpId>> = vec![None; g]; // PV[j-1] per row
            let mut pv_row2: Vec<Option<OpId>> = vec![None; g]; // PV[j-2] per row
            let mut join_deps: Vec<OpId> = Vec::with_capacity(g + 2);
            for j in j_lo..t_c_eff {
                let masked = j >= mask_from || j < win_until;
                let c = iter_costs(arch, wl, tiling, t_r_slice, masked, j, n_dest);

                // ③ South-edge loads + ④ column multicasts (kept).
                // Buffering deps: the south row's PV delay op stands in
                // for pv[j-1] / pv[j-2] of every south tile (their
                // completions are identical).
                let db_dep = pv_row2[g - 1];
                let nodb_dep = pv_row[g - 1];
                let buf_dep = if asynchronous || !double_buffer { nodb_dep } else { db_dep };
                let mut kv_mcast: Vec<OpId> = Vec::with_capacity(g);
                for lx in 0..g {
                    let (ch_idx, ch_hops) = kv_channel(pages, j, lx, c.t_c_slice);
                    let tkv = dma_hbm_time(&arch.hbm, &arch.noc, c.kv_bytes, ch_hops);
                    let mut dbuf = [OpId(0); 2];
                    let nd = opt_deps(&mut dbuf, start_dep, buf_dep);
                    let load = prog.op(
                        chan_res[ch_idx],
                        tkv.occupancy,
                        tkv.latency,
                        Component::HbmAccess,
                        tid(lx, g - 1),
                        c.kv_bytes,
                        &dbuf[..nd],
                    );
                    if let Some(ed) = edits.as_deref_mut() {
                        ed.push(DbEdit {
                            op: load.0,
                            base: start_dep.map(|o| o.0),
                            db: db_dep.map(|o| o.0),
                            nodb: nodb_dep.map(|o| o.0),
                        });
                    }
                    let mc = prog.op(
                        gc.col_bus[lx],
                        c.mt_kv.occupancy,
                        c.mt_kv.latency,
                        Component::Multicast,
                        tid(lx, g - 1),
                        0,
                        &[load],
                    );
                    kv_mcast.push(mc);
                }

                for ly in 0..g {
                    // ⑤⑥⑦ collapsed QKᵀ + softmax-1 row chain: ready when
                    // the row's Q multicast, *all* column multicasts and
                    // the row's previous PV have completed — the max the
                    // slowest original tile chain would wait for.
                    join_deps.clear();
                    join_deps.push(q_mcast[ly]);
                    join_deps.extend_from_slice(&kv_mcast);
                    if let Some(p) = pv_row[ly] {
                        join_deps.push(p);
                    }
                    let jop = prog.op(
                        gc.redmule[local(0, ly)],
                        c.qk_cycles + c.sm1_cycles,
                        0,
                        Component::Other,
                        NO_TILE,
                        0,
                        &join_deps,
                    );
                    // ⑧⑨ kept row-bus max reduction + multicast.
                    let red = prog.op(
                        gc.row_bus[ly],
                        c.rt_max.occupancy,
                        c.rt_max.latency,
                        Component::MaxReduce,
                        tid(0, ly),
                        0,
                        &[jop],
                    );
                    let max_mc = prog.op(
                        gc.row_bus[ly],
                        c.mt_stat.occupancy,
                        c.mt_stat.latency,
                        Component::Multicast,
                        tid(0, ly),
                        0,
                        &[red],
                    );
                    // ⑩⑪ collapsed exp + row sums.
                    let s2 = prog.op(
                        gc.spatz[local(0, ly)],
                        c.sm2_cycles,
                        0,
                        Component::Other,
                        NO_TILE,
                        0,
                        &[max_mc],
                    );
                    // ⑫⑬ kept row-bus sum reduction + multicast.
                    let sum_red = prog.op(
                        gc.row_bus[ly],
                        c.rt_sum.occupancy,
                        c.rt_sum.latency,
                        Component::SumReduce,
                        tid(0, ly),
                        0,
                        &[s2],
                    );
                    let sum_mc = prog.op(
                        gc.row_bus[ly],
                        c.mt_stat.occupancy,
                        c.mt_stat.latency,
                        Component::Multicast,
                        tid(0, ly),
                        0,
                        &[sum_red],
                    );
                    // ⑭–⑰ collapsed stats update + rescale + P·V.
                    let pvop = prog.op(
                        gc.redmule[local(0, ly)],
                        c.sm3_cycles + c.pv_cycles,
                        0,
                        Component::Other,
                        NO_TILE,
                        0,
                        &[sum_mc],
                    );
                    pv_row2[ly] = pv_row[ly];
                    pv_row[ly] = Some(pvop);
                }
                // Elided per iteration: g²·(qk, sm1, sm2, sm3, pv) ops,
                // replaced by 3 delay ops per row.
                prog.fold.ops += 5 * gg - 3 * g64;
                prog.fold.redmule_busy += gg * (c.qk_cycles + c.pv_cycles);
                prog.fold.spatz_busy += gg * (c.sm1_cycles + c.sm2_cycles + c.sm3_cycles);
            }

            // ⑱ collapsed normalize, ⑲⑳ kept O-reduce + store per row.
            for ly in 0..g {
                let norm = prog.op(
                    gc.spatz[local(0, ly)],
                    norm_cycles,
                    0,
                    Component::Other,
                    NO_TILE,
                    0,
                    &[pv_row[ly].expect("inner loop ran")],
                );
                let red = prog.op(
                    gc.row_bus[ly],
                    rt_o.occupancy,
                    rt_o.latency,
                    Component::SumReduce,
                    tid(0, ly),
                    0,
                    &[norm],
                );
                let (gx, gy) = (ox, oy + ly);
                let ch = hbm_map.row_channel(gx, gy);
                let to = dma_hbm_time(&arch.hbm, &arch.noc, o_bytes, ch.hops);
                let store = prog.op(
                    chan_res[ch.index],
                    to.occupancy,
                    to.latency,
                    Component::HbmAccess,
                    tid(0, ly),
                    o_bytes,
                    &[red],
                );
                stores.push(store);
            }
            prog.fold.ops += gg - g64;
            prog.fold.spatz_busy += gg * norm_cycles;
        } else {
            // Inner loop over K/V column blocks.
            let mut pv_prev: Vec<Option<OpId>> = vec![None; g * g]; // pv[j-1] per tile
            let mut pv_prev2: Vec<Option<OpId>> = vec![None; g * g]; // pv[j-2] per tile

            for j in j_lo..t_c_eff {
                // Per-iteration costs are identical across the g / g²
                // emission loops below — compute each once (§Perf).
                let masked = j >= mask_from || j < win_until;
                let c = iter_costs(arch, wl, tiling, t_r_slice, masked, j, n_dest);

                // ③ South-edge tiles load Kᵀ/V slices; ④ column multicast.
                let mut kv_mcast: Vec<OpId> = Vec::with_capacity(g);
                for lx in 0..g {
                    let (ch_idx, ch_hops) = kv_channel(pages, j, lx, c.t_c_slice);
                    let tkv = dma_hbm_time(&arch.hbm, &arch.noc, c.kv_bytes, ch_hops);
                    let south = local(lx, g - 1);
                    // Buffering: double-buffered for sync, single for async
                    // (the second head-stream provides the overlap).
                    let db_dep = pv_prev2[south];
                    let nodb_dep = pv_prev[south];
                    let buf_dep = if asynchronous || !double_buffer { nodb_dep } else { db_dep };
                    let mut dbuf = [OpId(0); 2];
                    let nd = opt_deps(&mut dbuf, start_dep, buf_dep);
                    let load = prog.op(
                        chan_res[ch_idx],
                        tkv.occupancy,
                        tkv.latency,
                        Component::HbmAccess,
                        tid(lx, g - 1),
                        c.kv_bytes,
                        &dbuf[..nd],
                    );
                    if let Some(ed) = edits.as_deref_mut() {
                        ed.push(DbEdit {
                            op: load.0,
                            base: start_dep.map(|o| o.0),
                            db: db_dep.map(|o| o.0),
                            nodb: nodb_dep.map(|o| o.0),
                        });
                    }
                    let mc = prog.op(
                        gc.col_bus[lx],
                        c.mt_kv.occupancy,
                        c.mt_kv.latency,
                        Component::Multicast,
                        tid(lx, g - 1),
                        0,
                        &[load],
                    );
                    kv_mcast.push(mc);
                }

                let mut sm1_row: Vec<Vec<OpId>> = vec![Vec::with_capacity(g); g];
                for ly in 0..g {
                    for lx in 0..g {
                        let tl = local(lx, ly);
                        // ⑤ S slice = Q_iy · Kᵀ_jx.
                        let mut dbuf = [OpId(0); 3];
                        dbuf[0] = q_mcast[ly];
                        dbuf[1] = kv_mcast[lx];
                        let mut nd = 2;
                        if let Some(p) = pv_prev[tl] {
                            // serialize with own prior iteration
                            dbuf[nd] = p;
                            nd += 1;
                        }
                        let qk = prog.op(
                            gc.redmule[tl],
                            c.qk_cycles,
                            0,
                            Component::RedMule,
                            tid(lx, ly),
                            0,
                            &dbuf[..nd],
                        );
                        // ⑥⑦ scale + local row maxima + running max
                        // (+ causal triangular mask on diagonal blocks).
                        let sm1 = prog.op(
                            gc.spatz[tl],
                            c.sm1_cycles,
                            0,
                            Component::Spatz,
                            tid(lx, ly),
                            0,
                            &[qk],
                        );
                        sm1_row[ly].push(sm1);
                    }
                }

                // ⑧⑨ Row-wise max reduction + multicast of global maxima.
                let mut max_mc: Vec<OpId> = Vec::with_capacity(g);
                for ly in 0..g {
                    let red = prog.op(
                        gc.row_bus[ly],
                        c.rt_max.occupancy,
                        c.rt_max.latency,
                        Component::MaxReduce,
                        tid(0, ly),
                        0,
                        &sm1_row[ly],
                    );
                    let mc = prog.op(
                        gc.row_bus[ly],
                        c.mt_stat.occupancy,
                        c.mt_stat.latency,
                        Component::Multicast,
                        tid(0, ly),
                        0,
                        &[red],
                    );
                    max_mc.push(mc);
                }

                // ⑩⑪ exp + local row sums, ⑫⑬ sum reduction + multicast.
                let mut sm2_row: Vec<Vec<OpId>> = vec![Vec::with_capacity(g); g];
                for ly in 0..g {
                    for lx in 0..g {
                        let tl = local(lx, ly);
                        let sm2 = prog.op(
                            gc.spatz[tl],
                            c.sm2_cycles,
                            0,
                            Component::Spatz,
                            tid(lx, ly),
                            0,
                            &[max_mc[ly]],
                        );
                        sm2_row[ly].push(sm2);
                    }
                }
                let mut sum_mc: Vec<OpId> = Vec::with_capacity(g);
                for ly in 0..g {
                    let red = prog.op(
                        gc.row_bus[ly],
                        c.rt_sum.occupancy,
                        c.rt_sum.latency,
                        Component::SumReduce,
                        tid(0, ly),
                        0,
                        &sm2_row[ly],
                    );
                    let mc = prog.op(
                        gc.row_bus[ly],
                        c.mt_stat.occupancy,
                        c.mt_stat.latency,
                        Component::Multicast,
                        tid(0, ly),
                        0,
                        &[red],
                    );
                    sum_mc.push(mc);
                }

                // ⑭–⑰ stats update, O rescale, O += P̃·V.
                for ly in 0..g {
                    for lx in 0..g {
                        let tl = local(lx, ly);
                        let sm3 = prog.op(
                            gc.spatz[tl],
                            c.sm3_cycles,
                            0,
                            Component::Spatz,
                            tid(lx, ly),
                            0,
                            &[sum_mc[ly]],
                        );
                        let pv = prog.op(
                            gc.redmule[tl],
                            c.pv_cycles,
                            0,
                            Component::RedMule,
                            tid(lx, ly),
                            0,
                            &[sm3],
                        );
                        pv_prev2[tl] = pv_prev[tl];
                        pv_prev[tl] = Some(pv);
                    }
                }
            }

            // ⑱ normalize, ⑲ row-reduce O to the west edge, ⑳ store.
            let mut norm_row: Vec<Vec<OpId>> = vec![Vec::with_capacity(g); g];
            for ly in 0..g {
                for lx in 0..g {
                    let tl = local(lx, ly);
                    let norm = prog.op(
                        gc.spatz[tl],
                        norm_cycles,
                        0,
                        Component::Spatz,
                        tid(lx, ly),
                        0,
                        &[pv_prev[tl].expect("inner loop ran")],
                    );
                    norm_row[ly].push(norm);
                }
            }
            for ly in 0..g {
                let red = prog.op(
                    gc.row_bus[ly],
                    rt_o.occupancy,
                    rt_o.latency,
                    Component::SumReduce,
                    tid(0, ly),
                    0,
                    &norm_row[ly],
                );
                let (gx, gy) = (ox, oy + ly);
                let ch = hbm_map.row_channel(gx, gy);
                let to = dma_hbm_time(&arch.hbm, &arch.noc, o_bytes, ch.hops);
                let store = prog.op(
                    chan_res[ch.index],
                    to.occupancy,
                    to.latency,
                    Component::HbmAccess,
                    tid(0, ly),
                    o_bytes,
                    &[red],
                );
                stores.push(store);
            }
        }

        // Block barrier: the stream's next block starts after all stores.
        let barrier = prog.op(gc.sync, 0, 0, Component::Other, NO_TILE, 0, &stores);
        if stamping && start_dep.is_some() {
            let len = prog.num_ops() as u32 - block_base;
            templates.push((i, share_c, block_base, len, prog.fold.delta_since(&fold_before)));
        }
        prev_barrier = Some(barrier);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{table1, table1_sw_collectives};
    use crate::dataflow::{
        assert_programs_equal, run, set_symmetry_folding, set_template_stamping, tracked_tile,
        Dataflow,
    };
    use crate::sim::execute;

    fn wl_big() -> Workload {
        Workload::new(4096, 128, 32, 2)
    }

    fn wl_small() -> Workload {
        Workload::new(1024, 128, 8, 1)
    }

    #[test]
    fn program_builds_and_validates() {
        let arch = table1();
        let p = flat_program(&arch, &wl_small(), 8, false);
        assert!(p.validate().is_ok());
        assert!(p.num_ops() > 0);
        assert!(p.is_sealed());
    }

    #[test]
    fn stamped_build_is_identical_to_naive_build() {
        // Template stamping is a pure construction-speed optimization: the
        // emitted program must match the naive per-block emission op for
        // op, dep for dep — under both folding modes (stamping must also
        // reproduce the collapsed emission and its fold accounting).
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let arch = table1();
        for folding in [true, false] {
            set_symmetry_folding(folding);
            for (wl, group, asyn) in [
                (Workload::new(2048, 128, 24, 1), 8usize, false),
                (Workload::new(4096, 128, 8, 1), 32, true),
                (Workload::new(1024, 64, 32, 2).with_causal(true), 8, false),
                (Workload::new(512, 128, 32, 4), 16, true),
                (Workload::new(2048, 128, 24, 1).with_kv_heads(6), 8, false),
                (Workload::new(1024, 64, 32, 2).with_kv_heads(8).with_causal(true), 8, false),
                (Workload::new(4096, 128, 32, 2).with_kv_heads(4).decode(), 16, true),
            ] {
                let stamped = flat_program(&arch, &wl, group, asyn);
                set_template_stamping(false);
                let naive = flat_program(&arch, &wl, group, asyn);
                set_template_stamping(true);
                assert_programs_equal(&stamped, &naive);
            }
        }
        set_symmetry_folding(true);
    }

    #[test]
    fn folded_build_executes_bit_identically() {
        // §Fold exactness for the synchronous group schedule: identical
        // RunStats from folded and unfolded builds, on both the hardware-
        // and software-collective paths.
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        for (arch, wl, group) in [
            (table1(), Workload::new(1024, 128, 48, 1), 8usize),
            (table1_sw_collectives(), Workload::new(512, 64, 20, 1).with_causal(true), 16),
            (table1(), Workload::new(1024, 128, 48, 1).with_kv_heads(12), 8),
            (table1(), Workload::new(2048, 64, 32, 1).with_kv_heads(8).decode(), 8),
        ] {
            let tracked = tracked_tile(&arch, Dataflow::FlatColl, group);
            set_symmetry_folding(true);
            let folded = flat_program(&arch, &wl, group, false);
            set_symmetry_folding(false);
            let unfolded = flat_program(&arch, &wl, group, false);
            set_symmetry_folding(true);
            assert!(folded.fold.streams > 0, "folding should engage");
            assert_eq!(
                folded.num_ops() as u64 + folded.fold.ops,
                unfolded.num_ops() as u64,
                "op conservation"
            );
            assert_eq!(execute(&folded, tracked), execute(&unfolded, tracked));
        }
    }

    #[test]
    fn traffic_matches_io_model() {
        // HBM traffic must match §III-A: 2·H·B·D·S·(1 + S/(G·t)) elements.
        let arch = table1();
        let wl = wl_small();
        for group in [4usize, 8, 16] {
            let tiling = FlatTiling::resolve(&arch, &wl, group, false);
            let p = flat_program(&arch, &wl, group, false);
            let st = execute(&p, 0);
            let expected = 2
                * wl.heads
                * wl.batch
                * wl.head_dim
                * wl.seq
                * Workload::BYTES_PER_ELEM
                * (1 + wl.seq.div_ceil(tiling.block));
            let ratio = st.hbm_bytes as f64 / expected as f64;
            assert!(
                (0.95..1.05).contains(&ratio),
                "group {group}: traffic {} vs model {expected} (ratio {ratio:.3})",
                st.hbm_bytes
            );
        }
    }

    #[test]
    fn decode_kv_traffic_scales_with_kv_heads() {
        // Decode on a group: K/V streams through the south edge once per
        // KV head (T_r = 1, whole group stacked ⇒ one chunk), so the K/V
        // share of the traffic scales exactly by kv_heads/heads. Q/O pay
        // the group-padding cost (G row slices per single decode row) but
        // are independent of kv_heads.
        let arch = table1();
        let eb = Workload::BYTES_PER_ELEM;
        let base = Workload::new(4096, 64, 32, 2).decode();
        let qo = 2 * 2 * 32 * 8 * 64 * eb; // 2 · B·H·G·D (padded rows)
        let mut kv = Vec::new();
        for kv_heads in [32u64, 8, 1] {
            let wl = base.with_kv_heads(kv_heads);
            let st = execute(&flat_program(&arch, &wl, 8, false), 0);
            assert_eq!(
                st.hbm_bytes,
                qo + 2 * 2 * kv_heads * 4096 * 64 * eb,
                "kv{kv_heads}"
            );
            kv.push(st.hbm_bytes - qo);
        }
        assert_eq!(kv[0] / kv[1], 4); // 32 → 8 KV heads
        assert_eq!(kv[0] / kv[2], 32); // 32 → 1 (MQA)
    }

    #[test]
    fn hbm_traffic_16x_below_fa3() {
        // Headline claim: 16× HBM traffic reduction vs FA-3 (D128, S4096).
        let arch = table1();
        let wl = wl_big();
        let flat = execute(&flat_program(&arch, &wl, 32, true), 0);
        let fa3 = execute(&crate::dataflow::flash::flash_program(&arch, &wl, true), 0);
        let ratio = fa3.hbm_bytes as f64 / flat.hbm_bytes as f64;
        assert!(
            (13.0..20.0).contains(&ratio),
            "traffic reduction {ratio:.1}× (paper: 16×)"
        );
    }

    #[test]
    fn flat_asyn_hits_high_utilization() {
        // Headline: up to ~89% utilization at D=128, S=4096, G=32.
        let arch = table1();
        let wl = wl_big();
        let st = run(&arch, &wl, Dataflow::FlatAsyn, 32);
        let u = st.compute_utilization(arch.peak_flops_per_cycle());
        assert!(u > 0.75, "FlatAsyn utilization {u:.3} (paper: up to 0.893)");
    }

    #[test]
    fn hw_collectives_beat_sw_collectives() {
        // Fig. 3: Flat (software collectives) is much slower than FlatColl.
        let arch = table1();
        let wl = wl_small();
        let sw = run(&table1_sw_collectives(), &wl, Dataflow::Flat, 32);
        let hw = run(&arch, &wl, Dataflow::FlatColl, 32);
        assert!(
            sw.makespan > hw.makespan,
            "sw {} vs hw {}",
            sw.makespan,
            hw.makespan
        );
    }

    #[test]
    fn speedup_over_fa3_in_paper_range() {
        // Headline: up to 4.1× speedup over FA-3 (D128, S4096).
        let arch = table1();
        let wl = wl_big();
        let flat = run(&arch, &wl, Dataflow::FlatAsyn, 32);
        let fa3 = run(&arch, &wl, Dataflow::Flash3, 32);
        let speedup = fa3.makespan as f64 / flat.makespan as f64;
        assert!(
            (2.5..6.0).contains(&speedup),
            "speedup {speedup:.2}× (paper: 4.1×)"
        );
    }

    #[test]
    fn breakdown_tracked_tile_sees_all_components() {
        let arch = table1();
        let wl = wl_small();
        let p = flat_program(&arch, &wl, 8, false);
        let st = execute(&p, tracked_tile(&arch, Dataflow::FlatColl, 8));
        let bd = &st.breakdown;
        assert!(bd.redmule > 0, "{bd:?}");
        assert!(bd.spatz > 0, "{bd:?}");
        assert!(bd.hbm > 0, "{bd:?}");
        assert!(bd.multicast + bd.max_reduce + bd.sum_reduce > 0, "{bd:?}");
        assert_eq!(bd.total(), st.makespan);
    }

    #[test]
    fn window_equal_to_seq_reproduces_dense_causal_emission() {
        // W == S must emit the dense-causal group program op for op.
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let arch = table1();
        for (wl, group, asyn) in [
            (Workload::new(1024, 64, 32, 2).with_causal(true), 8usize, false),
            (Workload::new(1024, 64, 32, 2).with_kv_heads(8).with_causal(true), 8, false),
            (Workload::new(512, 128, 32, 4).with_causal(true), 16, true),
        ] {
            let dense = flat_program(&arch, &wl, group, asyn);
            let windowed = flat_program(&arch, &wl.with_window(wl.seq), group, asyn);
            assert_programs_equal(&dense, &windowed);
        }
    }

    #[test]
    fn sliding_window_cuts_group_traffic() {
        // A small window skips most group-level K/V blocks; traffic drops
        // and stays above the windowed compulsory bytes.
        let arch = table1();
        let dense = Workload::new(4096, 128, 32, 1).with_causal(true);
        let wind = dense.with_window(512);
        let st_dense = execute(&flat_program(&arch, &dense, 8, false), 0);
        let st_wind = execute(&flat_program(&arch, &wind, 8, false), 0);
        assert!(
            st_wind.hbm_bytes < st_dense.hbm_bytes,
            "windowed {} vs dense {}",
            st_wind.hbm_bytes,
            st_dense.hbm_bytes
        );
        assert!(st_wind.hbm_bytes >= wind.compulsory_bytes());
    }

    #[test]
    fn double_buffer_pair_matches_fresh_builds() {
        // The derived variant must be bit-identical to a fresh build of
        // each mode — ops, deps, fold accounting and execution — on both
        // collective paths.
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        for (arch, wl, group) in [
            (table1(), Workload::new(1024, 128, 24, 1), 8usize),
            (table1_sw_collectives(), Workload::new(512, 64, 20, 1).with_causal(true), 16),
            (table1(), Workload::new(2048, 64, 16, 1).with_kv_heads(4).decode(), 8),
        ] {
            let tracked = tracked_tile(&arch, Dataflow::FlatColl, group);
            let (db, nodb) = flat_program_db_pair(&arch, &wl, group);
            let fresh_db = flat_program_ext(&arch, &wl, group, false, true);
            let fresh_nodb = flat_program_ext(&arch, &wl, group, false, false);
            assert_programs_equal(&db, &fresh_db);
            assert_programs_equal(&nodb, &fresh_nodb);
            assert_eq!(execute(&db, tracked), execute(&fresh_db, tracked), "{wl:?} db");
            assert_eq!(execute(&nodb, tracked), execute(&fresh_nodb, tracked), "{wl:?} nodb");
        }
    }

    #[test]
    fn over_flattening_smaller_groups_win_short_seq() {
        // §V-B: at S=512 a 32×32 group over-flattens; a smaller group is
        // faster (or at least no slower) per unit work.
        let arch = table1();
        let wl = Workload::new(512, 128, 32, 4);
        let g8 = run(&arch, &wl, Dataflow::FlatAsyn, 8);
        let g32 = run(&arch, &wl, Dataflow::FlatAsyn, 32);
        assert!(
            g8.makespan < g32.makespan,
            "8×8 {} should beat 32×32 {} at S=512",
            g8.makespan,
            g32.makespan
        );
    }
}
