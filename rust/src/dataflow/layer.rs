//! Full transformer-layer composition: attention + projections + FFN
//! chained into ONE [`Program`] with explicit cross-kernel dependencies.
//!
//! §Kernel rotation. The op DAG forbids forward dependencies (an op's
//! deps must already exist), and the attention builders must run first
//! (they own the channel-resource-index invariant), so a layer is
//! emitted in the rotation
//!
//! ```text
//! attention → out-proj → FFN-up → FFN-down → QKV-proj (next layer)
//! ```
//!
//! i.e. the QKV projection emitted at the *end* of layer `l` produces
//! the Q/K/V consumed by layer `l+1`'s attention. Over `L` layers the
//! rotation carries exactly the same per-layer cost as the textbook
//! order (each layer runs one attention kernel and the same four GEMMs)
//! while keeping every dependency backward.
//!
//! §Cross-kernel edges and fold exactness. Each kernel ends in a
//! zero-cost *sink barrier* and starts with a zero-cost *entry barrier*
//! depending on the previous kernel's sinks, so kernels serialize
//! strictly. Symmetry folding stays exact under this composition because
//! it only ever elides ops *inside* an attention stream's private
//! compute chain — the per-stream store ops (the attention sinks) are
//! emitted verbatim in both folded and unfolded programs and complete at
//! identical cycles (fold ≡ unfold), so the cross-kernel edges attach to
//! the same ops at the same times in both modes. GEMM kernels never fold.
//! `tests/layer_differential.rs` pins both facts: the composed layer
//! reproduces the solo attention and solo GEMM timelines bit-for-bit
//! (strict-barrier additivity), folded or not.

use crate::arch::ArchConfig;
use crate::sim::{Component, OpId, Program, NO_TILE};

use super::gemm::{append_gemm_band, WeightResidency};
use super::summa::GemmWorkload;
use super::{build_program, Dataflow, Workload};

/// An attention workload plus the projection/FFN GEMMs that complete a
/// transformer layer around it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerWorkload {
    /// The layer's attention kernel (defines `d_model = heads·head_dim`
    /// and the activation row count `batch·q_len`).
    pub attn: Workload,
    /// FFN expansion factor (hidden = `ffn_mult · d_model`; ≥ 1).
    pub ffn_mult: u64,
    /// Where the projection/FFN weights live (the sweepable axis).
    pub weights: WeightResidency,
}

impl LayerWorkload {
    /// Bundle an attention workload into a layer. Panics on
    /// `ffn_mult == 0` — an FFN-less layer is just the attention
    /// workload.
    pub fn new(attn: Workload, ffn_mult: u64, weights: WeightResidency) -> Self {
        assert!(ffn_mult >= 1, "LayerWorkload: ffn_mult must be >= 1");
        Self { attn, ffn_mult, weights }
    }

    /// Model width `d_model = heads · head_dim`.
    pub fn d_model(&self) -> u64 {
        self.attn.heads * self.attn.head_dim
    }

    /// K/V projection width `kv_heads · head_dim` (< `d_model` under
    /// GQA/MQA — the QKV projection output narrows accordingly).
    pub fn kv_dim(&self) -> u64 {
        self.attn.kv_heads * self.attn.head_dim
    }

    /// Activation rows through every GEMM: `batch · q_len` (1·batch for
    /// decode steps).
    pub fn gemm_rows(&self) -> u64 {
        self.attn.batch * self.attn.q_len()
    }

    /// The layer's GEMMs in §Kernel-rotation order: output projection,
    /// FFN up, FFN down, then the *next* layer's QKV projection (GQA
    /// narrows its output to `d_model + 2·kv_dim`).
    pub fn gemms(&self) -> [GemmWorkload; 4] {
        let m = self.gemm_rows();
        let dm = self.d_model();
        let hidden = self.ffn_mult * dm;
        [
            GemmWorkload::new(m, dm, dm, "out-proj"),
            GemmWorkload::new(m, dm, hidden, "ffn-up"),
            GemmWorkload::new(m, hidden, dm, "ffn-down"),
            GemmWorkload::new(m, dm, dm + 2 * self.kv_dim(), "qkv-proj"),
        ]
    }

    /// Useful FLOPs of the whole layer (attention + all four GEMMs).
    pub fn flops(&self) -> u64 {
        self.attn.matmul_flops() + self.gemms().iter().map(GemmWorkload::flops).sum::<u64>()
    }
}

/// A composed layer program plus per-kernel op spans (attention first,
/// then the GEMMs in [`LayerWorkload::gemms`] order).
#[derive(Debug)]
pub struct LayerProgram {
    /// The sealed composed program.
    pub program: Program,
    /// Per kernel: `[start, end)` op range. `spans[0]` is attention;
    /// GEMM spans include their entry and sink barriers.
    pub spans: Vec<(usize, usize)>,
    /// Kernel labels parallel to `spans` (`"attention"`, then GEMM
    /// labels).
    pub labels: Vec<String>,
}

/// Ops in `[lo, hi)` with no dependent inside `[lo, hi)` — the range's
/// sinks, i.e. where a cross-kernel barrier must attach.
pub(crate) fn sinks_in(prog: &Program, lo: usize, hi: usize) -> Vec<OpId> {
    let mut has_dependent = vec![false; hi - lo];
    for op in &prog.ops()[lo..hi] {
        for &d in prog.deps_of(op) {
            let d = d as usize;
            if d >= lo {
                has_dependent[d - lo] = true;
            }
        }
    }
    (lo..hi).filter(|&i| !has_dependent[i - lo]).map(|i| OpId(i as u32)).collect()
}

/// Compose one full layer on the whole mesh: the solo attention program
/// for `lw.attn` under `df`/`group`, then the four projection/FFN GEMMs
/// appended behind strict barriers (§Cross-kernel edges).
pub fn layer_program(
    arch: &ArchConfig,
    lw: &LayerWorkload,
    df: Dataflow,
    group: usize,
) -> LayerProgram {
    let attn = build_program(arch, &lw.attn, df, group);
    let mut prog = attn.unsealed_clone();
    let n_attn = prog.num_ops();
    let mut spans = vec![(0, n_attn)];
    let mut labels = vec!["attention".to_string()];

    let mut deps = sinks_in(&prog, 0, n_attn);
    for g in lw.gemms() {
        let begin = prog.num_ops();
        let sink = append_gemm_band(&mut prog, arch, &g, 0, arch.mesh_y, lw.weights, &deps);
        prog.flops += g.flops();
        spans.push((begin, prog.num_ops()));
        labels.push(g.label.clone());
        deps = vec![sink];
    }
    prog.seal();
    LayerProgram { program: prog, spans, labels }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::sim::execute;

    fn lw(weights: WeightResidency) -> LayerWorkload {
        LayerWorkload::new(
            Workload::new(512, 64, 8, 1).with_kv_heads(2).with_causal(true),
            4,
            weights,
        )
    }

    #[test]
    fn gemm_shapes_follow_the_rotation() {
        let l = lw(WeightResidency::HbmStream);
        let dm = 8 * 64;
        let [op, up, down, qkv] = l.gemms();
        assert_eq!((op.m, op.k, op.n), (512, dm, dm));
        assert_eq!((up.m, up.k, up.n), (512, dm, 4 * dm));
        assert_eq!((down.m, down.k, down.n), (512, 4 * dm, dm));
        // GQA: kv_dim = 2 heads · 64 = 128, so QKV output is dm + 256.
        assert_eq!((qkv.m, qkv.k, qkv.n), (512, dm, dm + 256));
        let gemm_flops: u64 = l.gemms().iter().map(|g| g.flops()).sum();
        assert_eq!(l.flops(), l.attn.matmul_flops() + gemm_flops);
    }

    #[test]
    fn layer_program_builds_for_every_dataflow() {
        let arch = presets::table2(8);
        for df in crate::dataflow::ALL_DATAFLOWS {
            let l = lw(WeightResidency::HbmStream);
            let lp = layer_program(&arch, &l, df, 2);
            assert!(lp.program.validate().is_ok(), "{df:?}");
            assert_eq!(lp.spans.len(), 5, "{df:?}");
            assert_eq!(lp.labels[0], "attention");
            assert_eq!(lp.program.flops, l.flops(), "{df:?}");
            // Spans tile the program contiguously.
            assert_eq!(lp.spans[0].0, 0);
            for w in lp.spans.windows(2) {
                assert_eq!(w[0].1, w[1].0, "{df:?}");
            }
            assert_eq!(lp.spans.last().unwrap().1, lp.program.num_ops());
            let st = execute(&lp.program, 0);
            assert!(st.makespan > 0, "{df:?}");
        }
    }

    #[test]
    fn resident_layer_is_no_slower() {
        let arch = presets::table2(8);
        let stream = layer_program(&arch, &lw(WeightResidency::HbmStream), Dataflow::FlatColl, 2);
        let resident = layer_program(&arch, &lw(WeightResidency::Resident), Dataflow::FlatColl, 2);
        let ms = execute(&stream.program, 0).makespan;
        let mr = execute(&resident.program, 0).makespan;
        assert!(mr <= ms, "resident {mr} vs streamed {ms}");
    }

    #[test]
    fn sinks_are_real_sinks() {
        let arch = presets::table2(8);
        let l = lw(WeightResidency::HbmStream);
        let lp = layer_program(&arch, &l, Dataflow::Flash2, 2);
        let (lo, hi) = lp.spans[0];
        let sinks = sinks_in(&lp.program, lo, hi);
        assert!(!sinks.is_empty());
        // No op in the attention span depends on a sink.
        for op in &lp.program.ops()[lo..hi] {
            for &d in lp.program.deps_of(op) {
                assert!(!sinks.iter().any(|s| s.0 == d));
            }
        }
    }
}
