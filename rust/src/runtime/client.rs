//! PJRT client wrapper: compile-once executable cache + typed entry points.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Tensor;

use super::artifacts::{block_step_artifact_name, mha_artifact_name, Manifest};

/// The runtime: a PJRT CPU client plus a cache of compiled executables.
///
/// Executables are compiled lazily on first use and reused for every
/// subsequent invocation of the same artifact (one compiled executable per
/// model variant, as in a serving deployment).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a runtime over an artifact directory. Fails if the PJRT
    /// client cannot start or the manifest is missing.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)
            .ok_or_else(|| anyhow!("no manifest.json in {} — run `make artifacts`", dir.display()))?;
        let client = xla::PjRtClient::cpu().context("starting PJRT CPU client")?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// True if an artifact directory looks usable.
    pub fn available(dir: &std::path::Path) -> bool {
        Manifest::load(dir).is_some()
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Name of the PJRT platform backing this client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (for tests/metrics).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute the FlatAttention per-tile block step
    /// (q [Br,D], kt [D,Bc], v [Bc,D], m/l [Br], o [Br,D])
    /// → (m', l', o'). Requires a matching artifact shape.
    pub fn block_step(
        &self,
        q: &Tensor,
        kt: &Tensor,
        v: &Tensor,
        m: &[f32],
        l: &[f32],
        o: &Tensor,
    ) -> Result<(Vec<f32>, Vec<f32>, Tensor)> {
        let (br, d) = (q.rows() as u64, q.cols() as u64);
        let bc = v.rows() as u64;
        if !self.manifest.has_block_step(br, bc, d) {
            bail!("no block_step artifact for shape r{br} c{bc} d{d} (run aot.py with this shape)");
        }
        let exe = self.executable(&block_step_artifact_name(br, bc, d))?;

        let lit2 = |t: &Tensor| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(t.data()).reshape(&[t.rows() as i64, t.cols() as i64])?)
        };
        let args = [
            lit2(q)?,
            lit2(kt)?,
            lit2(v)?,
            xla::Literal::vec1(m),
            xla::Literal::vec1(l),
            lit2(o)?,
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (m_out, l_out, o_out) = result.to_tuple3()?;
        let o_vec = o_out.to_vec::<f32>()?;
        Ok((
            m_out.to_vec::<f32>()?,
            l_out.to_vec::<f32>()?,
            Tensor::from_vec(br as usize, d as usize, o_vec),
        ))
    }

    /// Execute a full MHA forward artifact. Inputs are flattened
    /// `[B, H, S, D]` f32 buffers; returns the flattened output.
    pub fn mha(
        &self,
        b: u64,
        h: u64,
        s: u64,
        d: u64,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<Vec<f32>> {
        let n = (b * h * s * d) as usize;
        if q.len() != n || k.len() != n || v.len() != n {
            bail!("mha input length mismatch: want {n}, got {}/{}/{}", q.len(), k.len(), v.len());
        }
        if !self.manifest.mha.contains(&(b, h, s, d)) {
            bail!("no mha artifact for b{b} h{h} s{s} d{d}");
        }
        let exe = self.executable(&mha_artifact_name(b, h, s, d))?;
        let dims = [b as i64, h as i64, s as i64, d as i64];
        let args = [
            xla::Literal::vec1(q).reshape(&dims)?,
            xla::Literal::vec1(k).reshape(&dims)?,
            xla::Literal::vec1(v).reshape(&dims)?,
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

// PJRT-backed tests live in rust/tests/runtime_integration.rs (they need
// `make artifacts` to have run and the `pjrt` feature enabled); the pure
// artifact plumbing is tested in `super::artifacts`.
