//! Artifact discovery: the manifest written by `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Naming convention shared with `aot.py`.
pub fn block_step_artifact_name(br: u64, bc: u64, d: u64) -> String {
    format!("block_step_r{br}_c{bc}_d{d}.hlo.txt")
}

/// Naming convention shared with `aot.py`.
pub fn mha_artifact_name(b: u64, h: u64, s: u64, d: u64) -> String {
    format!("mha_b{b}_h{h}_s{s}_d{d}.hlo.txt")
}

/// Default artifact directory: `$FLATATTN_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("FLATATTN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Available `(br, bc, d)` block-step shapes.
    pub block_step: Vec<(u64, u64, u64)>,
    /// Available `(b, h, s, d)` full-MHA shapes.
    pub mha: Vec<(u64, u64, u64, u64)>,
}

impl Manifest {
    /// Load from `dir/manifest.json`. Returns `None` if absent or invalid
    /// (callers fall back to the native compute path).
    pub fn load(dir: &Path) -> Option<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).ok()?;
        let json = Json::parse(&text).ok()?;
        let mut m = Manifest::default();
        if let Some(list) = json.get("block_step").and_then(|v| v.as_arr()) {
            for e in list {
                let get = |k: &str| e.get(k).and_then(|v| v.as_f64()).map(|v| v as u64);
                if let (Some(br), Some(bc), Some(d)) = (get("br"), get("bc"), get("d")) {
                    m.block_step.push((br, bc, d));
                }
            }
        }
        if let Some(list) = json.get("mha").and_then(|v| v.as_arr()) {
            for e in list {
                let get = |k: &str| e.get(k).and_then(|v| v.as_f64()).map(|v| v as u64);
                if let (Some(b), Some(h), Some(s), Some(d)) = (get("b"), get("h"), get("s"), get("d"))
                {
                    m.mha.push((b, h, s, d));
                }
            }
        }
        Some(m)
    }

    /// Does a block-step artifact exist for this shape?
    pub fn has_block_step(&self, br: u64, bc: u64, d: u64) -> bool {
        self.block_step.contains(&(br, bc, d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_match_aot_convention() {
        assert_eq!(block_step_artifact_name(64, 64, 128), "block_step_r64_c64_d128.hlo.txt");
        assert_eq!(mha_artifact_name(1, 4, 256, 64), "mha_b1_h4_s256_d64.hlo.txt");
    }

    #[test]
    fn manifest_parses_generated_format() {
        let dir = std::env::temp_dir().join(format!("fa-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"block_step": [{"br": 16, "bc": 16, "d": 128, "file": "x"}],
                "mha": [{"b": 1, "h": 4, "s": 256, "d": 64, "file": "y"}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.has_block_step(16, 16, 128));
        assert!(!m.has_block_step(16, 16, 64));
        assert_eq!(m.mha, vec![(1, 4, 256, 64)]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_none() {
        assert!(Manifest::load(Path::new("/nonexistent-dir-xyz")).is_none());
    }

    #[test]
    fn default_dir_env_override() {
        // Uses a uniquely-named var interaction — set and restore.
        std::env::set_var("FLATATTN_ARTIFACTS", "/tmp/some-artifacts");
        assert_eq!(default_artifact_dir(), PathBuf::from("/tmp/some-artifacts"));
        std::env::remove_var("FLATATTN_ARTIFACTS");
        assert_eq!(default_artifact_dir(), PathBuf::from("artifacts"));
    }
}
