//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the stack touches XLA at run time. Artifacts are
//! produced once by `python/compile/aot.py` (`make artifacts`); the Rust
//! side loads the HLO text (`HloModuleProto::from_text_file` — the id-safe
//! interchange, see DESIGN.md §3), compiles each module once on the PJRT
//! CPU client, caches the executable, and feeds it `f32` literals. Python
//! is never on this path.

pub mod artifacts;
pub mod client;

pub use artifacts::{block_step_artifact_name, default_artifact_dir, mha_artifact_name, Manifest};
pub use client::Runtime;
