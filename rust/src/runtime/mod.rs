//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the stack touches XLA at run time. Artifacts are
//! produced once by `python/compile/aot.py` (`make artifacts`); the Rust
//! side loads the HLO text (`HloModuleProto::from_text_file` — the id-safe
//! interchange, see DESIGN.md §3), compiles each module once on the PJRT
//! CPU client, caches the executable, and feeds it `f32` literals. Python
//! is never on this path.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;

pub use artifacts::{block_step_artifact_name, default_artifact_dir, mha_artifact_name, Manifest};
#[cfg(feature = "pjrt")]
pub use client::Runtime;

/// True if an artifact directory looks usable (manifest present). Available
/// without the `pjrt` feature so callers can report artifact status even in
/// simulation-only builds.
pub fn artifacts_available(dir: &std::path::Path) -> bool {
    Manifest::load(dir).is_some()
}
