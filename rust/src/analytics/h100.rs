//! Published H100 reference numbers for the Fig. 5b/5c comparisons.
//!
//! The paper compares BestArch+FlatAttention against FlashAttention-3 *as
//! published* ("based on the H100 performance numbers in Shah et al. [6]",
//! arXiv v1, FP16 forward, no causal mask) and against the SemiAnalysis
//! H100 GEMM benchmarks [26] for the LLaMA-70B FFN shapes. The tables
//! below are digitized from those sources; values are achieved TFLOPS.

/// H100 SXM FP16/BF16 dense peak (no sparsity), TFLOPS.
pub const H100_PEAK_TFLOPS: f64 = 989.0;

/// H100 HBM3 peak bandwidth, GB/s (for the 40%-less-bandwidth claim).
pub const H100_HBM_GBPS: f64 = 3350.0;

/// FlashAttention-3 achieved TFLOPS on H100 (FP16 forward, non-causal),
/// digitized from Shah et al. arXiv v1 Fig. 5/6. Returns `None` for
/// shapes outside the published sweep.
pub fn h100_fa3_tflops(head_dim: u64, seq: u64) -> Option<f64> {
    let table: &[(u64, u64, f64)] = &[
        // (D, S, TFLOPS)
        (64, 512, 340.0),
        (64, 1024, 420.0),
        (64, 2048, 490.0),
        (64, 4096, 533.0),
        (64, 8192, 560.0),
        (64, 16384, 570.0),
        (128, 512, 480.0),
        (128, 1024, 560.0),
        (128, 2048, 620.0),
        (128, 4096, 660.0),
        (128, 8192, 690.0),
        (128, 16384, 700.0),
    ];
    table
        .iter()
        .find(|&&(d, s, _)| d == head_dim && s == seq)
        .map(|&(_, _, t)| t)
}

/// FA-3 utilization on H100 for a shape.
pub fn h100_fa3_utilization(head_dim: u64, seq: u64) -> Option<f64> {
    h100_fa3_tflops(head_dim, seq).map(|t| t / H100_PEAK_TFLOPS)
}

/// H100 BF16 GEMM utilization for LLaMA-70B-style shapes, digitized from
/// the SemiAnalysis benchmark the paper cites [26].
pub fn h100_gemm_utilization(m: u64, k: u64, n: u64) -> f64 {
    let table: &[(u64, u64, u64, f64)] = &[
        (4096, 8192, 28672, 760.0), // FFN up/gate
        (4096, 28672, 8192, 730.0), // FFN down
        (4096, 8192, 8192, 720.0),  // attention out-proj
        (8192, 8192, 8192, 750.0),  // square reference
    ];
    let t = table
        .iter()
        .find(|&&(tm, tk, tn, _)| tm == m && tk == k && tn == n)
        .map(|&(_, _, _, t)| t)
        // Fallback: interpolate as the mean of published points.
        .unwrap_or(740.0);
    t / H100_PEAK_TFLOPS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fa3_peaks_below_75_percent() {
        // The paper's §I footnote: FA-3 (arXiv v1) reaches no more than
        // ~75% utilization on H100.
        for &(d, s) in &[(64u64, 4096u64), (128, 4096), (128, 16384)] {
            let u = h100_fa3_utilization(d, s).unwrap();
            assert!(u < 0.75, "D{d} S{s}: {u}");
            assert!(u > 0.3);
        }
    }

    #[test]
    fn fa3_monotone_in_seq() {
        for d in [64u64, 128] {
            let mut prev = 0.0;
            for s in [512u64, 1024, 2048, 4096, 8192, 16384] {
                let t = h100_fa3_tflops(d, s).unwrap();
                assert!(t >= prev);
                prev = t;
            }
        }
    }

    #[test]
    fn unknown_shape_is_none() {
        assert!(h100_fa3_tflops(96, 4096).is_none());
        assert!(h100_fa3_tflops(128, 3000).is_none());
    }

    #[test]
    fn gemm_utilization_range() {
        let u = h100_gemm_utilization(4096, 8192, 28672);
        assert!((0.7..0.8).contains(&u));
    }
}
