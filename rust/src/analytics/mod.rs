//! Analytical models and external reference data.
//!
//! * [`io`] — the paper's §III-A HBM I/O-complexity formulas, used to
//!   cross-check the simulator's measured traffic.
//! * [`h100`] — the published H100 FlashAttention-3 and GEMM numbers the
//!   paper compares against in Fig. 5b/5c (digitized from the cited
//!   sources; the paper itself compares against these publications, not
//!   against reruns).

pub mod h100;
pub mod io;

pub use h100::{h100_fa3_tflops, h100_gemm_utilization, H100_PEAK_TFLOPS};
pub use io::{flash_io_bytes, flat_io_bytes, io_reduction};
