//! HBM I/O complexity (paper §III-A).
//!
//! FlashAttention with block size `M` on independent tiles:
//! `IO = 2·H·B·D·S·(1 + S/M)` elements.
//!
//! FlatAttention grouping `N = G²` tiles (block `√N·M` per group):
//! `IO = 2·H·B·D·S·(1 + S/(√N·M))` elements.
//!
//! Both formulas model the paper's dense-MHA *prefill*; for GQA/decode
//! traffic the builders' modeled bytes are pinned directly by tests
//! (`Workload::compulsory_bytes` carries the serving K/V scaling).

use crate::dataflow::Workload;

/// FlashAttention HBM traffic in bytes for block size `m`.
pub fn flash_io_bytes(wl: &Workload, m: u64) -> u64 {
    let elems = 2 * wl.heads * wl.batch * wl.head_dim * wl.seq * (1 + wl.seq.div_ceil(m));
    elems * Workload::BYTES_PER_ELEM
}

/// FlatAttention HBM traffic in bytes for group-level block size `block`
/// (= slice × G).
pub fn flat_io_bytes(wl: &Workload, block: u64) -> u64 {
    let elems = 2 * wl.heads * wl.batch * wl.head_dim * wl.seq * (1 + wl.seq.div_ceil(block));
    elems * Workload::BYTES_PER_ELEM
}

/// Theoretical I/O reduction of grouping `n` tiles at fixed `m`.
pub fn io_reduction(seq: u64, m: u64, n: u64) -> f64 {
    let flash = 1.0 + seq as f64 / m as f64;
    let flat = 1.0 + seq as f64 / ((n as f64).sqrt() * m as f64);
    flash / flat
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_6_6x() {
        // §III-A: S=4096, M=128, N=64 ⇒ ~6.6×.
        let r = io_reduction(4096, 128, 64);
        assert!((r - 6.6).abs() < 0.1, "{r}");
    }

    #[test]
    fn flash_io_formula() {
        let wl = Workload::new(4096, 128, 32, 2);
        // 2·32·2·128·4096·(1+32) elements × 2 bytes.
        assert_eq!(flash_io_bytes(&wl, 128), 2 * 32 * 2 * 128 * 4096 * 33 * 2);
    }

    #[test]
    fn flat_io_reduces_with_block() {
        let wl = Workload::new(4096, 128, 32, 2);
        assert!(flat_io_bytes(&wl, 4096) < flash_io_bytes(&wl, 128));
        // Full-S block: Q+O once, K/V once ⇒ exactly the compulsory traffic.
        assert_eq!(flat_io_bytes(&wl, 4096), wl.compulsory_bytes());
    }

    #[test]
    fn reduction_monotone_in_n() {
        assert!(io_reduction(4096, 128, 256) > io_reduction(4096, 128, 64));
        assert!(io_reduction(4096, 128, 1) < 1.0 + 1e-9);
    }
}
