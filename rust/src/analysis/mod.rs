//! §Analysis: static program verifier + roofline cross-checker.
//!
//! Every headline this repo reports — the paper's utilization and
//! HBM-traffic claims, the fold/parallel/fault bit-identity walls of the
//! earlier PRs — rests on invariants of sealed [`Program`] DAGs that were
//! previously enforced only by randomized differential tests and scattered
//! `debug_assert!`s. This module turns those invariants into a checkable
//! artifact: a linter that *proves* them per program and a roofline model
//! that cross-checks every DES makespan against analytical lower bounds.
//!
//! # What is proven vs what stays tested
//!
//! **Proven per program** (by [`verify_program`] / [`verify_batch`], a
//! linear-time pass over the concrete DAG at hand):
//!
//! - *Well-formedness*: every op names an allocated resource and every
//!   dependency points at an existing op (`resource-range`,
//!   `dangling-dep`).
//! - *Acyclicity*: a Kahn pass settles every op or the diagnostic carries
//!   a cycle witness naming the ops on it (`cycle`). Builder programs are
//!   topologically ordered by construction (`Program::op` requires deps
//!   to precede the op), so this guards the hand-built and
//!   template-stamped paths.
//! - *Shard-partition soundness* — the invariant wall the parallel
//!   executor's bit-identity proof stands on, promoted here from
//!   `tests/parallel_differential.rs`: the shard CSR partitions the ops
//!   (ascending within each shard), no resource's ops span two shards,
//!   every contended resource (ops from ≥ 2 distinct owner tiles) lives
//!   in [`SHARED_SHARD`], the per-resource owner table agrees with the
//!   per-op map, and every cross-shard dependency edge touches the shared
//!   shard (`shard-partition`, `shard-resource-span`, `shard-leak`,
//!   `shard-cross-edge`).
//! - *Fold-exactness precondition* (`fold-chain`): symmetry folding
//!   (see `crate::dataflow`) is exact iff synchronous private chains
//!   never resource-block. The static sufficient condition: for each
//!   private resource, every op transitively depends on the previous op
//!   on that resource — then FIFO order equals dependency order and an op
//!   is never ready before its resource is free. Dependency edges always
//!   point at smaller op ids, so the reachability search for consecutive
//!   ops `a < b` is confined to `(a, b]` and the whole pass stays near
//!   linear. Checked on programs that actually folded (`fold.ops > 0`):
//!   the surviving representative stream is congruent to every elided
//!   one, so proving its chains proves theirs.
//! - *Batch geometry*: entry op spans are ascending, disjoint and
//!   contained in the program, and no tile carries ops of two entries —
//!   the disjoint-band property the scheduler's conservative-composition
//!   argument requires (`batch-span`, `batch-band-overlap`).
//! - *Fault-plan sanity* ([`verify_fault_plan`]): windows are non-empty,
//!   derate/slowdown ratios are ≥ 1, channels and killed tiles exist in
//!   the target architecture, and no tile dies twice (`fault-window`,
//!   `fault-ratio`, `fault-channel`, `fault-tile`,
//!   `fault-duplicate-death`).
//!
//! **Still tested, not proven**: that the DES *executes* a verified
//! program correctly (engine differential tests), that folding/parallel
//! runs are bit-identical (fold/parallel/fault differential tests), and
//! data-race freedom of the parallel executor (the determinism matrix
//! plus the nightly ThreadSanitizer CI job). The verifier checks the
//! *inputs* those proofs assume; it cannot replace them.
//!
//! # The roofline cross-check
//!
//! [`Roofline`] computes lower bounds on the makespan of any run and
//! [`Roofline::check`] asserts `makespan ≥ max(bounds)` — a violation is
//! a simulator bug by construction, and the diagnostic names the
//! offending bound and resource. Bounds:
//!
//! - *Compute*: `flops / peak_flops_per_cycle`. Sound because every
//!   RedMulE op's occupancy is at least its flops divided by the tile's
//!   peak (the timing model only adds fill/drain overhead), so one tile
//!   cannot retire more than `tile_peak` flops per busy cycle and the
//!   mesh cannot retire more than `peak_flops_per_cycle` per makespan
//!   cycle. Uses the workload's compulsory matmul flops and, when a
//!   program is given, the program's (≥ compulsory) executed flops.
//! - *HBM*: compulsory bytes over aggregate bandwidth
//!   (workload-level), and per-channel occupancy sums (program-level) —
//!   each channel is a FIFO resource, so its total occupancy serializes.
//! - *NoC*: per-bus occupancy sums over resources carrying fabric
//!   collective ops.
//! - *Serialization*: the same per-resource occupancy sum over *every*
//!   resource — the binding FIFO is a lower bound whatever kind of
//!   resource it is.
//!
//! **Sound under folding**: folded and unfolded runs have identical
//! makespans (the fold differential wall), shared-resource ops are kept
//! verbatim (channel/bus occupancy sums unchanged), `Program::flops`
//! counts elided work, and a folded delay op's occupancy equals the real
//! chain residency it stands for — every bound is computed against
//! quantities folding preserves.
//!
//! **Sound under slow-faults, skipped under deaths**: outages, derates
//! and NoC slowdowns only delay ops or stretch their occupancy, so a
//! faulted makespan only grows and every fault-free lower bound still
//! holds. Tile deaths *remove* work, so the bounds above (which count all
//! of it) are no longer lower bounds; callers skip the roofline check for
//! plans with deaths ([`FaultPlan::deaths`] non-empty).
//!
//! # Wiring
//!
//! `Program::seal` re-verifies every program it seals in debug builds,
//! and in release builds when [`set_release_verify`] is on (the `--verify`
//! CLI flag on `run` / `schedule` / `report`). `flatattention lint`
//! sweeps dataflows × presets × fold/solo/paged modes × fault plans and
//! prints a pass/fail table; CI runs it in the `rust-analysis` job, and
//! the benches record `roofline_utilization` gated by
//! `scripts/check_bench_targets.py`.
//!
//! [`SHARED_SHARD`]: crate::sim::SHARED_SHARD
//! [`FaultPlan::deaths`]: crate::sim::FaultPlan

mod roofline;
mod verify;

pub use roofline::{Roofline, RooflineReport};
pub use verify::{verify_batch, verify_fault_plan, verify_program, Diagnostic};

use std::sync::atomic::{AtomicBool, Ordering};

use crate::sim::Program;

/// Release-mode verify-on-seal switch (debug builds always verify).
static RELEASE_VERIFY: AtomicBool = AtomicBool::new(false);

/// Make every `Program::seal` re-run the structural verifier in release
/// builds too — the CLI's `--verify` flag. Debug builds always verify.
pub fn set_release_verify(enabled: bool) {
    RELEASE_VERIFY.store(enabled, Ordering::Relaxed);
}

/// Current release-mode verify-on-seal setting.
pub fn release_verify() -> bool {
    RELEASE_VERIFY.load(Ordering::Relaxed)
}

/// Run the structural verifier and panic with every diagnostic on
/// failure — the seal-time hook and the builders' debug self-check.
pub fn assert_verified(p: &Program) {
    let diags = verify_program(p);
    if diags.is_empty() {
        return;
    }
    let mut msg = String::from("program verification failed:");
    for d in diags.iter().take(8) {
        msg.push_str("\n  ");
        msg.push_str(&d.to_string());
    }
    if diags.len() > 8 {
        msg.push_str(&format!("\n  ... and {} more", diags.len() - 8));
    }
    panic!("{msg}");
}
