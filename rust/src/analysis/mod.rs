//! §Analysis: static program verifier + roofline cross-checker.
//!
//! [`verify_program`] / [`verify_batch`] prove, in one linear pass over a
//! sealed [`Program`] DAG, the invariants the differential-test walls
//! assume: well-formedness (`resource-range`, `dangling-dep`), acyclicity
//! with a cycle witness (`cycle`), §Shard partition soundness
//! (`shard-partition`, `shard-resource-span`, `shard-leak`,
//! `shard-cross-edge`), the fold-exactness chain precondition
//! (`fold-chain`), batch band disjointness (`batch-span`,
//! `batch-band-overlap`), and fault-plan sanity ([`verify_fault_plan`]).
//! What stays tested rather than proven — and why the verifier cannot
//! replace the differential walls — is argued in `docs/ARCHITECTURE.md`
//! §"Static verification and the roofline cross-check".
//!
//! [`Roofline`] computes analytical lower bounds on any run's makespan
//! (compute, HBM, NoC, per-resource serialization) and
//! [`Roofline::check`] asserts `makespan ≥ max(bounds)` — a violation is
//! a simulator bug by construction, and the diagnostic names the
//! offending bound and resource. The bounds are sound under folding and
//! under slow-faults, and are skipped for plans with tile deaths (which
//! remove work); the soundness arguments live in the same ARCHITECTURE
//! section.
//!
//! Wiring: `Program::seal` re-verifies every program it seals in debug
//! builds, and in release when [`set_release_verify`] is on (the
//! `--verify` CLI flag). `flatattention lint` sweeps dataflows × presets
//! × fold/solo/paged modes × fault plans and prints a pass/fail table;
//! CI runs it in the `rust-analysis` job, and the benches record
//! `roofline_utilization` gated by `scripts/check_bench_targets.py`.
//!
//! [`Program`]: crate::sim::Program

mod roofline;
mod verify;

pub use roofline::{Roofline, RooflineReport};
pub use verify::{verify_batch, verify_fault_plan, verify_program, Diagnostic};

use std::sync::atomic::{AtomicBool, Ordering};

use crate::sim::Program;

/// Release-mode verify-on-seal switch (debug builds always verify).
static RELEASE_VERIFY: AtomicBool = AtomicBool::new(false);

/// Make every `Program::seal` re-run the structural verifier in release
/// builds too — the CLI's `--verify` flag. Debug builds always verify.
pub fn set_release_verify(enabled: bool) {
    RELEASE_VERIFY.store(enabled, Ordering::Relaxed);
}

/// Current release-mode verify-on-seal setting.
pub fn release_verify() -> bool {
    RELEASE_VERIFY.load(Ordering::Relaxed)
}

/// Run the structural verifier and panic with every diagnostic on
/// failure — the seal-time hook and the builders' debug self-check.
pub fn assert_verified(p: &Program) {
    let diags = verify_program(p);
    if diags.is_empty() {
        return;
    }
    let mut msg = String::from("program verification failed:");
    for d in diags.iter().take(8) {
        msg.push_str("\n  ");
        msg.push_str(&d.to_string());
    }
    if diags.len() > 8 {
        msg.push_str(&format!("\n  ... and {} more", diags.len() - 8));
    }
    panic!("{msg}");
}
