//! Roofline cross-checker: analytical lower bounds on DES makespans
//! (`docs/ARCHITECTURE.md` §"Static verification and the roofline
//! cross-check" argues each bound's soundness — including under folding
//! and slow-faults).

use crate::arch::ArchConfig;
use crate::dataflow::Workload;
use crate::noc::is_fabric_component;
use crate::sim::{Component, Cycle, Program};

use super::Diagnostic;

/// Lower bounds on the makespan of one run, with the resource each
/// program-level bound binds on (for diagnostics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Roofline {
    /// Flops over whole-mesh peak FLOP/cycle.
    pub compute_bound: Cycle,
    /// Compulsory bytes over aggregate HBM bandwidth (workload-level),
    /// raised to the busiest channel's occupancy sum when a program is
    /// given.
    pub hbm_bound: Cycle,
    /// Busiest HBM channel resource, when program-derived.
    pub hbm_resource: Option<u32>,
    /// Busiest NoC bus occupancy sum (program-level only; a workload
    /// alone does not determine the collective schedule).
    pub noc_bound: Cycle,
    /// Busiest NoC bus resource, when program-derived.
    pub noc_resource: Option<u32>,
    /// Busiest resource of *any* kind: every resource is a FIFO, so its
    /// total occupancy serializes whatever it is.
    pub serial_bound: Cycle,
    /// The resource binding `serial_bound`, when program-derived.
    pub serial_resource: Option<u32>,
}

/// A passed roofline check: the binding bound and the run's utilization
/// against it (`bound / makespan`, in `(0, 1]`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RooflineReport {
    /// The binding lower bound (cycles).
    pub bound: Cycle,
    /// Which bound binds: `"compute"`, `"hbm"`, `"noc"` or `"serial"`.
    pub binding: &'static str,
    /// `bound / makespan`, in `(0, 1]`.
    pub utilization: f64,
}

fn ceil_div(a: u64, b: u64) -> u64 {
    if b == 0 {
        0
    } else {
        a.div_ceil(b)
    }
}

impl Roofline {
    /// Bounds derivable from the workload and architecture alone:
    /// compulsory flops over peak compute, compulsory bytes over peak
    /// aggregate HBM bandwidth.
    pub fn from_workload(arch: &ArchConfig, wl: &Workload) -> Roofline {
        Roofline {
            compute_bound: ceil_div(wl.matmul_flops(), arch.peak_flops_per_cycle()),
            hbm_bound: ceil_div(wl.compulsory_bytes(), arch.hbm.peak_bytes_per_cycle()),
            hbm_resource: None,
            noc_bound: 0,
            noc_resource: None,
            serial_bound: 0,
            serial_resource: None,
        }
    }

    /// Workload bounds sharpened by the concrete program: executed flops
    /// (≥ compulsory — masked blocks compute before masking) and
    /// per-resource occupancy sums. Resources are classified by the ops
    /// they carry: HBM if any op is an HBM access, NoC if any op is a
    /// fabric collective.
    pub fn of(arch: &ArchConfig, wl: &Workload, p: &Program) -> Roofline {
        let mut r = Roofline::from_workload(arch, wl);
        r.fold_in_program(arch, p);
        r
    }

    /// Program-only bounds (no workload): a composed batch program has no
    /// single `Workload`, but `Program::flops` and the occupancy sums
    /// still bound its makespan.
    pub fn from_program(arch: &ArchConfig, p: &Program) -> Roofline {
        let mut r = Roofline {
            compute_bound: 0,
            hbm_bound: 0,
            hbm_resource: None,
            noc_bound: 0,
            noc_resource: None,
            serial_bound: 0,
            serial_resource: None,
        };
        r.fold_in_program(arch, p);
        r
    }

    fn fold_in_program(&mut self, arch: &ArchConfig, p: &Program) {
        self.compute_bound =
            self.compute_bound.max(ceil_div(p.flops, arch.peak_flops_per_cycle()));
        let nr = p.num_resources();
        let mut occ = vec![0u64; nr];
        let mut is_hbm = vec![false; nr];
        let mut is_noc = vec![false; nr];
        for op in p.ops() {
            let r = op.resource.0 as usize;
            occ[r] += op.occupancy;
            is_hbm[r] |= op.component == Component::HbmAccess;
            is_noc[r] |= is_fabric_component(op.component);
        }
        for r in 0..nr {
            if occ[r] > self.serial_bound {
                self.serial_bound = occ[r];
                self.serial_resource = Some(r as u32);
            }
            if is_hbm[r] && occ[r] > self.hbm_bound {
                self.hbm_bound = occ[r];
                self.hbm_resource = Some(r as u32);
            }
            if is_noc[r] && occ[r] > self.noc_bound {
                self.noc_bound = occ[r];
                self.noc_resource = Some(r as u32);
            }
        }
    }

    /// The tightest lower bound.
    pub fn bound(&self) -> Cycle {
        self.compute_bound.max(self.hbm_bound).max(self.noc_bound).max(self.serial_bound)
    }

    /// Cross-check one run: `makespan >= max(bounds)` or a diagnostic
    /// naming the violated bound and its resource. On success, reports
    /// utilization = `bound / makespan`.
    ///
    /// ```
    /// use flatattention::analysis::Roofline;
    /// use flatattention::arch::presets;
    /// use flatattention::dataflow::{run, Dataflow, Workload};
    ///
    /// let arch = presets::table2(8);
    /// let wl = Workload::new(256, 64, 4, 1);
    /// let stats = run(&arch, &wl, Dataflow::Flash2, 1);
    /// let rep = Roofline::from_workload(&arch, &wl).check(stats.makespan).unwrap();
    /// assert!(rep.utilization > 0.0 && rep.utilization <= 1.0);
    /// ```
    pub fn check(&self, makespan: Cycle) -> Result<RooflineReport, Diagnostic> {
        let bounds: [(&'static str, Cycle, Option<u32>); 4] = [
            ("compute", self.compute_bound, None),
            ("hbm", self.hbm_bound, self.hbm_resource),
            ("noc", self.noc_bound, self.noc_resource),
            ("serial", self.serial_bound, self.serial_resource),
        ];
        let &(binding, bound, resource) =
            bounds.iter().max_by_key(|&&(_, b, _)| b).expect("non-empty");
        if makespan < bound {
            let on = resource.map_or_else(String::new, |r| format!(" (resource {r})"));
            return Err(Diagnostic {
                check: "roofline",
                message: format!(
                    "makespan {makespan} below the {binding} lower bound {bound}{on} — \
                     the simulator finished faster than the hardware could"
                ),
            });
        }
        Ok(RooflineReport {
            bound,
            binding,
            utilization: if makespan == 0 { 1.0 } else { bound as f64 / makespan as f64 },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::dataflow::{build_program, run, tracked_tile, Dataflow, Workload};
    use crate::sim::execute;

    #[test]
    fn bounds_hold_and_name_violations() {
        let arch = presets::table2(8);
        let wl = Workload::new(512, 64, 8, 1);
        let df = Dataflow::Flash2;
        let group = arch.mesh_x;
        let mut p = build_program(&arch, &wl, df, group);
        p.seal();
        let stats = execute(&p, tracked_tile(&arch, df, group));
        let rl = Roofline::of(&arch, &wl, &p);
        assert!(rl.bound() > 0);
        let rep = rl.check(stats.makespan).expect("bound must hold");
        assert!(rep.utilization > 0.0 && rep.utilization <= 1.0, "{rep:?}");
        // A makespan below the bound is flagged and names the bound.
        let err = rl.check(rl.bound() - 1).expect_err("must violate");
        assert_eq!(err.check, "roofline");
        assert!(err.message.contains("lower bound"), "{err:?}");
    }

    #[test]
    fn workload_bounds_hold_for_every_dataflow() {
        let arch = presets::table2(8);
        let wl = Workload::new(256, 64, 4, 1);
        for df in crate::dataflow::ALL_DATAFLOWS {
            let stats = run(&arch, &wl, df, arch.mesh_x);
            let rl = Roofline::from_workload(&arch, &wl);
            let rep = rl.check(stats.makespan).unwrap_or_else(|d| panic!("{}: {d}", df.label()));
            assert!(rep.utilization <= 1.0);
        }
    }
}
