//! Structural verifier over [`Program`] / [`BatchProgram`] DAGs and
//! [`FaultPlan`]s — the "proven per program" half of `crate::analysis`
//! (the module essay states each invariant and why it matters).
//!
//! Every check appends [`Diagnostic`]s instead of panicking, so callers
//! choose the failure mode: `Program::seal` panics through
//! [`crate::analysis::assert_verified`], `flatattention lint` renders a
//! table, and tests pin exact defect classes.

use std::collections::HashMap;
use std::fmt;

use crate::scheduler::BatchProgram;
use crate::sim::{FaultPlan, Program, NO_TILE, SHARED_SHARD};

/// One verifier finding: a stable defect-class tag (`cycle`,
/// `shard-leak`, `batch-band-overlap`, ...) plus a message naming the
/// offending ops/resources.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Stable defect-class tag.
    pub check: &'static str,
    /// Names the offending ops/resources.
    pub message: String,
}

impl Diagnostic {
    fn new(check: &'static str, message: String) -> Self {
        Diagnostic { check, message }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.check, self.message)
    }
}

/// Verify one program. Well-formedness and acyclicity always run; the
/// shard wall and the fold-chain precondition additionally run once the
/// program is sealed (they audit seal's own derived state).
pub fn verify_program(p: &Program) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    well_formed(p, &mut diags);
    if diags.is_empty() {
        // Later passes index by dep id; skip them on malformed input.
        acyclic(p, &mut diags);
        if p.is_sealed() {
            shard_wall(p, &mut diags);
            fold_chains(p, &mut diags);
        }
    }
    diags
}

/// Verify a composed batch program: the underlying DAG plus the entry
/// span/band geometry the scheduler's composition argument requires.
pub fn verify_batch(bp: &BatchProgram) -> Vec<Diagnostic> {
    let mut diags = verify_program(&bp.program);
    let n = bp.program.num_ops();
    let mut prev_end = 0usize;
    for (k, &(start, end)) in bp.spans.iter().enumerate() {
        if start > end || end > n {
            diags.push(Diagnostic::new(
                "batch-span",
                format!("entry {k} spans ops [{start}, {end}) outside the {n}-op program"),
            ));
        } else if start < prev_end {
            diags.push(Diagnostic::new(
                "batch-span",
                format!("entry {k} span [{start}, {end}) overlaps the previous entry"),
            ));
        }
        prev_end = prev_end.max(end);
    }

    // GEMM tails (layered composition): one tail per entry or none at
    // all, every tail after every attention span, tails pairwise ordered.
    if !bp.tail_spans.is_empty() && bp.tail_spans.len() != bp.spans.len() {
        diags.push(Diagnostic::new(
            "batch-tail",
            format!(
                "{} tail spans for {} entries (must be 0 or one per entry)",
                bp.tail_spans.len(),
                bp.spans.len()
            ),
        ));
    }
    let attn_end = prev_end;
    let mut prev_tail_end = attn_end;
    for (k, &(start, end)) in bp.tail_spans.iter().enumerate() {
        if start > end || end > n {
            diags.push(Diagnostic::new(
                "batch-tail",
                format!("entry {k} tail spans ops [{start}, {end}) outside the {n}-op program"),
            ));
        } else if start < prev_tail_end {
            diags.push(Diagnostic::new(
                "batch-tail",
                format!(
                    "entry {k} tail [{start}, {end}) overlaps a previous span (attention ends at {attn_end})"
                ),
            ));
        }
        prev_tail_end = prev_tail_end.max(end);
    }

    // Disjoint tile bands: a tile may carry ops of at most one entry —
    // counting the entry's GEMM tail, which must stay on the same band.
    // (Channel/bus ops are tile-tagged by their *issuing* tile, so they
    // participate too — sharing a tile across entries would break the
    // per-entry completion attribution either way.)
    let ops = bp.program.ops();
    let mut owner: HashMap<u32, usize> = HashMap::new();
    let mut reported: Vec<u32> = Vec::new();
    let entry_ranges = bp
        .spans
        .iter()
        .enumerate()
        .chain(bp.tail_spans.iter().enumerate())
        .map(|(k, &(s, e))| (k, s, e));
    for (k, start, end) in entry_ranges {
        if start > end || end > n {
            continue; // already diagnosed above
        }
        for op in &ops[start..end] {
            if op.tile == NO_TILE {
                continue;
            }
            match owner.insert(op.tile, k) {
                Some(prev) if prev != k && !reported.contains(&op.tile) => {
                    reported.push(op.tile);
                    diags.push(Diagnostic::new(
                        "batch-band-overlap",
                        format!("tile {} carries ops of entries {prev} and {k}", op.tile),
                    ));
                }
                _ => {}
            }
        }
    }
    diags
}

/// Sanity-check a fault plan against the architecture it will be resolved
/// on: `channels`/`tiles` are the target's HBM channel and tile counts.
pub fn verify_fault_plan(plan: &FaultPlan, channels: usize, tiles: usize) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut window = |kind: &str, ch: Option<u32>, from: u64, until: u64| {
        if from >= until {
            let target = ch.map_or_else(String::new, |c| format!(" on channel {c}"));
            diags.push(Diagnostic::new(
                "fault-window",
                format!("{kind}{target}: window [{from}, {until}) is empty or inverted"),
            ));
        }
    };
    for o in &plan.outages {
        window("outage", Some(o.channel), o.from, o.until);
    }
    for d in &plan.derates {
        window("derate", Some(d.channel), d.from, d.until);
    }
    for s in &plan.noc {
        window("NoC slowdown", None, s.from, s.until);
    }

    for (kind, num, den) in plan
        .derates
        .iter()
        .map(|d| ("derate", d.num, d.den))
        .chain(plan.noc.iter().map(|s| ("NoC slowdown", s.num, s.den)))
    {
        if den == 0 || num < den {
            diags.push(Diagnostic::new(
                "fault-ratio",
                format!("{kind} ratio {num}/{den} must be >= 1 (faults only slow things down)"),
            ));
        }
    }

    for (kind, c) in plan
        .outages
        .iter()
        .map(|o| ("outage", o.channel))
        .chain(plan.derates.iter().map(|d| ("derate", d.channel)))
    {
        if c as usize >= channels {
            diags.push(Diagnostic::new(
                "fault-channel",
                format!("{kind} targets channel {c}, but the architecture has {channels}"),
            ));
        }
    }

    let mut seen: Vec<u32> = Vec::new();
    for t in &plan.deaths {
        if t.tile as usize >= tiles {
            diags.push(Diagnostic::new(
                "fault-tile",
                format!("death targets tile {}, but the mesh has {tiles} tiles", t.tile),
            ));
        }
        if seen.contains(&t.tile) {
            diags.push(Diagnostic::new(
                "fault-duplicate-death",
                format!("tile {} dies more than once", t.tile),
            ));
        } else {
            seen.push(t.tile);
        }
    }
    diags
}

/// Every op names an allocated resource; every dependency record stays
/// inside the deps pool and points at an existing op.
fn well_formed(p: &Program, diags: &mut Vec<Diagnostic>) {
    let n = p.num_ops() as u32;
    let nr = p.num_resources() as u32;
    let pool = p.deps_pool.len();
    for (i, op) in p.ops().iter().enumerate() {
        if op.resource.0 >= nr {
            diags.push(Diagnostic::new(
                "resource-range",
                format!("op {i} runs on resource {}, but only {nr} were allocated", op.resource.0),
            ));
        }
        let end = op.deps_start as usize + op.deps_len as usize;
        if end > pool {
            diags.push(Diagnostic::new(
                "dangling-dep",
                format!("op {i} dep record [{}..{end}) runs past the deps pool", op.deps_start),
            ));
            continue;
        }
        for &d in p.deps_of(op) {
            if d >= n {
                diags.push(Diagnostic::new(
                    "dangling-dep",
                    format!("op {i} depends on op {d}, past the last op ({})", n - 1),
                ));
            }
        }
    }
}

/// Kahn pass: every op must settle; otherwise extract a concrete cycle
/// witness by walking unsettled deps (any unsettled op has one, and the
/// walk must revisit an op).
fn acyclic(p: &Program, diags: &mut Vec<Diagnostic>) {
    let n = p.num_ops();
    let ops = p.ops();
    let mut indeg: Vec<u32> = ops.iter().map(|op| op.deps_len).collect();
    // Dependents CSR derived from the deps themselves — this pass audits
    // the sealed CSR rather than trusting it.
    let mut out_count = vec![0u32; n + 1];
    for op in ops {
        for &d in p.deps_of(op) {
            out_count[d as usize + 1] += 1;
        }
    }
    for i in 0..n {
        out_count[i + 1] += out_count[i];
    }
    let mut out_edges = vec![0u32; *out_count.last().unwrap_or(&0) as usize];
    let mut cursor = out_count.clone();
    for (i, op) in ops.iter().enumerate() {
        for &d in p.deps_of(op) {
            out_edges[cursor[d as usize] as usize] = i as u32;
            cursor[d as usize] += 1;
        }
    }

    let mut stack: Vec<u32> =
        indeg.iter().enumerate().filter(|&(_, &d)| d == 0).map(|(i, _)| i as u32).collect();
    let mut settled = 0usize;
    while let Some(i) = stack.pop() {
        settled += 1;
        for &j in &out_edges[out_count[i as usize] as usize..out_count[i as usize + 1] as usize] {
            indeg[j as usize] -= 1;
            if indeg[j as usize] == 0 {
                stack.push(j);
            }
        }
    }
    if settled == n {
        return;
    }

    // Witness: from any unsettled op, repeatedly step to an unsettled dep
    // until an op repeats; the slice from its first visit is a cycle.
    let start = indeg.iter().position(|&d| d > 0).expect("unsettled op exists") as u32;
    let mut path: Vec<u32> = vec![start];
    let mut pos: HashMap<u32, usize> = HashMap::from([(start, 0)]);
    let cycle = loop {
        let cur = *path.last().unwrap();
        let next = p.deps_of(&ops[cur as usize])
            .iter()
            .copied()
            .find(|&d| indeg[d as usize] > 0)
            .expect("unsettled op has an unsettled dep");
        if let Some(&at) = pos.get(&next) {
            break &path[at..];
        }
        pos.insert(next, path.len());
        path.push(next);
    };
    let mut names: Vec<String> = cycle
        .iter()
        .take(8)
        .map(|&i| format!("op {i} (resource {})", ops[i as usize].resource.0))
        .collect();
    if cycle.len() > 8 {
        names.push(format!("... {} more", cycle.len() - 8));
    }
    diags.push(Diagnostic::new(
        "cycle",
        format!(
            "dependency cycle of {} ops ({} ops never settle): {}",
            cycle.len(),
            n - settled,
            names.join(" -> ")
        ),
    ));
}

/// The shard-partition wall the parallel executor's bit-identity rests
/// on (promoted from `tests/parallel_differential.rs`; see the module
/// essay for the invariant list).
fn shard_wall(p: &Program, diags: &mut Vec<Diagnostic>) {
    let n = p.num_ops();
    let shard_of = p.op_shards();
    let n_shards = p.num_shards();
    if shard_of.len() != n {
        diags.push(Diagnostic::new(
            "shard-partition",
            format!("shard map covers {} ops, program has {n}", shard_of.len()),
        ));
        return;
    }
    if let Some((i, &s)) = shard_of.iter().enumerate().find(|&(_, &s)| s as usize >= n_shards) {
        diags.push(Diagnostic::new(
            "shard-partition",
            format!("op {i} mapped to shard {s}, but only {n_shards} shards exist"),
        ));
        return;
    }

    // The CSR partitions 0..n: each op listed exactly once, ascending,
    // in the shard the per-op map names.
    let mut seen = vec![false; n];
    for s in 0..n_shards as u32 {
        let mut prev: Option<u32> = None;
        for &i in p.shard_op_list(s) {
            let iu = i as usize;
            if iu >= n || seen[iu] {
                diags.push(Diagnostic::new(
                    "shard-partition",
                    format!("shard {s} lists op {i} out of range or twice"),
                ));
                return;
            }
            seen[iu] = true;
            if shard_of[iu] != s {
                diags.push(Diagnostic::new(
                    "shard-partition",
                    format!("op {i} listed in shard {s} but mapped to shard {}", shard_of[iu]),
                ));
            }
            if prev.is_some_and(|pr| pr >= i) {
                diags.push(Diagnostic::new(
                    "shard-partition",
                    format!("shard {s} op list not ascending at op {i}"),
                ));
            }
            prev = Some(i);
        }
    }
    if let Some(i) = seen.iter().position(|&b| !b) {
        diags.push(Diagnostic::new(
            "shard-partition",
            format!("op {i} (shard {}) missing from every shard's op list", shard_of[i]),
        ));
    }

    // Resources never span shards; contended resources (>= 2 distinct
    // owner tiles) live in the shared shard; the per-resource owner
    // table agrees with the ops.
    let nr = p.num_resources();
    let ops = p.ops();
    let mut res_first_shard = vec![u32::MAX; nr];
    let mut res_first_tile: Vec<Option<u32>> = vec![None; nr];
    let mut res_reported = vec![false; nr];
    for (i, op) in ops.iter().enumerate() {
        let r = op.resource.0 as usize;
        let s = shard_of[i];
        if res_first_shard[r] == u32::MAX {
            res_first_shard[r] = s;
        } else if res_first_shard[r] != s && !res_reported[r] {
            res_reported[r] = true;
            diags.push(Diagnostic::new(
                "shard-resource-span",
                format!(
                    "resource {r} has ops in shard {} and shard {s} (op {i})",
                    res_first_shard[r]
                ),
            ));
        }
        match res_first_tile[r] {
            None => res_first_tile[r] = Some(op.tile),
            Some(t) if t != op.tile && res_first_shard[r] != SHARED_SHARD && !res_reported[r] => {
                res_reported[r] = true;
                diags.push(Diagnostic::new(
                    "shard-leak",
                    format!(
                        "contended resource {r} (tiles {t} and {}) lives in private shard {}",
                        op.tile, res_first_shard[r]
                    ),
                ));
            }
            _ => {}
        }
    }
    let res_shards = p.resource_shards();
    for r in 0..nr {
        if res_first_shard[r] != u32::MAX && res_shards[r] != res_first_shard[r] {
            diags.push(Diagnostic::new(
                "shard-partition",
                format!(
                    "resource {r} owner table says shard {}, its ops sit in shard {}",
                    res_shards[r], res_first_shard[r]
                ),
            ));
        }
    }

    // Every cross-shard dependency edge touches the shared shard.
    for (i, op) in ops.iter().enumerate() {
        let si = shard_of[i];
        for &d in p.deps_of(op) {
            let sd = shard_of[d as usize];
            if si != sd && si != SHARED_SHARD && sd != SHARED_SHARD {
                diags.push(Diagnostic::new(
                    "shard-cross-edge",
                    format!("private->private edge op {d} (shard {sd}) -> op {i} (shard {si})"),
                ));
                return; // one witness is enough; these cascade
            }
        }
    }
}

/// Fold-exactness precondition on programs that actually folded: on
/// every private resource, each op transitively depends on the previous
/// op on that resource, so FIFO order equals dependency order and the
/// chain can never resource-block (module essay, "fold-chain").
fn fold_chains(p: &Program, diags: &mut Vec<Diagnostic>) {
    if p.fold.ops == 0 {
        return;
    }
    let res_shards = p.resource_shards();
    let ops = p.ops();
    let mut last = vec![u32::MAX; p.num_resources()];
    // Epoch-stamped visited set reused across the (op, prev-op) queries.
    let mut visited = vec![0u32; p.num_ops()];
    let mut epoch = 0u32;
    let mut stack: Vec<u32> = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        let r = op.resource.0 as usize;
        if res_shards[r] == SHARED_SHARD {
            continue; // shared resources simulate verbatim; FIFO contention is the model
        }
        let prev = last[r];
        last[r] = i as u32;
        if prev == u32::MAX {
            continue;
        }
        // Backward reachability i -> prev. Deps point at strictly smaller
        // ids, so the search stays within (prev, i] and terminates.
        epoch += 1;
        stack.clear();
        stack.push(i as u32);
        let mut found = false;
        while let Some(cur) = stack.pop() {
            for &d in p.deps_of(&ops[cur as usize]) {
                if d == prev {
                    found = true;
                    stack.clear();
                    break;
                }
                if d > prev && visited[d as usize] != epoch {
                    visited[d as usize] = epoch;
                    stack.push(d);
                }
            }
        }
        if !found {
            diags.push(Diagnostic::new(
                "fold-chain",
                format!(
                    "private resource {r}: op {i} has no dependency path to op {prev}, the \
                     previous op on the resource — the chain can resource-block and folding \
                     would not be exact"
                ),
            ));
            return; // one witness; a broken builder repeats this per block
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Component, Op, ResourceId};

    fn two_op_chain() -> Program {
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 4, 0, Component::Other, 0, 0, &[]);
        let _b = p.op(r, 4, 0, Component::Other, 0, 0, &[a]);
        p
    }

    #[test]
    fn clean_program_verifies() {
        let mut p = two_op_chain();
        assert!(verify_program(&p).is_empty());
        p.seal();
        assert!(verify_program(&p).is_empty());
    }

    /// §Incremental: a sealed batch program whose costs were patched from
    /// a structurally identical re-emission must still pass every check —
    /// the shard wall and span geometry audit seal-derived state, which a
    /// cost patch deliberately keeps.
    #[test]
    fn cost_patched_batch_program_still_verifies() {
        use crate::arch::presets;
        use crate::dataflow::{Dataflow, Workload};
        use crate::hbm::PageMap;
        use crate::scheduler::batch::{compose, compose_unsealed_in, BatchEntry};
        use crate::sim::ProgramArena;

        let arch = presets::table2(8);
        let mut pages = PageMap::new(32);
        pages.grow_to(300, |p| (8 + (p % 2)) as u32);
        let wl0 = Workload::new(300, 64, 4, 1).with_kv_heads(2).decode();
        let e0 = [BatchEntry { request: 0, slot: 0, workload: wl0, pages: &pages }];
        let mut bp = compose(&arch, Dataflow::Flash2, 2, 4, &e0);
        assert!(verify_batch(&bp).is_empty());
        // One more cached token: same op structure, new costs.
        pages.grow_to(301, |p| (8 + (p % 2)) as u32);
        let wl1 = Workload::new(301, 64, 4, 1).with_kv_heads(2).decode();
        let e1 = [BatchEntry { request: 0, slot: 0, workload: wl1, pages: &pages }];
        let mut arena = ProgramArena::new();
        let scratch = compose_unsealed_in(&mut arena, &arch, Dataflow::Flash2, 2, 4, &e1);
        assert_eq!(bp.spans, scratch.spans);
        assert!(bp.program.patch_costs_from(&scratch.program), "structure must be stable");
        assert!(verify_batch(&bp).is_empty(), "patched programs verify unchanged");
    }

    #[test]
    fn cycle_is_named_with_its_ops() {
        // `Program::op` cannot express a cycle; corrupt the pools directly
        // (op 0 <-> op 1) the way `sim::engine`'s cycle tests do.
        let mut p = Program::new();
        let r = p.resource();
        let proto = |deps_start: u32| Op {
            resource: r,
            occupancy: 1,
            latency: 0,
            component: Component::Other,
            tile: NO_TILE,
            hbm_bytes: 0,
            deps_start,
            deps_len: 1,
        };
        p.deps_pool.push(1);
        p.ops.push(proto(0));
        p.deps_pool.push(0);
        p.ops.push(proto(1));
        let diags = verify_program(&p);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].check, "cycle");
        assert!(diags[0].message.contains("op 0") && diags[0].message.contains("op 1"));
    }

    #[test]
    fn dangling_dep_and_bad_resource_are_named() {
        let mut p = two_op_chain();
        p.ops[1].deps_start = 0;
        p.ops[1].deps_len = 2; // runs past the 1-entry pool
        p.ops[0].resource = ResourceId(7);
        let diags = verify_program(&p);
        let checks: Vec<_> = diags.iter().map(|d| d.check).collect();
        assert!(checks.contains(&"dangling-dep"), "{diags:?}");
        assert!(checks.contains(&"resource-range"), "{diags:?}");
    }

    #[test]
    fn shard_leak_is_named() {
        // Two tiles on one engine resource is a contended resource; force
        // it into a private shard by tampering with the sealed state.
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 1, 0, Component::RedMule, 0, 0, &[]);
        let _ = p.op(r, 1, 0, Component::RedMule, 1, 0, &[a]);
        p.seal();
        assert!(verify_program(&p).is_empty());
        // Corrupt: pretend the resource's ops live in a private shard 1.
        for s in p.shard_of.iter_mut() {
            *s = 1;
        }
        p.shard_start = vec![0, 0, 2];
        p.res_shard[0] = 1;
        let diags = verify_program(&p);
        assert!(diags.iter().any(|d| d.check == "shard-leak"), "{diags:?}");
    }

    #[test]
    fn private_private_cross_edge_is_named() {
        // Two genuinely private single-tile chains with a dependency
        // between them: seal unions them into ONE shard (correct). Tamper
        // the map to split them so the edge crosses two private shards.
        let mut p = Program::new();
        let r0 = p.resource();
        let r1 = p.resource();
        let a = p.op(r0, 1, 0, Component::RedMule, 0, 0, &[]);
        let _b = p.op(r1, 1, 0, Component::RedMule, 1, 0, &[a]);
        p.seal();
        assert!(verify_program(&p).is_empty());
        p.shard_of = vec![1, 2];
        p.shard_start = vec![0, 0, 1, 2];
        p.shard_ops = vec![0, 1];
        p.res_shard = vec![1, 2];
        let diags = verify_program(&p);
        assert!(diags.iter().any(|d| d.check == "shard-cross-edge"), "{diags:?}");
    }

    #[test]
    fn broken_fold_chain_is_named() {
        // Two ops on one private resource with no dependency between
        // them, on a program claiming folded work.
        let mut p = Program::new();
        let r = p.resource();
        let _a = p.op(r, 4, 0, Component::RedMule, 0, 0, &[]);
        let _b = p.op(r, 4, 0, Component::RedMule, 0, 0, &[]);
        p.fold.ops = 1;
        p.seal();
        let diags = verify_program(&p);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].check, "fold-chain");
        // The same shape without folding is legal (FIFO handles it).
        p.fold.ops = 0;
        assert!(verify_program(&p).is_empty());
    }

    #[test]
    fn fold_chain_accepts_transitive_paths() {
        // redmule -> spatz -> redmule: consecutive RedMulE ops are linked
        // through the Spatz op, not directly.
        let mut p = Program::new();
        let rm = p.resource();
        let sp = p.resource();
        let a = p.op(rm, 4, 0, Component::RedMule, 0, 0, &[]);
        let s = p.op(sp, 2, 0, Component::Spatz, 0, 0, &[a]);
        let _b = p.op(rm, 4, 0, Component::RedMule, 0, 0, &[s]);
        p.fold.ops = 1;
        p.seal();
        assert!(verify_program(&p).is_empty());
    }

    #[test]
    fn batch_span_and_band_overlap_are_named() {
        let mut p = Program::new();
        let r0 = p.resource();
        let r1 = p.resource();
        let _ = p.op(r0, 1, 0, Component::RedMule, 3, 0, &[]);
        let _ = p.op(r1, 1, 0, Component::RedMule, 3, 0, &[]);
        p.seal();
        let bp = BatchProgram { program: p, spans: vec![(0, 1), (1, 2)], tail_spans: vec![] };
        // Both entries' ops sit on tile 3: band overlap.
        let diags = verify_batch(&bp);
        assert!(diags.iter().any(|d| d.check == "batch-band-overlap"), "{diags:?}");
        // Overlapping spans are a distinct defect class.
        let bp =
            BatchProgram { program: bp.program, spans: vec![(0, 2), (1, 2)], tail_spans: vec![] };
        let diags = verify_batch(&bp);
        assert!(diags.iter().any(|d| d.check == "batch-span"), "{diags:?}");
    }

    #[test]
    fn batch_tail_defects_are_named() {
        let mut p = Program::new();
        let r0 = p.resource();
        let r1 = p.resource();
        let _ = p.op(r0, 1, 0, Component::RedMule, 0, 0, &[]);
        let _ = p.op(r1, 1, 0, Component::RedMule, 8, 0, &[]);
        p.seal();
        // Tail count must match the entry count.
        let bp = BatchProgram { program: p, spans: vec![(0, 1), (1, 2)], tail_spans: vec![(2, 2)] };
        let diags = verify_batch(&bp);
        assert!(diags.iter().any(|d| d.check == "batch-tail"), "{diags:?}");
        // A tail overlapping the attention spans is named too.
        let bp = BatchProgram {
            program: bp.program,
            spans: vec![(0, 1)],
            tail_spans: vec![(0, 2)],
        };
        let diags = verify_batch(&bp);
        assert!(diags.iter().any(|d| d.check == "batch-tail"), "{diags:?}");
    }

    /// A real layered compose (attention + per-entry GEMM tails across
    /// two bands) passes every batch rule, including the extended
    /// tail/band geometry.
    #[test]
    fn layered_batch_compose_verifies_clean() {
        use crate::arch::presets;
        use crate::dataflow::{Dataflow, WeightResidency, Workload};
        use crate::hbm::PageMap;
        use crate::scheduler::batch::{compose_layered, BatchEntry, LayerParams};

        let arch = presets::table2(8);
        let mut p0 = PageMap::new(32);
        p0.grow_to(256, |p| (8 + (p % 2)) as u32);
        let mut p1 = PageMap::new(32);
        p1.grow_to(300, |p| (12 + (p % 2)) as u32);
        let entries = [
            BatchEntry {
                request: 0,
                slot: 0,
                workload: Workload::new(128, 64, 4, 1).with_causal(true).with_kv_prefix(128),
                pages: &p0,
            },
            BatchEntry {
                request: 1,
                slot: 2,
                workload: Workload::new(300, 64, 4, 1).with_kv_heads(2).decode(),
                pages: &p1,
            },
        ];
        let lp = LayerParams { ffn_mult: 4, weights: WeightResidency::HbmStream };
        let bp = compose_layered(&arch, Dataflow::Flash2, 2, 4, &entries, lp);
        assert!(verify_batch(&bp).is_empty());
    }

    #[test]
    fn fault_plan_defects_are_named() {
        let plan = FaultPlan {
            outages: vec![crate::sim::fault::ChannelOutage { channel: 9, from: 10, until: 10 }],
            derates: vec![crate::sim::fault::ChannelDerate {
                channel: 0,
                from: 0,
                until: 100,
                num: 1,
                den: 2,
            }],
            noc: vec![],
            deaths: vec![
                crate::sim::fault::TileDeath { tile: 64, at: 5 },
                crate::sim::fault::TileDeath { tile: 3, at: 5 },
                crate::sim::fault::TileDeath { tile: 3, at: 9 },
            ],
        };
        let diags = verify_fault_plan(&plan, 8, 64);
        let checks: Vec<_> = diags.iter().map(|d| d.check).collect();
        for want in
            ["fault-window", "fault-ratio", "fault-channel", "fault-tile", "fault-duplicate-death"]
        {
            assert!(checks.contains(&want), "missing {want} in {diags:?}");
        }
        assert!(verify_fault_plan(&FaultPlan::none(), 8, 64).is_empty());
    }
}
