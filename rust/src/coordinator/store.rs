//! JSON persistence for experiment results.
//!
//! `flatattention report --out results.json` writes every figure's data in
//! machine-readable form so plots can be regenerated without re-simulating.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::experiment::ExperimentResult;

/// An accumulating result store, grouped into named sections (one per
/// figure/table).
#[derive(Debug, Default)]
pub struct ResultStore {
    sections: Vec<(String, Vec<Json>)>,
}

impl ResultStore {
    /// An empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add results under a section name (e.g. "fig3").
    pub fn add_results(&mut self, section: &str, results: &[ExperimentResult]) {
        self.add_json(section, results.iter().map(|r| r.to_json()).collect());
    }

    /// Add raw JSON rows under a section name.
    pub fn add_json(&mut self, section: &str, rows: Vec<Json>) {
        if let Some((_, existing)) = self.sections.iter_mut().find(|(s, _)| s == section) {
            existing.extend(rows);
        } else {
            self.sections.push((section.to_string(), rows));
        }
    }

    /// Serialize every section into one JSON object.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.sections
                .iter()
                .map(|(k, v)| (k.clone(), Json::Arr(v.clone())))
                .collect(),
        )
    }

    /// Write the store as pretty JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load a store back (sections of raw JSON rows).
    pub fn load(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("parse error: {e}"))?;
        let mut store = Self::new();
        if let Json::Obj(map) = json {
            for (k, v) in map {
                if let Json::Arr(rows) = v {
                    store.add_json(&k, rows);
                }
            }
        }
        Ok(store)
    }

    /// Rows of a named section, if present.
    pub fn section(&self, name: &str) -> Option<&[Json]> {
        self.sections
            .iter()
            .find(|(s, _)| s == name)
            .map(|(_, v)| v.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::table1;
    use crate::coordinator::{run_one, ExperimentSpec};
    use crate::dataflow::{Dataflow, Workload};

    #[test]
    fn round_trip_through_disk() {
        let spec = ExperimentSpec {
            arch: table1(),
            workload: Workload::new(512, 64, 2, 1),
            dataflow: Dataflow::FlatColl,
            group: 8,
        };
        let result = run_one(&spec);
        let mut store = ResultStore::new();
        store.add_results("fig3", &[result.clone()]);
        store.add_json("meta", vec![Json::obj([("version", Json::num(1))])]);

        let path = std::env::temp_dir().join(format!("fa-store-{}.json", std::process::id()));
        store.save(&path).unwrap();
        let loaded = ResultStore::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        let rows = loaded.section("fig3").unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(
            rows[0].get("makespan_cycles").unwrap().as_f64().unwrap() as u64,
            result.makespan
        );
        assert!(loaded.section("meta").is_some());
        assert!(loaded.section("nope").is_none());
    }

    #[test]
    fn sections_accumulate() {
        let mut store = ResultStore::new();
        store.add_json("a", vec![Json::num(1)]);
        store.add_json("a", vec![Json::num(2)]);
        assert_eq!(store.section("a").unwrap().len(), 2);
    }
}
