//! Experiment coordination: specs, parallel execution, sweeps, persistence.
//!
//! This is the L3 leader in deployment terms: it owns the experiment
//! queue, fans simulation runs out over a worker pool, searches the
//! FlatAttention group-size space (the paper's per-sequence-length optimum
//! of §V-B), and persists machine-readable results.
//!
//! §Perf: results are memoized by content fingerprint ([`SpecKey`]) so
//! `best_group` sweeps and the figure generators never simulate the same
//! point twice — the pool works off the deduplicated uncached set (see
//! [`runner`]). `run_{one,all}_uncached` bypass the cache for baselines
//! and equivalence tests.

pub mod experiment;
pub mod runner;
pub mod store;

pub use experiment::{ExperimentResult, ExperimentSpec};
pub use runner::{
    best_group, clear_memo, engine_threads, fault_plan, layer_key, memo_len, memo_stats, run_all,
    run_all_uncached, run_layer, run_one, run_one_uncached, set_engine_threads, set_fault_plan,
    spec_key, valid_groups, LayerKey, LayerResult, SpecKey,
};
pub use store::ResultStore;
