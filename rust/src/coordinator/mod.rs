//! Experiment coordination: specs, parallel execution, sweeps, persistence.
//!
//! This is the L3 leader in deployment terms: it owns the experiment
//! queue, fans simulation runs out over a worker pool, searches the
//! FlatAttention group-size space (the paper's per-sequence-length optimum
//! of §V-B), and persists machine-readable results.

pub mod experiment;
pub mod runner;
pub mod store;

pub use experiment::{ExperimentResult, ExperimentSpec};
pub use runner::{best_group, run_all, run_one, valid_groups};
pub use store::ResultStore;
