//! Parallel experiment execution, group-size search, and result
//! memoization.
//!
//! §Perf: the report generators (`crate::report::fig*`) and `best_group`
//! sweeps revisit many identical `(arch, workload, dataflow, group)`
//! points — e.g. every figure touches the D=128/S=4096 headline layer.
//! Experiments are deterministic, so results are memoized in a global
//! cache keyed by a *content* fingerprint of the spec ([`SpecKey`]: every
//! architecture/workload field, not the display id). `run_all` also
//! deduplicates within a batch, so the worker pool only simulates the
//! unique uncached points. Memoized and uncached runs are bit-identical —
//! asserted by tests here and in `tests/coordinator_integration.rs`.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::arch::ArchConfig;
use crate::dataflow::{self, Dataflow, LayerWorkload, WeightResidency, Workload};
use crate::util::pool;

use super::experiment::{ExperimentResult, ExperimentSpec};

/// Content fingerprint of an [`ExperimentSpec`]: two specs compare equal
/// iff every field influencing the simulation (and the derived metrics,
/// including `freq_ghz` and the id-forming `arch.name`) is identical.
/// The global symmetry-folding switch joins the key so a toggled process
/// never serves one mode's results for the other (they are bit-identical
/// by construction — `tests/fold_differential.rs` — but the cache must
/// not depend on that invariant for correctness).
/// The active fault plan's fingerprint joins for the same reason: a
/// faulted run's stats must never be served for the fault-free point (or
/// for a different plan) — see [`set_fault_plan`].
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SpecKey {
    arch_name: String,
    dataflow: Dataflow,
    group: usize,
    folding: bool,
    fault: u64,
    nums: [u64; 28],
}

/// Fingerprint a spec for memoization.
///
/// Every config struct is destructured *exhaustively* (no `..`), so adding
/// a field to `ArchConfig`/`TileConfig`/`NocConfig`/`HbmConfig`/`Workload`
/// is a compile error here until the new field joins the key — a silently
/// incomplete fingerprint would serve one architecture's results for
/// another.
pub fn spec_key(spec: &ExperimentSpec) -> SpecKey {
    use crate::arch::{HbmConfig, NocConfig, TileConfig};
    let ExperimentSpec { arch, workload, dataflow, group } = spec;
    let ArchConfig { name, mesh_x, mesh_y, tile, noc, hbm, freq_ghz } = arch;
    let TileConfig {
        redmule_rows,
        redmule_cols,
        redmule_fill,
        redmule_setup,
        spatz_fpus,
        spatz_lanes_per_fpu,
        spatz_exp_per_fpu,
        l1_kib,
        l1_bytes_per_cycle,
    } = tile;
    let NocConfig { link_bytes_per_cycle, router_latency, inject_latency, hw_collectives } = noc;
    let HbmConfig { channels_west, channels_south, channel_bytes_per_cycle, access_latency } = hbm;
    let Workload { seq, head_dim, heads, kv_heads, batch, causal, phase, kv_prefix, window } =
        workload;
    SpecKey {
        arch_name: name.clone(),
        dataflow: *dataflow,
        group: *group,
        folding: dataflow::symmetry_folding(),
        fault: fault_plan().map_or(0, |p| p.fingerprint()),
        nums: [
            *mesh_x as u64,
            *mesh_y as u64,
            *redmule_rows as u64,
            *redmule_cols as u64,
            *redmule_fill,
            *redmule_setup,
            *spatz_fpus as u64,
            *spatz_lanes_per_fpu as u64,
            *spatz_exp_per_fpu as u64,
            *l1_kib as u64,
            *l1_bytes_per_cycle,
            *link_bytes_per_cycle,
            *router_latency,
            *inject_latency,
            *hw_collectives as u64,
            *channels_west as u64,
            *channels_south as u64,
            *channel_bytes_per_cycle,
            *access_latency,
            freq_ghz.to_bits(),
            *seq,
            *head_dim,
            *heads,
            (*batch << 1) | *causal as u64,
            *kv_heads,
            matches!(phase, crate::dataflow::Phase::Decode) as u64,
            *kv_prefix,
            *window,
        ],
    }
}

/// DES workers used *inside* each experiment's event loop
/// (`sim::execute_parallel` over the program's §Shard partition). This is
/// orthogonal to the `threads` argument of [`run_all`], which fans out
/// *across* experiments; the two compose (e.g. a wide sweep keeps
/// engine threads at 1, a single big run raises them).
///
/// Deliberately NOT part of [`SpecKey`]: the sharded executor is
/// bit-identical to the serial engine at every thread count
/// (`tests/parallel_differential.rs`), so a result memoized under one
/// setting is exactly the result any other setting would compute —
/// changing the knob must never split or invalidate the cache.
static ENGINE_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Set the per-experiment DES worker count (clamped to ≥ 1).
pub fn set_engine_threads(n: usize) {
    ENGINE_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Current per-experiment DES worker count.
pub fn engine_threads() -> usize {
    ENGINE_THREADS.load(Ordering::Relaxed)
}

/// Process-global fault plan applied to every experiment run through the
/// coordinator (`dataflow::run_faulted` when set). Follows the
/// symmetry-folding pattern — a global switch rather than an
/// `ExperimentSpec` field (every figure constructs specs by struct
/// literal) — and, unlike [`set_engine_threads`], it DOES join
/// [`SpecKey`]: fault plans change results, so each plan partitions the
/// memo key space. Empty plans normalize to "no plan" (they are
/// bit-identical to fault-free runs and must share their cache entries).
static FAULT_PLAN: Mutex<Option<crate::sim::FaultPlan>> = Mutex::new(None);

/// Install (or clear, with `None`) the global fault plan.
pub fn set_fault_plan(plan: Option<crate::sim::FaultPlan>) {
    *FAULT_PLAN.lock().unwrap() = plan.filter(|p| !p.is_none());
}

/// The active global fault plan, if any.
pub fn fault_plan() -> Option<crate::sim::FaultPlan> {
    FAULT_PLAN.lock().unwrap().clone()
}

/// Global result cache. `Mutex<Option<..>>` because `HashMap::new` is not
/// const; initialized on first use.
static MEMO: Mutex<Option<HashMap<SpecKey, ExperimentResult>>> = Mutex::new(None);
static MEMO_HITS: AtomicUsize = AtomicUsize::new(0);
static MEMO_MISSES: AtomicUsize = AtomicUsize::new(0);

fn cache_get(key: &SpecKey) -> Option<ExperimentResult> {
    MEMO.lock()
        .unwrap()
        .as_ref()
        .and_then(|m| m.get(key).cloned())
}

fn cache_put(key: SpecKey, result: ExperimentResult) {
    MEMO.lock()
        .unwrap()
        .get_or_insert_with(HashMap::new)
        .insert(key, result);
}

/// True if the exact content point is already memoized.
pub fn memo_contains(spec: &ExperimentSpec) -> bool {
    cache_get(&spec_key(spec)).is_some()
}

/// Number of memoized experiment points.
pub fn memo_len() -> usize {
    MEMO.lock().unwrap().as_ref().map_or(0, |m| m.len())
}

/// `(hits, misses)` counters since process start.
pub fn memo_stats() -> (usize, usize) {
    (MEMO_HITS.load(Ordering::Relaxed), MEMO_MISSES.load(Ordering::Relaxed))
}

/// Drop every memoized result (tests / long-lived services).
pub fn clear_memo() {
    *MEMO.lock().unwrap() = None;
    *LAYER_MEMO.lock().unwrap() = None;
}

/// Content fingerprint of a composed-layer experiment: the attention
/// point's [`SpecKey`] (which already pins every architecture and
/// workload field plus the folding switch) joined by the layer knobs.
/// The global fault plan joins through the inner key even though
/// [`run_layer`] is always fault-free — a spurious partition costs a
/// recompute, never a wrong hit.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct LayerKey {
    attn: SpecKey,
    ffn_mult: u64,
    resident: bool,
}

/// Fingerprint a composed-layer point for memoization.
pub fn layer_key(arch: &ArchConfig, lw: &LayerWorkload, df: Dataflow, group: usize) -> LayerKey {
    let spec =
        ExperimentSpec { arch: arch.clone(), workload: lw.attn, dataflow: df, group };
    LayerKey {
        attn: spec_key(&spec),
        ffn_mult: lw.ffn_mult,
        resident: lw.weights == WeightResidency::Resident,
    }
}

/// Result of one composed-layer run ([`run_layer`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerResult {
    /// Makespan of the composed layer program (cycles).
    pub makespan: u64,
    /// Useful FLOPs of the whole layer.
    pub flops: u64,
    /// HBM bytes moved by the whole layer.
    pub hbm_bytes: u64,
    /// `(label, solo makespan)` per kernel, `"attention"` first then the
    /// GEMMs in rotation order. Cross-kernel barriers serialize kernels
    /// strictly, so these sum to exactly [`LayerResult::makespan`]
    /// (strict-barrier additivity, pinned by
    /// `tests/layer_differential.rs`) — the per-kernel share of the layer
    /// critical path.
    pub kernels: Vec<(String, u64)>,
}

/// Memo for [`run_layer`]; cleared together with the experiment memo.
static LAYER_MEMO: Mutex<Option<HashMap<LayerKey, LayerResult>>> = Mutex::new(None);

/// Execute one composed transformer layer (attention + the four
/// projection/FFN GEMMs, `dataflow::layer_program`) and its per-kernel
/// solo programs, memoized by [`LayerKey`]. Always fault-free.
pub fn run_layer(
    arch: &ArchConfig,
    lw: &LayerWorkload,
    df: Dataflow,
    group: usize,
) -> LayerResult {
    let key = layer_key(arch, lw, df, group);
    if let Some(hit) = LAYER_MEMO.lock().unwrap().as_ref().and_then(|m| m.get(&key).cloned()) {
        return hit;
    }
    let lp = dataflow::layer_program(arch, lw, df, group);
    let stats = crate::sim::execute(&lp.program, 0);
    let attn = dataflow::build_program(arch, &lw.attn, df, group);
    let mut kernels = vec![("attention".to_string(), crate::sim::execute(&attn, 0).makespan)];
    for g in lw.gemms() {
        let gp = dataflow::gemm_band_program(arch, &g, 0, arch.mesh_y, lw.weights);
        kernels.push((g.label.clone(), crate::sim::execute(&gp, 0).makespan));
    }
    let result = LayerResult {
        makespan: stats.makespan,
        flops: lp.program.flops,
        hbm_bytes: stats.hbm_bytes,
        kernels,
    };
    LAYER_MEMO
        .lock()
        .unwrap()
        .get_or_insert_with(HashMap::new)
        .insert(key, result.clone());
    result
}

/// Execute one experiment, bypassing the memo cache. The DES runs with
/// [`engine_threads`] workers (default 1 — sweeps parallelize across
/// experiments instead).
pub fn run_one_uncached(spec: &ExperimentSpec) -> ExperimentResult {
    let stats = match fault_plan() {
        Some(plan) => {
            // Faulted runs report the surviving schedule's stats; killed
            // and stalled ops simply never contribute (graceful DES exit).
            dataflow::run_faulted(
                &spec.arch,
                &spec.workload,
                spec.dataflow,
                spec.group,
                engine_threads(),
                &plan,
            )
            .0
        }
        None => dataflow::run_threads(
            &spec.arch,
            &spec.workload,
            spec.dataflow,
            spec.group,
            engine_threads(),
        ),
    };
    ExperimentResult::from_stats(spec, &stats)
}

/// Execute one experiment, served from the memo cache when possible.
pub fn run_one(spec: &ExperimentSpec) -> ExperimentResult {
    let key = spec_key(spec);
    if let Some(hit) = cache_get(&key) {
        MEMO_HITS.fetch_add(1, Ordering::Relaxed);
        return hit;
    }
    MEMO_MISSES.fetch_add(1, Ordering::Relaxed);
    let result = run_one_uncached(spec);
    cache_put(key, result.clone());
    result
}

/// Execute all experiments across the worker pool, bypassing the cache.
pub fn run_all_uncached(specs: &[ExperimentSpec], threads: usize) -> Vec<ExperimentResult> {
    pool::par_map(specs, threads, run_one_uncached)
}

/// Execute all experiments, preserving order. Duplicate content points —
/// within the batch or already memoized from earlier batches — simulate
/// exactly once; the worker pool fans out over the unique uncached set.
pub fn run_all(specs: &[ExperimentSpec], threads: usize) -> Vec<ExperimentResult> {
    let keys: Vec<SpecKey> = specs.iter().map(spec_key).collect();

    // First occurrence of each uncached key.
    let mut to_run: Vec<usize> = Vec::new();
    {
        let mut seen: HashSet<&SpecKey> = HashSet::new();
        for (i, key) in keys.iter().enumerate() {
            if seen.insert(key) && cache_get(key).is_none() {
                to_run.push(i);
            }
        }
    }
    MEMO_MISSES.fetch_add(to_run.len(), Ordering::Relaxed);
    MEMO_HITS.fetch_add(specs.len() - to_run.len(), Ordering::Relaxed);

    let unique_specs: Vec<&ExperimentSpec> = to_run.iter().map(|&i| &specs[i]).collect();
    let fresh = pool::par_map(&unique_specs, threads, |s| run_one_uncached(s));

    let mut local: HashMap<&SpecKey, &ExperimentResult> = HashMap::new();
    for (&i, result) in to_run.iter().zip(&fresh) {
        cache_put(keys[i].clone(), result.clone());
        local.insert(&keys[i], result);
    }

    keys.iter()
        .zip(specs)
        .map(|(key, spec)| match local.get(key) {
            Some(r) => (*r).clone(),
            // Normally served by the cache; recompute if `clear_memo` ran
            // concurrently between the dedup scan and this collect.
            None => match cache_get(key) {
                Some(r) => r,
                None => run_one(spec),
            },
        })
        .collect()
}

/// Square group sizes valid on an architecture (divide both mesh axes,
/// from 2 up to the full mesh edge).
pub fn valid_groups(arch: &ArchConfig) -> Vec<usize> {
    let max = arch.mesh_x.min(arch.mesh_y);
    [2usize, 4, 8, 16, 32, 64]
        .into_iter()
        .filter(|&g| g <= max && arch.mesh_x % g == 0 && arch.mesh_y % g == 0)
        .collect()
}

/// Find the best (lowest-makespan) group size for a FlatAttention dataflow
/// on a workload — the §V-B per-sequence-length optimum. Returns the
/// winning result.
pub fn best_group(
    arch: &ArchConfig,
    wl: &Workload,
    df: Dataflow,
    threads: usize,
) -> ExperimentResult {
    assert!(df.is_flat(), "best_group only applies to FlatAttention variants");
    let specs: Vec<ExperimentSpec> = valid_groups(arch)
        .into_iter()
        .map(|group| ExperimentSpec {
            arch: arch.clone(),
            workload: *wl,
            dataflow: df,
            group,
        })
        .collect();
    run_all(&specs, threads)
        .into_iter()
        .min_by_key(|r| r.makespan)
        .expect("at least one valid group")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{table1, table2};

    #[test]
    fn valid_groups_table1() {
        assert_eq!(valid_groups(&table1()), vec![2, 4, 8, 16, 32]);
        assert_eq!(valid_groups(&table2(8)), vec![2, 4, 8]);
    }

    #[test]
    fn run_all_preserves_order_and_ids() {
        let arch = table1();
        let wl = Workload::new(512, 64, 4, 1);
        let specs: Vec<ExperimentSpec> = [Dataflow::Flash2, Dataflow::FlatColl]
            .into_iter()
            .map(|df| ExperimentSpec { arch: arch.clone(), workload: wl, dataflow: df, group: 8 })
            .collect();
        let results = run_all(&specs, 2);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].dataflow, Dataflow::Flash2);
        assert_eq!(results[1].dataflow, Dataflow::FlatColl);
        assert!(results.iter().all(|r| r.makespan > 0));
    }

    #[test]
    fn spec_key_separates_content_not_just_ids() {
        let base = ExperimentSpec {
            arch: table1(),
            workload: Workload::new(1024, 128, 8, 1),
            dataflow: Dataflow::FlatColl,
            group: 8,
        };
        assert_eq!(spec_key(&base), spec_key(&base.clone()));

        // Same display id, different content (id only carries arch.name).
        let mut tweaked = base.clone();
        tweaked.arch.hbm.access_latency += 1;
        assert_eq!(base.id(), tweaked.id());
        assert_ne!(spec_key(&base), spec_key(&tweaked));

        let mut causal = base.clone();
        causal.workload.causal = true;
        assert_ne!(spec_key(&base), spec_key(&causal));

        // Serving fields must partition the key space too — a GQA or
        // decode run must never be served an MHA prefill result.
        let gqa = ExperimentSpec {
            workload: base.workload.with_kv_heads(2),
            ..base.clone()
        };
        assert_ne!(spec_key(&base), spec_key(&gqa));
        let dec = ExperimentSpec {
            workload: base.workload.decode(),
            ..base.clone()
        };
        assert_ne!(spec_key(&base), spec_key(&dec));
        assert_ne!(spec_key(&gqa), spec_key(&dec));

        // Batch-spec fields (chunked-prefill prefix, sliding window) must
        // partition the key space too: a scheduler chunk or a windowed
        // layer must never be served a dense single-shot result.
        let chunk = ExperimentSpec {
            workload: base.workload.with_kv_prefix(512),
            ..base.clone()
        };
        assert_ne!(spec_key(&base), spec_key(&chunk));
        let windowed = ExperimentSpec {
            workload: base.workload.with_causal(true).with_window(256),
            ..base.clone()
        };
        assert_ne!(spec_key(&causal), spec_key(&windowed));
        assert_ne!(spec_key(&chunk), spec_key(&windowed));
    }

    #[test]
    fn memoized_results_are_bit_identical_and_computed_once() {
        // Use a workload unique to this test so other concurrently-running
        // tests cannot pre-populate these keys.
        let arch = table2(8);
        let wl = Workload::new(640, 64, 3, 1);
        let mk = |dataflow, group| ExperimentSpec {
            arch: arch.clone(),
            workload: wl,
            dataflow,
            group,
        };
        let specs = vec![
            mk(Dataflow::FlatColl, 4),
            mk(Dataflow::Flash2, 1),
            mk(Dataflow::FlatColl, 4), // duplicate of [0]
        ];
        assert!(!memo_contains(&specs[0]));

        let uncached = run_all_uncached(&specs, 2);
        let memoized = run_all(&specs, 2);
        assert_eq!(uncached, memoized);
        assert_eq!(memoized[0], memoized[2]);
        assert!(memo_contains(&specs[0]) && memo_contains(&specs[1]));

        // A second pass is served from the cache and stays identical.
        let again = run_all(&specs, 2);
        assert_eq!(memoized, again);
        assert_eq!(run_one(&specs[1]), memoized[1]);
    }

    #[test]
    fn engine_threads_do_not_touch_spec_keys_and_results_interchange() {
        // The sharded executor is bit-identical to the serial engine, so
        // the engine-thread knob must neither join the memo key nor
        // change any computed result: a result cached at one thread count
        // is served verbatim at another.
        let spec = ExperimentSpec {
            arch: table2(8),
            workload: Workload::new(704, 64, 4, 1).with_causal(true),
            dataflow: Dataflow::Flash2,
            group: 1,
        };
        let prev = engine_threads();
        set_engine_threads(1);
        let k1 = spec_key(&spec);
        let serial = run_one_uncached(&spec);
        set_engine_threads(4);
        let k4 = spec_key(&spec);
        let parallel = run_one_uncached(&spec);
        set_engine_threads(prev);
        assert_eq!(k1, k4, "engine threads must not partition the memo key space");
        assert_eq!(serial, parallel, "parallel DES must be bit-identical to serial");
    }

    #[test]
    fn spec_key_tracks_folding_switch() {
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let spec = ExperimentSpec {
            arch: table1(),
            workload: Workload::new(1024, 128, 8, 1),
            dataflow: Dataflow::FlatColl,
            group: 8,
        };
        crate::dataflow::set_symmetry_folding(false);
        let k_off = spec_key(&spec);
        crate::dataflow::set_symmetry_folding(true);
        let k_on = spec_key(&spec);
        assert_ne!(k_off, k_on, "folding mode must partition the memo key space");
    }

    #[test]
    fn spec_key_tracks_fault_plan() {
        use crate::sim::FaultPlan;
        // Serialized with the other global-switch tests: set_fault_plan is
        // process-global state just like the folding toggle.
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let spec = ExperimentSpec {
            arch: table1(),
            workload: Workload::new(512, 128, 8, 1),
            dataflow: Dataflow::Flash2,
            group: 8,
        };
        set_fault_plan(None);
        let k_free = spec_key(&spec);
        let free = run_one_uncached(&spec);
        // An empty plan normalizes away: same key, bit-identical result.
        set_fault_plan(Some(FaultPlan::none()));
        assert_eq!(spec_key(&spec), k_free);
        assert_eq!(run_one_uncached(&spec), free);
        // A real plan partitions the key space and derates the makespan.
        let mut plan = FaultPlan::none();
        for c in 0..spec.arch.hbm.total_channels() as u32 {
            plan = plan.with_derate(c, 0, u64::MAX / 2, 4, 1);
        }
        set_fault_plan(Some(plan));
        let k_fault = spec_key(&spec);
        let faulted = run_one_uncached(&spec);
        set_fault_plan(None);
        assert_ne!(k_fault, k_free, "fault plan must partition the memo key space");
        assert!(
            faulted.makespan > free.makespan,
            "derating channel 0 must slow the run: {} vs {}",
            faulted.makespan,
            free.makespan
        );
    }

    #[test]
    fn layer_runs_are_memoized_and_strictly_additive() {
        let arch = table2(8);
        let lw = LayerWorkload::new(
            Workload::new(256, 64, 4, 1).with_kv_heads(2).with_causal(true),
            2,
            WeightResidency::HbmStream,
        );
        let a = run_layer(&arch, &lw, Dataflow::FlatColl, 2);
        let b = run_layer(&arch, &lw, Dataflow::FlatColl, 2);
        assert_eq!(a, b, "memoized layer result must be bit-identical");
        assert_eq!(a.kernels.len(), 5);
        assert_eq!(a.kernels[0].0, "attention");
        let sum: u64 = a.kernels.iter().map(|k| k.1).sum();
        assert_eq!(a.makespan, sum, "strict-barrier additivity of kernel makespans");
        // The layer knobs partition the key space.
        let resident = LayerWorkload { weights: WeightResidency::Resident, ..lw };
        assert_ne!(
            layer_key(&arch, &lw, Dataflow::FlatColl, 2),
            layer_key(&arch, &resident, Dataflow::FlatColl, 2)
        );
    }

    #[test]
    fn best_group_short_seq_prefers_small_groups() {
        // §V-B over-flattening: at S=512 the optimum must not be the full
        // 32×32 mesh.
        let arch = table1();
        let wl = Workload::new(512, 128, 32, 4);
        let best = best_group(&arch, &wl, Dataflow::FlatAsyn, pool::default_threads());
        assert!(best.group < 32, "best group {} at S=512", best.group);
    }

    #[test]
    fn best_group_long_seq_prefers_large_groups() {
        let arch = table1();
        let wl = Workload::new(4096, 128, 32, 2);
        let best = best_group(&arch, &wl, Dataflow::FlatAsyn, pool::default_threads());
        assert!(best.group >= 16, "best group {} at S=4096", best.group);
    }
}
