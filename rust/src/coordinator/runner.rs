//! Parallel experiment execution and group-size search.

use crate::arch::ArchConfig;
use crate::dataflow::{self, Dataflow, Workload};
use crate::util::pool;

use super::experiment::{ExperimentResult, ExperimentSpec};

/// Execute one experiment.
pub fn run_one(spec: &ExperimentSpec) -> ExperimentResult {
    let stats = dataflow::run(&spec.arch, &spec.workload, spec.dataflow, spec.group);
    ExperimentResult::from_stats(spec, &stats)
}

/// Execute all experiments across the worker pool, preserving order.
pub fn run_all(specs: &[ExperimentSpec], threads: usize) -> Vec<ExperimentResult> {
    pool::par_map(specs, threads, run_one)
}

/// Square group sizes valid on an architecture (divide both mesh axes,
/// from 2 up to the full mesh edge).
pub fn valid_groups(arch: &ArchConfig) -> Vec<usize> {
    let max = arch.mesh_x.min(arch.mesh_y);
    [2usize, 4, 8, 16, 32, 64]
        .into_iter()
        .filter(|&g| g <= max && arch.mesh_x % g == 0 && arch.mesh_y % g == 0)
        .collect()
}

/// Find the best (lowest-makespan) group size for a FlatAttention dataflow
/// on a workload — the §V-B per-sequence-length optimum. Returns the
/// winning result.
pub fn best_group(
    arch: &ArchConfig,
    wl: &Workload,
    df: Dataflow,
    threads: usize,
) -> ExperimentResult {
    assert!(df.is_flat(), "best_group only applies to FlatAttention variants");
    let specs: Vec<ExperimentSpec> = valid_groups(arch)
        .into_iter()
        .map(|group| ExperimentSpec {
            arch: arch.clone(),
            workload: *wl,
            dataflow: df,
            group,
        })
        .collect();
    run_all(&specs, threads)
        .into_iter()
        .min_by_key(|r| r.makespan)
        .expect("at least one valid group")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{table1, table2};

    #[test]
    fn valid_groups_table1() {
        assert_eq!(valid_groups(&table1()), vec![2, 4, 8, 16, 32]);
        assert_eq!(valid_groups(&table2(8)), vec![2, 4, 8]);
    }

    #[test]
    fn run_all_preserves_order_and_ids() {
        let arch = table1();
        let wl = Workload::new(512, 64, 4, 1);
        let specs: Vec<ExperimentSpec> = [Dataflow::Flash2, Dataflow::FlatColl]
            .into_iter()
            .map(|df| ExperimentSpec { arch: arch.clone(), workload: wl, dataflow: df, group: 8 })
            .collect();
        let results = run_all(&specs, 2);
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].dataflow, Dataflow::Flash2);
        assert_eq!(results[1].dataflow, Dataflow::FlatColl);
        assert!(results.iter().all(|r| r.makespan > 0));
    }

    #[test]
    fn best_group_short_seq_prefers_small_groups() {
        // §V-B over-flattening: at S=512 the optimum must not be the full
        // 32×32 mesh.
        let arch = table1();
        let wl = Workload::new(512, 128, 32, 4);
        let best = best_group(&arch, &wl, Dataflow::FlatAsyn, pool::default_threads());
        assert!(best.group < 32, "best group {} at S=512", best.group);
    }

    #[test]
    fn best_group_long_seq_prefers_large_groups() {
        let arch = table1();
        let wl = Workload::new(4096, 128, 32, 2);
        let best = best_group(&arch, &wl, Dataflow::FlatAsyn, pool::default_threads());
        assert!(best.group >= 16, "best group {} at S=4096", best.group);
    }
}
