//! Experiment specification and result records.

use crate::arch::ArchConfig;
use crate::dataflow::{Dataflow, Workload};
use crate::sim::{Breakdown, RunStats};
use crate::util::json::Json;

/// One simulation to run: a workload × architecture × dataflow (+ group).
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Architecture instance to simulate.
    pub arch: ArchConfig,
    /// Attention workload shape.
    pub workload: Workload,
    /// Dataflow mapping to evaluate.
    pub dataflow: Dataflow,
    /// FlatAttention group edge (ignored for FlashAttention variants).
    pub group: usize,
}

impl ExperimentSpec {
    /// Stable key naming this spec (memoization and result-row joins).
    pub fn id(&self) -> String {
        if self.dataflow.is_flat() {
            format!(
                "{}/{}/{}-g{}",
                self.arch.name,
                self.workload.label(),
                self.dataflow.label(),
                self.group
            )
        } else {
            format!("{}/{}/{}", self.arch.name, self.workload.label(), self.dataflow.label())
        }
    }
}

/// Result of one experiment with derived metrics. `PartialEq` is bitwise
/// (floats included): experiments are deterministic, and the memoization
/// tests assert cached results are bit-identical to recomputed ones.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentResult {
    /// The spec's [`ExperimentSpec::id`].
    pub id: String,
    /// Dataflow that ran.
    pub dataflow: Dataflow,
    /// Workload that ran.
    pub workload: Workload,
    /// FlatAttention group edge used (1 for FlashAttention variants).
    pub group: usize,
    /// End-to-end modeled cycles.
    pub makespan: u64,
    /// Host wall-clock spent simulating (not modeled time).
    pub runtime_ms: f64,
    /// Per-component busy time on the tracked tile.
    pub breakdown: Breakdown,
    /// Total HBM traffic of the run.
    pub hbm_bytes: u64,
    /// System compute utilization (matrix FLOPs vs whole-chip peak).
    pub utilization: f64,
    /// RedMulE utilization *when active* (Fig. 4 labels).
    pub redmule_active_util: f64,
    /// Average HBM bandwidth utilization.
    pub hbm_bw_util: f64,
    /// Achieved TFLOPS at the architecture clock.
    pub tflops: f64,
    /// DES ops executed (folded runs execute fewer).
    pub ops_executed: usize,
}

impl ExperimentResult {
    /// Derive the result row from a finished run's stats.
    pub fn from_stats(spec: &ExperimentSpec, stats: &RunStats) -> Self {
        let arch = &spec.arch;
        let util = stats.compute_utilization(arch.peak_flops_per_cycle());
        Self {
            id: spec.id(),
            dataflow: spec.dataflow,
            workload: spec.workload,
            group: spec.group,
            makespan: stats.makespan,
            runtime_ms: stats.runtime_ms(arch.freq_ghz),
            breakdown: stats.breakdown.clone(),
            hbm_bytes: stats.hbm_bytes,
            utilization: util,
            redmule_active_util: stats
                .redmule_active_utilization(arch.tile.redmule_flops_per_cycle()),
            hbm_bw_util: stats.hbm_bw_utilization(arch.hbm.peak_bytes_per_cycle()),
            tflops: util * arch.peak_tflops(),
            ops_executed: stats.ops_executed,
        }
    }

    /// Serialize for the [`crate::coordinator::ResultStore`].
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("id", Json::str(self.id.clone())),
            ("dataflow", Json::str(self.dataflow.label())),
            ("seq", Json::num(self.workload.seq as f64)),
            ("head_dim", Json::num(self.workload.head_dim as f64)),
            ("heads", Json::num(self.workload.heads as f64)),
            ("kv_heads", Json::num(self.workload.kv_heads as f64)),
            ("phase", Json::str(self.workload.phase.label())),
            ("kv_prefix", Json::num(self.workload.kv_prefix as f64)),
            ("window", Json::num(self.workload.window as f64)),
            ("batch", Json::num(self.workload.batch as f64)),
            ("group", Json::num(self.group as f64)),
            ("makespan_cycles", Json::num(self.makespan as f64)),
            ("runtime_ms", Json::num(self.runtime_ms)),
            ("breakdown", self.breakdown.to_json()),
            ("hbm_bytes", Json::num(self.hbm_bytes as f64)),
            ("utilization", Json::num(self.utilization)),
            ("redmule_active_util", Json::num(self.redmule_active_util)),
            ("hbm_bw_util", Json::num(self.hbm_bw_util)),
            ("tflops", Json::num(self.tflops)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::table1;

    #[test]
    fn spec_ids_distinguish_groups() {
        let base = ExperimentSpec {
            arch: table1(),
            workload: Workload::new(1024, 128, 8, 1),
            dataflow: Dataflow::FlatColl,
            group: 8,
        };
        let mut other = base.clone();
        other.group = 16;
        assert_ne!(base.id(), other.id());

        let mut flash = base.clone();
        flash.dataflow = Dataflow::Flash2;
        assert!(!flash.id().contains("-g"));
    }
}
