//! §Incremental step composition and memoized delta re-simulation.
//!
//! [`StepComposer`] removes the per-step rebuild taxes of trace replay
//! while staying **bit-identical** to the full-rebuild path (pinned by
//! `tests/incremental_differential.rs`):
//!
//! 1. **Incremental compose** — keep the previous step's *sealed*
//!    [`BatchProgram`] alive; when an op-for-op structural compare
//!    against a freshly-emitted scratch program shows the topology is
//!    unchanged (the steady-decode common case), cost-patch the cached
//!    program in place (`Program::patch_costs_from`), reusing its sealed
//!    dependents and §Shard CSRs verbatim. Correctness never depends on
//!    predicting stability — it is checked op for op.
//! 2. **Memoized delta re-simulation** — when the entries' analytic
//!    channel masks are pairwise disjoint, skip batch execution and
//!    merge memoized per-request *solo* runs, exact by the conservation
//!    property. Disabled for any step with a live fault window.
//!
//! Both levers are config knobs ([`SchedulerConfig::incremental`] /
//! [`SchedulerConfig::memoize`], default on); faulted steps always run
//! the real batch. The full design essay lives in `docs/ARCHITECTURE.md`
//! §"Incremental composition and memoized delta re-simulation".

use std::collections::HashMap;
use std::time::Instant;

use super::batch::{self, BatchEntry, BatchProgram};
use super::SchedulerConfig;
use crate::arch::ArchConfig;
use crate::dataflow::Workload;
use crate::hbm::HbmMap;
use crate::sim::breakdown::Component;
use crate::sim::program::Program;
use crate::sim::{Breakdown, FaultPlan, ProgramArena, RunStats};
use crate::telemetry::{profile, FaultNote, ProfPhase, Profiler, StepMode, StepProbe};

/// Memo key of one entry's solo run: the slot pins the tile band (hence
/// hop distances and the fold representative), the workload pins the op
/// graph and costs, and the page-table prefix pins every K/V transfer's
/// channel. The key stores the actual channel prefix, not a hash of it,
/// so a collision can never alias two different placements.
#[derive(PartialEq, Eq, Hash)]
struct SoloKey {
    slot: usize,
    workload: Workload,
    page_tokens: u64,
    chans: Box<[u32]>,
}

/// Solo-memo capacity: on overflow the map is cleared outright — crude
/// but deterministic (eviction order can never shape results because
/// cached and recomputed solo stats are identical by construction).
const SOLO_CACHE_CAP: usize = 1 << 14;

/// Memoized result of one solo run. Besides the [`RunStats`] the scheduler
/// consumes, it carries the per-channel / NoC-collective occupancy sums the
/// telemetry probe needs: on a memo hit no program exists to scan, and the
/// conservation property (an entry's op costs are bit-identical solo vs in
/// a batch) makes these sums additive, so merging them reproduces the batch
/// scan exactly. Busy fields stay empty while the probe is disabled.
struct SoloRun {
    stats: RunStats,
    /// Sparse `(channel, busy_cycles)` pairs of the entry's HBM traffic.
    chan_busy: Box<[(u32, u64)]>,
    /// Total NoC-collective (SumReduce/MaxReduce/Multicast) busy cycles.
    noc_busy: u64,
}

/// Per-run step composer: owns the persistent sealed step program, the
/// solo-run memo and the recycled build buffers. Construct one per
/// `simulate`/`route` call — cached state is specific to one
/// `(arch, cfg)` pair and must not leak across runs. Public so the bench
/// harness can price the compose paths in isolation; scheduler callers
/// go through [`super::simulate`] / [`super::router::route`].
pub struct StepComposer {
    incremental: bool,
    memoize: bool,
    /// Buffers cycling between the scratch emission and the retired
    /// cached program (promote/patch keeps exactly one set in flight).
    arena: ProgramArena,
    /// Separate buffers for solo composes on memo misses.
    solo_arena: ProgramArena,
    cached: Option<BatchProgram>,
    solo: HashMap<SoloKey, SoloRun>,
    /// Union + per-entry scratch for the channel-mask disjointness gate.
    mask_union: Vec<u64>,
    mask_entry: Vec<u64>,
    patched: usize,
    resealed: usize,
    memo_steps: usize,
    memo_hits: usize,
    memo_misses: usize,
    /// Telemetry probe, enabled by [`Self::enable_probe`]; when `None`
    /// (the default) no per-step attribution work happens at all.
    probe: Option<StepProbe>,
    /// Wall-clock phase timers, enabled by [`Self::enable_profiling`].
    profiler: Option<Profiler>,
}

impl StepComposer {
    /// A composer for the given scheduler configuration.
    pub fn new(cfg: &SchedulerConfig) -> Self {
        Self {
            incremental: cfg.incremental,
            memoize: cfg.memoize,
            arena: ProgramArena::new(),
            solo_arena: ProgramArena::new(),
            cached: None,
            solo: HashMap::new(),
            mask_union: Vec::new(),
            mask_entry: Vec::new(),
            patched: 0,
            resealed: 0,
            memo_steps: 0,
            memo_hits: 0,
            memo_misses: 0,
            probe: None,
            profiler: None,
        }
    }

    /// Attach the telemetry probe: every subsequent step fills per-channel
    /// and per-slot busy attribution into [`Self::probe`]. Clears the solo
    /// memo so cached entries (stored without busy data) are recomputed.
    pub fn enable_probe(&mut self, n_chan: usize, slots: usize) {
        self.probe = Some(StepProbe::new(n_chan, slots));
        self.solo.clear();
    }

    /// The last executed step's probe, if [`Self::enable_probe`] was called.
    pub fn probe(&self) -> Option<&StepProbe> {
        self.probe.as_ref()
    }

    /// Attach wall-clock phase timers (also arms the global profiling gate
    /// so `Program::seal` reports verify time).
    pub fn enable_profiling(&mut self) {
        profile::set_profiling(true);
        self.profiler = Some(Profiler::new());
    }

    /// The accumulated phase timings, if [`Self::enable_profiling`] was
    /// called.
    pub fn profiler(&self) -> Option<&Profiler> {
        self.profiler.as_ref()
    }

    /// Steps whose program was cost-patched in place (seal skipped).
    pub fn patched_steps(&self) -> usize {
        self.patched
    }

    /// Steps that rebuilt + resealed (structure changed, or first step).
    pub fn resealed_steps(&self) -> usize {
        self.resealed
    }

    /// Steps served entirely from the solo-merge path (no batch DES run).
    pub fn memo_steps(&self) -> usize {
        self.memo_steps
    }

    /// Solo-run memo hits across all memoized steps.
    pub fn memo_hits(&self) -> usize {
        self.memo_hits
    }

    /// Solo-run memo misses (fresh solo compose + execute) across all
    /// memoized steps.
    pub fn memo_misses(&self) -> usize {
        self.memo_misses
    }

    /// Start a wall-clock lap if profiling is on.
    fn t0(&self) -> Option<Instant> {
        self.profiler.as_ref().map(|_| Instant::now())
    }

    /// Close a lap into `phase`.
    fn lap(&mut self, phase: ProfPhase, t: Option<Instant>) {
        if let (Some(p), Some(t)) = (self.profiler.as_mut(), t) {
            p.add_nanos(phase, t.elapsed().as_nanos() as u64);
        }
    }

    /// Close a seal lap, splitting out the verify time `Program::seal`
    /// reported through the thread-local accumulator.
    fn lap_seal(&mut self, t: Option<Instant>) {
        if let (Some(p), Some(t)) = (self.profiler.as_mut(), t) {
            let total = t.elapsed().as_nanos() as u64;
            let verify = profile::take_verify_nanos();
            p.add_nanos(ProfPhase::Verify, verify);
            p.add_nanos(ProfPhase::Seal, total.saturating_sub(verify));
        }
    }

    /// Compose (incrementally) and execute one fault-free step, serving
    /// it from the solo memo when the disjointness gate allows.
    pub fn run_step(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        entries: &[BatchEntry<'_>],
    ) -> RunStats {
        if let Some(p) = self.probe.as_mut() {
            p.reset();
            p.mode = StepMode::Memoized;
        }
        if self.memoize {
            if let Some(stats) = self.try_memoized(arch, cfg, entries) {
                self.memo_steps += 1;
                return stats;
            }
        }
        let threads = cfg.threads;
        self.with_composed(arch, cfg, entries, |bp| bp.run_threads(threads))
    }

    /// Compose (incrementally) and execute one step under a shifted fault
    /// plan; returns the entries that made no progress. The solo memo
    /// never applies here: faults couple timelines across entries.
    pub fn run_step_faulted(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        entries: &[BatchEntry<'_>],
        plan: &FaultPlan,
    ) -> (RunStats, Vec<usize>) {
        let threads = cfg.threads;
        let want_note = self.probe.is_some();
        let (stats, affected, note) = self.with_composed(arch, cfg, entries, |bp| {
            let (stats, fr) = bp.run_faulted(threads, plan);
            let affected = bp.affected_entries(&fr);
            // Route the DES stall diagnostics (previously stderr-only via
            // the fault-free panic path) into the telemetry event stream.
            let note = (want_note && !(fr.killed.is_empty() && fr.stalled.is_empty())).then(|| {
                let detail = if fr.stalled.is_empty() {
                    format!("{} op(s) killed by tile death", fr.killed.len())
                } else {
                    crate::sim::engine::stall_diagnostics(&bp.program, &fr)
                };
                FaultNote {
                    killed: fr.killed.len() as u32,
                    stalled: fr.stalled.len() as u32,
                    detail,
                }
            });
            (stats, affected, note)
        });
        if let Some(p) = self.probe.as_mut() {
            p.fault = note;
        }
        (stats, affected)
    }

    /// Compose and execute one *layered* step: every entry's attention
    /// kernel plus its four projection/FFN GEMMs appended on the entry's
    /// own tile-row band (`batch::compose_layered_in`).
    ///
    /// The layered path always rebuilds and reseals. Neither shortcut
    /// pays for its bookkeeping here: the GEMM tails re-shape with every
    /// prefill chunk (cost-patching would almost never apply), and the
    /// cross-kernel barriers make an entry's tail timeline a function of
    /// its own attention sinks, which the solo memo could honour but only
    /// by doubling its key space. Correctness is pinned directly instead:
    /// `tests/layer_differential.rs` asserts the composed layer
    /// reproduces the solo attention + solo GEMM timelines bit for bit.
    pub fn run_step_layered(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        entries: &[BatchEntry<'_>],
        lp: batch::LayerParams,
    ) -> RunStats {
        // Drop any cached attention-only step program; its structure can
        // never match a layered step's.
        if let Some(p) = self.cached.take() {
            self.arena.recycle(p.program);
        }
        let (df, group, slots) = (cfg.dataflow, cfg.group, cfg.slots);
        let t = self.t0();
        let bp = batch::compose_layered_in(&mut self.arena, arch, df, group, slots, entries, lp);
        // `compose_layered_in` seals internally, so one wall-clock lap
        // covers compose + seal; the verify share is split back out via
        // the same thread-local channel `lap_seal` drains.
        if let (Some(p), Some(t)) = (self.profiler.as_mut(), t) {
            let total = t.elapsed().as_nanos() as u64;
            let verify = profile::take_verify_nanos();
            p.add_nanos(ProfPhase::Verify, verify);
            p.add_nanos(ProfPhase::Compose, total.saturating_sub(verify));
        }
        self.resealed += 1;
        if let Some(probe) = self.probe.as_mut() {
            fill_probe(probe, &bp.program, &bp.spans, &bp.tail_spans, entries, StepMode::Rebuilt);
        }
        let t = self.t0();
        let out = bp.run_threads(cfg.threads);
        self.lap(ProfPhase::Execute, t);
        self.arena.recycle(bp.program);
        out
    }

    /// Produce this step's sealed [`BatchProgram`] — cost-patching the
    /// cached one, promoting the scratch emission, or (with
    /// `incremental` off) plain full rebuild — and hand it to `f`.
    fn with_composed<R>(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        entries: &[BatchEntry<'_>],
        f: impl FnOnce(&BatchProgram) -> R,
    ) -> R {
        let (df, group, slots) = (cfg.dataflow, cfg.group, cfg.slots);
        if !self.incremental {
            let t = self.t0();
            let mut bp =
                batch::compose_unsealed_in(&mut self.arena, arch, df, group, slots, entries);
            self.lap(ProfPhase::Compose, t);
            let t = self.t0();
            bp.program.seal();
            self.lap_seal(t);
            if let Some(probe) = self.probe.as_mut() {
                fill_probe(
                    probe,
                    &bp.program,
                    &bp.spans,
                    &bp.tail_spans,
                    entries,
                    StepMode::Rebuilt,
                );
            }
            let t = self.t0();
            let out = f(&bp);
            self.lap(ProfPhase::Execute, t);
            self.arena.recycle(bp.program);
            return out;
        }
        let t = self.t0();
        let scratch = batch::compose_unsealed_in(&mut self.arena, arch, df, group, slots, entries);
        self.lap(ProfPhase::Compose, t);
        // `patch_costs_from` verifies structure before touching costs, so
        // a `false` here leaves the cached program intact — and the
        // failure path below discards it whole anyway.
        let t = self.t0();
        let patched = match self.cached.as_mut() {
            Some(prev) if prev.spans == scratch.spans => {
                prev.program.patch_costs_from(&scratch.program)
            }
            _ => false,
        };
        self.lap(ProfPhase::Patch, t);
        if patched {
            self.patched += 1;
            self.arena.recycle(scratch.program);
        } else {
            if let Some(p) = self.cached.take() {
                self.arena.recycle(p.program);
            }
            self.resealed += 1;
            let mut bp = scratch;
            let t = self.t0();
            bp.program.seal();
            self.lap_seal(t);
            self.cached = Some(bp);
        }
        if let Some(probe) = self.probe.as_mut() {
            let bp = self.cached.as_ref().expect("step program just installed");
            let mode = if patched { StepMode::Patched } else { StepMode::Rebuilt };
            fill_probe(probe, &bp.program, &bp.spans, &bp.tail_spans, entries, mode);
        }
        let t = self.t0();
        let out = f(self.cached.as_ref().expect("step program just installed"));
        self.lap(ProfPhase::Execute, t);
        out
    }

    /// The memoized delta path: gate on pairwise-disjoint channel masks,
    /// then merge (cached or freshly computed) solo runs. `None` means
    /// the gate failed and the batch must actually run.
    fn try_memoized(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        entries: &[BatchEntry<'_>],
    ) -> Option<RunStats> {
        if entries.is_empty() || !self.masks_disjoint(arch, cfg, entries) {
            return None;
        }
        let mut makespan = 0;
        let mut slot0: Option<RunStats> = None;
        let mut out = RunStats {
            makespan: 0,
            breakdown: Breakdown::default(),
            hbm_bytes: 0,
            flops: 0,
            redmule_busy_total: 0,
            spatz_busy_total: 0,
            ops_executed: 0,
        };
        for e in entries {
            let solo = self.solo_stats(arch, cfg, e);
            makespan = makespan.max(solo.makespan);
            out.hbm_bytes += solo.hbm_bytes;
            out.flops += solo.flops;
            out.redmule_busy_total += solo.redmule_busy_total;
            out.spatz_busy_total += solo.spatz_busy_total;
            out.ops_executed += solo.ops_executed;
            if e.slot == 0 {
                slot0 = Some(solo);
            }
        }
        // `solo_stats` accumulated each entry's busy attribution into the
        // probe (additive by the conservation property), so the probe now
        // equals what a scan of the batch program would have produced.
        out.makespan = makespan;
        // The tracked tile (0) belongs to slot 0's band: its intervals in
        // the batch equal its solo intervals, so the batch breakdown is
        // the solo one re-closed over the longer step — the added barrier
        // wait is uncovered time, i.e. `other`. With slot 0 empty the
        // tracked tile runs nothing and the whole step is `other`.
        out.breakdown = match slot0 {
            Some(s0) => {
                let mut bd = s0.breakdown;
                bd.other += makespan - s0.makespan;
                bd
            }
            None => Breakdown { other: makespan, ..Breakdown::default() },
        };
        Some(out)
    }

    /// Superset channel masks, pairwise-disjointness gate: an entry can
    /// only ever touch the channels its K/V pages live on plus the row
    /// channels of its band's tiles (Q loads / O stores / stats), so
    /// disjoint masks imply the entries share no resource at all.
    fn masks_disjoint(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        entries: &[BatchEntry<'_>],
    ) -> bool {
        let hbm_map = HbmMap::new(arch);
        let words = hbm_map.total_channels().div_ceil(64);
        self.mask_union.clear();
        self.mask_union.resize(words, 0);
        let rows_per = arch.mesh_y / cfg.slots;
        for e in entries {
            self.mask_entry.clear();
            self.mask_entry.resize(words, 0);
            let pages = e.pages.pages_for(e.workload.kv_len()) as usize;
            for &c in &e.pages.channels()[..pages] {
                self.mask_entry[c as usize / 64] |= 1u64 << (c % 64);
            }
            for y in e.slot * rows_per..(e.slot + 1) * rows_per {
                for x in 0..arch.mesh_x {
                    let c = hbm_map.row_channel(x, y).index;
                    self.mask_entry[c / 64] |= 1u64 << (c % 64);
                }
            }
            if self.mask_entry.iter().zip(&self.mask_union).any(|(m, u)| m & u != 0) {
                return false;
            }
            for (u, m) in self.mask_union.iter_mut().zip(&self.mask_entry) {
                *u |= m;
            }
        }
        true
    }

    /// One entry's solo [`RunStats`], from the memo or a fresh
    /// compose+execute. Results are thread-count invariant (pinned by
    /// `tests/parallel_differential.rs`), so the memo never needs to key
    /// on `cfg.threads`.
    fn solo_stats(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        e: &BatchEntry<'_>,
    ) -> RunStats {
        let pages = e.pages.pages_for(e.workload.kv_len()) as usize;
        let key = SoloKey {
            slot: e.slot,
            workload: e.workload,
            page_tokens: e.pages.page_tokens(),
            chans: e.pages.channels()[..pages].into(),
        };
        if let Some(s) = self.solo.get(&key) {
            self.memo_hits += 1;
            if let Some(probe) = self.probe.as_mut() {
                for &(c, b) in s.chan_busy.iter() {
                    probe.chan_busy[c as usize] += b;
                }
                probe.noc_slot_busy[e.slot] += s.noc_busy;
            }
            return s.stats.clone();
        }
        self.memo_misses += 1;
        let one = [BatchEntry {
            request: e.request,
            slot: e.slot,
            workload: e.workload,
            pages: e.pages,
        }];
        let (df, group, slots) = (cfg.dataflow, cfg.group, cfg.slots);
        let t = self.t0();
        let mut bp = batch::compose_unsealed_in(&mut self.solo_arena, arch, df, group, slots, &one);
        self.lap(ProfPhase::Compose, t);
        let t = self.t0();
        bp.program.seal();
        self.lap_seal(t);
        let t = self.t0();
        let stats = bp.run_threads(cfg.threads);
        self.lap(ProfPhase::Execute, t);
        let (chan_busy, noc_busy) = if let Some(probe) = self.probe.as_mut() {
            let (chan, noc) = solo_busy(&bp.program, &bp.spans, probe.chan_busy.len());
            for &(c, b) in chan.iter() {
                probe.chan_busy[c as usize] += b;
            }
            probe.noc_slot_busy[e.slot] += noc;
            (chan, noc)
        } else {
            (Box::default(), 0)
        };
        self.solo_arena.recycle(bp.program);
        if self.solo.len() >= SOLO_CACHE_CAP {
            self.solo.clear();
        }
        self.solo.insert(key, SoloRun { stats: stats.clone(), chan_busy, noc_busy });
        stats
    }
}

/// True for NoC-fabric collective components (row/col buses have no stable
/// global `ResourceId` across solo-vs-batch composes, so telemetry
/// attributes their traffic per batch slot instead of per bus).
fn is_noc(c: Component) -> bool {
    matches!(c, Component::SumReduce | Component::MaxReduce | Component::Multicast)
}

/// Scan a composed batch program into the probe: per-HBM-channel occupancy
/// (the batch builders allocate channel resources first, so
/// `ResourceId(c) == channel c`) plus per-slot NoC-collective occupancy via
/// the entry spans (attention spans plus GEMM tail spans, both indexed in
/// `entries` order; `tails` is empty for attention-only steps). Occupancy
/// sums are schedule-independent, hence identical across thread counts,
/// and additive across entries — see the determinism argument in
/// `crate::telemetry`.
fn fill_probe(
    probe: &mut StepProbe,
    program: &Program,
    spans: &[(usize, usize)],
    tails: &[(usize, usize)],
    entries: &[BatchEntry<'_>],
    mode: StepMode,
) {
    probe.reset();
    probe.mode = mode;
    let n_chan = probe.chan_busy.len();
    for op in program.ops() {
        let r = op.resource.0 as usize;
        if r < n_chan {
            probe.chan_busy[r] += op.occupancy;
        }
    }
    for (k, &(s, e)) in spans.iter().enumerate().chain(tails.iter().enumerate()) {
        let slot = entries[k].slot;
        let mut busy = 0u64;
        for op in &program.ops()[s..e] {
            if is_noc(op.component) {
                busy += op.occupancy;
            }
        }
        probe.noc_slot_busy[slot] += busy;
    }
}

/// A solo program's busy attribution: sparse per-channel occupancy plus the
/// entry's NoC-collective occupancy. Counted exactly like [`fill_probe`]
/// (channels over all ops, NoC over the entry span) so memo-merged sums
/// reproduce the batch scan bit for bit.
fn solo_busy(
    program: &Program,
    spans: &[(usize, usize)],
    n_chan: usize,
) -> (Box<[(u32, u64)]>, u64) {
    let mut dense = vec![0u64; n_chan];
    for op in program.ops() {
        let r = op.resource.0 as usize;
        if r < n_chan {
            dense[r] += op.occupancy;
        }
    }
    let mut noc = 0u64;
    for &(s, e) in spans {
        for op in &program.ops()[s..e] {
            if is_noc(op.component) {
                noc += op.occupancy;
            }
        }
    }
    let sparse: Box<[(u32, u64)]> = dense
        .iter()
        .enumerate()
        .filter(|&(_, &b)| b != 0)
        .map(|(c, &b)| (c as u32, b))
        .collect();
    (sparse, noc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::dataflow::Dataflow;
    use crate::hbm::PageMap;

    fn tiny_cfg(df: Dataflow) -> SchedulerConfig {
        let mut cfg = SchedulerConfig::new(df);
        cfg.slots = 4;
        cfg.group = 2;
        cfg.chunk = 96;
        cfg.page_tokens = 32;
        cfg.heads = 4;
        cfg.head_dim = 64;
        cfg
    }

    /// Pages on the slot's affine south-channel partition of table2-8
    /// (8 west + 8 south channels, 4 slots ⇒ 2 south channels per slot).
    fn affine_pages(slot: usize, tokens: u64) -> PageMap {
        let mut pm = PageMap::new(32);
        pm.grow_to(tokens, |p| (8 + slot as u32 * 2) + (p % 2) as u32);
        pm
    }

    /// A growing decode cache that stays inside one tiling/page shape
    /// only changes op *costs*: the composer must cost-patch the sealed
    /// step program instead of resealing, and every step must match the
    /// full-rebuild path bit for bit.
    #[test]
    fn decode_growth_patches_in_place_and_matches_rebuild() {
        let arch = presets::table2(8);
        let mut cfg = tiny_cfg(Dataflow::Flash2);
        cfg.memoize = false;
        let mut full_cfg = cfg.clone();
        full_cfg.incremental = false;
        let mut inc = StepComposer::new(&cfg);
        let mut full = StepComposer::new(&full_cfg);
        let mut pages = PageMap::new(32);
        for kv in [300u64, 301, 302] {
            pages.grow_to(kv, |p| (8 + (p % 2)) as u32);
            let wl = Workload::new(kv, 64, 4, 1).with_kv_heads(2).decode();
            let entries = [BatchEntry { request: 0, slot: 0, workload: wl, pages: &pages }];
            let a = inc.run_step(&arch, &cfg, &entries);
            let b = full.run_step(&arch, &full_cfg, &entries);
            assert_eq!(a, b, "kv={kv}");
        }
        assert_eq!(inc.resealed_steps(), 1, "only the first step seals");
        assert_eq!(inc.patched_steps(), 2, "pure-cost steps patch in place");
        assert_eq!(full.patched_steps(), 0);
    }

    /// Channel-disjoint entries take the solo-merge path, hit the memo on
    /// repeats, and reproduce the batch execution exactly.
    #[test]
    fn memoized_steps_match_batch_execution() {
        let arch = presets::table2(8);
        let cfg = tiny_cfg(Dataflow::Flash2);
        let mut full_cfg = cfg.clone();
        full_cfg.incremental = false;
        full_cfg.memoize = false;
        let mut memo = StepComposer::new(&cfg);
        let mut full = StepComposer::new(&full_cfg);
        let wl0 = Workload::new(128, 64, 4, 1).with_kv_heads(2).with_causal(true);
        let wl2 = Workload::new(300, 64, 4, 1).with_kv_heads(1).decode();
        let (p0, p2) = (affine_pages(0, wl0.kv_len()), affine_pages(2, wl2.kv_len()));
        let entries = [
            BatchEntry { request: 0, slot: 0, workload: wl0, pages: &p0 },
            BatchEntry { request: 1, slot: 2, workload: wl2, pages: &p2 },
        ];
        for round in 0..2 {
            let a = memo.run_step(&arch, &cfg, &entries);
            let b = full.run_step(&arch, &full_cfg, &entries);
            assert_eq!(a, b, "round {round}");
        }
        assert_eq!(memo.memo_steps(), 2, "disjoint masks take the solo path");
        assert_eq!(memo.memo_hits(), 2, "the repeat round is pure memo hits");
    }

    /// Entries sharing a K/V channel fail the disjointness gate and run
    /// as a real batch (the contention they model is real).
    #[test]
    fn overlapping_channels_bypass_the_memo() {
        let arch = presets::table2(8);
        let cfg = tiny_cfg(Dataflow::Flash2);
        let mut memo = StepComposer::new(&cfg);
        let wl = Workload::new(128, 64, 4, 1).with_kv_heads(2).with_causal(true);
        let shared0 = affine_pages(0, wl.kv_len());
        let shared2 = affine_pages(0, wl.kv_len()); // slot 2 on slot 0's channels
        let entries = [
            BatchEntry { request: 0, slot: 0, workload: wl, pages: &shared0 },
            BatchEntry { request: 1, slot: 2, workload: wl, pages: &shared2 },
        ];
        let _ = memo.run_step(&arch, &cfg, &entries);
        assert_eq!(memo.memo_steps(), 0);
        assert_eq!(memo.resealed_steps(), 1);
    }
}
