//! §Incremental step composition and memoized delta re-simulation.
//!
//! The scheduler's step loop used to rebuild and re-simulate the whole
//! batch program from scratch every step, making step cost linear in the
//! total in-flight op count — fine for a five-request smoke trace, fatal
//! for the ROADMAP's million-request horizon. [`StepComposer`] removes
//! both rebuild taxes while staying **bit-identical** to the full-rebuild
//! path (pinned by `tests/incremental_differential.rs`):
//!
//! 1. **Incremental compose.** The composer keeps the previous step's
//!    *sealed* [`BatchProgram`] alive. Each step it re-emits the entries
//!    into an unsealed scratch program (`batch::compose_unsealed_in`;
//!    template stamping makes the emission itself cheap) and compares it
//!    structurally against the cached program. When every op matches in
//!    resource/component/tile/dependency topology — the common case: a
//!    steady decode step moves latencies and byte counts, not the op
//!    graph — the cached program is cost-patched in place
//!    (`Program::patch_costs_from`) and its dependents + §Shard CSRs from
//!    the previous seal stay valid verbatim, both partitions being
//!    functions of op structure only. Any structural change (admit or
//!    finish, a tiling boundary, a new page segment) falls back to
//!    sealing the scratch program as the new cached step program.
//!    Correctness never depends on *predicting* stability; it is checked
//!    op for op, and the check is the cheap part of a build.
//! 2. **Memoized delta re-simulation.** Batch composition is conservative
//!    (PR 4, pinned by `tests/scheduler_integration.rs`): entries own
//!    private tile bands and couple only through shared HBM channel
//!    FIFOs, so when the entries' channel sets are pairwise disjoint each
//!    entry's op timeline in the batch is bit-identical to composing it
//!    alone. Under that gate the step outcome is a pure function of the
//!    per-entry solo runs: makespan is the max of solo makespans, the
//!    additive totals (HBM bytes, FLOPs, engine busy, ops) are sums, and
//!    the tracked-tile breakdown is slot 0's solo breakdown with the
//!    extra barrier wait folded into `other`. Solo runs are memoized by
//!    `(slot, workload, page-table prefix)`, so a steady-state decode
//!    step over recurring request shapes costs a few hash lookups and a
//!    merge — no compose, no DES. The gate uses a *superset* channel
//!    mask built analytically from the page table and the band's row
//!    channels (disjoint supersets imply disjoint actual sets), and the
//!    memo path is disabled for any step with a live fault window, where
//!    a dead tile stalls timelines across the step barrier.
//!
//! Both levers are config knobs ([`SchedulerConfig::incremental`] /
//! [`SchedulerConfig::memoize`], default on) so the differential wall can
//! force the full-rebuild path and compare reports field by field.

use std::collections::HashMap;

use super::batch::{self, BatchEntry, BatchProgram};
use super::SchedulerConfig;
use crate::arch::ArchConfig;
use crate::dataflow::Workload;
use crate::hbm::HbmMap;
use crate::sim::{Breakdown, FaultPlan, ProgramArena, RunStats};

/// Memo key of one entry's solo run: the slot pins the tile band (hence
/// hop distances and the fold representative), the workload pins the op
/// graph and costs, and the page-table prefix pins every K/V transfer's
/// channel. The key stores the actual channel prefix, not a hash of it,
/// so a collision can never alias two different placements.
#[derive(PartialEq, Eq, Hash)]
struct SoloKey {
    slot: usize,
    workload: Workload,
    page_tokens: u64,
    chans: Box<[u32]>,
}

/// Solo-memo capacity: on overflow the map is cleared outright — crude
/// but deterministic (eviction order can never shape results because
/// cached and recomputed solo stats are identical by construction).
const SOLO_CACHE_CAP: usize = 1 << 14;

/// Per-run step composer: owns the persistent sealed step program, the
/// solo-run memo and the recycled build buffers. Construct one per
/// `simulate`/`route` call — cached state is specific to one
/// `(arch, cfg)` pair and must not leak across runs. Public so the bench
/// harness can price the compose paths in isolation; scheduler callers
/// go through [`super::simulate`] / [`super::router::route`].
pub struct StepComposer {
    incremental: bool,
    memoize: bool,
    /// Buffers cycling between the scratch emission and the retired
    /// cached program (promote/patch keeps exactly one set in flight).
    arena: ProgramArena,
    /// Separate buffers for solo composes on memo misses.
    solo_arena: ProgramArena,
    cached: Option<BatchProgram>,
    solo: HashMap<SoloKey, RunStats>,
    /// Union + per-entry scratch for the channel-mask disjointness gate.
    mask_union: Vec<u64>,
    mask_entry: Vec<u64>,
    patched: usize,
    resealed: usize,
    memo_steps: usize,
    memo_hits: usize,
}

impl StepComposer {
    pub fn new(cfg: &SchedulerConfig) -> Self {
        Self {
            incremental: cfg.incremental,
            memoize: cfg.memoize,
            arena: ProgramArena::new(),
            solo_arena: ProgramArena::new(),
            cached: None,
            solo: HashMap::new(),
            mask_union: Vec::new(),
            mask_entry: Vec::new(),
            patched: 0,
            resealed: 0,
            memo_steps: 0,
            memo_hits: 0,
        }
    }

    /// Steps whose program was cost-patched in place (seal skipped).
    pub fn patched_steps(&self) -> usize {
        self.patched
    }

    /// Steps that rebuilt + resealed (structure changed, or first step).
    pub fn resealed_steps(&self) -> usize {
        self.resealed
    }

    /// Steps served entirely from the solo-merge path (no batch DES run).
    pub fn memo_steps(&self) -> usize {
        self.memo_steps
    }

    /// Solo-run memo hits across all memoized steps.
    pub fn memo_hits(&self) -> usize {
        self.memo_hits
    }

    /// Compose (incrementally) and execute one fault-free step, serving
    /// it from the solo memo when the disjointness gate allows.
    pub fn run_step(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        entries: &[BatchEntry<'_>],
    ) -> RunStats {
        if self.memoize {
            if let Some(stats) = self.try_memoized(arch, cfg, entries) {
                self.memo_steps += 1;
                return stats;
            }
        }
        let threads = cfg.threads;
        self.with_composed(arch, cfg, entries, |bp| bp.run_threads(threads))
    }

    /// Compose (incrementally) and execute one step under a shifted fault
    /// plan; returns the entries that made no progress. The solo memo
    /// never applies here: faults couple timelines across entries.
    pub fn run_step_faulted(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        entries: &[BatchEntry<'_>],
        plan: &FaultPlan,
    ) -> (RunStats, Vec<usize>) {
        let threads = cfg.threads;
        self.with_composed(arch, cfg, entries, |bp| {
            let (stats, fr) = bp.run_faulted(threads, plan);
            let affected = bp.affected_entries(&fr);
            (stats, affected)
        })
    }

    /// Produce this step's sealed [`BatchProgram`] — cost-patching the
    /// cached one, promoting the scratch emission, or (with
    /// `incremental` off) plain full rebuild — and hand it to `f`.
    fn with_composed<R>(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        entries: &[BatchEntry<'_>],
        f: impl FnOnce(&BatchProgram) -> R,
    ) -> R {
        let (df, group, slots) = (cfg.dataflow, cfg.group, cfg.slots);
        if !self.incremental {
            let bp = batch::compose_in(&mut self.arena, arch, df, group, slots, entries);
            let out = f(&bp);
            self.arena.recycle(bp.program);
            return out;
        }
        let scratch = batch::compose_unsealed_in(&mut self.arena, arch, df, group, slots, entries);
        // `patch_costs_from` verifies structure before touching costs, so
        // a `false` here leaves the cached program intact — and the
        // failure path below discards it whole anyway.
        let patched = match self.cached.as_mut() {
            Some(prev) if prev.spans == scratch.spans => {
                prev.program.patch_costs_from(&scratch.program)
            }
            _ => false,
        };
        if patched {
            self.patched += 1;
            self.arena.recycle(scratch.program);
        } else {
            if let Some(p) = self.cached.take() {
                self.arena.recycle(p.program);
            }
            self.resealed += 1;
            let mut bp = scratch;
            bp.program.seal();
            self.cached = Some(bp);
        }
        f(self.cached.as_ref().expect("step program just installed"))
    }

    /// The memoized delta path: gate on pairwise-disjoint channel masks,
    /// then merge (cached or freshly computed) solo runs. `None` means
    /// the gate failed and the batch must actually run.
    fn try_memoized(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        entries: &[BatchEntry<'_>],
    ) -> Option<RunStats> {
        if entries.is_empty() || !self.masks_disjoint(arch, cfg, entries) {
            return None;
        }
        let mut makespan = 0;
        let mut slot0: Option<RunStats> = None;
        let mut out = RunStats {
            makespan: 0,
            breakdown: Breakdown::default(),
            hbm_bytes: 0,
            flops: 0,
            redmule_busy_total: 0,
            spatz_busy_total: 0,
            ops_executed: 0,
        };
        for e in entries {
            let solo = self.solo_stats(arch, cfg, e);
            makespan = makespan.max(solo.makespan);
            out.hbm_bytes += solo.hbm_bytes;
            out.flops += solo.flops;
            out.redmule_busy_total += solo.redmule_busy_total;
            out.spatz_busy_total += solo.spatz_busy_total;
            out.ops_executed += solo.ops_executed;
            if e.slot == 0 {
                slot0 = Some(solo);
            }
        }
        out.makespan = makespan;
        // The tracked tile (0) belongs to slot 0's band: its intervals in
        // the batch equal its solo intervals, so the batch breakdown is
        // the solo one re-closed over the longer step — the added barrier
        // wait is uncovered time, i.e. `other`. With slot 0 empty the
        // tracked tile runs nothing and the whole step is `other`.
        out.breakdown = match slot0 {
            Some(s0) => {
                let mut bd = s0.breakdown;
                bd.other += makespan - s0.makespan;
                bd
            }
            None => Breakdown { other: makespan, ..Breakdown::default() },
        };
        Some(out)
    }

    /// Superset channel masks, pairwise-disjointness gate: an entry can
    /// only ever touch the channels its K/V pages live on plus the row
    /// channels of its band's tiles (Q loads / O stores / stats), so
    /// disjoint masks imply the entries share no resource at all.
    fn masks_disjoint(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        entries: &[BatchEntry<'_>],
    ) -> bool {
        let hbm_map = HbmMap::new(arch);
        let words = hbm_map.total_channels().div_ceil(64);
        self.mask_union.clear();
        self.mask_union.resize(words, 0);
        let rows_per = arch.mesh_y / cfg.slots;
        for e in entries {
            self.mask_entry.clear();
            self.mask_entry.resize(words, 0);
            let pages = e.pages.pages_for(e.workload.kv_len()) as usize;
            for &c in &e.pages.channels()[..pages] {
                self.mask_entry[c as usize / 64] |= 1u64 << (c % 64);
            }
            for y in e.slot * rows_per..(e.slot + 1) * rows_per {
                for x in 0..arch.mesh_x {
                    let c = hbm_map.row_channel(x, y).index;
                    self.mask_entry[c / 64] |= 1u64 << (c % 64);
                }
            }
            if self.mask_entry.iter().zip(&self.mask_union).any(|(m, u)| m & u != 0) {
                return false;
            }
            for (u, m) in self.mask_union.iter_mut().zip(&self.mask_entry) {
                *u |= m;
            }
        }
        true
    }

    /// One entry's solo [`RunStats`], from the memo or a fresh
    /// compose+execute. Results are thread-count invariant (pinned by
    /// `tests/parallel_differential.rs`), so the memo never needs to key
    /// on `cfg.threads`.
    fn solo_stats(
        &mut self,
        arch: &ArchConfig,
        cfg: &SchedulerConfig,
        e: &BatchEntry<'_>,
    ) -> RunStats {
        let pages = e.pages.pages_for(e.workload.kv_len()) as usize;
        let key = SoloKey {
            slot: e.slot,
            workload: e.workload,
            page_tokens: e.pages.page_tokens(),
            chans: e.pages.channels()[..pages].into(),
        };
        if let Some(s) = self.solo.get(&key) {
            self.memo_hits += 1;
            return s.clone();
        }
        let one = [BatchEntry {
            request: e.request,
            slot: e.slot,
            workload: e.workload,
            pages: e.pages,
        }];
        let (df, group, slots) = (cfg.dataflow, cfg.group, cfg.slots);
        let bp = batch::compose_in(&mut self.solo_arena, arch, df, group, slots, &one);
        let stats = bp.run_threads(cfg.threads);
        self.solo_arena.recycle(bp.program);
        if self.solo.len() >= SOLO_CACHE_CAP {
            self.solo.clear();
        }
        self.solo.insert(key, stats.clone());
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::dataflow::Dataflow;
    use crate::hbm::PageMap;

    fn tiny_cfg(df: Dataflow) -> SchedulerConfig {
        let mut cfg = SchedulerConfig::new(df);
        cfg.slots = 4;
        cfg.group = 2;
        cfg.chunk = 96;
        cfg.page_tokens = 32;
        cfg.heads = 4;
        cfg.head_dim = 64;
        cfg
    }

    /// Pages on the slot's affine south-channel partition of table2-8
    /// (8 west + 8 south channels, 4 slots ⇒ 2 south channels per slot).
    fn affine_pages(slot: usize, tokens: u64) -> PageMap {
        let mut pm = PageMap::new(32);
        pm.grow_to(tokens, |p| (8 + slot as u32 * 2) + (p % 2) as u32);
        pm
    }

    /// A growing decode cache that stays inside one tiling/page shape
    /// only changes op *costs*: the composer must cost-patch the sealed
    /// step program instead of resealing, and every step must match the
    /// full-rebuild path bit for bit.
    #[test]
    fn decode_growth_patches_in_place_and_matches_rebuild() {
        let arch = presets::table2(8);
        let mut cfg = tiny_cfg(Dataflow::Flash2);
        cfg.memoize = false;
        let mut full_cfg = cfg.clone();
        full_cfg.incremental = false;
        let mut inc = StepComposer::new(&cfg);
        let mut full = StepComposer::new(&full_cfg);
        let mut pages = PageMap::new(32);
        for kv in [300u64, 301, 302] {
            pages.grow_to(kv, |p| (8 + (p % 2)) as u32);
            let wl = Workload::new(kv, 64, 4, 1).with_kv_heads(2).decode();
            let entries = [BatchEntry { request: 0, slot: 0, workload: wl, pages: &pages }];
            let a = inc.run_step(&arch, &cfg, &entries);
            let b = full.run_step(&arch, &full_cfg, &entries);
            assert_eq!(a, b, "kv={kv}");
        }
        assert_eq!(inc.resealed_steps(), 1, "only the first step seals");
        assert_eq!(inc.patched_steps(), 2, "pure-cost steps patch in place");
        assert_eq!(full.patched_steps(), 0);
    }

    /// Channel-disjoint entries take the solo-merge path, hit the memo on
    /// repeats, and reproduce the batch execution exactly.
    #[test]
    fn memoized_steps_match_batch_execution() {
        let arch = presets::table2(8);
        let cfg = tiny_cfg(Dataflow::Flash2);
        let mut full_cfg = cfg.clone();
        full_cfg.incremental = false;
        full_cfg.memoize = false;
        let mut memo = StepComposer::new(&cfg);
        let mut full = StepComposer::new(&full_cfg);
        let wl0 = Workload::new(128, 64, 4, 1).with_kv_heads(2).with_causal(true);
        let wl2 = Workload::new(300, 64, 4, 1).with_kv_heads(1).decode();
        let (p0, p2) = (affine_pages(0, wl0.kv_len()), affine_pages(2, wl2.kv_len()));
        let entries = [
            BatchEntry { request: 0, slot: 0, workload: wl0, pages: &p0 },
            BatchEntry { request: 1, slot: 2, workload: wl2, pages: &p2 },
        ];
        for round in 0..2 {
            let a = memo.run_step(&arch, &cfg, &entries);
            let b = full.run_step(&arch, &full_cfg, &entries);
            assert_eq!(a, b, "round {round}");
        }
        assert_eq!(memo.memo_steps(), 2, "disjoint masks take the solo path");
        assert_eq!(memo.memo_hits(), 2, "the repeat round is pure memo hits");
    }

    /// Entries sharing a K/V channel fail the disjointness gate and run
    /// as a real batch (the contention they model is real).
    #[test]
    fn overlapping_channels_bypass_the_memo() {
        let arch = presets::table2(8);
        let cfg = tiny_cfg(Dataflow::Flash2);
        let mut memo = StepComposer::new(&cfg);
        let wl = Workload::new(128, 64, 4, 1).with_kv_heads(2).with_causal(true);
        let shared0 = affine_pages(0, wl.kv_len());
        let shared2 = affine_pages(0, wl.kv_len()); // slot 2 on slot 0's channels
        let entries = [
            BatchEntry { request: 0, slot: 0, workload: wl, pages: &shared0 },
            BatchEntry { request: 1, slot: 2, workload: wl, pages: &shared2 },
        ];
        let _ = memo.run_step(&arch, &cfg, &entries);
        assert_eq!(memo.memo_steps(), 0);
        assert_eq!(memo.resealed_steps(), 1);
    }
}
