//! Mixed prefill+decode batch-program composition.
//!
//! One scheduler step turns the set of in-flight requests into ONE
//! [`Program`]: each request contributes either a chunked-prefill row
//! block span or a single decode row, emitted onto its own horizontal
//! *band* of tile rows (`mesh_y / slots` rows per slot), while HBM
//! channels are shared chip-wide — so a request's compute is private but
//! its paged K/V placement contends with every other request's traffic on
//! the channels its pages landed on. Composition preserves the per-request
//! fold machinery (each band's first tile/group is that request's
//! representative stream) and is *conservative*: on an architecture where
//! the entries' channels don't overlap, each entry's op timeline is
//! bit-identical to composing that entry alone (asserted by
//! `tests/scheduler_integration.rs`).

use crate::arch::ArchConfig;
use crate::dataflow::gemm::append_gemm_band;
use crate::dataflow::layer::sinks_in;
use crate::dataflow::{flash, flat, Dataflow, LayerWorkload, WeightResidency, Workload};
use crate::hbm::PageMap;
use crate::sim::{
    execute, execute_faulted, execute_parallel, execute_traced, Cycle, FaultPlan, FaultReport,
    Program, ProgramArena, RunStats,
};

/// One request's contribution to a batch step.
#[derive(Debug)]
pub struct BatchEntry<'a> {
    /// Trace index of the request (metrics label only).
    pub request: usize,
    /// Scheduler slot — selects the entry's tile-row band.
    pub slot: usize,
    /// The step's workload: a causal chunked-prefill span
    /// (`kv_prefix = tokens already prefilled`) or a decode row
    /// (`seq = current cache length`). `batch == 1`.
    pub workload: Workload,
    /// Channel placement of the request's KV cache; must cover
    /// `workload.kv_len()` tokens.
    pub pages: &'a PageMap,
}

/// A composed batch program plus each entry's contiguous op span.
#[derive(Debug)]
pub struct BatchProgram {
    /// The composed step program.
    pub program: Program,
    /// Per entry: `[start, end)` op range of the entry's *attention*
    /// kernel, in `entries` order.
    pub spans: Vec<(usize, usize)>,
    /// Per entry: `[start, end)` op range of the entry's projection/FFN
    /// GEMM *tail* (see [`compose_layered`]); empty for attention-only
    /// batches. Tail spans follow all attention spans and stay on their
    /// entry's tile-row band, so the band-disjointness story (and
    /// `analysis::verify_batch`'s rules) extend to them unchanged.
    pub tail_spans: Vec<(usize, usize)>,
}

/// Per-entry execution summary extracted from a traced run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EntryStats {
    /// Completion cycle of the entry's last tile-owned op.
    pub completion: Cycle,
    /// HBM bytes moved by the entry's ops.
    pub hbm_bytes: u64,
    /// `(span-relative op id, start, complete)` for every tile-owned op,
    /// sorted by op id — the conservation-test observable.
    pub trace: Vec<(u32, Cycle, Cycle)>,
}

impl BatchProgram {
    /// Execute the composed program (breakdown tracked on tile 0 — slot
    /// 0's representative).
    pub fn run(&self) -> RunStats {
        self.run_threads(1)
    }

    /// Like [`BatchProgram::run`], executing with `threads` DES workers
    /// over the program's §Shard partition — each request band is a
    /// natural shard set, so a well-filled batch parallelizes per
    /// request. Bit-identical to [`BatchProgram::run`] at every count
    /// (`tests/parallel_differential.rs`).
    pub fn run_threads(&self, threads: usize) -> RunStats {
        if threads > 1 {
            execute_parallel(&self.program, 0, threads)
        } else {
            execute(&self.program, 0)
        }
    }

    /// Execute under a fault plan (windows relative to this step's start —
    /// the router shifts its absolute plan by the virtual clock first).
    /// Ops of dead tiles are killed and their dependents stall instead of
    /// completing; the [`FaultReport`] names both sets so the router can
    /// tell which entries made no progress this step.
    pub fn run_faulted(&self, threads: usize, plan: &FaultPlan) -> (RunStats, FaultReport) {
        execute_faulted(&self.program, 0, plan, threads)
    }

    /// Map a [`FaultReport`] to the entries whose spans (attention or
    /// GEMM tail) contain a killed or stalled op — the entries that made
    /// no progress this step.
    pub fn affected_entries(&self, fr: &FaultReport) -> Vec<usize> {
        let hit = |op: u32| {
            let op = op as usize;
            self.spans
                .iter()
                .position(|&(s, e)| op >= s && op < e)
                .or_else(|| self.tail_spans.iter().position(|&(s, e)| op >= s && op < e))
        };
        let mut out: Vec<usize> =
            fr.killed.iter().chain(&fr.stalled).filter_map(|&op| hit(op)).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Execute with full tracing and split the records per entry. Tail
    /// ops continue the entry's span-relative id space (tail op `t` maps
    /// to `span_len + (t - tail_start)`), so an entry's trace is one
    /// contiguous observable across both kernels.
    pub fn entry_stats(&self) -> (RunStats, Vec<EntryStats>) {
        let (stats, mut records) = execute_traced(&self.program, 0, Some(u32::MAX));
        records.sort_unstable_by_key(|r| r.0);
        let slice = |s: usize, e: usize, base: u32, out: &mut Vec<(u32, Cycle, Cycle)>| {
            let lo = records.partition_point(|r| (r.0 as usize) < s);
            let hi = records.partition_point(|r| (r.0 as usize) < e);
            out.extend(records[lo..hi].iter().map(|&(op, st, en)| (op - s as u32 + base, st, en)));
        };
        let out = self
            .spans
            .iter()
            .enumerate()
            .map(|(k, &(s, e))| {
                let mut trace = Vec::new();
                slice(s, e, 0, &mut trace);
                let mut hbm_bytes: u64 =
                    self.program.ops()[s..e].iter().map(|o| o.hbm_bytes).sum();
                if let Some(&(ts, te)) = self.tail_spans.get(k) {
                    slice(ts, te, (e - s) as u32, &mut trace);
                    hbm_bytes +=
                        self.program.ops()[ts..te].iter().map(|o| o.hbm_bytes).sum::<u64>();
                }
                EntryStats {
                    completion: trace.iter().map(|r| r.2).max().unwrap_or(0),
                    hbm_bytes,
                    trace,
                }
            })
            .collect();
        (stats, out)
    }
}

/// Validate a slot count against the mesh (bands must tile the rows).
pub fn validate_slots(
    arch: &ArchConfig,
    slots: usize,
    group: usize,
    df: Dataflow,
) -> Result<usize, String> {
    if slots == 0 || arch.mesh_y % slots != 0 {
        return Err(format!(
            "slots {slots} must divide the {}-row mesh (each slot owns a tile-row band)",
            arch.mesh_y
        ));
    }
    let rows_per = arch.mesh_y / slots;
    if df.is_flat() && (group == 0 || rows_per % group != 0 || arch.mesh_x % group != 0) {
        return Err(format!(
            "group {group} must divide both the {rows_per}-row slot band and the {}-column mesh",
            arch.mesh_x
        ));
    }
    Ok(rows_per)
}

/// Compose a batch program from `entries` on `arch` under dataflow `df`
/// (`group` applies to the FlatAttention family). Entries must occupy
/// distinct slots below `slots`.
pub fn compose(
    arch: &ArchConfig,
    df: Dataflow,
    group: usize,
    slots: usize,
    entries: &[BatchEntry<'_>],
) -> BatchProgram {
    compose_in(&mut ProgramArena::new(), arch, df, group, slots, entries)
}

/// Like [`compose`], constructing into buffers recycled by `arena` — the
/// scheduler's per-step entry point.
pub fn compose_in(
    arena: &mut ProgramArena,
    arch: &ArchConfig,
    df: Dataflow,
    group: usize,
    slots: usize,
    entries: &[BatchEntry<'_>],
) -> BatchProgram {
    let mut bp = compose_unsealed_in(arena, arch, df, group, slots, entries);
    bp.program.seal();
    bp
}

/// Like [`compose_in`] but the returned program is *unsealed*: the
/// §Incremental step composer (`scheduler::incremental`) compares it
/// structurally against the previous step's sealed program and either
/// cost-patches that one in place — skipping the seal (dependents +
/// §Shard CSR derivation) entirely — or seals this one as the new
/// persistent step program.
pub(crate) fn compose_unsealed_in(
    arena: &mut ProgramArena,
    arch: &ArchConfig,
    df: Dataflow,
    group: usize,
    slots: usize,
    entries: &[BatchEntry<'_>],
) -> BatchProgram {
    let rows_per = match validate_slots(arch, slots, group, df) {
        Ok(r) => r,
        Err(e) => panic!("compose: {e}"),
    };
    assert!(!entries.is_empty(), "compose: empty batch");
    for (k, e) in entries.iter().enumerate() {
        assert!(e.slot < slots, "entry {k}: slot {} out of range (slots {slots})", e.slot);
        assert!(
            entries[..k].iter().all(|p| p.slot != e.slot),
            "entry {k}: slot {} already occupied",
            e.slot
        );
        assert!(
            e.pages.tokens_capacity() >= e.workload.kv_len(),
            "entry {k}: page map covers {} tokens but the cache holds {}",
            e.pages.tokens_capacity(),
            e.workload.kv_len()
        );
    }

    let prog = arena.fresh();
    let (program, spans) = match df {
        Dataflow::Flash2 | Dataflow::Flash3 => {
            let fe: Vec<flash::FlashBatchEntry<'_>> = entries
                .iter()
                .map(|e| flash::FlashBatchEntry {
                    wl: e.workload,
                    pages: e.pages,
                    y0: e.slot * rows_per,
                    y1: (e.slot + 1) * rows_per,
                })
                .collect();
            flash::flash_batch_program_in(prog, arch, &fe, df == Dataflow::Flash3)
        }
        Dataflow::Flat | Dataflow::FlatColl | Dataflow::FlatAsyn => {
            let mut a = arch.clone();
            a.noc.hw_collectives = df != Dataflow::Flat;
            let fe: Vec<flat::FlatBatchEntry<'_>> = entries
                .iter()
                .map(|e| flat::FlatBatchEntry {
                    wl: e.workload,
                    pages: e.pages,
                    y0: e.slot * rows_per,
                    y1: (e.slot + 1) * rows_per,
                })
                .collect();
            flat::flat_batch_program_in(prog, &a, &fe, group, df == Dataflow::FlatAsyn)
        }
    };
    BatchProgram { program, spans, tail_spans: Vec::new() }
}

/// Layer-serving parameters shared by every entry of a composed step:
/// the FFN expansion factor and where the projection/FFN weights live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerParams {
    /// FFN hidden width = `ffn_mult · d_model` (≥ 1).
    pub ffn_mult: u64,
    /// Weight residency of every GEMM tail.
    pub weights: WeightResidency,
}

/// Like [`compose`], additionally appending each entry's transformer-
/// layer GEMM tail (out-proj → FFN-up → FFN-down → next-layer QKV, see
/// `dataflow::layer` §Kernel rotation) onto the entry's own tile-row
/// band behind strict cross-kernel barriers. The result carries
/// per-entry [`BatchProgram::tail_spans`].
pub fn compose_layered(
    arch: &ArchConfig,
    df: Dataflow,
    group: usize,
    slots: usize,
    entries: &[BatchEntry<'_>],
    lp: LayerParams,
) -> BatchProgram {
    compose_layered_in(&mut ProgramArena::new(), arch, df, group, slots, entries, lp)
}

/// Like [`compose_layered`], constructing into buffers recycled by
/// `arena` — the scheduler's layered-step entry point. Always seals (the
/// layered path never cost-patches; see `StepComposer::run_step_layered`).
pub(crate) fn compose_layered_in(
    arena: &mut ProgramArena,
    arch: &ArchConfig,
    df: Dataflow,
    group: usize,
    slots: usize,
    entries: &[BatchEntry<'_>],
    lp: LayerParams,
) -> BatchProgram {
    let mut bp = compose_unsealed_in(arena, arch, df, group, slots, entries);
    let rows_per = validate_slots(arch, slots, group, df).expect("validated by compose");
    for (k, e) in entries.iter().enumerate() {
        let (s, end) = bp.spans[k];
        // Cross-kernel edges attach to the entry's attention sinks —
        // per entry, not batch-wide: bands stay independent.
        let mut deps = sinks_in(&bp.program, s, end);
        let begin = bp.program.num_ops();
        let lw = LayerWorkload::new(e.workload, lp.ffn_mult, lp.weights);
        let (y0, y1) = (e.slot * rows_per, (e.slot + 1) * rows_per);
        for g in lw.gemms() {
            let sink = append_gemm_band(&mut bp.program, arch, &g, y0, y1, lp.weights, &deps);
            bp.program.flops += g.flops();
            deps = vec![sink];
        }
        bp.tail_spans.push((begin, bp.program.num_ops()));
    }
    bp.program.seal();
    bp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::dataflow::ALL_DATAFLOWS;

    fn pages_for(tokens: u64, chan: u32) -> PageMap {
        let mut pm = PageMap::new(32);
        pm.grow_to(tokens, |_| chan);
        pm
    }

    #[test]
    fn compose_builds_valid_programs_for_every_dataflow() {
        let arch = presets::table2(8);
        let p0 = pages_for(256, 8);
        let p1 = pages_for(300, 9);
        let entries = vec![
            BatchEntry {
                request: 0,
                slot: 0,
                workload: Workload::new(128, 64, 4, 1).with_causal(true).with_kv_prefix(128),
                pages: &p0,
            },
            BatchEntry {
                request: 1,
                slot: 2,
                workload: Workload::new(300, 64, 4, 1).with_kv_heads(2).decode(),
                pages: &p1,
            },
        ];
        for df in ALL_DATAFLOWS {
            let bp = compose(&arch, df, 2, 4, &entries);
            assert!(bp.program.validate().is_ok(), "{df:?}");
            assert_eq!(bp.spans.len(), 2);
            assert!(bp.spans[0].0 < bp.spans[0].1 && bp.spans[0].1 <= bp.spans[1].0);
            let (stats, per) = bp.entry_stats();
            assert!(stats.makespan > 0, "{df:?}");
            assert!(per.iter().all(|e| e.completion > 0 && e.hbm_bytes > 0), "{df:?}");
            // Span traffic partitions the program traffic.
            assert_eq!(per.iter().map(|e| e.hbm_bytes).sum::<u64>(), stats.hbm_bytes, "{df:?}");
        }
    }

    #[test]
    fn stamped_paged_compose_is_identical_to_naive() {
        // Template stamping now applies to paged batch entries: a block's
        // page segments depend only on its K/V token range, which the
        // template key pins, so stamped instances copy verbatim. The
        // composed program must match the naive per-block emission op for
        // op under both folding modes. Heads are sized so every stream
        // holds ≥3 same-key blocks (template registered at the second,
        // stamped from the third).
        use crate::dataflow::{assert_programs_equal, set_symmetry_folding, set_template_stamping};
        let _guard = crate::dataflow::GLOBAL_SWITCH_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let arch = presets::table2(8);
        let mut p0 = PageMap::new(32);
        p0.grow_to(512, |page| (page % 4) as u32);
        let mut p1 = PageMap::new(32);
        p1.grow_to(700, |page| 4 + (page % 4) as u32);
        let entries = vec![
            BatchEntry {
                request: 0,
                slot: 0,
                workload: Workload::new(256, 64, 48, 1).with_causal(true).with_kv_prefix(256),
                pages: &p0,
            },
            BatchEntry {
                request: 1,
                slot: 2,
                workload: Workload::new(700, 64, 48, 1).with_kv_heads(12).decode(),
                pages: &p1,
            },
        ];
        for folding in [true, false] {
            set_symmetry_folding(folding);
            for df in ALL_DATAFLOWS {
                let stamped = compose(&arch, df, 2, 4, &entries);
                set_template_stamping(false);
                let naive = compose(&arch, df, 2, 4, &entries);
                set_template_stamping(true);
                assert_programs_equal(&stamped.program, &naive.program);
                assert_eq!(stamped.spans, naive.spans, "{df:?}");
            }
        }
        set_symmetry_folding(true);
    }

    #[test]
    fn layered_compose_appends_band_local_tails() {
        let arch = presets::table2(8);
        let p0 = pages_for(256, 8);
        let p1 = pages_for(300, 9);
        let entries = vec![
            BatchEntry {
                request: 0,
                slot: 0,
                workload: Workload::new(128, 64, 4, 1).with_causal(true).with_kv_prefix(128),
                pages: &p0,
            },
            BatchEntry {
                request: 1,
                slot: 2,
                workload: Workload::new(300, 64, 4, 1).with_kv_heads(2).decode(),
                pages: &p1,
            },
        ];
        let lp = LayerParams { ffn_mult: 4, weights: WeightResidency::HbmStream };
        let rows_per = arch.mesh_y / 4;
        for df in ALL_DATAFLOWS {
            let bp = compose_layered(&arch, df, 2, 4, &entries, lp);
            assert!(bp.program.validate().is_ok(), "{df:?}");
            assert_eq!(bp.tail_spans.len(), 2, "{df:?}");
            // Tails follow every attention span and tile contiguously.
            assert!(bp.tail_spans[0].0 >= bp.spans[1].1, "{df:?}");
            assert_eq!(bp.tail_spans[0].1, bp.tail_spans[1].0, "{df:?}");
            assert_eq!(bp.tail_spans[1].1, bp.program.num_ops(), "{df:?}");
            // Tail ops stay on their entry's tile-row band.
            for (k, &(s, e)) in bp.tail_spans.iter().enumerate() {
                let slot = entries[k].slot;
                for op in &bp.program.ops()[s..e] {
                    if op.tile != crate::sim::NO_TILE {
                        let y = op.tile as usize / arch.mesh_x;
                        assert!(
                            (slot * rows_per..(slot + 1) * rows_per).contains(&y),
                            "{df:?}: tail op on row {y} outside slot {slot}'s band"
                        );
                    }
                }
            }
            // Per-entry traffic (span + tail) still partitions the total.
            let (stats, per) = bp.entry_stats();
            assert!(stats.makespan > 0, "{df:?}");
            assert_eq!(per.iter().map(|e| e.hbm_bytes).sum::<u64>(), stats.hbm_bytes, "{df:?}");
        }
    }

    #[test]
    fn validate_slots_rejects_bad_geometry() {
        let arch = presets::table2(8);
        assert!(validate_slots(&arch, 3, 1, Dataflow::Flash2).is_err());
        assert!(validate_slots(&arch, 0, 1, Dataflow::Flash2).is_err());
        assert!(validate_slots(&arch, 4, 4, Dataflow::FlatColl).is_err()); // band 2 % 4 != 0
        assert_eq!(validate_slots(&arch, 4, 2, Dataflow::FlatColl), Ok(2));
        assert_eq!(validate_slots(&arch, 2, 4, Dataflow::FlatColl), Ok(4));
    }

    #[test]
    #[should_panic(expected = "already occupied")]
    fn compose_rejects_duplicate_slots() {
        let arch = presets::table2(8);
        let p = pages_for(64, 8);
        let wl = Workload::new(64, 64, 2, 1).with_causal(true);
        let entries = vec![
            BatchEntry { request: 0, slot: 1, workload: wl, pages: &p },
            BatchEntry { request: 1, slot: 1, workload: wl, pages: &p },
        ];
        let _ = compose(&arch, Dataflow::Flash2, 2, 4, &entries);
    }

    #[test]
    #[should_panic(expected = "page map covers")]
    fn compose_rejects_undersized_page_maps() {
        let arch = presets::table2(8);
        let p = pages_for(64, 8);
        let entries = vec![BatchEntry {
            request: 0,
            slot: 0,
            workload: Workload::new(300, 64, 2, 1).decode(),
            pages: &p,
        }];
        let _ = compose(&arch, Dataflow::Flash2, 2, 4, &entries);
    }
}
