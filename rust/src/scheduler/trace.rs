//! Request traces: the serving workload input.
//!
//! A trace is a list of requests `(arrival cycle, prompt length, output
//! length, kv_heads)` sorted by arrival. Built-in synthetic traces cover
//! the common study shapes (a mixed staggered-arrival stream, an all-at-
//! once burst with skewed output lengths for the static-vs-continuous
//! comparison); external traces load from a simple CSV.

/// One serving request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Index in the trace (stable id).
    pub id: usize,
    /// Arrival time in simulated cycles.
    pub arrival: u64,
    /// Prompt (prefill) length in tokens.
    pub prompt: u64,
    /// Output tokens to generate (>= 1; the first is produced by the last
    /// prefill step).
    pub output: u64,
    /// K/V heads of the request's model configuration (GQA/MQA).
    pub kv_heads: u64,
}

/// A request trace, sorted by arrival time.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    pub requests: Vec<Request>,
}

impl RequestTrace {
    /// Build from `(arrival, prompt, output)` rows with a uniform
    /// `kv_heads`; validates and sorts.
    pub fn from_rows(rows: &[(u64, u64, u64)], kv_heads: u64) -> Self {
        let rows: Vec<(u64, u64, u64, u64)> =
            rows.iter().map(|&(a, p, o)| (a, p, o, kv_heads)).collect();
        Self::from_full_rows(&rows)
    }

    /// Build from `(arrival, prompt, output, kv_heads)` rows.
    pub fn from_full_rows(rows: &[(u64, u64, u64, u64)]) -> Self {
        let mut requests: Vec<Request> = rows
            .iter()
            .enumerate()
            .map(|(id, &(arrival, prompt, output, kv_heads))| {
                assert!(prompt > 0, "request {id}: prompt must be >= 1 token");
                assert!(output > 0, "request {id}: output must be >= 1 token");
                assert!(kv_heads > 0, "request {id}: kv_heads must be >= 1");
                Request { id, arrival, prompt, output, kv_heads }
            })
            .collect();
        requests.sort_by_key(|r| (r.arrival, r.id));
        Self { requests }
    }

    /// Built-in synthetic traces. `kv_heads` fills the per-request model
    /// configuration (must divide the scheduler's query-head count).
    ///
    /// * `builtin` / `mixed` — 12 requests with staggered arrivals, mixed
    ///   prompt lengths and skewed output lengths: exercises chunked
    ///   prefill riding alongside in-flight decodes.
    /// * `burst` — 8 requests arriving at once with outputs alternating
    ///   8/64: the shape where continuous batching beats static batching
    ///   (short requests free their slot while long ones keep decoding).
    pub fn builtin(name: &str, kv_heads: u64) -> Option<Self> {
        let rows: &[(u64, u64, u64)] = match name {
            "builtin" | "mixed" => &[
                (0, 384, 24),
                (0, 768, 48),
                (10_000, 256, 8),
                (40_000, 1024, 64),
                (80_000, 512, 16),
                (120_000, 640, 32),
                (200_000, 128, 96),
                (220_000, 896, 12),
                (300_000, 512, 40),
                (340_000, 256, 24),
                (400_000, 768, 8),
                (420_000, 384, 56),
            ],
            "burst" => &[
                (0, 512, 8),
                (0, 512, 64),
                (0, 512, 8),
                (0, 512, 64),
                (0, 512, 8),
                (0, 512, 64),
                (0, 512, 8),
                (0, 512, 64),
            ],
            _ => return None,
        };
        Some(Self::from_rows(rows, kv_heads))
    }

    /// Parse a CSV trace: one request per line as
    /// `arrival,prompt,output[,kv_heads]`; blank lines and `#` comments
    /// are skipped. `default_kv_heads` fills the optional column.
    pub fn parse(text: &str, default_kv_heads: u64) -> Result<Self, String> {
        let mut rows: Vec<(u64, u64, u64, u64)> = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split(',').map(|f| f.trim()).collect();
            if fields.len() < 3 || fields.len() > 4 {
                return Err(format!(
                    "line {}: expected 'arrival,prompt,output[,kv_heads]', got '{line}'",
                    lineno + 1
                ));
            }
            let mut nums = [0u64; 4];
            nums[3] = default_kv_heads;
            for (k, f) in fields.iter().enumerate() {
                nums[k] = f
                    .parse()
                    .map_err(|_| format!("line {}: bad integer '{f}'", lineno + 1))?;
            }
            if nums[1] == 0 || nums[2] == 0 || nums[3] == 0 {
                return Err(format!(
                    "line {}: prompt, output and kv_heads must be >= 1",
                    lineno + 1
                ));
            }
            rows.push((nums[0], nums[1], nums[2], nums[3]));
        }
        if rows.is_empty() {
            return Err("trace holds no requests".into());
        }
        Ok(Self::from_full_rows(&rows))
    }

    /// Total output tokens the trace will generate.
    pub fn total_output_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.output).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_traces_sorted_and_valid() {
        for name in ["builtin", "mixed", "burst"] {
            let t = RequestTrace::builtin(name, 8).expect(name);
            assert!(!t.requests.is_empty());
            assert!(t.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
            assert!(t.requests.iter().all(|r| r.kv_heads == 8));
            assert!(t.total_output_tokens() > 0);
        }
        assert!(RequestTrace::builtin("nope", 8).is_none());
    }

    #[test]
    fn parse_csv_with_defaults_comments_and_sorting() {
        let text = "# arrival,prompt,output[,kv_heads]\n\n40,128,4\n0,256,8,2\n";
        let t = RequestTrace::parse(text, 8).unwrap();
        assert_eq!(t.requests.len(), 2);
        // Sorted by arrival: the 0-cycle request first.
        assert_eq!(t.requests[0].arrival, 0);
        assert_eq!(t.requests[0].kv_heads, 2);
        assert_eq!(t.requests[1].kv_heads, 8); // default filled in
        assert_eq!(t.requests[1].prompt, 128);
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(RequestTrace::parse("1,2\n", 8).is_err());
        assert!(RequestTrace::parse("a,2,3\n", 8).is_err());
        assert!(RequestTrace::parse("1,0,3\n", 8).is_err());
        assert!(RequestTrace::parse("# only a comment\n", 8).is_err());
    }
}
