//! Request traces: the serving workload input.
//!
//! A trace is a list of requests `(arrival cycle, prompt length, output
//! length, kv_heads)` sorted by arrival. Built-in synthetic traces cover
//! the common study shapes (a mixed staggered-arrival stream, an all-at-
//! once burst with skewed output lengths for the static-vs-continuous
//! comparison); external traces load from a simple CSV.

/// One serving request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Index in the trace (stable id).
    pub id: usize,
    /// Arrival time in simulated cycles.
    pub arrival: u64,
    /// Prompt (prefill) length in tokens.
    pub prompt: u64,
    /// Output tokens to generate (>= 1; the first is produced by the last
    /// prefill step).
    pub output: u64,
    /// K/V heads of the request's model configuration (GQA/MQA).
    pub kv_heads: u64,
}

/// A request trace, sorted by arrival time.
#[derive(Debug, Clone)]
pub struct RequestTrace {
    /// Every request, sorted by arrival.
    pub requests: Vec<Request>,
}

impl RequestTrace {
    /// Build from `(arrival, prompt, output)` rows with a uniform
    /// `kv_heads`; validates and sorts.
    pub fn from_rows(rows: &[(u64, u64, u64)], kv_heads: u64) -> Self {
        let rows: Vec<(u64, u64, u64, u64)> =
            rows.iter().map(|&(a, p, o)| (a, p, o, kv_heads)).collect();
        Self::from_full_rows(&rows)
    }

    /// Build from `(arrival, prompt, output, kv_heads)` rows. Panics on
    /// invalid rows; library callers with untrusted input should prefer
    /// [`RequestTrace::try_from_full_rows`].
    pub fn from_full_rows(rows: &[(u64, u64, u64, u64)]) -> Self {
        match Self::try_from_full_rows(rows) {
            Ok(t) => t,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible variant of [`RequestTrace::from_full_rows`]: names the
    /// offending request and field instead of panicking.
    pub fn try_from_full_rows(rows: &[(u64, u64, u64, u64)]) -> Result<Self, String> {
        let mut requests: Vec<Request> = Vec::with_capacity(rows.len());
        for (id, &(arrival, prompt, output, kv_heads)) in rows.iter().enumerate() {
            for (field, value) in [("prompt", prompt), ("output", output), ("kv_heads", kv_heads)] {
                if value == 0 {
                    return Err(format!("request {id}: field '{field}' must be >= 1"));
                }
            }
            requests.push(Request { id, arrival, prompt, output, kv_heads });
        }
        requests.sort_by_key(|r| (r.arrival, r.id));
        Ok(Self { requests })
    }

    /// Built-in synthetic traces. `kv_heads` fills the per-request model
    /// configuration (must divide the scheduler's query-head count).
    ///
    /// * `builtin` / `mixed` — 12 requests with staggered arrivals, mixed
    ///   prompt lengths and skewed output lengths: exercises chunked
    ///   prefill riding alongside in-flight decodes.
    /// * `burst` — 8 requests arriving at once with outputs alternating
    ///   8/64: the shape where continuous batching beats static batching
    ///   (short requests free their slot while long ones keep decoding).
    pub fn builtin(name: &str, kv_heads: u64) -> Option<Self> {
        let rows: &[(u64, u64, u64)] = match name {
            "builtin" | "mixed" => &[
                (0, 384, 24),
                (0, 768, 48),
                (10_000, 256, 8),
                (40_000, 1024, 64),
                (80_000, 512, 16),
                (120_000, 640, 32),
                (200_000, 128, 96),
                (220_000, 896, 12),
                (300_000, 512, 40),
                (340_000, 256, 24),
                (400_000, 768, 8),
                (420_000, 384, 56),
            ],
            "burst" => &[
                (0, 512, 8),
                (0, 512, 64),
                (0, 512, 8),
                (0, 512, 64),
                (0, 512, 8),
                (0, 512, 64),
                (0, 512, 8),
                (0, 512, 64),
            ],
            _ => return None,
        };
        Some(Self::from_rows(rows, kv_heads))
    }

    /// Parse a CSV trace: one request per line as
    /// `arrival,prompt,output[,kv_heads]`; blank lines and `#` comments
    /// are skipped. `default_kv_heads` fills the optional column.
    ///
    /// Errors carry the 1-based line number and the CSV field name
    /// (`arrival` / `prompt` / `output` / `kv_heads`) so a bad row in a
    /// thousand-line trace is findable without bisection.
    pub fn parse(text: &str, default_kv_heads: u64) -> Result<Self, String> {
        const COLUMNS: [&str; 4] = ["arrival", "prompt", "output", "kv_heads"];
        let mut rows: Vec<(u64, u64, u64, u64)> = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let lineno = lineno + 1;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split(',').map(|f| f.trim()).collect();
            if fields.len() < 3 {
                let missing = COLUMNS[fields.len()];
                return Err(format!(
                    "line {lineno}: missing column '{missing}': expected \
                     'arrival,prompt,output[,kv_heads]', got '{line}'"
                ));
            }
            if fields.len() > 4 {
                return Err(format!(
                    "line {lineno}: {} columns is too many: expected \
                     'arrival,prompt,output[,kv_heads]', got '{line}'",
                    fields.len()
                ));
            }
            let mut nums = [0u64; 4];
            nums[3] = default_kv_heads;
            for (k, f) in fields.iter().enumerate() {
                nums[k] = f.parse().map_err(|_| {
                    format!(
                        "line {lineno}: field '{}': expected a non-negative integer, got '{f}'",
                        COLUMNS[k]
                    )
                })?;
            }
            for k in 1..4 {
                if nums[k] == 0 {
                    return Err(format!(
                        "line {lineno}: field '{}': must be >= 1, got 0",
                        COLUMNS[k]
                    ));
                }
            }
            rows.push((nums[0], nums[1], nums[2], nums[3]));
        }
        if rows.is_empty() {
            return Err("trace holds no requests".into());
        }
        Self::try_from_full_rows(&rows)
    }

    /// Total output tokens the trace will generate.
    pub fn total_output_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.output).sum()
    }

    /// Deterministic synthetic stream of `n` requests for scale testing
    /// (§Incremental in `crate::scheduler`): shapes cycle through a small
    /// `(prompt, output, kv_heads)` palette — recurring shapes are what a
    /// production stream looks like, and exactly what the step composer's
    /// solo memo feeds on — with arrivals staggered `gap` cycles apart.
    /// Every palette `kv_heads` divides 4 (and hence any larger
    /// power-of-two head count), so the default model configs accept it.
    pub fn synthetic(n: usize, gap: u64) -> Self {
        const PALETTE: [(u64, u64, u64); 6] = [
            (384, 6, 2),
            (768, 8, 4),
            (256, 4, 1),
            (512, 6, 2),
            (640, 8, 4),
            (128, 12, 1),
        ];
        let mut requests = Vec::with_capacity(n);
        for id in 0..n {
            let (prompt, output, kv_heads) = PALETTE[id % PALETTE.len()];
            requests.push(Request { id, arrival: id as u64 * gap, prompt, output, kv_heads });
        }
        Self { requests }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_traces_sorted_and_valid() {
        for name in ["builtin", "mixed", "burst"] {
            let t = RequestTrace::builtin(name, 8).expect(name);
            assert!(!t.requests.is_empty());
            assert!(t.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
            assert!(t.requests.iter().all(|r| r.kv_heads == 8));
            assert!(t.total_output_tokens() > 0);
        }
        assert!(RequestTrace::builtin("nope", 8).is_none());
    }

    #[test]
    fn parse_csv_with_defaults_comments_and_sorting() {
        let text = "# arrival,prompt,output[,kv_heads]\n\n40,128,4\n0,256,8,2\n";
        let t = RequestTrace::parse(text, 8).unwrap();
        assert_eq!(t.requests.len(), 2);
        // Sorted by arrival: the 0-cycle request first.
        assert_eq!(t.requests[0].arrival, 0);
        assert_eq!(t.requests[0].kv_heads, 2);
        assert_eq!(t.requests[1].kv_heads, 8); // default filled in
        assert_eq!(t.requests[1].prompt, 128);
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(RequestTrace::parse("1,2\n", 8).is_err());
        assert!(RequestTrace::parse("a,2,3\n", 8).is_err());
        assert!(RequestTrace::parse("1,0,3\n", 8).is_err());
        assert!(RequestTrace::parse("# only a comment\n", 8).is_err());
    }

    #[test]
    fn parse_errors_name_the_line_and_field() {
        // Missing column: names the first absent column.
        let e = RequestTrace::parse("0,128,4\n40,256\n", 8).unwrap_err();
        assert!(e.contains("line 2"), "{e}");
        assert!(e.contains("missing column 'output'"), "{e}");

        // Non-numeric arrival: names the field and echoes the token.
        let e = RequestTrace::parse("soon,128,4\n", 8).unwrap_err();
        assert!(e.contains("line 1"), "{e}");
        assert!(e.contains("field 'arrival'"), "{e}");
        assert!(e.contains("'soon'"), "{e}");

        // Zero output tokens: names the field, counts comment lines.
        let e = RequestTrace::parse("# header\n0,128,0\n", 8).unwrap_err();
        assert!(e.contains("line 2"), "{e}");
        assert!(e.contains("field 'output'"), "{e}");

        // Zero prompt and bad kv_heads column.
        let e = RequestTrace::parse("0,0,4\n", 8).unwrap_err();
        assert!(e.contains("field 'prompt'"), "{e}");
        let e = RequestTrace::parse("0,128,4,zero\n", 8).unwrap_err();
        assert!(e.contains("field 'kv_heads'"), "{e}");
        let e = RequestTrace::parse("0,128,4,0\n", 8).unwrap_err();
        assert!(e.contains("field 'kv_heads'"), "{e}");

        // Too many columns.
        let e = RequestTrace::parse("0,128,4,8,9\n", 8).unwrap_err();
        assert!(e.contains("too many"), "{e}");
    }

    #[test]
    fn synthetic_traces_are_deterministic_valid_and_recurrent() {
        let t = RequestTrace::synthetic(1000, 64);
        assert_eq!(t.requests.len(), 1000);
        assert!(t.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(t.requests.iter().all(|r| r.prompt > 0 && r.output > 0 && r.kv_heads > 0));
        assert_eq!(t.requests, RequestTrace::synthetic(1000, 64).requests);
        // Shapes recur with the palette period — the §Incremental solo
        // memo depends on a bounded shape set.
        assert_eq!(t.requests[0].prompt, t.requests[6].prompt);
        assert_eq!(t.requests[1].kv_heads, t.requests[7].kv_heads);
    }

    #[test]
    fn try_from_full_rows_names_the_request_and_field() {
        let e = RequestTrace::try_from_full_rows(&[(0, 128, 4, 8), (5, 128, 0, 8)]).unwrap_err();
        assert!(e.contains("request 1"), "{e}");
        assert!(e.contains("field 'output'"), "{e}");
        assert!(RequestTrace::try_from_full_rows(&[(0, 128, 4, 8)]).is_ok());
    }
}
