//! Serving scheduler: continuous batching of mixed prefill+decode request
//! streams with paged-KV channel placement.
//!
//! The paper (and PRs 1–3) evaluate *isolated* attention kernels; a
//! serving system sees a **stream of requests** instead. This subsystem
//! turns a [`trace::RequestTrace`] into a sequence of simulated batch
//! programs and serving metrics (tokens/s, TTFT, TPOT, batch occupancy),
//! converting the kernel simulator into a serving simulator. Design:
//!
//! # Admission and chunking
//!
//! The scheduler owns `slots` request slots, each mapped to a horizontal
//! band of `mesh_y / slots` tile rows. Arrived requests are admitted FCFS
//! into free slots (continuous batching; the `Static` policy instead
//! waits for the whole batch to drain — the classic baseline continuous
//! batching was invented to beat). Each step composes ONE program
//! ([`batch::compose`]) holding, per in-flight request, either the next
//! `chunk`-token **prefill chunk** (`Workload` with `kv_prefix` = tokens
//! already prefilled, causal — chunked prefill is exactly the rectangular
//! decode geometry PR 3 built, with the query span mid-cache instead of a
//! single end row) or one **decode row** over the request's full cache.
//! The DES executes the composed program; the virtual clock advances by
//! its makespan (iteration-level scheduling à la vLLM/Orca: a step is a
//! barrier, so a decode step stretches to the slowest co-scheduled chunk
//! — the honest cost of mixing prefill into decode batches, visible in
//! the TPOT metric).
//!
//! # Paged-KV placement
//!
//! Each request's KV cache grows page by page ([`crate::hbm::PageMap`],
//! `page_tokens` per page) and every page is pinned to an HBM channel at
//! allocation by the [`PagePlacement`] policy:
//!
//! * [`PagePlacement::ChannelAffine`] — pages stay on the slot's own
//!   partition of the south channels: maximal locality, zero cross-
//!   request interference (and the policy under which composition is
//!   exactly conservative — see below), but a single request can only
//!   ever draw its partition's bandwidth.
//! * [`PagePlacement::RoundRobin`] — pages stripe every channel in
//!   global allocation order: each request reads at full-chip bandwidth
//!   but fragments across everyone else's channels.
//! * [`PagePlacement::Random`] — seeded uniform placement, the
//!   fragmentation worst case.
//!
//! Because the dataflow builders emit paged K/V transfers on the page's
//! *actual* channel, placement differences show up as real FIFO channel
//! contention in the DES, not as an analytic penalty — on a narrow-HBM
//! architecture the three policies produce measurably different
//! makespans (`tests/scheduler_integration.rs`).
//!
//! # Why fold exactness carries over per request
//!
//! Composition shares HBM channels but gives each request private tile
//! bands, so every argument in the PR-2 fold essay localizes: within one
//! request's band the non-representative streams' private chains still
//! never resource-block (the band's engines serve only that request), and
//! the band's first tile/group is that request's representative stream.
//! Folded and unfolded *batch* programs therefore execute bit-identically
//! (`tests/fold_differential.rs` mixed-batch axis). Batch entries are
//! template-stamped like solo programs: the stamp cache patches each K/V
//! transfer's channel per page segment, so a paged entry is a
//! table-driven re-point of a cached skeleton, not a fresh emission
//! (pinned against naive emission by `batch::tests`). The same locality
//! gives the conservation property the tests pin: with per-slot-disjoint
//! channels (wide HBM + channel-affine pages), a request's op timeline in
//! a mixed batch is bit-identical to composing it alone.
//!
//! # Incremental composition (§Incremental)
//!
//! Replaying a trace used to rebuild, reseal and fully re-simulate the
//! batch program every step — step cost linear in total in-flight ops,
//! fatal at the million-request scale the ROADMAP targets. The
//! [`incremental::StepComposer`] keeps the previous step's *sealed*
//! program alive and cost-patches it in place whenever the op structure
//! is unchanged (the steady-decode common case), reusing the PR-5 shard
//! CSR and the dependents CSR verbatim instead of re-deriving them; and
//! when the entries' channel masks are pairwise disjoint it skips batch
//! execution entirely, merging memoized per-request *solo* runs — exact
//! by the conservation property above. Both levers are config knobs
//! ([`SchedulerConfig::incremental`] / [`SchedulerConfig::memoize`],
//! default on), faulted steps always run the real batch, and
//! `tests/incremental_differential.rs` pins every mode against the
//! full-rebuild path bit for bit, reports compared field by field.
//!
//! # Graceful-degradation router (§Router)
//!
//! [`router::route`] wraps the same composition/execution step in a
//! request-*lifecycle* layer — the part of a serving stack that decides
//! *whether* work runs, not just where:
//!
//! * **Admission** — a token budget (`max_batch_total_tokens`, the
//!   TGI-style cap on Σ prompt+output across the batch) and a page budget
//!   (`max_total_pages`) gate the waiting queue. With preemption off, the
//!   page budget is enforced by *reservation*: a request is admitted only
//!   if its maximal KV footprint fits alongside every in-flight
//!   reservation, so pressure can never materialize mid-flight. With
//!   preemption on, admission is optimistic (current footprints) and
//!   pressure is resolved by eviction — the throughput/latency trade the
//!   `report robustness` figure measures. An idle machine always admits
//!   the front waiter, so no budget setting can deadlock the router.
//! * **Preemption** — under page pressure a victim
//!   ([`router::VictimPolicy`]: newest / fewest-pages / most-remaining)
//!   is evicted: its pages are freed ([`crate::hbm::PageMap::reset`]) and
//!   it re-queues with `rebuild_to = prompt + generated`. Rebuilding is
//!   re-emitted as *real chunked-prefill traffic* over the tokens the
//!   request had already processed — not a free reset. This is
//!   deliberately **conservative** (an upper bound on recovery cost):
//!   real stacks snapshot/restore or recompute selectively, and anything
//!   they do is at most the full recompute we charge, so degradation
//!   numbers derived from it can only be pessimistic, never flattering.
//!   Already-delivered tokens stay delivered (they left the server);
//!   rebuilt prefill produces no new output until the cache again covers
//!   `rebuild_to`.
//! * **TTFT is per-attempt** — every requeue (band eviction, deadline
//!   retry, preemption) clears the request's first-token mark, and the
//!   next token it actually delivers re-arms it. TTFT therefore measures
//!   arrival → first token delivered *after the last disruption*: the
//!   service the client experienced once the stream finally flowed, not
//!   a stale pre-eviction timestamp
//!   (`router::tests::requeued_requests_restart_ttft_per_attempt`).
//! * **Deadlines** — `deadline` cycles per attempt: an in-flight or
//!   waiting request that exceeds it is retried (bounded by
//!   `max_retries`, eviction semantics as above) and finally *expired* —
//!   dropped with its slot and pages reclaimed. Expired requests are
//!   excluded from latency percentiles and goodput (they produced no
//!   service), but counted in the router report.
//! * **Fault-aware band remapping** — the step program executes under the
//!   session [`crate::sim::FaultPlan`] shifted to the step's clock. A
//!   tile death kills its band's ops mid-step (`affected_entries` on the
//!   [`batch::BatchProgram`] names the entries that made no progress); those
//!   requests requeue *keeping pages and progress* — the KV cache lives
//!   in HBM, only the compute band died — and the dead band leaves the
//!   usable-slot set, shrinking the machine. When every band is dead the
//!   remaining requests expire instead of spinning.
//!
//! Termination: every step either advances at least one request's state,
//! frees a slot, consumes a retry, or shrinks the usable-band set — all
//! monotone — and expiry bounds each request's attempts, so `route`
//! always terminates even under total-failure plans.

pub mod batch;
pub mod incremental;
pub mod router;
pub mod trace;

pub use batch::{compose, BatchEntry, BatchProgram, EntryStats};
pub use incremental::StepComposer;
pub use router::{route, try_route, try_route_with, RouterConfig, RouterReport, VictimPolicy};
pub use trace::{Request, RequestTrace};

use crate::arch::ArchConfig;
use crate::dataflow::{Dataflow, Workload};
use crate::hbm::PageMap;
use crate::sim::Cycle;
use crate::telemetry::{RunTelemetry, StepObs};
use crate::util::Rng;

/// KV-cache page → HBM-channel placement policy (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PagePlacement {
    RoundRobin,
    ChannelAffine,
    Random,
}

pub const ALL_PLACEMENTS: [PagePlacement; 3] =
    [PagePlacement::RoundRobin, PagePlacement::ChannelAffine, PagePlacement::Random];

impl PagePlacement {
    pub fn label(self) -> &'static str {
        match self {
            PagePlacement::RoundRobin => "round-robin",
            PagePlacement::ChannelAffine => "affine",
            PagePlacement::Random => "random",
        }
    }

    pub fn from_label(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "roundrobin" | "rr" => Some(PagePlacement::RoundRobin),
            "affine" | "channel-affine" => Some(PagePlacement::ChannelAffine),
            "random" | "rand" => Some(PagePlacement::Random),
            _ => None,
        }
    }
}

/// Batching policy: continuous (admit into any free slot every step) or
/// static (admit a batch, run it to completion, then admit the next).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicy {
    Continuous,
    Static,
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub dataflow: Dataflow,
    /// FlatAttention group edge (must divide the slot band).
    pub group: usize,
    /// Concurrent request slots (= tile-row bands).
    pub slots: usize,
    /// Prefill chunk length in tokens.
    pub chunk: u64,
    /// KV page size in tokens.
    pub page_tokens: u64,
    pub placement: PagePlacement,
    pub policy: BatchPolicy,
    /// Model configuration: query heads and head dimension (per-request
    /// `kv_heads` comes from the trace).
    pub heads: u64,
    pub head_dim: u64,
    /// Sliding-window extent (0 = unlimited).
    pub window: u64,
    /// Seed for [`PagePlacement::Random`].
    pub seed: u64,
    /// DES workers per composed batch program
    /// ([`crate::sim::execute_parallel`]; each request band is a natural
    /// shard set). Every count produces bit-identical reports — this is a
    /// wall-clock knob only. Default 1 (serial).
    pub threads: usize,
    /// TTFT service-level objective (ms) for goodput accounting: a
    /// request contributes to goodput only if its TTFT and TPOT both meet
    /// their SLOs.
    pub slo_ttft_ms: f64,
    /// TPOT service-level objective (ms) for goodput accounting.
    pub slo_tpot_ms: f64,
    /// §Incremental: keep the previous step's sealed program and
    /// cost-patch it in place when the op structure is unchanged,
    /// resealing only on structural change. Bit-identical to the
    /// full-rebuild path (`tests/incremental_differential.rs`).
    pub incremental: bool,
    /// §Incremental: serve channel-disjoint fault-free steps by merging
    /// memoized per-request solo runs instead of executing the batch
    /// DES. Bit-identical by the conservative-composition property.
    pub memoize: bool,
}

impl SchedulerConfig {
    pub fn new(dataflow: Dataflow) -> Self {
        Self {
            dataflow,
            group: 8,
            slots: 4,
            chunk: 512,
            page_tokens: 64,
            placement: PagePlacement::ChannelAffine,
            policy: BatchPolicy::Continuous,
            heads: 32,
            head_dim: 128,
            window: 0,
            seed: 0x5EED,
            threads: 1,
            slo_ttft_ms: 2.0,
            slo_tpot_ms: 0.1,
            incremental: true,
            memoize: true,
        }
    }
}

/// Per-request serving metrics (cycles are absolute virtual-clock times).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestMetrics {
    pub id: usize,
    pub arrival: Cycle,
    /// Clock at the end of the step that produced the first output token.
    pub first_token: Cycle,
    /// Clock at the end of the step that produced the last output token.
    pub finish: Cycle,
    pub prompt: u64,
    pub output: u64,
}

/// Aggregate serving metrics of one trace replay.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingReport {
    pub total_cycles: Cycle,
    pub steps: usize,
    pub tokens: u64,
    pub tokens_per_s: f64,
    /// Mean time-to-first-token over all requests (ms).
    pub ttft_mean_ms: f64,
    /// Mean time-per-output-token over requests with more than one output
    /// token (ms).
    pub tpot_mean_ms: f64,
    /// TTFT tail percentiles (nearest-rank, ms).
    pub ttft_p50_ms: f64,
    pub ttft_p95_ms: f64,
    pub ttft_p99_ms: f64,
    /// TPOT tail percentiles (nearest-rank, ms; over requests with more
    /// than one output token).
    pub tpot_p50_ms: f64,
    pub tpot_p95_ms: f64,
    pub tpot_p99_ms: f64,
    /// Output tokens of requests meeting both SLOs
    /// ([`SchedulerConfig::slo_ttft_ms`] / [`SchedulerConfig::slo_tpot_ms`])
    /// per second — the goodput-under-SLO serving headline.
    pub goodput_tokens_per_s: f64,
    /// Mean fraction of slots occupied, weighted by step makespan.
    pub occupancy: f64,
    pub hbm_bytes: u64,
    pub requests: Vec<RequestMetrics>,
    /// Compact JSON of the run's deterministic telemetry snapshot
    /// ([`crate::telemetry::RunTelemetry::snapshot_json`]), present when
    /// the run was invoked through [`try_simulate_with`] /
    /// [`router::try_route_with`] with a sink attached. Deterministic
    /// content only, so reports stay comparable across thread counts and
    /// composer modes.
    pub telemetry: Option<String>,
}

/// Nearest-rank percentile of an ascending-sorted sample (`q` in
/// `[0, 100]`); 0 for an empty sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Aggregate per-request metrics into a [`ServingReport`]. Shared by
/// [`simulate`] and [`router::route`] so means, tail percentiles and
/// goodput are computed one way; `requests` holds *completed* requests
/// only (the router excludes expired ones — they produced no service).
#[allow(clippy::too_many_arguments)]
pub(crate) fn finish_report(
    arch: &ArchConfig,
    cfg: &SchedulerConfig,
    clock: Cycle,
    steps: usize,
    tokens: u64,
    hbm_bytes: u64,
    occupancy: f64,
    requests: Vec<RequestMetrics>,
) -> ServingReport {
    let to_ms = |cycles: f64| cycles / (arch.freq_ghz * 1e6);
    let ttft_of = |r: &RequestMetrics| to_ms((r.first_token - r.arrival) as f64);
    let tpot_of =
        |r: &RequestMetrics| to_ms((r.finish - r.first_token) as f64) / (r.output - 1) as f64;
    let mut ttfts: Vec<f64> = requests.iter().map(ttft_of).collect();
    let mut tpots: Vec<f64> = requests.iter().filter(|r| r.output > 1).map(tpot_of).collect();
    ttfts.sort_by(f64::total_cmp);
    tpots.sort_by(f64::total_cmp);
    let mean = |v: &[f64]| if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 };
    let secs = clock as f64 / (arch.freq_ghz * 1e9);
    let good_tokens: u64 = requests
        .iter()
        .filter(|r| {
            let tpot = if r.output > 1 { tpot_of(r) } else { 0.0 };
            ttft_of(r) <= cfg.slo_ttft_ms && tpot <= cfg.slo_tpot_ms
        })
        .map(|r| r.output)
        .sum();
    let per_s = |t: u64| if secs > 0.0 { t as f64 / secs } else { 0.0 };
    ServingReport {
        total_cycles: clock,
        steps,
        tokens,
        tokens_per_s: per_s(tokens),
        ttft_mean_ms: mean(&ttfts),
        tpot_mean_ms: mean(&tpots),
        ttft_p50_ms: percentile(&ttfts, 50.0),
        ttft_p95_ms: percentile(&ttfts, 95.0),
        ttft_p99_ms: percentile(&ttfts, 99.0),
        tpot_p50_ms: percentile(&tpots, 50.0),
        tpot_p95_ms: percentile(&tpots, 95.0),
        tpot_p99_ms: percentile(&tpots, 99.0),
        goodput_tokens_per_s: per_s(good_tokens),
        occupancy,
        hbm_bytes,
        requests,
        telemetry: None,
    }
}

/// Fold the composer's mode-dependent counters (`engine_` section) and its
/// profiler, if any, into the telemetry sink at the end of a run. Shared by
/// [`simulate`] and [`router::route`].
pub(crate) fn absorb_composer(tel: &mut RunTelemetry, composer: &StepComposer) {
    let m = &mut tel.metrics;
    m.set_counter("engine_steps_patched", composer.patched_steps() as u64);
    m.set_counter("engine_steps_resealed", composer.resealed_steps() as u64);
    m.set_counter("engine_steps_memoized", composer.memo_steps() as u64);
    m.set_counter("engine_solo_memo_hits", composer.memo_hits() as u64);
    m.set_counter("engine_solo_memo_misses", composer.memo_misses() as u64);
    if let Some(p) = composer.profiler() {
        tel.merge_profile(p);
    }
}

struct ReqState {
    prefill_done: u64,
    generated: u64,
    first_token: Option<Cycle>,
    finish: Option<Cycle>,
    pages: PageMap,
}

/// The per-slot affine channel range `(base, count)`: the slot's
/// partition of the south channels (K/V's natural edge), falling back to
/// partitioning the full channel set when the south edge is too narrow.
fn affine_range(arch: &ArchConfig, slot: usize, slots: usize) -> (u32, u32) {
    let cw = arch.hbm.channels_west as u32;
    let cs = arch.hbm.channels_south as u32;
    let (slot, slots) = (slot as u32, slots as u32);
    if cs >= slots {
        let per = cs / slots;
        (cw + slot * per, per)
    } else {
        let total = cw + cs;
        if total >= slots {
            let per = total / slots;
            (slot * per, per)
        } else {
            (slot % total, 1)
        }
    }
}

/// Structured rejection of an impossible `(arch, trace, cfg)`
/// combination. [`try_simulate`] / [`try_route`] return these instead of
/// panicking so the `schedule` CLI can print one clean diagnostic and
/// exit 1; the panicking wrappers [`simulate`] / [`router::route`] remain
/// for callers that treat a bad config as a programming error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleError {
    /// Slot/group geometry incompatible with the mesh or dataflow
    /// (from [`batch::validate_slots`]).
    BadGeometry(String),
    /// `chunk == 0`: a prefill chunk must carry at least one token.
    ZeroChunk,
    /// A trace request's `kv_heads` does not divide the model's query
    /// heads (GQA requires an integer group size).
    BadKvHeads { request: usize, kv_heads: u64, heads: u64 },
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::BadGeometry(msg) => f.write_str(msg),
            ScheduleError::ZeroChunk => f.write_str("prefill chunk must be >= 1 token"),
            ScheduleError::BadKvHeads { request, kv_heads, heads } => write!(
                f,
                "request {request}: kv_heads {kv_heads} must divide the model's \
                 {heads} query heads"
            ),
        }
    }
}

impl std::error::Error for ScheduleError {}

/// Shared `(arch, trace, cfg)` validation behind [`try_simulate`] and
/// [`try_route`]. Every rejection path is pinned by `mod tests` below.
pub(crate) fn validate_config(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
) -> Result<(), ScheduleError> {
    batch::validate_slots(arch, cfg.slots, cfg.group, cfg.dataflow)
        .map_err(ScheduleError::BadGeometry)?;
    if cfg.chunk == 0 {
        return Err(ScheduleError::ZeroChunk);
    }
    for r in &trace.requests {
        if r.kv_heads == 0 || r.kv_heads > cfg.heads || cfg.heads % r.kv_heads != 0 {
            return Err(ScheduleError::BadKvHeads {
                request: r.id,
                kv_heads: r.kv_heads,
                heads: cfg.heads,
            });
        }
    }
    Ok(())
}

/// Replay a request trace through the scheduler and report serving
/// metrics, rejecting impossible configurations up front. Deterministic
/// for a given `(arch, trace, cfg)`.
pub fn try_simulate(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
) -> Result<ServingReport, ScheduleError> {
    try_simulate_with(arch, trace, cfg, None)
}

/// Like [`try_simulate`], optionally attaching a telemetry sink: with
/// `Some`, the run streams lifecycle events and windowed metrics into it
/// and embeds the deterministic snapshot in [`ServingReport::telemetry`];
/// with `None`, no telemetry work happens at all.
pub fn try_simulate_with(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
    tel: Option<&mut RunTelemetry>,
) -> Result<ServingReport, ScheduleError> {
    validate_config(arch, trace, cfg)?;
    Ok(simulate_validated(arch, trace, cfg, tel))
}

/// Panicking wrapper of [`try_simulate`] for callers that treat an
/// invalid configuration as a programming error.
pub fn simulate(arch: &ArchConfig, trace: &RequestTrace, cfg: &SchedulerConfig) -> ServingReport {
    try_simulate(arch, trace, cfg).unwrap_or_else(|e| panic!("scheduler: {e}"))
}

fn simulate_validated(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
    mut tel: Option<&mut RunTelemetry>,
) -> ServingReport {
    let n = trace.requests.len();
    let n_chan = arch.hbm.total_channels() as u64;
    let mut states: Vec<ReqState> = (0..n)
        .map(|_| ReqState {
            prefill_done: 0,
            generated: 0,
            first_token: None,
            finish: None,
            pages: PageMap::new(cfg.page_tokens),
        })
        .collect();
    let mut slots: Vec<Option<usize>> = vec![None; cfg.slots];
    let mut next_arrival = 0usize;
    let mut clock: Cycle = 0;
    let mut steps = 0usize;
    let mut tokens = 0u64;
    let mut hbm_bytes = 0u64;
    let mut busy_slot_cycles = 0u128;
    let mut total_slot_cycles = 0u128;
    let mut rr_next = 0u64;
    let mut rng = Rng::new(cfg.seed);
    let mut composer = StepComposer::new(cfg);
    if let Some(t) = tel.as_deref_mut() {
        composer.enable_probe(n_chan as usize, cfg.slots);
        if t.profile.is_some() {
            composer.enable_profiling();
        }
    }
    // Step scratch hoisted out of the loop (§Incremental): a
    // million-request replay must not pay a round of Vec reallocation
    // per step. `entries` alone stays per-step — it borrows `states`.
    let mut active: Vec<(usize, usize)> = Vec::new();
    let mut metas: Vec<(usize, usize, bool, u64)> = Vec::new();
    let mut workloads: Vec<Workload> = Vec::new();

    loop {
        // Admission: continuous fills any free slot; static only admits
        // into an idle machine.
        let all_free = slots.iter().all(|s| s.is_none());
        if cfg.policy == BatchPolicy::Continuous || all_free {
            for (si, slot) in slots.iter_mut().enumerate() {
                if slot.is_none()
                    && next_arrival < n
                    && trace.requests[next_arrival].arrival <= clock
                {
                    *slot = Some(next_arrival);
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_queued(next_arrival, trace.requests[next_arrival].arrival);
                        t.on_admitted(next_arrival, si, clock);
                    }
                    next_arrival += 1;
                }
            }
        }
        active.clear();
        active.extend(slots.iter().enumerate().filter_map(|(s, r)| r.map(|ri| (s, ri))));
        if active.is_empty() {
            if next_arrival >= n {
                break;
            }
            // Idle: jump to the next arrival.
            clock = clock.max(trace.requests[next_arrival].arrival);
            continue;
        }

        // Build each active request's step workload and grow its pages.
        metas.clear();
        workloads.clear();
        for &(slot, ri) in &active {
            let req = &trace.requests[ri];
            let st = &mut states[ri];
            let (wl_is_prefill, len, wl) = if st.prefill_done < req.prompt {
                let len = cfg.chunk.min(req.prompt - st.prefill_done);
                let mut wl = Workload::new(len, cfg.head_dim, cfg.heads, 1)
                    .with_kv_heads(req.kv_heads)
                    .with_causal(true)
                    .with_kv_prefix(st.prefill_done);
                if cfg.window > 0 {
                    wl = wl.with_window(cfg.window);
                }
                (true, len, wl)
            } else {
                let cache = req.prompt + st.generated;
                let mut wl = Workload::new(cache, cfg.head_dim, cfg.heads, 1)
                    .with_kv_heads(req.kv_heads)
                    .decode();
                if cfg.window > 0 {
                    wl = wl.with_window(cfg.window);
                }
                (false, 1, wl)
            };
            let placement = cfg.placement;
            let (base, count) = affine_range(arch, slot, cfg.slots);
            st.pages.grow_to(wl.kv_len(), |page| match placement {
                PagePlacement::RoundRobin => {
                    let c = (rr_next % n_chan) as u32;
                    rr_next += 1;
                    c
                }
                PagePlacement::ChannelAffine => base + (page % count as u64) as u32,
                PagePlacement::Random => rng.gen_range(n_chan) as u32,
            });
            metas.push((slot, ri, wl_is_prefill, len));
            workloads.push(wl);
        }

        // Compose and execute this step's batch program.
        let stats = {
            let entries: Vec<BatchEntry<'_>> = metas
                .iter()
                .zip(&workloads)
                .map(|(&(slot, ri, _, _), wl)| BatchEntry {
                    request: ri,
                    slot,
                    workload: *wl,
                    pages: &states[ri].pages,
                })
                .collect();
            composer.run_step(arch, cfg, &entries)
        };
        debug_assert!(stats.makespan > 0, "a non-empty step must advance the clock");
        let step_start = clock;
        clock = clock.checked_add(stats.makespan).expect("virtual clock overflowed u64 cycles");
        steps += 1;
        hbm_bytes += stats.hbm_bytes;
        busy_slot_cycles += active.len() as u128 * stats.makespan as u128;
        total_slot_cycles += cfg.slots as u128 * stats.makespan as u128;
        if let Some(t) = tel.as_deref_mut() {
            let queue_depth = trace.requests[next_arrival..]
                .partition_point(|r| r.arrival <= clock) as u64;
            let pages_in_use: u64 =
                active.iter().map(|&(_, ri)| states[ri].pages.num_pages() as u64).sum();
            t.record_step(&StepObs {
                index: (steps - 1) as u64,
                start: step_start,
                end: clock,
                stats: &stats,
                entries: &metas,
                queue_depth,
                pages_in_use,
                slots: cfg.slots as u64,
                probe: composer.probe(),
            });
        }

        // Advance request states at the step barrier.
        for &(slot, ri, is_prefill, len) in &metas {
            let req = &trace.requests[ri];
            let st = &mut states[ri];
            if is_prefill {
                st.prefill_done += len;
                if st.prefill_done == req.prompt {
                    // The last prefill step samples the first output token.
                    st.first_token = Some(clock);
                    st.generated = 1;
                    tokens += 1;
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_token();
                        t.on_first_token(ri, clock);
                    }
                }
            } else {
                st.generated += 1;
                tokens += 1;
                if let Some(t) = tel.as_deref_mut() {
                    t.on_token();
                }
            }
            if st.generated >= req.output {
                st.finish = Some(clock);
                if let Some(t) = tel.as_deref_mut() {
                    let first = st.first_token.expect("finished request saw a first token");
                    t.on_completed(ri, clock, req.arrival, first, req.output);
                }
                // Retired for good: free the page table's allocation so a
                // long trace holds page state for in-flight requests only.
                st.pages.release();
                slots[slot] = None;
            }
        }
    }

    // Aggregate metrics.
    let requests: Vec<RequestMetrics> = trace
        .requests
        .iter()
        .enumerate()
        .map(|(ri, req)| {
            let st = &states[ri];
            RequestMetrics {
                id: req.id,
                arrival: req.arrival,
                first_token: st.first_token.expect("request finished prefill"),
                finish: st.finish.expect("request finished"),
                prompt: req.prompt,
                output: req.output,
            }
        })
        .collect();
    let occupancy = if total_slot_cycles > 0 {
        busy_slot_cycles as f64 / total_slot_cycles as f64
    } else {
        0.0
    };
    let mut report =
        finish_report(arch, cfg, clock, steps, tokens, hbm_bytes, occupancy, requests);
    if let Some(t) = tel {
        t.finish_run(clock);
        absorb_composer(t, &composer);
        report.telemetry = Some(t.snapshot_json().to_string());
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    fn cfg4(df: Dataflow) -> SchedulerConfig {
        let mut cfg = SchedulerConfig::new(df);
        cfg.slots = 4;
        cfg.group = 2;
        cfg.heads = 4;
        cfg.head_dim = 64;
        cfg
    }

    fn one_request() -> RequestTrace {
        RequestTrace::from_rows(&[(0, 64, 2)], 2)
    }

    #[test]
    fn bad_slot_count_is_a_structured_error() {
        let arch = presets::table2(8);
        let mut cfg = cfg4(Dataflow::Flash2);
        cfg.slots = 3; // does not divide the 8-row mesh
        let err = try_simulate(&arch, &one_request(), &cfg).unwrap_err();
        assert!(matches!(err, ScheduleError::BadGeometry(_)), "{err:?}");
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn bad_group_edge_is_a_structured_error() {
        let arch = presets::table2(8);
        let mut cfg = cfg4(Dataflow::FlatColl);
        cfg.group = 3; // flat groups must divide the slot band edge
        let err = try_simulate(&arch, &one_request(), &cfg).unwrap_err();
        assert!(matches!(err, ScheduleError::BadGeometry(_)), "{err:?}");
    }

    #[test]
    fn zero_prefill_chunk_is_a_structured_error() {
        let arch = presets::table2(8);
        let mut cfg = cfg4(Dataflow::Flash2);
        cfg.chunk = 0;
        let err = try_simulate(&arch, &one_request(), &cfg).unwrap_err();
        assert_eq!(err, ScheduleError::ZeroChunk);
        assert_eq!(err.to_string(), "prefill chunk must be >= 1 token");
    }

    #[test]
    fn non_dividing_kv_heads_is_a_structured_error() {
        let arch = presets::table2(8);
        let cfg = cfg4(Dataflow::Flash2);
        let bad = RequestTrace::from_rows(&[(0, 64, 2), (0, 64, 2)], 3); // 3 ∤ 4
        let err = try_simulate(&arch, &bad, &cfg).unwrap_err();
        assert_eq!(err, ScheduleError::BadKvHeads { request: 0, kv_heads: 3, heads: 4 });
        assert_eq!(
            err.to_string(),
            "request 0: kv_heads 3 must divide the model's 4 query heads"
        );
    }

    #[test]
    #[should_panic(expected = "scheduler: prefill chunk must be >= 1 token")]
    fn panicking_wrapper_carries_the_same_message() {
        let arch = presets::table2(8);
        let mut cfg = cfg4(Dataflow::Flash2);
        cfg.chunk = 0;
        let _ = simulate(&arch, &one_request(), &cfg);
    }
}
