//! Serving scheduler: continuous batching of mixed prefill+decode request
//! streams with paged-KV channel placement.
//!
//! The paper (and PRs 1–3) evaluate *isolated* attention kernels; a
//! serving system sees a **stream of requests** instead. This subsystem
//! turns a [`trace::RequestTrace`] into a sequence of simulated batch
//! programs and serving metrics (tokens/s, TTFT, TPOT, batch occupancy),
//! converting the kernel simulator into a serving simulator.
//!
//! # Admission and chunking
//!
//! The scheduler owns `slots` request slots, each mapped to a horizontal
//! band of `mesh_y / slots` tile rows. Arrived requests are admitted
//! FCFS into free slots (continuous batching; the `Static` policy is the
//! drain-the-whole-batch baseline). Each step composes ONE program
//! ([`batch::compose`]) holding, per in-flight request, either the next
//! `chunk`-token prefill chunk or one decode row over the request's full
//! cache; the DES executes it and the virtual clock advances by its
//! makespan (iteration-level scheduling à la vLLM/Orca — a step is a
//! barrier, and the stretch from mixing prefill into decode batches is
//! visible in the TPOT metric).
//!
//! # Paged-KV placement
//!
//! Each request's KV cache grows page by page ([`crate::hbm::PageMap`],
//! `page_tokens` per page); every page is pinned to an HBM channel at
//! allocation by the [`PagePlacement`] policy (channel-affine /
//! round-robin / random). Builders emit paged K/V transfers on the
//! page's *actual* channel, so placement differences are real FIFO
//! contention in the DES, not an analytic penalty
//! (`tests/scheduler_integration.rs`).
//!
//! # Fold exactness and conservation
//!
//! Composition shares HBM channels but gives each request a private tile
//! band, so the fold-exactness argument localizes per request — folded
//! and unfolded batch programs execute bit-identically
//! (`tests/fold_differential.rs`, mixed-batch axis) — and with
//! per-slot-disjoint channels a request's in-batch op timeline is
//! bit-identical to composing it alone (the **conservation property**).
//! The full essay lives in `docs/ARCHITECTURE.md` §"Serving scheduler".
//!
//! # Incremental composition (§Incremental)
//!
//! [`incremental::StepComposer`] keeps the previous step's sealed
//! program alive and cost-patches it in place when the op structure is
//! unchanged, and merges memoized per-request solo runs when the
//! entries' channel masks are pairwise disjoint — both bit-identical to
//! the full-rebuild path (`tests/incremental_differential.rs`). Essay:
//! `docs/ARCHITECTURE.md` §"Incremental composition and memoized delta
//! re-simulation".
//!
//! # Graceful-degradation router (§Router)
//!
//! [`router::route`] wraps the step loop in a request-lifecycle layer:
//! token/page-budget admission (reservation-based without preemption,
//! optimistic with it), preemption with conservatively-charged
//! chunked-prefill rebuild, per-attempt TTFT, per-attempt deadlines with
//! bounded retries and expiry, and fault-aware band remapping that
//! shrinks the machine as bands die. Design rationale and the
//! termination argument: `docs/ARCHITECTURE.md` §"Graceful-degradation
//! router".

pub mod batch;
pub mod incremental;
pub mod router;
pub mod trace;

pub use batch::{compose, compose_layered, BatchEntry, BatchProgram, EntryStats, LayerParams};
pub use incremental::StepComposer;
pub use router::{route, try_route, try_route_with, RouterConfig, RouterReport, VictimPolicy};
pub use trace::{Request, RequestTrace};

use crate::arch::ArchConfig;
use crate::dataflow::{Dataflow, WeightResidency, Workload};
use crate::hbm::PageMap;
use crate::sim::Cycle;
use crate::telemetry::{RunTelemetry, StepObs};
use crate::util::Rng;

/// KV-cache page → HBM-channel placement policy (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PagePlacement {
    /// Pages dealt over channels in order.
    RoundRobin,
    /// Pages pinned to the channels nearest the request's band.
    ChannelAffine,
    /// Uniform pseudo-random placement (deterministic seed).
    Random,
}

/// Every placement policy, in report order.
pub const ALL_PLACEMENTS: [PagePlacement; 3] =
    [PagePlacement::RoundRobin, PagePlacement::ChannelAffine, PagePlacement::Random];

impl PagePlacement {
    /// Stable CLI/report name.
    pub fn label(self) -> &'static str {
        match self {
            PagePlacement::RoundRobin => "round-robin",
            PagePlacement::ChannelAffine => "affine",
            PagePlacement::Random => "random",
        }
    }

    /// Parse a (case-insensitive) label, e.g. from the CLI.
    pub fn from_label(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "roundrobin" | "rr" => Some(PagePlacement::RoundRobin),
            "affine" | "channel-affine" => Some(PagePlacement::ChannelAffine),
            "random" | "rand" => Some(PagePlacement::Random),
            _ => None,
        }
    }
}

/// Batching policy: continuous (admit into any free slot every step) or
/// static (admit a batch, run it to completion, then admit the next).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Admit into any free slot every step.
    Continuous,
    /// Run each admitted batch to completion before admitting more.
    Static,
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Attention dataflow of every step.
    pub dataflow: Dataflow,
    /// FlatAttention group edge (must divide the slot band).
    pub group: usize,
    /// Concurrent request slots (= tile-row bands).
    pub slots: usize,
    /// Prefill chunk length in tokens.
    pub chunk: u64,
    /// KV page size in tokens.
    pub page_tokens: u64,
    /// KV page -> channel placement policy.
    pub placement: PagePlacement,
    /// Batch admission policy.
    pub policy: BatchPolicy,
    /// Model configuration: query heads and head dimension (per-request
    /// `kv_heads` comes from the trace).
    pub heads: u64,
    /// Head dimension.
    pub head_dim: u64,
    /// Sliding-window extent (0 = unlimited).
    pub window: u64,
    /// Seed for [`PagePlacement::Random`].
    pub seed: u64,
    /// DES workers per composed batch program
    /// ([`crate::sim::execute_parallel`]; each request band is a natural
    /// shard set). Every count produces bit-identical reports — this is a
    /// wall-clock knob only. Default 1 (serial).
    pub threads: usize,
    /// TTFT service-level objective (ms) for goodput accounting: a
    /// request contributes to goodput only if its TTFT and TPOT both meet
    /// their SLOs.
    pub slo_ttft_ms: f64,
    /// TPOT service-level objective (ms) for goodput accounting.
    pub slo_tpot_ms: f64,
    /// §Incremental: keep the previous step's sealed program and
    /// cost-patch it in place when the op structure is unchanged,
    /// resealing only on structural change. Bit-identical to the
    /// full-rebuild path (`tests/incremental_differential.rs`).
    pub incremental: bool,
    /// §Incremental: serve channel-disjoint fault-free steps by merging
    /// memoized per-request solo runs instead of executing the batch
    /// DES. Bit-identical by the conservative-composition property.
    pub memoize: bool,
    /// §Layer serving: FFN expansion factor. `0` (the default) serves
    /// attention-only steps — the pre-layer behaviour, bit for bit.
    /// `>= 1` turns every step into a full transformer layer: each
    /// entry's attention kernel plus its projection/FFN GEMM tail
    /// (out-proj → FFN-up → FFN-down → next-layer QKV; see
    /// `dataflow::layer` §Kernel rotation) on the entry's tile-row band.
    pub ffn_mult: u64,
    /// §Layer serving: transformer layers per token (≥ 1). A request's
    /// token state advances only after it has run `layers` layer steps;
    /// requests at different depths share a batch, so layer `l` decode
    /// overlaps layer `l'` prefill across tile bands. Requires
    /// `ffn_mult >= 1` when `> 1`.
    pub layers: usize,
    /// §Layer serving: weight residency of the GEMM tails.
    pub weights: WeightResidency,
}

impl SchedulerConfig {
    /// Defaults for the given dataflow (see the field docs).
    pub fn new(dataflow: Dataflow) -> Self {
        Self {
            dataflow,
            group: 8,
            slots: 4,
            chunk: 512,
            page_tokens: 64,
            placement: PagePlacement::ChannelAffine,
            policy: BatchPolicy::Continuous,
            heads: 32,
            head_dim: 128,
            window: 0,
            seed: 0x5EED,
            threads: 1,
            slo_ttft_ms: 2.0,
            slo_tpot_ms: 0.1,
            incremental: true,
            memoize: true,
            ffn_mult: 0,
            layers: 1,
            weights: WeightResidency::HbmStream,
        }
    }

    /// True when this config serves full transformer layers (§Layer
    /// serving) rather than attention-only steps.
    pub fn layered(&self) -> bool {
        self.ffn_mult > 0
    }

    /// The per-step [`LayerParams`] of a layered config.
    pub(crate) fn layer_params(&self) -> LayerParams {
        LayerParams { ffn_mult: self.ffn_mult, weights: self.weights }
    }
}

/// Per-request serving metrics (cycles are absolute virtual-clock times).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestMetrics {
    /// Trace index of the request.
    pub id: usize,
    /// Arrival time (cycles).
    pub arrival: Cycle,
    /// Clock at the end of the step that produced the first output token.
    pub first_token: Cycle,
    /// Clock at the end of the step that produced the last output token.
    pub finish: Cycle,
    /// Prompt length in tokens.
    pub prompt: u64,
    /// Output budget in tokens.
    pub output: u64,
}

/// Aggregate serving metrics of one trace replay.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingReport {
    /// Virtual clock when the last step finished.
    pub total_cycles: Cycle,
    /// Composed steps executed.
    pub steps: usize,
    /// Total tokens produced (prefill + decode).
    pub tokens: u64,
    /// Token throughput at the architecture clock.
    pub tokens_per_s: f64,
    /// Mean time-to-first-token over all requests (ms).
    pub ttft_mean_ms: f64,
    /// Mean time-per-output-token over requests with more than one output
    /// token (ms).
    pub tpot_mean_ms: f64,
    /// TTFT tail percentiles (nearest-rank, ms).
    pub ttft_p50_ms: f64,
    /// TTFT p95 (nearest-rank, ms).
    pub ttft_p95_ms: f64,
    /// TTFT p99 (nearest-rank, ms).
    pub ttft_p99_ms: f64,
    /// TPOT tail percentiles (nearest-rank, ms; over requests with more
    /// than one output token).
    pub tpot_p50_ms: f64,
    /// TPOT p95 (nearest-rank, ms).
    pub tpot_p95_ms: f64,
    /// TPOT p99 (nearest-rank, ms).
    pub tpot_p99_ms: f64,
    /// Output tokens of requests meeting both SLOs
    /// ([`SchedulerConfig::slo_ttft_ms`] / [`SchedulerConfig::slo_tpot_ms`])
    /// per second — the goodput-under-SLO serving headline.
    pub goodput_tokens_per_s: f64,
    /// Mean fraction of slots occupied, weighted by step makespan.
    pub occupancy: f64,
    /// Total HBM traffic across every step.
    pub hbm_bytes: u64,
    /// Per-request metrics, in trace order.
    pub requests: Vec<RequestMetrics>,
    /// Compact JSON of the run's deterministic telemetry snapshot
    /// ([`crate::telemetry::RunTelemetry::snapshot_json`]), present when
    /// the run was invoked through [`try_simulate_with`] /
    /// [`router::try_route_with`] with a sink attached. Deterministic
    /// content only, so reports stay comparable across thread counts and
    /// composer modes.
    pub telemetry: Option<String>,
}

/// Nearest-rank percentile of an ascending-sorted sample (`q` in
/// `[0, 100]`); 0 for an empty sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Aggregate per-request metrics into a [`ServingReport`]. Shared by
/// [`simulate`] and [`router::route`] so means, tail percentiles and
/// goodput are computed one way; `requests` holds *completed* requests
/// only (the router excludes expired ones — they produced no service).
#[allow(clippy::too_many_arguments)]
pub(crate) fn finish_report(
    arch: &ArchConfig,
    cfg: &SchedulerConfig,
    clock: Cycle,
    steps: usize,
    tokens: u64,
    hbm_bytes: u64,
    occupancy: f64,
    requests: Vec<RequestMetrics>,
) -> ServingReport {
    let to_ms = |cycles: f64| cycles / (arch.freq_ghz * 1e6);
    let ttft_of = |r: &RequestMetrics| to_ms((r.first_token - r.arrival) as f64);
    let tpot_of =
        |r: &RequestMetrics| to_ms((r.finish - r.first_token) as f64) / (r.output - 1) as f64;
    let mut ttfts: Vec<f64> = requests.iter().map(ttft_of).collect();
    let mut tpots: Vec<f64> = requests.iter().filter(|r| r.output > 1).map(tpot_of).collect();
    ttfts.sort_by(f64::total_cmp);
    tpots.sort_by(f64::total_cmp);
    let mean = |v: &[f64]| if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 };
    let secs = clock as f64 / (arch.freq_ghz * 1e9);
    let good_tokens: u64 = requests
        .iter()
        .filter(|r| {
            let tpot = if r.output > 1 { tpot_of(r) } else { 0.0 };
            ttft_of(r) <= cfg.slo_ttft_ms && tpot <= cfg.slo_tpot_ms
        })
        .map(|r| r.output)
        .sum();
    let per_s = |t: u64| if secs > 0.0 { t as f64 / secs } else { 0.0 };
    ServingReport {
        total_cycles: clock,
        steps,
        tokens,
        tokens_per_s: per_s(tokens),
        ttft_mean_ms: mean(&ttfts),
        tpot_mean_ms: mean(&tpots),
        ttft_p50_ms: percentile(&ttfts, 50.0),
        ttft_p95_ms: percentile(&ttfts, 95.0),
        ttft_p99_ms: percentile(&ttfts, 99.0),
        tpot_p50_ms: percentile(&tpots, 50.0),
        tpot_p95_ms: percentile(&tpots, 95.0),
        tpot_p99_ms: percentile(&tpots, 99.0),
        goodput_tokens_per_s: per_s(good_tokens),
        occupancy,
        hbm_bytes,
        requests,
        telemetry: None,
    }
}

/// Fold the composer's mode-dependent counters (`engine_` section) and its
/// profiler, if any, into the telemetry sink at the end of a run. Shared by
/// [`simulate`] and [`router::route`].
pub(crate) fn absorb_composer(tel: &mut RunTelemetry, composer: &StepComposer) {
    let m = &mut tel.metrics;
    m.set_counter("engine_steps_patched", composer.patched_steps() as u64);
    m.set_counter("engine_steps_resealed", composer.resealed_steps() as u64);
    m.set_counter("engine_steps_memoized", composer.memo_steps() as u64);
    m.set_counter("engine_solo_memo_hits", composer.memo_hits() as u64);
    m.set_counter("engine_solo_memo_misses", composer.memo_misses() as u64);
    if let Some(p) = composer.profiler() {
        tel.merge_profile(p);
    }
}

struct ReqState {
    prefill_done: u64,
    generated: u64,
    first_token: Option<Cycle>,
    finish: Option<Cycle>,
    pages: PageMap,
    /// §Layer serving: index of the transformer layer the request runs
    /// next (always 0 for attention-only runs). Token/prefill state
    /// advances only when this wraps past `SchedulerConfig::layers`.
    layer: usize,
}

/// The per-slot affine channel range `(base, count)`: the slot's
/// partition of the south channels (K/V's natural edge), falling back to
/// partitioning the full channel set when the south edge is too narrow.
fn affine_range(arch: &ArchConfig, slot: usize, slots: usize) -> (u32, u32) {
    let cw = arch.hbm.channels_west as u32;
    let cs = arch.hbm.channels_south as u32;
    let (slot, slots) = (slot as u32, slots as u32);
    if cs >= slots {
        let per = cs / slots;
        (cw + slot * per, per)
    } else {
        let total = cw + cs;
        if total >= slots {
            let per = total / slots;
            (slot * per, per)
        } else {
            (slot % total, 1)
        }
    }
}

/// Structured rejection of an impossible `(arch, trace, cfg)`
/// combination. [`try_simulate`] / [`try_route`] return these instead of
/// panicking so the `schedule` CLI can print one clean diagnostic and
/// exit 1; the panicking wrappers [`simulate`] / [`router::route`] remain
/// for callers that treat a bad config as a programming error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleError {
    /// Slot/group geometry incompatible with the mesh or dataflow
    /// (from [`batch::validate_slots`]).
    BadGeometry(String),
    /// `chunk == 0`: a prefill chunk must carry at least one token.
    ZeroChunk,
    /// A trace request's `kv_heads` does not divide the model's query
    /// heads (GQA requires an integer group size).
    BadKvHeads { request: usize, kv_heads: u64, heads: u64 },
    /// `layers == 0`, or `layers > 1` without an FFN (`ffn_mult == 0`):
    /// multi-layer serving needs the projection/FFN tail that carries
    /// activations between layers.
    BadLayers { layers: usize, ffn_mult: u64 },
    /// Layer serving requested under the graceful-degradation router,
    /// which serves attention-only steps.
    LayeredRouting,
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::BadGeometry(msg) => f.write_str(msg),
            ScheduleError::ZeroChunk => f.write_str("prefill chunk must be >= 1 token"),
            ScheduleError::BadKvHeads { request, kv_heads, heads } => write!(
                f,
                "request {request}: kv_heads {kv_heads} must divide the model's \
                 {heads} query heads"
            ),
            ScheduleError::BadLayers { layers, ffn_mult } => write!(
                f,
                "layers {layers} with ffn-mult {ffn_mult}: layer serving needs \
                 layers >= 1, and layers > 1 needs ffn-mult >= 1"
            ),
            ScheduleError::LayeredRouting => f.write_str(
                "the router serves attention-only steps; layer serving \
                 (ffn-mult >= 1 or layers > 1) runs under plain `schedule`",
            ),
        }
    }
}

impl std::error::Error for ScheduleError {}

/// Shared `(arch, trace, cfg)` validation behind [`try_simulate`] and
/// [`try_route`]. Every rejection path is pinned by `mod tests` below.
pub(crate) fn validate_config(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
) -> Result<(), ScheduleError> {
    batch::validate_slots(arch, cfg.slots, cfg.group, cfg.dataflow)
        .map_err(ScheduleError::BadGeometry)?;
    if cfg.chunk == 0 {
        return Err(ScheduleError::ZeroChunk);
    }
    if cfg.layers == 0 || (cfg.layers > 1 && cfg.ffn_mult == 0) {
        return Err(ScheduleError::BadLayers { layers: cfg.layers, ffn_mult: cfg.ffn_mult });
    }
    for r in &trace.requests {
        if r.kv_heads == 0 || r.kv_heads > cfg.heads || cfg.heads % r.kv_heads != 0 {
            return Err(ScheduleError::BadKvHeads {
                request: r.id,
                kv_heads: r.kv_heads,
                heads: cfg.heads,
            });
        }
    }
    Ok(())
}

/// Replay a request trace through the scheduler and report serving
/// metrics, rejecting impossible configurations up front. Deterministic
/// for a given `(arch, trace, cfg)`.
pub fn try_simulate(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
) -> Result<ServingReport, ScheduleError> {
    try_simulate_with(arch, trace, cfg, None)
}

/// Like [`try_simulate`], optionally attaching a telemetry sink: with
/// `Some`, the run streams lifecycle events and windowed metrics into it
/// and embeds the deterministic snapshot in [`ServingReport::telemetry`];
/// with `None`, no telemetry work happens at all.
pub fn try_simulate_with(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
    tel: Option<&mut RunTelemetry>,
) -> Result<ServingReport, ScheduleError> {
    validate_config(arch, trace, cfg)?;
    Ok(simulate_validated(arch, trace, cfg, tel))
}

/// Panicking wrapper of [`try_simulate`] for callers that treat an
/// invalid configuration as a programming error.
pub fn simulate(arch: &ArchConfig, trace: &RequestTrace, cfg: &SchedulerConfig) -> ServingReport {
    try_simulate(arch, trace, cfg).unwrap_or_else(|e| panic!("scheduler: {e}"))
}

fn simulate_validated(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
    mut tel: Option<&mut RunTelemetry>,
) -> ServingReport {
    let n = trace.requests.len();
    let n_chan = arch.hbm.total_channels() as u64;
    let layered = cfg.layered();
    let mut states: Vec<ReqState> = (0..n)
        .map(|_| ReqState {
            prefill_done: 0,
            generated: 0,
            first_token: None,
            finish: None,
            pages: PageMap::new(cfg.page_tokens),
            layer: 0,
        })
        .collect();
    let mut slots: Vec<Option<usize>> = vec![None; cfg.slots];
    let mut next_arrival = 0usize;
    let mut clock: Cycle = 0;
    let mut steps = 0usize;
    let mut tokens = 0u64;
    let mut hbm_bytes = 0u64;
    let mut busy_slot_cycles = 0u128;
    let mut total_slot_cycles = 0u128;
    let mut rr_next = 0u64;
    let mut rng = Rng::new(cfg.seed);
    let mut composer = StepComposer::new(cfg);
    if let Some(t) = tel.as_deref_mut() {
        composer.enable_probe(n_chan as usize, cfg.slots);
        if t.profile.is_some() {
            composer.enable_profiling();
        }
    }
    // Step scratch hoisted out of the loop (§Incremental): a
    // million-request replay must not pay a round of Vec reallocation
    // per step. `entries` alone stays per-step — it borrows `states`.
    let mut active: Vec<(usize, usize)> = Vec::new();
    let mut metas: Vec<(usize, usize, bool, u64)> = Vec::new();
    let mut workloads: Vec<Workload> = Vec::new();
    let mut layer_counts: Vec<u64> = Vec::new();

    loop {
        // Admission: continuous fills any free slot; static only admits
        // into an idle machine.
        let all_free = slots.iter().all(|s| s.is_none());
        if cfg.policy == BatchPolicy::Continuous || all_free {
            for (si, slot) in slots.iter_mut().enumerate() {
                if slot.is_none()
                    && next_arrival < n
                    && trace.requests[next_arrival].arrival <= clock
                {
                    *slot = Some(next_arrival);
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_queued(next_arrival, trace.requests[next_arrival].arrival);
                        t.on_admitted(next_arrival, si, clock);
                    }
                    next_arrival += 1;
                }
            }
        }
        active.clear();
        active.extend(slots.iter().enumerate().filter_map(|(s, r)| r.map(|ri| (s, ri))));
        if active.is_empty() {
            if next_arrival >= n {
                break;
            }
            // Idle: jump to the next arrival.
            clock = clock.max(trace.requests[next_arrival].arrival);
            continue;
        }

        // Build each active request's step workload and grow its pages.
        metas.clear();
        workloads.clear();
        for &(slot, ri) in &active {
            let req = &trace.requests[ri];
            let st = &mut states[ri];
            let (wl_is_prefill, len, wl) = if st.prefill_done < req.prompt {
                let len = cfg.chunk.min(req.prompt - st.prefill_done);
                let mut wl = Workload::new(len, cfg.head_dim, cfg.heads, 1)
                    .with_kv_heads(req.kv_heads)
                    .with_causal(true)
                    .with_kv_prefix(st.prefill_done);
                if cfg.window > 0 {
                    wl = wl.with_window(cfg.window);
                }
                (true, len, wl)
            } else {
                let cache = req.prompt + st.generated;
                let mut wl = Workload::new(cache, cfg.head_dim, cfg.heads, 1)
                    .with_kv_heads(req.kv_heads)
                    .decode();
                if cfg.window > 0 {
                    wl = wl.with_window(cfg.window);
                }
                (false, 1, wl)
            };
            let placement = cfg.placement;
            let (base, count) = affine_range(arch, slot, cfg.slots);
            st.pages.grow_to(wl.kv_len(), |page| match placement {
                PagePlacement::RoundRobin => {
                    let c = (rr_next % n_chan) as u32;
                    rr_next += 1;
                    c
                }
                PagePlacement::ChannelAffine => base + (page % count as u64) as u32,
                PagePlacement::Random => rng.gen_range(n_chan) as u32,
            });
            metas.push((slot, ri, wl_is_prefill, len));
            workloads.push(wl);
        }

        // Compose and execute this step's batch program.
        let stats = {
            let entries: Vec<BatchEntry<'_>> = metas
                .iter()
                .zip(&workloads)
                .map(|(&(slot, ri, _, _), wl)| BatchEntry {
                    request: ri,
                    slot,
                    workload: *wl,
                    pages: &states[ri].pages,
                })
                .collect();
            if layered {
                composer.run_step_layered(arch, cfg, &entries, cfg.layer_params())
            } else {
                composer.run_step(arch, cfg, &entries)
            }
        };
        debug_assert!(stats.makespan > 0, "a non-empty step must advance the clock");
        let step_start = clock;
        clock = clock.checked_add(stats.makespan).expect("virtual clock overflowed u64 cycles");
        steps += 1;
        hbm_bytes += stats.hbm_bytes;
        busy_slot_cycles += active.len() as u128 * stats.makespan as u128;
        total_slot_cycles += cfg.slots as u128 * stats.makespan as u128;
        if let Some(t) = tel.as_deref_mut() {
            let queue_depth = trace.requests[next_arrival..]
                .partition_point(|r| r.arrival <= clock) as u64;
            let pages_in_use: u64 =
                active.iter().map(|&(_, ri)| states[ri].pages.num_pages() as u64).sum();
            if layered {
                layer_counts.clear();
                layer_counts.resize(cfg.layers, 0);
                for &(_, ri, _, _) in &metas {
                    layer_counts[states[ri].layer] += 1;
                }
            }
            t.record_step(&StepObs {
                index: (steps - 1) as u64,
                start: step_start,
                end: clock,
                stats: &stats,
                entries: &metas,
                queue_depth,
                pages_in_use,
                slots: cfg.slots as u64,
                probe: composer.probe(),
                layer_counts: layered.then_some(layer_counts.as_slice()),
            });
        }

        // Advance request states at the step barrier. Under layer serving
        // a step is one transformer layer: the request's layer index
        // advances every step, but its token/prefill state (and hence its
        // workload shape) only moves when the index wraps — the same
        // chunk or decode row runs once per layer.
        for &(slot, ri, is_prefill, len) in &metas {
            let req = &trace.requests[ri];
            let st = &mut states[ri];
            if layered {
                st.layer += 1;
                if st.layer < cfg.layers {
                    continue;
                }
                st.layer = 0;
            }
            if is_prefill {
                st.prefill_done += len;
                if st.prefill_done == req.prompt {
                    // The last prefill step samples the first output token.
                    st.first_token = Some(clock);
                    st.generated = 1;
                    tokens += 1;
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_token();
                        t.on_first_token(ri, clock);
                    }
                }
            } else {
                st.generated += 1;
                tokens += 1;
                if let Some(t) = tel.as_deref_mut() {
                    t.on_token();
                }
            }
            if st.generated >= req.output {
                st.finish = Some(clock);
                if let Some(t) = tel.as_deref_mut() {
                    let first = st.first_token.expect("finished request saw a first token");
                    t.on_completed(ri, clock, req.arrival, first, req.output);
                }
                // Retired for good: free the page table's allocation so a
                // long trace holds page state for in-flight requests only.
                st.pages.release();
                slots[slot] = None;
            }
        }
    }

    // Aggregate metrics.
    let requests: Vec<RequestMetrics> = trace
        .requests
        .iter()
        .enumerate()
        .map(|(ri, req)| {
            let st = &states[ri];
            RequestMetrics {
                id: req.id,
                arrival: req.arrival,
                first_token: st.first_token.expect("request finished prefill"),
                finish: st.finish.expect("request finished"),
                prompt: req.prompt,
                output: req.output,
            }
        })
        .collect();
    let occupancy = if total_slot_cycles > 0 {
        busy_slot_cycles as f64 / total_slot_cycles as f64
    } else {
        0.0
    };
    let mut report =
        finish_report(arch, cfg, clock, steps, tokens, hbm_bytes, occupancy, requests);
    if let Some(t) = tel {
        t.finish_run(clock);
        absorb_composer(t, &composer);
        report.telemetry = Some(t.snapshot_json().to_string());
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    fn cfg4(df: Dataflow) -> SchedulerConfig {
        let mut cfg = SchedulerConfig::new(df);
        cfg.slots = 4;
        cfg.group = 2;
        cfg.heads = 4;
        cfg.head_dim = 64;
        cfg
    }

    fn one_request() -> RequestTrace {
        RequestTrace::from_rows(&[(0, 64, 2)], 2)
    }

    #[test]
    fn bad_slot_count_is_a_structured_error() {
        let arch = presets::table2(8);
        let mut cfg = cfg4(Dataflow::Flash2);
        cfg.slots = 3; // does not divide the 8-row mesh
        let err = try_simulate(&arch, &one_request(), &cfg).unwrap_err();
        assert!(matches!(err, ScheduleError::BadGeometry(_)), "{err:?}");
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn bad_group_edge_is_a_structured_error() {
        let arch = presets::table2(8);
        let mut cfg = cfg4(Dataflow::FlatColl);
        cfg.group = 3; // flat groups must divide the slot band edge
        let err = try_simulate(&arch, &one_request(), &cfg).unwrap_err();
        assert!(matches!(err, ScheduleError::BadGeometry(_)), "{err:?}");
    }

    #[test]
    fn zero_prefill_chunk_is_a_structured_error() {
        let arch = presets::table2(8);
        let mut cfg = cfg4(Dataflow::Flash2);
        cfg.chunk = 0;
        let err = try_simulate(&arch, &one_request(), &cfg).unwrap_err();
        assert_eq!(err, ScheduleError::ZeroChunk);
        assert_eq!(err.to_string(), "prefill chunk must be >= 1 token");
    }

    #[test]
    fn non_dividing_kv_heads_is_a_structured_error() {
        let arch = presets::table2(8);
        let cfg = cfg4(Dataflow::Flash2);
        let bad = RequestTrace::from_rows(&[(0, 64, 2), (0, 64, 2)], 3); // 3 ∤ 4
        let err = try_simulate(&arch, &bad, &cfg).unwrap_err();
        assert_eq!(err, ScheduleError::BadKvHeads { request: 0, kv_heads: 3, heads: 4 });
        assert_eq!(
            err.to_string(),
            "request 0: kv_heads 3 must divide the model's 4 query heads"
        );
    }

    #[test]
    #[should_panic(expected = "scheduler: prefill chunk must be >= 1 token")]
    fn panicking_wrapper_carries_the_same_message() {
        let arch = presets::table2(8);
        let mut cfg = cfg4(Dataflow::Flash2);
        cfg.chunk = 0;
        let _ = simulate(&arch, &one_request(), &cfg);
    }

    #[test]
    fn bad_layer_configs_are_structured_errors() {
        let arch = presets::table2(8);
        let mut cfg = cfg4(Dataflow::Flash2);
        cfg.layers = 0;
        let err = try_simulate(&arch, &one_request(), &cfg).unwrap_err();
        assert!(matches!(err, ScheduleError::BadLayers { .. }), "{err:?}");
        // Multi-layer depth without an FFN: there is no GEMM tail to
        // distinguish the layers, so the config is rejected, not silently
        // multiplied.
        cfg.layers = 2;
        cfg.ffn_mult = 0;
        let err = try_simulate(&arch, &one_request(), &cfg).unwrap_err();
        assert_eq!(err, ScheduleError::BadLayers { layers: 2, ffn_mult: 0 });
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn layered_serving_takes_layers_times_the_steps() {
        // One step = one transformer layer: a request's token advances
        // only every `layers` steps, so the layered replay runs (about —
        // admission timing can add a step) `layers`× the attention-only
        // step count, and every step still makes progress.
        let arch = presets::table2(8);
        let trace = RequestTrace::from_rows(&[(0, 64, 2), (0, 96, 3)], 2);
        let plain = simulate(&arch, &trace, &cfg4(Dataflow::Flash2));
        let mut cfg = cfg4(Dataflow::Flash2);
        cfg.ffn_mult = 2;
        cfg.layers = 3;
        let layered = simulate(&arch, &trace, &cfg);
        assert!(
            layered.steps >= 3 * plain.steps,
            "layered {} vs plain {} steps",
            layered.steps,
            plain.steps
        );
        assert!(layered.tokens_per_s > 0.0);
        // The GEMM tails add HBM traffic on top of the attention-only run.
        assert!(layered.hbm_bytes > plain.hbm_bytes);
    }

    #[test]
    fn single_layer_without_ffn_is_the_legacy_path_bit_for_bit() {
        // `layers = 1, ffn_mult = 0` (the defaults) must be the exact
        // attention-only scheduler — the layered branch never engages.
        let arch = presets::table2(8);
        let trace = RequestTrace::from_rows(&[(0, 64, 2), (1_000, 96, 3)], 2);
        let base = simulate(&arch, &trace, &cfg4(Dataflow::FlatColl));
        let mut cfg = cfg4(Dataflow::FlatColl);
        cfg.layers = 1;
        cfg.ffn_mult = 0;
        assert_eq!(simulate(&arch, &trace, &cfg), base);
    }

    #[test]
    fn router_rejects_layered_configs() {
        let arch = presets::table2(8);
        let mut cfg = cfg4(Dataflow::Flash2);
        cfg.ffn_mult = 1;
        let rc = RouterConfig::default();
        let err = try_route(&arch, &one_request(), &cfg, &rc).unwrap_err();
        assert_eq!(err, ScheduleError::LayeredRouting);
        assert!(err.to_string().contains("attention-only"));
    }
}
