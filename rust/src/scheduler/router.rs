//! Graceful-degradation request router.
//!
//! [`route`] wraps the scheduler's compose/execute step in a request
//! *lifecycle* layer: token-budget and page-budget admission, preemption
//! under page pressure, per-attempt deadlines with bounded retries, and
//! fault-aware band remapping under a [`FaultPlan`]. The design essay
//! lives in `docs/ARCHITECTURE.md` §"Graceful-degradation router"; this
//! file is the mechanism.
//!
//! The router shares [`finish_report`] with [`super::simulate`] so its
//! latency percentiles and goodput are computed identically; with a
//! default [`RouterConfig`] (no faults, no budgets, no deadline) it
//! reproduces `simulate`'s schedule exactly (`unit tests below`).

use std::collections::VecDeque;

use super::{
    affine_range, finish_report, validate_config, BatchEntry, PagePlacement, RequestMetrics,
    RequestTrace, ScheduleError, SchedulerConfig, ServingReport, StepComposer,
};
use crate::arch::ArchConfig;
use crate::dataflow::Workload;
use crate::hbm::PageMap;
use crate::sim::{Cycle, FaultPlan};
use crate::telemetry::{DropCause, RequeueCause, RunTelemetry, StepObs};
use crate::util::Rng;

/// Which in-flight request to evict under page pressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VictimPolicy {
    /// Most recently admitted first (vLLM-style recompute preemption:
    /// the oldest request keeps its head-of-line service).
    Newest,
    /// Smallest current KV footprint first — cheapest cache to rebuild.
    FewestPages,
    /// Most remaining work first — frees capacity for requests that are
    /// close to finishing (minimizes wasted service).
    MostRemaining,
}

impl VictimPolicy {
    /// Stable CLI/report name.
    pub fn label(self) -> &'static str {
        match self {
            VictimPolicy::Newest => "newest",
            VictimPolicy::FewestPages => "fewest-pages",
            VictimPolicy::MostRemaining => "most-remaining",
        }
    }

    /// Parse a (case-insensitive) label, e.g. from the CLI.
    pub fn from_label(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "newest" => Some(VictimPolicy::Newest),
            "fewest-pages" | "fewest" => Some(VictimPolicy::FewestPages),
            "most-remaining" | "remaining" => Some(VictimPolicy::MostRemaining),
            _ => None,
        }
    }
}

/// Router configuration: everything here defaults to "off", so a default
/// router is a transparent wrapper around the plain scheduler.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Fault plan in absolute virtual-clock cycles; sliced per step with
    /// [`FaultPlan::shifted`].
    pub faults: FaultPlan,
    /// Admission cap on Σ (prompt + output) across the batch, TGI's
    /// `max_batch_total_tokens`. 0 = unlimited.
    pub max_batch_total_tokens: u64,
    /// KV page pool size shared by all in-flight requests. 0 = unlimited.
    pub max_total_pages: u64,
    /// Per-attempt deadline in cycles; 0 = none.
    pub deadline: Cycle,
    /// Deadline retries before a request expires.
    pub max_retries: usize,
    /// Which running request to evict under page pressure.
    pub victim: VictimPolicy,
    /// Resolve page pressure by eviction (true) or prevent it by
    /// reservation-based admission (false). See the §Router essay.
    pub preemption: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            faults: FaultPlan::none(),
            max_batch_total_tokens: 0,
            max_total_pages: 0,
            deadline: 0,
            max_retries: 1,
            victim: VictimPolicy::FewestPages,
            preemption: true,
        }
    }
}

/// [`route`]'s result: the serving metrics of *completed* requests plus
/// the lifecycle counters the degradation figures plot.
#[derive(Debug, Clone, PartialEq)]
pub struct RouterReport {
    /// Serving metrics over completed requests.
    pub serving: ServingReport,
    /// Requests that ran to completion.
    pub completed: usize,
    /// Requests dropped (deadline retries exhausted, or no live band
    /// remained to run them).
    pub expired: usize,
    /// Page-pressure evictions (each re-queues the victim for a full
    /// cache rebuild).
    pub preemptions: usize,
    /// Deadline-triggered retries.
    pub retries: usize,
    /// Requests kicked off a band by a mid-step tile death (they keep
    /// pages and progress and re-queue).
    pub band_evictions: usize,
    /// Tile-row bands unusable at the end of the run.
    pub dead_bands: usize,
}

/// Per-request lifecycle state (superset of the plain scheduler's).
struct RState {
    pages: PageMap,
    prefill_done: u64,
    generated: u64,
    /// Prefill target of the current attempt: `prompt`, raised to
    /// `prompt + generated` after an eviction so the rebuilt cache covers
    /// every token the request had already processed.
    rebuild_to: u64,
    first_token: Option<Cycle>,
    finish: Option<Cycle>,
    /// Start of the current deadline window (arrival, then each retry).
    deadline_base: Cycle,
    retries: usize,
    admit_seq: u64,
    expired: bool,
}

/// Which slots are unusable at `clock`: a slot dies with any tile in its
/// row band.
fn dead_slots(arch: &ArchConfig, slots: usize, faults: &FaultPlan, clock: Cycle) -> Vec<bool> {
    let mut dead = vec![false; slots];
    let rows_per = arch.mesh_y / slots;
    for tile in faults.dead_tiles_at(clock) {
        let slot = (tile as usize / arch.mesh_x) / rows_per;
        if slot < slots {
            dead[slot] = true;
        }
    }
    dead
}

/// Eviction candidate snapshot; `idx` indexes the step's entry list.
struct VictimCand {
    idx: usize,
    admit_seq: u64,
    pages: u64,
    remaining: u64,
}

/// Deterministic victim choice: policy key, ties broken by entry order.
fn choose_victim(policy: VictimPolicy, cands: &[VictimCand]) -> usize {
    cands
        .iter()
        .min_by_key(|c| match policy {
            VictimPolicy::Newest => (u64::MAX - c.admit_seq, c.idx),
            VictimPolicy::FewestPages => (c.pages, c.idx),
            VictimPolicy::MostRemaining => (u64::MAX - c.remaining, c.idx),
        })
        .expect("choose_victim: no candidates")
        .idx
}

/// Replay `trace` through the graceful-degradation router, rejecting
/// impossible configurations with a structured [`ScheduleError`] up
/// front. Deterministic for a given `(arch, trace, cfg, rc)` at every
/// thread count.
pub fn try_route(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
    rc: &RouterConfig,
) -> Result<RouterReport, ScheduleError> {
    try_route_with(arch, trace, cfg, rc, None)
}

/// Like [`try_route`], optionally attaching a telemetry sink: with `Some`,
/// the run streams lifecycle events (admissions, requeues with cause
/// labels, band deaths, drops) and windowed metrics into it and embeds the
/// deterministic snapshot in the report; with `None`, no telemetry work
/// happens at all.
pub fn try_route_with(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
    rc: &RouterConfig,
    tel: Option<&mut RunTelemetry>,
) -> Result<RouterReport, ScheduleError> {
    validate_config(arch, trace, cfg)?;
    // The router's lifecycle machinery (preemption, rebuild, band death)
    // reasons about attention-only steps; layer serving runs under the
    // plain scheduler.
    if cfg.layered() || cfg.layers > 1 {
        return Err(super::ScheduleError::LayeredRouting);
    }
    Ok(route_validated(arch, trace, cfg, rc, tel))
}

/// Panicking wrapper of [`try_route`] for callers that treat an invalid
/// configuration as a programming error.
pub fn route(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
    rc: &RouterConfig,
) -> RouterReport {
    try_route(arch, trace, cfg, rc).unwrap_or_else(|e| panic!("router: {e}"))
}

fn route_validated(
    arch: &ArchConfig,
    trace: &RequestTrace,
    cfg: &SchedulerConfig,
    rc: &RouterConfig,
    mut tel: Option<&mut RunTelemetry>,
) -> RouterReport {
    let n = trace.requests.len();
    let n_chan = arch.hbm.total_channels() as u64;
    let mut states: Vec<RState> = trace
        .requests
        .iter()
        .map(|r| RState {
            pages: PageMap::new(cfg.page_tokens),
            prefill_done: 0,
            generated: 0,
            rebuild_to: r.prompt,
            first_token: None,
            finish: None,
            deadline_base: r.arrival,
            retries: 0,
            admit_seq: 0,
            expired: false,
        })
        .collect();
    let mut slots: Vec<Option<usize>> = vec![None; cfg.slots];
    let mut waiting: VecDeque<usize> = VecDeque::new();
    let mut next_arrival = 0usize;
    let mut clock: Cycle = 0;
    let mut steps = 0usize;
    let mut tokens = 0u64;
    let mut hbm_bytes = 0u64;
    let mut busy_slot_cycles = 0u128;
    let mut total_slot_cycles = 0u128;
    let mut rr_next = 0u64;
    let mut rng = Rng::new(cfg.seed);
    let mut composer = StepComposer::new(cfg);
    if let Some(t) = tel.as_deref_mut() {
        composer.enable_probe(n_chan as usize, cfg.slots);
        if t.profile.is_some() {
            composer.enable_profiling();
        }
    }
    // Telemetry-only memory of which bands were already reported dead, so
    // each band death is announced exactly once.
    let mut known_dead: Vec<bool> = vec![false; if tel.is_some() { cfg.slots } else { 0 }];
    // Step scratch hoisted out of the loop (§Incremental): a
    // million-request replay must not pay a round of Vec reallocation
    // per step. `entries` alone stays per-step — it borrows `states`.
    let mut active: Vec<(usize, usize)> = Vec::new();
    let mut metas: Vec<(usize, usize, bool, u64)> = Vec::new();
    let mut workloads: Vec<Workload> = Vec::new();
    let mut admit_ctr = 0u64;
    let (mut expired, mut preemptions, mut retries, mut band_evictions) = (0usize, 0, 0, 0);

    // Reservation footprint for preemption-off page admission: the
    // maximal cache the request can ever hold.
    let reserve_pages = |ri: usize| {
        let r = &trace.requests[ri];
        (r.prompt + r.output).div_ceil(cfg.page_tokens)
    };

    loop {
        // Queue new arrivals (FCFS).
        while next_arrival < n && trace.requests[next_arrival].arrival <= clock {
            if let Some(t) = tel.as_deref_mut() {
                t.on_queued(next_arrival, trace.requests[next_arrival].arrival);
            }
            waiting.push_back(next_arrival);
            next_arrival += 1;
        }

        // Fault-aware remapping: kick in-flight requests off bands that
        // died since the last step. They keep pages and progress — the KV
        // cache lives in HBM, only the compute band is gone.
        let dead = dead_slots(arch, cfg.slots, &rc.faults, clock);
        if let Some(t) = tel.as_deref_mut() {
            for (s, &d) in dead.iter().enumerate() {
                if d && !known_dead[s] {
                    known_dead[s] = true;
                    t.on_band_dead(s, clock);
                }
            }
        }
        for (slot, &d) in slots.iter_mut().zip(&dead) {
            if !d {
                continue;
            }
            if let Some(ri) = slot.take() {
                // Per-attempt TTFT: the next delivered token re-arms it.
                states[ri].first_token = None;
                if let Some(t) = tel.as_deref_mut() {
                    t.on_requeued(ri, clock, RequeueCause::BandDeath);
                }
                waiting.push_front(ri);
                band_evictions += 1;
            }
        }

        // Deadlines: an attempt that overran its window retries (eviction
        // semantics — pages freed, cache rebuilt) until retries exhaust.
        if rc.deadline > 0 {
            for slot in slots.iter_mut() {
                let Some(ri) = *slot else { continue };
                let st = &mut states[ri];
                if clock.saturating_sub(st.deadline_base) <= rc.deadline {
                    continue;
                }
                *slot = None;
                st.pages.reset();
                if st.retries < rc.max_retries {
                    st.retries += 1;
                    retries += 1;
                    st.deadline_base = clock;
                    st.prefill_done = 0;
                    st.rebuild_to = trace.requests[ri].prompt + st.generated;
                    st.first_token = None; // per-attempt TTFT
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_requeued(ri, clock, RequeueCause::DeadlineRetry);
                    }
                    waiting.push_back(ri);
                } else {
                    st.pages.release();
                    st.expired = true;
                    expired += 1;
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_dropped(ri, clock, DropCause::RetriesExhausted);
                    }
                }
            }
            waiting.retain(|&ri| {
                let st = &mut states[ri];
                if clock.saturating_sub(st.deadline_base) <= rc.deadline {
                    return true;
                }
                if st.retries < rc.max_retries {
                    st.retries += 1;
                    retries += 1;
                    st.deadline_base = clock;
                    st.first_token = None; // per-attempt TTFT
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_requeued(ri, clock, RequeueCause::DeadlineRetry);
                    }
                    true
                } else {
                    st.pages.release();
                    st.expired = true;
                    expired += 1;
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_dropped(ri, clock, DropCause::RetriesExhausted);
                    }
                    false
                }
            });
        }

        // Admission: front waiter into the lowest free live slot, gated
        // by the token and page budgets. An idle machine always admits
        // the front waiter, so no budget can deadlock the router.
        loop {
            let Some(&ri) = waiting.front() else { break };
            let Some(slot) = (0..cfg.slots).find(|&s| slots[s].is_none() && !dead[s]) else {
                break;
            };
            let idle = slots.iter().all(|s| s.is_none());
            if !idle {
                if rc.max_batch_total_tokens > 0 {
                    let load: u64 = slots
                        .iter()
                        .flatten()
                        .map(|&r| trace.requests[r].prompt + trace.requests[r].output)
                        .sum();
                    let cand = trace.requests[ri].prompt + trace.requests[ri].output;
                    if load + cand > rc.max_batch_total_tokens {
                        break;
                    }
                }
                if rc.max_total_pages > 0 {
                    let fits = if rc.preemption {
                        // Optimistic: current footprints + the candidate's
                        // next step; pressure is resolved by eviction.
                        let used: u64 = slots
                            .iter()
                            .flatten()
                            .map(|&r| states[r].pages.num_pages() as u64)
                            .sum();
                        let st = &states[ri];
                        let next_kv = if st.prefill_done < st.rebuild_to {
                            st.prefill_done + cfg.chunk.min(st.rebuild_to - st.prefill_done)
                        } else {
                            trace.requests[ri].prompt + st.generated
                        };
                        used + st.pages.pages_for(next_kv) <= rc.max_total_pages
                    } else {
                        // Reservation: maximal footprints must all fit, so
                        // pressure can never materialize mid-flight.
                        let reserved: u64 = slots.iter().flatten().map(|&r| reserve_pages(r)).sum();
                        reserved + reserve_pages(ri) <= rc.max_total_pages
                    };
                    if !fits {
                        break;
                    }
                }
            }
            waiting.pop_front();
            admit_ctr += 1;
            states[ri].admit_seq = admit_ctr;
            slots[slot] = Some(ri);
            if let Some(t) = tel.as_deref_mut() {
                t.on_admitted(ri, slot, clock);
            }
        }

        active.clear();
        active.extend(slots.iter().enumerate().filter_map(|(s, r)| r.map(|ri| (s, ri))));
        if active.is_empty() {
            if waiting.is_empty() && next_arrival >= n {
                break;
            }
            if dead.iter().all(|&d| d) {
                // No live band left: the remaining stream can never be
                // served — expire it rather than spin.
                while next_arrival < n {
                    waiting.push_back(next_arrival);
                    next_arrival += 1;
                }
                for ri in waiting.drain(..) {
                    states[ri].pages.release();
                    states[ri].expired = true;
                    expired += 1;
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_dropped(ri, clock, DropCause::NoLiveBand);
                    }
                }
                break;
            }
            if waiting.is_empty() {
                // Idle: jump to the next arrival.
                clock = clock.max(trace.requests[next_arrival].arrival);
                continue;
            }
            unreachable!("router: idle machine failed to admit a waiter");
        }

        // Build each active request's step workload (prefill chunks run
        // until the cache covers `rebuild_to`, so evicted requests pay
        // their rebuild as real traffic).
        metas.clear();
        workloads.clear();
        for &(slot, ri) in &active {
            let req = &trace.requests[ri];
            let st = &states[ri];
            let (is_prefill, len, wl) = if st.prefill_done < st.rebuild_to {
                let len = cfg.chunk.min(st.rebuild_to - st.prefill_done);
                let mut wl = Workload::new(len, cfg.head_dim, cfg.heads, 1)
                    .with_kv_heads(req.kv_heads)
                    .with_causal(true)
                    .with_kv_prefix(st.prefill_done);
                if cfg.window > 0 {
                    wl = wl.with_window(cfg.window);
                }
                (true, len, wl)
            } else {
                let cache = req.prompt + st.generated;
                let mut wl = Workload::new(cache, cfg.head_dim, cfg.heads, 1)
                    .with_kv_heads(req.kv_heads)
                    .decode();
                if cfg.window > 0 {
                    wl = wl.with_window(cfg.window);
                }
                (false, 1, wl)
            };
            metas.push((slot, ri, is_prefill, len));
            workloads.push(wl);
        }

        // Page pressure: evict until the step's caches fit the pool. A
        // lone request that cannot fit alone expires (retrying could
        // never succeed — the pool is simply too small for it).
        if rc.preemption && rc.max_total_pages > 0 {
            loop {
                let need: u64 = metas
                    .iter()
                    .zip(&workloads)
                    .map(|(&(_, ri, _, _), wl)| states[ri].pages.pages_for(wl.kv_len()))
                    .sum();
                if need <= rc.max_total_pages {
                    break;
                }
                if metas.len() == 1 {
                    let (slot, ri, _, _) = metas[0];
                    slots[slot] = None;
                    states[ri].pages.release();
                    states[ri].expired = true;
                    expired += 1;
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_dropped(ri, clock, DropCause::PoolTooSmall);
                    }
                    metas.clear();
                    workloads.clear();
                    break;
                }
                let cands: Vec<VictimCand> = metas
                    .iter()
                    .zip(&workloads)
                    .enumerate()
                    .map(|(idx, (&(_, ri, _, _), wl))| {
                        let req = &trace.requests[ri];
                        let st = &states[ri];
                        VictimCand {
                            idx,
                            admit_seq: st.admit_seq,
                            pages: st.pages.pages_for(wl.kv_len()),
                            remaining: (st.rebuild_to - st.prefill_done)
                                + (req.output - st.generated),
                        }
                    })
                    .collect();
                let k = choose_victim(rc.victim, &cands);
                let (slot, ri, _, _) = metas[k];
                let st = &mut states[ri];
                slots[slot] = None;
                st.pages.reset();
                st.prefill_done = 0;
                st.rebuild_to = trace.requests[ri].prompt + st.generated;
                st.first_token = None; // per-attempt TTFT
                if let Some(t) = tel.as_deref_mut() {
                    t.on_requeued(ri, clock, RequeueCause::Preemption);
                }
                waiting.push_back(ri);
                preemptions += 1;
                metas.remove(k);
                workloads.remove(k);
            }
            if metas.is_empty() {
                continue;
            }
        }

        // Grow pages and execute the step under the shifted fault plan.
        for (&(slot, ri, _, _), wl) in metas.iter().zip(&workloads) {
            let placement = cfg.placement;
            let (base, count) = affine_range(arch, slot, cfg.slots);
            states[ri].pages.grow_to(wl.kv_len(), |page| match placement {
                PagePlacement::RoundRobin => {
                    let c = (rr_next % n_chan) as u32;
                    rr_next += 1;
                    c
                }
                PagePlacement::ChannelAffine => base + (page % count as u64) as u32,
                PagePlacement::Random => rng.gen_range(n_chan) as u32,
            });
        }
        let (stats, affected) = {
            let entries: Vec<BatchEntry<'_>> = metas
                .iter()
                .zip(&workloads)
                .map(|(&(slot, ri, _, _), wl)| BatchEntry {
                    request: ri,
                    slot,
                    workload: *wl,
                    pages: &states[ri].pages,
                })
                .collect();
            let plan = rc.faults.shifted(clock);
            if plan.is_none() {
                (composer.run_step(arch, cfg, &entries), Vec::new())
            } else {
                composer.run_step_faulted(arch, cfg, &entries, &plan)
            }
        };
        let step_start = clock;
        clock = clock.checked_add(stats.makespan).expect("virtual clock overflowed u64 cycles");
        steps += 1;
        hbm_bytes += stats.hbm_bytes;
        busy_slot_cycles += metas.len() as u128 * stats.makespan as u128;
        total_slot_cycles += cfg.slots as u128 * stats.makespan as u128;
        if let Some(t) = tel.as_deref_mut() {
            let pages_in_use: u64 =
                metas.iter().map(|&(_, ri, _, _)| states[ri].pages.num_pages() as u64).sum();
            t.record_step(&StepObs {
                index: (steps - 1) as u64,
                start: step_start,
                end: clock,
                stats: &stats,
                entries: &metas,
                queue_depth: waiting.len() as u64,
                pages_in_use,
                slots: cfg.slots as u64,
                probe: composer.probe(),
                layer_counts: None,
            });
        }

        // Advance request states at the step barrier. Entries whose band
        // died mid-step made no progress; they re-queue (pages intact) and
        // the dead-band sweep above retires the band next iteration.
        for (k, &(slot, ri, is_prefill, len)) in metas.iter().enumerate() {
            if affected.binary_search(&k).is_ok() {
                slots[slot] = None;
                // Per-attempt TTFT: the next delivered token re-arms it.
                states[ri].first_token = None;
                if let Some(t) = tel.as_deref_mut() {
                    t.on_requeued(ri, clock, RequeueCause::BandDeath);
                }
                waiting.push_front(ri);
                band_evictions += 1;
                continue;
            }
            let req = &trace.requests[ri];
            let st = &mut states[ri];
            if is_prefill {
                st.prefill_done += len;
                if st.prefill_done == st.rebuild_to && st.generated == 0 {
                    // The last prefill step samples the first output
                    // token; rebuilds resume with their cache restored
                    // and emit nothing new until the next decode step.
                    st.first_token = Some(clock);
                    st.generated = 1;
                    tokens += 1;
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_token();
                        t.on_first_token(ri, clock);
                    }
                }
            } else {
                if st.first_token.is_none() {
                    // First token delivered by this attempt: a mid-decode
                    // requeue cleared the mark, so TTFT measures service
                    // after the last disruption (§Router, per-attempt).
                    st.first_token = Some(clock);
                    if let Some(t) = tel.as_deref_mut() {
                        t.on_first_token(ri, clock);
                    }
                }
                st.generated += 1;
                tokens += 1;
                if let Some(t) = tel.as_deref_mut() {
                    t.on_token();
                }
            }
            if st.generated >= req.output {
                st.finish = Some(clock);
                if let Some(t) = tel.as_deref_mut() {
                    let first = st.first_token.expect("completed request has a first token");
                    t.on_completed(ri, clock, req.arrival, first, req.output);
                }
                // Retired for good: free the page table's allocation.
                st.pages.release();
                slots[slot] = None;
            }
        }
    }

    // Aggregate: completed requests only — expired ones produced no
    // service and are excluded from latency/goodput (but counted).
    let requests: Vec<RequestMetrics> = trace
        .requests
        .iter()
        .enumerate()
        .filter(|(ri, _)| !states[*ri].expired)
        .map(|(ri, req)| {
            let st = &states[ri];
            RequestMetrics {
                id: req.id,
                arrival: req.arrival,
                first_token: st.first_token.expect("completed request has a first token"),
                finish: st.finish.expect("completed request has a finish time"),
                prompt: req.prompt,
                output: req.output,
            }
        })
        .collect();
    let completed = requests.len();
    let occupancy = if total_slot_cycles > 0 {
        busy_slot_cycles as f64 / total_slot_cycles as f64
    } else {
        0.0
    };
    let dead_bands =
        dead_slots(arch, cfg.slots, &rc.faults, clock).iter().filter(|&&d| d).count();
    let mut serving =
        finish_report(arch, cfg, clock, steps, tokens, hbm_bytes, occupancy, requests);
    if let Some(t) = tel {
        t.metrics.gauge_set("dead_bands", dead_bands as u64);
        t.finish_run(clock);
        super::absorb_composer(t, &composer);
        serving.telemetry = Some(t.snapshot_json().to_string());
    }
    RouterReport {
        serving,
        completed,
        expired,
        preemptions,
        retries,
        band_evictions,
        dead_bands,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::dataflow::Dataflow;
    use crate::scheduler::simulate;

    fn tiny_cfg(df: Dataflow) -> SchedulerConfig {
        let mut cfg = SchedulerConfig::new(df);
        cfg.slots = 4;
        cfg.group = 2;
        cfg.chunk = 96;
        cfg.page_tokens = 32;
        cfg.heads = 4;
        cfg.head_dim = 64;
        cfg
    }

    fn mixed_trace() -> RequestTrace {
        RequestTrace::from_rows(
            &[(0, 160, 4), (0, 96, 8), (5_000, 200, 3), (20_000, 64, 6), (40_000, 128, 5)],
            2,
        )
    }

    /// Four arrival-0 requests so every band (slot 3 included) is busy
    /// when faults land, plus a late arrival.
    fn burst_trace() -> RequestTrace {
        RequestTrace::from_rows(
            &[(0, 160, 4), (0, 96, 8), (0, 200, 3), (0, 64, 6), (40_000, 128, 5)],
            2,
        )
    }

    #[test]
    fn unconstrained_fault_free_router_matches_simulate() {
        let arch = presets::table2(8);
        let trace = mixed_trace();
        for df in [Dataflow::Flash2, Dataflow::FlatColl] {
            let cfg = tiny_cfg(df);
            let want = simulate(&arch, &trace, &cfg);
            let got = route(&arch, &trace, &cfg, &RouterConfig::default());
            assert_eq!(got.expired, 0, "{df:?}");
            assert_eq!(got.completed, trace.requests.len(), "{df:?}");
            assert_eq!(got.preemptions + got.retries + got.band_evictions, 0, "{df:?}");
            assert_eq!(got.serving.total_cycles, want.total_cycles, "{df:?}");
            assert_eq!(got.serving.steps, want.steps, "{df:?}");
            assert_eq!(got.serving.tokens, want.tokens, "{df:?}");
            assert_eq!(got.serving.hbm_bytes, want.hbm_bytes, "{df:?}");
            assert_eq!(got.serving.goodput_tokens_per_s, want.goodput_tokens_per_s, "{df:?}");
        }
    }

    #[test]
    fn tile_death_and_derate_complete_all_requests() {
        let arch = presets::table2(8);
        let trace = burst_trace();
        for df in [Dataflow::Flash2, Dataflow::FlatColl] {
            let cfg = tiny_cfg(df);
            let free = route(&arch, &trace, &cfg, &RouterConfig::default());
            // Band 3 (rows 6-7, first tile 48) dies almost immediately;
            // every channel runs at half bandwidth for the whole trace.
            let mut faults = FaultPlan::none().with_tile_death(48, 1);
            for c in 0..arch.hbm.total_channels() as u32 {
                faults = faults.with_derate(c, 0, u64::MAX / 2, 2, 1);
            }
            let rc = RouterConfig { faults, ..RouterConfig::default() };
            let got = route(&arch, &trace, &cfg, &rc);
            assert_eq!(got.expired, 0, "{df:?}: degraded, not dropped");
            assert_eq!(got.completed, trace.requests.len(), "{df:?}");
            assert_eq!(got.serving.tokens, free.serving.tokens, "{df:?}");
            assert_eq!(got.dead_bands, 1, "{df:?}");
            assert!(got.band_evictions >= 1, "{df:?}: the dying band evicts its request");
            assert!(
                got.serving.total_cycles > free.serving.total_cycles,
                "{df:?}: a dead band + derated channels must lengthen the run \
                 ({} vs {})",
                got.serving.total_cycles,
                free.serving.total_cycles
            );
        }
    }

    #[test]
    fn page_pressure_preemption_vs_admission_only() {
        let arch = presets::table2(8);
        // Four equal requests whose maximal footprints (6 pages each, 24
        // total) overflow a 12-page pool.
        let trace =
            RequestTrace::from_rows(&[(0, 160, 4), (0, 160, 4), (0, 160, 4), (0, 160, 4)], 2);
        let cfg = tiny_cfg(Dataflow::Flash2);
        let on = RouterConfig {
            max_total_pages: 12,
            victim: VictimPolicy::Newest,
            preemption: true,
            ..RouterConfig::default()
        };
        let off = RouterConfig { preemption: false, ..on.clone() };
        let r_on = route(&arch, &trace, &cfg, &on);
        let r_off = route(&arch, &trace, &cfg, &off);
        for (label, r) in [("preemption", &r_on), ("admission-only", &r_off)] {
            assert_eq!(r.expired, 0, "{label}: everyone completes");
            assert_eq!(r.completed, trace.requests.len(), "{label}");
            assert_eq!(r.serving.tokens, 16, "{label}: all output delivered");
        }
        assert!(r_on.preemptions >= 1, "optimistic admission must hit pressure");
        assert_eq!(r_off.preemptions, 0, "reservation admission never evicts");
    }

    #[test]
    fn infeasible_page_budget_expires_rather_than_deadlocks() {
        let arch = presets::table2(8);
        let trace = RequestTrace::from_rows(&[(0, 160, 4), (0, 96, 8)], 2);
        let cfg = tiny_cfg(Dataflow::Flash2);
        let rc = RouterConfig { max_total_pages: 1, preemption: true, ..RouterConfig::default() };
        let r = route(&arch, &trace, &cfg, &rc);
        assert_eq!(r.expired, trace.requests.len());
        assert_eq!(r.completed, 0);
        assert_eq!(r.serving.tokens, 0);
    }

    #[test]
    fn deadlines_retry_then_expire() {
        let arch = presets::table2(8);
        // Multi-step requests (output >= 2) under a 1-cycle deadline can
        // never finish an attempt in time.
        let trace = RequestTrace::from_rows(&[(0, 160, 4), (0, 96, 8), (0, 200, 3)], 2);
        let cfg = tiny_cfg(Dataflow::Flash2);
        let rc = RouterConfig { deadline: 1, max_retries: 1, ..RouterConfig::default() };
        let r = route(&arch, &trace, &cfg, &rc);
        assert_eq!(r.completed, 0);
        assert_eq!(r.expired, trace.requests.len());
        assert_eq!(r.retries, trace.requests.len());
    }

    /// §Router per-attempt TTFT: a request band-evicted *mid-decode* must
    /// not keep the first-token timestamp of its aborted attempt. Before
    /// the fix `first_token` survived the requeue, so the faulted run
    /// reported the same TTFT as the fault-free one — this test fails on
    /// that behavior.
    #[test]
    fn requeued_requests_restart_ttft_per_attempt() {
        let arch = presets::table2(8);
        let trace = RequestTrace::from_rows(&[(0, 96, 6)], 2);
        let cfg = tiny_cfg(Dataflow::Flash2);
        let free = route(&arch, &trace, &cfg, &RouterConfig::default());
        let t1 = free.serving.requests[0].first_token;
        // Kill the request's band (slot 0 starts at tile 0) one cycle
        // after the first token was delivered: the decoding request is
        // re-queued onto a live band and must re-earn its first token.
        let faults = FaultPlan::none().with_tile_death(0, t1 + 1);
        let rc = RouterConfig { faults, ..RouterConfig::default() };
        let got = route(&arch, &trace, &cfg, &rc);
        assert_eq!(got.completed, 1);
        assert!(got.band_evictions >= 1, "the death must actually evict");
        let ft = got.serving.requests[0].first_token;
        assert!(ft > t1, "per-attempt TTFT: first token {ft} must postdate the eviction at {t1}");
    }

    #[test]
    fn victim_policies_are_deterministic() {
        let cands = vec![
            VictimCand { idx: 0, admit_seq: 3, pages: 5, remaining: 10 },
            VictimCand { idx: 1, admit_seq: 7, pages: 2, remaining: 40 },
            VictimCand { idx: 2, admit_seq: 5, pages: 2, remaining: 25 },
        ];
        assert_eq!(choose_victim(VictimPolicy::Newest, &cands), 1);
        assert_eq!(choose_victim(VictimPolicy::FewestPages, &cands), 1);
        assert_eq!(choose_victim(VictimPolicy::MostRemaining, &cands), 1);
        let cands2 = vec![
            VictimCand { idx: 0, admit_seq: 9, pages: 4, remaining: 12 },
            VictimCand { idx: 1, admit_seq: 2, pages: 6, remaining: 30 },
        ];
        assert_eq!(choose_victim(VictimPolicy::Newest, &cands2), 0);
        assert_eq!(choose_victim(VictimPolicy::FewestPages, &cands2), 0);
        assert_eq!(choose_victim(VictimPolicy::MostRemaining, &cands2), 1);
    }

    #[test]
    fn all_bands_dead_expires_remaining() {
        let arch = presets::table2(8);
        let trace = burst_trace();
        let cfg = tiny_cfg(Dataflow::Flash2);
        // The representative tile of every band dies at cycle 1.
        let faults = FaultPlan::none()
            .with_tile_death(0, 1)
            .with_tile_death(16, 1)
            .with_tile_death(32, 1)
            .with_tile_death(48, 1);
        let rc = RouterConfig { faults, ..RouterConfig::default() };
        let r = route(&arch, &trace, &cfg, &rc);
        assert_eq!(r.completed, 0);
        assert_eq!(r.expired, trace.requests.len());
        assert_eq!(r.dead_bands, cfg.slots);
        assert_eq!(r.serving.tokens, 0, "no step can complete once every band is dead");
    }
}
