//! Discrete-event simulation core.
//!
//! The paper evaluates on GVSoC, an event-based full-platform simulator with
//! RTL-calibrated component models. We reproduce the same *accounting
//! granularity* — per DMA transfer, per NoC collective, per engine
//! invocation — with a dependency-driven discrete-event engine:
//!
//! 1. A dataflow (`crate::dataflow`) compiles a workload + architecture into
//!    a [`Program`]: a DAG of [`Op`]s, each bound to one resource
//!    (a tile's RedMulE / Spatz / DMA engine, an HBM channel, a NoC row/col
//!    bus) with a precomputed *occupancy* (resource hold time) and
//!    *latency* (pipeline delay until dependents may start).
//! 2. The [`engine`] executes the DAG: ops start when their dependencies
//!    have completed and their resource is free (FIFO per resource,
//!    earliest-ready first), exactly like queued DMA transfers and engine
//!    offloads behave in the modelled hardware.
//! 3. [`breakdown`] turns the executed schedule into the paper's runtime
//!    breakdown (Fig. 3/4): per-component time on a tracked critical tile,
//!    with the "not overlapped with RedMulE / Spatz" semantics of the
//!    paper's bar charts, plus global HBM-traffic and utilization metrics.
//!
//! # Sweep-scale hot path (§Perf)
//!
//! A Fig. 5-style co-exploration sweep pushes hundreds of `(arch,
//! workload, dataflow, group)` points through this engine, so the whole
//! path is organized around *reuse of repeated structure*:
//!
//! * **Template stamping** — the dataflow builders emit the per-head
//!   (Flash) / per-group-iteration (Flat) op subgraph once and instantiate
//!   every further repetition with [`Program::stamp_range`], which copies
//!   ops into preallocated buffers while offset-patching dependency ids
//!   (and, for Flash, rotating HBM-channel resources). Stamped and
//!   naively-built programs are op-for-op identical — asserted by tests.
//! * **Sealed dependents CSR** — [`Program::seal`] derives the dependents
//!   adjacency and initial in-degrees once at construction; every
//!   [`execute`] call then starts immediately instead of re-deriving them.
//! * **Indexed event queue** — [`queue::EventQueue`] is a monotone
//!   radix-bucket queue replacing the `BinaryHeap`, exploiting the
//!   near-monotonic completion times these schedules produce. The seed
//!   heap engine survives in [`reference`] and a differential test proves
//!   schedule equivalence.
//! * **Symmetry folding** — the Flash grid simulates ~1024 congruent tile
//!   streams (and every Flat group beyond the first repeats the same
//!   block schedule). With `dataflow::set_symmetry_folding` enabled (the
//!   default), builders emit all shared-resource ops (HBM channels, NoC
//!   buses) verbatim but collapse non-representative streams' private
//!   compute chains into single delay ops; the elided accounting travels
//!   in [`Program::fold`] and is re-added by the executors. The collapse
//!   is exact — folded and unfolded builds produce bit-identical
//!   `RunStats` (`tests/fold_differential.rs`) — because synchronous
//!   private chains are never resource-blocked and both engines schedule
//!   same-cycle-ready ops in op-id order.
//! * **[`arena`]** — [`ProgramArena`] recycles `ops`/`deps_pool`/CSR
//!   allocations across the experiments of a sweep (one arena per worker
//!   thread, used by `dataflow::run`).
//! * One level up, `crate::coordinator` memoizes experiment results by
//!   content key (including the folding switch) so identical points
//!   shared between figures simulate once.
//!
//! The `double_buffer` ablation pair is now derived from one builder
//! pass (`dataflow::double_buffer_programs`): the variants share their op
//! topology and differ only in K/V prefetch dependencies, so the second
//! program is a buffer clone + dependency retarget + reseal instead of a
//! full rebuild.
//!
//! # Sharded multi-worker execution (§Shard)
//!
//! FlatAttention's premise — heads, groups and tile-bands are independent
//! between fabric collectives — holds inside the simulator too, and
//! [`execute_parallel`] exploits it. [`Program::seal`] partitions every
//! DAG into *shards*: the connected components of the op graph restricted
//! to **private** resources (a resource whose ops all carry one owner
//! tile: a tile's RedMulE/Spatz/scalar engines, a folded stream's delay
//! chain, a group's barrier), plus one **shared** shard holding every op
//! on a *contended* resource (ops from ≥ 2 tiles: HBM channel FIFOs, NoC
//! row/column buses). Three structural invariants fall out of the
//! construction, not the heuristic: every op is in exactly one shard,
//! every resource is used by exactly one shard, and every cross-shard
//! dependency edge has an endpoint in the shared shard.
//!
//! Why cross-shard timestamps commute: the engine's schedule is fully
//! determined by, per resource, the `(ready time, generation, op id)`
//! order of its ops — the PR-2 tie-break argument. Since no resource
//! spans shards, that order is a *per-shard* property; shards influence
//! each other only through the completion times flowing across the
//! partition edges, i.e. through the shared shard's FIFO arbitration.
//! [`execute_parallel`] therefore advances all workers in epochs pinned
//! to the global minimum pending completion time: drain every completion
//! of that timestamp, exchange the cross-shard releases, then schedule
//! each shard's released ops in op-id order. Rounds map one-to-one onto
//! the serial engine's same-timestamp generations, so the PR-2 tie-break
//! localizes per shard and the parallel schedule is **bit-identical** to
//! the serial one — `RunStats`, breakdowns and traces alike
//! (`tests/parallel_differential.rs` pins this against both [`execute`]
//! and [`reference`] across dataflows × folding × paged batch programs ×
//! thread counts). The win is shape-dependent: epochs synchronize all
//! workers, so throughput comes from many shards being busy at the same
//! timestamp (congruent unfolded tile streams, multi-band scheduler
//! batches); sweep-level fan-out (`coordinator::run_all` /
//! `set_engine_threads`) composes with it.
//!
//! # Deterministic fault injection (§Fault)
//!
//! `fault::FaultPlan` describes timed hardware failures — HBM-channel
//! outage and derating windows, NoC bus slowdowns, whole-tile death — and
//! `engine::execute_faulted` applies them *inside* the scheduling step: an
//! outage window pushes an affected op's computed start past the window, a
//! derate window multiplies its occupancy, and a dead tile's ops are
//! dropped (their dependents then stall and are returned in a
//! `fault::FaultReport` instead of panicking).
//!
//! Why fault windows commute with the §Shard partition: every fault
//! decision is a pure function of (the op's fields, the owning resource's
//! local FIFO cursor, the epoch timestamp, the plan). A resource belongs
//! to exactly one shard, so the cursor is shard-local state the parallel
//! engine already reproduces exactly; the epoch timestamp is the global
//! `now` all workers agree on at fence 1; and the plan is immutable. No
//! fault decision reads any cross-shard state beyond what the fault-free
//! engine already exchanges, so injecting a plan preserves the serial ≡
//! parallel bit-identity — and `FaultPlan::none()` takes the identical
//! arithmetic with empty window tables, reproducing the fault-free
//! schedule bit for bit. Both properties are pinned across all dataflows ×
//! folding × thread counts by `tests/fault_differential.rs`.

pub mod arena;
pub mod breakdown;
pub mod engine;
pub mod fault;
pub mod program;
pub mod queue;
pub mod reference;
pub mod trace;

pub use arena::ProgramArena;
pub use breakdown::{Breakdown, Component, RunStats};
pub use engine::{
    execute, execute_faulted, execute_faulted_traced, execute_parallel, execute_parallel_traced,
    execute_traced,
};
pub use fault::{FaultPlan, FaultReport};
pub use program::{FoldStats, Op, OpId, Program, ResourceId, NO_TILE, SHARED_SHARD};
pub use queue::EventQueue;
pub use reference::{execute_reference, execute_reference_traced};

/// Simulation time in clock cycles (1 GHz in all paper configurations).
pub type Cycle = u64;
