//! Discrete-event simulation core.
//!
//! The paper evaluates on GVSoC, an event-based full-platform simulator with
//! RTL-calibrated component models. We reproduce the same *accounting
//! granularity* — per DMA transfer, per NoC collective, per engine
//! invocation — with a dependency-driven discrete-event engine:
//!
//! 1. A dataflow (`crate::dataflow`) compiles a workload + architecture into
//!    a [`Program`]: a DAG of [`Op`]s, each bound to one [`Resource`]
//!    (a tile's RedMulE / Spatz / DMA engine, an HBM channel, a NoC row/col
//!    bus) with a precomputed *occupancy* (resource hold time) and
//!    *latency* (pipeline delay until dependents may start).
//! 2. The [`engine`] executes the DAG: ops start when their dependencies
//!    have completed and their resource is free (FIFO per resource,
//!    earliest-ready first), exactly like queued DMA transfers and engine
//!    offloads behave in the modelled hardware.
//! 3. [`breakdown`] turns the executed schedule into the paper's runtime
//!    breakdown (Fig. 3/4): per-component time on a tracked critical tile,
//!    with the "not overlapped with RedMulE / Spatz" semantics of the
//!    paper's bar charts, plus global HBM-traffic and utilization metrics.

pub mod breakdown;
pub mod engine;
pub mod program;
pub mod trace;

pub use breakdown::{Breakdown, Component, RunStats};
pub use engine::{execute, execute_traced};
pub use program::{Op, OpId, Program, ResourceId};

/// Simulation time in clock cycles (1 GHz in all paper configurations).
pub type Cycle = u64;
