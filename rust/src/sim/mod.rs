//! Discrete-event simulation core.
//!
//! The paper evaluates on GVSoC, an event-based full-platform simulator with
//! RTL-calibrated component models. We reproduce the same *accounting
//! granularity* — per DMA transfer, per NoC collective, per engine
//! invocation — with a dependency-driven discrete-event engine:
//!
//! 1. A dataflow (`crate::dataflow`) compiles a workload + architecture into
//!    a [`Program`]: a DAG of [`Op`]s, each bound to one resource
//!    (a tile's RedMulE / Spatz / DMA engine, an HBM channel, a NoC row/col
//!    bus) with a precomputed *occupancy* (resource hold time) and
//!    *latency* (pipeline delay until dependents may start).
//! 2. The [`engine`] executes the DAG: ops start when their dependencies
//!    have completed and their resource is free (FIFO per resource,
//!    earliest-ready first), exactly like queued DMA transfers and engine
//!    offloads behave in the modelled hardware.
//! 3. [`breakdown`] turns the executed schedule into the paper's runtime
//!    breakdown (Fig. 3/4): per-component time on a tracked critical tile,
//!    with the "not overlapped with RedMulE / Spatz" semantics of the
//!    paper's bar charts, plus global HBM-traffic and utilization metrics.
//!
//! # Sweep-scale hot path (§Perf)
//!
//! Repeated structure is reused everywhere: template stamping
//! ([`Program::stamp_range`]) instantiates congruent op subgraphs from one
//! emission; [`Program::seal`] derives the dependents CSR once; the
//! monotone radix-bucket [`queue::EventQueue`] replaces the seed heap
//! (which survives in [`reference`], pinned equivalent by a differential
//! test); symmetry folding collapses congruent tile streams' private
//! compute chains exactly — folded and unfolded builds produce
//! bit-identical `RunStats` (`tests/fold_differential.rs`), the elided
//! accounting travelling in [`Program::fold`]; and [`ProgramArena`]
//! recycles allocations across a sweep. The full design essay lives in
//! `docs/ARCHITECTURE.md` §"The DES hot path".
//!
//! # Sharded multi-worker execution (§Shard)
//!
//! [`Program::seal`] partitions every DAG into private-resource shards
//! plus one shared shard (no resource spans shards; every cross-shard
//! edge touches the shared shard), and [`execute_parallel`] advances all
//! workers in epochs pinned to the global minimum pending completion
//! time. The engine's tie-break localizes per shard, so the parallel
//! schedule is **bit-identical** to the serial one — `RunStats`,
//! breakdowns and traces alike (`tests/parallel_differential.rs`). Why
//! cross-shard timestamps commute: `docs/ARCHITECTURE.md` §"Sharded
//! multi-worker execution".
//!
//! # Deterministic fault injection (§Fault)
//!
//! [`fault::FaultPlan`] describes timed hardware failures — HBM-channel
//! outages/derates, NoC slowdowns, tile deaths — and
//! [`engine::execute_faulted`] applies them *inside* the scheduling step
//! (dead tiles' dependents stall into a [`fault::FaultReport`]). Every
//! fault decision is shard-local, so injection preserves the serial ≡
//! parallel bit-identity, and `FaultPlan::none()` reproduces the
//! fault-free schedule bit for bit (`tests/fault_differential.rs`). Full
//! argument: `docs/ARCHITECTURE.md` §"Deterministic fault injection".

pub mod arena;
pub mod breakdown;
pub mod engine;
pub mod fault;
pub mod program;
pub mod queue;
pub mod reference;
pub mod trace;

pub use arena::ProgramArena;
pub use breakdown::{Breakdown, Component, RunStats};
pub use engine::{
    execute, execute_faulted, execute_faulted_traced, execute_parallel, execute_parallel_traced,
    execute_traced,
};
pub use fault::{FaultPlan, FaultReport};
pub use program::{FoldStats, Op, OpId, Program, ResourceId, NO_TILE, SHARED_SHARD};
pub use queue::EventQueue;
pub use reference::{execute_reference, execute_reference_traced};

/// Simulation time in clock cycles (1 GHz in all paper configurations).
pub type Cycle = u64;
