//! Op-graph program representation.
//!
//! A [`Program`] is a DAG of [`Op`]s over a set of named [`ResourceId`]s.
//! Dataflow builders (`crate::dataflow`) emit one program per experiment;
//! the engine executes it. Ops model everything with a *time cost*:
//! engine invocations, DMA transfers, NoC collectives, synchronization.

use super::breakdown::Component;
use super::Cycle;

/// Index of an op within its program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub u32);

/// Index of a resource within its program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ResourceId(pub u32);

/// Sentinel tile id for ops not owned by any tile (e.g. pure barriers).
pub const NO_TILE: u32 = u32::MAX;

/// Accounting for work elided by symmetry folding (see `crate::dataflow`
/// on the fold design). Builders that collapse a congruent stream's
/// private compute chain into single delay ops record here the op count
/// and engine busy cycles of the elided ops; the executors add these
/// totals to their linear counters, so a folded program reports the same
/// grid-wide `RunStats` as its unfolded equivalent.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FoldStats {
    /// Elided ops, net of the synthetic delay ops emitted in their place.
    pub ops: u64,
    /// RedMulE busy cycles carried by elided ops (synthetic delay ops are
    /// `Component::Other` and contribute nothing to the engine counters).
    pub redmule_busy: u64,
    /// Spatz busy cycles carried by elided ops.
    pub spatz_busy: u64,
    /// Number of folded (collapsed) tile/group streams.
    pub streams: u64,
}

impl FoldStats {
    /// Field-wise difference `self - before` — used by the builders to
    /// capture a block template's fold delta for later stamping.
    pub(crate) fn delta_since(&self, before: &FoldStats) -> FoldStats {
        FoldStats {
            ops: self.ops - before.ops,
            redmule_busy: self.redmule_busy - before.redmule_busy,
            spatz_busy: self.spatz_busy - before.spatz_busy,
            streams: self.streams - before.streams,
        }
    }

    /// Field-wise accumulate (applied once per stamped template instance).
    pub(crate) fn accumulate(&mut self, d: &FoldStats) {
        self.ops += d.ops;
        self.redmule_busy += d.redmule_busy;
        self.spatz_busy += d.spatz_busy;
        self.streams += d.streams;
    }
}

/// One schedulable unit of work.
#[derive(Debug, Clone)]
pub struct Op {
    /// Resource this op executes on (FIFO-serialized).
    pub resource: ResourceId,
    /// Cycles the resource is held. Back-to-back ops on the same resource
    /// are spaced by at least this much.
    pub occupancy: Cycle,
    /// Additional pipeline latency after the resource is released before
    /// dependents observe completion (e.g. HBM access latency, NoC
    /// propagation). The resource can serve the next request meanwhile.
    pub latency: Cycle,
    /// Accounting category for the paper's runtime breakdowns.
    pub component: Component,
    /// Owning tile (global flat id) for per-tile accounting; `NO_TILE` if
    /// the op is not attributable to a tile.
    pub tile: u32,
    /// Bytes moved to/from HBM by this op (0 for non-HBM ops); used for
    /// traffic accounting and bandwidth-utilization metrics.
    pub hbm_bytes: u64,
    /// Dependency slice in the program's CSR pool (see [`Program::deps_of`]).
    pub(crate) deps_start: u32,
    pub(crate) deps_len: u32,
}

/// The shard id of the shared (contended-resource) shard — see
/// [`Program::seal`]'s §Shard notes and `crate::sim`'s sharding essay.
pub const SHARED_SHARD: u32 = 0;

/// Recycled backing buffers of a [`Program`] — everything a
/// [`crate::sim::ProgramArena`] keeps alive between the experiments of a
/// sweep (op table, dependency pool, dependents CSR, shard CSR).
#[derive(Debug, Default)]
pub(crate) struct ProgramBuffers {
    /// Op table.
    pub ops: Vec<Op>,
    /// Flattened dependency lists.
    pub deps_pool: Vec<u32>,
    /// CSR row starts into `out_edges`.
    pub out_start: Vec<u32>,
    /// Dependents CSR.
    pub out_edges: Vec<u32>,
    /// Ops with zero in-degree.
    pub indeg0: Vec<u32>,
    /// Op -> shard.
    pub shard_of: Vec<u32>,
    /// CSR row starts into `shard_ops`.
    pub shard_start: Vec<u32>,
    /// Shard -> op list CSR.
    pub shard_ops: Vec<u32>,
    /// Resource -> owning shard.
    pub res_shard: Vec<u32>,
    /// Resource -> dense per-shard slot.
    pub res_dense: Vec<u32>,
    /// Resources per shard.
    pub shard_res_count: Vec<u32>,
}

impl ProgramBuffers {
    /// Clear every buffer, retaining capacity.
    pub fn clear(&mut self) {
        let ProgramBuffers {
            ops,
            deps_pool,
            out_start,
            out_edges,
            indeg0,
            shard_of,
            shard_start,
            shard_ops,
            res_shard,
            res_dense,
            shard_res_count,
        } = self;
        ops.clear();
        deps_pool.clear();
        out_start.clear();
        out_edges.clear();
        indeg0.clear();
        shard_of.clear();
        shard_start.clear();
        shard_ops.clear();
        res_shard.clear();
        res_dense.clear();
        shard_res_count.clear();
    }
}

/// A complete op DAG plus its resource table. Dependencies live in one
/// flat CSR pool (`deps_pool`) instead of per-op `Vec`s: programs have
/// hundreds of thousands of ops and the per-op allocation dominated build
/// time before this layout (§Perf).
///
/// After construction, [`Program::seal`] derives the *dependents* CSR
/// (`out_start`/`out_edges`) and the initial in-degree vector once, so
/// every subsequent [`crate::sim::execute`] call starts immediately
/// instead of re-deriving them (§Perf: the executor used to rebuild this
/// on every run). Builders seal automatically; hand-built programs that
/// skip `seal` still execute through a fallback that derives the CSR
/// locally.
///
/// §Shard: `seal` additionally partitions the DAG into event-loop
/// *shards* for [`crate::sim::execute_parallel`]: the connected
/// components of the op graph over *private* resources (a resource is
/// private when every op on it carries the same owner tile — a tile's
/// engines, a stream's fold-delay chain, a group's barrier), plus one
/// shared shard ([`SHARED_SHARD`]) holding every op on a *contended*
/// resource (ops from ≥ 2 distinct tiles: HBM channel FIFOs, NoC buses).
/// By construction every resource is used by exactly one shard and every
/// cross-shard dependency edge has an endpoint in the shared shard —
/// the invariants the parallel executor's exactness proof rests on (see
/// `crate::sim`'s sharding essay and `tests/parallel_differential.rs`).
#[derive(Debug, Default)]
pub struct Program {
    pub(crate) ops: Vec<Op>,
    pub(crate) deps_pool: Vec<u32>,
    pub(crate) n_resources: u32,
    /// Total useful FLOPs represented by the program (set by the builder;
    /// used for utilization metrics, not timing).
    pub flops: u64,
    /// Accounting for ops elided by symmetry folding (zero when unfolded).
    pub fold: FoldStats,
    /// Dependents CSR row offsets (`len == ops.len() + 1` when sealed).
    pub(crate) out_start: Vec<u32>,
    /// Dependents CSR edge targets (op indices).
    pub(crate) out_edges: Vec<u32>,
    /// Initial in-degree of every op (== `deps_len`), cloned per execution.
    pub(crate) indeg0: Vec<u32>,
    /// Per-op shard id (§Shard; empty until sealed). Shard 0 is shared.
    pub(crate) shard_of: Vec<u32>,
    /// Shard CSR row offsets over `shard_ops` (`n_shards + 1` when sealed).
    pub(crate) shard_start: Vec<u32>,
    /// Shard CSR op ids, ascending within each shard.
    pub(crate) shard_ops: Vec<u32>,
    /// Per-resource owning shard (`u32::MAX` for unused resources).
    pub(crate) res_shard: Vec<u32>,
    /// Per-resource dense index within its owning shard's resource set.
    pub(crate) res_dense: Vec<u32>,
    /// Per-shard count of owned resources.
    pub(crate) shard_res_count: Vec<u32>,
    pub(crate) sealed: bool,
}

impl Program {
    /// An empty, unsealed program.
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a `Program` over buffers recycled by a
    /// [`crate::sim::ProgramArena`]. All buffers arrive cleared.
    pub(crate) fn from_buffers(bufs: ProgramBuffers) -> Self {
        Self {
            ops: bufs.ops,
            deps_pool: bufs.deps_pool,
            n_resources: 0,
            flops: 0,
            fold: FoldStats::default(),
            out_start: bufs.out_start,
            out_edges: bufs.out_edges,
            indeg0: bufs.indeg0,
            shard_of: bufs.shard_of,
            shard_start: bufs.shard_start,
            shard_ops: bufs.shard_ops,
            res_shard: bufs.res_shard,
            res_dense: bufs.res_dense,
            shard_res_count: bufs.shard_res_count,
            sealed: false,
        }
    }

    /// Decompose into raw buffers for arena recycling.
    pub(crate) fn into_buffers(self) -> ProgramBuffers {
        ProgramBuffers {
            ops: self.ops,
            deps_pool: self.deps_pool,
            out_start: self.out_start,
            out_edges: self.out_edges,
            indeg0: self.indeg0,
            shard_of: self.shard_of,
            shard_start: self.shard_start,
            shard_ops: self.shard_ops,
            res_shard: self.res_shard,
            res_dense: self.res_dense,
            shard_res_count: self.shard_res_count,
        }
    }

    /// Allocate a fresh resource.
    pub fn resource(&mut self) -> ResourceId {
        let id = ResourceId(self.n_resources);
        self.n_resources += 1;
        id
    }

    /// Allocate `n` fresh resources.
    pub fn resources(&mut self, n: usize) -> Vec<ResourceId> {
        (0..n).map(|_| self.resource()).collect()
    }

    /// Copy this program's op table, dependency pool and resource count
    /// into a fresh *unsealed* program, ready for further `op` /
    /// `stamp_range` appends. `flops` and fold accounting carry over; the
    /// sealed CSRs are not copied (the clone re-derives them at `seal`).
    ///
    /// This is the cross-kernel composition primitive: the attention
    /// builders allocate the HBM channel resources first and seal on
    /// return, so a layer composer (see `crate::dataflow::layer`) clones
    /// the sealed attention program unsealed and appends the projection /
    /// FFN GEMM kernels behind a barrier, reusing the channel resources
    /// by index.
    pub fn unsealed_clone(&self) -> Program {
        Program {
            ops: self.ops.clone(),
            deps_pool: self.deps_pool.clone(),
            n_resources: self.n_resources,
            flops: self.flops,
            fold: self.fold,
            ..Program::default()
        }
    }

    /// Append an op; returns its id.
    #[allow(clippy::too_many_arguments)]
    pub fn op(
        &mut self,
        resource: ResourceId,
        occupancy: Cycle,
        latency: Cycle,
        component: Component,
        tile: u32,
        hbm_bytes: u64,
        deps: &[OpId],
    ) -> OpId {
        debug_assert!(resource.0 < self.n_resources, "unknown resource");
        let id = OpId(self.ops.len() as u32);
        debug_assert!(deps.iter().all(|d| d.0 < id.0), "deps must precede op");
        let deps_start = self.deps_pool.len() as u32;
        self.deps_pool.extend(deps.iter().map(|d| d.0));
        self.sealed = false;
        self.ops.push(Op {
            resource,
            occupancy,
            latency,
            component,
            tile,
            hbm_bytes,
            deps_start,
            deps_len: deps.len() as u32,
        });
        id
    }

    /// Append a shifted copy of the op range `[src_start, src_start +
    /// src_len)` — the template-stamping primitive used by the dataflow
    /// builders (§Perf). Dependencies pointing *inside* the source range
    /// are offset to the copy; dependencies pointing *before* it (the
    /// template's single external predecessor, e.g. the previous block's
    /// barrier) are replaced by `ext_dep`. Resources, timings and
    /// accounting fields are copied verbatim; callers patch per-instance
    /// differences afterwards. Returns the index of the first stamped op.
    pub fn stamp_range(&mut self, src_start: u32, src_len: u32, ext_dep: OpId) -> u32 {
        let new_base = self.ops.len() as u32;
        // Real asserts (not debug): a bad stamp range copies garbage deps
        // that the release build would then simulate silently — the same
        // release-critical class as `EventQueue::push` monotonicity.
        assert!(src_start + src_len <= new_base, "stamp_range: source range out of bounds");
        assert!(ext_dep.0 < new_base, "stamp_range: external dep must already exist");
        let delta = new_base - src_start;
        self.sealed = false;
        self.ops.reserve(src_len as usize);
        for idx in src_start..src_start + src_len {
            let src = self.ops[idx as usize].clone();
            let new_deps_start = self.deps_pool.len() as u32;
            for k in src.deps_start..src.deps_start + src.deps_len {
                let d = self.deps_pool[k as usize];
                let nd = if d >= src_start { d + delta } else { ext_dep.0 };
                self.deps_pool.push(nd);
            }
            self.ops.push(Op {
                deps_start: new_deps_start,
                ..src
            });
        }
        new_base
    }

    /// Derive the dependents CSR, initial in-degrees and the shard map
    /// (§Shard) so executions can reuse them. Idempotent; implicitly
    /// invalidated by further `op` / `stamp_range` calls. Builds *in
    /// place* into the program's (possibly arena-recycled) buffers — no
    /// allocation once capacity exists.
    pub fn seal(&mut self) {
        if self.sealed {
            return;
        }
        let mut out_start = std::mem::take(&mut self.out_start);
        let mut out_edges = std::mem::take(&mut self.out_edges);
        let mut indeg0 = std::mem::take(&mut self.indeg0);
        Self::dependents_into(
            &self.ops,
            &self.deps_pool,
            &mut out_start,
            &mut out_edges,
            &mut indeg0,
        );
        self.out_start = out_start;
        self.out_edges = out_edges;
        self.indeg0 = indeg0;

        let mut shard_of = std::mem::take(&mut self.shard_of);
        let mut shard_start = std::mem::take(&mut self.shard_start);
        let mut shard_ops = std::mem::take(&mut self.shard_ops);
        let mut res_shard = std::mem::take(&mut self.res_shard);
        let mut res_dense = std::mem::take(&mut self.res_dense);
        let mut shard_res_count = std::mem::take(&mut self.shard_res_count);
        Self::shards_into(
            &self.ops,
            &self.deps_pool,
            self.n_resources as usize,
            &mut shard_of,
            &mut shard_start,
            &mut shard_ops,
            &mut res_shard,
            &mut res_dense,
            &mut shard_res_count,
        );
        self.shard_of = shard_of;
        self.shard_start = shard_start;
        self.shard_ops = shard_ops;
        self.res_shard = res_shard;
        self.res_dense = res_dense;
        self.shard_res_count = shard_res_count;
        self.sealed = true;

        // §Analysis: every sealed program re-verifies its own invariants
        // (acyclicity, shard wall, fold-chain precondition) in debug
        // builds, and in release builds under the CLI's `--verify` flag.
        if cfg!(debug_assertions) || crate::analysis::release_verify() {
            // Under `--profile` the verify cost is reported separately from
            // the rest of seal (timer is None when profiling is off).
            let vt = crate::telemetry::profile::verify_timer();
            crate::analysis::assert_verified(self);
            crate::telemetry::profile::verify_done(vt);
        }
    }

    /// Partition the DAG into event-loop shards (§Shard on [`Program`]).
    ///
    /// 1. A resource is *contended* iff its ops carry ≥ 2 distinct owner
    ///    tiles (HBM channels and NoC buses serve many tiles; a tile's
    ///    engines, a folded stream's delay chain and a group's barrier
    ///    resource do not). The classification is a partition *heuristic*
    ///    only — correctness of the parallel executor never depends on it,
    ///    because the construction below keeps each resource's ops inside
    ///    one shard either way.
    /// 2. Union-find over ops: ops on the same private resource are
    ///    unioned, and a dependency edge unions its endpoints when both
    ///    sit on private resources. Ops on contended resources join the
    ///    shared shard ([`SHARED_SHARD`] = 0) and never union, so every
    ///    cross-shard edge has an endpoint in the shared shard.
    /// 3. Private components become shards `1..n_shards`, materialized as
    ///    a CSR (ascending op ids per shard) plus per-resource
    ///    `(owning shard, dense index)` so each shard's executor keeps a
    ///    compact `res_free` cursor table.
    #[allow(clippy::too_many_arguments)]
    fn shards_into(
        ops: &[Op],
        deps_pool: &[u32],
        n_resources: usize,
        shard_of: &mut Vec<u32>,
        shard_start: &mut Vec<u32>,
        shard_ops: &mut Vec<u32>,
        res_shard: &mut Vec<u32>,
        res_dense: &mut Vec<u32>,
        shard_res_count: &mut Vec<u32>,
    ) {
        const NONE: u32 = u32::MAX;
        let n = ops.len();

        // Path-halving find.
        fn find(parent: &mut [u32], mut x: u32) -> u32 {
            loop {
                let p = parent[x as usize];
                if p == x {
                    return x;
                }
                let gp = parent[p as usize];
                parent[x as usize] = gp;
                x = gp;
            }
        }

        // 1. Contended-resource classification. Tiles are stored +1 so 0
        // can mean "unseen" (NO_TILE is a valid owner value).
        let mut seen_tile: Vec<u64> = vec![0; n_resources];
        let mut contended: Vec<bool> = vec![false; n_resources];
        for op in ops {
            let r = op.resource.0 as usize;
            let t = op.tile as u64 + 1;
            if seen_tile[r] == 0 {
                seen_tile[r] = t;
            } else if seen_tile[r] != t {
                contended[r] = true;
            }
        }

        // 2. Union-find over private ops.
        let mut parent: Vec<u32> = (0..n as u32).collect();
        let mut last_on_res: Vec<u32> = vec![NONE; n_resources];
        for (i, op) in ops.iter().enumerate() {
            let r = op.resource.0 as usize;
            if contended[r] {
                continue;
            }
            let iu = i as u32;
            if last_on_res[r] != NONE {
                let a = find(&mut parent, iu);
                let b = find(&mut parent, last_on_res[r]);
                if a != b {
                    parent[a as usize] = b;
                }
            }
            last_on_res[r] = iu;
            let (s, l) = (op.deps_start as usize, op.deps_len as usize);
            for &d in &deps_pool[s..s + l] {
                if !contended[ops[d as usize].resource.0 as usize] {
                    let a = find(&mut parent, iu);
                    let b = find(&mut parent, d);
                    if a != b {
                        parent[a as usize] = b;
                    }
                }
            }
        }

        // 3. Shard ids: shared = 0, private components numbered in
        // first-op order (deterministic).
        shard_of.clear();
        shard_of.resize(n, 0);
        let mut root_id: Vec<u32> = vec![NONE; n];
        let mut next = 1u32;
        for (i, op) in ops.iter().enumerate() {
            if contended[op.resource.0 as usize] {
                shard_of[i] = SHARED_SHARD;
            } else {
                let root = find(&mut parent, i as u32) as usize;
                if root_id[root] == NONE {
                    root_id[root] = next;
                    next += 1;
                }
                shard_of[i] = root_id[root];
            }
        }
        let n_shards = next as usize;

        // Shard CSR (counting sort in op-id order, then shift back — same
        // cursor trick as `dependents_into`).
        shard_start.clear();
        shard_start.resize(n_shards + 1, 0);
        for &s in shard_of.iter() {
            shard_start[s as usize + 1] += 1;
        }
        for i in 0..n_shards {
            shard_start[i + 1] += shard_start[i];
        }
        shard_ops.clear();
        shard_ops.resize(n, 0);
        for (i, &s) in shard_of.iter().enumerate() {
            shard_ops[shard_start[s as usize] as usize] = i as u32;
            shard_start[s as usize] += 1;
        }
        for i in (1..n_shards).rev() {
            shard_start[i] = shard_start[i - 1];
        }
        if n_shards > 0 {
            shard_start[0] = 0;
        }

        // Per-resource owning shard + dense per-shard index.
        res_shard.clear();
        res_shard.resize(n_resources, NONE);
        res_dense.clear();
        res_dense.resize(n_resources, 0);
        shard_res_count.clear();
        shard_res_count.resize(n_shards, 0);
        for (i, op) in ops.iter().enumerate() {
            let r = op.resource.0 as usize;
            if res_shard[r] == NONE {
                let s = shard_of[i];
                res_shard[r] = s;
                res_dense[r] = shard_res_count[s as usize];
                shard_res_count[s as usize] += 1;
            } else {
                // Routed through the verifier: `crate::analysis`'s
                // shard-resource-span check re-proves this on every seal
                // (debug builds and `--verify` release runs), with a
                // diagnostic naming the resource and both shards.
                debug_assert_eq!(res_shard[r], shard_of[i], "resource {r} spans shards");
            }
        }
    }

    /// Compute `(out_start, out_edges, indeg0)` for the current DAG into
    /// fresh buffers — the executor's unsealed-program fallback.
    #[allow(clippy::type_complexity)]
    pub(crate) fn build_dependents_csr(&self) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
        let (mut out_start, mut out_edges, mut indeg0) = (Vec::new(), Vec::new(), Vec::new());
        Self::dependents_into(
            &self.ops,
            &self.deps_pool,
            &mut out_start,
            &mut out_edges,
            &mut indeg0,
        );
        (out_start, out_edges, indeg0)
    }

    /// Fill the dependents CSR into the given buffers (cleared first,
    /// capacity retained). Uses the classic in-place cursor trick: the row
    /// offsets double as fill cursors and are shifted back afterwards, so
    /// no scratch allocation is needed.
    fn dependents_into(
        ops: &[Op],
        deps_pool: &[u32],
        out_start: &mut Vec<u32>,
        out_edges: &mut Vec<u32>,
        indeg0: &mut Vec<u32>,
    ) {
        let n = ops.len();
        indeg0.clear();
        indeg0.reserve(n);
        out_start.clear();
        out_start.resize(n + 1, 0);
        for op in ops {
            indeg0.push(op.deps_len);
            let (s, l) = (op.deps_start as usize, op.deps_len as usize);
            for &d in &deps_pool[s..s + l] {
                out_start[d as usize + 1] += 1;
            }
        }
        for i in 0..n {
            out_start[i + 1] += out_start[i];
        }
        let total = out_start[n] as usize;
        out_edges.clear();
        out_edges.resize(total, 0);
        for (i, op) in ops.iter().enumerate() {
            let (s, l) = (op.deps_start as usize, op.deps_len as usize);
            for &d in &deps_pool[s..s + l] {
                let di = d as usize;
                out_edges[out_start[di] as usize] = i as u32;
                out_start[di] += 1;
            }
        }
        // The cursors now hold each row's *end*; shift right to restore
        // the start offsets (out_start[n] is untouched and equals total).
        for i in (1..n).rev() {
            out_start[i] = out_start[i - 1];
        }
        if n > 0 {
            out_start[0] = 0;
        }
    }

    /// True once [`Program::seal`] has run (and no ops were added since).
    pub fn is_sealed(&self) -> bool {
        self.sealed
    }

    /// Forget the sealed state so the next [`crate::sim::execute`] takes
    /// the derive-per-run fallback (and the next [`Program::seal`] rebuilds).
    /// Benchmarking/testing aid — e.g. `sim_hotpath` uses it to price the
    /// CSR build when reconstructing the seed baseline.
    pub fn unseal(&mut self) {
        self.sealed = false;
    }

    /// §Incremental: overwrite this sealed program's *cost* fields
    /// (occupancy, latency, `hbm_bytes`, plus `flops`/`fold`) with those
    /// of `src`, keeping the sealed dependents + §Shard CSRs — legal
    /// because both partitions depend only on op *structure* (resource,
    /// component, tile, dependency topology), which is verified identical
    /// op for op first. Returns `false` without mutating anything when
    /// the structures differ; the caller must then rebuild and reseal.
    pub(crate) fn patch_costs_from(&mut self, src: &Program) -> bool {
        debug_assert!(self.sealed, "patch_costs_from targets a sealed program");
        if self.ops.len() != src.ops.len() || self.n_resources != src.n_resources {
            return false;
        }
        for (a, b) in self.ops.iter().zip(src.ops.iter()) {
            if a.resource != b.resource
                || a.component != b.component
                || a.tile != b.tile
                || self.deps_pool[a.deps_start as usize..(a.deps_start + a.deps_len) as usize]
                    != src.deps_pool[b.deps_start as usize..(b.deps_start + b.deps_len) as usize]
            {
                return false;
            }
        }
        for (a, b) in self.ops.iter_mut().zip(src.ops.iter()) {
            a.occupancy = b.occupancy;
            a.latency = b.latency;
            a.hbm_bytes = b.hbm_bytes;
        }
        self.flops = src.flops;
        self.fold = src.fold;
        true
    }

    /// Dependency ids of an op (raw op indices).
    #[inline]
    pub fn deps_of(&self, op: &Op) -> &[u32] {
        &self.deps_pool[op.deps_start as usize..(op.deps_start + op.deps_len) as usize]
    }

    /// Dependents CSR `(row offsets, edge targets)` — sealed programs only.
    #[inline]
    pub(crate) fn dependents_csr(&self) -> (&[u32], &[u32]) {
        debug_assert!(self.sealed, "dependents_csr requires a sealed program");
        (&self.out_start, &self.out_edges)
    }

    /// Number of event-loop shards (§Shard): the shared shard plus one per
    /// private connected component. Zero until sealed — the shard vectors
    /// linger physically after a sealed program is mutated (`op` /
    /// `stamp_range` only reset the flag), so every accessor gates on
    /// `sealed` rather than handing out the stale partition.
    pub fn num_shards(&self) -> usize {
        if self.sealed {
            self.shard_start.len().saturating_sub(1)
        } else {
            0
        }
    }

    /// Per-op shard ids (§Shard; empty until sealed). [`SHARED_SHARD`]
    /// holds every op on a contended resource.
    pub fn op_shards(&self) -> &[u32] {
        if self.sealed {
            &self.shard_of
        } else {
            &[]
        }
    }

    /// Op ids owned by one shard, ascending — sealed programs only.
    pub fn shard_op_list(&self, shard: u32) -> &[u32] {
        debug_assert!(self.sealed, "shard_op_list requires a sealed program");
        let s = shard as usize;
        &self.shard_ops[self.shard_start[s] as usize..self.shard_start[s + 1] as usize]
    }

    /// Per-resource owning shard ids (`u32::MAX` for resources no op
    /// uses; empty until sealed). Every resource belongs to exactly one
    /// shard — the invariant the parallel executor's per-shard FIFO
    /// cursors rely on.
    pub fn resource_shards(&self) -> &[u32] {
        if self.sealed {
            &self.res_shard
        } else {
            &[]
        }
    }

    /// Number of resources owned by `shard` (sealed programs only).
    #[inline]
    pub(crate) fn shard_res_len(&self, shard: u32) -> usize {
        self.shard_res_count[shard as usize] as usize
    }

    /// Dense index of a resource within its owning shard's cursor table
    /// (sealed programs only).
    #[inline]
    pub(crate) fn res_slot(&self, r: ResourceId) -> usize {
        self.res_dense[r.0 as usize] as usize
    }

    /// Ops added so far.
    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    /// Distinct resources referenced.
    pub fn num_resources(&self) -> usize {
        self.n_resources as usize
    }

    /// The op table.
    pub fn ops(&self) -> &[Op] {
        &self.ops
    }

    /// Validate DAG invariants (deps precede ops, resources in range).
    /// Builders are structurally correct by construction; this is used by
    /// tests and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        for (i, op) in self.ops.iter().enumerate() {
            if op.resource.0 >= self.n_resources {
                return Err(format!("op {i}: resource out of range"));
            }
            for &d in self.deps_of(op) {
                if d as usize >= i {
                    return Err(format!("op {i}: dep {d} does not precede it"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 10, 0, Component::RedMule, 0, 0, &[]);
        let b = p.op(r, 5, 2, Component::Spatz, 0, 0, &[a]);
        let _c = p.op(r, 1, 0, Component::Other, NO_TILE, 0, &[a, b]);
        assert_eq!(p.num_ops(), 3);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn seal_builds_dependents_csr_once() {
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 1, 0, Component::RedMule, 0, 0, &[]);
        let b = p.op(r, 1, 0, Component::Spatz, 0, 0, &[a]);
        let _c = p.op(r, 1, 0, Component::Other, NO_TILE, 0, &[a, b]);
        assert!(!p.is_sealed());
        p.seal();
        assert!(p.is_sealed());
        // a's dependents: b and c; b's: c; c's: none.
        assert_eq!(p.out_start, vec![0, 2, 3, 3]);
        assert_eq!(p.out_edges, vec![1, 2, 2]);
        assert_eq!(p.indeg0, vec![0, 1, 2]);
        // Adding an op invalidates the seal.
        p.op(r, 1, 0, Component::Other, NO_TILE, 0, &[b]);
        assert!(!p.is_sealed());
        p.seal();
        assert_eq!(p.indeg0, vec![0, 1, 2, 1]);
    }

    #[test]
    fn stamp_range_offsets_internal_and_replaces_external_deps() {
        let mut p = Program::new();
        let r = p.resource();
        let barrier0 = p.op(r, 1, 0, Component::Other, NO_TILE, 0, &[]);
        // Template "block": two ops, externally depending on barrier0.
        let t0 = p.op(r, 10, 0, Component::RedMule, 0, 64, &[barrier0]);
        let t1 = p.op(r, 5, 2, Component::Spatz, 0, 0, &[t0]);
        let base = t0.0;
        let len = 2;
        // Stamp a second instance gated on t1 (the new "previous barrier").
        let new_base = p.stamp_range(base, len, t1);
        assert_eq!(new_base, 3);
        assert_eq!(p.num_ops(), 5);
        let ops = p.ops();
        assert_eq!(ops[3].occupancy, 10);
        assert_eq!(ops[3].hbm_bytes, 64);
        assert_eq!(p.deps_of(&ops[3]), &[t1.0]); // external → t1
        assert_eq!(ops[4].occupancy, 5);
        assert_eq!(ops[4].latency, 2);
        assert_eq!(p.deps_of(&ops[4]), &[3]); // internal, offset by delta
        assert!(p.validate().is_ok());
    }

    // Regression for the promoted (release-mode) stamp_range asserts: an
    // out-of-bounds source range must panic in every build profile, not
    // copy garbage dependencies that only a debug build would catch.
    #[test]
    #[should_panic(expected = "stamp_range: source range out of bounds")]
    fn stamp_range_rejects_out_of_bounds_source() {
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 1, 0, Component::Other, NO_TILE, 0, &[]);
        let _ = p.stamp_range(a.0, 2, a); // only 1 op exists
    }

    #[test]
    #[should_panic(expected = "stamp_range: external dep must already exist")]
    fn stamp_range_rejects_future_external_dep() {
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 1, 0, Component::Other, NO_TILE, 0, &[]);
        let _ = p.stamp_range(a.0, 1, OpId(7)); // dep id past the ops built so far
    }

    #[test]
    fn seal_partitions_ops_into_shards() {
        // Two private chains on per-tile engines, coupled only through one
        // contended resource (ops from two distinct tiles).
        let mut p = Program::new();
        let chan = p.resource();
        let eng0 = p.resource();
        let eng1 = p.resource();
        let l0 = p.op(chan, 2, 1, Component::HbmAccess, 0, 64, &[]);
        let c0 = p.op(eng0, 5, 0, Component::RedMule, 0, 0, &[l0]);
        let l1 = p.op(chan, 2, 1, Component::HbmAccess, 1, 64, &[c0]);
        let c1 = p.op(eng1, 5, 0, Component::Spatz, 1, 0, &[l1]);
        p.seal();
        assert_eq!(p.num_shards(), 3); // shared + two private chains
        let sh = p.op_shards();
        assert_eq!(sh[l0.0 as usize], SHARED_SHARD);
        assert_eq!(sh[l1.0 as usize], SHARED_SHARD);
        assert_ne!(sh[c0.0 as usize], SHARED_SHARD);
        assert_ne!(sh[c1.0 as usize], SHARED_SHARD);
        assert_ne!(sh[c0.0 as usize], sh[c1.0 as usize]);
        assert_eq!(p.shard_op_list(SHARED_SHARD), &[l0.0, l1.0]);
        // Resource ownership follows the op partition.
        assert_eq!(p.resource_shards()[chan.0 as usize], SHARED_SHARD);
        assert_eq!(p.resource_shards()[eng0.0 as usize], sh[c0.0 as usize]);
        assert_eq!(p.resource_shards()[eng1.0 as usize], sh[c1.0 as usize]);
        assert_eq!(p.shard_res_len(SHARED_SHARD), 1);
    }

    #[test]
    fn shard_accessors_go_empty_when_a_sealed_program_is_mutated() {
        // Mutating a sealed program resets only the flag; the shard
        // accessors must not serve the stale partition.
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 1, 0, Component::RedMule, 0, 0, &[]);
        p.seal();
        assert_eq!(p.num_shards(), 2);
        assert_eq!(p.op_shards().len(), 1);
        p.op(r, 1, 0, Component::Spatz, 0, 0, &[a]);
        assert!(!p.is_sealed());
        assert_eq!(p.num_shards(), 0);
        assert!(p.op_shards().is_empty());
        assert!(p.resource_shards().is_empty());
        p.seal();
        assert_eq!(p.num_shards(), 2);
        assert_eq!(p.op_shards().len(), 2);
    }

    #[test]
    fn barrier_unions_the_streams_it_joins() {
        // A private sync op (single owner value) depended on by several
        // per-tile chains merges them into one shard: they are genuinely
        // coupled, and the sync resource stays single-owner.
        let mut p = Program::new();
        let rs = p.resources(3);
        let sync = p.resource();
        let a = p.op(rs[0], 4, 0, Component::RedMule, 0, 0, &[]);
        let b = p.op(rs[1], 6, 0, Component::RedMule, 1, 0, &[]);
        let bar = p.op(sync, 0, 0, Component::Other, NO_TILE, 0, &[a, b]);
        let c = p.op(rs[2], 2, 0, Component::Spatz, 2, 0, &[bar]);
        p.seal();
        // No contended resource at all: one private component, empty
        // shared shard.
        assert_eq!(p.num_shards(), 2);
        assert!(p.shard_op_list(SHARED_SHARD).is_empty());
        let sh = p.op_shards();
        assert!(sh.iter().all(|&s| s == sh[a.0 as usize] && s != SHARED_SHARD));
        let _ = c;
    }

    #[test]
    fn validate_catches_bad_dep() {
        let mut p = Program::new();
        let r = p.resource();
        // Manually construct an invalid forward dependency.
        p.deps_pool.push(5);
        p.ops.push(Op {
            resource: r,
            occupancy: 1,
            latency: 0,
            component: Component::Other,
            tile: NO_TILE,
            hbm_bytes: 0,
            deps_start: 0,
            deps_len: 1,
        });
        assert!(p.validate().is_err());
    }
}
