//! Op-graph program representation.
//!
//! A [`Program`] is a DAG of [`Op`]s over a set of named [`ResourceId`]s.
//! Dataflow builders (`crate::dataflow`) emit one program per experiment;
//! the engine executes it. Ops model everything with a *time cost*:
//! engine invocations, DMA transfers, NoC collectives, synchronization.

use super::breakdown::Component;
use super::Cycle;

/// Index of an op within its program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub u32);

/// Index of a resource within its program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ResourceId(pub u32);

/// Sentinel tile id for ops not owned by any tile (e.g. pure barriers).
pub const NO_TILE: u32 = u32::MAX;

/// Accounting for work elided by symmetry folding (see `crate::dataflow`
/// on the fold design). Builders that collapse a congruent stream's
/// private compute chain into single delay ops record here the op count
/// and engine busy cycles of the elided ops; the executors add these
/// totals to their linear counters, so a folded program reports the same
/// grid-wide `RunStats` as its unfolded equivalent.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FoldStats {
    /// Elided ops, net of the synthetic delay ops emitted in their place.
    pub ops: u64,
    /// RedMulE busy cycles carried by elided ops (synthetic delay ops are
    /// `Component::Other` and contribute nothing to the engine counters).
    pub redmule_busy: u64,
    /// Spatz busy cycles carried by elided ops.
    pub spatz_busy: u64,
    /// Number of folded (collapsed) tile/group streams.
    pub streams: u64,
}

impl FoldStats {
    /// Field-wise difference `self - before` — used by the builders to
    /// capture a block template's fold delta for later stamping.
    pub(crate) fn delta_since(&self, before: &FoldStats) -> FoldStats {
        FoldStats {
            ops: self.ops - before.ops,
            redmule_busy: self.redmule_busy - before.redmule_busy,
            spatz_busy: self.spatz_busy - before.spatz_busy,
            streams: self.streams - before.streams,
        }
    }

    /// Field-wise accumulate (applied once per stamped template instance).
    pub(crate) fn accumulate(&mut self, d: &FoldStats) {
        self.ops += d.ops;
        self.redmule_busy += d.redmule_busy;
        self.spatz_busy += d.spatz_busy;
        self.streams += d.streams;
    }
}

/// One schedulable unit of work.
#[derive(Debug, Clone)]
pub struct Op {
    /// Resource this op executes on (FIFO-serialized).
    pub resource: ResourceId,
    /// Cycles the resource is held. Back-to-back ops on the same resource
    /// are spaced by at least this much.
    pub occupancy: Cycle,
    /// Additional pipeline latency after the resource is released before
    /// dependents observe completion (e.g. HBM access latency, NoC
    /// propagation). The resource can serve the next request meanwhile.
    pub latency: Cycle,
    /// Accounting category for the paper's runtime breakdowns.
    pub component: Component,
    /// Owning tile (global flat id) for per-tile accounting; `NO_TILE` if
    /// the op is not attributable to a tile.
    pub tile: u32,
    /// Bytes moved to/from HBM by this op (0 for non-HBM ops); used for
    /// traffic accounting and bandwidth-utilization metrics.
    pub hbm_bytes: u64,
    /// Dependency slice in the program's CSR pool (see [`Program::deps_of`]).
    pub(crate) deps_start: u32,
    pub(crate) deps_len: u32,
}

/// A complete op DAG plus its resource table. Dependencies live in one
/// flat CSR pool (`deps_pool`) instead of per-op `Vec`s: programs have
/// hundreds of thousands of ops and the per-op allocation dominated build
/// time before this layout (§Perf).
///
/// After construction, [`Program::seal`] derives the *dependents* CSR
/// (`out_start`/`out_edges`) and the initial in-degree vector once, so
/// every subsequent [`crate::sim::execute`] call starts immediately
/// instead of re-deriving them (§Perf: the executor used to rebuild this
/// on every run). Builders seal automatically; hand-built programs that
/// skip `seal` still execute through a fallback that derives the CSR
/// locally.
#[derive(Debug, Default)]
pub struct Program {
    pub(crate) ops: Vec<Op>,
    pub(crate) deps_pool: Vec<u32>,
    pub(crate) n_resources: u32,
    /// Total useful FLOPs represented by the program (set by the builder;
    /// used for utilization metrics, not timing).
    pub flops: u64,
    /// Accounting for ops elided by symmetry folding (zero when unfolded).
    pub fold: FoldStats,
    /// Dependents CSR row offsets (`len == ops.len() + 1` when sealed).
    pub(crate) out_start: Vec<u32>,
    /// Dependents CSR edge targets (op indices).
    pub(crate) out_edges: Vec<u32>,
    /// Initial in-degree of every op (== `deps_len`), cloned per execution.
    pub(crate) indeg0: Vec<u32>,
    pub(crate) sealed: bool,
}

impl Program {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a `Program` over buffers recycled by a
    /// [`crate::sim::ProgramArena`]. All buffers arrive cleared.
    pub(crate) fn from_buffers(
        ops: Vec<Op>,
        deps_pool: Vec<u32>,
        out_start: Vec<u32>,
        out_edges: Vec<u32>,
        indeg0: Vec<u32>,
    ) -> Self {
        Self {
            ops,
            deps_pool,
            n_resources: 0,
            flops: 0,
            fold: FoldStats::default(),
            out_start,
            out_edges,
            indeg0,
            sealed: false,
        }
    }

    /// Decompose into raw buffers for arena recycling.
    #[allow(clippy::type_complexity)]
    pub(crate) fn into_buffers(self) -> (Vec<Op>, Vec<u32>, Vec<u32>, Vec<u32>, Vec<u32>) {
        (self.ops, self.deps_pool, self.out_start, self.out_edges, self.indeg0)
    }

    /// Allocate a fresh resource.
    pub fn resource(&mut self) -> ResourceId {
        let id = ResourceId(self.n_resources);
        self.n_resources += 1;
        id
    }

    /// Allocate `n` fresh resources.
    pub fn resources(&mut self, n: usize) -> Vec<ResourceId> {
        (0..n).map(|_| self.resource()).collect()
    }

    /// Append an op; returns its id.
    #[allow(clippy::too_many_arguments)]
    pub fn op(
        &mut self,
        resource: ResourceId,
        occupancy: Cycle,
        latency: Cycle,
        component: Component,
        tile: u32,
        hbm_bytes: u64,
        deps: &[OpId],
    ) -> OpId {
        debug_assert!(resource.0 < self.n_resources, "unknown resource");
        let id = OpId(self.ops.len() as u32);
        debug_assert!(deps.iter().all(|d| d.0 < id.0), "deps must precede op");
        let deps_start = self.deps_pool.len() as u32;
        self.deps_pool.extend(deps.iter().map(|d| d.0));
        self.sealed = false;
        self.ops.push(Op {
            resource,
            occupancy,
            latency,
            component,
            tile,
            hbm_bytes,
            deps_start,
            deps_len: deps.len() as u32,
        });
        id
    }

    /// Append a shifted copy of the op range `[src_start, src_start +
    /// src_len)` — the template-stamping primitive used by the dataflow
    /// builders (§Perf). Dependencies pointing *inside* the source range
    /// are offset to the copy; dependencies pointing *before* it (the
    /// template's single external predecessor, e.g. the previous block's
    /// barrier) are replaced by `ext_dep`. Resources, timings and
    /// accounting fields are copied verbatim; callers patch per-instance
    /// differences afterwards. Returns the index of the first stamped op.
    pub fn stamp_range(&mut self, src_start: u32, src_len: u32, ext_dep: OpId) -> u32 {
        let new_base = self.ops.len() as u32;
        debug_assert!(src_start + src_len <= new_base, "source range out of bounds");
        debug_assert!(ext_dep.0 < new_base, "external dep must already exist");
        let delta = new_base - src_start;
        self.sealed = false;
        self.ops.reserve(src_len as usize);
        for idx in src_start..src_start + src_len {
            let src = self.ops[idx as usize].clone();
            let new_deps_start = self.deps_pool.len() as u32;
            for k in src.deps_start..src.deps_start + src.deps_len {
                let d = self.deps_pool[k as usize];
                let nd = if d >= src_start { d + delta } else { ext_dep.0 };
                self.deps_pool.push(nd);
            }
            self.ops.push(Op {
                deps_start: new_deps_start,
                ..src
            });
        }
        new_base
    }

    /// Derive the dependents CSR and initial in-degrees so executions can
    /// reuse them. Idempotent; implicitly invalidated by further `op` /
    /// `stamp_range` calls. Builds *in place* into the program's (possibly
    /// arena-recycled) CSR buffers — no allocation once capacity exists.
    pub fn seal(&mut self) {
        if self.sealed {
            return;
        }
        let mut out_start = std::mem::take(&mut self.out_start);
        let mut out_edges = std::mem::take(&mut self.out_edges);
        let mut indeg0 = std::mem::take(&mut self.indeg0);
        Self::dependents_into(
            &self.ops,
            &self.deps_pool,
            &mut out_start,
            &mut out_edges,
            &mut indeg0,
        );
        self.out_start = out_start;
        self.out_edges = out_edges;
        self.indeg0 = indeg0;
        self.sealed = true;
    }

    /// Compute `(out_start, out_edges, indeg0)` for the current DAG into
    /// fresh buffers — the executor's unsealed-program fallback.
    #[allow(clippy::type_complexity)]
    pub(crate) fn build_dependents_csr(&self) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
        let (mut out_start, mut out_edges, mut indeg0) = (Vec::new(), Vec::new(), Vec::new());
        Self::dependents_into(
            &self.ops,
            &self.deps_pool,
            &mut out_start,
            &mut out_edges,
            &mut indeg0,
        );
        (out_start, out_edges, indeg0)
    }

    /// Fill the dependents CSR into the given buffers (cleared first,
    /// capacity retained). Uses the classic in-place cursor trick: the row
    /// offsets double as fill cursors and are shifted back afterwards, so
    /// no scratch allocation is needed.
    fn dependents_into(
        ops: &[Op],
        deps_pool: &[u32],
        out_start: &mut Vec<u32>,
        out_edges: &mut Vec<u32>,
        indeg0: &mut Vec<u32>,
    ) {
        let n = ops.len();
        indeg0.clear();
        indeg0.reserve(n);
        out_start.clear();
        out_start.resize(n + 1, 0);
        for op in ops {
            indeg0.push(op.deps_len);
            let (s, l) = (op.deps_start as usize, op.deps_len as usize);
            for &d in &deps_pool[s..s + l] {
                out_start[d as usize + 1] += 1;
            }
        }
        for i in 0..n {
            out_start[i + 1] += out_start[i];
        }
        let total = out_start[n] as usize;
        out_edges.clear();
        out_edges.resize(total, 0);
        for (i, op) in ops.iter().enumerate() {
            let (s, l) = (op.deps_start as usize, op.deps_len as usize);
            for &d in &deps_pool[s..s + l] {
                let di = d as usize;
                out_edges[out_start[di] as usize] = i as u32;
                out_start[di] += 1;
            }
        }
        // The cursors now hold each row's *end*; shift right to restore
        // the start offsets (out_start[n] is untouched and equals total).
        for i in (1..n).rev() {
            out_start[i] = out_start[i - 1];
        }
        if n > 0 {
            out_start[0] = 0;
        }
    }

    /// True once [`Program::seal`] has run (and no ops were added since).
    pub fn is_sealed(&self) -> bool {
        self.sealed
    }

    /// Forget the sealed state so the next [`crate::sim::execute`] takes
    /// the derive-per-run fallback (and the next [`Program::seal`] rebuilds).
    /// Benchmarking/testing aid — e.g. `sim_hotpath` uses it to price the
    /// CSR build when reconstructing the seed baseline.
    pub fn unseal(&mut self) {
        self.sealed = false;
    }

    /// Dependency ids of an op (raw op indices).
    #[inline]
    pub fn deps_of(&self, op: &Op) -> &[u32] {
        &self.deps_pool[op.deps_start as usize..(op.deps_start + op.deps_len) as usize]
    }

    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    pub fn num_resources(&self) -> usize {
        self.n_resources as usize
    }

    pub fn ops(&self) -> &[Op] {
        &self.ops
    }

    /// Validate DAG invariants (deps precede ops, resources in range).
    /// Builders are structurally correct by construction; this is used by
    /// tests and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        for (i, op) in self.ops.iter().enumerate() {
            if op.resource.0 >= self.n_resources {
                return Err(format!("op {i}: resource out of range"));
            }
            for &d in self.deps_of(op) {
                if d as usize >= i {
                    return Err(format!("op {i}: dep {d} does not precede it"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 10, 0, Component::RedMule, 0, 0, &[]);
        let b = p.op(r, 5, 2, Component::Spatz, 0, 0, &[a]);
        let _c = p.op(r, 1, 0, Component::Other, NO_TILE, 0, &[a, b]);
        assert_eq!(p.num_ops(), 3);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn seal_builds_dependents_csr_once() {
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 1, 0, Component::RedMule, 0, 0, &[]);
        let b = p.op(r, 1, 0, Component::Spatz, 0, 0, &[a]);
        let _c = p.op(r, 1, 0, Component::Other, NO_TILE, 0, &[a, b]);
        assert!(!p.is_sealed());
        p.seal();
        assert!(p.is_sealed());
        // a's dependents: b and c; b's: c; c's: none.
        assert_eq!(p.out_start, vec![0, 2, 3, 3]);
        assert_eq!(p.out_edges, vec![1, 2, 2]);
        assert_eq!(p.indeg0, vec![0, 1, 2]);
        // Adding an op invalidates the seal.
        p.op(r, 1, 0, Component::Other, NO_TILE, 0, &[b]);
        assert!(!p.is_sealed());
        p.seal();
        assert_eq!(p.indeg0, vec![0, 1, 2, 1]);
    }

    #[test]
    fn stamp_range_offsets_internal_and_replaces_external_deps() {
        let mut p = Program::new();
        let r = p.resource();
        let barrier0 = p.op(r, 1, 0, Component::Other, NO_TILE, 0, &[]);
        // Template "block": two ops, externally depending on barrier0.
        let t0 = p.op(r, 10, 0, Component::RedMule, 0, 64, &[barrier0]);
        let t1 = p.op(r, 5, 2, Component::Spatz, 0, 0, &[t0]);
        let base = t0.0;
        let len = 2;
        // Stamp a second instance gated on t1 (the new "previous barrier").
        let new_base = p.stamp_range(base, len, t1);
        assert_eq!(new_base, 3);
        assert_eq!(p.num_ops(), 5);
        let ops = p.ops();
        assert_eq!(ops[3].occupancy, 10);
        assert_eq!(ops[3].hbm_bytes, 64);
        assert_eq!(p.deps_of(&ops[3]), &[t1.0]); // external → t1
        assert_eq!(ops[4].occupancy, 5);
        assert_eq!(ops[4].latency, 2);
        assert_eq!(p.deps_of(&ops[4]), &[3]); // internal, offset by delta
        assert!(p.validate().is_ok());
    }

    #[test]
    fn validate_catches_bad_dep() {
        let mut p = Program::new();
        let r = p.resource();
        // Manually construct an invalid forward dependency.
        p.deps_pool.push(5);
        p.ops.push(Op {
            resource: r,
            occupancy: 1,
            latency: 0,
            component: Component::Other,
            tile: NO_TILE,
            hbm_bytes: 0,
            deps_start: 0,
            deps_len: 1,
        });
        assert!(p.validate().is_err());
    }
}
