//! Op-graph program representation.
//!
//! A [`Program`] is a DAG of [`Op`]s over a set of named [`ResourceId`]s.
//! Dataflow builders (`crate::dataflow`) emit one program per experiment;
//! the engine executes it. Ops model everything with a *time cost*:
//! engine invocations, DMA transfers, NoC collectives, synchronization.

use super::breakdown::Component;
use super::Cycle;

/// Index of an op within its program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub u32);

/// Index of a resource within its program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ResourceId(pub u32);

/// Sentinel tile id for ops not owned by any tile (e.g. pure barriers).
pub const NO_TILE: u32 = u32::MAX;

/// One schedulable unit of work.
#[derive(Debug, Clone)]
pub struct Op {
    /// Resource this op executes on (FIFO-serialized).
    pub resource: ResourceId,
    /// Cycles the resource is held. Back-to-back ops on the same resource
    /// are spaced by at least this much.
    pub occupancy: Cycle,
    /// Additional pipeline latency after the resource is released before
    /// dependents observe completion (e.g. HBM access latency, NoC
    /// propagation). The resource can serve the next request meanwhile.
    pub latency: Cycle,
    /// Accounting category for the paper's runtime breakdowns.
    pub component: Component,
    /// Owning tile (global flat id) for per-tile accounting; `NO_TILE` if
    /// the op is not attributable to a tile.
    pub tile: u32,
    /// Bytes moved to/from HBM by this op (0 for non-HBM ops); used for
    /// traffic accounting and bandwidth-utilization metrics.
    pub hbm_bytes: u64,
    /// Dependency slice in the program's CSR pool (see [`Program::deps_of`]).
    pub(crate) deps_start: u32,
    pub(crate) deps_len: u32,
}

/// A complete op DAG plus its resource table. Dependencies live in one
/// flat CSR pool (`deps_pool`) instead of per-op `Vec`s: programs have
/// hundreds of thousands of ops and the per-op allocation dominated build
/// time before this layout (§Perf).
#[derive(Debug, Default)]
pub struct Program {
    pub(crate) ops: Vec<Op>,
    pub(crate) deps_pool: Vec<u32>,
    pub(crate) n_resources: u32,
    /// Total useful FLOPs represented by the program (set by the builder;
    /// used for utilization metrics, not timing).
    pub flops: u64,
}

impl Program {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a fresh resource.
    pub fn resource(&mut self) -> ResourceId {
        let id = ResourceId(self.n_resources);
        self.n_resources += 1;
        id
    }

    /// Allocate `n` fresh resources.
    pub fn resources(&mut self, n: usize) -> Vec<ResourceId> {
        (0..n).map(|_| self.resource()).collect()
    }

    /// Append an op; returns its id.
    #[allow(clippy::too_many_arguments)]
    pub fn op(
        &mut self,
        resource: ResourceId,
        occupancy: Cycle,
        latency: Cycle,
        component: Component,
        tile: u32,
        hbm_bytes: u64,
        deps: &[OpId],
    ) -> OpId {
        debug_assert!(resource.0 < self.n_resources, "unknown resource");
        let id = OpId(self.ops.len() as u32);
        debug_assert!(deps.iter().all(|d| d.0 < id.0), "deps must precede op");
        let deps_start = self.deps_pool.len() as u32;
        self.deps_pool.extend(deps.iter().map(|d| d.0));
        self.ops.push(Op {
            resource,
            occupancy,
            latency,
            component,
            tile,
            hbm_bytes,
            deps_start,
            deps_len: deps.len() as u32,
        });
        id
    }

    /// Dependency ids of an op (raw op indices).
    #[inline]
    pub fn deps_of(&self, op: &Op) -> &[u32] {
        &self.deps_pool[op.deps_start as usize..(op.deps_start + op.deps_len) as usize]
    }

    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    pub fn num_resources(&self) -> usize {
        self.n_resources as usize
    }

    pub fn ops(&self) -> &[Op] {
        &self.ops
    }

    /// Validate DAG invariants (deps precede ops, resources in range).
    /// Builders are structurally correct by construction; this is used by
    /// tests and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        for (i, op) in self.ops.iter().enumerate() {
            if op.resource.0 >= self.n_resources {
                return Err(format!("op {i}: resource out of range"));
            }
            for &d in self.deps_of(op) {
                if d as usize >= i {
                    return Err(format!("op {i}: dep {d} does not precede it"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 10, 0, Component::RedMule, 0, 0, &[]);
        let b = p.op(r, 5, 2, Component::Spatz, 0, 0, &[a]);
        let _c = p.op(r, 1, 0, Component::Other, NO_TILE, 0, &[a, b]);
        assert_eq!(p.num_ops(), 3);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn validate_catches_bad_dep() {
        let mut p = Program::new();
        let r = p.resource();
        // Manually construct an invalid forward dependency.
        p.deps_pool.push(5);
        p.ops.push(Op {
            resource: r,
            occupancy: 1,
            latency: 0,
            component: Component::Other,
            tile: NO_TILE,
            hbm_bytes: 0,
            deps_start: 0,
            deps_len: 1,
        });
        assert!(p.validate().is_err());
    }
}
