//! Indexed bucket (monotone radix) event queue for the DES engine.
//!
//! The executor's event stream is *monotone*: an op scheduled while
//! processing time `t` always completes at `t' ≥ t`, and completion times
//! are strongly clustered just ahead of the current time. A binary heap
//! pays `O(log n)` pointer-chasing per event; this queue exploits the
//! monotone structure with the classic radix-bucket layout
//! (Ahuja–Magnanti–Orlin): bucket 0 covers exactly the current time,
//! bucket `i ≥ 1` a half-open range of width `≤ 2^(i-1)` above it. Pushes
//! append to the bucket whose range contains the key; pops drain bucket 0
//! FIFO, re-carving the lowest nonempty bucket when it empties. Each event
//! moves at most 64 times, and in the near-monotonic schedules this
//! workload produces, almost always lands directly in a low bucket.
//!
//! Determinism: entries with equal time are popped in push order (buckets
//! are FIFO and redistribution preserves relative order), which is exactly
//! the `(time, insertion seq)` order of the previous
//! `BinaryHeap<Reverse<(Cycle, u64)>>` — the differential test in
//! `tests/engine_differential.rs` pins schedule equivalence down.

/// Number of buckets: bucket 0 plus one per bit of the key domain.
const LEVELS: usize = 65;

/// A monotone priority queue over `(u64 key, u32 payload)` events.
/// Keys pushed must be `≥` the most recently popped key.
#[derive(Debug)]
pub struct EventQueue {
    buckets: Vec<Vec<(u64, u32)>>,
    /// Inclusive upper bound of each bucket's range; non-decreasing.
    /// `ubound[0]` is the current ("last popped") time.
    ubound: Vec<u64>,
    /// Pop cursor within bucket 0 (drained lazily to keep pops O(1)).
    cursor0: usize,
    len: usize,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    pub fn new() -> Self {
        let mut ubound = Vec::with_capacity(LEVELS);
        for i in 0..LEVELS {
            ubound.push(if i >= 64 { u64::MAX } else { (1u64 << i) - 1 });
        }
        Self {
            buckets: vec![Vec::new(); LEVELS],
            ubound,
            cursor0: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Index of the bucket whose current range contains `key`.
    #[inline]
    fn bucket_for(&self, key: u64) -> usize {
        self.ubound.partition_point(|&ub| ub < key)
    }

    /// Insert an event. `key` must be `≥` the last popped key.
    #[inline]
    pub fn push(&mut self, key: u64, payload: u32) {
        debug_assert!(key >= self.ubound[0], "monotonicity violated");
        let b = self.bucket_for(key);
        self.buckets[b].push((key, payload));
        self.len += 1;
    }

    /// Remove and return the minimum event; ties pop in push order.
    pub fn pop(&mut self) -> Option<(u64, u32)> {
        if self.cursor0 < self.buckets[0].len() {
            let e = self.buckets[0][self.cursor0];
            self.cursor0 += 1;
            self.len -= 1;
            return Some(e);
        }
        self.buckets[0].clear();
        self.cursor0 = 0;
        if self.len == 0 {
            return None;
        }
        // Re-carve ranges below the lowest nonempty bucket around its
        // minimum key, then redistribute that bucket (order-preserving).
        let b = (1..LEVELS)
            .find(|&i| !self.buckets[i].is_empty())
            .expect("len > 0 implies a nonempty bucket");
        let newlast = self.buckets[b]
            .iter()
            .map(|&(k, _)| k)
            .min()
            .expect("bucket nonempty");
        let cap = self.ubound[b];
        self.ubound[0] = newlast;
        for i in 1..b {
            let span = (1u64 << i) - 1;
            self.ubound[i] = newlast.saturating_add(span).min(cap);
        }
        let moved = std::mem::take(&mut self.buckets[b]);
        for (k, v) in moved {
            let nb = self.bucket_for(k);
            debug_assert!(nb < b, "redistribution must strictly descend");
            self.buckets[nb].push((k, v));
        }
        let e = self.buckets[0][0];
        self.cursor0 = 1;
        self.len -= 1;
        Some(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[test]
    fn pops_in_key_order_fifo_on_ties() {
        let mut q = EventQueue::new();
        q.push(5, 0);
        q.push(3, 1);
        q.push(5, 2);
        q.push(3, 3);
        q.push(1000, 4);
        assert_eq!(q.pop(), Some((3, 1)));
        assert_eq!(q.pop(), Some((3, 3)));
        assert_eq!(q.pop(), Some((5, 0)));
        assert_eq!(q.pop(), Some((5, 2)));
        assert_eq!(q.pop(), Some((1000, 4)));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_monotone_pushes() {
        // Pushes at the current pop time land behind pending ties.
        let mut q = EventQueue::new();
        q.push(10, 0);
        q.push(10, 1);
        assert_eq!(q.pop(), Some((10, 0)));
        q.push(10, 2); // same time as in-flight pops
        q.push(12, 3);
        assert_eq!(q.pop(), Some((10, 1)));
        assert_eq!(q.pop(), Some((10, 2)));
        assert_eq!(q.pop(), Some((12, 3)));
    }

    #[test]
    fn matches_binary_heap_on_random_monotone_streams() {
        let mut rng = Rng::new(0xEB);
        for _ in 0..50 {
            let mut q = EventQueue::new();
            let mut heap: BinaryHeap<Reverse<(u64, u64)>> = BinaryHeap::new();
            let mut seq = 0u64;
            let mut now = 0u64;
            let mut pending = 0usize;
            for _ in 0..400 {
                let push = pending == 0 || rng.gen_range(3) != 0;
                if push {
                    // Mix of near-future and far-future keys.
                    let delta = if rng.gen_range(10) == 0 {
                        rng.gen_range(1 << 40)
                    } else {
                        rng.gen_range(64)
                    };
                    let key = now + delta;
                    q.push(key, seq as u32);
                    heap.push(Reverse((key, seq)));
                    seq += 1;
                    pending += 1;
                } else {
                    let got = q.pop().unwrap();
                    let Reverse((k, s)) = heap.pop().unwrap();
                    assert_eq!(got, (k, s as u32));
                    now = k;
                    pending -= 1;
                }
            }
            while let Some(got) = q.pop() {
                let Reverse((k, s)) = heap.pop().unwrap();
                assert_eq!(got, (k, s as u32));
            }
            assert!(heap.is_empty());
        }
    }

    #[test]
    fn huge_key_range() {
        let mut q = EventQueue::new();
        q.push(0, 0);
        q.push(u64::MAX, 1);
        q.push(1, 2);
        assert_eq!(q.pop(), Some((0, 0)));
        assert_eq!(q.pop(), Some((1, 2)));
        assert_eq!(q.pop(), Some((u64::MAX, 1)));
    }
}
