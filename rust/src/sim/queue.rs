//! Indexed bucket (monotone radix) event queue for the DES engine.
//!
//! The executor's event stream is *monotone*: an op scheduled while
//! processing time `t` always completes at `t' ≥ t`, and completion times
//! are strongly clustered just ahead of the current time. A binary heap
//! pays `O(log n)` pointer-chasing per event; this queue exploits the
//! monotone structure with the classic radix-bucket layout
//! (Ahuja–Magnanti–Orlin): bucket 0 covers exactly the current time,
//! bucket `i ≥ 1` a half-open range of width `≤ 2^(i-1)` above it. Pushes
//! append to the bucket whose range contains the key; pops drain bucket 0
//! FIFO, re-carving the lowest nonempty bucket when it empties. Each event
//! moves at most 64 times, and in the near-monotonic schedules this
//! workload produces, almost always lands directly in a low bucket.
//!
//! Determinism: entries with equal time are popped in push order (buckets
//! are FIFO and redistribution preserves relative order), which is exactly
//! the `(time, insertion seq)` order of the previous
//! `BinaryHeap<Reverse<(Cycle, u64)>>` — the differential test in
//! `tests/engine_differential.rs` pins schedule equivalence down.

/// Number of buckets: bucket 0 plus one per bit of the key domain.
const LEVELS: usize = 65;

/// A monotone priority queue over `(u64 key, u32 payload)` events.
/// Keys pushed must be `≥` the most recently popped key.
#[derive(Debug)]
pub struct EventQueue {
    buckets: Vec<Vec<(u64, u32)>>,
    /// Inclusive upper bound of each bucket's range; non-decreasing.
    /// `ubound[0]` is the current ("last popped") time.
    ubound: Vec<u64>,
    /// Pop cursor within bucket 0 (drained lazily to keep pops O(1)).
    cursor0: usize,
    len: usize,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    /// An empty queue.
    pub fn new() -> Self {
        let mut ubound = Vec::with_capacity(LEVELS);
        for i in 0..LEVELS {
            ubound.push(if i >= 64 { u64::MAX } else { (1u64 << i) - 1 });
        }
        Self {
            buckets: vec![Vec::new(); LEVELS],
            ubound,
            cursor0: 0,
            len: 0,
        }
    }

    /// Pending events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no event is pending.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Index of the bucket whose current range contains `key`.
    #[inline]
    fn bucket_for(&self, key: u64) -> usize {
        self.ubound.partition_point(|&ub| ub < key)
    }

    /// Insert an event. `key` must be `≥` the last popped key.
    ///
    /// The monotonicity precondition is load-bearing: a smaller key would
    /// land in bucket 0's already-popped region and be silently dropped or
    /// misordered. That failure mode is far worse than a crash (a release
    /// build would quietly compute a wrong schedule), so the check is a
    /// real assert — one predictable branch per push — not a
    /// `debug_assert!`.
    #[inline]
    pub fn push(&mut self, key: u64, payload: u32) {
        assert!(
            key >= self.ubound[0],
            "EventQueue: non-monotone push (key {key} < current time {})",
            self.ubound[0]
        );
        let b = self.bucket_for(key);
        self.buckets[b].push((key, payload));
        self.len += 1;
    }

    /// Minimum event without removing it (among ties, the entry `pop`
    /// would surface next). Used by the engine to drain all events of one
    /// timestamp before scheduling the ops they release.
    ///
    /// Deliberately performs *no* re-carving: advancing the bucket ranges
    /// to the next pending key would raise the monotonicity floor past the
    /// current timestamp, making perfectly legal pushes (completions of
    /// ops scheduled *now*) look non-monotone. Bucket ranges are disjoint
    /// and increasing, so the first nonempty bucket holds the global
    /// minimum; redistribution preserves push order, so the first minimal
    /// entry here is exactly the one `pop` returns next.
    pub fn peek(&self) -> Option<(u64, u32)> {
        if self.cursor0 < self.buckets[0].len() {
            return Some(self.buckets[0][self.cursor0]);
        }
        if self.len == 0 {
            return None;
        }
        let b = (1..LEVELS)
            .find(|&i| !self.buckets[i].is_empty())
            .expect("len > 0 implies a nonempty bucket");
        let mut best = self.buckets[b][0];
        for &(k, v) in &self.buckets[b][1..] {
            if k < best.0 {
                best = (k, v);
            }
        }
        Some(best)
    }

    /// Key of the minimum event, without selecting among ties — the cheap
    /// "when is this shard's next event" probe used by the parallel
    /// executor's round scans ([`crate::sim::execute_parallel`]). Like
    /// [`EventQueue::peek`], performs no re-carving, so it never moves the
    /// monotonicity floor.
    pub fn next_time(&self) -> Option<u64> {
        if self.cursor0 < self.buckets[0].len() {
            return Some(self.buckets[0][self.cursor0].0);
        }
        if self.len == 0 {
            return None;
        }
        let b = (1..LEVELS)
            .find(|&i| !self.buckets[i].is_empty())
            .expect("len > 0 implies a nonempty bucket");
        self.buckets[b].iter().map(|&(k, _)| k).min()
    }

    /// Remove and return the minimum event; ties pop in push order.
    pub fn pop(&mut self) -> Option<(u64, u32)> {
        if self.cursor0 < self.buckets[0].len() {
            let e = self.buckets[0][self.cursor0];
            self.cursor0 += 1;
            self.len -= 1;
            return Some(e);
        }
        self.buckets[0].clear();
        self.cursor0 = 0;
        if self.len == 0 {
            return None;
        }
        // Re-carve ranges below the lowest nonempty bucket around its
        // minimum key, then redistribute that bucket (order-preserving).
        let b = (1..LEVELS)
            .find(|&i| !self.buckets[i].is_empty())
            .expect("len > 0 implies a nonempty bucket");
        let newlast = self.buckets[b]
            .iter()
            .map(|&(k, _)| k)
            .min()
            .expect("bucket nonempty");
        let cap = self.ubound[b];
        self.ubound[0] = newlast;
        for i in 1..b {
            let span = (1u64 << i) - 1;
            self.ubound[i] = newlast.saturating_add(span).min(cap);
        }
        let moved = std::mem::take(&mut self.buckets[b]);
        for (k, v) in moved {
            let nb = self.bucket_for(k);
            debug_assert!(nb < b, "redistribution must strictly descend");
            self.buckets[nb].push((k, v));
        }
        let e = self.buckets[0][0];
        self.cursor0 = 1;
        self.len -= 1;
        Some(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[test]
    fn pops_in_key_order_fifo_on_ties() {
        let mut q = EventQueue::new();
        q.push(5, 0);
        q.push(3, 1);
        q.push(5, 2);
        q.push(3, 3);
        q.push(1000, 4);
        assert_eq!(q.pop(), Some((3, 1)));
        assert_eq!(q.pop(), Some((3, 3)));
        assert_eq!(q.pop(), Some((5, 0)));
        assert_eq!(q.pop(), Some((5, 2)));
        assert_eq!(q.pop(), Some((1000, 4)));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_monotone_pushes() {
        // Pushes at the current pop time land behind pending ties.
        let mut q = EventQueue::new();
        q.push(10, 0);
        q.push(10, 1);
        assert_eq!(q.pop(), Some((10, 0)));
        q.push(10, 2); // same time as in-flight pops
        q.push(12, 3);
        assert_eq!(q.pop(), Some((10, 1)));
        assert_eq!(q.pop(), Some((10, 2)));
        assert_eq!(q.pop(), Some((12, 3)));
    }

    #[test]
    fn matches_binary_heap_on_random_monotone_streams() {
        let mut rng = Rng::new(0xEB);
        for _ in 0..50 {
            let mut q = EventQueue::new();
            let mut heap: BinaryHeap<Reverse<(u64, u64)>> = BinaryHeap::new();
            let mut seq = 0u64;
            let mut now = 0u64;
            let mut pending = 0usize;
            for _ in 0..400 {
                let push = pending == 0 || rng.gen_range(3) != 0;
                if push {
                    // Mix of near-future and far-future keys.
                    let delta = if rng.gen_range(10) == 0 {
                        rng.gen_range(1 << 40)
                    } else {
                        rng.gen_range(64)
                    };
                    let key = now + delta;
                    q.push(key, seq as u32);
                    heap.push(Reverse((key, seq)));
                    seq += 1;
                    pending += 1;
                } else {
                    let got = q.pop().unwrap();
                    let Reverse((k, s)) = heap.pop().unwrap();
                    assert_eq!(got, (k, s as u32));
                    now = k;
                    pending -= 1;
                }
            }
            while let Some(got) = q.pop() {
                let Reverse((k, s)) = heap.pop().unwrap();
                assert_eq!(got, (k, s as u32));
            }
            assert!(heap.is_empty());
        }
    }

    #[test]
    fn peek_is_nondestructive_and_matches_pop() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek(), None);
        q.push(7, 1);
        q.push(3, 2);
        assert_eq!(q.peek(), Some((3, 2)));
        assert_eq!(q.peek(), Some((3, 2)));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some((3, 2)));
        assert_eq!(q.peek(), Some((7, 1)));
        assert_eq!(q.pop(), Some((7, 1)));
        assert_eq!(q.peek(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn peek_does_not_advance_the_monotonicity_floor() {
        // Regression for the engine's batch-drain pattern: peeking a
        // far-future event must not raise the floor past the current
        // time, or completions of ops scheduled *now* would be rejected.
        let mut q = EventQueue::new();
        q.push(10, 0);
        q.push(100, 1);
        assert_eq!(q.pop(), Some((10, 0)));
        assert_eq!(q.peek(), Some((100, 1)));
        // Still legal: 15 ≥ the last popped key (10), despite 15 < 100.
        q.push(15, 2);
        assert_eq!(q.pop(), Some((15, 2)));
        assert_eq!(q.pop(), Some((100, 1)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    #[should_panic(expected = "non-monotone")]
    fn non_monotone_push_is_loud_in_release_builds() {
        // Regression for the release-only crash class: before this check
        // was a real assert, a `--release` build filed the key into bucket
        // 0's already-popped region and silently dropped or misordered it
        // (`debug_assert!` compiles out). Covered in release by the CI
        // `cargo test --release` job.
        let mut q = EventQueue::new();
        q.push(10, 0);
        assert_eq!(q.pop(), Some((10, 0)));
        q.push(5, 1); // 5 < current time 10: must panic, not mis-schedule
    }

    #[test]
    fn next_time_tracks_peek_without_carving() {
        let mut q = EventQueue::new();
        assert_eq!(q.next_time(), None);
        q.push(10, 0);
        q.push(100, 1);
        assert_eq!(q.next_time(), Some(10));
        assert_eq!(q.pop(), Some((10, 0)));
        assert_eq!(q.next_time(), Some(100));
        // No floor movement: a push at the current time is still legal.
        q.push(10, 2);
        assert_eq!(q.next_time(), Some(10));
        assert_eq!(q.pop(), Some((10, 2)));
        assert_eq!(q.pop(), Some((100, 1)));
        assert_eq!(q.next_time(), None);
    }

    #[test]
    fn huge_key_range() {
        let mut q = EventQueue::new();
        q.push(0, 0);
        q.push(u64::MAX, 1);
        q.push(1, 2);
        assert_eq!(q.pop(), Some((0, 0)));
        assert_eq!(q.pop(), Some((1, 2)));
        assert_eq!(q.pop(), Some((u64::MAX, 1)));
    }
}
