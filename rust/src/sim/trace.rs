//! Chrome-trace export of an executed schedule.
//!
//! `flatattention trace …` writes a `chrome://tracing` / Perfetto-loadable
//! JSON of every op executed on the first N tiles: one timeline row per
//! tile engine / bus / HBM channel, colored by breakdown component. This
//! is the observability tool used during the §Perf pass to see overlap
//! (e.g. FlatAsyn's two head-streams interleaving on RedMulE vs the DMA
//! stream).

use crate::sim::engine::TraceRecord;
use crate::sim::program::{Program, NO_TILE};
use crate::telemetry::chrome_trace_doc;
use crate::util::json::Json;

/// Convert trace records into Chrome-trace JSON ("traceEvents" array of
/// complete events). Time units follow the crate-wide convention documented
/// in [`crate::telemetry::events`]: 1 cycle = 1 µs in `ts`/`dur`, with
/// `displayTimeUnit: "ms"` ("1 ms" on screen = 1000 cycles).
pub fn to_chrome_trace(program: &Program, records: &[TraceRecord]) -> Json {
    let ops = program.ops();
    let events: Vec<Json> = records
        .iter()
        .map(|&(op_idx, start, complete)| {
            let op = &ops[op_idx as usize];
            let tid = op.resource.0;
            let pid = if op.tile == NO_TILE { 0 } else { op.tile };
            Json::obj([
                ("name", Json::str(op.component.label())),
                ("cat", Json::str(op.component.label())),
                ("ph", Json::str("X")),
                ("ts", Json::num(start as f64)),
                ("dur", Json::num((complete - start) as f64)),
                ("pid", Json::num(pid as f64)),
                ("tid", Json::num(tid as f64)),
            ])
        })
        .collect();
    chrome_trace_doc(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::breakdown::Component;
    use crate::sim::execute_traced;

    #[test]
    fn traces_only_requested_tiles() {
        let mut p = Program::new();
        let r0 = p.resource();
        let r1 = p.resource();
        p.op(r0, 10, 0, Component::RedMule, 0, 0, &[]);
        p.op(r1, 10, 0, Component::Spatz, 5, 0, &[]);
        let (_, trace) = execute_traced(&p, 0, Some(1));
        assert_eq!(trace.len(), 1);
        let (_, trace_all) = execute_traced(&p, 0, Some(64));
        assert_eq!(trace_all.len(), 2);
        let (_, none) = execute_traced(&p, 0, None);
        assert!(none.is_empty());
    }

    #[test]
    fn chrome_json_shape() {
        let mut p = Program::new();
        let r = p.resource();
        let a = p.op(r, 7, 3, Component::HbmAccess, 0, 64, &[]);
        p.op(r, 5, 0, Component::RedMule, 0, 0, &[a]);
        let (_, trace) = execute_traced(&p, 0, Some(1));
        let json = to_chrome_trace(&p, &trace);
        let events = json.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(events[0].get("name").unwrap().as_str(), Some("HBM"));
        // Shares the crate-wide time-unit convention with the serving export.
        assert_eq!(
            json.get("displayTimeUnit").unwrap().as_str(),
            Some(crate::telemetry::CHROME_DISPLAY_UNIT)
        );
        // Round-trips through the JSON parser.
        assert!(Json::parse(&json.to_string()).is_ok());
    }
}
