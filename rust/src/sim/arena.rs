//! Allocation recycling for sweep-scale program construction.
//!
//! A Fig. 5-style co-exploration sweep builds and discards hundreds of
//! programs, each holding multi-hundred-thousand-element `ops`/`deps_pool`
//! buffers plus the sealed dependents CSR. [`ProgramArena`] keeps one set
//! of those buffers alive per worker thread so successive experiments
//! reuse their capacity instead of re-growing from empty (§Perf):
//! `dataflow::run` takes a fresh program from its thread-local arena,
//! builds, executes, and recycles the buffers.

use super::program::{Program, ProgramBuffers};

/// Recycled backing buffers for [`Program`]s built in a sweep loop
/// (op table, dependency pool, dependents CSR and the §Shard CSR — see
/// `program::ProgramBuffers`).
///
/// ```ignore
/// let mut arena = ProgramArena::new();
/// for spec in sweep {
///     let program = build_program_in(&mut arena, ...);
///     let stats = execute(&program, tracked);
///     arena.recycle(program);
/// }
/// ```
#[derive(Debug, Default)]
pub struct ProgramArena {
    bufs: ProgramBuffers,
}

impl ProgramArena {
    /// An empty arena.
    pub fn new() -> Self {
        Self::default()
    }

    /// Take an empty [`Program`] backed by this arena's recycled buffers
    /// (retaining their capacity). The arena is left empty until
    /// [`ProgramArena::recycle`] returns the buffers.
    pub fn fresh(&mut self) -> Program {
        let mut bufs = std::mem::take(&mut self.bufs);
        bufs.clear();
        Program::from_buffers(bufs)
    }

    /// Reclaim a finished program's buffers for the next build.
    pub fn recycle(&mut self, program: Program) {
        self.bufs = program.into_buffers();
    }

    /// Currently recycled capacity (ops slots), for tests/metrics.
    pub fn ops_capacity(&self) -> usize {
        self.bufs.ops.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::breakdown::Component;
    use crate::sim::execute;

    #[test]
    fn buffers_round_trip_and_retain_capacity() {
        let mut arena = ProgramArena::new();
        let mut p = arena.fresh();
        let r = p.resource();
        for _ in 0..1000 {
            p.op(r, 1, 0, Component::Other, 0, 0, &[]);
        }
        p.seal();
        let stats = execute(&p, 0);
        assert_eq!(stats.ops_executed, 1000);
        arena.recycle(p);
        assert!(arena.ops_capacity() >= 1000);

        // The next program starts empty but reuses the allocation.
        let p2 = arena.fresh();
        assert_eq!(p2.num_ops(), 0);
        assert_eq!(p2.num_resources(), 0);
        assert!(!p2.is_sealed());
        arena.recycle(p2);
    }
}
