//! Allocation recycling for sweep-scale program construction.
//!
//! A Fig. 5-style co-exploration sweep builds and discards hundreds of
//! programs, each holding multi-hundred-thousand-element `ops`/`deps_pool`
//! buffers plus the sealed dependents CSR. [`ProgramArena`] keeps one set
//! of those buffers alive per worker thread so successive experiments
//! reuse their capacity instead of re-growing from empty (§Perf):
//! `dataflow::run` takes a fresh program from its thread-local arena,
//! builds, executes, and recycles the buffers.

use super::program::{Op, Program};

/// Recycled backing buffers for [`Program`]s built in a sweep loop.
///
/// ```ignore
/// let mut arena = ProgramArena::new();
/// for spec in sweep {
///     let program = build_program_in(&mut arena, ...);
///     let stats = execute(&program, tracked);
///     arena.recycle(program);
/// }
/// ```
#[derive(Debug, Default)]
pub struct ProgramArena {
    ops: Vec<Op>,
    deps_pool: Vec<u32>,
    out_start: Vec<u32>,
    out_edges: Vec<u32>,
    indeg0: Vec<u32>,
}

impl ProgramArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take an empty [`Program`] backed by this arena's recycled buffers
    /// (retaining their capacity). The arena is left empty until
    /// [`ProgramArena::recycle`] returns the buffers.
    pub fn fresh(&mut self) -> Program {
        let mut ops = std::mem::take(&mut self.ops);
        let mut deps_pool = std::mem::take(&mut self.deps_pool);
        let mut out_start = std::mem::take(&mut self.out_start);
        let mut out_edges = std::mem::take(&mut self.out_edges);
        let mut indeg0 = std::mem::take(&mut self.indeg0);
        ops.clear();
        deps_pool.clear();
        out_start.clear();
        out_edges.clear();
        indeg0.clear();
        Program::from_buffers(ops, deps_pool, out_start, out_edges, indeg0)
    }

    /// Reclaim a finished program's buffers for the next build.
    pub fn recycle(&mut self, program: Program) {
        let (ops, deps_pool, out_start, out_edges, indeg0) = program.into_buffers();
        self.ops = ops;
        self.deps_pool = deps_pool;
        self.out_start = out_start;
        self.out_edges = out_edges;
        self.indeg0 = indeg0;
    }

    /// Currently recycled capacity (ops slots), for tests/metrics.
    pub fn ops_capacity(&self) -> usize {
        self.ops.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::breakdown::Component;
    use crate::sim::execute;

    #[test]
    fn buffers_round_trip_and_retain_capacity() {
        let mut arena = ProgramArena::new();
        let mut p = arena.fresh();
        let r = p.resource();
        for _ in 0..1000 {
            p.op(r, 1, 0, Component::Other, 0, 0, &[]);
        }
        p.seal();
        let stats = execute(&p, 0);
        assert_eq!(stats.ops_executed, 1000);
        arena.recycle(p);
        assert!(arena.ops_capacity() >= 1000);

        // The next program starts empty but reuses the allocation.
        let p2 = arena.fresh();
        assert_eq!(p2.num_ops(), 0);
        assert_eq!(p2.num_resources(), 0);
        assert!(!p2.is_sealed());
        arena.recycle(p2);
    }
}
