//! Runtime-breakdown accounting matching the paper's Fig. 3/4 semantics.
//!
//! The paper's bars decompose the makespan of a *representative tile* into
//! components with an explicit overlap priority (footnotes: "⁺Runtime not
//! overlapped with RedMulE. ⁺⁺Runtime not overlapped with either Spatz or
//! RedMulE"). We reproduce that with interval coverage: each component's
//! reported time is the part of its busy intervals not covered by any
//! higher-priority component, and `Other` is the uncovered remainder of the
//! makespan (synchronization, dependency stalls, scheduling overhead).

use super::Cycle;
use crate::util::json::Json;

/// Accounting category of an op. Order here defines the overlap priority
/// used in [`Breakdown::from_intervals`] (earlier = higher priority).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Component {
    /// Matrix-engine (RedMulE) execution.
    RedMule,
    /// Vector-engine (Spatz) execution: scaling, rowmax/rowsum, exp, rescale.
    Spatz,
    /// NoC sum-reduction collectives (softmax denominator, O-slice reduce).
    SumReduce,
    /// NoC max-reduction collectives (softmax row maxima).
    MaxReduce,
    /// NoC multicast collectives (Q row-wise, K/V column-wise, stats).
    Multicast,
    /// HBM loads/stores (DMA transfers to/from main memory).
    HbmAccess,
    /// Synchronization, scheduling and other non-attributed time.
    Other,
}

/// Every component, in breakdown/report order.
pub const ALL_COMPONENTS: [Component; 7] = [
    Component::RedMule,
    Component::Spatz,
    Component::SumReduce,
    Component::MaxReduce,
    Component::Multicast,
    Component::HbmAccess,
    Component::Other,
];

impl Component {
    /// Stable lowercase name.
    pub fn label(self) -> &'static str {
        match self {
            Component::RedMule => "RedMulE",
            Component::Spatz => "Spatz",
            Component::SumReduce => "SumReduce",
            Component::MaxReduce => "MaxReduce",
            Component::Multicast => "Multicast",
            Component::HbmAccess => "HBM",
            Component::Other => "Other",
        }
    }
}

/// Per-component exclusive time (cycles) on the tracked tile.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Breakdown {
    /// RedMulE (matrix) busy cycles.
    pub redmule: Cycle,
    /// Spatz (vector) busy cycles.
    pub spatz: Cycle,
    /// Sum-reduce collective cycles.
    pub sum_reduce: Cycle,
    /// Max-reduce collective cycles.
    pub max_reduce: Cycle,
    /// Multicast collective cycles.
    pub multicast: Cycle,
    /// HBM access cycles.
    pub hbm: Cycle,
    /// Unattributed (sync/scheduling) cycles.
    pub other: Cycle,
}

impl Breakdown {
    /// Cycles of one component.
    pub fn get(&self, c: Component) -> Cycle {
        match c {
            Component::RedMule => self.redmule,
            Component::Spatz => self.spatz,
            Component::SumReduce => self.sum_reduce,
            Component::MaxReduce => self.max_reduce,
            Component::Multicast => self.multicast,
            Component::HbmAccess => self.hbm,
            Component::Other => self.other,
        }
    }

    fn set(&mut self, c: Component, v: Cycle) {
        match c {
            Component::RedMule => self.redmule = v,
            Component::Spatz => self.spatz = v,
            Component::SumReduce => self.sum_reduce = v,
            Component::MaxReduce => self.max_reduce = v,
            Component::Multicast => self.multicast = v,
            Component::HbmAccess => self.hbm = v,
            Component::Other => self.other = v,
        }
    }

    /// Sum over every component.
    pub fn total(&self) -> Cycle {
        ALL_COMPONENTS.iter().map(|&c| self.get(c)).sum()
    }

    /// Compute the priority-ordered exclusive coverage from raw busy
    /// intervals `(component, start, end)` over `[0, makespan)`.
    ///
    /// For each component in priority order, its reported time is the
    /// measure of its intervals minus everything already claimed by
    /// higher-priority components; `Other` absorbs the uncovered rest of
    /// the makespan.
    pub fn from_intervals(intervals: &[(Component, Cycle, Cycle)], makespan: Cycle) -> Breakdown {
        let mut bd = Breakdown::default();
        // Claimed regions so far, kept sorted & disjoint.
        let mut claimed: Vec<(Cycle, Cycle)> = Vec::new();
        for &comp in ALL_COMPONENTS.iter() {
            if comp == Component::Other {
                continue;
            }
            let mut mine: Vec<(Cycle, Cycle)> = intervals
                .iter()
                .filter(|(c, s, e)| *c == comp && e > s)
                .map(|&(_, s, e)| (s, e))
                .collect();
            if mine.is_empty() {
                continue;
            }
            mine.sort_unstable();
            let mine = merge(&mine);
            let exclusive = subtract_measure(&mine, &claimed);
            bd.set(comp, exclusive);
            claimed = union(&claimed, &mine);
        }
        let covered: Cycle = claimed.iter().map(|(s, e)| e - s).sum();
        bd.other = makespan.saturating_sub(covered);
        bd
    }

    /// Serialize as a `label -> cycles` object.
    pub fn to_json(&self) -> Json {
        Json::obj(ALL_COMPONENTS.map(|c| (c.label(), Json::num(self.get(c) as f64))))
    }
}

/// Merge sorted intervals into a disjoint sorted set.
fn merge(sorted: &[(Cycle, Cycle)]) -> Vec<(Cycle, Cycle)> {
    let mut out: Vec<(Cycle, Cycle)> = Vec::with_capacity(sorted.len());
    for &(s, e) in sorted {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// Union of two disjoint-sorted interval sets.
fn union(a: &[(Cycle, Cycle)], b: &[(Cycle, Cycle)]) -> Vec<(Cycle, Cycle)> {
    let mut all: Vec<(Cycle, Cycle)> = a.iter().chain(b.iter()).copied().collect();
    all.sort_unstable();
    merge(&all)
}

/// Total measure of `a` minus (the measure of `a` intersected with `b`),
/// where both are disjoint-sorted.
fn subtract_measure(a: &[(Cycle, Cycle)], b: &[(Cycle, Cycle)]) -> Cycle {
    let mut total: Cycle = a.iter().map(|(s, e)| e - s).sum();
    let mut bi = 0;
    for &(s, e) in a {
        while bi < b.len() && b[bi].1 <= s {
            bi += 1;
        }
        let mut j = bi;
        while j < b.len() && b[j].0 < e {
            let os = b[j].0.max(s);
            let oe = b[j].1.min(e);
            total -= oe - os;
            j += 1;
        }
    }
    total
}

/// Full result of one simulated experiment run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunStats {
    /// End-to-end runtime in cycles.
    pub makespan: Cycle,
    /// Breakdown on the tracked (critical) tile.
    pub breakdown: Breakdown,
    /// Total bytes moved to/from HBM.
    pub hbm_bytes: u64,
    /// Useful FLOPs of the workload (from the program).
    pub flops: u64,
    /// Sum of RedMulE busy cycles over all tiles.
    pub redmule_busy_total: Cycle,
    /// Sum of Spatz busy cycles over all tiles.
    pub spatz_busy_total: Cycle,
    /// Number of ops executed.
    pub ops_executed: usize,
}

impl RunStats {
    /// System-level compute utilization: FLOPs / (makespan × peak).
    /// `peak_flops_per_cycle` is the whole-system peak (all tiles).
    pub fn compute_utilization(&self, peak_flops_per_cycle: u64) -> f64 {
        if self.makespan == 0 {
            return 0.0;
        }
        self.flops as f64 / (self.makespan as f64 * peak_flops_per_cycle as f64)
    }

    /// RedMulE utilization *when active* (Fig. 4 percentage labels):
    /// FLOPs / (total RedMulE busy cycles × per-tile peak).
    pub fn redmule_active_utilization(&self, tile_peak_flops_per_cycle: u64) -> f64 {
        if self.redmule_busy_total == 0 {
            return 0.0;
        }
        self.flops as f64 / (self.redmule_busy_total as f64 * tile_peak_flops_per_cycle as f64)
    }

    /// Average HBM bandwidth utilization over the run.
    pub fn hbm_bw_utilization(&self, peak_bytes_per_cycle: u64) -> f64 {
        if self.makespan == 0 {
            return 0.0;
        }
        self.hbm_bytes as f64 / (self.makespan as f64 * peak_bytes_per_cycle as f64)
    }

    /// Runtime in milliseconds at the given clock.
    pub fn runtime_ms(&self, freq_ghz: f64) -> f64 {
        self.makespan as f64 / (freq_ghz * 1e9) * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coverage_priority() {
        // RedMulE [0,100); Spatz [50,150) -> Spatz exclusive 50;
        // HBM [140,160) -> exclusive 10; makespan 200 -> Other 50.
        let intervals = vec![
            (Component::RedMule, 0, 100),
            (Component::Spatz, 50, 150),
            (Component::HbmAccess, 140, 160),
        ];
        let bd = Breakdown::from_intervals(&intervals, 200);
        assert_eq!(bd.redmule, 100);
        assert_eq!(bd.spatz, 50);
        assert_eq!(bd.hbm, 10);
        assert_eq!(bd.other, 40);
        assert_eq!(bd.total(), 200);
    }

    #[test]
    fn fully_overlapped_disappears() {
        let intervals = vec![
            (Component::RedMule, 0, 100),
            (Component::Multicast, 10, 90),
        ];
        let bd = Breakdown::from_intervals(&intervals, 100);
        assert_eq!(bd.redmule, 100);
        assert_eq!(bd.multicast, 0);
        assert_eq!(bd.other, 0);
    }

    #[test]
    fn disjoint_sums() {
        let intervals = vec![
            (Component::HbmAccess, 0, 10),
            (Component::HbmAccess, 20, 30),
            (Component::RedMule, 40, 50),
        ];
        let bd = Breakdown::from_intervals(&intervals, 60);
        assert_eq!(bd.hbm, 20);
        assert_eq!(bd.redmule, 10);
        assert_eq!(bd.other, 30);
    }

    #[test]
    fn merge_overlapping_same_component() {
        // Two overlapping RedMulE intervals must not double count.
        let intervals = vec![
            (Component::RedMule, 0, 60),
            (Component::RedMule, 50, 100),
        ];
        let bd = Breakdown::from_intervals(&intervals, 100);
        assert_eq!(bd.redmule, 100);
    }

    #[test]
    fn breakdown_total_equals_makespan() {
        // Invariant: breakdown always partitions the makespan.
        let intervals = vec![
            (Component::Spatz, 5, 25),
            (Component::Multicast, 10, 40),
            (Component::SumReduce, 35, 45),
            (Component::MaxReduce, 44, 46),
        ];
        let bd = Breakdown::from_intervals(&intervals, 80);
        assert_eq!(bd.total(), 80);
    }

    #[test]
    fn utilization_math() {
        let stats = RunStats {
            makespan: 1000,
            breakdown: Breakdown::default(),
            hbm_bytes: 64_000,
            flops: 512_000,
            redmule_busy_total: 800,
            spatz_busy_total: 100,
            ops_executed: 10,
        };
        assert!((stats.compute_utilization(1024) - 0.5).abs() < 1e-9);
        assert!((stats.hbm_bw_utilization(128) - 0.5).abs() < 1e-9);
        assert!((stats.redmule_active_utilization(1024) - 0.625).abs() < 1e-9);
    }
}
