//! The seed discrete-event executor, preserved as the reference engine.
//!
//! This is the pre-§Perf engine: it re-derives the dependents CSR on every
//! call and drives completions through a `BinaryHeap` keyed by
//! `(completion time, insertion seq)`. It is retained as the semantic
//! reference for the optimized executor in [`crate::sim::engine`] — the
//! differential test (`tests/engine_differential.rs`) asserts both produce
//! identical `RunStats` and identical traces on randomized DAGs, and the
//! `sim_hotpath` bench uses it as the recorded baseline.
//!
//! One deliberate deviation from the seed (shared with the optimized
//! engine, so the two stay schedule-equivalent): ops becoming ready at the
//! same cycle are scheduled in op-id order via per-timestamp batching —
//! see the `engine` module docs for why symmetry folding requires this.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::breakdown::{Breakdown, Component, RunStats};
use super::engine::TraceRecord;
use super::program::Program;
use super::Cycle;

/// Execute `program` with the seed engine. Same contract as
/// [`crate::sim::execute`].
pub fn execute_reference(program: &Program, tracked_tile: u32) -> RunStats {
    execute_reference_traced(program, tracked_tile, None).0
}

/// Traced variant; same contract as [`crate::sim::execute_traced`].
pub fn execute_reference_traced(
    program: &Program,
    tracked_tile: u32,
    trace_tile_limit: Option<u32>,
) -> (RunStats, Vec<TraceRecord>) {
    let ops = program.ops();
    let n = ops.len();

    // Dependents adjacency in CSR form + in-degrees, rebuilt per call.
    let mut indeg: Vec<u32> = vec![0; n];
    let mut out_count: Vec<u32> = vec![0; n];
    for op in ops {
        for &d in program.deps_of(op) {
            out_count[d as usize] += 1;
        }
    }
    let mut out_start: Vec<u32> = Vec::with_capacity(n + 1);
    let mut acc = 0u32;
    for &c in &out_count {
        out_start.push(acc);
        acc += c;
    }
    out_start.push(acc);
    let mut out_edges: Vec<u32> = vec![0; acc as usize];
    let mut cursor = out_start.clone();
    for (i, op) in ops.iter().enumerate() {
        indeg[i] = op.deps_len;
        for &d in program.deps_of(op) {
            let di = d as usize;
            out_edges[cursor[di] as usize] = i as u32;
            cursor[di] += 1;
        }
    }

    let nr = program.num_resources();
    let mut res_free: Vec<Cycle> = vec![0; nr];

    // Event key: (completion time, seq<<32 | op idx) — 16 bytes,
    // deterministic insertion-order tie-breaking.
    let mut events: BinaryHeap<Reverse<(Cycle, u64)>> = BinaryHeap::new();
    let mut seq: u64 = 0;

    let mut makespan: Cycle = 0;
    let mut hbm_bytes: u64 = 0;
    let mut redmule_busy: Cycle = 0;
    let mut spatz_busy: Cycle = 0;
    let mut executed: usize = 0;
    let mut intervals: Vec<(Component, Cycle, Cycle)> = Vec::new();
    let mut trace: Vec<TraceRecord> = Vec::new();

    macro_rules! schedule {
        ($idx:expr, $now:expr) => {{
            let op_idx: u32 = $idx;
            let op = &ops[op_idx as usize];
            let r = op.resource.0 as usize;
            let start = res_free[r].max($now);
            let released = start + op.occupancy;
            let complete = released + op.latency;
            res_free[r] = released;
            seq += 1;
            events.push(Reverse((complete, (seq << 32) | op_idx as u64)));
            match op.component {
                Component::RedMule => redmule_busy += op.occupancy,
                Component::Spatz => spatz_busy += op.occupancy,
                _ => {}
            }
            hbm_bytes += op.hbm_bytes;
            if op.tile == tracked_tile && complete > $now {
                let from = match op.component {
                    Component::HbmAccess
                    | Component::Multicast
                    | Component::MaxReduce
                    | Component::SumReduce => $now,
                    _ => start,
                };
                intervals.push((op.component, from, complete));
            }
            if let Some(limit) = trace_tile_limit {
                if op.tile < limit {
                    trace.push((op_idx, start, complete));
                }
            }
            executed += 1;
            makespan = makespan.max(complete);
        }};
    }

    macro_rules! settle {
        ($idx:expr, $ready:ident) => {{
            let i = $idx as usize;
            let (s, e) = (out_start[i] as usize, out_start[i + 1] as usize);
            for &dep_idx in &out_edges[s..e] {
                let di = dep_idx as usize;
                indeg[di] -= 1;
                if indeg[di] == 0 {
                    $ready.push(dep_idx);
                }
            }
        }};
    }

    for (i, &d) in indeg.iter().enumerate() {
        if d == 0 {
            schedule!(i as u32, 0);
        }
    }

    // Same-timestamp batch scheduling, identical to the optimized engine.
    let mut completed = 0usize;
    let mut ready_buf: Vec<u32> = Vec::new();
    while let Some(Reverse((now, key))) = events.pop() {
        ready_buf.clear();
        completed += 1;
        settle!((key & 0xFFFF_FFFF) as u32, ready_buf);
        while let Some(&Reverse((t, key2))) = events.peek() {
            if t != now {
                break;
            }
            let _ = events.pop();
            completed += 1;
            settle!((key2 & 0xFFFF_FFFF) as u32, ready_buf);
        }
        ready_buf.sort_unstable();
        for &op_idx in &ready_buf {
            schedule!(op_idx, now);
        }
    }

    assert_eq!(
        completed, n,
        "dependency cycle: {} of {} ops never became ready",
        n - completed,
        n
    );

    let fold = program.fold;
    let breakdown = Breakdown::from_intervals(&intervals, makespan);
    (
        RunStats {
            makespan,
            breakdown,
            hbm_bytes,
            flops: program.flops,
            redmule_busy_total: redmule_busy + fold.redmule_busy,
            spatz_busy_total: spatz_busy + fold.spatz_busy,
            ops_executed: executed + fold.ops as usize,
        },
        trace,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::execute;
    use crate::sim::program::NO_TILE;

    #[test]
    fn reference_matches_engine_on_a_small_dag() {
        let mut p = Program::new();
        let rs = p.resources(3);
        let a = p.op(rs[0], 12, 5, Component::HbmAccess, 0, 96, &[]);
        let b = p.op(rs[1], 8, 0, Component::RedMule, 0, 0, &[a]);
        let c = p.op(rs[1], 8, 0, Component::RedMule, 1, 0, &[a]);
        let d = p.op(rs[2], 3, 0, Component::Spatz, 0, 0, &[b]);
        let _ = p.op(rs[0], 1, 0, Component::Other, NO_TILE, 0, &[c, d]);
        let reference = execute_reference(&p, 0);
        let engine = execute(&p, 0);
        assert_eq!(reference, engine);
        p.seal();
        assert_eq!(reference, execute(&p, 0));
    }
}
